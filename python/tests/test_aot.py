"""AOT export smoke tests: manifest schema + HLO text well-formedness."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.configs import CONFIGS


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entry = aot.export_config(CONFIGS["tiny"], str(out))
    return out, entry


def test_all_artifacts_written(exported):
    out, entry = exported
    for name, art in entry["artifacts"].items():
        path = os.path.join(out, art["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in text


def test_manifest_arg_specs_match_model(exported):
    _, entry = exported
    cfg = CONFIGS["tiny"]
    d = model.flat_len(cfg)
    ts = entry["artifacts"]["train_step"]
    names = [a["name"] for a in ts["args"]]
    assert names == ["params", "m", "v", "z", "u", "wmask", "pmask",
                     "tokens", "step", "lr", "lam"]
    assert ts["args"][0]["shape"] == [d]
    assert ts["args"][7]["dtype"] == "i32"
    assert entry["flat_len"] == d
    assert entry["lora_len"] == model.lora_len(cfg)


def test_manifest_segments_cover_flat_vector(exported):
    _, entry = exported
    off = 0
    for seg in entry["segments"]:
        assert seg["offset"] == off
        n = 1
        for s in seg["shape"]:
            n *= s
        off += n
    assert off == entry["flat_len"]


def test_hlo_text_roundtrips_through_lowering():
    """The exported computation must evaluate identically to the live fn."""
    cfg = CONFIGS["tiny"]
    d = model.flat_len(cfg)
    p = jnp.asarray(model.init_params(cfg))
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(
        0, cfg.vocab, size=(cfg.eval_batch, cfg.seq_len + 1)).astype(np.int32))
    live = model.eval_loss(cfg, p, tok)
    # Round-trip through the text format via the XLA client itself.
    lowered = jax.jit(
        lambda pp, tt: model.eval_loss(cfg, pp, tt)).lower(
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct(tok.shape, jnp.int32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # parse sanity: parameter count and a root tuple are present
    assert text.count("parameter(") >= 2
    assert float(live[1]) == cfg.eval_batch * cfg.seq_len

"""L2 model correctness: layout invariants, training math, LoRA, masking."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.configs import CONFIGS

CFG = CONFIGS["tiny"]


def _batch(cfg, seed=0, extra=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, cfg.vocab,
                     size=(cfg.batch, cfg.seq_len + extra)).astype(np.int32))


def _states(cfg):
    d = model.flat_len(cfg)
    p = jnp.asarray(model.init_params(cfg))
    zeros = jnp.zeros(d)
    return p, zeros, zeros, zeros, zeros, jnp.ones(d), jnp.asarray(
        model.prunable_mask(cfg))


# --------------------------------------------------------------------------
# layout
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(CONFIGS))
def test_layout_contiguous_no_overlap(name):
    cfg = CONFIGS[name]
    segs = model.param_layout(cfg)
    off = 0
    for seg in segs:
        assert seg.offset == off, f"gap/overlap at {seg.name}"
        off += seg.length
    assert off == model.flat_len(cfg)


@pytest.mark.parametrize("name", list(CONFIGS))
def test_layout_prunable_set_is_linears_only(name):
    cfg = CONFIGS[name]
    for seg in model.param_layout(cfg):
        is_linear = any(seg.name.endswith(t) for t in (
            "attn.wq", "attn.wk", "attn.wv", "attn.wo", "mlp.w1", "mlp.w2"))
        assert seg.prunable == is_linear, seg.name


def test_init_deterministic():
    a = model.init_params(CFG, seed=0)
    b = model.init_params(CFG, seed=0)
    c = model.init_params(CFG, seed=1)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_layernorm_segments_init_correctly():
    p = model.init_params(CFG)
    for seg in model.param_layout(CFG):
        view = p[seg.offset:seg.offset + seg.length]
        if seg.init == "ones":
            np.testing.assert_array_equal(view, 1.0)
        elif seg.init == "zeros":
            np.testing.assert_array_equal(view, 0.0)


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------

def test_forward_shapes():
    p, *_ = _states(CFG)
    tok = _batch(CFG, extra=0)
    logits = model.forward(CFG, p, tok)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)


def test_forward_pallas_matches_ref_path():
    p, *_ = _states(CFG)
    tok = _batch(CFG, extra=0)
    a = model.forward(CFG, p, tok, use_pallas=True)
    b = model.forward(CFG, p, tok, use_pallas=False)
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_nll_near_uniform_at_init():
    """A freshly initialized model should score ~log(V) per token."""
    p, *_ = _states(CFG)
    tok = _batch(CFG)
    loss = float(model.nll(CFG, p, tok))
    assert abs(loss - np.log(CFG.vocab)) < 1.0


def test_eval_loss_consistent_with_nll():
    p, *_ = _states(CFG)
    tok = _batch(CFG)
    total, count = model.eval_loss(CFG, p, tok)
    mean = float(model.nll(CFG, p, tok))
    assert abs(float(total) / float(count) - mean) < 1e-5
    assert float(count) == CFG.batch * CFG.seq_len


# --------------------------------------------------------------------------
# train_step
# --------------------------------------------------------------------------

def test_train_step_decreases_loss_on_repeated_batch():
    p, m, v, z, u, wm, pm = _states(CFG)
    tok = _batch(CFG)
    losses = []
    step = jax.jit(lambda *a: model.train_step(CFG, *a))
    for t in range(12):
        p, m, v, loss = step(p, m, v, z, u, wm, pm, tok,
                             float(t + 1), 3e-3, 0.0)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_train_step_masked_coords_stay_zero():
    """Wanda+Full retraining invariant: pruned weights never revive."""
    p, m, v, z, u, wm, pm = _states(CFG)
    rng = np.random.default_rng(0)
    d = model.flat_len(CFG)
    wmask = np.ones(d, dtype=np.float32)
    pmask = np.asarray(model.prunable_mask(CFG))
    dead = (rng.random(d) < 0.5) & (pmask > 0)
    wmask[dead] = 0.0
    p = jnp.asarray(np.where(dead, 0.0, np.asarray(p)))
    wm = jnp.asarray(wmask)
    tok = _batch(CFG)
    step = jax.jit(lambda *a: model.train_step(CFG, *a))
    for t in range(3):
        p, m, v, _ = step(p, m, v, z, u, wm, pm, tok, float(t + 1),
                          1e-3, 0.0)
    assert float(jnp.max(jnp.abs(jnp.asarray(p)[dead]))) == 0.0


def test_train_step_prox_pulls_params_to_z():
    """With a huge lam the prunable params must track z (ADMM coupling)."""
    p, m, v, z, u, wm, pm = _states(CFG)
    tok = _batch(CFG)
    z = jnp.zeros_like(p)  # target: zeros on prunables
    step = jax.jit(lambda *a: model.train_step(CFG, *a))
    pr = pm > 0
    before = float(jnp.mean(jnp.abs(p[pr])))
    for t in range(10):
        p, m, v, _ = step(p, m, v, z, u, wm, pm, tok, float(t + 1),
                          3e-3, 10.0)
    after = float(jnp.mean(jnp.abs(p[pr])))
    # Adam-normalized steps move ~lr per step; 10 steps at 3e-3 must cut
    # a visible fraction of the mean magnitude when lam dominates.
    assert after < before - 0.015, (before, after)


# --------------------------------------------------------------------------
# LoRA
# --------------------------------------------------------------------------

def test_lora_zero_B_is_identity():
    """init_lora zeroes every B, so the adapted forward == base forward."""
    p, *_ = _states(CFG)
    lora = jnp.asarray(model.init_lora(CFG))
    tok = _batch(CFG, extra=0)
    a = model.forward(CFG, p, tok)
    b = model.forward(CFG, p, tok, lora_flat=lora)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_lora_merge_equals_adapted_forward():
    rng = np.random.default_rng(5)
    p, *_ = _states(CFG)
    lora = jnp.asarray(
        rng.normal(0, 0.05, size=model.lora_len(CFG)).astype(np.float32))
    tok = _batch(CFG, extra=0)
    adapted = model.forward(CFG, p, tok, lora_flat=lora)
    merged = model.lora_merge(CFG, p, lora)
    merged_fwd = model.forward(CFG, merged, tok)
    np.testing.assert_allclose(adapted, merged_fwd, atol=1e-4, rtol=1e-4)


def test_lora_train_step_reduces_loss_and_freezes_base():
    p, m, v, z, u, wm, pm = _states(CFG)
    dl = model.lora_len(CFG)
    lora = jnp.asarray(model.init_lora(CFG))
    lm, lv = jnp.zeros(dl), jnp.zeros(dl)
    tok = _batch(CFG)
    step = jax.jit(lambda *a: model.lora_train_step(CFG, *a))
    losses = []
    for t in range(10):
        lora, lm, lv, loss = step(p, lora, lm, lv, wm, tok, float(t + 1),
                                  1e-2)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses


def test_lora_layout_contiguous():
    segs = model.lora_layout(CFG)
    off = 0
    for seg in segs:
        assert seg.offset == off
        off += seg.length
    assert off == model.lora_len(CFG)

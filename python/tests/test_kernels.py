"""L1 kernel correctness: Pallas vs pure-jnp oracles.

Hypothesis sweeps shapes; every kernel is compared elementwise against
ref.py. This is the CORE correctness signal for the compile path — the
same kernels are baked into every exported HLO artifact.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import admm, attention, quant, ref

SET = dict(max_examples=12, deadline=None)


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

@settings(**SET)
@given(
    bh=st.sampled_from([1, 2, 6]),
    seq=st.sampled_from([8, 32, 64, 96, 128]),
    dh=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2 ** 16),
)
def test_attention_matches_ref(bh, seq, dh, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, bh, seq, dh) for _ in range(3))
    out = attention.attention(q, k, v)
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


@settings(**SET)
@given(
    scale=st.floats(0.05, 4.0),
    seed=st.integers(0, 2 ** 16),
)
def test_attention_respects_sm_scale(scale, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, 2, 32, 16) for _ in range(3))
    out = attention.attention(q, k, v, sm_scale=scale)
    expect = ref.attention_ref(q, k, v, sm_scale=scale)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_attention_is_causal():
    """Future tokens must not influence the output at position t."""
    rng = np.random.default_rng(0)
    q, k, v = (_rand(rng, 1, 64, 16) for _ in range(3))
    base = attention.attention(q, k, v)
    # perturb keys/values strictly after position 10
    k2 = k.at[:, 11:, :].add(100.0)
    v2 = v.at[:, 11:, :].add(100.0)
    pert = attention.attention(q, k2, v2)
    np.testing.assert_allclose(base[:, :11], pert[:, :11], atol=1e-4)
    assert float(jnp.max(jnp.abs(base[:, 11:] - pert[:, 11:]))) > 1.0


def test_attention_block_shapes_equivalent():
    """Different VMEM tilings must be numerically identical."""
    rng = np.random.default_rng(1)
    q, k, v = (_rand(rng, 2, 64, 16) for _ in range(3))
    a = attention.attention(q, k, v, blk_q=64, blk_k=64)
    b = attention.attention(q, k, v, blk_q=16, blk_k=16)
    c = attention.attention(q, k, v, blk_q=32, blk_k=32)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(a, c, atol=2e-5, rtol=2e-5)


@settings(**SET)
@given(seed=st.integers(0, 2 ** 16))
def test_attention_vjp_grads_match_ref(seed):
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, 2, 32, 16) for _ in range(3))
    sm = 1.0 / 4.0
    f = lambda q, k, v: jnp.sum(attention.attention_vjp(q, k, v, sm) ** 2)
    g = lambda q, k, v: jnp.sum(ref.attention_ref(q, k, v, sm_scale=sm) ** 2)
    ga = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gb = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-4)


# --------------------------------------------------------------------------
# fused adam + proximal x-update
# --------------------------------------------------------------------------

@settings(**SET)
@given(
    d=st.sampled_from([1, 7, 100, 4096, 5000, 12288]),
    step=st.integers(1, 500),
    lam=st.floats(0.0, 1.0),
    seed=st.integers(0, 2 ** 16),
)
def test_adam_prox_matches_ref(d, step, lam, seed):
    rng = np.random.default_rng(seed)
    p, g, m, z, u = (_rand(rng, d) for _ in range(5))
    v = jnp.abs(_rand(rng, d))  # second moments are non-negative
    pm = jnp.asarray((rng.random(d) < 0.7).astype(np.float32))
    out = admm.adam_prox(p, g, m, v, z, u, pm, step=float(step), lr=1e-3,
                         lam=lam)
    expect = ref.adam_prox_ref(p, g, m, v, z, u, pm, step=float(step),
                               lr=1e-3, lam=lam)
    for a, b in zip(out, expect):
        np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


def test_adam_prox_zero_lam_is_plain_adam():
    """lam=0 must reduce exactly to Adam regardless of z/u."""
    rng = np.random.default_rng(3)
    d = 512
    p, g, m, z, u = (_rand(rng, d) for _ in range(5))
    v = jnp.abs(_rand(rng, d))
    pm = jnp.ones(d)
    a = admm.adam_prox(p, g, m, v, z, u, pm, step=5.0, lr=1e-3, lam=0.0)
    b = admm.adam_prox(p, g, m, v, jnp.zeros(d), jnp.zeros(d), pm,
                       step=5.0, lr=1e-3, lam=0.0)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, atol=1e-7)


def test_adam_prox_penalty_pulls_towards_z():
    """With zero data gradient, the prox term must move p towards z."""
    d = 256
    p = jnp.ones(d)
    z = jnp.full((d,), 3.0)
    g = jnp.zeros(d)
    m = jnp.zeros(d)
    v = jnp.zeros(d)
    u = jnp.zeros(d)
    pm = jnp.ones(d)
    p1, _, _ = admm.adam_prox(p, g, m, v, z, u, pm, step=1.0, lr=1e-2,
                              lam=1.0)
    assert float(jnp.min(p1)) > 1.0  # moved towards z=3


def test_adam_prox_pmask_gates_penalty():
    """pmask=0 coordinates must see a pure Adam step (no prox pull)."""
    rng = np.random.default_rng(4)
    d = 128
    p, g, m, z, u = (_rand(rng, d) for _ in range(5))
    v = jnp.abs(_rand(rng, d))
    pm = jnp.zeros(d)
    with_pen = admm.adam_prox(p, g, m, v, z, u, pm, step=2.0, lr=1e-3,
                              lam=5.0)
    no_pen = admm.adam_prox(p, g, m, v, z, u, pm, step=2.0, lr=1e-3,
                            lam=0.0)
    for a, b in zip(with_pen, no_pen):
        np.testing.assert_allclose(a, b, atol=1e-7)


# --------------------------------------------------------------------------
# quant/dequant cycle
# --------------------------------------------------------------------------

@settings(**SET)
@given(
    d=st.sampled_from([1, 100, 4096, 9000]),
    vmax=st.sampled_from([quant.VMAX_INT8, quant.VMAX_FP8_E4M3]),
    seed=st.integers(0, 2 ** 16),
)
def test_quant_roundtrip_matches_ref(d, vmax, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, d) * 10.0
    remat, codes, scale = quant.quant_roundtrip(x, vmax=vmax)
    expect = ref.quant_ref(x, scale, vmax=vmax)
    np.testing.assert_allclose(remat, expect, atol=1e-6)
    # codes are integers within range
    c = np.asarray(codes)
    assert np.all(c == np.round(c))
    assert np.all(np.abs(c) <= vmax)


@settings(**SET)
@given(seed=st.integers(0, 2 ** 16))
def test_quant_error_bounded_by_half_scale(seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, 2048) * 5.0
    remat, _, scale = quant.quant_roundtrip(x, vmax=quant.VMAX_INT8)
    err = float(jnp.max(jnp.abs(remat - x)))
    assert err <= 0.5 * float(scale) + 1e-6


def test_quant_idempotent():
    """Quantizing an already-quantized tensor must be exact."""
    rng = np.random.default_rng(7)
    x = _rand(rng, 1024)
    r1, _, _ = quant.quant_roundtrip(x)
    r2, _, _ = quant.quant_roundtrip(r1)
    np.testing.assert_allclose(r1, r2, atol=1e-6)


def test_quant_zero_tensor():
    x = jnp.zeros(256)
    remat, codes, scale = quant.quant_roundtrip(x)
    assert float(jnp.max(jnp.abs(remat))) == 0.0
    assert float(scale) == 1.0

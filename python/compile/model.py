"""L2: GPT-style causal LM over a flat f32 parameter vector, plus every
ADMM entry point lowered to HLO by aot.py.

The *flat-parameter calling convention* (DESIGN.md §2) is the backbone of
the surrogate-free formulation: ELSA's z-update is a global top-k over one
vector, so the model exposes its parameters as a single f32[d] argument,
with a static layout table mapping (name, offset, shape, prunable). The
rust coordinator slices the same table for per-layer baseline pruners and
for the sparse inference engine.

Entry points (each lowered once per ModelConfig, see aot.py):

  train_step(flat, m, v, z, u, wmask, pmask, tokens, step, lr, lam)
      -> (flat', m', v', loss)
    One fused HLO: forward on flat*wmask, backward, and the Pallas
    adam_prox kernel (eq. 7). lam=0 + wmask=1 is plain Adam pretraining;
    lam=0 + frozen wmask is the Wanda+Full retraining baseline; lam>0 is
    the ELSA x-update.
  eval_loss(flat, tokens) -> (nll_sum, count)    perplexity evaluation
  logits(flat, tokens)   -> logits               zero-shot scoring + the
                                                 rust-forward numerics check
  lora_train_step / lora_merge                   Wanda+LoRA baseline
"""

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .configs import ModelConfig, ADAM_BETA1, ADAM_BETA2, ADAM_EPS
from .kernels import admm
from .kernels.attention import attention_vjp, attention_ref_vjp


# --------------------------------------------------------------------------
# Parameter layout
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Segment:
    name: str
    offset: int
    shape: tuple
    prunable: bool
    init: str      # "normal" | "zeros" | "ones"

    @property
    def length(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def param_layout(cfg: ModelConfig):
    """Static layout of the flat parameter vector.

    Prunable = the transformer linear weights (wq/wk/wv/wo/w1/w2), the
    standard target set of Wanda/SparseGPT; embeddings, layernorms, biases
    and the LM head are kept dense (non-prunable, zero proximal penalty).
    """
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    segs = []
    off = 0

    def add(name, shape, prunable=False, init="normal"):
        nonlocal off
        seg = Segment(name, off, tuple(shape), prunable, init)
        segs.append(seg)
        off += seg.length

    add("embed", (v, d))
    add("pos", (s, d))
    for i in range(cfg.n_layers):
        p = f"l{i}."
        add(p + "ln1.g", (d,), init="ones")
        add(p + "ln1.b", (d,), init="zeros")
        add(p + "attn.wq", (d, d), prunable=True)
        add(p + "attn.wk", (d, d), prunable=True)
        add(p + "attn.wv", (d, d), prunable=True)
        add(p + "attn.wo", (d, d), prunable=True)
        add(p + "ln2.g", (d,), init="ones")
        add(p + "ln2.b", (d,), init="zeros")
        add(p + "mlp.w1", (d, f), prunable=True)
        add(p + "mlp.b1", (f,), init="zeros")
        add(p + "mlp.w2", (f, d), prunable=True)
        add(p + "mlp.b2", (d,), init="zeros")
    add("lnf.g", (d,), init="ones")
    add("lnf.b", (d,), init="zeros")
    add("head", (d, v))
    return segs


def flat_len(cfg: ModelConfig) -> int:
    segs = param_layout(cfg)
    return segs[-1].offset + segs[-1].length


def prunable_mask(cfg: ModelConfig):
    """0/1 f32 vector marking the prunable coordinates."""
    import numpy as np
    mask = np.zeros((flat_len(cfg),), dtype=np.float32)
    for seg in param_layout(cfg):
        if seg.prunable:
            mask[seg.offset:seg.offset + seg.length] = 1.0
    return mask


def init_params(cfg: ModelConfig, seed: int = 0):
    """Reference initializer (rust model/init mirrors this for tests)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    out = np.zeros((flat_len(cfg),), dtype=np.float32)
    for seg in param_layout(cfg):
        sl = slice(seg.offset, seg.offset + seg.length)
        if seg.init == "ones":
            out[sl] = 1.0
        elif seg.init == "zeros":
            out[sl] = 0.0
        else:
            fan_in = seg.shape[0] if len(seg.shape) == 2 else cfg.d_model
            std = 0.02 if seg.name in ("embed", "pos") else 1.0 / math.sqrt(fan_in)
            out[sl] = rng.normal(0.0, std, size=seg.length).astype(np.float32)
    return out


def _views(cfg: ModelConfig, flat):
    """Materialize named weight arrays from the flat vector (static slices)."""
    w = {}
    for seg in param_layout(cfg):
        w[seg.name] = flat[seg.offset:seg.offset + seg.length].reshape(seg.shape)
    return w


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _block(cfg: ModelConfig, w, prefix, x, attn_fn):
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    sm_scale = 1.0 / math.sqrt(dh)

    # attention
    xa = _layernorm(x, w[prefix + "ln1.g"], w[prefix + "ln1.b"])
    q = xa @ w[prefix + "attn.wq"]
    k = xa @ w[prefix + "attn.wk"]
    v = xa @ w[prefix + "attn.wv"]

    def split(t):  # (B,S,D) -> (B*H, S, Dh)
        return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)

    o = attn_fn(split(q), split(k), split(v), sm_scale)
    o = o.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + o @ w[prefix + "attn.wo"]

    # mlp
    xm = _layernorm(x, w[prefix + "ln2.g"], w[prefix + "ln2.b"])
    hmid = jax.nn.gelu(xm @ w[prefix + "mlp.w1"] + w[prefix + "mlp.b1"])
    x = x + hmid @ w[prefix + "mlp.w2"] + w[prefix + "mlp.b2"]
    return x


def forward(cfg: ModelConfig, flat, tokens, *, use_pallas=True,
            lora_flat=None):
    """tokens: i32 (B, S) -> logits f32 (B, S, V)."""
    attn_fn = attention_vjp if use_pallas else attention_ref_vjp
    w = _views(cfg, flat)
    if lora_flat is not None:
        w = _apply_lora(cfg, w, lora_flat)
    s = tokens.shape[1]
    x = w["embed"][tokens] + w["pos"][:s][None, :, :]
    for i in range(cfg.n_layers):
        x = _block(cfg, w, f"l{i}.", x, attn_fn)
    x = _layernorm(x, w["lnf.g"], w["lnf.b"])
    return x @ w["head"]


def nll(cfg: ModelConfig, flat, tokens, *, use_pallas=True, lora_flat=None):
    """Mean next-token NLL. tokens: i32 (B, S+1)."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, flat, inp, use_pallas=use_pallas,
                     lora_flat=lora_flat)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return -jnp.mean(picked)


# --------------------------------------------------------------------------
# Entry points (AOT-lowered)
# --------------------------------------------------------------------------

def train_step(cfg: ModelConfig, flat, m, v, z, u, wmask, pmask, tokens,
               step, lr, lam, *, use_pallas=True):
    """Fused fwd + bwd + Adam/proximal update (ELSA x-update, eq. 7)."""
    loss, g = jax.value_and_grad(
        lambda p: nll(cfg, p * wmask, tokens, use_pallas=use_pallas))(flat)
    if use_pallas:
        p_new, m_new, v_new = admm.adam_prox(
            flat, g, m, v, z, u, pmask, step=step, lr=lr, lam=lam,
            beta1=ADAM_BETA1, beta2=ADAM_BETA2, eps=ADAM_EPS)
    else:
        from .kernels.ref import adam_prox_ref
        p_new, m_new, v_new = adam_prox_ref(
            flat, g, m, v, z, u, pmask, step=step, lr=lr, lam=lam,
            beta1=ADAM_BETA1, beta2=ADAM_BETA2, eps=ADAM_EPS)
    return p_new, m_new, v_new, loss


def eval_loss(cfg: ModelConfig, flat, tokens, *, use_pallas=True):
    """Summed NLL + token count for exact corpus perplexity aggregation."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, flat, inp, use_pallas=use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    total = -jnp.sum(picked)
    count = jnp.asarray(picked.size, jnp.float32)
    return total, count


# --------------------------------------------------------------------------
# LoRA (Wanda+LoRA retraining baseline, paper §5.2 / Table 2)
# --------------------------------------------------------------------------

LORA_TARGETS = ("attn.wq", "attn.wk", "attn.wv", "attn.wo",
                "mlp.w1", "mlp.w2")
LORA_ALPHA = 8.0


def lora_layout(cfg: ModelConfig):
    """Rank-r adapters (A: din x r, B: r x dout) on every linear target."""
    r = cfg.lora_rank
    segs = []
    off = 0
    for seg in param_layout(cfg):
        if not any(seg.name.endswith(t) for t in LORA_TARGETS):
            continue
        din, dout = seg.shape
        segs.append(Segment(seg.name + ".A", off, (din, r), False, "normal"))
        off += din * r
        segs.append(Segment(seg.name + ".B", off, (r, dout), False, "zeros"))
        off += r * dout
    return segs


def lora_len(cfg: ModelConfig) -> int:
    segs = lora_layout(cfg)
    return segs[-1].offset + segs[-1].length if segs else 0


def init_lora(cfg: ModelConfig, seed: int = 1):
    import numpy as np
    rng = np.random.default_rng(seed)
    out = np.zeros((lora_len(cfg),), dtype=np.float32)
    for seg in lora_layout(cfg):
        if seg.init == "normal":
            std = 1.0 / math.sqrt(seg.shape[0])
            sl = slice(seg.offset, seg.offset + seg.length)
            out[sl] = rng.normal(0.0, std, size=seg.length).astype(np.float32)
    return out


def _apply_lora(cfg: ModelConfig, w, lora_flat):
    lv = {}
    for seg in lora_layout(cfg):
        lv[seg.name] = lora_flat[seg.offset:seg.offset + seg.length].reshape(seg.shape)
    scale = LORA_ALPHA / cfg.lora_rank
    w = dict(w)
    for seg in param_layout(cfg):
        if seg.name + ".A" in lv:
            w[seg.name] = w[seg.name] + scale * (lv[seg.name + ".A"] @ lv[seg.name + ".B"])
    return w


def lora_train_step(cfg: ModelConfig, flat, lora, m, v, wmask, tokens,
                    step, lr, *, use_pallas=True):
    """Adam step on the adapter parameters only; base weights frozen
    (and masked: the Wanda mask stays applied throughout retraining)."""
    loss, g = jax.value_and_grad(
        lambda a: nll(cfg, flat * wmask, tokens, use_pallas=use_pallas,
                      lora_flat=a))(lora)
    zeros = jnp.zeros_like(lora)
    ones = jnp.ones_like(lora)
    if use_pallas:
        l_new, m_new, v_new = admm.adam_prox(
            lora, g, m, v, zeros, zeros, ones, step=step, lr=lr, lam=0.0,
            beta1=ADAM_BETA1, beta2=ADAM_BETA2, eps=ADAM_EPS)
    else:
        from .kernels.ref import adam_prox_ref
        l_new, m_new, v_new = adam_prox_ref(
            lora, g, m, v, zeros, zeros, ones, step=step, lr=lr, lam=0.0,
            beta1=ADAM_BETA1, beta2=ADAM_BETA2, eps=ADAM_EPS)
    return l_new, m_new, v_new, loss


def lora_merge(cfg: ModelConfig, flat, lora):
    """Fold the adapters back into the flat vector (rust pulls the result)."""
    w = _views(cfg, flat)
    wl = _apply_lora(cfg, w, lora)
    parts = [wl[seg.name].reshape(-1) for seg in param_layout(cfg)]
    return jnp.concatenate(parts)

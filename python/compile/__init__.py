"""ELSA compile path: JAX/Pallas authoring, AOT-lowered to HLO text."""

"""AOT exporter: lower every L2 entry point to HLO *text* + manifest.json.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that the crate's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Python runs exactly once: `make artifacts` invokes this module, after
which the rust binary is self-contained. The manifest records, for every
(config, artifact): the HLO file, the argument/output specs, the flat
parameter layout (name/offset/shape/prunable), and the shared Adam
hyperparameters — everything the rust runtime needs to drive the graphs.

Usage: python -m compile.aot --out-dir ../artifacts [--configs tiny,small]
                             [--no-pallas]
"""

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp

from . import model
from .configs import CONFIGS, ADAM_BETA1, ADAM_BETA2, ADAM_EPS
from .kernels import quant


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    from jax._src.lib import xla_client as xc
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def _lower(fn, args):
    return jax.jit(fn).lower(*args)


def export_config(cfg, out_dir, *, use_pallas=True):
    """Lower all entry points for one ModelConfig; returns manifest entry."""
    d = model.flat_len(cfg)
    dl = model.lora_len(cfg)
    b, s, be = cfg.batch, cfg.seq_len, cfg.eval_batch

    f32 = jnp.float32
    i32 = jnp.int32
    vec = lambda n: jax.ShapeDtypeStruct((n,), f32)
    scalar = jax.ShapeDtypeStruct((), f32)
    tok_train = jax.ShapeDtypeStruct((b, s + 1), i32)
    tok_eval = jax.ShapeDtypeStruct((be, s + 1), i32)
    tok_fwd = jax.ShapeDtypeStruct((be, s), i32)

    arts = {}

    def emit(name, lowered, args_spec, outs_spec):
        fname = f"{cfg.name}_{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        t0 = time.time()
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  [{cfg.name}] {name}: {len(text) / 1e6:.2f} MB "
              f"({time.time() - t0:.1f}s)")
        arts[name] = {
            "file": fname,
            "args": args_spec,
            "outputs": outs_spec,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }

    # train_step(flat, m, v, z, u, wmask, pmask, tokens, step, lr, lam)
    emit(
        "train_step",
        _lower(
            lambda p, m, v, z, u, wm, pm, t, st, lr, lam: model.train_step(
                cfg, p, m, v, z, u, wm, pm, t, st, lr, lam,
                use_pallas=use_pallas),
            (vec(d), vec(d), vec(d), vec(d), vec(d), vec(d), vec(d),
             tok_train, scalar, scalar, scalar)),
        [
            {"name": "params", **_spec([d])}, {"name": "m", **_spec([d])},
            {"name": "v", **_spec([d])}, {"name": "z", **_spec([d])},
            {"name": "u", **_spec([d])}, {"name": "wmask", **_spec([d])},
            {"name": "pmask", **_spec([d])},
            {"name": "tokens", **_spec([b, s + 1], "i32")},
            {"name": "step", **_spec([])}, {"name": "lr", **_spec([])},
            {"name": "lam", **_spec([])},
        ],
        [{"name": "params", **_spec([d])}, {"name": "m", **_spec([d])},
         {"name": "v", **_spec([d])}, {"name": "loss", **_spec([])}],
    )

    # eval_loss(flat, tokens) -> (nll_sum, count)
    emit(
        "eval_loss",
        _lower(lambda p, t: model.eval_loss(cfg, p, t, use_pallas=use_pallas),
               (vec(d), tok_eval)),
        [{"name": "params", **_spec([d])},
         {"name": "tokens", **_spec([be, s + 1], "i32")}],
        [{"name": "nll_sum", **_spec([])}, {"name": "count", **_spec([])}],
    )

    # logits(flat, tokens) -> (logits,)
    emit(
        "logits",
        _lower(lambda p, t: (model.forward(cfg, p, t, use_pallas=use_pallas),),
               (vec(d), tok_fwd)),
        [{"name": "params", **_spec([d])},
         {"name": "tokens", **_spec([be, s], "i32")}],
        [{"name": "logits", **_spec([be, s, cfg.vocab])}],
    )

    # lora_train_step(flat, lora, m, v, wmask, tokens, step, lr)
    emit(
        "lora_train_step",
        _lower(
            lambda p, a, m, v, wm, t, st, lr: model.lora_train_step(
                cfg, p, a, m, v, wm, t, st, lr, use_pallas=use_pallas),
            (vec(d), vec(dl), vec(dl), vec(dl), vec(d), tok_train, scalar,
             scalar)),
        [{"name": "params", **_spec([d])}, {"name": "lora", **_spec([dl])},
         {"name": "m", **_spec([dl])}, {"name": "v", **_spec([dl])},
         {"name": "wmask", **_spec([d])},
         {"name": "tokens", **_spec([b, s + 1], "i32")},
         {"name": "step", **_spec([])}, {"name": "lr", **_spec([])}],
        [{"name": "lora", **_spec([dl])}, {"name": "m", **_spec([dl])},
         {"name": "v", **_spec([dl])}, {"name": "loss", **_spec([])}],
    )

    # lora_merge(flat, lora) -> (flat',)
    emit(
        "lora_merge",
        _lower(lambda p, a: (model.lora_merge(cfg, p, a),), (vec(d), vec(dl))),
        [{"name": "params", **_spec([d])}, {"name": "lora", **_spec([dl])}],
        [{"name": "params", **_spec([d])}],
    )

    segs = [
        {"name": sg.name, "offset": sg.offset, "shape": list(sg.shape),
         "prunable": sg.prunable, "init": sg.init}
        for sg in model.param_layout(cfg)
    ]
    lsegs = [
        {"name": sg.name, "offset": sg.offset, "shape": list(sg.shape),
         "init": sg.init}
        for sg in model.lora_layout(cfg)
    ]
    return {
        "vocab": cfg.vocab, "d_model": cfg.d_model,
        "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
        "seq_len": cfg.seq_len, "batch": cfg.batch,
        "eval_batch": cfg.eval_batch, "d_ff": cfg.d_ff,
        "lora_rank": cfg.lora_rank, "lora_alpha": model.LORA_ALPHA,
        "flat_len": d, "lora_len": dl,
        "segments": segs, "lora_segments": lsegs,
        "artifacts": arts,
    }


def export_quant_demo(out_dir):
    """Standalone quant round-trip artifact (cross-checks rust codecs)."""
    n = 8192
    vecspec = jax.ShapeDtypeStruct((n,), jnp.float32)
    lowered = jax.jit(
        lambda x: quant.quant_roundtrip(x, vmax=quant.VMAX_INT8)).lower(vecspec)
    text = to_hlo_text(lowered)
    fname = "quant_roundtrip_int8.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    return {
        "file": fname,
        "args": [{"name": "x", **_spec([n])}],
        "outputs": [{"name": "remat", **_spec([n])},
                    {"name": "codes", **_spec([n])},
                    {"name": "scale", **_spec([])}],
        "vmax": quant.VMAX_INT8, "n": n,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small,med")
    ap.add_argument("--no-pallas", action="store_true",
                    help="build against the jnp oracles (debug only)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {
        "format_version": 1,
        "use_pallas": not args.no_pallas,
        "adam": {"beta1": ADAM_BETA1, "beta2": ADAM_BETA2, "eps": ADAM_EPS},
        "configs": {},
    }
    for name in args.configs.split(","):
        cfg = CONFIGS[name.strip()]
        print(f"exporting config '{cfg.name}' "
              f"(flat_len={model.flat_len(cfg)})")
        manifest["configs"][cfg.name] = export_config(
            cfg, args.out_dir, use_pallas=not args.no_pallas)
    manifest["quant_roundtrip"] = export_quant_demo(args.out_dir)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()

"""Model configurations exported as AOT artifacts.

Scales are chosen for a single-core CPU testbed (see DESIGN.md §3): the
cross-scale story of the paper (Fig 2) is preserved with three sizes. Every
config is lowered to a self-contained set of HLO-text artifacts; the rust
coordinator picks a config by name at run time.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int          # training sequence length (static in the HLO)
    batch: int            # training batch size (static in the HLO)
    eval_batch: int       # batch size of the eval_loss artifact
    d_ff_mult: int = 4
    lora_rank: int = 4

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return self.d_ff_mult * self.d_model


# The three scales used across the experiment suite. `tiny` drives tests
# and the full method x sparsity sweep; `small` is the end-to-end example
# model; `med` is the "largest scale" used for the ELSA-L experiment
# (Fig 5 analogue).
CONFIGS = {
    "tiny": ModelConfig(
        name="tiny", vocab=256, d_model=64, n_layers=2, n_heads=2,
        seq_len=64, batch=8, eval_batch=8,
    ),
    "small": ModelConfig(
        name="small", vocab=512, d_model=128, n_layers=4, n_heads=4,
        seq_len=64, batch=8, eval_batch=8,
    ),
    "med": ModelConfig(
        name="med", vocab=1024, d_model=192, n_layers=6, n_heads=6,
        seq_len=96, batch=8, eval_batch=8,
    ),
}

# Adam hyperparameters shared by every artifact (paper Table 4).
ADAM_BETA1 = 0.9
ADAM_BETA2 = 0.999
ADAM_EPS = 1e-8

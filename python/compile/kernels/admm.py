"""L1 Pallas kernel: fused Adam + ADMM proximal x-update (paper eq. 7).

One elementwise pass over the flat parameter vector fuses: the proximal
penalty gradient lam * pmask * (p - z + u), both Adam moment updates, bias
correction and the parameter step. On real hardware this is the classic
memory-bound optimizer fusion — seven vectors are streamed through VMEM
once per step instead of materializing g_total/m_hat/v_hat intermediates
in HBM (a 7-read/3-write roofline instead of ~16 accesses unfused).

On real TPU this is blocked in (8, 128)-aligned 1-D chunks (BLOCK = 4096
elements) to match lane layout. Under interpret=True the same kernel is
executed with a single whole-vector tile (grid=1): XLA lowers the
interpreted grid loop to a while-loop that carries the FULL output
buffers through every step, making a blocked grid O(d * n_blocks) on CPU
— a 30x regression measured on the 0.9M-param config (see EXPERIMENTS.md
§Perf L2). Scalars (step, lr, lam) arrive as (1,)-shaped operands (read
via s_ref[0]) so the same compiled artifact serves every schedule point.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU tile size (documented/roofline); interpret-mode runs single-tile.
BLOCK = 4096
# lane alignment for the single interpret-mode tile
_ALIGN = 1024
INTERPRET = True


def _block_for(d: int) -> int:
    """Whole-vector tile (padded to lane alignment) for interpret mode."""
    return -(-d // _ALIGN) * _ALIGN


def _adam_prox_kernel(p_ref, g_ref, m_ref, v_ref, z_ref, u_ref, pm_ref,
                      step_ref, lr_ref, lam_ref,
                      po_ref, mo_ref, vo_ref, *, beta1, beta2, eps):
    p = p_ref[...]
    g = g_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    z = z_ref[...]
    u = u_ref[...]
    pm = pm_ref[...]
    step = step_ref[0]
    lr = lr_ref[0]
    lam = lam_ref[0]

    g_total = g + lam * pm * (p - z + u)
    m_new = beta1 * m + (1.0 - beta1) * g_total
    v_new = beta2 * v + (1.0 - beta2) * g_total * g_total
    bc1 = 1.0 - jnp.power(beta1, step)
    bc2 = 1.0 - jnp.power(beta2, step)
    mhat = m_new / bc1
    vhat = v_new / bc2
    po_ref[...] = p - lr * mhat / (jnp.sqrt(vhat) + eps)
    mo_ref[...] = m_new
    vo_ref[...] = v_new


def adam_prox(p, g, m, v, z, u, pmask, *, step, lr, lam,
              beta1=0.9, beta2=0.999, eps=1e-8):
    """Fused x-update over flat f32 vectors (all shape (d,)).

    step/lr/lam may be python floats or 0-d/1-d traced arrays.
    Returns (p_new, m_new, v_new).
    """
    d = p.shape[0]
    block = _block_for(d)
    # Pad to the tile size; pmask padding is 0 so padded lanes are inert.
    pad = (-d) % block
    if pad:
        zpad = jnp.zeros((pad,), p.dtype)
        p, g, m, v, z, u = (jnp.concatenate([a, zpad]) for a in
                            (p, g, m, v, z, u))
        pmask = jnp.concatenate([pmask, zpad])
    dp = p.shape[0]

    as1 = lambda s: jnp.asarray(s, jnp.float32).reshape((1,))
    scalars = (as1(step), as1(lr), as1(lam))

    grid = (dp // block,)
    vec_spec = pl.BlockSpec((block,), lambda i: (i,))
    scal_spec = pl.BlockSpec((1,), lambda i: (0,))
    kernel = functools.partial(_adam_prox_kernel, beta1=beta1, beta2=beta2,
                               eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[vec_spec] * 7 + [scal_spec] * 3,
        out_specs=[vec_spec] * 3,
        out_shape=[jax.ShapeDtypeStruct((dp,), jnp.float32)] * 3,
        interpret=True,
    )(p, g, m, v, z, u, pmask, *scalars)
    p_new, m_new, v_new = out
    if pad:
        p_new, m_new, v_new = p_new[:d], m_new[:d], v_new[:d]
    return p_new, m_new, v_new

"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are deliberately the most direct possible implementations: the
pytest suite asserts `assert_allclose(kernel(...), ref(...))` across shape
sweeps (hypothesis), and the L2 model can be built against either
implementation (`use_pallas` flag) so the whole lowered HLO can be
A/B-checked end to end.
"""

import jax.numpy as jnp


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def attention_ref(q, k, v, *, sm_scale=None):
    """Causal attention oracle.

    q, k, v: (BH, S, Dh) — batch*heads folded into the leading dim.
    Returns (BH, S, Dh).
    """
    _, s, dh = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / (dh ** 0.5)
    scores = jnp.einsum("bqd,bkd->bqk", q, k) * sm_scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None, :, :], scores, jnp.finfo(scores.dtype).min)
    probs = _softmax(scores)
    return jnp.einsum("bqk,bkd->bqd", probs, v)


def adam_prox_ref(p, g, m, v, z, u, pmask, *, step, lr, lam,
                  beta1=0.9, beta2=0.999, eps=1e-8):
    """Fused Adam + ADMM proximal x-update oracle (paper eq. 7).

    Minimizes f(x) + lam/2 ||pmask * (x - z + u)||^2 by one Adam step: the
    proximal penalty gradient lam * pmask * (p - z + u) is added to the
    data gradient g before the moment updates, so the second moment `v`
    recycled as the empirical Fisher (paper §3.2) reflects the full
    augmented objective. Returns (p_new, m_new, v_new).
    """
    g_total = g + lam * pmask * (p - z + u)
    m_new = beta1 * m + (1.0 - beta1) * g_total
    v_new = beta2 * v + (1.0 - beta2) * g_total * g_total
    mhat = m_new / (1.0 - beta1 ** step)
    vhat = v_new / (1.0 - beta2 ** step)
    p_new = p - lr * mhat / (jnp.sqrt(vhat) + eps)
    return p_new, m_new, v_new


def quant_ref(x, scale, *, vmax):
    """Symmetric absmax quant/dequant round-trip oracle (paper eq. 12-13).

    `scale` is computed by the caller as max(|x|)/vmax; the round trip is
    R(Q(x)) = scale * clip(round(x / scale), -vmax, vmax).
    """
    q = jnp.clip(jnp.round(x / scale), -vmax, vmax)
    return scale * q

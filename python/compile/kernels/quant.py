"""L1 Pallas kernel: symmetric absmax quant/dequant cycle (paper eq. 12-13).

ELSA-L stores the auxiliary ADMM states (z, u) in low precision between
outer iterations: Q(x) = (round(x/s), s) with s = max|x| / vmax, and
R(z_q, s) = s * z_q. The kernel implements the elementwise half of the
cycle — the global absmax reduction is a one-pass jnp.max outside (a
two-pass grid reduction on real hardware); the blocked kernel then streams
the vector once, emitting the *rematerialized* value (what the next
high-precision update consumes) plus the quantized codes.

vmax selects the format: 127 -> INT8, 448 -> FP8-E4M3 dynamic range,
57344 -> FP8-E5M2. The rust-side quant/ module mirrors these codecs
natively for the state manager; this artifact is the cross-checked
reference (tests assert rust codec == HLO kernel == ref.quant_ref).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU tile (documented); interpret mode runs one whole-vector tile —
# see admm.py for why a blocked interpreted grid is O(d * n_blocks).
BLOCK = 4096
_ALIGN = 1024


def _block_for(d):
    return -(-d // _ALIGN) * _ALIGN

VMAX_INT8 = 127.0
VMAX_FP8_E4M3 = 448.0
VMAX_FP8_E5M2 = 57344.0


def _quant_kernel(x_ref, s_ref, q_ref, r_ref, *, vmax):
    x = x_ref[...]
    s = s_ref[0]
    q = jnp.clip(jnp.round(x / s), -vmax, vmax)
    q_ref[...] = q
    r_ref[...] = s * q


def quant_roundtrip(x, *, vmax=VMAX_INT8):
    """Quantize-dequantize a flat f32 vector.

    Returns (rematerialized, codes, scale). codes are f32-held integers in
    [-vmax, vmax] (the storage narrowing to int8/fp8 bytes happens in the
    rust state manager; HLO keeps f32 for CPU-PJRT portability).
    """
    d = x.shape[0]
    absmax = jnp.max(jnp.abs(x))
    # Guard the all-zero tensor: scale 1.0 quantizes everything to 0.
    scale = jnp.where(absmax > 0, absmax / vmax, 1.0)

    block = _block_for(d)
    pad = (-d) % block
    xp = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)]) if pad else x
    dp = xp.shape[0]

    vec_spec = pl.BlockSpec((block,), lambda i: (i,))
    scal_spec = pl.BlockSpec((1,), lambda i: (0,))
    kernel = functools.partial(_quant_kernel, vmax=vmax)
    q, r = pl.pallas_call(
        kernel,
        grid=(dp // block,),
        in_specs=[vec_spec, scal_spec],
        out_specs=[vec_spec, vec_spec],
        out_shape=[jax.ShapeDtypeStruct((dp,), jnp.float32)] * 2,
        interpret=True,
    )(xp, scale.reshape((1,)))
    if pad:
        q, r = q[:d], r[:d]
    return r, q, scale

"""L1 Pallas kernel: fused causal attention with online softmax.

Hardware adaptation (DESIGN.md §4): the paper's training runs on A100s
with CUDA flash-attention; the TPU re-think tiles the HBM->VMEM schedule
with BlockSpecs instead of threadblocks. The grid is (batch*heads,
q-blocks); each program holds one (blk_q, d_head) query tile resident in
VMEM and streams (blk_k, d_head) key/value tiles through an online-softmax
accumulator, so the (S, S) score matrix is never materialized. On the MXU
the two inner matmuls are (blk_q x d_head x blk_k) and (blk_q x blk_k x
d_head); with blk_q = blk_k = 128 and bf16 inputs they map one-to-one onto
the 128x128 systolic array (we run fp32 tiles sized to the toy models
here; the roofline discussion lives in EXPERIMENTS.md §Perf).

Executed with interpret=True: the CPU PJRT plugin cannot run Mosaic
custom-calls, and interpret mode lowers the kernel to plain HLO that the
rust runtime executes directly (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Large-negative instead of -inf: keeps exp() well-defined for fully
# masked rows without generating NaNs in interpret mode.
_NEG_BIG = -1e30


def _attn_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale, blk_q, blk_k,
                     seq_len):
    """One program: one (blk_q, dh) query tile vs all key/value tiles."""
    qi = pl.program_id(1)
    q = q_ref[0]  # (blk_q, dh)
    dh = q.shape[-1]
    n_k = seq_len // blk_k

    q_pos = qi * blk_q + jax.lax.iota(jnp.int32, blk_q)  # (blk_q,)

    def body(j, carry):
        acc, m_i, l_i = carry
        k = pl.load(k_ref, (0, pl.ds(j * blk_k, blk_k), slice(None)))
        v = pl.load(v_ref, (0, pl.ds(j * blk_k, blk_k), slice(None)))
        s = jnp.dot(q, k.T) * sm_scale  # (blk_q, blk_k)
        k_pos = j * blk_k + jax.lax.iota(jnp.int32, blk_k)
        causal = k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(causal, s, _NEG_BIG)
        # online softmax update
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(p, v)
        return acc, m_new, l_new

    acc0 = jnp.zeros((blk_q, dh), dtype=jnp.float32)
    m0 = jnp.full((blk_q,), _NEG_BIG, dtype=jnp.float32)
    l0 = jnp.zeros((blk_q,), dtype=jnp.float32)
    # Causality: key tiles beyond this query tile contribute nothing, so
    # the loop stops at the diagonal tile (the HBM->VMEM schedule skips
    # them entirely rather than masking them out).
    n_live = jnp.minimum(qi + 1 if blk_q == blk_k else n_k, n_k)
    acc, m_i, l_i = jax.lax.fori_loop(0, n_live, body, (acc0, m0, l0))
    o_ref[0] = (acc / l_i[:, None]).astype(o_ref.dtype)


def attention(q, k, v, *, sm_scale=None, blk_q=None, blk_k=None):
    """Fused causal attention. q, k, v: (BH, S, Dh) -> (BH, S, Dh)."""
    bh, seq_len, dh = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / (dh ** 0.5)
    if blk_q is None:
        # largest power-of-two tile <= 64 that divides seq_len
        blk_q = 1
        while blk_q < 64 and seq_len % (blk_q * 2) == 0:
            blk_q *= 2
        blk_q = min(blk_q, seq_len)
    if blk_k is None:
        blk_k = blk_q
    assert seq_len % blk_q == 0 and seq_len % blk_k == 0, (
        f"seq_len {seq_len} must tile by blk_q={blk_q}, blk_k={blk_k}")

    grid = (bh, seq_len // blk_q)
    kernel = functools.partial(
        _attn_fwd_kernel, sm_scale=sm_scale, blk_q=blk_q, blk_k=blk_k,
        seq_len=seq_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq_len, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq_len, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq_len, dh), q.dtype),
        interpret=True,
    )(q, k, v)


# --- custom VJP: pallas forward, analytic jnp backward -------------------
#
# Autodiff cannot trace through pallas_call; the backward pass recomputes
# the (tiled-size) probabilities in plain jnp. It lowers into the same HLO
# module as the forward, keeping the whole train_step a single artifact.

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention_vjp(q, k, v, sm_scale):
    return attention(q, k, v, sm_scale=sm_scale)


def _attn_fwd(q, k, v, sm_scale):
    o = attention(q, k, v, sm_scale=sm_scale)
    return o, (q, k, v)


def _attn_bwd(sm_scale, res, do):
    q, k, v = res
    s = jnp.einsum("bqd,bkd->bqk", q, k) * sm_scale
    seq = q.shape[1]
    mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))[None]
    s = jnp.where(mask, s, _NEG_BIG)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)          # (b, q, k)
    dv = jnp.einsum("bqk,bqd->bkd", p, do)
    dp = jnp.einsum("bqd,bkd->bqk", do, v)
    # softmax jacobian: ds = p * (dp - sum(dp * p))
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    ds = jnp.where(mask, ds, 0.0) * sm_scale
    dq = jnp.einsum("bqk,bkd->bqd", ds, k)
    dk = jnp.einsum("bqk,bqd->bkd", ds, q)
    return dq, dk, dv


attention_vjp.defvjp(_attn_fwd, _attn_bwd)


def attention_ref_vjp(q, k, v, sm_scale):
    """Oracle path with the same signature as attention_vjp."""
    return ref.attention_ref(q, k, v, sm_scale=sm_scale)

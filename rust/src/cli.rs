//! Hand-rolled CLI (clap is not in the offline vendor set).
//!
//! Subcommands:
//!   pretrain  --config tiny --steps 300 [--lr 3e-3] [--out ckpt.bin]
//!   prune     --config tiny --method elsa --sparsity 0.9 [...]
//!             one-shot methods (magnitude|wanda|sparsegpt|l-admm|alps|
//!             wanda-owl|...) additionally take [--workers N] (pool
//!             lanes for segment fan-out / per-column sharding;
//!             bit-identical to --workers 1), [--alloc
//!             {uniform,owl,evo,global}] (cross-layer budget
//!             allocation) and [--feedback-rounds R] (held-out-NLL
//!             budget refinement); [--out ckpt.bin] feeds the pruned
//!             checkpoint straight into `serve` (prune → quantize →
//!             serve)
//!   eval      --config tiny --ckpt ckpt.bin [--dataset synth-c4]
//!   generate  --config tiny --ckpt ckpt.bin [--sparse] [--prompt-len 8]
//!   infer     alias of generate; --batch N --threads N serves N
//!             prompts through the batched engine
//!             [--shard-workers M] splits each layer's linears across
//!             M persistent row-band workers per thread (batch 1 rides
//!             the same pool); [--prefill-chunk C] sets the prompt
//!             window of the chunked prefill pass (default 16);
//!             [--prefix-cache {on,off}] toggles the shared-prefix KV
//!             cache (default on); [--quant {none,int8,int4}] decodes
//!             quantized sparse payloads (csr/macko backends only);
//!             [--nm {off,2:4,4:8}] serves N:M structured checkpoints
//!             through the branch-free N:M kernels (csr/macko
//!             backends; pattern verified at build);
//!             [--kernel-path {scalar,unrolled}] forces the kernel
//!             traversal (default unrolled; bit-identical either way);
//!             [--pin-workers {on,off}] pins shard-pool lanes to cores
//!             (default off, best effort, Linux only)
//!   serve     --config tiny --ckpt ckpt.bin --requests 32
//!             --max-slots 8 --threads 4 [--shard-workers M]
//!             [--prefill-chunk C] [--prefix-cache {on,off}]
//!             [--quant {none,int8,int4}] [--nm {off,2:4,4:8}]
//!             [--kernel-path {scalar,unrolled}]
//!             [--pin-workers {on,off}]
//!             [--arrival-gap 2.0] [--deadline STEPS] [--verbose] —
//!             continuous-batching scheduler over a seeded Poisson-ish
//!             request stream (slots × row bands, chunked prompt
//!             prefill, shared-prefix KV reuse)
//!   exp       --id fig2|fig3|...|all [--scale quick|full] [--threads N]
//!   report    --results results/

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed `--key value` flags plus the subcommand name.
#[derive(Debug, Default)]
pub struct Args {
    pub cmd: String,
    flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        if argv.is_empty() {
            bail!("usage: elsa <pretrain|prune|eval|generate|serve|exp|\
                   report> [--key value ...]");
        }
        let mut a = Args { cmd: argv[0].clone(), ..Default::default() };
        let mut i = 1;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got '{}'", argv[i]))?;
            let v = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                i += 1;
                argv[i].clone()
            } else {
                "true".to_string() // boolean flag
            };
            a.flags.insert(k.to_string(), v);
            i += 1;
        }
        Ok(a)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }

    /// Comma-separated usize list, e.g. `--batch-sizes 1,2,4,8`.
    pub fn usize_list_or(&self, key: &str, default: &[usize])
                         -> Result<Vec<usize>> {
        match self.get(key) {
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse::<usize>()
                     .with_context(|| format!("--{key} {v}")))
                .collect(),
            None => Ok(default.to_vec()),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key)
            .with_context(|| format!("missing required flag --{key}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags() {
        let a = Args::parse(&argv(&[
            "prune", "--config", "tiny", "--sparsity", "0.9", "--quiet",
        ]))
        .unwrap();
        assert_eq!(a.cmd, "prune");
        assert_eq!(a.get("config"), Some("tiny"));
        assert_eq!(a.f32_or("sparsity", 0.5).unwrap(), 0.9);
        assert!(a.bool("quiet"));
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv(&["eval"])).unwrap();
        assert_eq!(a.usize_or("steps", 100).unwrap(), 100);
        assert_eq!(a.str_or("config", "tiny"), "tiny");
        assert!(a.require("ckpt").is_err());
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&argv(&["exp", "oops"])).is_err());
        assert!(Args::parse(&argv(&[])).is_err());
    }

    #[test]
    fn negative_number_values() {
        let a = Args::parse(&argv(&["exp", "--id", "fig2"])).unwrap();
        assert_eq!(a.get("id"), Some("fig2"));
    }

    #[test]
    fn usize_list_parsing() {
        let a = Args::parse(&argv(&[
            "infer", "--batch-sizes", "1,2, 4,8",
        ]))
        .unwrap();
        assert_eq!(a.usize_list_or("batch-sizes", &[1]).unwrap(),
                   vec![1, 2, 4, 8]);
        assert_eq!(a.usize_list_or("missing", &[3, 5]).unwrap(),
                   vec![3, 5]);
        let bad = Args::parse(&argv(&["infer", "--batch-sizes", "1,x"]))
            .unwrap();
        assert!(bad.usize_list_or("batch-sizes", &[1]).is_err());
    }
}

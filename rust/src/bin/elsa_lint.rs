//! `elsa-lint` — the repo's invariant linter, run as a blocking CI
//! step: `cargo run --release --bin elsa-lint [root]`.
//!
//! Walks every `.rs` file under `root` (default `rust/src`) and
//! enforces the four static invariants described in
//! `docs/ARCHITECTURE.md` §8: SAFETY-commented `unsafe`, no
//! nondeterminism in kernel/model modules, no allocation in the decode
//! hot path, and no wildcard arms over the format/backend enums. All
//! logic lives in [`elsa::lint`]; this binary is argument parsing and
//! exit-status plumbing.

use std::path::Path;
use std::process::ExitCode;

use elsa::lint::{lint_tree, Config};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().collect();
    if args.iter().any(|a| a == "-h" || a == "--help") {
        eprintln!("usage: elsa-lint [root]   (root defaults to rust/src)");
        return ExitCode::SUCCESS;
    }
    let root = args.get(1).map(String::as_str).unwrap_or("rust/src");
    let violations = match lint_tree(&Config::repo(), Path::new(root)) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("elsa-lint: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    for v in &violations {
        eprintln!("{v}");
    }
    if violations.is_empty() {
        println!("elsa-lint: clean ({root})");
        ExitCode::SUCCESS
    } else {
        eprintln!("elsa-lint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

//! Baseline pruners (the paper's comparators, §5): every method the
//! evaluation tables sweep, implemented over per-layer matrix views +
//! calibration activations from the rust reference forward.
//!
//! All of these are *layer-wise reconstruction/saliency* methods — the
//! practice the paper argues against (§2) — so they share the same
//! skeleton: calibrate once on the dense model, then prune each
//! prunable matrix independently.

pub mod alloc;
pub mod ladmm;
pub mod magnitude;
pub mod sparsegpt;
pub mod wanda;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::coordinator::retrain::{full_retrain, lora_retrain,
                                  RetrainOptions};
use crate::data;
use crate::model::forward::{collect_calibration, CalibSet};
use crate::model::Params;
use crate::runtime::{ConfigEntry, Runtime};

/// Number of calibration sequences (the 128-sequence convention of
/// Frantar & Alistarh 2023, scaled to the CPU testbed).
pub const CALIB_SEQS: usize = 64;

/// Collect calibration statistics for `dense` on `train`.
pub fn calibrate(cfg: &ConfigEntry, dense: &[f32], train: &[u32],
                 seed: u64) -> Result<CalibSet> {
    let params = Params::new(cfg, dense.to_vec());
    let seqs = data::calibration(train, CALIB_SEQS, cfg.seq_len, seed);
    collect_calibration(&params, &seqs)
}

/// One-shot (no gradient) pruning dispatch. `sparsity` is uniform
/// per-layer unless the method carries its own allocation.
pub fn prune_oneshot(rt: &Runtime, cfg: &ConfigEntry, method: &str,
                     dense: &[f32], train: &[u32], sparsity: f64,
                     args: &Args) -> Result<Vec<f32>> {
    let uniform = uniform_alloc(cfg, sparsity);
    match method {
        "magnitude" => magnitude::prune(cfg, dense, &uniform),
        "wanda" => {
            let calib = calibrate(cfg, dense, train, 7)?;
            wanda::prune(cfg, dense, &calib, &uniform)
        }
        "sparsegpt" => {
            let calib = calibrate(cfg, dense, train, 7)?;
            sparsegpt::prune(cfg, dense, &calib, &uniform)
        }
        "l-admm" => {
            let calib = calibrate(cfg, dense, train, 7)?;
            ladmm::prune(cfg, dense, &calib, &uniform,
                         &ladmm::LAdmmOptions::default())
        }
        "alps" => {
            let calib = calibrate(cfg, dense, train, 7)?;
            ladmm::prune(cfg, dense, &calib, &uniform,
                         &ladmm::LAdmmOptions::alps())
        }
        "wanda-owl" => {
            let calib = calibrate(cfg, dense, train, 7)?;
            let alloc = alloc::owl_allocation(cfg, dense, &calib, sparsity);
            wanda::prune(cfg, dense, &calib, &alloc)
        }
        "wanda-full" => {
            let calib = calibrate(cfg, dense, train, 7)?;
            let pruned = wanda::prune(cfg, dense, &calib, &uniform)?;
            let mask = mask_of(cfg, &pruned);
            let opts = RetrainOptions::new(
                args.usize_or("retrain-steps", 500)?,
                args.f32_or("retrain-lr", 1e-3)?);
            let (p, _) = full_retrain(rt, cfg, train, &pruned, &mask,
                                      &opts)?;
            Ok(p)
        }
        "wanda-lora" => {
            let calib = calibrate(cfg, dense, train, 7)?;
            let pruned = wanda::prune(cfg, dense, &calib, &uniform)?;
            let mask = mask_of(cfg, &pruned);
            let opts = RetrainOptions::new(
                args.usize_or("retrain-steps", 500)?,
                args.f32_or("retrain-lr", 3e-3)?);
            let (p, _) = lora_retrain(rt, cfg, train, &pruned, &mask,
                                      &opts)?;
            Ok(p)
        }
        other => bail!("unknown pruning method '{other}'"),
    }
}

/// Uniform per-segment sparsity allocation.
pub fn uniform_alloc(cfg: &ConfigEntry, sparsity: f64)
                     -> BTreeMap<String, f64> {
    cfg.segments
        .iter()
        .filter(|s| s.prunable)
        .map(|s| (s.name.clone(), sparsity))
        .collect()
}

/// Flat keep-mask implied by the zeros of pruned params (prunable
/// segments only; everything else 1).
pub fn mask_of(cfg: &ConfigEntry, params: &[f32]) -> Vec<f32> {
    let mut mask = vec![1.0f32; cfg.flat_len];
    for seg in cfg.segments.iter().filter(|s| s.prunable) {
        for i in seg.offset..seg.end() {
            mask[i] = if params[i] == 0.0 { 0.0 } else { 1.0 };
        }
    }
    mask
}

/// Shared helper: replace the prunable matrices of `dense` with the
/// per-segment matrices produced by `f(segment_name, W, target_sparsity)`.
pub fn map_prunable(cfg: &ConfigEntry, dense: &[f32],
                    alloc: &BTreeMap<String, f64>,
                    mut f: impl FnMut(&str, crate::tensor::Matrix, f64)
                        -> Result<crate::tensor::Matrix>)
                    -> Result<Vec<f32>> {
    let mut out = dense.to_vec();
    let params = Params::new(cfg, dense.to_vec());
    for seg in cfg.segments.iter().filter(|s| s.prunable) {
        let sp = alloc.get(&seg.name).copied().unwrap_or(0.0);
        let w = params.matrix(&seg.name)?;
        let new = f(&seg.name, w, sp)?;
        anyhow::ensure!(new.rows * new.cols == seg.len());
        out[seg.offset..seg.end()].copy_from_slice(&new.data);
    }
    Ok(out)
}

#[cfg(test)]
pub mod test_support {
    use super::*;
    use crate::model::fake_config;
    use crate::util::rng::Rng;

    /// Dense toy params + a calibration set from random walks.
    pub fn toy_setup() -> (ConfigEntry, Vec<f32>, CalibSet) {
        let cfg = fake_config();
        let params = Params::init(&cfg, 3);
        let mut rng = Rng::new(9);
        let seqs: Vec<Vec<u32>> = (0..8)
            .map(|_| (0..8).map(|_| rng.below(16) as u32).collect())
            .collect();
        let calib = collect_calibration(&params, &seqs).unwrap();
        (cfg, params.flat, calib)
    }

    /// Achieved sparsity of a pruned flat vector over prunable segments.
    pub fn sparsity_of(cfg: &ConfigEntry, flat: &[f32]) -> f64 {
        Params::new(cfg, flat.to_vec()).sparsity()
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::*;
    use super::*;

    #[test]
    fn uniform_alloc_covers_prunables() {
        let (cfg, _, _) = toy_setup();
        let a = uniform_alloc(&cfg, 0.5);
        assert_eq!(a.len(),
                   cfg.segments.iter().filter(|s| s.prunable).count());
        assert!(a.values().all(|&v| v == 0.5));
    }

    #[test]
    fn mask_of_tracks_zeros() {
        let (cfg, mut flat, _) = toy_setup();
        let seg = cfg.segment("l0.attn.wq").unwrap().clone();
        flat[seg.offset] = 0.0;
        let m = mask_of(&cfg, &flat);
        assert_eq!(m[seg.offset], 0.0);
        assert_eq!(m[seg.offset + 1], 1.0);
        // non-prunable zeros stay 1 (they are not "pruned")
        let b1 = cfg.segment("l0.mlp.b1").unwrap().clone();
        assert_eq!(m[b1.offset], 1.0);
    }
}

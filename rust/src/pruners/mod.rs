//! Baseline pruners (the paper's comparators, §5): every method the
//! evaluation tables sweep, implemented over per-layer matrix views +
//! calibration activations from the rust reference forward.
//!
//! All of these are *layer-wise reconstruction/saliency* methods — the
//! practice the paper argues against (§2) — so they share the same
//! skeleton: calibrate once on the dense model, then prune each
//! prunable matrix independently.
//!
//! ## Parallel pruning (ISSUE 9)
//!
//! Per-layer pruning is embarrassingly parallel, and every per-layer
//! solver here is additionally independent *per output column* (the
//! comparison group of Wanda/SparseGPT and the ridge systems of
//! L-ADMM/ALPS never mix columns). [`prune_oneshot_core`] therefore
//! builds one persistent [`WorkerPool`] (`--workers N`) and threads it
//! through the solvers:
//!
//!  - magnitude fans whole **segments** across the pool (its top-k is
//!    global per layer, so there is no column axis) via
//!    [`map_prunable_pooled`];
//!  - wanda / sparsegpt / l-admm / alps keep the serial segment walk
//!    and shard **columns** inside each `prune_layer` via
//!    [`shard_columns`], which keeps the pool's one-dispatcher rule
//!    intact (one `run` at a time, never nested).
//!
//! Determinism: a task is one column (or one segment) and runs the
//! exact serial loop body in the exact serial accumulation order;
//! writes are disjoint per task. Which lane runs which task therefore
//! cannot change a single output bit — `--workers N` is bit-identical
//! to `--workers 1` for every method (asserted in
//! `tests/prune_pipeline.rs` and pre-timing in `benches/bench_prune`).
//!
//! ## Cross-layer allocation
//!
//! `--alloc {uniform,owl,evo,global}` plus an optional NLL-feedback
//! refinement (`--feedback-rounds R`) select the per-layer sparsity
//! budgets; see [`alloc`] for the OWL / EvoPress / SparseLLM-style
//! global / UniPruning-style feedback implementations. Every
//! allocation's size-weighted mean sparsity equals the requested
//! target exactly (the budget-accounting bugs fixed in ISSUE 9).

pub mod alloc;
pub mod ladmm;
pub mod magnitude;
pub mod sparsegpt;
pub mod wanda;

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::coordinator::retrain::{full_retrain, lora_retrain,
                                  RetrainOptions};
use crate::data;
use crate::infer::pool::WorkerPool;
use crate::model::forward::{collect_calibration, CalibSet};
use crate::model::Params;
use crate::runtime::{ConfigEntry, Runtime};

/// Number of calibration sequences (the 128-sequence convention of
/// Frantar & Alistarh 2023, scaled to the CPU testbed).
pub const CALIB_SEQS: usize = 64;

/// Collect calibration statistics for `dense` on `train`.
pub fn calibrate(cfg: &ConfigEntry, dense: &[f32], train: &[u32],
                 seed: u64) -> Result<CalibSet> {
    let params = Params::new(cfg, dense.to_vec());
    let seqs = data::calibration(train, CALIB_SEQS, cfg.seq_len, seed);
    collect_calibration(&params, &seqs)
}

/// Cross-layer sparsity allocation mode (`--alloc`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocMode {
    /// Same sparsity for every prunable segment.
    Uniform,
    /// OWL outlier-ratio budgets ([`alloc::owl_allocation`]).
    Owl,
    /// EvoPress-lite evolutionary search
    /// ([`alloc::evopress_allocation`]).
    Evo,
    /// Global saliency ranking across all segments at once
    /// ([`alloc::global_allocation`]).
    Global,
}

impl AllocMode {
    pub fn parse(s: &str) -> Result<AllocMode> {
        Ok(match s {
            "uniform" => AllocMode::Uniform,
            "owl" => AllocMode::Owl,
            "evo" => AllocMode::Evo,
            "global" => AllocMode::Global,
            other => bail!("bad --alloc '{other}' \
                            (expected uniform|owl|evo|global)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AllocMode::Uniform => "uniform",
            AllocMode::Owl => "owl",
            AllocMode::Evo => "evo",
            AllocMode::Global => "global",
        }
    }
}

/// Options for [`prune_oneshot_core`] (the `elsa prune` knobs that do
/// not need a [`Runtime`]).
#[derive(Debug, Clone)]
pub struct PruneOptions {
    /// Pool lanes for segment fan-out / column sharding. 1 = serial;
    /// results are bit-identical for every value.
    pub workers: usize,
    /// Cross-layer budget allocation.
    pub alloc: AllocMode,
    /// Rounds of held-out-NLL budget feedback
    /// ([`alloc::feedback_allocation`]) applied on top of `alloc`.
    pub feedback_rounds: usize,
}

impl Default for PruneOptions {
    fn default() -> Self {
        PruneOptions { workers: 1, alloc: AllocMode::Uniform,
                       feedback_rounds: 0 }
    }
}

impl PruneOptions {
    /// Parse `--workers N --alloc MODE --feedback-rounds R`.
    pub fn from_args(args: &Args) -> Result<PruneOptions> {
        Ok(PruneOptions {
            workers: args.usize_or("workers", 1)?,
            alloc: AllocMode::parse(&args.str_or("alloc", "uniform"))?,
            feedback_rounds: args.usize_or("feedback-rounds", 0)?,
        })
    }
}

/// One-shot (no gradient) pruning dispatch. `sparsity` is uniform
/// per-layer unless the method carries its own allocation. Thin
/// [`Runtime`]-requiring wrapper over [`prune_oneshot_core`]: only the
/// retraining variants (`wanda-full`, `wanda-lora`) touch the HLO
/// path; everything else — including `--workers` / `--alloc` parsing —
/// runs through the core.
pub fn prune_oneshot(rt: &Runtime, cfg: &ConfigEntry, method: &str,
                     dense: &[f32], train: &[u32], sparsity: f64,
                     args: &Args) -> Result<Vec<f32>> {
    let opts = PruneOptions::from_args(args)?;
    match method {
        "wanda-full" => {
            let pruned = prune_oneshot_core(cfg, "wanda", dense, train,
                                            sparsity, &opts)?;
            let mask = mask_of(cfg, &pruned);
            let ropts = RetrainOptions::new(
                args.usize_or("retrain-steps", 500)?,
                args.f32_or("retrain-lr", 1e-3)?);
            let (p, _) = full_retrain(rt, cfg, train, &pruned, &mask,
                                      &ropts)?;
            Ok(p)
        }
        "wanda-lora" => {
            let pruned = prune_oneshot_core(cfg, "wanda", dense, train,
                                            sparsity, &opts)?;
            let mask = mask_of(cfg, &pruned);
            let ropts = RetrainOptions::new(
                args.usize_or("retrain-steps", 500)?,
                args.f32_or("retrain-lr", 3e-3)?);
            let (p, _) = lora_retrain(rt, cfg, train, &pruned, &mask,
                                      &ropts)?;
            Ok(p)
        }
        _ => prune_oneshot_core(cfg, method, dense, train, sparsity,
                                &opts),
    }
}

/// One-shot pruning without a [`Runtime`]: calibrate (if the method or
/// allocation needs it), compute the cross-layer budget, then run the
/// per-layer solver over the shared worker pool. This is the whole
/// prune half of the prune→quantize→serve pipeline, callable from
/// integration tests and benches with no artifacts directory.
pub fn prune_oneshot_core(cfg: &ConfigEntry, method: &str, dense: &[f32],
                          train: &[u32], sparsity: f64,
                          opts: &PruneOptions) -> Result<Vec<f32>> {
    let method_needs_calib = matches!(
        method, "wanda" | "sparsegpt" | "l-admm" | "alps" | "wanda-owl");
    let need_calib = method_needs_calib
        || opts.alloc != AllocMode::Uniform
        || opts.feedback_rounds > 0;
    let calib = if need_calib {
        Some(calibrate(cfg, dense, train, 7)?)
    } else {
        None
    };
    let calib_ref = calib.as_ref();

    // cross-layer budgets: the method's own allocation (wanda-owl)
    // wins, otherwise --alloc picks one; --feedback-rounds refines it.
    let mut allocation = match method {
        "wanda-owl" => alloc::owl_allocation(cfg, dense,
                                             calib_ref.unwrap(),
                                             sparsity)?,
        _ => match opts.alloc {
            AllocMode::Uniform => uniform_alloc(cfg, sparsity),
            AllocMode::Owl => alloc::owl_allocation(
                cfg, dense, calib_ref.unwrap(), sparsity)?,
            AllocMode::Evo => alloc::evopress_allocation(
                cfg, dense, calib_ref.unwrap(), train, sparsity,
                &alloc::EvoOptions::default())?,
            AllocMode::Global => alloc::global_allocation(
                cfg, dense, calib_ref.unwrap(), sparsity)?,
        },
    };
    if opts.feedback_rounds > 0 {
        allocation = alloc::feedback_allocation(
            cfg, dense, calib_ref.unwrap(), train, &allocation, sparsity,
            opts.feedback_rounds)?;
    }

    // one pool for the whole prune; width 1 spawns nothing and every
    // dispatch runs inline (the serial reference path).
    let pool = (opts.workers > 1)
        .then(|| WorkerPool::new(opts.workers));
    let pool = pool.as_ref();

    match method {
        "magnitude" => magnitude::prune_pooled(cfg, dense, &allocation,
                                               pool),
        "wanda" | "wanda-owl" => wanda::prune_pooled(
            cfg, dense, calib_ref.unwrap(), &allocation, pool),
        "sparsegpt" => sparsegpt::prune_pooled(
            cfg, dense, calib_ref.unwrap(), &allocation, pool),
        "l-admm" => ladmm::prune_pooled(
            cfg, dense, calib_ref.unwrap(), &allocation,
            &ladmm::LAdmmOptions::default(), pool),
        "alps" => ladmm::prune_pooled(
            cfg, dense, calib_ref.unwrap(), &allocation,
            &ladmm::LAdmmOptions::alps(), pool),
        other => bail!("unknown pruning method '{other}'"),
    }
}

/// Uniform per-segment sparsity allocation.
pub fn uniform_alloc(cfg: &ConfigEntry, sparsity: f64)
                     -> BTreeMap<String, f64> {
    cfg.segments
        .iter()
        .filter(|s| s.prunable)
        .map(|s| (s.name.clone(), sparsity))
        .collect()
}

/// Flat keep-mask implied by the zeros of pruned params (prunable
/// segments only; everything else 1).
pub fn mask_of(cfg: &ConfigEntry, params: &[f32]) -> Vec<f32> {
    let mut mask = vec![1.0f32; cfg.flat_len];
    for seg in cfg.segments.iter().filter(|s| s.prunable) {
        for i in seg.offset..seg.end() {
            mask[i] = if params[i] == 0.0 { 0.0 } else { 1.0 };
        }
    }
    mask
}

/// Raw-pointer view of an `f32` buffer for *disjoint* writes from pool
/// lanes — the `SendPtr` idiom of `infer/pool.rs` / `sparse/tile.rs`.
/// Sound only because every task writes a set of elements no other
/// task touches (its own column / its own segment range) and the
/// pool's `run` barrier outlives every dereference.
#[derive(Clone, Copy)]
pub(crate) struct MatPtr(pub *mut f32);
// SAFETY: see above — tasks write disjoint element sets, and the
// borrow behind the pointer outlives the dispatch barrier.
unsafe impl Send for MatPtr {}
unsafe impl Sync for MatPtr {}

/// Run `f(c)` for every column `0..cols`, sharded across `pool` when
/// one is given (serial loop otherwise — the reference order). A task
/// is one column and runs the identical loop body either way, so the
/// result is bit-exact for any pool width.
pub(crate) fn shard_columns(pool: Option<&WorkerPool>, cols: usize,
                            f: &(dyn Fn(usize) + Sync)) {
    match pool {
        Some(p) if p.width() > 1 && cols > 1 => p.run(cols, f),
        _ => (0..cols).for_each(f),
    }
}

/// Shared helper: replace the prunable matrices of `dense` with the
/// per-segment matrices produced by `f(segment_name, W, target_sparsity)`.
pub fn map_prunable(cfg: &ConfigEntry, dense: &[f32],
                    alloc: &BTreeMap<String, f64>,
                    mut f: impl FnMut(&str, crate::tensor::Matrix, f64)
                        -> Result<crate::tensor::Matrix>)
                    -> Result<Vec<f32>> {
    let mut out = dense.to_vec();
    let params = Params::new(cfg, dense.to_vec());
    for seg in cfg.segments.iter().filter(|s| s.prunable) {
        let sp = alloc.get(&seg.name).copied().unwrap_or(0.0);
        let w = params.matrix(&seg.name)?;
        let new = f(&seg.name, w, sp)?;
        anyhow::ensure!(new.rows * new.cols == seg.len());
        out[seg.offset..seg.end()].copy_from_slice(&new.data);
    }
    Ok(out)
}

/// [`map_prunable`] with the *segments* fanned out across `pool` — for
/// per-layer closures with no internal parallelism (magnitude's
/// whole-layer top-k). Each task writes only its own segment's
/// disjoint `out[offset..end)` range, so any lane interleaving is
/// bit-identical to the serial walk.
pub fn map_prunable_pooled<F>(cfg: &ConfigEntry, dense: &[f32],
                              alloc: &BTreeMap<String, f64>,
                              pool: Option<&WorkerPool>, f: F)
                              -> Result<Vec<f32>>
where
    F: Fn(&str, crate::tensor::Matrix, f64)
        -> Result<crate::tensor::Matrix> + Sync,
{
    let pool = match pool {
        Some(p) if p.width() > 1 => p,
        _ => return map_prunable(cfg, dense, alloc,
                                 |n, w, sp| f(n, w, sp)),
    };
    let mut out = dense.to_vec();
    let params = Params::new(cfg, dense.to_vec());
    let segs: Vec<_> =
        cfg.segments.iter().filter(|s| s.prunable).cloned().collect();
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let ptr = MatPtr(out.as_mut_ptr());
    let params_ref = &params;
    let f_ref = &f;
    pool.run(segs.len(), &|i| {
        let seg = &segs[i];
        let sp = alloc.get(&seg.name).copied().unwrap_or(0.0);
        let res = params_ref
            .matrix(&seg.name)
            .and_then(|w| f_ref(&seg.name, w, sp));
        match res {
            Ok(new) if new.rows * new.cols == seg.len() => {
                // SAFETY: segments are disjoint ranges of `out`, and
                // the pool barrier keeps `out` alive past every write.
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        new.data.as_ptr(), ptr.0.add(seg.offset),
                        seg.len());
                }
            }
            Ok(_) => errors.lock().unwrap().push(
                format!("{}: pruned size mismatch", seg.name)),
            Err(e) => errors.lock().unwrap().push(
                format!("{}: {e:#}", seg.name)),
        }
    });
    let errs = errors.into_inner().unwrap();
    anyhow::ensure!(errs.is_empty(), "pruning failed: {}",
                    errs.join("; "));
    Ok(out)
}

#[cfg(test)]
pub mod test_support {
    use super::*;
    use crate::model::fake_config;
    use crate::util::rng::Rng;

    /// Dense toy params + a calibration set from random walks.
    pub fn toy_setup() -> (ConfigEntry, Vec<f32>, CalibSet) {
        let cfg = fake_config();
        let params = Params::init(&cfg, 3);
        let mut rng = Rng::new(9);
        let seqs: Vec<Vec<u32>> = (0..8)
            .map(|_| (0..8).map(|_| rng.below(16) as u32).collect())
            .collect();
        let calib = collect_calibration(&params, &seqs).unwrap();
        (cfg, params.flat, calib)
    }

    /// Achieved sparsity of a pruned flat vector over prunable segments.
    pub fn sparsity_of(cfg: &ConfigEntry, flat: &[f32]) -> f64 {
        Params::new(cfg, flat.to_vec()).sparsity()
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::*;
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn uniform_alloc_covers_prunables() {
        let (cfg, _, _) = toy_setup();
        let a = uniform_alloc(&cfg, 0.5);
        assert_eq!(a.len(),
                   cfg.segments.iter().filter(|s| s.prunable).count());
        assert!(a.values().all(|&v| v == 0.5));
    }

    #[test]
    fn mask_of_tracks_zeros() {
        let (cfg, mut flat, _) = toy_setup();
        let seg = cfg.segment("l0.attn.wq").unwrap().clone();
        flat[seg.offset] = 0.0;
        let m = mask_of(&cfg, &flat);
        assert_eq!(m[seg.offset], 0.0);
        assert_eq!(m[seg.offset + 1], 1.0);
        // non-prunable zeros stay 1 (they are not "pruned")
        let b1 = cfg.segment("l0.mlp.b1").unwrap().clone();
        assert_eq!(m[b1.offset], 1.0);
    }

    #[test]
    fn alloc_mode_parses() {
        assert_eq!(AllocMode::parse("uniform").unwrap(),
                   AllocMode::Uniform);
        assert_eq!(AllocMode::parse("owl").unwrap(), AllocMode::Owl);
        assert_eq!(AllocMode::parse("evo").unwrap(), AllocMode::Evo);
        assert_eq!(AllocMode::parse("global").unwrap(),
                   AllocMode::Global);
        assert!(AllocMode::parse("nope").is_err());
        assert_eq!(AllocMode::Global.name(), "global");
    }

    #[test]
    fn prune_options_from_args() {
        let argv: Vec<String> =
            ["prune", "--workers", "4", "--alloc", "global",
             "--feedback-rounds", "2"]
                .iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv).unwrap();
        let o = PruneOptions::from_args(&args).unwrap();
        assert_eq!(o.workers, 4);
        assert_eq!(o.alloc, AllocMode::Global);
        assert_eq!(o.feedback_rounds, 2);
        let d = PruneOptions::default();
        assert_eq!(d.workers, 1);
        assert_eq!(d.alloc, AllocMode::Uniform);
    }

    #[test]
    fn map_prunable_pooled_matches_serial() {
        let (cfg, dense, _) = toy_setup();
        let alloc = uniform_alloc(&cfg, 0.5);
        let negate = |_: &str, mut w: crate::tensor::Matrix, _: f64|
                      -> Result<crate::tensor::Matrix> {
            for x in w.data.iter_mut() {
                *x = -*x;
            }
            Ok(w)
        };
        let serial =
            map_prunable_pooled(&cfg, &dense, &alloc, None, negate)
                .unwrap();
        let pool = WorkerPool::new(4);
        let pooled = map_prunable_pooled(&cfg, &dense, &alloc,
                                         Some(&pool), negate)
            .unwrap();
        assert_eq!(serial, pooled);
        // non-prunable untouched, prunable negated
        let emb = cfg.segment("embed").unwrap().clone();
        assert_eq!(&serial[emb.offset..emb.end()],
                   &dense[emb.offset..emb.end()]);
        let wq = cfg.segment("l0.attn.wq").unwrap().clone();
        assert_eq!(serial[wq.offset], -dense[wq.offset]);
    }

    #[test]
    fn map_prunable_pooled_propagates_errors() {
        let (cfg, dense, _) = toy_setup();
        let alloc = uniform_alloc(&cfg, 0.5);
        let pool = WorkerPool::new(4);
        let err = map_prunable_pooled(
            &cfg, &dense, &alloc, Some(&pool),
            |name, w, _| {
                if name == "l0.attn.wk" {
                    anyhow::bail!("boom");
                }
                Ok(w)
            });
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("l0.attn.wk"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn shard_columns_covers_every_column_once() {
        let hits: Vec<std::sync::atomic::AtomicUsize> =
            (0..37).map(|_| std::sync::atomic::AtomicUsize::new(0))
                   .collect();
        let pool = WorkerPool::new(4);
        shard_columns(Some(&pool), hits.len(), &|c| {
            hits[c].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        for (c, h) in hits.iter().enumerate() {
            assert_eq!(h.load(std::sync::atomic::Ordering::Relaxed), 1,
                       "col {c}");
        }
    }

    #[test]
    fn core_dispatch_magnitude_needs_no_calibration() {
        let (cfg, dense, _) = toy_setup();
        let mut rng = Rng::new(0);
        let train: Vec<u32> =
            (0..512).map(|_| rng.below(16) as u32).collect();
        let p = prune_oneshot_core(&cfg, "magnitude", &dense, &train,
                                   0.5, &PruneOptions::default())
            .unwrap();
        assert!((sparsity_of(&cfg, &p) - 0.5).abs() < 0.05);
        assert!(prune_oneshot_core(&cfg, "nope", &dense, &train, 0.5,
                                   &PruneOptions::default())
                .is_err());
    }
}

//! Magnitude pruning (Han et al. 2015): keep the largest |w| per layer.
//!
//! The top-k is global per layer (no column axis to shard), so the
//! pooled variant fans whole *segments* across the worker pool via
//! [`super::map_prunable_pooled`] — each lane prunes a disjoint layer,
//! which is bit-identical to the serial walk for any pool width.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::infer::pool::WorkerPool;
use crate::runtime::ConfigEntry;
use crate::tensor::select::topk_mask;
use crate::tensor::Matrix;

pub fn prune(cfg: &ConfigEntry, dense: &[f32],
             alloc: &BTreeMap<String, f64>) -> Result<Vec<f32>> {
    prune_pooled(cfg, dense, alloc, None)
}

/// [`prune`] with the prunable segments fanned across `pool`.
pub fn prune_pooled(cfg: &ConfigEntry, dense: &[f32],
                    alloc: &BTreeMap<String, f64>,
                    pool: Option<&WorkerPool>) -> Result<Vec<f32>> {
    super::map_prunable_pooled(cfg, dense, alloc, pool, |_, mut w, sp| {
        let scores: Vec<f32> = w.data.iter().map(|x| x.abs()).collect();
        let keep = ((1.0 - sp) * scores.len() as f64).round() as usize;
        let mask = topk_mask(&scores, keep.min(scores.len()));
        for (x, m) in w.data.iter_mut().zip(mask.iter()) {
            *x *= m;
        }
        Ok(w)
    })
}

/// Score-only variant used by allocation search: returns the keep-mask
/// for one matrix.
pub fn layer_mask(w: &Matrix, sparsity: f64) -> Vec<f32> {
    let scores: Vec<f32> = w.data.iter().map(|x| x.abs()).collect();
    let keep = ((1.0 - sparsity) * scores.len() as f64).round() as usize;
    topk_mask(&scores, keep.min(scores.len()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruners::test_support::*;
    use crate::pruners::uniform_alloc;

    #[test]
    fn hits_target_sparsity() {
        let (cfg, dense, _) = toy_setup();
        for sp in [0.25, 0.5, 0.9] {
            let pruned =
                prune(&cfg, &dense, &uniform_alloc(&cfg, sp)).unwrap();
            assert!((sparsity_of(&cfg, &pruned) - sp).abs() < 0.05,
                    "sp={sp}");
        }
    }

    #[test]
    fn keeps_largest() {
        let (cfg, dense, _) = toy_setup();
        let pruned =
            prune(&cfg, &dense, &uniform_alloc(&cfg, 0.5)).unwrap();
        let seg = cfg.segment("l0.attn.wq").unwrap().clone();
        let orig = &dense[seg.offset..seg.end()];
        let new = &pruned[seg.offset..seg.end()];
        let kept_min = orig
            .iter()
            .zip(new.iter())
            .filter(|(_, n)| **n != 0.0)
            .map(|(o, _)| o.abs())
            .fold(f32::INFINITY, f32::min);
        let pruned_max = orig
            .iter()
            .zip(new.iter())
            .filter(|(_, n)| **n == 0.0)
            .map(|(o, _)| o.abs())
            .fold(0.0f32, f32::max);
        assert!(kept_min >= pruned_max);
    }

    #[test]
    fn pooled_is_bit_identical_to_serial() {
        let (cfg, dense, _) = toy_setup();
        let alloc = uniform_alloc(&cfg, 0.55);
        let serial = prune(&cfg, &dense, &alloc).unwrap();
        for width in [2, 4, 8] {
            let pool = WorkerPool::new(width);
            let pooled =
                prune_pooled(&cfg, &dense, &alloc, Some(&pool)).unwrap();
            assert_eq!(serial, pooled, "width {width}");
        }
    }

    #[test]
    fn nonprunable_untouched() {
        let (cfg, dense, _) = toy_setup();
        let pruned =
            prune(&cfg, &dense, &uniform_alloc(&cfg, 0.9)).unwrap();
        let emb = cfg.segment("embed").unwrap().clone();
        assert_eq!(&dense[emb.offset..emb.end()],
                   &pruned[emb.offset..emb.end()]);
    }
}

//! SparseGPT (Frantar & Alistarh 2023): one-shot pruning with OBS-style
//! error compensation against the damped layer Hessian H = X^T X + eps I.
//!
//! For each prunable (din, dout) matrix: factor H once; walk the input
//! dimension in blocks; inside a block, mark the lowest-saliency weights
//! (w^2 / [H^{-1}]_jj) of each output column, zero them, and fold the
//! incurred error into the not-yet-processed inputs via the H^{-1} rows
//! (the exact OBS update). This is the transposed-but-equivalent form of
//! the original row-major algorithm.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::model::forward::CalibSet;
use crate::runtime::ConfigEntry;
use crate::tensor::linalg::{damp, Cholesky};
use crate::tensor::select::topk_mask;
use crate::tensor::Matrix;

pub const DAMP_EPS: f32 = 0.01;
pub const BLOCK: usize = 32;

pub fn prune(cfg: &ConfigEntry, dense: &[f32], calib: &CalibSet,
             alloc: &BTreeMap<String, f64>) -> Result<Vec<f32>> {
    super::map_prunable(cfg, dense, alloc, |name, w, sp| {
        let stat = calib.get(name)
            .with_context(|| format!("no calibration for {name}"))?;
        prune_layer(&w, &stat.gram, sp)
    })
}

/// Prune one (din, dout) matrix against Hessian proxy `gram` (din, din).
pub fn prune_layer(w: &Matrix, gram: &Matrix, sparsity: f64)
                   -> Result<Matrix> {
    let din = w.rows;
    let dout = w.cols;
    let mut h = gram.clone();
    damp(&mut h, DAMP_EPS);
    let u = upper_chol_of_inverse(&h)?;

    let mut out = w.clone();
    let mut j = 0;
    while j < din {
        let b_end = (j + BLOCK).min(din);
        // saliency of every (input in block, output) weight:
        // score = w^2 / U[j,j]^2, i.e. w^2 / [H_remaining^{-1}]_jj — the
        // exact OBS pruning cost in elimination order.
        for c in 0..dout {
            let mut scores = Vec::with_capacity(b_end - j);
            for r in j..b_end {
                let d = u.at(r, r).max(1e-9);
                let wv = out.at(r, c);
                scores.push(wv * wv / (d * d));
            }
            let keep = ((1.0 - sparsity) * scores.len() as f64).round()
                as usize;
            let mask = topk_mask(&scores, keep.min(scores.len()));
            // sequential zero + OBS compensation onto unprocessed inputs
            for (bi, r) in (j..b_end).enumerate() {
                if mask[bi] > 0.0 {
                    continue;
                }
                let wv = out.at(r, c);
                if wv == 0.0 {
                    continue;
                }
                let d = u.at(r, r).max(1e-9);
                let err = wv / d;
                // the U row encodes the Schur-complement update for the
                // remaining (r.., c) weights; r itself lands on zero
                for r2 in r..din {
                    *out.at_mut(r2, c) -= err * u.at(r, r2);
                }
                *out.at_mut(r, c) = 0.0;
            }
        }
        j = b_end;
    }
    Ok(out)
}

/// Upper-triangular U with H^{-1} = U^T U — SparseGPT's
/// `cholesky(Hinv, upper=True)`, which is exactly the transpose of the
/// standard lower Cholesky factor of H^{-1}. Its diagonal encodes the
/// remaining-set inverse diagonals in elimination order, and its rows
/// carry the Schur-complement updates.
fn upper_chol_of_inverse(h: &Matrix) -> Result<Matrix> {
    let n = h.rows;
    let mut hinv = Cholesky::factor(h)?.inverse();
    // symmetrize + guard tiny drift before the second factorization
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (hinv.at(i, j) + hinv.at(j, i));
            *hinv.at_mut(i, j) = avg;
            *hinv.at_mut(j, i) = avg;
        }
    }
    damp(&mut hinv, 1e-6);
    let l = Cholesky::factor(&hinv)?;
    let mut u = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            u.data[i * n + j] = l.l[j * n + i] as f32; // U = L^T
        }
    }
    Ok(u)
}

/// Frobenius reconstruction error ||X(W' - W)||_F^2 expressed through the
/// gram matrix: trace((W'-W)^T H (W'-W)). Used by tests + ALPS refine.
pub fn recon_error(w_new: &Matrix, w_old: &Matrix, gram: &Matrix) -> f64 {
    let din = w_old.rows;
    let dout = w_old.cols;
    let mut total = 0.0f64;
    let mut delta_col = vec![0.0f32; din];
    for c in 0..dout {
        for r in 0..din {
            delta_col[r] = w_new.at(r, c) - w_old.at(r, c);
        }
        let hd = gram.matvec(&delta_col);
        total += delta_col
            .iter()
            .zip(hd.iter())
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum::<f64>();
    }
    total
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::pruners::magnitude;
    use crate::pruners::test_support::*;
    use crate::pruners::uniform_alloc;
    use crate::util::rng::Rng;

    /// Anisotropic activations (X = G A with spiky diag A): the regime
    /// where Hessian-aware pruning matters. Shared with ladmm tests.
    pub fn correlated_problem(din: usize, dout: usize, rows: usize,
                              seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let g = Matrix::randn(rows, din, 1.0, &mut rng);
        let mut a = Matrix::randn(din, din, 0.3, &mut rng);
        for i in 0..din {
            *a.at_mut(i, i) += if i % 4 == 0 { 3.0 } else { 0.2 };
        }
        let x = g.matmul(&a);
        let w = Matrix::randn(din, dout, 1.0, &mut rng);
        (w, x.gram())
    }

    #[test]
    fn hits_target_sparsity() {
        let (w, gram) = correlated_problem(32, 8, 64, 0);
        let pruned = prune_layer(&w, &gram, 0.5).unwrap();
        let nnz = pruned.nnz();
        let expect = (32 * 8) / 2;
        // OBS updates can create incidental zeros; never fewer than target
        assert!(nnz <= expect, "nnz={nnz}");
        assert!(nnz >= expect - 8, "nnz={nnz}");
    }

    #[test]
    fn beats_same_granularity_magnitude_on_reconstruction() {
        // the point of OBS compensation: lower ||X(W'-W)||^2 than a pure
        // magnitude mask at the same (per-column) selection granularity
        let mut worse = 0;
        for seed in 0..8 {
            let (w, gram) = correlated_problem(24, 6, 48, seed);
            let sg = prune_layer(&w, &gram, 0.6).unwrap();
            let colmag =
                crate::pruners::wanda::prune_layer(&w, &vec![1.0; 24], 0.6);
            let e_sg = recon_error(&sg, &w, &gram);
            let e_mag = recon_error(&colmag, &w, &gram);
            if e_sg >= e_mag {
                worse += 1;
            }
        }
        // greedy block selection with stale scores can occasionally lose
        assert!(worse <= 2, "sparsegpt worse than magnitude {worse}/8");
    }

    #[test]
    fn upper_chol_factorizes_inverse() {
        let (_, gram) = correlated_problem(12, 2, 24, 3);
        let mut h = gram.clone();
        damp(&mut h, DAMP_EPS);
        let u = upper_chol_of_inverse(&h).unwrap();
        // U^T U must equal H^{-1}
        let hinv = Cholesky::factor(&h).unwrap().inverse();
        let utu = u.transpose().matmul(&u);
        let scale = hinv.frob_norm();
        for i in 0..12 {
            for j in 0..12 {
                assert!((utu.at(i, j) - hinv.at(i, j)).abs()
                        < 2e-3 * scale,
                        "({i},{j})");
            }
        }
        // upper triangular
        for i in 0..12 {
            for j in 0..i {
                assert_eq!(u.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn full_pipeline_runs() {
        let (cfg, dense, calib) = toy_setup();
        let pruned =
            prune(&cfg, &dense, &calib, &uniform_alloc(&cfg, 0.5)).unwrap();
        let sp = sparsity_of(&cfg, &pruned);
        assert!(sp >= 0.45 && sp <= 0.65, "sp={sp}");
    }
}

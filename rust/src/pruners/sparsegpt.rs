//! SparseGPT (Frantar & Alistarh 2023): one-shot pruning with OBS-style
//! error compensation against the damped layer Hessian H = X^T X + eps I.
//!
//! For each prunable (din, dout) matrix: factor H once; walk the input
//! dimension in blocks; inside a block, mark the lowest-saliency weights
//! (w^2 / [H^{-1}]_jj) of each output column, zero them, and fold the
//! incurred error into the not-yet-processed inputs via the H^{-1} rows
//! (the exact OBS update). This is the transposed-but-equivalent form of
//! the original row-major algorithm.
//!
//! Budget exactness (ISSUE 9): the keep count is a *cumulative* quota —
//! block [j, b_end) keeps `round((1-sp)·b_end) - round((1-sp)·j)`
//! weights per column, so the per-block rounding errors telescope away
//! and every column's total is `round((1-sp)·din)` exactly, for any
//! sparsity (not just multiples of 1/BLOCK).
//!
//! Parallelism: output columns never interact — each column's
//! elimination reads the shared U factor and its own column of `out` —
//! so [`prune_layer_pooled`] shards the per-block column loop across
//! the worker pool with bit-identical results.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::infer::pool::WorkerPool;
use crate::model::forward::CalibSet;
use crate::pruners::{shard_columns, MatPtr};
use crate::runtime::ConfigEntry;
use crate::tensor::linalg::{damp, Cholesky};
use crate::tensor::select::topk_mask;
use crate::tensor::Matrix;

pub const DAMP_EPS: f32 = 0.01;
pub const BLOCK: usize = 32;

pub fn prune(cfg: &ConfigEntry, dense: &[f32], calib: &CalibSet,
             alloc: &BTreeMap<String, f64>) -> Result<Vec<f32>> {
    prune_pooled(cfg, dense, calib, alloc, None)
}

/// [`prune`] with per-layer column sharding across `pool`.
pub fn prune_pooled(cfg: &ConfigEntry, dense: &[f32], calib: &CalibSet,
                    alloc: &BTreeMap<String, f64>,
                    pool: Option<&WorkerPool>) -> Result<Vec<f32>> {
    super::map_prunable(cfg, dense, alloc, |name, w, sp| {
        let stat = calib.get(name)
            .with_context(|| format!("no calibration for {name}"))?;
        prune_layer_pooled(&w, &stat.gram, sp, pool)
    })
}

/// Prune one (din, dout) matrix against Hessian proxy `gram` (din, din).
pub fn prune_layer(w: &Matrix, gram: &Matrix, sparsity: f64)
                   -> Result<Matrix> {
    prune_layer_pooled(w, gram, sparsity, None)
}

/// [`prune_layer`] with each block's independent per-column
/// elimination sharded over `pool` (serial when `None`; bit-identical
/// either way — a task is one column and runs the serial body).
pub fn prune_layer_pooled(w: &Matrix, gram: &Matrix, sparsity: f64,
                          pool: Option<&WorkerPool>) -> Result<Matrix> {
    let din = w.rows;
    let dout = w.cols;
    let mut h = gram.clone();
    damp(&mut h, DAMP_EPS);
    let u = upper_chol_of_inverse(&h)?;
    let u_ref = &u;

    // cumulative keep quota: everything kept up to input x
    let quota = |x: usize| ((1.0 - sparsity) * x as f64).round() as usize;

    let mut out = w.clone();
    let ptr = MatPtr(out.data.as_mut_ptr());
    let mut j = 0;
    while j < din {
        let b_end = (j + BLOCK).min(din);
        // per-block keep so column totals telescope to quota(din)
        let keep = quota(b_end) - quota(j);
        // saliency of every (input in block, output) weight:
        // score = w^2 / U[j,j]^2, i.e. w^2 / [H_remaining^{-1}]_jj — the
        // exact OBS pruning cost in elimination order.
        shard_columns(pool, dout, &|c| {
            // SAFETY: this task reads and writes only column c of
            // `out`; tasks are disjoint and the shard barrier
            // outlives the borrow.
            let at = |r: usize| unsafe { *ptr.0.add(r * dout + c) };
            let mut scores = Vec::with_capacity(b_end - j);
            for r in j..b_end {
                let d = u_ref.at(r, r).max(1e-9);
                let wv = at(r);
                scores.push(wv * wv / (d * d));
            }
            let mask = topk_mask(&scores, keep.min(scores.len()));
            // sequential zero + OBS compensation onto unprocessed inputs
            for (bi, r) in (j..b_end).enumerate() {
                if mask[bi] > 0.0 {
                    continue;
                }
                let wv = at(r);
                if wv == 0.0 {
                    continue;
                }
                let d = u_ref.at(r, r).max(1e-9);
                let err = wv / d;
                // the U row encodes the Schur-complement update for the
                // remaining (r.., c) weights; r itself lands on zero
                for r2 in r..din {
                    // SAFETY: this task owns column c of `out`
                    // exclusively; `r2 < din` and `c < dout`, so
                    // `r2 * dout + c` is inside the (din, dout)
                    // buffer, and the shard barrier outlives `ptr`.
                    unsafe {
                        *ptr.0.add(r2 * dout + c) -=
                            err * u_ref.at(r, r2);
                    }
                }
                // SAFETY: same disjoint-column ownership as above with
                // `r < din`.
                unsafe {
                    *ptr.0.add(r * dout + c) = 0.0;
                }
            }
        });
        j = b_end;
    }
    Ok(out)
}

/// Upper-triangular U with H^{-1} = U^T U — SparseGPT's
/// `cholesky(Hinv, upper=True)`, which is exactly the transpose of the
/// standard lower Cholesky factor of H^{-1}. Its diagonal encodes the
/// remaining-set inverse diagonals in elimination order, and its rows
/// carry the Schur-complement updates.
fn upper_chol_of_inverse(h: &Matrix) -> Result<Matrix> {
    let n = h.rows;
    let mut hinv = Cholesky::factor(h)?.inverse();
    // symmetrize + guard tiny drift before the second factorization
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (hinv.at(i, j) + hinv.at(j, i));
            *hinv.at_mut(i, j) = avg;
            *hinv.at_mut(j, i) = avg;
        }
    }
    damp(&mut hinv, 1e-6);
    let l = Cholesky::factor(&hinv)?;
    let mut u = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            u.data[i * n + j] = l.l[j * n + i] as f32; // U = L^T
        }
    }
    Ok(u)
}

/// Frobenius reconstruction error ||X(W' - W)||_F^2 expressed through the
/// gram matrix: trace((W'-W)^T H (W'-W)). Used by tests + ALPS refine.
pub fn recon_error(w_new: &Matrix, w_old: &Matrix, gram: &Matrix) -> f64 {
    let din = w_old.rows;
    let dout = w_old.cols;
    let mut total = 0.0f64;
    let mut delta_col = vec![0.0f32; din];
    for c in 0..dout {
        for r in 0..din {
            delta_col[r] = w_new.at(r, c) - w_old.at(r, c);
        }
        let hd = gram.matvec(&delta_col);
        total += delta_col
            .iter()
            .zip(hd.iter())
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum::<f64>();
    }
    total
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::pruners::test_support::*;
    use crate::pruners::uniform_alloc;
    use crate::util::rng::Rng;

    /// Anisotropic activations (X = G A with spiky diag A): the regime
    /// where Hessian-aware pruning matters. Shared with ladmm tests.
    pub fn correlated_problem(din: usize, dout: usize, rows: usize,
                              seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let g = Matrix::randn(rows, din, 1.0, &mut rng);
        let mut a = Matrix::randn(din, din, 0.3, &mut rng);
        for i in 0..din {
            *a.at_mut(i, i) += if i % 4 == 0 { 3.0 } else { 0.2 };
        }
        let x = g.matmul(&a);
        let w = Matrix::randn(din, dout, 1.0, &mut rng);
        (w, x.gram())
    }

    #[test]
    fn hits_target_sparsity() {
        let (w, gram) = correlated_problem(32, 8, 64, 0);
        let pruned = prune_layer(&w, &gram, 0.5).unwrap();
        let nnz = pruned.nnz();
        let expect = (32 * 8) / 2;
        // OBS updates can create incidental zeros; never more than target
        assert!(nnz <= expect, "nnz={nnz}");
        // exact per-column quota (incidental zeros are astronomically
        // unlikely on continuous random data, so equality is expected)
        assert_eq!(nnz, expect, "nnz={nnz}");
    }

    #[test]
    fn per_column_quota_is_exact_for_unaligned_sparsity() {
        // sparsities NOT aligned to 1/BLOCK: per-block independent
        // rounding drifts (0.55 on din=64 gave 0.5625, 0.9 gave
        // 0.90625); the cumulative quota telescopes exactly.
        for (din, sp) in [(64usize, 0.55f64), (64, 0.9), (48, 0.55),
                          (80, 0.7)] {
            let (w, gram) = correlated_problem(din, 6, 2 * din, 1);
            let pruned = prune_layer(&w, &gram, sp).unwrap();
            let expect = ((1.0 - sp) * din as f64).round() as usize;
            for c in 0..6 {
                let kept =
                    (0..din).filter(|&r| pruned.at(r, c) != 0.0).count();
                assert_eq!(kept, expect,
                           "din={din} sp={sp} col={c}");
            }
        }
    }

    #[test]
    fn pooled_layer_is_bit_identical_to_serial() {
        let (w, gram) = correlated_problem(48, 11, 96, 5);
        let serial = prune_layer(&w, &gram, 0.55).unwrap();
        for width in [2, 4, 8] {
            let pool = WorkerPool::new(width);
            let pooled =
                prune_layer_pooled(&w, &gram, 0.55, Some(&pool)).unwrap();
            assert_eq!(serial, pooled, "width {width}");
        }
    }

    #[test]
    // 8-seed statistical sweep of full prunes — out of Miri's budget;
    // pooled_layer_is_bit_identical_to_serial carries the unsafe-path
    // coverage under Miri
    #[cfg_attr(miri, ignore)]
    fn beats_same_granularity_magnitude_on_reconstruction() {
        // the point of OBS compensation: lower ||X(W'-W)||^2 than a pure
        // magnitude mask at the same (per-column) selection granularity
        let mut worse = 0;
        for seed in 0..8 {
            let (w, gram) = correlated_problem(24, 6, 48, seed);
            let sg = prune_layer(&w, &gram, 0.6).unwrap();
            let colmag =
                crate::pruners::wanda::prune_layer(&w, &vec![1.0; 24], 0.6);
            let e_sg = recon_error(&sg, &w, &gram);
            let e_mag = recon_error(&colmag, &w, &gram);
            if e_sg >= e_mag {
                worse += 1;
            }
        }
        // greedy block selection with stale scores can occasionally lose
        assert!(worse <= 2, "sparsegpt worse than magnitude {worse}/8");
    }

    #[test]
    fn upper_chol_factorizes_inverse() {
        let (_, gram) = correlated_problem(12, 2, 24, 3);
        let mut h = gram.clone();
        damp(&mut h, DAMP_EPS);
        let u = upper_chol_of_inverse(&h).unwrap();
        // U^T U must equal H^{-1}
        let hinv = Cholesky::factor(&h).unwrap().inverse();
        let utu = u.transpose().matmul(&u);
        let scale = hinv.frob_norm();
        for i in 0..12 {
            for j in 0..12 {
                assert!((utu.at(i, j) - hinv.at(i, j)).abs()
                        < 2e-3 * scale,
                        "({i},{j})");
            }
        }
        // upper triangular
        for i in 0..12 {
            for j in 0..i {
                assert_eq!(u.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn full_pipeline_runs() {
        let (cfg, dense, calib) = toy_setup();
        let pruned =
            prune(&cfg, &dense, &calib, &uniform_alloc(&cfg, 0.5)).unwrap();
        let sp = sparsity_of(&cfg, &pruned);
        assert!(sp >= 0.45 && sp <= 0.65, "sp={sp}");
    }
}

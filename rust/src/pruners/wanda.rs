//! Wanda (Sun et al. 2024): prune by |W_ij| * ||X_i||_2, compared within
//! each output's input group — no weight update, only calibration norms.
//!
//! Our weights are stored (din, dout) for x @ W, so the comparison group
//! for output neuron c is column c, and the activation norm indexes the
//! *row* (input feature) i. Columns are fully independent, so
//! [`prune_layer_pooled`] shards them across the worker pool with
//! bit-identical results (each task runs the serial per-column body and
//! writes only its own column).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::infer::pool::WorkerPool;
use crate::model::forward::CalibSet;
use crate::pruners::{shard_columns, MatPtr};
use crate::runtime::ConfigEntry;
use crate::tensor::select::topk_mask;
use crate::tensor::Matrix;

pub fn prune(cfg: &ConfigEntry, dense: &[f32], calib: &CalibSet,
             alloc: &BTreeMap<String, f64>) -> Result<Vec<f32>> {
    prune_pooled(cfg, dense, calib, alloc, None)
}

/// [`prune`] with per-layer column sharding across `pool`.
pub fn prune_pooled(cfg: &ConfigEntry, dense: &[f32], calib: &CalibSet,
                    alloc: &BTreeMap<String, f64>,
                    pool: Option<&WorkerPool>) -> Result<Vec<f32>> {
    super::map_prunable(cfg, dense, alloc, |name, w, sp| {
        let stat = calib.get(name)
            .with_context(|| format!("no calibration for {name}"))?;
        Ok(prune_layer_pooled(&w, &stat.col_norms(), sp, pool))
    })
}

/// Prune one (din, dout) matrix given input-feature norms (len din).
pub fn prune_layer(w: &Matrix, xnorms: &[f32], sparsity: f64) -> Matrix {
    prune_layer_pooled(w, xnorms, sparsity, None)
}

/// [`prune_layer`] with the per-column mask work sharded over `pool`
/// (serial when `None` — same loop body, same bits either way).
pub fn prune_layer_pooled(w: &Matrix, xnorms: &[f32], sparsity: f64,
                          pool: Option<&WorkerPool>) -> Matrix {
    assert_eq!(xnorms.len(), w.rows);
    let mut out = w.clone();
    let keep_per_col =
        ((1.0 - sparsity) * w.rows as f64).round() as usize;
    let cols = w.cols;
    let ptr = MatPtr(out.data.as_mut_ptr());
    shard_columns(pool, cols, &|c| {
        let mut col_scores = vec![0.0f32; w.rows];
        for r in 0..w.rows {
            col_scores[r] = w.at(r, c).abs() * xnorms[r];
        }
        let mask = topk_mask(&col_scores, keep_per_col.min(w.rows));
        for r in 0..w.rows {
            if mask[r] == 0.0 {
                // SAFETY: this task owns column c; writes are disjoint
                // and the shard barrier outlives the borrow of `out`.
                unsafe {
                    *ptr.0.add(r * cols + c) = 0.0;
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruners::test_support::*;
    use crate::pruners::uniform_alloc;
    use crate::util::rng::Rng;

    #[test]
    fn hits_target_sparsity() {
        let (cfg, dense, calib) = toy_setup();
        let pruned =
            prune(&cfg, &dense, &calib, &uniform_alloc(&cfg, 0.5)).unwrap();
        assert!((sparsity_of(&cfg, &pruned) - 0.5).abs() < 0.05);
    }

    #[test]
    fn activation_norms_matter() {
        // identical weights, one input feature with huge activations:
        // its weights must survive
        let mut rng = Rng::new(0);
        let w = Matrix::randn(8, 4, 1.0, &mut rng);
        let mut xn = vec![1.0f32; 8];
        xn[3] = 1e4;
        let pruned = prune_layer(&w, &xn, 0.5);
        for c in 0..4 {
            assert!(pruned.at(3, c) != 0.0, "high-activation row pruned");
        }
    }

    #[test]
    fn per_output_group_budget() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(16, 5, 1.0, &mut rng);
        let xn = vec![1.0f32; 16];
        let pruned = prune_layer(&w, &xn, 0.75);
        for c in 0..5 {
            let kept = (0..16).filter(|&r| pruned.at(r, c) != 0.0).count();
            assert_eq!(kept, 4, "col {c}");
        }
    }

    #[test]
    fn pooled_layer_is_bit_identical_to_serial() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(24, 17, 1.0, &mut rng);
        let xn: Vec<f32> = (0..24).map(|i| 0.5 + (i % 5) as f32).collect();
        let serial = prune_layer(&w, &xn, 0.6);
        for width in [2, 4, 8] {
            let pool = WorkerPool::new(width);
            let pooled = prune_layer_pooled(&w, &xn, 0.6, Some(&pool));
            assert_eq!(serial, pooled, "width {width}");
        }
    }
}

//! Wanda (Sun et al. 2024): prune by |W_ij| * ||X_i||_2, compared within
//! each output's input group — no weight update, only calibration norms.
//!
//! Our weights are stored (din, dout) for x @ W, so the comparison group
//! for output neuron c is column c, and the activation norm indexes the
//! *row* (input feature) i.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::model::forward::CalibSet;
use crate::runtime::ConfigEntry;
use crate::tensor::select::topk_mask;
use crate::tensor::Matrix;

pub fn prune(cfg: &ConfigEntry, dense: &[f32], calib: &CalibSet,
             alloc: &BTreeMap<String, f64>) -> Result<Vec<f32>> {
    super::map_prunable(cfg, dense, alloc, |name, w, sp| {
        let stat = calib.get(name)
            .with_context(|| format!("no calibration for {name}"))?;
        Ok(prune_layer(&w, &stat.col_norms(), sp))
    })
}

/// Prune one (din, dout) matrix given input-feature norms (len din).
pub fn prune_layer(w: &Matrix, xnorms: &[f32], sparsity: f64) -> Matrix {
    assert_eq!(xnorms.len(), w.rows);
    let mut out = w.clone();
    let keep_per_col =
        ((1.0 - sparsity) * w.rows as f64).round() as usize;
    let mut col_scores = vec![0.0f32; w.rows];
    for c in 0..w.cols {
        for r in 0..w.rows {
            col_scores[r] = w.at(r, c).abs() * xnorms[r];
        }
        let mask = topk_mask(&col_scores, keep_per_col.min(w.rows));
        for r in 0..w.rows {
            if mask[r] == 0.0 {
                *out.at_mut(r, c) = 0.0;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruners::test_support::*;
    use crate::pruners::uniform_alloc;
    use crate::util::rng::Rng;

    #[test]
    fn hits_target_sparsity() {
        let (cfg, dense, calib) = toy_setup();
        let pruned =
            prune(&cfg, &dense, &calib, &uniform_alloc(&cfg, 0.5)).unwrap();
        assert!((sparsity_of(&cfg, &pruned) - 0.5).abs() < 0.05);
    }

    #[test]
    fn activation_norms_matter() {
        // identical weights, one input feature with huge activations:
        // its weights must survive
        let mut rng = Rng::new(0);
        let w = Matrix::randn(8, 4, 1.0, &mut rng);
        let mut xn = vec![1.0f32; 8];
        xn[3] = 1e4;
        let pruned = prune_layer(&w, &xn, 0.5);
        for c in 0..4 {
            assert!(pruned.at(3, c) != 0.0, "high-activation row pruned");
        }
    }

    #[test]
    fn per_output_group_budget() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(16, 5, 1.0, &mut rng);
        let xn = vec![1.0f32; 16];
        let pruned = prune_layer(&w, &xn, 0.75);
        for c in 0..5 {
            let kept = (0..16).filter(|&r| pruned.at(r, c) != 0.0).count();
            assert_eq!(kept, 4, "col {c}");
        }
    }
}

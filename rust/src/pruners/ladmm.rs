//! Layer-wise ADMM reconstruction solvers: L-ADMM (Boža 2024) and the
//! ALPS preset (Meng et al. 2024).
//!
//! Both minimize the layer reconstruction error ||X W - X W0||_F^2
//! subject to per-layer sparsity, by ADMM over W with exact ridge
//! W-updates:
//!     W  <- (H + rho I)^{-1} (H W0 + rho (Z - U))
//!     Z  <- Pi_S(W + U)          (magnitude projection)
//!     U  <- U + W - Z
//! L-ADMM runs a fixed rho; ALPS ramps rho and finishes with an
//! OBS-compensated backsolve on the final support (its "optimal weight
//! update" step). These are the strongest layer-wise baselines in the
//! paper's tables — and still collapse at extreme sparsity, which is the
//! paper's point.
//!
//! Parallelism: the W-update is one ridge solve per output column
//! against the *shared* Cholesky factor of (H + rho I), and the ALPS
//! refinement is one support-restricted solve per column — both fully
//! column-independent, so [`prune_layer_pooled`] shards them across
//! the worker pool bit-identically. The Z-update's magnitude
//! projection is global over the whole matrix and stays serial.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::infer::pool::WorkerPool;
use crate::model::forward::CalibSet;
use crate::pruners::{shard_columns, MatPtr};
use crate::runtime::ConfigEntry;
use crate::tensor::linalg::{damp, Cholesky};
use crate::tensor::select::topk_mask;
use crate::tensor::Matrix;

#[derive(Debug, Clone)]
pub struct LAdmmOptions {
    pub iters: usize,
    pub rho: f32,
    /// multiply rho by this factor each iteration (ALPS ramp)
    pub rho_growth: f32,
    /// OBS-compensated solve on the final support (ALPS refinement)
    pub obs_refine: bool,
}

impl Default for LAdmmOptions {
    fn default() -> Self {
        LAdmmOptions { iters: 12, rho: 0.1, rho_growth: 1.0,
                       obs_refine: false }
    }
}

impl LAdmmOptions {
    pub fn alps() -> Self {
        LAdmmOptions { iters: 16, rho: 0.03, rho_growth: 1.3,
                       obs_refine: true }
    }
}

pub fn prune(cfg: &ConfigEntry, dense: &[f32], calib: &CalibSet,
             alloc: &BTreeMap<String, f64>, opts: &LAdmmOptions)
             -> Result<Vec<f32>> {
    prune_pooled(cfg, dense, calib, alloc, opts, None)
}

/// [`prune`] with per-layer column sharding across `pool`.
pub fn prune_pooled(cfg: &ConfigEntry, dense: &[f32], calib: &CalibSet,
                    alloc: &BTreeMap<String, f64>, opts: &LAdmmOptions,
                    pool: Option<&WorkerPool>) -> Result<Vec<f32>> {
    super::map_prunable(cfg, dense, alloc, |name, w, sp| {
        let stat = calib.get(name)
            .with_context(|| format!("no calibration for {name}"))?;
        prune_layer_pooled(&w, &stat.gram, sp, opts, pool)
    })
}

/// Layer-wise ADMM on one (din, dout) matrix.
pub fn prune_layer(w0: &Matrix, gram: &Matrix, sparsity: f64,
                   opts: &LAdmmOptions) -> Result<Matrix> {
    prune_layer_pooled(w0, gram, sparsity, opts, None)
}

/// [`prune_layer`] with the per-column ridge solves (and the ALPS
/// support refinement) sharded over `pool` — bit-identical to serial.
pub fn prune_layer_pooled(w0: &Matrix, gram: &Matrix, sparsity: f64,
                          opts: &LAdmmOptions, pool: Option<&WorkerPool>)
                          -> Result<Matrix> {
    let din = w0.rows;
    let dout = w0.cols;
    let mut h = gram.clone();
    damp(&mut h, 0.01);

    let mut w = w0.clone();
    let mut z = project_magnitude(&w, sparsity);
    let mut u = Matrix::zeros(din, dout);
    let mut rho = opts.rho * mean_diag(&h);

    for _ in 0..opts.iters {
        // W-update: ridge solve per output column
        let mut a = h.clone();
        for i in 0..din {
            *a.at_mut(i, i) += rho;
        }
        let ch = Cholesky::factor(&a)?;
        // rhs = H w0_col + rho (z - u)_col, one independent solve per
        // column against the shared factor
        {
            let ptr = MatPtr(w.data.as_mut_ptr());
            let (h_ref, ch_ref, z_ref, u_ref) = (&h, &ch, &z, &u);
            shard_columns(pool, dout, &|c| {
                let mut w0_col = vec![0.0f32; din];
                let mut zu_col = vec![0.0f32; din];
                for r in 0..din {
                    w0_col[r] = w0.at(r, c);
                    zu_col[r] = z_ref.at(r, c) - u_ref.at(r, c);
                }
                let mut rhs = h_ref.matvec(&w0_col);
                for r in 0..din {
                    rhs[r] += rho * zu_col[r];
                }
                let sol = ch_ref.solve(&rhs);
                for r in 0..din {
                    // SAFETY: this task owns column c of `w`; writes
                    // are disjoint and the barrier outlives the borrow.
                    unsafe {
                        *ptr.0.add(r * dout + c) = sol[r];
                    }
                }
            });
        }
        // Z-update + dual ascent
        let wu = add(&w, &u);
        z = project_magnitude(&wu, sparsity);
        for i in 0..u.data.len() {
            u.data[i] += w.data[i] - z.data[i];
        }
        rho *= opts.rho_growth;
    }

    if opts.obs_refine {
        refine_on_support(w0, &h, &z, pool)
    } else {
        // Return the primal W restricted to the converged support: z's
        // values still carry the (scaled) dual u, which is only a valid
        // weight estimate at exact convergence; W on supp(z) is the
        // consistent finite-iteration answer (Boza 2024 runs the same
        // masked retrieval).
        let mut out = w;
        for i in 0..out.data.len() {
            if z.data[i] == 0.0 {
                out.data[i] = 0.0;
            }
        }
        Ok(out)
    }
}

/// Ridge regression restricted to the kept support of each column
/// (solve the small SPD system over the support indices). Columns are
/// independent and shard across `pool`; a failed per-column
/// factorization is collected and surfaced after the barrier.
fn refine_on_support(w0: &Matrix, h: &Matrix, z: &Matrix,
                     pool: Option<&WorkerPool>) -> Result<Matrix> {
    let din = w0.rows;
    let dout = w0.cols;
    let mut out = Matrix::zeros(din, dout);
    let failed = std::sync::Mutex::new(Vec::new());
    {
        let ptr = MatPtr(out.data.as_mut_ptr());
        shard_columns(pool, dout, &|c| {
            let support: Vec<usize> =
                (0..din).filter(|&r| z.at(r, c) != 0.0).collect();
            if support.is_empty() {
                return;
            }
            let mut w0_col = vec![0.0f32; din];
            for r in 0..din {
                w0_col[r] = w0.at(r, c);
            }
            // minimize (w - w0)^T H (w - w0) over support:
            //   H_ss w_s = H_s: w0   (rows of H restricted to support)
            let k = support.len();
            let mut hss = Matrix::zeros(k, k);
            let mut rhs = vec![0.0f32; k];
            let hw0 = h.matvec(&w0_col);
            for (a, &ra) in support.iter().enumerate() {
                for (b, &rb) in support.iter().enumerate() {
                    *hss.at_mut(a, b) = h.at(ra, rb);
                }
                rhs[a] = hw0[ra];
            }
            damp(&mut hss, 1e-4);
            let ch = match Cholesky::factor(&hss) {
                Ok(ch) => ch,
                Err(e) => {
                    failed.lock().unwrap().push(format!("col {c}: {e}"));
                    return;
                }
            };
            let sol = ch.solve(&rhs);
            for (a, &ra) in support.iter().enumerate() {
                // SAFETY: this task owns column c of `out`.
                unsafe {
                    *ptr.0.add(ra * dout + c) = sol[a];
                }
            }
        });
    }
    let errs = failed.into_inner().unwrap();
    anyhow::ensure!(errs.is_empty(), "support refine failed: {}",
                    errs.join("; "));
    Ok(out)
}

fn project_magnitude(w: &Matrix, sparsity: f64) -> Matrix {
    let scores: Vec<f32> = w.data.iter().map(|x| x.abs()).collect();
    let keep = ((1.0 - sparsity) * scores.len() as f64).round() as usize;
    let mask = topk_mask(&scores, keep.min(scores.len()));
    let mut out = w.clone();
    for (x, m) in out.data.iter_mut().zip(mask.iter()) {
        *x *= m;
    }
    out
}

fn add(a: &Matrix, b: &Matrix) -> Matrix {
    let mut out = a.clone();
    for (x, y) in out.data.iter_mut().zip(b.data.iter()) {
        *x += y;
    }
    out
}

fn mean_diag(h: &Matrix) -> f32 {
    (0..h.rows).map(|i| h.at(i, i)).sum::<f32>() / h.rows as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruners::sparsegpt::recon_error;
    use crate::pruners::test_support::*;
    use crate::pruners::uniform_alloc;

    use crate::pruners::sparsegpt::tests::correlated_problem as
        random_problem;

    #[test]
    fn output_is_sparse() {
        let (w, gram) = random_problem(16, 4, 32, 0);
        let z = prune_layer(&w, &gram, 0.5,
                            &LAdmmOptions::default()).unwrap();
        let nnz = z.nnz();
        assert!(nnz <= 32, "nnz={nnz}");
    }

    #[test]
    // multi-seed statistical sweep (5 full ADMM solves) — out of
    // Miri's budget; memory-model coverage comes from the single-solve
    // tests in this module
    #[cfg_attr(miri, ignore)]
    fn admm_beats_plain_magnitude_projection() {
        let mut worse = 0;
        for seed in 0..5 {
            let (w, gram) = random_problem(20, 5, 40, seed);
            let admm = prune_layer(&w, &gram, 0.6,
                                   &LAdmmOptions::default()).unwrap();
            let mag = project_magnitude(&w, 0.6);
            if recon_error(&admm, &w, &gram) >= recon_error(&mag, &w, &gram)
            {
                worse += 1;
            }
        }
        assert!(worse <= 1, "l-admm worse {worse}/5");
    }

    #[test]
    // multi-seed statistical sweep — see above
    #[cfg_attr(miri, ignore)]
    fn alps_refine_improves_over_plain_admm() {
        let mut worse = 0;
        for seed in 10..15 {
            let (w, gram) = random_problem(20, 5, 40, seed);
            let plain = prune_layer(&w, &gram, 0.7,
                                    &LAdmmOptions::default()).unwrap();
            let alps =
                prune_layer(&w, &gram, 0.7, &LAdmmOptions::alps()).unwrap();
            if recon_error(&alps, &w, &gram)
                > recon_error(&plain, &w, &gram) * 1.05
            {
                worse += 1;
            }
        }
        assert!(worse <= 1, "alps worse {worse}/5");
    }

    #[test]
    fn pooled_layer_is_bit_identical_to_serial() {
        // both presets (fixed rho, and the ALPS ramp + support refine)
        for opts in [LAdmmOptions::default(), LAdmmOptions::alps()] {
            let (w, gram) = random_problem(24, 7, 48, 21);
            let serial =
                prune_layer(&w, &gram, 0.6, &opts).unwrap();
            for width in [2, 4, 8] {
                let pool = WorkerPool::new(width);
                let pooled = prune_layer_pooled(&w, &gram, 0.6, &opts,
                                                Some(&pool))
                    .unwrap();
                assert_eq!(serial, pooled,
                           "width {width} refine={}", opts.obs_refine);
            }
        }
    }

    #[test]
    fn full_pipeline_runs() {
        let (cfg, dense, calib) = toy_setup();
        let pruned = prune(&cfg, &dense, &calib, &uniform_alloc(&cfg, 0.5),
                           &LAdmmOptions::default()).unwrap();
        let sp = sparsity_of(&cfg, &pruned);
        assert!(sp >= 0.45, "sp={sp}");
    }
}

//! Non-uniform sparsity allocation (paper Table 7): OWL outlier-based
//! budgets, an EvoPress-style evolutionary search, a SparseLLM-style
//! global saliency ranking, and a UniPruning-style held-out-NLL
//! feedback loop.
//!
//! Budget exactness (ISSUE 9): every allocation returned from this
//! module has a size-weighted mean sparsity equal to the requested
//! global target (to f64 rounding) — mutations that a clamp would
//! knock off-budget are rejected, and [`rebalance`] redistributes its
//! residual only over layers that still have clamp headroom.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::model::forward::{nll_seq, CalibSet};
use crate::model::Params;
use crate::runtime::ConfigEntry;
use crate::util::rng::Rng;

/// Per-layer sparsity clamp range shared by every allocator: never
/// fully dense (below 0.02) or fully empty (above 0.998) a layer.
const SP_MIN: f64 = 0.02;
const SP_MAX: f64 = 0.998;

/// OWL (Yin et al. 2024): layers with more activation-weighted outliers
/// get *less* sparsity. Outlier ratio D_l = fraction of |W_ij|*||X_i||
/// scores above `m_factor` x layer mean; budgets are
/// s_l = S - lam * (D_l - mean D), then rescaled so the weighted mean
/// (by layer size) equals the global target S.
///
/// A prunable layer missing from `calib` is an error (it would
/// otherwise score with unit norms and skew its outlier ratio against
/// the calibrated layers) — same contract as `wanda::prune`.
pub fn owl_allocation(cfg: &ConfigEntry, dense: &[f32], calib: &CalibSet,
                      target: f64) -> Result<BTreeMap<String, f64>> {
    const M_FACTOR: f32 = 5.0;
    const LAM: f64 = 0.08;
    let params = Params::new(cfg, dense.to_vec());
    let segs: Vec<_> =
        cfg.segments.iter().filter(|s| s.prunable).cloned().collect();

    let mut ratios = Vec::with_capacity(segs.len());
    for seg in &segs {
        let w = params.matrix(&seg.name)?;
        let xn = calib
            .get(&seg.name)
            .with_context(|| format!("no calibration for {}", seg.name))?
            .col_norms();
        let mut scores = Vec::with_capacity(w.rows * w.cols);
        for r in 0..w.rows {
            for c in 0..w.cols {
                scores.push(w.at(r, c).abs() * xn[r]);
            }
        }
        let mean = scores.iter().sum::<f32>() / scores.len() as f32;
        let outliers =
            scores.iter().filter(|&&s| s > M_FACTOR * mean).count();
        ratios.push(outliers as f64 / scores.len() as f64);
    }
    let mean_ratio = ratios.iter().sum::<f64>() / ratios.len() as f64;

    let raw: Vec<f64> = ratios
        .iter()
        .map(|d| (target - LAM * (d - mean_ratio) / mean_ratio.max(1e-9))
             .clamp(0.05, 0.995))
        .collect();
    Ok(rebalance(&segs, raw, target))
}

/// Rescale per-layer budgets so the size-weighted mean hits `target`.
///
/// The residual is redistributed only over layers that still have
/// clamp headroom in the needed direction (a layer pinned at a bound
/// cannot absorb any shift, so spreading the shift over everyone —
/// the pre-ISSUE-9 behavior — stalled geometrically and could exit
/// 32 iterations with the budget still off). If every layer clamps
/// before the target is reached, the target is infeasible within
/// [SP_MIN, SP_MAX] and the achieved mean is logged.
fn rebalance(segs: &[crate::runtime::Segment], mut raw: Vec<f64>,
             target: f64) -> BTreeMap<String, f64> {
    let sizes: Vec<f64> = segs.iter().map(|s| s.len() as f64).collect();
    let total: f64 = sizes.iter().sum();
    let weighted_mean = |raw: &[f64]| -> f64 {
        raw.iter().zip(sizes.iter()).map(|(s, n)| s * n).sum::<f64>()
            / total
    };
    for _ in 0..64 {
        let resid = target - weighted_mean(&raw);
        if resid.abs() < 1e-9 {
            break;
        }
        // layers with headroom in the residual's direction
        let movable: f64 = raw
            .iter()
            .zip(sizes.iter())
            .filter(|(s, _)| {
                if resid > 0.0 { **s < SP_MAX } else { **s > SP_MIN }
            })
            .map(|(_, n)| *n)
            .sum();
        if movable == 0.0 {
            break; // infeasible: every layer pinned at a bound
        }
        let shift = resid * total / movable;
        for s in raw.iter_mut() {
            if (resid > 0.0 && *s < SP_MAX)
                || (resid < 0.0 && *s > SP_MIN)
            {
                *s = (*s + shift).clamp(SP_MIN, SP_MAX);
            }
        }
    }
    let achieved = weighted_mean(&raw);
    if (achieved - target).abs() > 1e-6 {
        crate::debug!("alloc",
                      "rebalance did not converge: achieved \
                       {achieved:.6} vs target {target:.6} \
                       (infeasible within [{SP_MIN}, {SP_MAX}])");
    }
    segs.iter()
        .map(|s| s.name.clone())
        .zip(raw)
        .collect()
}

/// EvoPress-lite (Sieberling et al. 2024): (mu + lambda) evolutionary
/// search over per-layer budgets; fitness = NLL of the wanda-pruned
/// candidate on a few held-out calibration windows (rust forward, no
/// HLO dependency so it can run inside other loops).
pub struct EvoOptions {
    pub generations: usize,
    pub population: usize,
    pub mutation: f64,
    pub fitness_windows: usize,
    pub seed: u64,
}

impl Default for EvoOptions {
    fn default() -> Self {
        EvoOptions { generations: 6, population: 6, mutation: 0.08,
                     fitness_windows: 4, seed: 0 }
    }
}

/// One budget-moving mutation: shift layer `a` by `delta` (clamped)
/// and compensate layer `b` size-weightedly so the global
/// size-weighted mean is unchanged. Returns `None` — mutation
/// rejected — when `b`'s compensation would itself clamp: the
/// pre-ISSUE-9 code clamped `b` anyway, silently changing the
/// candidate's global sparsity, so lower-sparsity candidates won
/// fitness unfairly and the returned allocation could miss the target.
fn mutate(best: &[f64], sizes: &[f64], a: usize, b: usize, delta: f64)
          -> Option<Vec<f64>> {
    let mut cand = best.to_vec();
    let new_a = (cand[a] + delta).clamp(SP_MIN, SP_MAX);
    // size-weighted compensation from the *actual* (post-clamp) move
    let moved = (new_a - cand[a]) * sizes[a] / sizes[b];
    let new_b = cand[b] - moved;
    if !(SP_MIN..=SP_MAX).contains(&new_b) {
        return None;
    }
    cand[a] = new_a;
    cand[b] = new_b;
    Some(cand)
}

pub fn evopress_allocation(cfg: &ConfigEntry, dense: &[f32],
                           calib: &CalibSet, train: &[u32], target: f64,
                           opts: &EvoOptions)
                           -> Result<BTreeMap<String, f64>> {
    let segs: Vec<_> =
        cfg.segments.iter().filter(|s| s.prunable).cloned().collect();
    let n = segs.len();
    let sizes: Vec<f64> = segs.iter().map(|s| s.len() as f64).collect();
    let mut rng = Rng::new(opts.seed ^ 0xE70);

    // fitness evaluation windows (fixed across the whole search)
    let windows = crate::data::calibration(train, opts.fitness_windows,
                                           cfg.seq_len + 1, 0xF17);

    let fitness = |alloc: &Vec<f64>| -> Result<f64> {
        let map: BTreeMap<String, f64> = segs
            .iter()
            .map(|s| s.name.clone())
            .zip(alloc.iter().copied())
            .collect();
        let pruned = super::wanda::prune(cfg, dense, calib, &map)?;
        let p = Params::new(cfg, pruned);
        let mut total = 0.0;
        for w in &windows {
            total += nll_seq(&p, w)?;
        }
        Ok(total / windows.len() as f64)
    };

    let mut best: Vec<f64> = vec![target; n];
    let mut best_fit = fitness(&best)?;

    for gen in 0..opts.generations {
        let mut improved = false;
        for _ in 0..opts.population {
            // mutate: move budget between two random layers, keeping the
            // size-weighted global sparsity fixed (off-budget mutations
            // are rejected, so every evaluated candidate is on-budget)
            let a = rng.below(n);
            let mut b = rng.below(n);
            while b == a {
                b = rng.below(n);
            }
            let delta = (rng.f64() * 2.0 - 1.0) * opts.mutation;
            let Some(cand) = mutate(&best, &sizes, a, b, delta) else {
                continue;
            };
            let f = fitness(&cand)?;
            if f < best_fit {
                best = cand;
                best_fit = f;
                improved = true;
            }
        }
        crate::debug!("evopress", "gen {gen}: fitness {best_fit:.4} \
                       (improved={improved})");
    }
    Ok(segs.iter().map(|s| s.name.clone()).zip(best).collect())
}

/// SparseLLM-style global allocation (Bai et al. 2024): rank the
/// per-weight wanda saliency |W_ij|·||X_i||_2 across *all* prunable
/// segments at once and keep the global top-K,
/// K = round((1-target)·N). Per-layer budgets are each layer's share
/// of the cut, so the size-weighted mean sparsity equals
/// `1 - K/N` — the global target, exactly (one global rounding instead
/// of one per layer).
pub fn global_allocation(cfg: &ConfigEntry, dense: &[f32],
                         calib: &CalibSet, target: f64)
                         -> Result<BTreeMap<String, f64>> {
    let params = Params::new(cfg, dense.to_vec());
    let segs: Vec<_> =
        cfg.segments.iter().filter(|s| s.prunable).cloned().collect();

    // concatenated saliency over all prunable weights, plus each
    // segment's [start, end) range in the concatenation
    let mut scores: Vec<f32> = Vec::new();
    let mut ranges = Vec::with_capacity(segs.len());
    for seg in &segs {
        let w = params.matrix(&seg.name)?;
        let xn = calib
            .get(&seg.name)
            .with_context(|| format!("no calibration for {}", seg.name))?
            .col_norms();
        let start = scores.len();
        for r in 0..w.rows {
            for c in 0..w.cols {
                scores.push(w.at(r, c).abs() * xn[r]);
            }
        }
        ranges.push((start, scores.len()));
    }
    let n = scores.len();
    let keep_total = ((1.0 - target) * n as f64).round() as usize;
    let mask = crate::tensor::select::topk_mask(&scores,
                                               keep_total.min(n));

    Ok(segs
        .iter()
        .zip(ranges.iter())
        .map(|(seg, &(s, e))| {
            let kept = mask[s..e].iter().filter(|&&m| m > 0.0).count();
            let sp = 1.0 - kept as f64 / (e - s) as f64;
            (seg.name.clone(), sp)
        })
        .collect())
}

/// UniPruning-style global feedback (Ding et al. 2025): greedy
/// coordinate descent on the per-layer budgets, driven by held-out
/// NLL of the wanda-pruned candidate. Each move shifts one layer's
/// budget by ±step and funds it uniformly (in sparsity units) across
/// the other layers, so the size-weighted global mean is invariant;
/// moves that any clamp would knock off-budget are rejected. The step
/// halves after a round with no accepted move.
pub fn feedback_allocation(cfg: &ConfigEntry, dense: &[f32],
                           calib: &CalibSet, train: &[u32],
                           base: &BTreeMap<String, f64>, target: f64,
                           rounds: usize)
                           -> Result<BTreeMap<String, f64>> {
    let segs: Vec<_> =
        cfg.segments.iter().filter(|s| s.prunable).cloned().collect();
    let n = segs.len();
    let sizes: Vec<f64> = segs.iter().map(|s| s.len() as f64).collect();
    let total: f64 = sizes.iter().sum();

    let mut cur: Vec<f64> = segs
        .iter()
        .map(|s| base.get(&s.name).copied().unwrap_or(target))
        .collect();

    // held-out windows, disjoint seed from the evo fitness windows
    let windows = crate::data::calibration(train, 4, cfg.seq_len + 1,
                                           0x5EED);
    let fitness = |alloc: &[f64]| -> Result<f64> {
        let map: BTreeMap<String, f64> = segs
            .iter()
            .map(|s| s.name.clone())
            .zip(alloc.iter().copied())
            .collect();
        let pruned = super::wanda::prune(cfg, dense, calib, &map)?;
        let p = Params::new(cfg, pruned);
        let mut nll = 0.0;
        for w in &windows {
            nll += nll_seq(&p, w)?;
        }
        Ok(nll / windows.len() as f64)
    };

    // budget-preserving candidate: layer `a` moves by `delta`, every
    // other layer absorbs a uniform compensating shift
    let shifted = |cur: &[f64], a: usize, delta: f64|
                   -> Option<Vec<f64>> {
        let mut cand = cur.to_vec();
        let new_a = cand[a] + delta;
        if !(SP_MIN..=SP_MAX).contains(&new_a) {
            return None;
        }
        let comp = -delta * sizes[a] / (total - sizes[a]);
        for (i, c) in cand.iter_mut().enumerate() {
            if i == a {
                *c = new_a;
            } else {
                *c += comp;
                if !(SP_MIN..=SP_MAX).contains(c) {
                    return None;
                }
            }
        }
        Some(cand)
    };

    let mut best_fit = fitness(&cur)?;
    let mut step = 0.05;
    for round in 0..rounds {
        let mut improved = false;
        for a in 0..n {
            for delta in [-step, step] {
                let Some(cand) = shifted(&cur, a, delta) else {
                    continue;
                };
                let f = fitness(&cand)?;
                if f < best_fit {
                    cur = cand;
                    best_fit = f;
                    improved = true;
                }
            }
        }
        if !improved {
            step *= 0.5;
        }
        crate::debug!("alloc", "feedback round {round}: nll \
                       {best_fit:.4} step {step:.3}");
    }
    Ok(segs.iter().map(|s| s.name.clone()).zip(cur).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruners::test_support::*;

    fn weighted_mean(cfg: &ConfigEntry,
                     alloc: &BTreeMap<String, f64>) -> f64 {
        let segs: Vec<_> =
            cfg.segments.iter().filter(|s| s.prunable).collect();
        let total: f64 = segs.iter().map(|s| s.len() as f64).sum();
        segs.iter()
            .map(|s| alloc[&s.name] * s.len() as f64)
            .sum::<f64>()
            / total
    }

    #[test]
    fn owl_respects_global_budget() {
        let (cfg, dense, calib) = toy_setup();
        for target in [0.5, 0.7] {
            let alloc =
                owl_allocation(&cfg, &dense, &calib, target).unwrap();
            let mean = weighted_mean(&cfg, &alloc);
            assert!((mean - target).abs() < 1e-9,
                    "target={target} mean={mean}");
        }
    }

    #[test]
    fn owl_gives_outlier_heavy_layers_less_sparsity() {
        let (cfg, mut dense, calib) = toy_setup();
        // plant one extreme outlier in wq: OWL must protect the layer
        let seg = cfg.segment("l0.attn.wq").unwrap().clone();
        dense[seg.offset] = 500.0;
        let alloc = owl_allocation(&cfg, &dense, &calib, 0.7).unwrap();
        let wq = alloc["l0.attn.wq"];
        let others: Vec<f64> = alloc
            .iter()
            .filter(|(k, _)| k.as_str() != "l0.attn.wq")
            .map(|(_, v)| *v)
            .collect();
        let mean_other = others.iter().sum::<f64>() / others.len() as f64;
        assert!(wq < mean_other,
                "outlier layer not protected: {wq} vs {mean_other}");
    }

    #[test]
    fn owl_missing_calibration_is_an_error() {
        let (cfg, dense, mut calib) = toy_setup();
        calib.remove("l0.attn.wk");
        let err = owl_allocation(&cfg, &dense, &calib, 0.5).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("l0.attn.wk"),
                "error must name the layer: {msg}");
    }

    #[test]
    fn rebalance_converges_under_heavy_clamping() {
        // one small layer (wq, 16 of 192 weights) holds all the
        // headroom. The pre-fix uniform shift was mostly absorbed by
        // the five layers pinned at SP_MAX, shrinking the residual by
        // only 1/12 per iteration — 32 iterations left the mean off
        // by ~1e-3. Redistributing over unclamped layers only lands
        // exactly.
        let (cfg, _, _) = toy_setup();
        let segs: Vec<_> =
            cfg.segments.iter().filter(|s| s.prunable).cloned().collect();
        let raw: Vec<f64> = segs
            .iter()
            .map(|s| if s.name == "l0.attn.wq" { 0.2 } else { SP_MAX })
            .collect();
        let alloc = rebalance(&segs, raw, 0.95);
        let mean = weighted_mean(&cfg, &alloc);
        assert!((mean - 0.95).abs() < 1e-9, "mean={mean}");
        // the pinned layers never moved; wq absorbed the residual
        assert_eq!(alloc["l0.attn.wk"], SP_MAX);
        assert!(alloc["l0.attn.wq"] > 0.2);
    }

    #[test]
    fn rebalance_handles_infeasible_targets_without_overshoot() {
        // every layer pinned at SP_MAX and the target still higher:
        // infeasible — rebalance must stop at the bound, not loop or
        // return out-of-range budgets
        let (cfg, _, _) = toy_setup();
        let segs: Vec<_> =
            cfg.segments.iter().filter(|s| s.prunable).cloned().collect();
        let raw = vec![SP_MAX; segs.len()];
        let alloc = rebalance(&segs, raw, 0.9999);
        for (name, sp) in &alloc {
            assert_eq!(*sp, SP_MAX, "{name}");
        }
    }

    #[test]
    fn evopress_mutations_preserve_budget_within_1e9() {
        // clamp-prone budgets: many draws push a or b past a bound.
        // Every *accepted* mutation must keep the size-weighted mean
        // bit-for-bit on budget; off-budget ones must be rejected.
        let (cfg, _, _) = toy_setup();
        let segs: Vec<_> =
            cfg.segments.iter().filter(|s| s.prunable).cloned().collect();
        let sizes: Vec<f64> = segs.iter().map(|s| s.len() as f64).collect();
        let total: f64 = sizes.iter().sum();
        let best = vec![0.03, 0.997, 0.5, 0.98, 0.05, 0.6];
        let base_mean: f64 = best.iter().zip(sizes.iter())
            .map(|(s, n)| s * n).sum::<f64>() / total;
        let mut rng = Rng::new(0xB06E7);
        let n = best.len();
        let (mut accepted, mut rejected) = (0, 0);
        for _ in 0..500 {
            let a = rng.below(n);
            let mut b = rng.below(n);
            while b == a {
                b = rng.below(n);
            }
            let delta = (rng.f64() * 2.0 - 1.0) * 0.5;
            match mutate(&best, &sizes, a, b, delta) {
                Some(cand) => {
                    accepted += 1;
                    let mean: f64 = cand.iter().zip(sizes.iter())
                        .map(|(s, n)| s * n).sum::<f64>() / total;
                    assert!((mean - base_mean).abs() < 1e-9,
                            "a={a} b={b} delta={delta}: {mean} vs \
                             {base_mean}");
                }
                None => rejected += 1,
            }
        }
        assert!(accepted > 0 && rejected > 0,
                "clamp-prone setup must exercise both paths \
                 ({accepted} accepted, {rejected} rejected)");
    }

    #[test]
    // full evolutionary search with model-eval fitness — far past
    // Miri's interpreter budget; the budget arithmetic it guards is
    // covered under Miri by evopress_mutations_preserve_budget_within_1e9
    #[cfg_attr(miri, ignore)]
    fn evopress_returns_on_budget_allocation_under_clamping() {
        let (cfg, dense, calib) = toy_setup();
        let mut rng = crate::util::rng::Rng::new(0);
        let train: Vec<u32> =
            (0..2000).map(|_| rng.below(16) as u32).collect();
        // near-SP_MAX target + huge mutation: pre-fix, clamped
        // compensations silently lowered candidates' global sparsity
        // and the winner drifted off budget
        let opts = EvoOptions { generations: 2, population: 4,
                                mutation: 0.5, fitness_windows: 2,
                                ..Default::default() };
        let target = 0.97;
        let alloc = evopress_allocation(&cfg, &dense, &calib, &train,
                                        target, &opts).unwrap();
        let mean = weighted_mean(&cfg, &alloc);
        assert!((mean - target).abs() < 1e-9, "mean={mean}");
    }

    #[test]
    // full evolutionary search with model-eval fitness — see above
    #[cfg_attr(miri, ignore)]
    fn evopress_improves_or_matches_uniform() {
        let (cfg, dense, calib) = toy_setup();
        // fake_config has vocab 16; synth grammars need >= 33 tokens, so
        // use a plain random stream for the search fitness here
        let mut rng = crate::util::rng::Rng::new(0);
        let train: Vec<u32> =
            (0..2000).map(|_| rng.below(16) as u32).collect();
        let opts = EvoOptions { generations: 2, population: 3,
                                fitness_windows: 2, ..Default::default() };
        let alloc = evopress_allocation(&cfg, &dense, &calib, &train, 0.6,
                                        &opts).unwrap();
        assert_eq!(alloc.len(),
                   cfg.segments.iter().filter(|s| s.prunable).count());
    }

    #[test]
    fn global_allocation_budget_is_exact() {
        let (cfg, dense, calib) = toy_setup();
        let n: usize = cfg.segments.iter().filter(|s| s.prunable)
            .map(|s| s.len()).sum();
        // non-1/32-aligned targets: one global rounding, no drift
        for target in [0.55, 0.7, 0.9] {
            let alloc =
                global_allocation(&cfg, &dense, &calib, target).unwrap();
            let mean = weighted_mean(&cfg, &alloc);
            let exact =
                1.0 - ((1.0 - target) * n as f64).round() / n as f64;
            assert!((mean - exact).abs() < 1e-12,
                    "target={target} mean={mean} exact={exact}");
        }
    }

    #[test]
    fn global_allocation_protects_salient_layers() {
        let (cfg, mut dense, calib) = toy_setup();
        // make every wq weight huge: global ranking must keep wq
        // nearly dense and push sparsity onto the other layers
        let seg = cfg.segment("l0.attn.wq").unwrap().clone();
        for i in seg.offset..seg.end() {
            dense[i] = 50.0 + (i - seg.offset) as f32;
        }
        let alloc = global_allocation(&cfg, &dense, &calib, 0.7).unwrap();
        let wq = alloc["l0.attn.wq"];
        let others: Vec<f64> = alloc
            .iter()
            .filter(|(k, _)| k.as_str() != "l0.attn.wq")
            .map(|(_, v)| *v)
            .collect();
        let mean_other = others.iter().sum::<f64>() / others.len() as f64;
        assert!(wq < mean_other,
                "salient layer not protected: {wq} vs {mean_other}");
    }

    #[test]
    // one feedback round = a full prune + 2000-token eval — too heavy
    // for the interpreter; the quota arithmetic is Miri-covered by the
    // pure-allocation tests above
    #[cfg_attr(miri, ignore)]
    fn feedback_preserves_global_budget() {
        let (cfg, dense, calib) = toy_setup();
        let mut rng = crate::util::rng::Rng::new(1);
        let train: Vec<u32> =
            (0..2000).map(|_| rng.below(16) as u32).collect();
        let target = 0.6;
        let base = crate::pruners::uniform_alloc(&cfg, target);
        let alloc = feedback_allocation(&cfg, &dense, &calib, &train,
                                        &base, target, 1).unwrap();
        assert_eq!(alloc.len(), base.len());
        let mean = weighted_mean(&cfg, &alloc);
        assert!((mean - target).abs() < 1e-9, "mean={mean}");
    }
}

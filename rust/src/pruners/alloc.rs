//! Non-uniform sparsity allocation (paper Table 7): OWL outlier-based
//! budgets and an EvoPress-style evolutionary search.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::forward::{nll_seq, CalibSet};
use crate::model::Params;
use crate::runtime::ConfigEntry;
use crate::util::rng::Rng;

/// OWL (Yin et al. 2024): layers with more activation-weighted outliers
/// get *less* sparsity. Outlier ratio D_l = fraction of |W_ij|*||X_i||
/// scores above `m_factor` x layer mean; budgets are
/// s_l = S - lam * (D_l - mean D), then rescaled so the weighted mean
/// (by layer size) equals the global target S.
pub fn owl_allocation(cfg: &ConfigEntry, dense: &[f32], calib: &CalibSet,
                      target: f64) -> BTreeMap<String, f64> {
    const M_FACTOR: f32 = 5.0;
    const LAM: f64 = 0.08;
    let params = Params::new(cfg, dense.to_vec());
    let segs: Vec<_> =
        cfg.segments.iter().filter(|s| s.prunable).cloned().collect();

    let mut ratios = Vec::with_capacity(segs.len());
    for seg in &segs {
        let w = params.matrix(&seg.name).expect("matrix");
        let xn = calib
            .get(&seg.name)
            .map(|s| s.col_norms())
            .unwrap_or_else(|| vec![1.0; w.rows]);
        let mut scores = Vec::with_capacity(w.rows * w.cols);
        for r in 0..w.rows {
            for c in 0..w.cols {
                scores.push(w.at(r, c).abs() * xn[r]);
            }
        }
        let mean = scores.iter().sum::<f32>() / scores.len() as f32;
        let outliers =
            scores.iter().filter(|&&s| s > M_FACTOR * mean).count();
        ratios.push(outliers as f64 / scores.len() as f64);
    }
    let mean_ratio = ratios.iter().sum::<f64>() / ratios.len() as f64;

    let raw: Vec<f64> = ratios
        .iter()
        .map(|d| (target - LAM * (d - mean_ratio) / mean_ratio.max(1e-9))
             .clamp(0.05, 0.995))
        .collect();
    rebalance(&segs, raw, target)
}

/// Rescale per-layer budgets so the size-weighted mean hits `target`.
fn rebalance(segs: &[crate::runtime::Segment], mut raw: Vec<f64>,
             target: f64) -> BTreeMap<String, f64> {
    let sizes: Vec<f64> = segs.iter().map(|s| s.len() as f64).collect();
    let total: f64 = sizes.iter().sum();
    for _ in 0..32 {
        let cur: f64 = raw.iter().zip(sizes.iter())
            .map(|(s, n)| s * n).sum::<f64>() / total;
        let shift = target - cur;
        if shift.abs() < 1e-6 {
            break;
        }
        for s in raw.iter_mut() {
            *s = (*s + shift).clamp(0.02, 0.998);
        }
    }
    segs.iter()
        .map(|s| s.name.clone())
        .zip(raw)
        .collect()
}

/// EvoPress-lite (Sieberling et al. 2024): (mu + lambda) evolutionary
/// search over per-layer budgets; fitness = NLL of the wanda-pruned
/// candidate on a few held-out calibration windows (rust forward, no
/// HLO dependency so it can run inside other loops).
pub struct EvoOptions {
    pub generations: usize,
    pub population: usize,
    pub mutation: f64,
    pub fitness_windows: usize,
    pub seed: u64,
}

impl Default for EvoOptions {
    fn default() -> Self {
        EvoOptions { generations: 6, population: 6, mutation: 0.08,
                     fitness_windows: 4, seed: 0 }
    }
}

pub fn evopress_allocation(cfg: &ConfigEntry, dense: &[f32],
                           calib: &CalibSet, train: &[u32], target: f64,
                           opts: &EvoOptions)
                           -> Result<BTreeMap<String, f64>> {
    let segs: Vec<_> =
        cfg.segments.iter().filter(|s| s.prunable).cloned().collect();
    let n = segs.len();
    let mut rng = Rng::new(opts.seed ^ 0xE70);

    // fitness evaluation windows (fixed across the whole search)
    let windows = crate::data::calibration(train, opts.fitness_windows,
                                           cfg.seq_len + 1, 0xF17);

    let fitness = |alloc: &Vec<f64>| -> Result<f64> {
        let map: BTreeMap<String, f64> = segs
            .iter()
            .map(|s| s.name.clone())
            .zip(alloc.iter().copied())
            .collect();
        let pruned = super::wanda::prune(cfg, dense, calib, &map)?;
        let p = Params::new(cfg, pruned);
        let mut total = 0.0;
        for w in &windows {
            total += nll_seq(&p, w)?;
        }
        Ok(total / windows.len() as f64)
    };

    let mut best: Vec<f64> = vec![target; n];
    let mut best_fit = fitness(&best)?;

    for gen in 0..opts.generations {
        let mut improved = false;
        for _ in 0..opts.population {
            // mutate: move budget between two random layers, keeping the
            // size-weighted global sparsity fixed
            let mut cand = best.clone();
            let a = rng.below(n);
            let mut b = rng.below(n);
            while b == a {
                b = rng.below(n);
            }
            let delta = (rng.f64() * 2.0 - 1.0) * opts.mutation;
            let na = segs[a].len() as f64;
            let nb = segs[b].len() as f64;
            cand[a] = (cand[a] + delta).clamp(0.02, 0.998);
            let moved = (cand[a] - best[a]) * na / nb;
            cand[b] = (cand[b] - moved).clamp(0.02, 0.998);
            let f = fitness(&cand)?;
            if f < best_fit {
                best = cand;
                best_fit = f;
                improved = true;
            }
        }
        crate::debug!("evopress", "gen {gen}: fitness {best_fit:.4} \
                       (improved={improved})");
    }
    Ok(segs.iter().map(|s| s.name.clone()).zip(best).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruners::test_support::*;

    #[test]
    fn owl_respects_global_budget() {
        let (cfg, dense, calib) = toy_setup();
        for target in [0.5, 0.7] {
            let alloc = owl_allocation(&cfg, &dense, &calib, target);
            let segs: Vec<_> = cfg.segments.iter()
                .filter(|s| s.prunable).collect();
            let total: f64 = segs.iter().map(|s| s.len() as f64).sum();
            let mean: f64 = segs.iter()
                .map(|s| alloc[&s.name] * s.len() as f64)
                .sum::<f64>() / total;
            assert!((mean - target).abs() < 0.01, "target={target}");
        }
    }

    #[test]
    fn owl_gives_outlier_heavy_layers_less_sparsity() {
        let (cfg, mut dense, calib) = toy_setup();
        // plant one extreme outlier in wq: OWL must protect the layer
        let seg = cfg.segment("l0.attn.wq").unwrap().clone();
        dense[seg.offset] = 500.0;
        let alloc = owl_allocation(&cfg, &dense, &calib, 0.7);
        let wq = alloc["l0.attn.wq"];
        let others: Vec<f64> = alloc
            .iter()
            .filter(|(k, _)| k.as_str() != "l0.attn.wq")
            .map(|(_, v)| *v)
            .collect();
        let mean_other = others.iter().sum::<f64>() / others.len() as f64;
        assert!(wq < mean_other,
                "outlier layer not protected: {wq} vs {mean_other}");
    }

    #[test]
    fn evopress_improves_or_matches_uniform() {
        let (cfg, dense, calib) = toy_setup();
        // fake_config has vocab 16; synth grammars need >= 33 tokens, so
        // use a plain random stream for the search fitness here
        let mut rng = crate::util::rng::Rng::new(0);
        let train: Vec<u32> =
            (0..2000).map(|_| rng.below(16) as u32).collect();
        let opts = EvoOptions { generations: 2, population: 3,
                                fitness_windows: 2, ..Default::default() };
        let alloc = evopress_allocation(&cfg, &dense, &calib, &train, 0.6,
                                        &opts).unwrap();
        assert_eq!(alloc.len(),
                   cfg.segments.iter().filter(|s| s.prunable).count());
    }
}

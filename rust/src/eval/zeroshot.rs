//! Zero-shot probe tasks over the synthetic language (DESIGN.md §3).
//!
//! Seven likelihood-ranked multiple-choice tasks mirroring the paper's
//! lm-eval suite (ARC-E/C, BoolQ, HellaSwag, OBQA, RTE, Winogrande):
//! each probes a capability the grammar defines ground truth for, and
//! each degrades with model damage at its own rate — which is exactly
//! what the Fig-4 radar plots measure across sparsity levels.
//!
//! Scoring follows the lm-eval convention: candidate = argmax of the
//! summed token log-likelihood of the continuation given the context
//! (rust forward; no HLO dependency so arbitrary lengths work).

use anyhow::Result;

use crate::data::grammar::{Grammar, AGREE_GAP, N_AGREE};
use crate::model::forward::forward_seq;
use crate::model::Params;
use crate::util::rng::Rng;

/// One multiple-choice example.
#[derive(Debug, Clone)]
pub struct Example {
    pub context: Vec<u32>,
    pub candidates: Vec<Vec<u32>>,
    pub answer: usize,
}

/// Task names, paired 1:1 with the paper's seven tasks.
pub const TASK_NAMES: [&str; 7] = [
    "agree",      // Winogrande: long-range agreement, 2-way
    "cloze-easy", // ARC-E: next token vs random distractors, 4-way
    "cloze-hard", // ARC-C: next token vs frequent distractors, 4-way
    "boolstate",  // BoolQ: high- vs low-probability token, 2-way
    "contin",     // HellaSwag: true vs shuffled continuation, 2-way
    "recall",     // OBQA: which opener was seen, 4-way
    "entail",     // RTE: true vs foreign continuation, 2-way
];

const CTX: usize = 24;

/// Generate `n` examples for each task. Deterministic in `seed`.
pub fn build_suite(g: &Grammar, n: usize, seed: u64)
                   -> Vec<(String, Vec<Example>)> {
    TASK_NAMES
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let mut rng = Rng::new(seed ^ ((i as u64 + 1) * 0x9E37));
            let exs = (0..n)
                .map(|j| make_example(g, name, &mut rng, j as u64))
                .collect();
            (name.to_string(), exs)
        })
        .collect()
}

fn stream_with_opener(g: &Grammar, rng: &mut Rng) -> (Vec<u32>, usize) {
    // regenerate until an opener lands early enough for a full context
    loop {
        let s = g.generate(CTX + AGREE_GAP + 4, rng.next_u64());
        if let Some(p) = s
            .iter()
            .take(CTX)
            .position(|t| g.closer_for(*t).is_some())
        {
            if p + AGREE_GAP < s.len() {
                return (s, p);
            }
        }
    }
}

fn other_closer(g: &Grammar, not: u32, rng: &mut Rng) -> u32 {
    loop {
        let c = g.closers[rng.below(N_AGREE)];
        if c != not {
            return c;
        }
    }
}

fn make_example(g: &Grammar, task: &str, rng: &mut Rng, _id: u64)
                -> Example {
    match task {
        "agree" => {
            let (s, p) = stream_with_opener(g, rng);
            let closer = g.closer_for(s[p]).unwrap();
            let context = s[..p + AGREE_GAP].to_vec();
            let wrong = other_closer(g, closer, rng);
            shuffle2(context, vec![closer], vec![wrong], rng)
        }
        "recall" => {
            let (s, p) = stream_with_opener(g, rng);
            let closer = g.closer_for(s[p]).unwrap();
            let context = s[..p + AGREE_GAP].to_vec();
            let mut cands = vec![vec![closer]];
            while cands.len() < 4 {
                let c = other_closer(g, closer, rng);
                if !cands.iter().any(|v| v[0] == c) {
                    cands.push(vec![c]);
                }
            }
            shuffle_n(context, cands, 0, rng)
        }
        "cloze-easy" | "cloze-hard" => {
            let s = g.generate(CTX + 1, rng.next_u64());
            let context = s[..CTX].to_vec();
            let truth = s[CTX];
            let mut cands = vec![vec![truth]];
            let hard = task == "cloze-hard";
            // distractors: random tokens (easy) or tokens drawn from the
            // same stream, i.e. plausible under the marginal (hard)
            let alt = g.generate(256, rng.next_u64());
            while cands.len() < 4 {
                let c = if hard {
                    alt[rng.below(alt.len())]
                } else {
                    rng.below(g.ordinary_vocab()) as u32
                };
                if c != truth && !cands.iter().any(|v| v[0] == c) {
                    cands.push(vec![c]);
                }
            }
            shuffle_n(context, cands, 0, rng)
        }
        "boolstate" => {
            let s = g.generate(CTX + 64, rng.next_u64());
            let context = s[..CTX].to_vec();
            // "yes" = the actually-next token; "no" = a token that never
            // appears in this stream (out-of-distribution for the state)
            let truth = s[CTX];
            let mut no = rng.below(g.ordinary_vocab()) as u32;
            while s.contains(&no) {
                no = rng.below(g.ordinary_vocab()) as u32;
            }
            shuffle2(context, vec![truth], vec![no], rng)
        }
        "contin" => {
            let s = g.generate(CTX + 8, rng.next_u64());
            let context = s[..CTX].to_vec();
            let truth = s[CTX..CTX + 8].to_vec();
            let mut wrong = truth.clone();
            wrong.reverse();
            if wrong == truth {
                wrong[0] = wrong[0].wrapping_add(1) % g.vocab as u32;
            }
            shuffle2(context, truth, wrong, rng)
        }
        "entail" => {
            let s = g.generate(CTX + 8, rng.next_u64());
            let context = s[..CTX].to_vec();
            let truth = s[CTX..CTX + 8].to_vec();
            // foreign continuation from an independent stream
            let other = g.generate(CTX + 8, rng.next_u64());
            let wrong = other[CTX..CTX + 8].to_vec();
            shuffle2(context, truth, wrong, rng)
        }
        _ => panic!("unknown task {task}"),
    }
}

fn shuffle2(context: Vec<u32>, truth: Vec<u32>, wrong: Vec<u32>,
            rng: &mut Rng) -> Example {
    shuffle_n(context, vec![truth, wrong], 0, rng)
}

fn shuffle_n(context: Vec<u32>, mut cands: Vec<Vec<u32>>, answer: usize,
             rng: &mut Rng) -> Example {
    let mut order: Vec<usize> = (0..cands.len()).collect();
    rng.shuffle(&mut order);
    let new_answer = order.iter().position(|&i| i == answer).unwrap();
    let mut shuffled = Vec::with_capacity(cands.len());
    for &i in &order {
        shuffled.push(std::mem::take(&mut cands[i]));
    }
    Example { context, candidates: shuffled, answer: new_answer }
}

/// Log-likelihood of `cand` following `context` under the model.
fn cand_loglik(p: &Params, context: &[u32], cand: &[u32]) -> Result<f64> {
    let mut seq = context.to_vec();
    seq.extend_from_slice(cand);
    // positions are bounded by the pos table
    anyhow::ensure!(seq.len() <= p.cfg.seq_len, "example too long");
    let logits = forward_seq(p, &seq[..seq.len() - 1], None)?;
    let mut total = 0.0f64;
    for (i, &tok) in cand.iter().enumerate() {
        let t = context.len() + i - 1; // logits row predicting position t+1
        let row = logits.row(t);
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let lse: f32 =
            row.iter().map(|v| (v - max).exp()).sum::<f32>().ln() + max;
        total += (row[tok as usize] - lse) as f64;
    }
    Ok(total)
}

/// Accuracy of the model on one task.
pub fn score_task(p: &Params, examples: &[Example]) -> Result<f64> {
    let mut correct = 0usize;
    for ex in examples {
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (i, cand) in ex.candidates.iter().enumerate() {
            let ll = cand_loglik(p, &ex.context, cand)?;
            if ll > best.0 {
                best = (ll, i);
            }
        }
        if best.1 == ex.answer {
            correct += 1;
        }
    }
    Ok(correct as f64 / examples.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grammar() -> Grammar {
        Grammar::named("synth-c4", 256)
    }

    #[test]
    fn suite_shapes() {
        let g = grammar();
        let suite = build_suite(&g, 5, 0);
        assert_eq!(suite.len(), 7);
        for (name, exs) in &suite {
            assert_eq!(exs.len(), 5, "{name}");
            for ex in exs {
                assert!(ex.answer < ex.candidates.len());
                assert!(!ex.context.is_empty());
                let total = ex.context.len()
                    + ex.candidates.iter().map(|c| c.len()).max().unwrap();
                assert!(total <= 64, "{name} example too long: {total}");
            }
        }
    }

    #[test]
    fn suite_deterministic() {
        let g = grammar();
        let a = build_suite(&g, 3, 42);
        let b = build_suite(&g, 3, 42);
        for ((_, ea), (_, eb)) in a.iter().zip(b.iter()) {
            for (x, y) in ea.iter().zip(eb.iter()) {
                assert_eq!(x.context, y.context);
                assert_eq!(x.answer, y.answer);
            }
        }
    }

    #[test]
    fn agree_answer_is_the_forced_closer() {
        let g = grammar();
        let suite = build_suite(&g, 10, 1);
        let (_, agree) = &suite[0];
        for ex in agree {
            // the opener appears in the context...
            let opener_pos = ex
                .context
                .iter()
                .position(|t| g.closer_for(*t).is_some())
                .expect("no opener in context");
            let closer = g.closer_for(ex.context[opener_pos]).unwrap();
            // ...and the gold candidate is exactly its closer
            assert_eq!(ex.candidates[ex.answer], vec![closer]);
        }
    }

    #[test]
    fn answers_shuffled_uniformly() {
        // guards against an always-first-answer bug that would let a
        // position-biased scorer cheat
        let g = grammar();
        let suite = build_suite(&g, 40, 3);
        for (name, exs) in &suite {
            let firsts =
                exs.iter().filter(|e| e.answer == 0).count();
            assert!(firsts < exs.len(), "{name}: answers never shuffled");
        }
    }

    #[test]
    fn random_model_scores_near_chance() {
        let g = grammar();
        // a fresh random model should be ~chance on cloze-easy (4-way)
        let cfg_entry = {
            // reuse the real tiny layout via a quick manifest-free params:
            // fake_config has vocab 16 < 256, so build examples on a tiny
            // vocab-compatible grammar is impossible; instead just check
            // the scorer runs on the fake model with clipped tokens.
            crate::model::fake_config()
        };
        let p = Params::init(&cfg_entry, 0);
        let exs: Vec<Example> = (0..8)
            .map(|i| Example {
                context: vec![1, 2, 3, (i % 8) as u32],
                candidates: vec![vec![4], vec![5], vec![6], vec![7]],
                answer: (i % 4) as usize,
            })
            .collect();
        let acc = score_task(&p, &exs).unwrap();
        assert!((0.0..=1.0).contains(&acc));
        let _ = g;
    }
}

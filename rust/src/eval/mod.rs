//! Evaluation harness: perplexity (via the HLO eval_loss artifact, see
//! coordinator::eval_ppl) and the zero-shot probe suite (Fig 4 / Tables
//! 11-12 analogue).

pub mod zeroshot;

pub use zeroshot::{build_suite, score_task, Example, TASK_NAMES};

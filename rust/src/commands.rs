//! CLI subcommand implementations (the `elsa` binary surface).

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::coordinator::elsa::{prune_elsa, ElsaOptions};
use crate::coordinator::patterns::Pattern;
use crate::coordinator::pretrain::{pretrain_cached, PretrainOptions};
use crate::coordinator::{self};
use crate::data::Dataset;
use crate::model::checkpoint::Checkpoint;
use crate::model::Params;
use crate::quant::Precision;
use crate::runtime::Runtime;

pub fn dispatch(args: &Args) -> Result<()> {
    match args.cmd.as_str() {
        "pretrain" => cmd_pretrain(args),
        "prune" => cmd_prune(args),
        "eval" => cmd_eval(args),
        // `infer` is the serving alias: --batch N --threads N drives
        // the batched engine
        "generate" | "infer" => crate::infer::cmd_generate(args),
        // continuous-batching scheduler over a seeded request stream
        "serve" => crate::infer::scheduler::cmd_serve(args),
        "exp" => crate::experiments::cmd_exp(args),
        other => bail!(
            "unknown subcommand '{other}'\n\
             usage: elsa <pretrain|prune|eval|generate|infer|serve|exp> \
             [--flags]"),
    }
}

pub fn open_runtime(args: &Args) -> Result<Runtime> {
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    Runtime::load(&dir)
}

fn ckpt_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("ckpt-dir", "checkpoints"))
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let cfg_name = args.str_or("config", "tiny");
    let cfg = rt.manifest.config(&cfg_name)?.clone();
    let steps = args.usize_or("steps", 400)?;
    let ds = Dataset::standard(&args.str_or("dataset", "synth-c4"),
                               cfg.vocab);
    let mut opts = PretrainOptions::new(steps);
    opts.lr = args.f32_or("lr", opts.lr)?;
    opts.seed = args.usize_or("seed", 0)? as u64;
    let p = pretrain_cached(&rt, &cfg, &ds.train, &opts, &ckpt_dir(args))?;
    let ppl = coordinator::eval_ppl(&rt, &cfg, &p, &ds.valid)?;
    crate::info!("pretrain", "dense valid ppl = {ppl:.3}");
    println!("dense_ppl {ppl:.4}");
    Ok(())
}

pub fn parse_elsa_options(args: &Args, sparsity: f64, steps: usize)
                          -> Result<ElsaOptions> {
    let mut opts = ElsaOptions::new(sparsity, steps);
    opts.lr = args.f32_or("lr", opts.lr)?;
    opts.lam = args.f32_or("lam", opts.lam)?;
    opts.interval_k = args.usize_or("interval-k", opts.interval_k)?;
    opts.seed = args.usize_or("seed", 0)? as u64;
    if args.bool("no-objective-aware") {
        opts.objective_aware = false;
    }
    if let Some(p) = args.get("pattern") {
        opts.pattern = Pattern::parse(p)
            .ok_or_else(|| anyhow::anyhow!("bad --pattern '{p}'"))?;
    }
    if args.bool("low-memory") {
        opts = opts.low_memory();
    }
    if let Some(zp) = args.get("z-prec") {
        opts.z_prec = Precision::parse(zp)
            .ok_or_else(|| anyhow::anyhow!("bad --z-prec '{zp}'"))?;
    }
    if let Some(up) = args.get("u-prec") {
        opts.u_prec = Precision::parse(up)
            .ok_or_else(|| anyhow::anyhow!("bad --u-prec '{up}'"))?;
    }
    Ok(opts)
}

fn cmd_prune(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let cfg_name = args.str_or("config", "tiny");
    let cfg = rt.manifest.config(&cfg_name)?.clone();
    let sparsity = args.f64_or("sparsity", 0.9)?;
    let method = args.str_or("method", "elsa");
    let ds = Dataset::standard(&args.str_or("dataset", "synth-c4"),
                               cfg.vocab);

    // dense base model (pretrained + cached)
    let psteps = args.usize_or("pretrain-steps", 400)?;
    let dense = pretrain_cached(&rt, &cfg, &ds.train,
                                &PretrainOptions::new(psteps),
                                &ckpt_dir(args))?;
    let dense_ppl = coordinator::eval_ppl(&rt, &cfg, &dense, &ds.valid)?;

    let steps = args.usize_or("steps", 300)?;
    let (pruned, note) = match method.as_str() {
        "elsa" => {
            let opts = parse_elsa_options(args, sparsity, steps)?;
            let (p, m) = prune_elsa(&rt, &cfg, &ds.train, &dense, &opts)?;
            (p, format!("achieved={:.4} aux_state={} wall={:.1}s",
                        m.achieved_sparsity,
                        crate::util::human_bytes(m.aux_state_bytes),
                        m.wall_seconds))
        }
        other => {
            let popts = crate::pruners::PruneOptions::from_args(args)?;
            // TIMING-OK: wall-seconds for the summary line only.
            let t0 = std::time::Instant::now();
            let p = crate::pruners::prune_oneshot(
                &rt, &cfg, other, &dense, &ds.train, sparsity, args)?;
            (p, format!("workers={} alloc={} wall={:.1}s",
                        popts.workers, popts.alloc.name(),
                        t0.elapsed().as_secs_f64()))
        }
    };

    let params = Params::new(&cfg, pruned.clone());
    let ppl = coordinator::eval_ppl(&rt, &cfg, &pruned, &ds.valid)?;
    crate::info!("prune", "{method} @ {sparsity}: ppl {dense_ppl:.2} -> \
                  {ppl:.2} (sparsity {:.4}) {note}", params.sparsity());
    println!("method {method}");
    println!("sparsity {:.4}", params.sparsity());
    println!("dense_ppl {dense_ppl:.4}");
    println!("pruned_ppl {ppl:.4}");

    if let Some(out) = args.get("out") {
        let mut ck = Checkpoint::new(&cfg.name);
        ck.insert("params", pruned);
        ck.save(&PathBuf::from(out))?;
        crate::info!("prune", "saved to {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let ck = Checkpoint::load(&PathBuf::from(args.require("ckpt")?))?;
    let cfg = rt.manifest.config(&ck.config)?.clone();
    let params = ck.get("params")?.clone();
    let ds = Dataset::standard(&args.str_or("dataset", "synth-c4"),
                               cfg.vocab);
    let ppl = coordinator::eval_ppl(&rt, &cfg, &params, &ds.valid)?;
    let p = Params::new(&cfg, params);
    println!("config {}", cfg.name);
    println!("sparsity {:.4}", p.sparsity());
    println!("ppl {ppl:.4}");
    Ok(())
}

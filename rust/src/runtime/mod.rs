//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`. Text
//! (not serialized proto) is the interchange format — jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns them.
//!
//! Python never runs here: every graph was lowered once by `make
//! artifacts` and is compiled lazily on first use, then cached for the
//! lifetime of the `Runtime`.

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable,
          XlaComputation};

pub use manifest::{AdamHp, ArgSpec, ArtifactSpec, ConfigEntry, DType,
                   Manifest, Segment};

pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

/// A compiled artifact plus its manifest spec (for arg validation).
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: PjRtLoadedExecutable,
}

impl Runtime {
    /// Load the manifest in `dir` and create the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::debug!("runtime", "PJRT platform={} devices={}",
                      client.platform_name(), client.device_count());
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Compile (or fetch the cached) artifact `art` of config `cfg`.
    pub fn executable(&self, cfg: &str, art: &str) -> Result<Rc<Executable>> {
        let key = format!("{cfg}/{art}");
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let spec = self.manifest.config(cfg)?.artifact(art)?.clone();
        let exe = self.compile_file(&spec.file)?;
        let e = Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(key, e.clone());
        Ok(e)
    }

    /// Compile a standalone artifact (e.g. the quant round-trip demo).
    pub fn compile_file(&self, file: &str) -> Result<PjRtLoadedExecutable> {
        let path = self.dir.join(file);
        let t = crate::util::timer::Timer::start();
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        crate::debug!("runtime", "compiled {} in {:.2}s", file, t.seconds());
        Ok(exe)
    }

    /// Execute with positional literals; unwraps the 1-tuple convention
    /// (aot.py lowers with return_tuple=True) into the flat output list.
    pub fn execute(&self, exe: &Executable, args: &[Literal])
                   -> Result<Vec<Literal>> {
        if args.len() != exe.spec.args.len() {
            bail!("artifact '{}' expects {} args, got {}",
                  exe.spec.file, exe.spec.args.len(), args.len());
        }
        let result = exe.exe.execute::<Literal>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        let outs = lit.to_tuple()?;
        if outs.len() != exe.spec.outputs.len() {
            bail!("artifact '{}' returned {} outputs, expected {}",
                  exe.spec.file, outs.len(), exe.spec.outputs.len());
        }
        Ok(outs)
    }
}

// ---------------------------------------------------------------------
// Literal plumbing
// ---------------------------------------------------------------------

/// f32 slice -> rank-1 literal.
pub fn lit_f32(xs: &[f32]) -> Literal {
    Literal::vec1(xs)
}

/// f32 scalar literal (rank 0).
pub fn lit_scalar(x: f32) -> Literal {
    Literal::scalar(x)
}

/// i32 matrix -> rank-2 literal of shape (rows, cols).
pub fn lit_i32_2d(xs: &[i32], rows: usize, cols: usize) -> Result<Literal> {
    assert_eq!(xs.len(), rows * cols);
    Ok(Literal::vec1(xs).reshape(&[rows as i64, cols as i64])?)
}

/// literal -> Vec<f32> (any shape, flattened).
pub fn to_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// rank-0 f32 literal -> f32.
pub fn to_scalar(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the single contract between the compile path and the
//! rust hot path: artifact files, argument/output specs, the flat
//! parameter layout, and the Adam hyperparameters baked into the HLO.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unknown dtype '{s}'"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ArgSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

/// One contiguous named region of the flat parameter vector.
#[derive(Debug, Clone)]
pub struct Segment {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
    pub prunable: bool,
    pub init: String,
}

impl Segment {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_matrix(&self) -> bool {
        self.shape.len() == 2
    }

    pub fn end(&self) -> usize {
        self.offset + self.len()
    }
}

#[derive(Debug, Clone)]
pub struct ConfigEntry {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub d_ff: usize,
    pub lora_rank: usize,
    pub lora_alpha: f32,
    pub flat_len: usize,
    pub lora_len: usize,
    pub segments: Vec<Segment>,
    pub lora_segments: Vec<Segment>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl ConfigEntry {
    pub fn segment(&self, name: &str) -> Result<&Segment> {
        self.segments
            .iter()
            .find(|s| s.name == name)
            .with_context(|| format!("no segment '{name}'"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("no artifact '{name}'"))
    }

    /// Prunable coordinate count (the denominator of every sparsity %).
    pub fn prunable_len(&self) -> usize {
        self.segments.iter().filter(|s| s.prunable).map(|s| s.len()).sum()
    }

    /// 0/1 mask over the flat vector marking prunable coordinates.
    pub fn prunable_mask(&self) -> Vec<f32> {
        let mut m = vec![0.0f32; self.flat_len];
        for seg in self.segments.iter().filter(|s| s.prunable) {
            m[seg.offset..seg.end()].fill(1.0);
        }
        m
    }
}

#[derive(Debug, Clone, Copy)]
pub struct AdamHp {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

#[derive(Debug, Clone)]
pub struct QuantDemo {
    pub file: String,
    pub n: usize,
    pub vmax: f32,
}

#[derive(Debug)]
pub struct Manifest {
    pub use_pallas: bool,
    pub adam: AdamHp,
    pub configs: BTreeMap<String, ConfigEntry>,
    pub quant_demo: Option<QuantDemo>,
}

fn parse_args(v: &Value) -> Result<Vec<ArgSpec>> {
    v.as_arr()?
        .iter()
        .map(|a| {
            Ok(ArgSpec {
                name: a.get("name")?.as_str()?.to_string(),
                shape: a.get("shape")?.as_usize_vec()?,
                dtype: DType::parse(a.get("dtype")?.as_str()?)?,
            })
        })
        .collect()
}

fn parse_segments(v: &Value, with_prunable: bool) -> Result<Vec<Segment>> {
    v.as_arr()?
        .iter()
        .map(|s| {
            Ok(Segment {
                name: s.get("name")?.as_str()?.to_string(),
                offset: s.get("offset")?.as_usize()?,
                shape: s.get("shape")?.as_usize_vec()?,
                prunable: if with_prunable {
                    s.get("prunable")?.as_bool()?
                } else {
                    false
                },
                init: s.get("init")?.as_str()?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).context("parsing manifest.json")?;

        let adam_v = v.get("adam")?;
        let adam = AdamHp {
            beta1: adam_v.get("beta1")?.as_f64()? as f32,
            beta2: adam_v.get("beta2")?.as_f64()? as f32,
            eps: adam_v.get("eps")?.as_f64()? as f32,
        };

        let mut configs = BTreeMap::new();
        for (name, c) in v.get("configs")?.as_obj()? {
            let mut artifacts = BTreeMap::new();
            for (aname, a) in c.get("artifacts")?.as_obj()? {
                artifacts.insert(
                    aname.clone(),
                    ArtifactSpec {
                        file: a.get("file")?.as_str()?.to_string(),
                        args: parse_args(a.get("args")?)?,
                        outputs: parse_args(a.get("outputs")?)?,
                    },
                );
            }
            let entry = ConfigEntry {
                name: name.clone(),
                vocab: c.get("vocab")?.as_usize()?,
                d_model: c.get("d_model")?.as_usize()?,
                n_layers: c.get("n_layers")?.as_usize()?,
                n_heads: c.get("n_heads")?.as_usize()?,
                seq_len: c.get("seq_len")?.as_usize()?,
                batch: c.get("batch")?.as_usize()?,
                eval_batch: c.get("eval_batch")?.as_usize()?,
                d_ff: c.get("d_ff")?.as_usize()?,
                lora_rank: c.get("lora_rank")?.as_usize()?,
                lora_alpha: c.get("lora_alpha")?.as_f64()? as f32,
                flat_len: c.get("flat_len")?.as_usize()?,
                lora_len: c.get("lora_len")?.as_usize()?,
                segments: parse_segments(c.get("segments")?, true)?,
                lora_segments: parse_segments(c.get("lora_segments")?, false)?,
                artifacts,
            };
            // integrity: segments must tile [0, flat_len) contiguously
            let mut off = 0;
            for seg in &entry.segments {
                if seg.offset != off {
                    bail!("manifest segment '{}' not contiguous", seg.name);
                }
                off = seg.end();
            }
            if off != entry.flat_len {
                bail!("segments cover {off} != flat_len {}", entry.flat_len);
            }
            configs.insert(name.clone(), entry);
        }

        let quant_demo = match v.opt("quant_roundtrip") {
            Some(q) => Some(QuantDemo {
                file: q.get("file")?.as_str()?.to_string(),
                n: q.get("n")?.as_usize()?,
                vmax: q.get("vmax")?.as_f64()? as f32,
            }),
            None => None,
        };

        Ok(Manifest {
            use_pallas: v.get("use_pallas")?.as_bool()?,
            adam,
            configs,
            quant_demo,
        })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigEntry> {
        self.configs
            .get(name)
            .with_context(|| format!("no config '{name}' in manifest"))
    }
}

//! Learning-rate and penalty schedules (paper Tables 4-5).
//!
//! The paper keeps λ constant for moderate sparsity (50-60%) and uses a
//! cosine ramp 0 → λ for high sparsity (70-90%), with a linearly decaying
//! learning rate throughout.

/// LR schedule over `total` steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    Constant,
    /// linear decay from lr to lr*floor_frac
    LinearDecay { floor_frac: f32 },
}

impl LrSchedule {
    pub fn at(&self, base: f32, step: usize, total: usize) -> f32 {
        match self {
            LrSchedule::Constant => base,
            LrSchedule::LinearDecay { floor_frac } => {
                let t = step as f32 / total.max(1) as f32;
                base * (1.0 - t * (1.0 - floor_frac))
            }
        }
    }
}

/// Penalty (λ) schedule over `total` steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PenaltySchedule {
    Constant,
    /// cosine ramp: 0 at step 0 rising to λ at the final step
    CosineRamp,
}

impl PenaltySchedule {
    pub fn at(&self, lam: f32, step: usize, total: usize) -> f32 {
        match self {
            PenaltySchedule::Constant => lam,
            PenaltySchedule::CosineRamp => {
                // 0 -> lam following (1 - cos(pi t)) / 2, saturating at
                // 60% of training so the final x-updates run against the
                // full-strength constraint (keeps the primal residual low
                // going into the terminal projection).
                let t = (step as f32 / (0.6 * total.max(1) as f32))
                    .clamp(0.0, 1.0);
                lam * 0.5 * (1.0 - (std::f32::consts::PI * t).cos())
            }
        }
    }

    /// The paper's rule of thumb: constant for <= 60% sparsity, cosine
    /// ramp above (Table 5).
    pub fn for_sparsity(sparsity: f64) -> PenaltySchedule {
        if sparsity <= 0.60 {
            PenaltySchedule::Constant
        } else {
            PenaltySchedule::CosineRamp
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_decay_endpoints() {
        let s = LrSchedule::LinearDecay { floor_frac: 0.1 };
        assert_eq!(s.at(1.0, 0, 100), 1.0);
        assert!((s.at(1.0, 100, 100) - 0.1).abs() < 1e-6);
        assert!((s.at(1.0, 50, 100) - 0.55).abs() < 1e-6);
    }

    #[test]
    fn cosine_ramp_monotone() {
        let s = PenaltySchedule::CosineRamp;
        let mut prev = -1.0;
        for t in 0..=50 {
            let v = s.at(2.0, t, 50);
            assert!(v >= prev, "not monotone at {t}");
            prev = v;
        }
        assert!(s.at(2.0, 0, 50).abs() < 1e-6);
        assert!((s.at(2.0, 50, 50) - 2.0).abs() < 1e-5);
    }

    #[test]
    fn schedule_selection_rule() {
        assert_eq!(PenaltySchedule::for_sparsity(0.5),
                   PenaltySchedule::Constant);
        assert_eq!(PenaltySchedule::for_sparsity(0.9),
                   PenaltySchedule::CosineRamp);
    }
}

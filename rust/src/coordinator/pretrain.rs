//! Dense pretraining: produces the "pretrained LLM" every pruning
//! experiment starts from (the paper's substitution for downloading
//! OPT/LLaMA checkpoints — DESIGN.md §3).
//!
//! Reuses the train_step artifact with λ=0 (plain Adam), linear-decay LR.

use anyhow::Result;

use super::schedule::LrSchedule;
use crate::data::Batcher;
use crate::model::checkpoint::Checkpoint;
use crate::model::Params;
use crate::runtime::{ConfigEntry, Runtime};

#[derive(Debug, Clone)]
pub struct PretrainOptions {
    pub steps: usize,
    pub lr: f32,
    pub lr_schedule: LrSchedule,
    pub seed: u64,
    pub log_every: usize,
}

impl PretrainOptions {
    pub fn new(steps: usize) -> PretrainOptions {
        PretrainOptions {
            steps,
            lr: 3e-3,
            lr_schedule: LrSchedule::LinearDecay { floor_frac: 0.1 },
            seed: 0,
            log_every: 50,
        }
    }
}

/// Pretrain from random init; returns (params, per-step losses).
pub fn pretrain(rt: &Runtime, cfg: &ConfigEntry, train: &[u32],
                opts: &PretrainOptions) -> Result<(Vec<f32>, Vec<f32>)> {
    let d = cfg.flat_len;
    let exe = rt.executable(&cfg.name, "train_step")?;
    let init = Params::init(cfg, opts.seed);
    let zeros = vec![0.0f32; d];
    let ones = vec![1.0f32; d];
    let pmask = cfg.prunable_mask();
    let mut batcher = Batcher::new(train, cfg.batch, cfg.seq_len,
                                   opts.seed ^ 0x5eed);

    let mut p = init.flat;
    let mut m = zeros.clone();
    let mut v = zeros.clone();
    let mut losses = Vec::with_capacity(opts.steps);
    for t in 1..=opts.steps {
        let lr = opts.lr_schedule.at(opts.lr, t, opts.steps);
        let batch = batcher.next_batch();
        let (np, nm, nv, loss) = super::run_train_step(
            rt, &exe, cfg, &p, &m, &v, &zeros, &zeros, &ones, &pmask,
            &batch, t as f32, lr, 0.0)?;
        p = np;
        m = nm;
        v = nv;
        losses.push(loss);
        if opts.log_every > 0 && t % opts.log_every == 0 {
            crate::info!("pretrain", "{}/{} loss={loss:.4} lr={lr:.2e}",
                         t, opts.steps);
        }
    }
    Ok((p, losses))
}

/// Pretrain-or-load: caches the dense model under `cache_dir` so the
/// experiment suite pretrains each config exactly once.
pub fn pretrain_cached(rt: &Runtime, cfg: &ConfigEntry, train: &[u32],
                       opts: &PretrainOptions, cache_dir: &std::path::Path)
                       -> Result<Vec<f32>> {
    let path = cache_dir.join(format!("{}_dense_s{}.bin", cfg.name,
                                      opts.steps));
    if path.exists() {
        let ck = Checkpoint::load(&path)?;
        anyhow::ensure!(ck.config == cfg.name, "checkpoint config mismatch");
        let p = ck.get("params")?.clone();
        anyhow::ensure!(p.len() == cfg.flat_len);
        crate::info!("pretrain", "loaded cached dense model {}",
                     path.display());
        return Ok(p);
    }
    let (p, losses) = pretrain(rt, cfg, train, opts)?;
    let mut ck = Checkpoint::new(&cfg.name);
    ck.insert("params", p.clone());
    ck.insert("final_losses",
              losses[losses.len().saturating_sub(16)..].to_vec());
    ck.save(&path)?;
    crate::info!("pretrain", "saved dense model to {}", path.display());
    Ok(p)
}

//! Sparsity patterns: the feasible set S of the z-update (eq. 8 / §C.1).
//!
//! Given a per-coordinate score vector over the flat parameters, build
//! the 0/1 keep-mask implementing the projection onto:
//!  - `Global`      — ||z||_0 <= k over ALL prunable coordinates jointly
//!                    (the surrogate-free ELSA set; the global top-k is
//!                    what distinguishes it from layer-wise methods),
//!  - `PerLayer`    — uniform per-segment sparsity (baseline convention),
//!  - `NM{n, m}`    — N:M semi-structured along the input dimension
//!                    (Table 8),
//!  - `NonUniform`  — per-segment budgets from OWL / EvoPress (Table 7).
//!
//! Non-prunable coordinates are always kept.

use std::collections::BTreeMap;

use crate::runtime::ConfigEntry;
use crate::tensor::select::topk_mask;

#[derive(Debug, Clone, PartialEq)]
pub enum Pattern {
    Global,
    PerLayer,
    NM { n: usize, m: usize },
    /// segment name -> sparsity (fraction pruned); segments absent from
    /// the map fall back to `default`
    NonUniform { per_segment: BTreeMap<String, f64>, default: f64 },
}

impl Pattern {
    pub fn parse(s: &str) -> Option<Pattern> {
        match s {
            "global" => Some(Pattern::Global),
            "per-layer" => Some(Pattern::PerLayer),
            _ => {
                // "2:4" / "4:8"
                let (n, m) = s.split_once(':')?;
                Some(Pattern::NM { n: n.parse().ok()?, m: m.parse().ok()? })
            }
        }
    }
}

/// Build the keep-mask over the flat vector. `sparsity` is the fraction
/// of *prunable* weights to remove. Scores must be >= 0 (larger = more
/// important); non-prunable coordinates get mask 1 regardless.
pub fn project_mask(cfg: &ConfigEntry, scores: &[f32], pattern: &Pattern,
                    sparsity: f64) -> Vec<f32> {
    assert_eq!(scores.len(), cfg.flat_len);
    let mut mask = vec![1.0f32; cfg.flat_len];
    match pattern {
        Pattern::Global => {
            // gather prunable scores, global top-k, scatter back
            let prunable: Vec<(usize, f32)> = cfg
                .segments
                .iter()
                .filter(|s| s.prunable)
                .flat_map(|s| (s.offset..s.end()).map(|i| (i, scores[i])))
                .collect();
            let keep = ((1.0 - sparsity) * prunable.len() as f64).round()
                as usize;
            let vals: Vec<f32> = prunable.iter().map(|(_, v)| *v).collect();
            let sub = topk_mask(&vals, keep.min(vals.len()));
            for ((i, _), &m) in prunable.iter().zip(sub.iter()) {
                mask[*i] = m;
            }
        }
        Pattern::PerLayer => {
            for seg in cfg.segments.iter().filter(|s| s.prunable) {
                let vals = &scores[seg.offset..seg.end()];
                let keep = ((1.0 - sparsity) * vals.len() as f64).round()
                    as usize;
                let sub = topk_mask(vals, keep.min(vals.len()));
                mask[seg.offset..seg.end()].copy_from_slice(&sub);
            }
        }
        Pattern::NM { n, m } => {
            assert!(n <= m && *m > 0);
            for seg in cfg.segments.iter().filter(|s| s.prunable) {
                let (rows, cols) = (seg.shape[0], seg.shape[1]);
                // groups of M consecutive weights along the input (row)
                // dimension of each output column
                for c in 0..cols {
                    let mut r = 0;
                    while r < rows {
                        let g = (rows - r).min(*m);
                        let grp: Vec<f32> = (0..g)
                            .map(|i| scores[seg.offset + (r + i) * cols + c])
                            .collect();
                        let keep = (*n).min(g);
                        let sub = topk_mask(&grp, keep);
                        for i in 0..g {
                            mask[seg.offset + (r + i) * cols + c] = sub[i];
                        }
                        r += g;
                    }
                }
            }
        }
        Pattern::NonUniform { per_segment, default } => {
            for seg in cfg.segments.iter().filter(|s| s.prunable) {
                let sp = per_segment.get(&seg.name).copied()
                    .unwrap_or(*default);
                let vals = &scores[seg.offset..seg.end()];
                let keep = ((1.0 - sp) * vals.len() as f64).round() as usize;
                let sub = topk_mask(vals, keep.min(vals.len()));
                mask[seg.offset..seg.end()].copy_from_slice(&sub);
            }
        }
    }
    mask
}

/// Achieved sparsity of a mask over the prunable set.
pub fn mask_sparsity(cfg: &ConfigEntry, mask: &[f32]) -> f64 {
    let mut zeros = 0usize;
    let mut total = 0usize;
    for seg in cfg.segments.iter().filter(|s| s.prunable) {
        zeros += mask[seg.offset..seg.end()]
            .iter()
            .filter(|x| **x == 0.0)
            .count();
        total += seg.len();
    }
    zeros as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fake_config;
    use crate::util::rng::Rng;

    fn scores(cfg: &ConfigEntry, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..cfg.flat_len).map(|_| rng.f32()).collect()
    }

    #[test]
    fn global_hits_exact_sparsity() {
        let cfg = fake_config();
        let sc = scores(&cfg, 0);
        for sp in [0.3, 0.5, 0.9] {
            let mask = project_mask(&cfg, &sc, &Pattern::Global, sp);
            assert!((mask_sparsity(&cfg, &mask) - sp).abs() < 0.01,
                    "sp={sp}");
        }
    }

    #[test]
    fn global_never_touches_nonprunable() {
        let cfg = fake_config();
        let sc = scores(&cfg, 1);
        let mask = project_mask(&cfg, &sc, &Pattern::Global, 0.99);
        for seg in cfg.segments.iter().filter(|s| !s.prunable) {
            assert!(mask[seg.offset..seg.end()].iter().all(|&m| m == 1.0),
                    "{} was pruned", seg.name);
        }
    }

    #[test]
    fn per_layer_uniform_within_each_segment() {
        let cfg = fake_config();
        let sc = scores(&cfg, 2);
        let mask = project_mask(&cfg, &sc, &Pattern::PerLayer, 0.5);
        for seg in cfg.segments.iter().filter(|s| s.prunable) {
            let kept: usize = mask[seg.offset..seg.end()]
                .iter()
                .filter(|x| **x > 0.0)
                .count();
            assert_eq!(kept, seg.len() / 2, "{}", seg.name);
        }
    }

    #[test]
    fn nm_pattern_respects_group_budget() {
        let cfg = fake_config();
        let sc = scores(&cfg, 3);
        let mask = project_mask(&cfg, &sc,
                                &Pattern::NM { n: 2, m: 4 }, 0.5);
        for seg in cfg.segments.iter().filter(|s| s.prunable) {
            let (rows, cols) = (seg.shape[0], seg.shape[1]);
            for c in 0..cols {
                let mut r = 0;
                while r < rows {
                    let g = (rows - r).min(4);
                    let kept: usize = (0..g)
                        .filter(|i| {
                            mask[seg.offset + (r + i) * cols + c] > 0.0
                        })
                        .count();
                    assert_eq!(kept, 2.min(g), "{} col {c} row {r}",
                               seg.name);
                    r += g;
                }
            }
        }
        // overall N:M(2:4) == 50%
        assert!((mask_sparsity(&cfg, &mask) - 0.5).abs() < 0.05);
    }

    #[test]
    fn non_uniform_budgets() {
        let cfg = fake_config();
        let sc = scores(&cfg, 4);
        let mut per = BTreeMap::new();
        per.insert("l0.attn.wq".to_string(), 0.9);
        let mask = project_mask(
            &cfg, &sc,
            &Pattern::NonUniform { per_segment: per, default: 0.25 }, 0.0);
        let wq = cfg.segment("l0.attn.wq").unwrap();
        let kept: usize = mask[wq.offset..wq.end()]
            .iter().filter(|x| **x > 0.0).count();
        assert_eq!(kept, (wq.len() as f64 * 0.1).round() as usize);
        let wk = cfg.segment("l0.attn.wk").unwrap();
        let kept_k: usize = mask[wk.offset..wk.end()]
            .iter().filter(|x| **x > 0.0).count();
        assert_eq!(kept_k, (wk.len() as f64 * 0.75).round() as usize);
    }

    #[test]
    fn pattern_parse() {
        assert_eq!(Pattern::parse("global"), Some(Pattern::Global));
        assert_eq!(Pattern::parse("2:4"),
                   Some(Pattern::NM { n: 2, m: 4 }));
        assert_eq!(Pattern::parse("junk"), None);
    }
}

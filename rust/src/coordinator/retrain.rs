//! Retraining baselines for Tables 2-3: one-shot prune (Wanda) followed
//! by either full fine-tuning of the surviving weights or LoRA adapters.
//!
//! Full FT reuses the train_step artifact with a frozen weight mask
//! (masked forward + masked updates: pruned coords have zero gradient by
//! the chain rule, so they stay dead — tested in python/tests). LoRA
//! drives the lora_train_step artifact and folds adapters back with
//! lora_merge.

use anyhow::Result;

use super::schedule::LrSchedule;
use crate::data::Batcher;
use crate::runtime::{self, ConfigEntry, Runtime};

#[derive(Debug, Clone)]
pub struct RetrainOptions {
    pub steps: usize,
    pub lr: f32,
    pub lr_schedule: LrSchedule,
    pub seed: u64,
}

impl RetrainOptions {
    pub fn new(steps: usize, lr: f32) -> RetrainOptions {
        RetrainOptions {
            steps,
            lr,
            lr_schedule: LrSchedule::LinearDecay { floor_frac: 0.1 },
            seed: 1,
        }
    }
}

/// Full fine-tuning of the unpruned weights under a frozen mask.
/// `mask` is the flat keep-mask (1 = alive); params must already be
/// masked. Returns (params, losses).
pub fn full_retrain(rt: &Runtime, cfg: &ConfigEntry, train: &[u32],
                    params: &[f32], mask: &[f32], opts: &RetrainOptions)
                    -> Result<(Vec<f32>, Vec<f32>)> {
    let d = cfg.flat_len;
    let exe = rt.executable(&cfg.name, "train_step")?;
    let zeros = vec![0.0f32; d];
    let pmask = cfg.prunable_mask();
    let mut batcher = Batcher::new(train, cfg.batch, cfg.seq_len,
                                   opts.seed);
    let mut p: Vec<f32> = params
        .iter()
        .zip(mask.iter())
        .map(|(&x, &m)| x * m)
        .collect();
    let mut m_st = zeros.clone();
    let mut v_st = zeros.clone();
    let mut losses = Vec::with_capacity(opts.steps);
    for t in 1..=opts.steps {
        let lr = opts.lr_schedule.at(opts.lr, t, opts.steps);
        let batch = batcher.next_batch();
        let (np, nm, nv, loss) = super::run_train_step(
            rt, &exe, cfg, &p, &m_st, &v_st, &zeros, &zeros, mask, &pmask,
            &batch, t as f32, lr, 0.0)?;
        p = np;
        m_st = nm;
        v_st = nv;
        losses.push(loss);
    }
    // Belt-and-braces: the masked coords are zero-gradient by
    // construction, but enforce exact zeros against fp drift.
    for (x, &mk) in p.iter_mut().zip(mask.iter()) {
        if mk == 0.0 {
            *x = 0.0;
        }
    }
    Ok((p, losses))
}

/// LoRA retraining: rank-r adapters trained on top of the frozen masked
/// base, then merged. NOTE: merging densifies the adapted matrices — the
/// merged model is only *approximately* sparse, which is exactly the
/// deployment caveat the paper raises for LoRA at extreme sparsity.
/// Returns (merged params, losses).
pub fn lora_retrain(rt: &Runtime, cfg: &ConfigEntry, train: &[u32],
                    params: &[f32], mask: &[f32], opts: &RetrainOptions)
                    -> Result<(Vec<f32>, Vec<f32>)> {
    let dl = cfg.lora_len;
    let exe = rt.executable(&cfg.name, "lora_train_step")?;
    let merge = rt.executable(&cfg.name, "lora_merge")?;
    let masked: Vec<f32> = params
        .iter()
        .zip(mask.iter())
        .map(|(&x, &m)| x * m)
        .collect();

    // init A ~ N(0, 1/sqrt(din)), B = 0 — mirrors model.init_lora
    let mut rng = crate::util::rng::Rng::new(opts.seed);
    let mut lora = vec![0.0f32; dl];
    for seg in &cfg.lora_segments {
        if seg.init == "normal" {
            let std = 1.0 / (seg.shape[0] as f32).sqrt();
            let end = seg.offset + seg.shape.iter().product::<usize>();
            for x in lora[seg.offset..end].iter_mut() {
                *x = rng.normal() * std;
            }
        }
    }

    let mut m_st = vec![0.0f32; dl];
    let mut v_st = vec![0.0f32; dl];
    let mut batcher = Batcher::new(train, cfg.batch, cfg.seq_len,
                                   opts.seed ^ 0x10ca);
    let mut losses = Vec::with_capacity(opts.steps);
    let base_lit = runtime::lit_f32(&masked);
    for t in 1..=opts.steps {
        let lr = opts.lr_schedule.at(opts.lr, t, opts.steps);
        let batch = batcher.next_batch();
        let outs = rt.execute(&exe, &[
            base_lit.clone(),
            runtime::lit_f32(&lora),
            runtime::lit_f32(&m_st),
            runtime::lit_f32(&v_st),
            runtime::lit_f32(mask),
            runtime::lit_i32_2d(&batch, cfg.batch, cfg.seq_len + 1)?,
            runtime::lit_scalar(t as f32),
            runtime::lit_scalar(lr),
        ])?;
        lora = runtime::to_f32(&outs[0])?;
        m_st = runtime::to_f32(&outs[1])?;
        v_st = runtime::to_f32(&outs[2])?;
        losses.push(runtime::to_scalar(&outs[3])?);
    }

    let outs = rt.execute(&merge, &[
        runtime::lit_f32(&masked),
        runtime::lit_f32(&lora),
    ])?;
    Ok((runtime::to_f32(&outs[0])?, losses))
}

//! L3 coordinator — the paper's system contribution.
//!
//! The ADMM pruning orchestrator (ELSA / ELSA-L), the pretrainer that
//! produces the dense models every experiment starts from, and the
//! retrainers used by the Wanda+Full / Wanda+LoRA baselines. All compute
//! flows through the AOT HLO artifacts via `runtime::Runtime`; the
//! coordinator owns schedules, the z/u updates, state precision, and
//! metrics.

pub mod elsa;
pub mod patterns;
pub mod pretrain;
pub mod retrain;
pub mod schedule;

use anyhow::Result;
use xla::Literal;

use crate::runtime::{self, ConfigEntry, Executable, Runtime};

/// One train_step invocation: feeds the 11-arg artifact, returns the
/// updated (params, m, v) and the batch loss.
#[allow(clippy::too_many_arguments)]
pub fn run_train_step(rt: &Runtime, exe: &Executable, cfg: &ConfigEntry,
                      p: &[f32], m: &[f32], v: &[f32], z: &[f32],
                      u: &[f32], wmask: &[f32], pmask: &[f32],
                      batch: &[i32], step: f32, lr: f32, lam: f32)
                      -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
    let outs = rt.execute(exe, &[
        runtime::lit_f32(p),
        runtime::lit_f32(m),
        runtime::lit_f32(v),
        runtime::lit_f32(z),
        runtime::lit_f32(u),
        runtime::lit_f32(wmask),
        runtime::lit_f32(pmask),
        runtime::lit_i32_2d(batch, cfg.batch, cfg.seq_len + 1)?,
        runtime::lit_scalar(step),
        runtime::lit_scalar(lr),
        runtime::lit_scalar(lam),
    ])?;
    Ok((
        runtime::to_f32(&outs[0])?,
        runtime::to_f32(&outs[1])?,
        runtime::to_f32(&outs[2])?,
        runtime::to_scalar(&outs[3])?,
    ))
}

/// Perplexity of `params` on a token stream via the eval_loss artifact.
pub fn eval_ppl(rt: &Runtime, cfg: &ConfigEntry, params: &[f32],
                tokens: &[u32]) -> Result<f64> {
    let exe = rt.executable(&cfg.name, "eval_loss")?;
    let batches =
        crate::data::Batcher::eval_batches(tokens, cfg.eval_batch,
                                           cfg.seq_len);
    anyhow::ensure!(!batches.is_empty(), "eval stream too short");
    let plit: Literal = runtime::lit_f32(params);
    let mut nll = 0.0f64;
    let mut count = 0.0f64;
    for b in &batches {
        let outs = rt.execute(&exe, &[
            plit.clone(),
            runtime::lit_i32_2d(b, cfg.eval_batch, cfg.seq_len + 1)?,
        ])?;
        nll += runtime::to_scalar(&outs[0])? as f64;
        count += runtime::to_scalar(&outs[1])? as f64;
    }
    Ok((nll / count).exp())
}

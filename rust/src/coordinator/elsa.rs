//! ELSA / ELSA-L: surrogate-free ADMM sparsification (paper §3).
//!
//! The outer loop alternates:
//!   x-update (eq. 7)  — `interval_k` fused Adam+proximal HLO steps on
//!                       the true next-token objective,
//!   z-update (eq. 8/11) — projection of x+u onto the sparsity set, in
//!                       the diag-Fisher norm recycled from Adam's second
//!                       moments (objective-aware projection, §3.2),
//!   u-update (eq. 9)  — dual ascent u += x - z.
//!
//! ELSA-L (§3.3) stores (z, u) — and optionally the Adam moments — in low
//! precision between outer iterations through the quant/dequant cycle of
//! eq. (12)-(13); the convergence condition of Thm 4.6 bounds how much
//! quantization noise (γ) the penalty λ can absorb.

use anyhow::Result;

use super::patterns::{mask_sparsity, project_mask, Pattern};
use super::schedule::{LrSchedule, PenaltySchedule};
use crate::data::Batcher;
use crate::quant::{Precision, StoredVec};
use crate::runtime::{ConfigEntry, Runtime};
use crate::util::timer::Timer;

#[derive(Debug, Clone)]
pub struct ElsaOptions {
    pub steps: usize,
    pub lr: f32,
    pub lam: f32,
    pub lam_schedule: PenaltySchedule,
    pub lr_schedule: LrSchedule,
    /// x-steps between consecutive z/u updates (paper Table 4: 32).
    pub interval_k: usize,
    pub sparsity: f64,
    pub pattern: Pattern,
    /// Fisher-weighted projection (§3.2). Off = plain Euclidean (ablation
    /// Table 9).
    pub objective_aware: bool,
    /// ELSA-L state precisions; F32/F32 = plain ELSA.
    pub z_prec: Precision,
    pub u_prec: Precision,
    /// Block-wise INT8 Adam moments (the adam8bit analogue, §5.4).
    pub adam8bit: bool,
    pub seed: u64,
}

impl ElsaOptions {
    pub fn new(sparsity: f64, steps: usize) -> ElsaOptions {
        ElsaOptions {
            steps,
            lr: 1e-3,
            lam: 1e-2,
            lam_schedule: PenaltySchedule::for_sparsity(sparsity),
            lr_schedule: LrSchedule::LinearDecay { floor_frac: 0.1 },
            interval_k: 32,
            sparsity,
            pattern: Pattern::Global,
            objective_aware: true,
            z_prec: Precision::F32,
            u_prec: Precision::F32,
            adam8bit: false,
            seed: 0,
        }
    }

    /// ELSA-L preset: (bf16, fp8) for (u, z) + 8-bit Adam (paper §5.4).
    pub fn low_memory(mut self) -> ElsaOptions {
        self.z_prec = Precision::Fp8E4M3;
        self.u_prec = Precision::Bf16;
        self.adam8bit = true;
        self
    }
}

#[derive(Debug, Clone, Default)]
pub struct PruneMetrics {
    pub losses: Vec<f32>,
    /// (step, ||x-z|| / ||x||) at each outer iteration
    pub residuals: Vec<(usize, f64)>,
    /// peak bytes held by the ADMM auxiliary states (z, u)
    pub aux_state_bytes: usize,
    /// peak bytes held by the optimizer moments (m, v)
    pub opt_state_bytes: usize,
    pub achieved_sparsity: f64,
    pub wall_seconds: f64,
}

/// Run ELSA on `init` params; returns (exactly-sparse params, metrics).
pub fn prune_elsa(rt: &Runtime, cfg: &ConfigEntry, train: &[u32],
                  init: &[f32], opts: &ElsaOptions)
                  -> Result<(Vec<f32>, PruneMetrics)> {
    let timer = Timer::start();
    let d = cfg.flat_len;
    anyhow::ensure!(init.len() == d, "param length mismatch");
    let exe = rt.executable(&cfg.name, "train_step")?;
    let pmask = cfg.prunable_mask();
    let wmask = vec![1.0f32; d];
    let mut batcher = Batcher::new(train, cfg.batch, cfg.seq_len,
                                   opts.seed);

    let mut p = init.to_vec();
    let mut m = vec![0.0f32; d];
    let mut v = vec![0.0f32; d];

    // z0 = Pi_S(x0) by magnitude (Fisher is empty before any step),
    // u0 = 0.
    let mut z = project(cfg, &p, &vec![0.0; d], &v, &pmask, opts, false);
    let mut u = vec![0.0f32; d];

    let mut metrics = PruneMetrics::default();
    track_state_mem(&z, &u, &m, &v, opts, &mut metrics);

    for t in 1..=opts.steps {
        let lr = opts.lr_schedule.at(opts.lr, t, opts.steps);
        let lam = opts.lam_schedule.at(opts.lam, t, opts.steps);
        let batch = batcher.next_batch();
        let (np, nm, nv, loss) = super::run_train_step(
            rt, &exe, cfg, &p, &m, &v, &z, &u, &wmask, &pmask, &batch,
            t as f32, lr, lam)?;
        p = np;
        m = nm;
        v = nv;
        metrics.losses.push(loss);

        if opts.adam8bit {
            // adam8bit cycle: moments live in block-wise INT8 between
            // steps; rematerialize for the next update.
            // m: signed linear blocks; v: sqrt-companded unsigned blocks
            // (linear INT8 on v zeroes small second moments and the
            // update explodes — see quant::Precision::U8Sqrt)
            let ms = StoredVec::quantize(&m, Precision::Int8Block(256));
            let vs = StoredVec::quantize(&v, Precision::U8Sqrt(256));
            m = ms.dequantize();
            v = vs.dequantize();
        }

        if t % opts.interval_k == 0 || t == opts.steps {
            // z-update: objective-aware projection of x + u (eq. 11)
            z = project(cfg, &p, &u, &v, &pmask, opts,
                        opts.objective_aware);
            // u-update: dual ascent (eq. 9), only where the constraint
            // lives (pmask gates the penalty, so the dual is zero
            // elsewhere by construction)
            let mut res_num = 0.0f64;
            let mut res_den = 0.0f64;
            for i in 0..d {
                if pmask[i] > 0.0 {
                    let r = p[i] - z[i];
                    u[i] += r;
                    res_num += (r as f64) * (r as f64);
                    res_den += (p[i] as f64) * (p[i] as f64);
                }
            }
            metrics
                .residuals
                .push((t, (res_num / res_den.max(1e-30)).sqrt()));

            // ELSA-L: states are stored quantized between outer
            // iterations; the next x-updates consume the rematerialized
            // values (the R step of eq. 13).
            let zs = StoredVec::quantize(&z, opts.z_prec);
            let us = StoredVec::quantize(&u, opts.u_prec);
            z = zs.dequantize();
            u = us.dequantize();
            track_state_mem_stored(&zs, &us, &m, &v, opts, &mut metrics);
        }
    }

    // Final retrieval: hard-project x itself (the sparse solution the
    // paper reports); Fisher weights come from the final Adam moments.
    let final_mask = scores_and_mask(cfg, &p, &vec![0.0; d], &v, &pmask,
                                     opts, opts.objective_aware);
    for i in 0..d {
        if pmask[i] > 0.0 && final_mask[i] == 0.0 {
            p[i] = 0.0;
        }
    }
    metrics.achieved_sparsity = mask_sparsity(cfg, &final_mask);
    metrics.wall_seconds = timer.seconds();
    Ok((p, metrics))
}

/// z = mask .* (x + u) with mask from the (optionally Fisher-weighted)
/// projection.
fn project(cfg: &ConfigEntry, p: &[f32], u: &[f32], fisher: &[f32],
           pmask: &[f32], opts: &ElsaOptions, objective_aware: bool)
           -> Vec<f32> {
    let mask = scores_and_mask(cfg, p, u, fisher, pmask, opts,
                               objective_aware);
    let mut z = vec![0.0f32; p.len()];
    for i in 0..p.len() {
        let xu = p[i] + u[i];
        z[i] = if pmask[i] > 0.0 { mask[i] * xu } else { xu };
    }
    z
}

fn scores_and_mask(cfg: &ConfigEntry, p: &[f32], u: &[f32], fisher: &[f32],
                   pmask: &[f32], opts: &ElsaOptions,
                   objective_aware: bool) -> Vec<f32> {
    // score_i = F_ii * (x_i + u_i)^2 (eq. 11); F=1 for the Euclidean
    // ablation. The small floor keeps never-touched coords comparable.
    let mut scores = vec![0.0f32; p.len()];
    for i in 0..p.len() {
        if pmask[i] > 0.0 {
            let xu = p[i] + u[i];
            let f = if objective_aware { fisher[i] + 1e-12 } else { 1.0 };
            scores[i] = f * xu * xu;
        }
    }
    project_mask(cfg, &scores, &opts.pattern, opts.sparsity)
}

fn track_state_mem(z: &[f32], u: &[f32], m: &[f32], v: &[f32],
                   opts: &ElsaOptions, metrics: &mut PruneMetrics) {
    let zs = StoredVec::quantize(z, opts.z_prec);
    let us = StoredVec::quantize(u, opts.u_prec);
    track_state_mem_stored(&zs, &us, m, v, opts, metrics);
}

fn track_state_mem_stored(zs: &StoredVec, us: &StoredVec, m: &[f32],
                          v: &[f32], opts: &ElsaOptions,
                          metrics: &mut PruneMetrics) {
    let aux = zs.mem_bytes() + us.mem_bytes();
    let opt = if opts.adam8bit {
        StoredVec::quantize(m, Precision::Int8Block(256)).mem_bytes()
            + StoredVec::quantize(v, Precision::U8Sqrt(256)).mem_bytes()
    } else {
        m.len() * 4 + v.len() * 4
    };
    metrics.aux_state_bytes = metrics.aux_state_bytes.max(aux);
    metrics.opt_state_bytes = metrics.opt_state_bytes.max(opt);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn options_presets() {
        let o = ElsaOptions::new(0.9, 100);
        assert_eq!(o.lam_schedule, PenaltySchedule::CosineRamp);
        assert_eq!(o.interval_k, 32);
        let l = o.low_memory();
        assert_eq!(l.z_prec, Precision::Fp8E4M3);
        assert_eq!(l.u_prec, Precision::Bf16);
        assert!(l.adam8bit);
    }

    #[test]
    fn moderate_sparsity_keeps_constant_penalty() {
        let o = ElsaOptions::new(0.5, 100);
        assert_eq!(o.lam_schedule, PenaltySchedule::Constant);
    }
}

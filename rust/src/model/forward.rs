//! Rust reference forward pass (dense).
//!
//! Mirrors python/compile/model.py exactly (pre-LN GPT, tanh-GELU,
//! causal attention, untied head) and is cross-checked against the AOT
//! `logits` artifact in tests/runtime_integration.rs. Used for:
//!  - calibration activation capture for the layer-wise baselines
//!    (Wanda / SparseGPT / L-ADMM / ALPS need per-layer X^T X),
//!  - the dense CPU baseline of the sparse inference engine,
//!  - zero-shot probe scoring when the HLO batch shape doesn't fit.

use std::collections::BTreeMap;

use anyhow::Result;

use super::Params;
use crate::tensor::Matrix;

/// jax.nn.gelu(approximate=True): 0.5x(1+tanh(sqrt(2/pi)(x+0.044715x^3))).
#[inline]
pub fn gelu_tanh(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Row-wise layernorm (eps matches the L2 model).
pub fn layernorm(x: &Matrix, g: &[f32], b: &[f32]) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    let n = x.cols as f32;
    for r in 0..x.rows {
        let row = x.row(r);
        let mean = row.iter().sum::<f32>() / n;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let orow = out.row_mut(r);
        for c in 0..x.cols {
            orow[c] = (row[c] - mean) * inv * g[c] + b[c];
        }
    }
    out
}

/// Softmax over the last axis with causal masking already applied.
fn softmax_rows(m: &mut Matrix) {
    for r in 0..m.rows {
        let row = m.row_mut(r);
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Calibration statistics for one linear layer: running X^T X + row count.
#[derive(Debug, Clone)]
pub struct CalibStat {
    pub gram: Matrix,
    pub rows: usize,
}

impl CalibStat {
    pub fn new(dim: usize) -> CalibStat {
        CalibStat { gram: Matrix::zeros(dim, dim), rows: 0 }
    }

    pub fn add(&mut self, x: &Matrix) {
        assert_eq!(x.cols, self.gram.cols);
        let g = x.gram();
        for (a, b) in self.gram.data.iter_mut().zip(g.data.iter()) {
            *a += b;
        }
        self.rows += x.rows;
    }

    /// Column L2 norms of the calibration inputs (Wanda's activation term).
    pub fn col_norms(&self) -> Vec<f32> {
        (0..self.gram.cols).map(|i| self.gram.at(i, i).sqrt()).collect()
    }
}

/// Per-layer calibration capture, keyed by segment name.
pub type CalibSet = BTreeMap<String, CalibStat>;

/// Causal self-attention for one sequence. x: (S, D) -> (S, D).
fn attention_seq(x: &Matrix, wq: &Matrix, wk: &Matrix, wv: &Matrix,
                 n_heads: usize) -> Matrix {
    let (s, d) = (x.rows, x.cols);
    let dh = d / n_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let q = x.matmul(wq);
    let k = x.matmul(wk);
    let v = x.matmul(wv);
    let mut out = Matrix::zeros(s, d);
    for h in 0..n_heads {
        let c0 = h * dh;
        // scores (S, S) for this head
        let mut scores = Matrix::zeros(s, s);
        for i in 0..s {
            let qi = &q.row(i)[c0..c0 + dh];
            for j in 0..=i {
                let kj = &k.row(j)[c0..c0 + dh];
                let mut acc = 0.0f32;
                for t in 0..dh {
                    acc += qi[t] * kj[t];
                }
                *scores.at_mut(i, j) = acc * scale;
            }
            for j in i + 1..s {
                *scores.at_mut(i, j) = f32::NEG_INFINITY;
            }
        }
        softmax_rows(&mut scores);
        for i in 0..s {
            let orow = &mut out.row_mut(i)[c0..c0 + dh];
            for j in 0..=i {
                let p = scores.at(i, j);
                if p == 0.0 {
                    continue;
                }
                let vj = &v.row(j)[c0..c0 + dh];
                for t in 0..dh {
                    orow[t] += p * vj[t];
                }
            }
        }
    }
    out
}

fn add_bias(m: &mut Matrix, b: &[f32]) {
    for r in 0..m.rows {
        let row = m.row_mut(r);
        for (x, bi) in row.iter_mut().zip(b.iter()) {
            *x += bi;
        }
    }
}

fn add_into(dst: &mut Matrix, src: &Matrix) {
    for (a, b) in dst.data.iter_mut().zip(src.data.iter()) {
        *a += b;
    }
}

/// Full forward for one sequence of tokens. Returns logits (S, V).
/// If `calib` is Some, accumulates the input activations of every
/// prunable linear into it.
pub fn forward_seq(p: &Params, tokens: &[u32],
                   mut calib: Option<&mut CalibSet>) -> Result<Matrix> {
    let cfg = &p.cfg;
    let s = tokens.len();
    let d = cfg.d_model;
    let embed = p.matrix("embed")?;
    let pos = p.matrix("pos")?;

    let mut x = Matrix::zeros(s, d);
    for (t, &tok) in tokens.iter().enumerate() {
        let e = embed.row(tok as usize);
        let pr = pos.row(t);
        let row = x.row_mut(t);
        for c in 0..d {
            row[c] = e[c] + pr[c];
        }
    }

    for l in 0..cfg.n_layers {
        let pre = format!("l{l}.");
        let ln1 = layernorm(&x, p.vector(&(pre.clone() + "ln1.g"))?,
                            p.vector(&(pre.clone() + "ln1.b"))?);
        if let Some(cal) = calib.as_deref_mut() {
            for t in ["attn.wq", "attn.wk", "attn.wv"] {
                cal.entry(pre.clone() + t)
                    .or_insert_with(|| CalibStat::new(d))
                    .add(&ln1);
            }
        }
        let wq = p.matrix(&(pre.clone() + "attn.wq"))?;
        let wk = p.matrix(&(pre.clone() + "attn.wk"))?;
        let wv = p.matrix(&(pre.clone() + "attn.wv"))?;
        let o = attention_seq(&ln1, &wq, &wk, &wv, cfg.n_heads);
        if let Some(cal) = calib.as_deref_mut() {
            cal.entry(pre.clone() + "attn.wo")
                .or_insert_with(|| CalibStat::new(d))
                .add(&o);
        }
        let wo = p.matrix(&(pre.clone() + "attn.wo"))?;
        add_into(&mut x, &o.matmul(&wo));

        let ln2 = layernorm(&x, p.vector(&(pre.clone() + "ln2.g"))?,
                            p.vector(&(pre.clone() + "ln2.b"))?);
        if let Some(cal) = calib.as_deref_mut() {
            cal.entry(pre.clone() + "mlp.w1")
                .or_insert_with(|| CalibStat::new(d))
                .add(&ln2);
        }
        let w1 = p.matrix(&(pre.clone() + "mlp.w1"))?;
        let mut h = ln2.matmul(&w1);
        add_bias(&mut h, p.vector(&(pre.clone() + "mlp.b1"))?);
        for v in h.data.iter_mut() {
            *v = gelu_tanh(*v);
        }
        if let Some(cal) = calib.as_deref_mut() {
            cal.entry(pre.clone() + "mlp.w2")
                .or_insert_with(|| CalibStat::new(cfg.d_ff))
                .add(&h);
        }
        let w2 = p.matrix(&(pre.clone() + "mlp.w2"))?;
        let mut mo = h.matmul(&w2);
        add_bias(&mut mo, p.vector(&(pre.clone() + "mlp.b2"))?);
        add_into(&mut x, &mo);
    }

    let xf = layernorm(&x, p.vector("lnf.g")?, p.vector("lnf.b")?);
    let head = p.matrix("head")?;
    Ok(xf.matmul(&head))
}

/// Mean next-token NLL of a window (tokens length S+1) under the model.
pub fn nll_seq(p: &Params, window: &[u32]) -> Result<f64> {
    let inp = &window[..window.len() - 1];
    let logits = forward_seq(p, inp, None)?;
    let mut total = 0.0f64;
    for t in 0..inp.len() {
        let row = logits.row(t);
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let lse: f32 = row.iter().map(|v| (v - max).exp()).sum::<f32>().ln()
            + max;
        let tgt = window[t + 1] as usize;
        total += (lse - row[tgt]) as f64;
    }
    Ok(total / inp.len() as f64)
}

/// Run the calibration set through the model, returning per-layer stats.
pub fn collect_calibration(p: &Params, seqs: &[Vec<u32>])
                           -> Result<CalibSet> {
    let mut calib = CalibSet::new();
    for seq in seqs {
        forward_seq(p, seq, Some(&mut calib))?;
    }
    Ok(calib)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fake_config;
    use crate::model::Params;

    fn toy() -> Params {
        Params::init(&fake_config(), 0)
    }

    #[test]
    fn gelu_known_values() {
        assert!(gelu_tanh(0.0).abs() < 1e-7);
        assert!((gelu_tanh(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu_tanh(-1.0) + 0.158808).abs() < 1e-4);
        // large positive ~ identity, large negative ~ 0
        assert!((gelu_tanh(6.0) - 6.0).abs() < 1e-4);
        assert!(gelu_tanh(-6.0).abs() < 1e-4);
    }

    #[test]
    fn layernorm_normalizes() {
        let x = Matrix::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let out = layernorm(&x, &[1.0; 4], &[0.0; 4]);
        let mean: f32 = out.row(0).iter().sum::<f32>() / 4.0;
        let var: f32 =
            out.row(0).iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
            / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn forward_shapes() {
        let p = toy();
        let logits = forward_seq(&p, &[1, 2, 3, 4, 5], None).unwrap();
        assert_eq!((logits.rows, logits.cols), (5, 16));
    }

    #[test]
    fn forward_is_causal() {
        let p = toy();
        let a = forward_seq(&p, &[1, 2, 3, 4, 5, 6], None).unwrap();
        let b = forward_seq(&p, &[1, 2, 3, 9, 9, 9], None).unwrap();
        // positions 0..2 depend only on tokens 0..2
        for t in 0..3 {
            for c in 0..16 {
                assert!((a.at(t, c) - b.at(t, c)).abs() < 1e-5,
                        "leak at t={t}");
            }
        }
    }

    #[test]
    fn calibration_capture_covers_all_prunables() {
        let p = toy();
        let calib =
            collect_calibration(&p, &[vec![1, 2, 3, 4], vec![5, 6, 7, 8]])
                .unwrap();
        for seg in p.prunable_segments() {
            let stat = calib.get(&seg.name).expect(&seg.name);
            assert_eq!(stat.gram.rows, seg.shape[0]);
            assert_eq!(stat.rows, 8); // 2 seqs x 4 tokens
        }
    }

    #[test]
    fn nll_positive_and_finite() {
        let p = toy();
        let nll = nll_seq(&p, &[1, 2, 3, 4, 5]).unwrap();
        assert!(nll.is_finite() && nll > 0.0);
    }
}

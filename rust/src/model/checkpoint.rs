//! Checkpoint IO: a simple named-section binary format.
//!
//! Layout: magic "ELSACKP1" | config-name | n sections | per section:
//! name, f32 length, raw LE bytes. Sections store the flat params and
//! optionally optimizer/ADMM state for resumable pruning runs.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 8] = b"ELSACKP1";

#[derive(Debug, Default)]
pub struct Checkpoint {
    pub config: String,
    pub sections: BTreeMap<String, Vec<f32>>,
}

impl Checkpoint {
    pub fn new(config: &str) -> Checkpoint {
        Checkpoint { config: config.to_string(), sections: BTreeMap::new() }
    }

    pub fn insert(&mut self, name: &str, data: Vec<f32>) {
        self.sections.insert(name.to_string(), data);
    }

    pub fn get(&self, name: &str) -> Result<&Vec<f32>> {
        self.sections
            .get(name)
            .with_context(|| format!("checkpoint missing section '{name}'"))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        write_str(&mut f, &self.config)?;
        f.write_all(&(self.sections.len() as u32).to_le_bytes())?;
        for (name, data) in &self.sections {
            write_str(&mut f, name)?;
            f.write_all(&(data.len() as u64).to_le_bytes())?;
            // SAFETY-free path: stream as LE bytes
            let mut buf = Vec::with_capacity(data.len() * 4);
            for x in data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            f.write_all(&buf)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("opening {}", path.display()))?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not an ELSA checkpoint", path.display());
        }
        let config = read_str(&mut f)?;
        let mut n = [0u8; 4];
        f.read_exact(&mut n)?;
        let n = u32::from_le_bytes(n) as usize;
        let mut sections = BTreeMap::new();
        for _ in 0..n {
            let name = read_str(&mut f)?;
            let mut len8 = [0u8; 8];
            f.read_exact(&mut len8)?;
            let len = u64::from_le_bytes(len8) as usize;
            let mut bytes = vec![0u8; len * 4];
            f.read_exact(&mut bytes)?;
            let data = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            sections.insert(name, data);
        }
        Ok(Checkpoint { config, sections })
    }
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len > 1 << 20 {
        bail!("implausible string length {len}");
    }
    let mut bytes = vec![0u8; len];
    r.read_exact(&mut bytes)?;
    Ok(String::from_utf8(bytes)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("elsa_ckpt_test");
        let path = dir.join("a.bin");
        let mut c = Checkpoint::new("tiny");
        c.insert("params", vec![1.0, -2.5, 3.25]);
        c.insert("m", vec![0.0; 10]);
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.config, "tiny");
        assert_eq!(back.get("params").unwrap(), &vec![1.0, -2.5, 3.25]);
        assert_eq!(back.get("m").unwrap().len(), 10);
        assert!(back.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("elsa_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTACKPT________").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Model plumbing on the rust side: parameter views over the flat vector,
//! initialization, checkpoints, and the rust reference forward.
//!
//! The flat vector + manifest layout is the contract with L2 (see
//! DESIGN.md §2): `Params` wraps one `Vec<f32>` and hands out per-segment
//! matrix views for the baseline pruners and the sparse inference engine.

pub mod checkpoint;
pub mod forward;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::runtime::{ConfigEntry, Segment};
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Build a self-contained synthetic `ConfigEntry` (no manifest file):
/// the standard pre-LN GPT layout with the given shape knobs. Used by
/// the unit/integration tests and the serving benchmarks, which need a
/// model config without the AOT artifact pipeline.
pub fn synthetic_config(name: &str, d_model: usize, n_layers: usize,
                        n_heads: usize, d_ff: usize, vocab: usize,
                        seq_len: usize) -> ConfigEntry {
    assert_eq!(d_model % n_heads, 0, "d_model must divide into heads");
    let mut segments: Vec<Segment> = vec![];
    let mut off = 0usize;
    let mut add = |name: String, shape: Vec<usize>, prunable: bool,
                   init: &str, segments: &mut Vec<Segment>| {
        let len: usize = shape.iter().product();
        segments.push(Segment {
            name,
            offset: off,
            shape,
            prunable,
            init: init.into(),
        });
        off += len;
    };
    add("embed".into(), vec![vocab, d_model], false, "normal",
        &mut segments);
    add("pos".into(), vec![seq_len, d_model], false, "normal",
        &mut segments);
    for l in 0..n_layers {
        let p = format!("l{l}.");
        add(p.clone() + "ln1.g", vec![d_model], false, "ones",
            &mut segments);
        add(p.clone() + "ln1.b", vec![d_model], false, "zeros",
            &mut segments);
        add(p.clone() + "attn.wq", vec![d_model, d_model], true, "normal",
            &mut segments);
        add(p.clone() + "attn.wk", vec![d_model, d_model], true, "normal",
            &mut segments);
        add(p.clone() + "attn.wv", vec![d_model, d_model], true, "normal",
            &mut segments);
        add(p.clone() + "attn.wo", vec![d_model, d_model], true, "normal",
            &mut segments);
        add(p.clone() + "ln2.g", vec![d_model], false, "ones",
            &mut segments);
        add(p.clone() + "ln2.b", vec![d_model], false, "zeros",
            &mut segments);
        add(p.clone() + "mlp.w1", vec![d_model, d_ff], true, "normal",
            &mut segments);
        add(p.clone() + "mlp.b1", vec![d_ff], false, "zeros",
            &mut segments);
        add(p.clone() + "mlp.w2", vec![d_ff, d_model], true, "normal",
            &mut segments);
        add(p.clone() + "mlp.b2", vec![d_model], false, "zeros",
            &mut segments);
    }
    add("lnf.g".into(), vec![d_model], false, "ones", &mut segments);
    add("lnf.b".into(), vec![d_model], false, "zeros", &mut segments);
    add("head".into(), vec![d_model, vocab], false, "normal",
        &mut segments);
    let flat_len = off;
    ConfigEntry {
        name: name.into(),
        vocab,
        d_model,
        n_layers,
        n_heads,
        seq_len,
        batch: 2,
        eval_batch: 2,
        d_ff,
        lora_rank: 2,
        lora_alpha: 8.0,
        flat_len,
        lora_len: 0,
        segments,
        lora_segments: vec![],
        artifacts: BTreeMap::new(),
    }
}

/// The miniature config every unit test uses (d=4, one layer).
pub fn fake_config() -> ConfigEntry {
    synthetic_config("fake", 4, 1, 2, 16, 16, 8)
}

/// A model instance: flat parameters + its manifest config.
#[derive(Debug, Clone)]
pub struct Params {
    pub flat: Vec<f32>,
    pub cfg: ConfigEntry,
}

impl Params {
    pub fn new(cfg: &ConfigEntry, flat: Vec<f32>) -> Params {
        assert_eq!(flat.len(), cfg.flat_len);
        Params { flat, cfg: cfg.clone() }
    }

    /// Initialize like python model.init_params: ones for LN gains,
    /// zeros for biases, scaled normals for weights. (Distributionally
    /// identical, not bit-identical — the RNGs differ.)
    pub fn init(cfg: &ConfigEntry, seed: u64) -> Params {
        let mut rng = Rng::new(seed);
        let mut flat = vec![0.0f32; cfg.flat_len];
        for seg in &cfg.segments {
            let sl = &mut flat[seg.offset..seg.end()];
            match seg.init.as_str() {
                "ones" => sl.fill(1.0),
                "zeros" => sl.fill(0.0),
                _ => {
                    let std = if seg.name == "embed" || seg.name == "pos" {
                        0.02
                    } else {
                        let fan_in = if seg.shape.len() == 2 {
                            seg.shape[0]
                        } else {
                            cfg.d_model
                        };
                        1.0 / (fan_in as f32).sqrt()
                    };
                    for x in sl.iter_mut() {
                        *x = rng.normal() * std;
                    }
                }
            }
        }
        Params { flat, cfg: cfg.clone() }
    }

    /// Immutable matrix view (copies; segments are small).
    pub fn matrix(&self, name: &str) -> Result<Matrix> {
        let seg = self.cfg.segment(name)?;
        anyhow::ensure!(seg.is_matrix(), "segment '{name}' is not 2-D");
        Ok(Matrix::from_vec(
            seg.shape[0],
            seg.shape[1],
            self.flat[seg.offset..seg.end()].to_vec(),
        ))
    }

    /// Vector view.
    pub fn vector(&self, name: &str) -> Result<&[f32]> {
        let seg = self.cfg.segment(name)?;
        Ok(&self.flat[seg.offset..seg.end()])
    }

    /// Write a matrix back into its segment.
    pub fn set_matrix(&mut self, name: &str, m: &Matrix) -> Result<()> {
        let seg = self.cfg.segment(name)?.clone();
        anyhow::ensure!(seg.shape == [m.rows, m.cols], "shape mismatch");
        self.flat[seg.offset..seg.end()].copy_from_slice(&m.data);
        Ok(())
    }

    /// Prunable segments (the pruning target set), in layout order.
    pub fn prunable_segments(&self) -> Vec<Segment> {
        self.cfg.segments.iter().filter(|s| s.prunable).cloned().collect()
    }

    /// Fraction of *prunable* weights that are exactly zero.
    pub fn sparsity(&self) -> f64 {
        let mut zeros = 0usize;
        let mut total = 0usize;
        for seg in self.cfg.segments.iter().filter(|s| s.prunable) {
            for &x in &self.flat[seg.offset..seg.end()] {
                if x == 0.0 {
                    zeros += 1;
                }
            }
            total += seg.len();
        }
        zeros as f64 / total.max(1) as f64
    }

    /// Count of non-zero parameters over the whole flat vector.
    pub fn nnz_total(&self) -> usize {
        self.flat.iter().filter(|x| **x != 0.0).count()
    }

    /// Apply a 0/1 mask over the flat vector in place.
    pub fn apply_mask(&mut self, mask: &[f32]) {
        assert_eq!(mask.len(), self.flat.len());
        for (p, m) in self.flat.iter_mut().zip(mask.iter()) {
            if *m == 0.0 {
                *p = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_respects_segment_kinds() {
        let cfg = fake_config();
        let p = Params::init(&cfg, 0);
        assert!(p.vector("l0.ln1.g").unwrap().iter().all(|&x| x == 1.0));
        assert!(p.vector("l0.mlp.b1").unwrap().iter().all(|&x| x == 0.0));
        let wq = p.vector("l0.attn.wq").unwrap();
        assert!(wq.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn matrix_roundtrip() {
        let cfg = fake_config();
        let mut p = Params::init(&cfg, 1);
        let mut m = p.matrix("l0.attn.wq").unwrap();
        m.data[0] = 42.0;
        p.set_matrix("l0.attn.wq", &m).unwrap();
        assert_eq!(p.matrix("l0.attn.wq").unwrap().data[0], 42.0);
    }

    #[test]
    fn sparsity_counts_prunable_only() {
        let cfg = fake_config();
        let mut p = Params::init(&cfg, 2);
        assert!(p.sparsity() < 0.01);
        // zero half of wq
        let seg = cfg.segment("l0.attn.wq").unwrap().clone();
        for i in 0..seg.len() / 2 {
            p.flat[seg.offset + i] = 0.0;
        }
        let expected = (seg.len() / 2) as f64
            / cfg.prunable_len() as f64;
        assert!((p.sparsity() - expected).abs() < 1e-9);
    }

    #[test]
    fn apply_mask_zeroes() {
        let cfg = fake_config();
        let mut p = Params::init(&cfg, 3);
        let mut mask = vec![1.0f32; cfg.flat_len];
        mask[0] = 0.0;
        p.apply_mask(&mask);
        assert_eq!(p.flat[0], 0.0);
    }

    #[test]
    fn synthetic_config_tiles_contiguously() {
        let cfg = synthetic_config("t", 8, 2, 2, 32, 64, 16);
        let mut off = 0usize;
        for seg in &cfg.segments {
            assert_eq!(seg.offset, off, "segment '{}'", seg.name);
            off = seg.end();
        }
        assert_eq!(off, cfg.flat_len);
        assert!(cfg.prunable_len() > 0);
        // every prunable matrix present per layer
        for l in 0..2 {
            for t in ["attn.wq", "attn.wk", "attn.wv", "attn.wo",
                      "mlp.w1", "mlp.w2"] {
                let seg = cfg.segment(&format!("l{l}.{t}")).unwrap();
                assert!(seg.prunable);
            }
        }
    }
}

//! ELSA: Extreme LLM Sparsity via Surrogate-free ADMM — a rust + JAX +
//! Pallas reproduction of Lee et al., 2025 (see DESIGN.md).
//!
//! Layering (python never on the hot path):
//! - L1/L2 live in `python/compile/` and are AOT-lowered once to
//!   `artifacts/*.hlo.txt` by `make artifacts`.
//! - L3 is this crate: the ADMM pruning coordinator, baseline pruners,
//!   sparse inference engine, evaluation + experiment harness.
//!
//! The serving stack (request lifecycle, determinism contract, slots ×
//! bands × quant composition, how to add a weight format) is documented
//! end-to-end in `docs/ARCHITECTURE.md`; start there before touching
//! [`infer`] or [`sparse`].

// Lint policy (CI runs `cargo clippy --all-targets -- -D warnings` as a
// blocking job): two style lints are allowed crate-wide because they
// fight deliberate choices — the kernels index in explicit loops so the
// floating-point accumulation order stays part of the bit-exactness
// contract, and the engine/coordinator plumb wide argument lists
// through hot paths instead of bundling short-lived structs.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
// Every unsafe operation must sit in an explicit `unsafe` block even
// inside an `unsafe fn`, so the per-site `// SAFETY:` comments enforced
// by `elsa-lint` (rule 1) map one-to-one onto the operations they
// justify.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod cli;
pub mod commands;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod infer;
pub mod lint;
pub mod model;
pub mod pruners;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sparse;
pub mod tensor;
pub mod util;

use anyhow::Result;

/// Entry point for the `elsa` binary.
pub fn run_cli() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::Args::parse(&argv)?;
    commands::dispatch(&args)
}

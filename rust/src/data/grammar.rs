//! Synthetic language generator (the C4/WikiText substitute, DESIGN.md §3).
//!
//! A hidden-state Markov source with Zipfian state-conditional emissions
//! plus a *long-range agreement rule*: designated "opener" tokens force a
//! matching "closer" token exactly `AGREE_GAP` steps later. The hidden
//! dynamics make next-token prediction genuinely contextual (a bigram
//! table is not enough), and the agreement rule gives the zero-shot probe
//! tasks (eval/zeroshot.rs) a ground truth that a damaged model loses
//! progressively — the property Fig 4 measures.
//!
//! Two named corpora are derived from different seeds/shapes:
//! `synth-c4` (larger state space) and `synth-wiki` (peakier emissions),
//! mirroring the paper's two-dataset reporting.

use crate::util::rng::Rng;

/// Distance between an opener and its forced closer.
pub const AGREE_GAP: usize = 8;
/// Number of opener/closer pairs (token ids are reserved at the top of
/// the vocab so they do not collide with ordinary emissions).
pub const N_AGREE: usize = 8;

#[derive(Debug, Clone)]
pub struct Grammar {
    pub vocab: usize,
    pub n_states: usize,
    /// transition[s] = (next states, probs)
    trans: Vec<(Vec<usize>, Vec<f32>)>,
    /// emission[s] = unnormalized weights over ordinary tokens
    emit: Vec<Vec<f32>>,
    /// opener token ids (vocab-reserved) and their matching closers
    pub openers: Vec<u32>,
    pub closers: Vec<u32>,
    /// probability of injecting an opener at any step
    p_open: f32,
}

impl Grammar {
    /// Deterministically derive a grammar from (vocab, seed, shape knobs).
    pub fn new(vocab: usize, n_states: usize, zipf_a: f64, p_open: f32,
               seed: u64) -> Grammar {
        Grammar::with_seeds(vocab, n_states, zipf_a, p_open, seed, seed)
    }

    /// Separate lexicon/dynamics seeds: two corpora sharing `emit_seed`
    /// are *dialects* of the same language (same state lexicons, different
    /// dynamics) — a model trained on one transfers to the other with a
    /// moderate, meaningful distribution shift, like WikiText vs C4.
    pub fn with_seeds(vocab: usize, n_states: usize, zipf_a: f64,
                      p_open: f32, emit_seed: u64, trans_seed: u64)
                      -> Grammar {
        assert!(vocab > 2 * N_AGREE + 16, "vocab too small");
        let ordinary = vocab - 2 * N_AGREE;

        // sparse stochastic transitions: 3 successors per state
        let mut trng = Rng::new(trans_seed);
        let mut trans = Vec::with_capacity(n_states);
        for _ in 0..n_states {
            let nexts: Vec<usize> =
                (0..3).map(|_| trng.below(n_states)).collect();
            let mut probs: Vec<f32> =
                (0..3).map(|_| 0.2 + trng.f32()).collect();
            let tot: f32 = probs.iter().sum();
            probs.iter_mut().for_each(|p| *p /= tot);
            trans.push((nexts, probs));
        }

        // state-conditional Zipf over a state-specific permutation
        let mut erng = Rng::new(emit_seed);
        let mut emit = Vec::with_capacity(n_states);
        for _ in 0..n_states {
            let mut perm: Vec<usize> = (0..ordinary).collect();
            erng.shuffle(&mut perm);
            let mut w = vec![0.0f32; ordinary];
            for (rank, &tok) in perm.iter().enumerate() {
                w[tok] = (1.0 / ((rank + 1) as f64).powf(zipf_a)) as f32;
            }
            emit.push(w);
        }

        let openers = (0..N_AGREE).map(|i| (ordinary + i) as u32).collect();
        let closers =
            (0..N_AGREE).map(|i| (ordinary + N_AGREE + i) as u32).collect();

        Grammar { vocab, n_states, trans, emit, openers, closers, p_open }
    }

    /// The two standard corpora used across all experiments.
    pub fn named(name: &str, vocab: usize) -> Grammar {
        match name {
            // Zipf exponents are chosen so the language has a low enough
            // entropy floor for a tiny transformer to visibly learn it
            // (dense ppl << unigram ppl << uniform vocab) — the dynamic
            // range all pruning-damage comparisons live in.
            // Same lexicon seed -> synth-wiki is a dialect of synth-c4
            // (shared vocabulary statistics, different state dynamics):
            // a c4-trained model transfers with a visible shift, like the
            // paper's WikiText-vs-C4 dual reporting.
            "synth-c4" => Grammar::new(vocab, 12, 1.8, 0.18, 0xC4C4),
            "synth-wiki" =>
                Grammar::with_seeds(vocab, 12, 1.8, 0.18, 0xC4C4, 0x111),
            _ => panic!("unknown corpus '{name}'"),
        }
    }

    /// Map an opener token to its forced closer.
    pub fn closer_for(&self, opener: u32) -> Option<u32> {
        self.openers
            .iter()
            .position(|&o| o == opener)
            .map(|i| self.closers[i])
    }

    /// Generate a token stream of length `n`.
    pub fn generate(&self, n: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        let mut state = rng.below(self.n_states);
        let mut out = Vec::with_capacity(n);
        // pending[j] = closer forced at position j
        let mut pending: Vec<Option<u32>> = vec![None; n + AGREE_GAP + 1];
        for t in 0..n {
            let tok = if let Some(c) = pending[t] {
                c
            } else if rng.f32() < self.p_open {
                let i = rng.below(N_AGREE);
                let pos = t + AGREE_GAP;
                if pos < pending.len() {
                    pending[pos] = Some(self.closers[i]);
                }
                self.openers[i]
            } else {
                rng.categorical(&self.emit[state]) as u32
            };
            out.push(tok);
            let (nexts, probs) = &self.trans[state];
            state = nexts[rng.categorical(probs)];
        }
        out
    }

    /// True next-token distribution entropy is not closed-form here, but
    /// the Zipf shape bounds the per-state entropy; used in tests.
    pub fn ordinary_vocab(&self) -> usize {
        self.vocab - 2 * N_AGREE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let g = Grammar::named("synth-c4", 256);
        assert_eq!(g.generate(100, 1), g.generate(100, 1));
        assert_ne!(g.generate(100, 1), g.generate(100, 2));
    }

    #[test]
    fn corpora_differ() {
        let a = Grammar::named("synth-c4", 256).generate(200, 7);
        let b = Grammar::named("synth-wiki", 256).generate(200, 7);
        assert_ne!(a, b);
    }

    #[test]
    fn tokens_in_vocab() {
        let g = Grammar::named("synth-c4", 256);
        for &t in g.generate(5000, 3).iter() {
            assert!((t as usize) < 256);
        }
    }

    #[test]
    fn agreement_rule_holds() {
        let g = Grammar::named("synth-c4", 256);
        let stream = g.generate(20_000, 11);
        let mut found = 0;
        for (t, &tok) in stream.iter().enumerate() {
            if let Some(closer) = g.closer_for(tok) {
                if t + AGREE_GAP < stream.len() {
                    assert_eq!(stream[t + AGREE_GAP], closer,
                               "agreement violated at {t}");
                    found += 1;
                }
            }
        }
        assert!(found > 100, "openers too rare: {found}");
    }

    #[test]
    fn zipf_head_dominates() {
        let g = Grammar::named("synth-wiki", 256);
        let stream = g.generate(50_000, 5);
        let mut counts = vec![0usize; 256];
        for &t in &stream {
            counts[t as usize] += 1;
        }
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let top32: usize = sorted[..32].iter().sum();
        assert!(top32 as f64 > 0.35 * stream.len() as f64,
                "head mass {top32} too flat");
    }
}

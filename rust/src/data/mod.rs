//! Data pipeline: synthetic corpora, datasets, batch iterators,
//! calibration sampling.

pub mod grammar;

use crate::util::rng::Rng;
pub use grammar::Grammar;

/// A tokenized corpus with a train/validation split.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub train: Vec<u32>,
    pub valid: Vec<u32>,
}

impl Dataset {
    /// Generate `n_train`+`n_valid` tokens of the named corpus.
    pub fn generate(name: &str, vocab: usize, n_train: usize,
                    n_valid: usize, seed: u64) -> Dataset {
        let g = Grammar::named(name, vocab);
        // disjoint streams so validation is held out by construction
        let train = g.generate(n_train, seed.wrapping_mul(2) + 1);
        let valid = g.generate(n_valid, seed.wrapping_mul(2) + 2);
        Dataset { name: name.to_string(), train, valid }
    }

    /// Standard sizes used across the experiment suite.
    pub fn standard(name: &str, vocab: usize) -> Dataset {
        Dataset::generate(name, vocab, 600_000, 60_000, 0xDA7A)
    }
}

/// Iterator over (batch, seq_len+1) i32 token windows, reshuffled each
/// epoch. Mirrors the paper's "each data point has sequence length S"
/// protocol: windows are drawn at stride S so one epoch covers the
/// corpus once.
pub struct Batcher {
    tokens: Vec<u32>,
    batch: usize,
    window: usize, // seq_len + 1
    starts: Vec<usize>,
    cursor: usize,
    rng: Rng,
    pub epoch: usize,
}

impl Batcher {
    pub fn new(tokens: &[u32], batch: usize, seq_len: usize, seed: u64)
               -> Batcher {
        let window = seq_len + 1;
        assert!(tokens.len() >= window * batch,
                "corpus too small: {} tokens < {}", tokens.len(),
                window * batch);
        let n_windows = tokens.len() / window;
        let mut starts: Vec<usize> =
            (0..n_windows).map(|i| i * window).collect();
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut starts);
        Batcher {
            tokens: tokens.to_vec(),
            batch,
            window,
            starts,
            cursor: 0,
            rng,
            epoch: 0,
        }
    }

    /// Next (batch * window) i32 buffer, row-major.
    pub fn next_batch(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * self.window);
        for _ in 0..self.batch {
            if self.cursor >= self.starts.len() {
                self.rng.shuffle(&mut self.starts);
                self.cursor = 0;
                self.epoch += 1;
            }
            let s = self.starts[self.cursor];
            self.cursor += 1;
            out.extend(self.tokens[s..s + self.window].iter()
                       .map(|&t| t as i32));
        }
        out
    }

    /// Deterministic sequential batches over a corpus (for evaluation:
    /// every window visited exactly once, no shuffling).
    pub fn eval_batches(tokens: &[u32], batch: usize, seq_len: usize)
                        -> Vec<Vec<i32>> {
        let window = seq_len + 1;
        let n_windows = tokens.len() / window;
        let n_batches = n_windows / batch;
        let mut out = Vec::with_capacity(n_batches);
        for b in 0..n_batches {
            let mut buf = Vec::with_capacity(batch * window);
            for r in 0..batch {
                let s = (b * batch + r) * window;
                buf.extend(tokens[s..s + window].iter().map(|&t| t as i32));
            }
            out.push(buf);
        }
        out
    }
}

/// Calibration set: `n` sequences of `seq_len` tokens (the layer-wise
/// baselines' 128-sequence convention, Frantar & Alistarh 2023).
pub fn calibration(tokens: &[u32], n: usize, seq_len: usize, seed: u64)
                   -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    let max_start = tokens.len().saturating_sub(seq_len);
    (0..n)
        .map(|_| {
            let s = rng.below(max_start.max(1));
            tokens[s..s + seq_len].to_vec()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_split_disjoint_streams() {
        let d = Dataset::generate("synth-c4", 256, 5000, 1000, 0);
        assert_eq!(d.train.len(), 5000);
        assert_eq!(d.valid.len(), 1000);
        assert_ne!(&d.train[..1000], &d.valid[..]);
    }

    #[test]
    fn batcher_shapes_and_determinism() {
        let d = Dataset::generate("synth-c4", 256, 20_000, 0, 1);
        let mut a = Batcher::new(&d.train, 4, 16, 7);
        let mut b = Batcher::new(&d.train, 4, 16, 7);
        for _ in 0..5 {
            let x = a.next_batch();
            let y = b.next_batch();
            assert_eq!(x.len(), 4 * 17);
            assert_eq!(x, y);
        }
    }

    #[test]
    fn batcher_epochs_roll() {
        let d = Dataset::generate("synth-c4", 256, 4 * 17 * 3, 0, 2);
        let mut b = Batcher::new(&d.train, 4, 16, 0);
        for _ in 0..10 {
            b.next_batch();
        }
        assert!(b.epoch >= 2);
    }

    #[test]
    fn eval_batches_cover_once() {
        let tokens: Vec<u32> = (0..(17 * 8)).map(|i| (i % 250) as u32).collect();
        let bs = Batcher::eval_batches(&tokens, 2, 16);
        assert_eq!(bs.len(), 4);
        // first window of first batch is the corpus head
        assert_eq!(bs[0][..17],
                   tokens[..17].iter().map(|&t| t as i32)
                       .collect::<Vec<_>>()[..]);
    }

    #[test]
    fn calibration_shapes() {
        let d = Dataset::generate("synth-wiki", 256, 10_000, 0, 3);
        let c = calibration(&d.train, 32, 64, 5);
        assert_eq!(c.len(), 32);
        assert!(c.iter().all(|s| s.len() == 64));
    }
}

//! Software low-precision codecs for ELSA-L state storage (paper §3.3).
//!
//! The coordinator stores the ADMM auxiliary states (z, u) and optionally
//! the Adam moments in low precision between outer iterations, exactly
//! the quant/dequant cycle of eq. (12)-(13): Q(x) = (round(x/s), s) with
//! a per-tensor (or per-block) dynamic scale, R(q, s) = s*q. Codecs:
//!
//! - `Bf16`   — truncated-f32 storage (u in the paper's 27B run)
//! - `Fp8E4M3`/`Fp8E5M2` — byte-table FP8 (z in the paper's 27B run)
//! - `Int8`   — symmetric absmax INT8
//! - `Int8Block` — block-wise absmax INT8 (the adam8bit analogue,
//!   Dettmers et al. 2022)
//!
//! Every codec round-trips through an actual compact byte buffer so the
//! memory accounting in the Fig-5 experiment reflects real storage.

use std::sync::OnceLock;

pub const FP8_E4M3_MAX: f32 = 448.0;
pub const FP8_E5M2_MAX: f32 = 57344.0;

/// Decode an E4M3 byte (1-4-3, bias 7; no inf, S.1111.111 = NaN).
pub fn fp8_e4m3_decode(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp = ((b >> 3) & 0x0f) as i32;
    let man = (b & 0x07) as f32;
    if exp == 0x0f && man == 7.0 {
        return f32::NAN;
    }
    if exp == 0 {
        // subnormal: man * 2^-9
        sign * man * (2.0f32).powi(-9)
    } else {
        sign * (1.0 + man / 8.0) * (2.0f32).powi(exp - 7)
    }
}

/// Decode an E5M2 byte (1-5-2, bias 15; IEEE-style inf/nan).
pub fn fp8_e5m2_decode(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp = ((b >> 2) & 0x1f) as i32;
    let man = (b & 0x03) as f32;
    if exp == 0x1f {
        return if man == 0.0 { sign * f32::INFINITY } else { f32::NAN };
    }
    if exp == 0 {
        sign * man * (2.0f32).powi(-16)
    } else {
        sign * (1.0 + man / 4.0) * (2.0f32).powi(exp - 15)
    }
}

fn e4m3_table() -> &'static [(f32, u8)] {
    static T: OnceLock<Vec<(f32, u8)>> = OnceLock::new();
    T.get_or_init(|| build_table(fp8_e4m3_decode))
}

fn e5m2_table() -> &'static [(f32, u8)] {
    static T: OnceLock<Vec<(f32, u8)>> = OnceLock::new();
    T.get_or_init(|| build_table(fp8_e5m2_decode))
}

fn build_table(decode: fn(u8) -> f32) -> Vec<(f32, u8)> {
    let mut t: Vec<(f32, u8)> = (0u16..256)
        .map(|b| (decode(b as u8), b as u8))
        .filter(|(v, _)| v.is_finite())
        .collect();
    t.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    t
}

/// Nearest-value FP8 encode via the sorted decode table.
fn fp8_encode(x: f32, table: &[(f32, u8)]) -> u8 {
    let x = if x.is_nan() { 0.0 } else { x };
    let i = table.partition_point(|(v, _)| *v < x);
    if i == 0 {
        return table[0].1;
    }
    if i >= table.len() {
        return table[table.len() - 1].1;
    }
    // nearest of neighbours (ties -> lower, adequate for storage)
    let (lo, hi) = (table[i - 1], table[i]);
    if (x - lo.0).abs() <= (hi.0 - x).abs() {
        lo.1
    } else {
        hi.1
    }
}

pub fn fp8_e4m3_encode(x: f32) -> u8 {
    fp8_encode(x.clamp(-FP8_E4M3_MAX, FP8_E4M3_MAX), e4m3_table())
}

pub fn fp8_e5m2_encode(x: f32) -> u8 {
    fp8_encode(x.clamp(-FP8_E5M2_MAX, FP8_E5M2_MAX), e5m2_table())
}

// Fast path: a 64 KB LUT keyed by the bf16 bits of the input maps
// straight to the nearest FP8 code. bf16's 8 mantissa bits dominate
// FP8's 2-3, so routing the nearest-value decision through bf16 loses
// nothing measurable; this replaced a per-element binary search and took
// the 1M-element quantize from 32.5 ms to ~1 ms (EXPERIMENTS.md §Perf).
fn e4m3_lut() -> &'static [u8; 65536] {
    static T: OnceLock<Box<[u8; 65536]>> = OnceLock::new();
    T.get_or_init(|| {
        let mut t = Box::new([0u8; 65536]);
        for b in 0u32..65536 {
            let x = bf16_decode(b as u16);
            t[b as usize] = if x.is_finite() {
                fp8_encode(x.clamp(-FP8_E4M3_MAX, FP8_E4M3_MAX),
                           e4m3_table())
            } else {
                fp8_e4m3_encode(if x > 0.0 { FP8_E4M3_MAX }
                                else if x < 0.0 { -FP8_E4M3_MAX }
                                else { 0.0 })
            };
        }
        t
    })
}

/// LUT-accelerated E4M3 encode (bit-identical to `fp8_e4m3_encode` on
/// every bf16-representable input; tested on the full grid).
#[inline]
pub fn fp8_e4m3_encode_fast(x: f32) -> u8 {
    e4m3_lut()[bf16_encode(x) as usize]
}

/// bf16 = top 16 bits of f32 with round-to-nearest-even.
pub fn bf16_encode(x: f32) -> u16 {
    let bits = x.to_bits();
    let round = ((bits >> 16) & 1) + 0x7fff;
    ((bits + round) >> 16) as u16
}

pub fn bf16_decode(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

// ---------------------------------------------------------------------
// Vector codecs
// ---------------------------------------------------------------------

/// Storage precision for a state vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F32,
    Bf16,
    Fp8E4M3,
    Fp8E5M2,
    Int8,
    /// block-wise absmax INT8 with the given block size (adam8bit style)
    Int8Block(usize),
    /// block-wise sqrt-companded unsigned 8-bit for NON-NEGATIVE tensors
    /// (Adam second moments): code = round(255*sqrt(x/s)), decode =
    /// (c/255)^2 * s. Quadratic spacing concentrates codes near zero —
    /// first non-zero level ~1.5e-5*s vs 3.9e-3*s linear — which keeps
    /// 1/sqrt(v_hat) bounded (a linear INT8 v zeroes small moments and
    /// the Adam update explodes; the dynamic-quantization insight of
    /// Dettmers et al. 2022).
    U8Sqrt(usize),
}

impl Precision {
    pub fn parse(s: &str) -> Option<Precision> {
        Some(match s {
            "f32" => Precision::F32,
            "bf16" => Precision::Bf16,
            "fp8" | "fp8e4m3" => Precision::Fp8E4M3,
            "fp8e5m2" => Precision::Fp8E5M2,
            "int8" => Precision::Int8,
            "int8block" => Precision::Int8Block(256),
            _ => return None,
        })
    }
}

/// A state vector held in its storage precision.
#[derive(Debug, Clone)]
pub enum StoredVec {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    /// FP8 with a per-tensor dynamic scale (eq. 12): codes store x/s.
    Fp8 { codes: Vec<u8>, scale: f32, e5m2: bool },
    Int8 { codes: Vec<i8>, scale: f32 },
    Int8Block { codes: Vec<i8>, scales: Vec<f32>, block: usize },
    U8Sqrt { codes: Vec<u8>, scales: Vec<f32>, block: usize },
}

impl StoredVec {
    /// Q: quantize a f32 vector into its storage form.
    pub fn quantize(xs: &[f32], p: Precision) -> StoredVec {
        match p {
            Precision::F32 => StoredVec::F32(xs.to_vec()),
            Precision::Bf16 => {
                StoredVec::Bf16(xs.iter().map(|&x| bf16_encode(x)).collect())
            }
            Precision::Fp8E4M3 | Precision::Fp8E5M2 => {
                let e5m2 = p == Precision::Fp8E5M2;
                let vmax = if e5m2 { FP8_E5M2_MAX } else { FP8_E4M3_MAX };
                let absmax = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
                let scale = if absmax > 0.0 { absmax / vmax } else { 1.0 };
                let codes = if e5m2 {
                    xs.iter().map(|&x| fp8_e5m2_encode(x / scale))
                        .collect()
                } else {
                    let inv = 1.0 / scale;
                    xs.iter().map(|&x| fp8_e4m3_encode_fast(x * inv))
                        .collect()
                };
                StoredVec::Fp8 { codes, scale, e5m2 }
            }
            Precision::Int8 => {
                let absmax = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
                let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
                let codes = xs
                    .iter()
                    .map(|&x| (x / scale).round().clamp(-127.0, 127.0) as i8)
                    .collect();
                StoredVec::Int8 { codes, scale }
            }
            Precision::Int8Block(block) => {
                let mut codes = Vec::with_capacity(xs.len());
                let mut scales = Vec::with_capacity(xs.len() / block + 1);
                for chunk in xs.chunks(block) {
                    let absmax =
                        chunk.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
                    let scale =
                        if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
                    scales.push(scale);
                    codes.extend(chunk.iter().map(|&x| {
                        (x / scale).round().clamp(-127.0, 127.0) as i8
                    }));
                }
                StoredVec::Int8Block { codes, scales, block }
            }
            Precision::U8Sqrt(block) => {
                let mut codes = Vec::with_capacity(xs.len());
                let mut scales = Vec::with_capacity(xs.len() / block + 1);
                for chunk in xs.chunks(block) {
                    let absmax =
                        chunk.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
                    let scale =
                        if absmax > 0.0 { absmax } else { 1.0 };
                    scales.push(scale);
                    codes.extend(chunk.iter().map(|&x| {
                        let r = (x.max(0.0) / scale).sqrt();
                        (r * 255.0).round().clamp(0.0, 255.0) as u8
                    }));
                }
                StoredVec::U8Sqrt { codes, scales, block }
            }
        }
    }

    /// R: rematerialize the f32 vector.
    pub fn dequantize(&self) -> Vec<f32> {
        match self {
            StoredVec::F32(v) => v.clone(),
            StoredVec::Bf16(v) => v.iter().map(|&b| bf16_decode(b)).collect(),
            StoredVec::Fp8 { codes, scale, e5m2 } => {
                // 256-entry decode LUT (powi per element was the decode
                // bottleneck — EXPERIMENTS.md §Perf)
                let dec = if *e5m2 { fp8_e5m2_decode as fn(u8) -> f32 }
                          else { fp8_e4m3_decode as fn(u8) -> f32 };
                let mut lut = [0.0f32; 256];
                for (b, v) in lut.iter_mut().enumerate() {
                    *v = dec(b as u8) * scale;
                }
                codes.iter().map(|&b| lut[b as usize]).collect()
            }
            StoredVec::Int8 { codes, scale } => {
                codes.iter().map(|&c| c as f32 * scale).collect()
            }
            StoredVec::Int8Block { codes, scales, block } => codes
                .chunks(*block)
                .zip(scales.iter())
                .flat_map(|(chunk, &s)| {
                    chunk.iter().map(move |&c| c as f32 * s)
                })
                .collect(),
            StoredVec::U8Sqrt { codes, scales, block } => codes
                .chunks(*block)
                .zip(scales.iter())
                .flat_map(|(chunk, &s)| {
                    chunk.iter().map(move |&c| {
                        let r = c as f32 / 255.0;
                        r * r * s
                    })
                })
                .collect(),
        }
    }

    /// Actual storage footprint in bytes (the Fig-5 accounting).
    pub fn mem_bytes(&self) -> usize {
        match self {
            StoredVec::F32(v) => v.len() * 4,
            StoredVec::Bf16(v) => v.len() * 2,
            StoredVec::Fp8 { codes, .. } => codes.len() + 4,
            StoredVec::Int8 { codes, .. } => codes.len() + 4,
            StoredVec::Int8Block { codes, scales, .. } => {
                codes.len() + scales.len() * 4
            }
            StoredVec::U8Sqrt { codes, scales, .. } => {
                codes.len() + scales.len() * 4
            }
        }
    }

    pub fn len(&self) -> usize {
        match self {
            StoredVec::F32(v) => v.len(),
            StoredVec::Bf16(v) => v.len(),
            StoredVec::Fp8 { codes, .. } => codes.len(),
            StoredVec::Int8 { codes, .. } => codes.len(),
            StoredVec::Int8Block { codes, .. } => codes.len(),
            StoredVec::U8Sqrt { codes, .. } => codes.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn e4m3_decode_known_values() {
        assert_eq!(fp8_e4m3_decode(0x00), 0.0);
        assert_eq!(fp8_e4m3_decode(0x38), 1.0); // exp=7, man=0
        assert_eq!(fp8_e4m3_decode(0xb8), -1.0);
        assert_eq!(fp8_e4m3_decode(0x7e), 448.0); // max finite
        assert!(fp8_e4m3_decode(0x7f).is_nan());
        // smallest subnormal = 2^-9
        assert!((fp8_e4m3_decode(0x01) - 0.001953125).abs() < 1e-9);
    }

    #[test]
    fn e5m2_decode_known_values() {
        assert_eq!(fp8_e5m2_decode(0x3c), 1.0); // exp=15, man=0
        assert_eq!(fp8_e5m2_decode(0x7b), 57344.0); // max finite
        assert!(fp8_e5m2_decode(0x7c).is_infinite());
    }

    #[test]
    fn fp8_encode_decode_exact_on_grid() {
        for b in 0u16..256 {
            let v = fp8_e4m3_decode(b as u8);
            if !v.is_finite() {
                continue;
            }
            let rt = fp8_e4m3_decode(fp8_e4m3_encode(v));
            assert_eq!(rt, v, "byte {b:#x}");
        }
    }

    #[test]
    fn fp8_fast_lut_matches_reference_on_grid() {
        // every bf16-exact value must encode identically via the LUT
        for b in 0u16..=u16::MAX {
            let x = bf16_decode(b);
            if !x.is_finite() {
                continue;
            }
            let slow =
                fp8_e4m3_encode(x.clamp(-FP8_E4M3_MAX, FP8_E4M3_MAX));
            let fast = fp8_e4m3_encode_fast(x);
            assert_eq!(fp8_e4m3_decode(slow), fp8_e4m3_decode(fast),
                       "bf16 bits {b:#x} ({x})");
        }
    }

    #[test]
    fn fp8_relative_error_bounded() {
        let mut rng = Rng::new(0);
        for _ in 0..2000 {
            let x = rng.normal() * 10.0;
            let rt = fp8_e4m3_decode(fp8_e4m3_encode(x));
            if x.abs() > 0.02 {
                // 3 mantissa bits -> <= ~6.7% relative step, half for RTN
                assert!((rt - x).abs() / x.abs() < 0.0667,
                        "x={x} rt={rt}");
            }
        }
    }

    #[test]
    fn bf16_roundtrip_precision() {
        let mut rng = Rng::new(1);
        for _ in 0..2000 {
            let x = rng.normal() * 100.0;
            let rt = bf16_decode(bf16_encode(x));
            assert!((rt - x).abs() <= x.abs() * 0.004 + 1e-30, "x={x}");
        }
        assert_eq!(bf16_decode(bf16_encode(1.0)), 1.0);
        assert_eq!(bf16_decode(bf16_encode(0.0)), 0.0);
    }

    #[test]
    fn stored_vec_roundtrip_error_by_precision() {
        let mut rng = Rng::new(2);
        let xs: Vec<f32> = (0..4096).map(|_| rng.normal()).collect();
        let absmax = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        for (p, tol_rel) in [
            (Precision::F32, 0.0f32),
            (Precision::Bf16, 0.004),
            (Precision::Int8, 0.5 / 127.0),
            (Precision::Int8Block(256), 0.5 / 127.0),
        ] {
            let sv = StoredVec::quantize(&xs, p);
            let back = sv.dequantize();
            let max_err = xs
                .iter()
                .zip(back.iter())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_err <= tol_rel * absmax + 1e-7,
                    "{p:?}: err {max_err}");
        }
    }

    #[test]
    fn blockwise_beats_per_tensor_on_outliers() {
        // one huge outlier ruins a per-tensor scale but not block scales
        let mut xs = vec![0.01f32; 4096];
        xs[0] = 100.0;
        let per_tensor = StoredVec::quantize(&xs, Precision::Int8);
        let blockwise = StoredVec::quantize(&xs, Precision::Int8Block(256));
        // compare outside the outlier's block: block scales recover the
        // small values there, the per-tensor scale cannot
        let err = |sv: &StoredVec| {
            sv.dequantize()
                .iter()
                .zip(xs.iter())
                .skip(256)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max)
        };
        assert!(err(&blockwise) < err(&per_tensor) * 0.1,
                "block {} vs tensor {}", err(&blockwise), err(&per_tensor));
    }

    // Edge cases feeding the serving-path quantizer
    // (`sparse::quantized` mirrors this scale machinery; its own tests
    // cover the NaN/inf `ensure!` rejection and int4 odd-row packing).

    #[test]
    fn all_zero_blocks_round_trip_exactly_with_unit_scale() {
        // an all-zero tensor must not divide by an absmax of 0: the
        // scale falls back to 1.0 and every value round-trips to an
        // exact +0.0 (no NaN, no -0.0 from a negative scale)
        let xs = vec![0.0f32; 700];
        for p in [Precision::Int8, Precision::Int8Block(256)] {
            let sv = StoredVec::quantize(&xs, p);
            let back = sv.dequantize();
            assert_eq!(back.len(), xs.len());
            assert!(back.iter().all(|&v| v == 0.0 && !v.is_sign_negative()),
                    "{p:?}");
        }
        if let StoredVec::Int8Block { scales, .. } =
            StoredVec::quantize(&xs, Precision::Int8Block(256))
        {
            assert_eq!(scales, vec![1.0; 3]); // 256+256+188-tail blocks
        } else {
            unreachable!();
        }
        // a zero block embedded in a nonzero tensor gets its own unit
        // scale instead of inheriting a neighbour's
        let mut mixed = vec![0.0f32; 512];
        mixed[300] = 5.0;
        if let StoredVec::Int8Block { scales, .. } =
            StoredVec::quantize(&mixed, Precision::Int8Block(256))
        {
            assert_eq!(scales[0], 1.0);
            assert_eq!(scales[1], 5.0 / 127.0);
        } else {
            unreachable!();
        }
    }

    #[test]
    fn absmax_at_block_boundaries_is_exact() {
        // the absmax element quantizes to exactly ±127 and so
        // round-trips exactly; placing it at the last index of one
        // block and the first of the next verifies the chunking is
        // half-open [k*block, (k+1)*block) with no off-by-one leakage
        let block = 64;
        let mut xs = vec![0.25f32; 4 * block];
        xs[block - 1] = -3.0; // last element of block 0
        xs[block] = 7.0; // first element of block 1
        let sv = StoredVec::quantize(&xs, Precision::Int8Block(block));
        let back = sv.dequantize();
        assert_eq!(back[block - 1], -3.0);
        assert_eq!(back[block], 7.0);
        if let StoredVec::Int8Block { scales, codes, .. } = &sv {
            assert_eq!(scales.len(), 4);
            assert_eq!(scales[0], 3.0 / 127.0);
            assert_eq!(scales[1], 7.0 / 127.0);
            // blocks 2/3 never see the outliers
            assert_eq!(scales[2], 0.25 / 127.0);
            assert_eq!(codes[block - 1], -127);
            assert_eq!(codes[block], 127);
        } else {
            unreachable!();
        }
    }

    #[test]
    fn tail_block_shorter_than_block_size_is_scaled_independently() {
        let block = 256;
        let mut xs = vec![1.0f32; block + 10];
        xs[block + 3] = 50.0; // tail-only outlier
        let sv = StoredVec::quantize(&xs, Precision::Int8Block(block));
        if let StoredVec::Int8Block { scales, .. } = &sv {
            assert_eq!(scales.len(), 2);
            assert_eq!(scales[0], 1.0 / 127.0); // full block unpolluted
            assert_eq!(scales[1], 50.0 / 127.0);
        } else {
            unreachable!();
        }
        let back = sv.dequantize();
        assert_eq!(back.len(), xs.len());
        assert_eq!(back[block + 3], 50.0);
    }

    #[test]
    fn memory_footprints() {
        let xs = vec![1.0f32; 1024];
        assert_eq!(StoredVec::quantize(&xs, Precision::F32).mem_bytes(),
                   4096);
        assert_eq!(StoredVec::quantize(&xs, Precision::Bf16).mem_bytes(),
                   2048);
        assert_eq!(
            StoredVec::quantize(&xs, Precision::Fp8E4M3).mem_bytes(),
            1028
        );
        assert_eq!(
            StoredVec::quantize(&xs, Precision::Int8Block(256)).mem_bytes(),
            1024 + 4 * 4
        );
    }
}

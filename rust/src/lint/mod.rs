//! `elsa-lint`: the repo's invariant linter (ISSUE 10).
//!
//! The determinism contract — bit-identical token streams across slots
//! × bands × tiling × quant × N:M × worker counts — is enforced
//! dynamically by the determinism sweep. This module enforces the
//! *static* half: whole classes of regression that a sweep case may or
//! may not trip over are rejected at CI time by four rules over
//! `rust/src`:
//!
//! 1. **safety** — every `unsafe` block/fn/impl is immediately
//!    preceded by a `// SAFETY:` comment with a non-empty argument
//!    (attribute lines may sit between; a single SAFETY block may
//!    cover a contiguous pair of `unsafe impl Send`/`Sync` lines).
//! 2. **nondet** — no nondeterminism sources (`Instant::now`,
//!    `SystemTime`, `env::var`, `thread::sleep`, `RandomState`,
//!    `HashMap`) in the kernel/model modules (`sparse/`, `model/`,
//!    `tensor/`, `pruners/`) outside sites annotated
//!    `// TIMING-OK: <why>` or `// DETERMINISM-OK: <why>`.
//! 3. **alloc** — no allocation calls (`Vec::new`, `vec!`, `.clone(`,
//!    `.collect`, `with_capacity`, `format!`, …) inside the per-step
//!    decode hot path — a fixed table of (file, fn) pairs — outside
//!    `// ALLOC-OK: <why>` sites. Renaming a listed fn without
//!    updating the table is itself an error, so the table cannot go
//!    stale silently. The check is token-level: an allocation hidden
//!    inside a callee (e.g. `TilePlan::shard_ranges`) is out of scope.
//! 4. **wildcard** — no `_ =>` arm in any `match` whose arm patterns
//!    name `WeightFmt`/`QuantMode`/`KernelPath`/`Backend` variants, so
//!    adding a format is a compile-time exhaustiveness sweep instead
//!    of a silent fallthrough. Matches *over other scrutinees* (e.g.
//!    the string matches in `Backend::parse`) may use `_ =>` freely —
//!    only the pattern text left of `=>` is inspected.
//!
//! The lexer is deliberately line-based and std-only (no syn /
//! proc-macro, consistent with the offline vendored-deps policy): a
//! single char-level pass blanks comment and string/char-literal
//! contents (preserving line structure), then the rules scan the
//! blanked code with the original lines kept alongside for annotation
//! lookups. `ci/lint_mirror.py` re-implements the same rules for
//! toolchain-free environments and shares the fixture suite in
//! `rust/tests/lint_fixtures/`; this module is authoritative.

use std::fmt;
use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

/// Annotation tags. Each requires a non-empty reason after the colon.
pub const SAFETY_TAG: &str = "SAFETY:";
pub const TIMING_TAG: &str = "TIMING-OK:";
pub const DETERMINISM_TAG: &str = "DETERMINISM-OK:";
pub const ALLOC_TAG: &str = "ALLOC-OK:";

/// Which rule a violation belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// `unsafe` without an immediately preceding `// SAFETY:` comment.
    Safety,
    /// Nondeterminism source in a kernel/model module.
    Nondet,
    /// Allocation call inside a hot-path fn.
    Alloc,
    /// `_ =>` wildcard over an exhaustiveness-checked enum.
    Wildcard,
    /// The linter's own hot-path table went stale (fn not found).
    Config,
}

impl Rule {
    pub fn label(self) -> &'static str {
        match self {
            Rule::Safety => "safety",
            Rule::Nondet => "nondet",
            Rule::Alloc => "alloc",
            Rule::Wildcard => "wildcard",
            Rule::Config => "config",
        }
    }
}

/// One finding: file, 1-based line, rule, message.
#[derive(Debug, Clone)]
pub struct Violation {
    pub path: String,
    pub line: usize,
    pub rule: Rule,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line,
               self.rule.label(), self.msg)
    }
}

/// Rule configuration. [`Config::repo`] is the committed policy;
/// fixture tests build narrow configs to exercise single rules.
pub struct Config {
    /// Module prefixes (relative to the lint root) where the nondet
    /// rule applies.
    pub watched_dirs: &'static [&'static str],
    /// Substrings treated as nondeterminism sources.
    pub nondet_tokens: &'static [&'static str],
    /// Substrings treated as allocation calls in hot fns.
    pub alloc_tokens: &'static [&'static str],
    /// Enum path prefixes whose matches must stay wildcard-free.
    pub exhaustive_enums: &'static [&'static str],
    /// (file, fn names) pairs forming the decode hot path.
    pub hot_fns: &'static [(&'static str, &'static [&'static str])],
}

impl Config {
    /// The repo policy enforced by CI. Keep in sync with
    /// `ci/lint_mirror.py` and the table in docs/ARCHITECTURE.md §8.
    pub fn repo() -> Config {
        Config {
            watched_dirs: &["sparse/", "model/", "tensor/", "pruners/"],
            nondet_tokens: &["Instant::now", "SystemTime", "env::var",
                             "thread::sleep", "RandomState", "HashMap"],
            alloc_tokens: &["Vec::new", "vec!", ".to_vec(", ".clone(",
                            ".collect", "Box::new", "with_capacity",
                            "String::new", "format!", ".to_string(",
                            ".to_owned("],
            exhaustive_enums: &["WeightFmt::", "QuantMode::",
                                "KernelPath::", "Backend::"],
            hot_fns: &[
                ("sparse/mod.rs",
                 &["matvec", "matvec_batch_into",
                   "matvec_batch_tiled_into", "axpy_lanes",
                   "transpose_batch_into"]),
                ("sparse/tile.rs",
                 &["exec_tiles", "matvec_batch_tiled",
                   "pool_matvec_batch_tiled", "pool_t_matmat",
                   "scatter_rows"]),
                ("sparse/quantized.rs",
                 &["matvec", "matvec_batch_into",
                   "matvec_batch_tiled_into", "exec_tiles"]),
                ("sparse/nm.rs",
                 &["matvec", "row_acc", "matvec_batch_into",
                   "matvec_batch_tiled_into", "exec_tiles"]),
                ("infer/pool.rs", &["run", "drain", "worker_loop"]),
                ("infer/mod.rs",
                 &["decode_step_batch", "layer_qkv", "layer_ffn",
                   "attend_cached", "prefill_pass_multi"]),
            ],
        }
    }
}

// ---------------------------------------------------------------- lexer

/// Replace comment and string/char-literal contents with spaces,
/// preserving length and line structure, so token scans see only code.
fn blank(src: &str) -> String {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        Line,
        /// nesting depth
        Block(u32),
        Str,
        /// hash count of the opening `r#*"`
        RawStr(u32),
        Ch,
    }
    let b = src.as_bytes();
    let n = b.len();
    let mut out = Vec::with_capacity(n);
    let push_blank = |out: &mut Vec<u8>, c: u8| {
        out.push(if c == b'\n' { b'\n' } else { b' ' });
    };
    let mut st = St::Code;
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        let nxt = b.get(i + 1).copied();
        match st {
            St::Code => {
                if c == b'/' && nxt == Some(b'/') {
                    st = St::Line;
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'/' && nxt == Some(b'*') {
                    st = St::Block(1);
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'"' {
                    st = St::Str;
                    out.push(b' ');
                    i += 1;
                } else if c == b'r' || c == b'b' {
                    // raw string openers: r"  r#"  br"  br#"
                    let j = if c == b'b' && nxt == Some(b'r') {
                        i + 1
                    } else {
                        i
                    };
                    let mut k = j + 1;
                    let mut hashes = 0u32;
                    if b[j] == b'r' {
                        while b.get(k) == Some(&b'#') {
                            hashes += 1;
                            k += 1;
                        }
                    }
                    if b[j] == b'r' && b.get(k) == Some(&b'"') {
                        for _ in i..=k {
                            out.push(b' ');
                        }
                        i = k + 1;
                        st = St::RawStr(hashes);
                    } else {
                        out.push(c);
                        i += 1;
                    }
                } else if c == b'\'' {
                    // char literal vs lifetime: `'\…'` or `'x'` is a
                    // literal; `'ident` is a lifetime and stays code
                    let is_char = nxt == Some(b'\\')
                        || b.get(i + 2) == Some(&b'\'');
                    out.push(if is_char { b' ' } else { c });
                    if is_char {
                        st = St::Ch;
                    }
                    i += 1;
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            St::Line => {
                if c == b'\n' {
                    out.push(b'\n');
                    st = St::Code;
                } else {
                    out.push(b' ');
                }
                i += 1;
            }
            St::Block(d) => {
                if c == b'*' && nxt == Some(b'/') {
                    out.extend_from_slice(b"  ");
                    i += 2;
                    st = if d == 1 { St::Code } else { St::Block(d - 1) };
                } else if c == b'/' && nxt == Some(b'*') {
                    out.extend_from_slice(b"  ");
                    i += 2;
                    st = St::Block(d + 1);
                } else {
                    push_blank(&mut out, c);
                    i += 1;
                }
            }
            St::Str => {
                if c == b'\\' && i + 1 < n {
                    push_blank(&mut out, c);
                    push_blank(&mut out, b[i + 1]);
                    i += 2;
                } else if c == b'"' {
                    out.push(b' ');
                    st = St::Code;
                    i += 1;
                } else {
                    push_blank(&mut out, c);
                    i += 1;
                }
            }
            St::RawStr(h) => {
                let mut closed = false;
                if c == b'"' {
                    let mut k = i + 1;
                    let mut m = 0u32;
                    while m < h && b.get(k) == Some(&b'#') {
                        m += 1;
                        k += 1;
                    }
                    if m == h {
                        for _ in i..k {
                            out.push(b' ');
                        }
                        i = k;
                        st = St::Code;
                        closed = true;
                    }
                }
                if !closed {
                    push_blank(&mut out, c);
                    i += 1;
                }
            }
            St::Ch => {
                if c == b'\\' && i + 1 < n {
                    push_blank(&mut out, c);
                    push_blank(&mut out, b[i + 1]);
                    i += 2;
                } else if c == b'\'' {
                    out.push(b' ');
                    st = St::Code;
                    i += 1;
                } else {
                    push_blank(&mut out, c);
                    i += 1;
                }
            }
        }
    }
    // blanking is byte-for-byte, so the output is valid ASCII/UTF-8
    String::from_utf8(out).expect("blanked source is valid utf-8")
}

fn is_ident(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Byte index of `word` in `hay` with non-identifier chars (or edges)
/// on both sides, searching from `start`.
fn find_word(hay: &str, word: &str, start: usize) -> Option<usize> {
    let h = hay.as_bytes();
    let mut i = start;
    while let Some(rel) = hay.get(i..).and_then(|s| s.find(word)) {
        let p = i + rel;
        let before_ok = p == 0 || !is_ident(h[p - 1]);
        let after = p + word.len();
        let after_ok = after >= h.len() || !is_ident(h[after]);
        if before_ok && after_ok {
            return Some(p);
        }
        i = p + 1;
    }
    None
}

/// True when `line` carries one of `tags` followed by a non-empty
/// reason.
fn line_has_tag(line: &str, tags: &[&str]) -> bool {
    tags.iter().any(|tag| match line.find(tag) {
        Some(p) => !line[p + tag.len()..].trim().is_empty(),
        None => false,
    })
}

/// True when line `idx` is annotated with one of `tags` on the same
/// line or in the immediately preceding block of comment/attribute
/// lines. With `skip_unsafe_impl`, `unsafe impl` lines may sit in
/// between so one SAFETY block covers a `Send`/`Sync` pair.
fn annotated(orig: &[&str], code: &[String], idx: usize, tags: &[&str],
             skip_unsafe_impl: bool) -> bool {
    if line_has_tag(orig[idx], tags) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let t = orig[j].trim_start();
        if t.starts_with("//") {
            if line_has_tag(orig[j], tags) {
                return true;
            }
            continue;
        }
        if t.starts_with("#[") || t.starts_with("#!") {
            continue;
        }
        if skip_unsafe_impl
            && find_word(&code[j], "unsafe", 0).is_some()
            && code[j].contains("impl")
        {
            continue;
        }
        break;
    }
    false
}

/// Per-char brace depth for the whole source: chars inside `{…}` sit
/// one level deeper; both braces of a pair report the outer depth.
fn brace_depths(code: &str) -> Vec<i32> {
    let mut depths = Vec::with_capacity(code.len());
    let mut d = 0i32;
    for c in code.bytes() {
        if c == b'}' {
            d -= 1;
        }
        depths.push(d);
        if c == b'{' {
            d += 1;
        }
    }
    depths
}

/// Char offset → 0-based line index.
fn offsets_to_lines(code: &str) -> Vec<usize> {
    let mut line_of = Vec::with_capacity(code.len());
    let mut ln = 0usize;
    for c in code.bytes() {
        line_of.push(ln);
        if c == b'\n' {
            ln += 1;
        }
    }
    line_of
}

/// `(body_start, body_end)` offsets for every `fn name` with a body;
/// bodyless trait declarations are skipped.
fn fn_extents(code: &str, name: &str) -> Vec<(usize, usize)> {
    let bytes = code.as_bytes();
    let depths = brace_depths(code);
    let mut extents = Vec::new();
    let mut i = 0usize;
    while let Some(p) = find_word(code, "fn", i) {
        i = p + 2;
        let rest = code[p + 2..].trim_start();
        let matches_name = rest.starts_with(name)
            && rest.as_bytes().get(name.len())
                .map_or(true, |&c| !is_ident(c));
        if !matches_name {
            continue;
        }
        // scan to the body `{` (or `;` for a bodyless declaration)
        let mut paren = 0i32;
        let mut j = p;
        let mut body = None;
        while j < bytes.len() {
            match bytes[j] {
                b'(' => paren += 1,
                b')' => paren -= 1,
                b';' if paren == 0 => break,
                b'{' if paren == 0 => {
                    body = Some(j);
                    break;
                }
                _ => {}
            }
            j += 1;
        }
        let Some(start) = body else { continue };
        let d = depths[start];
        let mut k = start + 1;
        while k < bytes.len() && !(bytes[k] == b'}' && depths[k] == d) {
            k += 1;
        }
        extents.push((start, k));
        i = k;
    }
    extents
}

// ---------------------------------------------------------------- rules

fn rule_safety(path: &str, orig: &[&str], code: &[String],
               out: &mut Vec<Violation>) {
    for (i, cl) in code.iter().enumerate() {
        if find_word(cl, "unsafe", 0).is_none() {
            continue;
        }
        let is_impl = cl.contains("impl");
        if !annotated(orig, code, i, &[SAFETY_TAG], is_impl) {
            out.push(Violation {
                path: path.to_string(),
                line: i + 1,
                rule: Rule::Safety,
                msg: "`unsafe` without an immediately preceding \
                      `// SAFETY:` comment"
                    .to_string(),
            });
        }
    }
}

fn rule_nondet(cfg: &Config, path: &str, orig: &[&str], code: &[String],
               out: &mut Vec<Violation>) {
    if !cfg.watched_dirs.iter().any(|d| path.starts_with(d)) {
        return;
    }
    for (i, cl) in code.iter().enumerate() {
        for tok in cfg.nondet_tokens {
            if !cl.contains(tok) {
                continue;
            }
            if !annotated(orig, code, i, &[TIMING_TAG, DETERMINISM_TAG],
                          false) {
                out.push(Violation {
                    path: path.to_string(),
                    line: i + 1,
                    rule: Rule::Nondet,
                    msg: format!(
                        "nondeterminism source `{tok}` in a \
                         kernel/model module without a \
                         TIMING-OK/DETERMINISM-OK annotation"),
                });
            }
        }
    }
}

fn rule_alloc(cfg: &Config, path: &str, orig: &[&str], code_lines: &[String],
              code: &str, out: &mut Vec<Violation>) {
    let Some((_, fns)) =
        cfg.hot_fns.iter().find(|(file, _)| *file == path)
    else {
        return;
    };
    let line_of = offsets_to_lines(code);
    for name in *fns {
        let extents = fn_extents(code, name);
        if extents.is_empty() {
            out.push(Violation {
                path: path.to_string(),
                line: 1,
                rule: Rule::Config,
                msg: format!(
                    "hot-path fn `{name}` not found in {path} — \
                     update the hot-path table in the linter"),
            });
            continue;
        }
        for (start, end) in extents {
            let first = line_of[start];
            let last = line_of[end.min(code.len() - 1)];
            for li in first..=last {
                for tok in cfg.alloc_tokens {
                    if !code_lines[li].contains(tok) {
                        continue;
                    }
                    if !annotated(orig, code_lines, li, &[ALLOC_TAG],
                                  false) {
                        out.push(Violation {
                            path: path.to_string(),
                            line: li + 1,
                            rule: Rule::Alloc,
                            msg: format!(
                                "allocation `{tok}` inside hot-path \
                                 fn `{name}` without an ALLOC-OK \
                                 annotation"),
                        });
                    }
                }
            }
        }
    }
}

fn rule_wildcard(cfg: &Config, path: &str, code: &str,
                 out: &mut Vec<Violation>) {
    let bytes = code.as_bytes();
    let depths = brace_depths(code);
    let line_of = offsets_to_lines(code);
    let mut i = 0usize;
    while let Some(p) = find_word(code, "match", i) {
        i = p + 5;
        if code[..p].trim_end().ends_with('.') {
            continue; // method call, not the keyword
        }
        // body `{` at paren/bracket depth 0 relative to the scrutinee
        let mut paren = 0i32;
        let mut j = p + 5;
        let mut body = None;
        while j < bytes.len() {
            match bytes[j] {
                b'(' | b'[' => paren += 1,
                b')' | b']' => paren -= 1,
                b'{' if paren == 0 => {
                    body = Some(j);
                    break;
                }
                b';' if paren == 0 => break,
                _ => {}
            }
            j += 1;
        }
        let Some(open) = body else { continue };
        let d = depths[open];
        let mut close = open + 1;
        while close < bytes.len()
            && !(bytes[close] == b'}' && depths[close] == d)
        {
            close += 1;
        }
        // arm separators: `=>` directly inside the match braces
        let mut seps = Vec::new();
        let mut m = open + 1;
        while m + 1 < close {
            if bytes[m] == b'=' && bytes[m + 1] == b'>'
                && depths[m] == d + 1
            {
                seps.push(m);
            }
            m += 1;
        }
        // pattern of each arm: text back to the previous arm-separating
        // comma (skipping commas nested in ()/[]) or the match `{`
        let mut arms = Vec::new();
        for &s in &seps {
            let mut b = s - 1;
            let mut nest = 0i32;
            while b > open {
                match bytes[b] {
                    b')' | b']' => nest += 1,
                    b'(' | b'[' => nest -= 1,
                    b',' if nest == 0 && depths[b] == d + 1 => break,
                    b'{' | b'}' if depths[b] <= d => break,
                    _ => {}
                }
                b -= 1;
            }
            let pat = code[b + 1..s].trim()
                .trim_start_matches('|').trim();
            // strip any guard: only the pattern itself is inspected
            let core = pat.split(" if ").next().unwrap_or(pat).trim();
            arms.push((core.to_string(), line_of[s]));
        }
        let over_watched_enum = arms.iter().any(|(core, _)| {
            cfg.exhaustive_enums.iter().any(|e| core.contains(e))
        });
        if !over_watched_enum {
            continue;
        }
        for (core, ln) in &arms {
            if core == "_" {
                out.push(Violation {
                    path: path.to_string(),
                    line: ln + 1,
                    rule: Rule::Wildcard,
                    msg: "`_ =>` wildcard arm in a match over \
                          WeightFmt/QuantMode/KernelPath/Backend — \
                          spell the variants so new formats fail \
                          exhaustiveness"
                        .to_string(),
                });
            }
        }
    }
}

// ---------------------------------------------------------------- driver

/// Lint one file. `path` is the file's path relative to the lint root
/// (`sparse/mod.rs` style) — it selects the watched-module and
/// hot-path tables.
pub fn lint_source(cfg: &Config, path: &str, src: &str) -> Vec<Violation> {
    let code = blank(src);
    let orig: Vec<&str> = src.split('\n').collect();
    let code_lines: Vec<String> =
        code.split('\n').map(|s| s.to_string()).collect();
    let mut out = Vec::new();
    rule_safety(path, &orig, &code_lines, &mut out);
    rule_nondet(cfg, path, &orig, &code_lines, &mut out);
    rule_alloc(cfg, path, &orig, &code_lines, &code, &mut out);
    rule_wildcard(cfg, path, &code, &mut out);
    out
}

/// Recursively lint every `.rs` file under `root`, in sorted path
/// order so output (and CI logs) are deterministic.
pub fn lint_tree(cfg: &Config, root: &Path) -> Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for full in &files {
        let rel = full.strip_prefix(root).unwrap_or(full);
        let rel = rel.to_string_lossy().replace('\\', "/");
        let src = fs::read_to_string(full)
            .with_context(|| format!("reading {}", full.display()))?;
        out.extend(lint_source(cfg, &rel, &src));
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<()> {
    for entry in fs::read_dir(dir)
        .with_context(|| format!("walking {}", dir.display()))?
    {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(v: &[Violation]) -> Vec<Rule> {
        v.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn blank_strips_comments_and_strings() {
        let src = "let a = \"unsafe\"; // unsafe\nlet b = 'x';\n";
        let out = blank(src);
        assert!(!out.contains("unsafe"));
        assert!(out.contains("let a ="));
        assert_eq!(out.len(), src.len());
        assert_eq!(out.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn blank_handles_raw_strings_and_escapes() {
        let src = "let s = r#\"match _ => unsafe\"#;\nlet c = '\\n';\n";
        let out = blank(src);
        assert!(!out.contains("unsafe"));
        assert!(!out.contains("match"));
        assert_eq!(out.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn blank_keeps_lifetimes_as_code() {
        let out = blank("fn f<'a>(x: &'a u32) -> &'a u32 { x }\n");
        assert!(out.contains("<'a>"));
    }

    #[test]
    fn undocumented_unsafe_is_flagged_and_safety_comment_passes() {
        let cfg = Config::repo();
        let bad = "fn f(x: &[f32]) -> f32 {\n    \
                   unsafe { *x.get_unchecked(0) }\n}\n";
        assert_eq!(rules(&lint_source(&cfg, "infer/f.rs", bad)),
                   vec![Rule::Safety]);
        let good = "fn f(x: &[f32]) -> f32 {\n    \
                    // SAFETY: caller guarantees x is non-empty\n    \
                    unsafe { *x.get_unchecked(0) }\n}\n";
        assert!(lint_source(&cfg, "infer/f.rs", good).is_empty());
    }

    #[test]
    fn safety_tag_requires_a_reason() {
        let cfg = Config::repo();
        let empty = "// SAFETY:\nunsafe impl Send for X {}\n";
        assert_eq!(rules(&lint_source(&cfg, "infer/f.rs", empty)),
                   vec![Rule::Safety]);
    }

    #[test]
    fn one_safety_block_covers_an_unsafe_impl_pair() {
        let cfg = Config::repo();
        let src = "// SAFETY: disjoint bands, barrier outlives borrow\n\
                   unsafe impl Send for P {}\n\
                   unsafe impl Sync for P {}\n";
        assert!(lint_source(&cfg, "infer/f.rs", src).is_empty());
    }

    #[test]
    fn nondet_fires_only_in_watched_modules() {
        let cfg = Config::repo();
        let src = "fn t() -> std::time::Instant \
                   { std::time::Instant::now() }\n";
        assert_eq!(rules(&lint_source(&cfg, "sparse/x.rs", src)),
                   vec![Rule::Nondet]);
        assert!(lint_source(&cfg, "util/x.rs", src).is_empty());
        let ok = "fn t() {\n    // TIMING-OK: bench-only wall clock\n    \
                  let _ = std::time::Instant::now();\n}\n";
        assert!(lint_source(&cfg, "sparse/x.rs", ok).is_empty());
    }

    #[test]
    fn alloc_rule_scans_only_listed_fns_and_honors_annotation() {
        let cfg = Config {
            watched_dirs: &[],
            nondet_tokens: &[],
            alloc_tokens: &["Vec::new"],
            exhaustive_enums: &[],
            hot_fns: &[("sparse/k.rs", &["hot"])],
        };
        let bad = "fn hot() { let v: Vec<f32> = Vec::new(); }\n\
                   fn cold() { let v: Vec<f32> = Vec::new(); }\n";
        let v = lint_source(&cfg, "sparse/k.rs", bad);
        assert_eq!(rules(&v), vec![Rule::Alloc]);
        assert_eq!(v[0].line, 1);
        let ok = "fn hot() {\n    \
                  // ALLOC-OK: one-time warmup, reused thereafter\n    \
                  let v: Vec<f32> = Vec::new();\n    drop(v);\n}\n";
        assert!(lint_source(&cfg, "sparse/k.rs", ok).is_empty());
    }

    #[test]
    fn missing_hot_fn_is_a_config_violation() {
        let cfg = Config {
            watched_dirs: &[],
            nondet_tokens: &[],
            alloc_tokens: &[],
            exhaustive_enums: &[],
            hot_fns: &[("sparse/k.rs", &["renamed_away"])],
        };
        let v = lint_source(&cfg, "sparse/k.rs", "fn other() {}\n");
        assert_eq!(rules(&v), vec![Rule::Config]);
    }

    #[test]
    fn wildcard_over_watched_enum_is_flagged() {
        let cfg = Config::repo();
        let bad = "fn f(p: KernelPath) -> u32 {\n    match p {\n        \
                   KernelPath::Scalar => 0,\n        _ => 1,\n    }\n}\n";
        assert_eq!(rules(&lint_source(&cfg, "infer/f.rs", bad)),
                   vec![Rule::Wildcard]);
    }

    #[test]
    fn wildcard_over_other_scrutinees_is_fine() {
        let cfg = Config::repo();
        // enum paths in arm BODIES (Backend::parse shape) don't arm
        // the rule; `_` over a string scrutinee stays legal
        let src = "fn parse(s: &str) -> Option<Backend> {\n    \
                   match s {\n        \
                   \"csr\" => Some(Backend::Csr),\n        \
                   _ => None,\n    }\n}\n";
        assert!(lint_source(&cfg, "infer/f.rs", src).is_empty());
    }

    #[test]
    fn exhaustive_match_over_watched_enum_is_fine() {
        let cfg = Config::repo();
        let src = "fn f(p: KernelPath) -> u32 {\n    match p {\n        \
                   KernelPath::Scalar => 0,\n        \
                   KernelPath::Unrolled => 1,\n    }\n}\n";
        assert!(lint_source(&cfg, "infer/f.rs", src).is_empty());
    }

    #[test]
    fn repo_tree_is_clean() {
        // the committed tree must satisfy its own invariants — this is
        // the in-process twin of the blocking `elsa-lint` CI step
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("rust").join("src");
        let v = lint_tree(&Config::repo(), &root).unwrap();
        assert!(v.is_empty(), "lint violations:\n{}",
                v.iter().map(|x| x.to_string())
                    .collect::<Vec<_>>().join("\n"));
    }
}

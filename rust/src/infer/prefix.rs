//! Shared-prefix KV cache for the continuous-batching scheduler.
//!
//! Real serving traffic is dominated by requests that share system
//! prompts and few-shot templates. The KV rows for prompt positions
//! `0..P` depend only on tokens `0..P` — nothing downstream — so once
//! one request has prefilled a prefix, every later request whose
//! prompt *starts with* those tokens can reuse the rows verbatim
//! instead of recomputing them. This module is the store for those
//! rows: refcounted immutable [`PrefixSegment`]s behind a hash index,
//! with LRU eviction under a byte budget.
//!
//! ## Lifecycle (copy-on-attach)
//!
//! Segments are immutable and shared via [`Arc`]; a slot never decodes
//! *into* a segment. At admission the scheduler probes
//! [`PrefixCache::lookup`]; on a hit it copies the matched rows into
//! the slot's own pooled KV buffers and starts prefill at the suffix.
//! When a slot finishes its headless prefill, the scheduler hands the
//! prompt's prefix rows to [`PrefixCache::insert`], which copies them
//! out of the (mutable, pooled) slot buffers into a fresh immutable
//! segment. Copy-on-attach keeps the attention loop reading one
//! contiguous per-slot buffer — the decode path does not know the
//! cache exists, which is also why a cache hit is bit-identical to a
//! cold start by construction: the attached rows are the same floats a
//! cold prefill would have appended, in the same layout. (KV rows are
//! always f32 — weight quantization via `--quant` changes what the
//! prefill computes, not how it is cached, so quantized engines get
//! prefix reuse unchanged and hits stay bit-identical within a mode.)
//!
//! ## Index
//!
//! Each segment is keyed by an FNV-1a rolling hash of its token
//! prefix at every multiple of [`PREFIX_BLOCK`] *and* at its full
//! length, so divergent-suffix families can share the common head
//! without the insertion lengths having to line up. `lookup` walks
//! candidate prefix lengths longest-first (the rolling hash makes all
//! prompt-prefix hashes one O(len) pass) and verifies tokens on every
//! hash hit, so a collision can never attach wrong rows.

use std::collections::HashMap;
use std::sync::Arc;

use super::Kv;

/// Index granularity: segments are additionally keyed at every
/// multiple of this many tokens, so a request can attach to the
/// common head of a cached prompt even when the cached prompt's full
/// length never matches its own.
pub const PREFIX_BLOCK: usize = 8;

/// Default byte budget for a scheduler's prefix cache.
pub const DEFAULT_PREFIX_CACHE_BYTES: usize = 64 << 20;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Rolling FNV-1a over little-endian token bytes: `out[p]` hashes
/// `tokens[..p]`, all `len + 1` prefixes in one pass.
fn prefix_hashes(tokens: &[u32]) -> Vec<u64> {
    let mut out = Vec::with_capacity(tokens.len() + 1);
    let mut h = FNV_OFFSET;
    out.push(h);
    for &t in tokens {
        for b in t.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
        out.push(h);
    }
    out
}

/// One immutable cached prefix: the K/V rows every layer produced for
/// `tokens`, reusable by any prompt that starts with them.
pub struct PrefixSegment {
    tokens: Vec<u32>,
    /// Per-layer K rows, row-major `(len, d_model)`.
    k: Vec<Vec<f32>>,
    /// Per-layer V rows, same layout as `k`.
    v: Vec<Vec<f32>>,
}

impl PrefixSegment {
    /// Cached prefix length in tokens.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// True when the segment caches no positions (never stored).
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Copy the first `n` cached positions into `kvs` (the slot's
    /// pooled buffers), leaving each layer's cache holding exactly
    /// those `n` rows. `n <= self.len()`.
    pub(crate) fn attach(&self, kvs: &mut [Kv], n: usize, d: usize) {
        debug_assert!(n <= self.tokens.len());
        debug_assert_eq!(kvs.len(), self.k.len());
        for (li, kv) in kvs.iter_mut().enumerate() {
            kv.k.clear();
            kv.v.clear();
            kv.k.extend_from_slice(&self.k[li][..n * d]);
            kv.v.extend_from_slice(&self.v[li][..n * d]);
            kv.len = n;
        }
    }

    fn bytes(&self) -> usize {
        let rows: usize = self.k.iter().map(Vec::len).sum::<usize>()
            + self.v.iter().map(Vec::len).sum::<usize>();
        self.tokens.len() * 4 + rows * 4
    }
}

/// Refcounted store of [`PrefixSegment`]s with hash lookup and LRU
/// eviction. The scheduler owns one behind a `Mutex`, shared by all
/// its workers; lock order is always queue-then-cache (admission) or
/// cache alone (insertion), so the two mutexes cannot deadlock.
pub struct PrefixCache {
    /// `(prefix hash, prefix len)` → candidate segments whose first
    /// `len` tokens hash there. Tokens are verified on every probe.
    index: HashMap<(u64, usize), Vec<Arc<PrefixSegment>>>,
    /// Every stored segment with its last-touched LRU stamp.
    segments: Vec<(Arc<PrefixSegment>, u64)>,
    max_bytes: usize,
    bytes: usize,
    stamp: u64,
    /// Segments stored (dedup-skipped re-inserts do not count).
    pub insertions: usize,
    /// Segments dropped by the LRU byte budget.
    pub evictions: usize,
}

impl PrefixCache {
    pub fn new(max_bytes: usize) -> PrefixCache {
        PrefixCache {
            index: HashMap::new(),
            segments: Vec::new(),
            max_bytes,
            bytes: 0,
            stamp: 0,
            insertions: 0,
            evictions: 0,
        }
    }

    /// Bytes currently held by stored segments.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Stored segment count.
    pub fn len(&self) -> usize {
        self.segments.len()
    }

    /// True when no segments are stored.
    pub fn is_empty(&self) -> bool {
        self.segments.is_empty()
    }

    /// Longest cached prefix of `prompt`, as `(segment, attach_len)`:
    /// the caller may copy the segment's first `attach_len` rows and
    /// start prefill there. `attach_len` is capped at
    /// `prompt.len() - 1` — the final prompt position must ride the
    /// head-projecting decode step to produce logits, so it is never
    /// attached even when the whole prompt is cached.
    pub fn lookup(&mut self, prompt: &[u32])
                  -> Option<(Arc<PrefixSegment>, usize)> {
        if prompt.len() < 2 {
            return None; // nothing attachable below 2 tokens
        }
        let hashes = prefix_hashes(prompt);
        for p in (1..=prompt.len()).rev() {
            let Some(cands) = self.index.get(&(hashes[p], p)) else {
                continue;
            };
            let hit = cands.iter().find(|s| {
                s.tokens.len() >= p && s.tokens[..p] == prompt[..p]
            });
            if let Some(seg) = hit {
                let seg = Arc::clone(seg);
                self.touch(&seg);
                let attach = p.min(prompt.len() - 1);
                return Some((seg, attach));
            }
        }
        None
    }

    /// Store the rows for `tokens` (a prompt's headless prefix) out of
    /// a slot's KV buffers: each layer's first `tokens.len()` cached
    /// rows are copied into a fresh immutable segment. No-op when the
    /// exact prefix is already cached (dedupe) or when the segment
    /// alone would exceed the byte budget; otherwise evicts
    /// least-recently-used segments until the budget holds.
    pub(crate) fn insert(&mut self, tokens: &[u32], kvs: &[Kv],
                         d: usize) {
        let len = tokens.len();
        if len == 0 {
            return;
        }
        let hashes = prefix_hashes(tokens);
        if self.covered(&hashes, tokens, len) {
            return;
        }
        let seg = Arc::new(PrefixSegment {
            tokens: tokens.to_vec(),
            k: kvs.iter().map(|kv| kv.k[..len * d].to_vec()).collect(),
            v: kvs.iter().map(|kv| kv.v[..len * d].to_vec()).collect(),
        });
        if seg.bytes() > self.max_bytes {
            return;
        }
        let mut boundaries: Vec<usize> = (1..)
            .map(|i| i * PREFIX_BLOCK)
            .take_while(|&b| b < len)
            .collect();
        boundaries.push(len);
        for b in boundaries {
            // skip boundaries another segment already answers for
            // these exact tokens — one candidate per distinct prefix
            if !self.covered(&hashes, tokens, b) {
                self.index
                    .entry((hashes[b], b))
                    .or_default()
                    .push(Arc::clone(&seg));
            }
        }
        self.bytes += seg.bytes();
        self.stamp += 1;
        self.segments.push((seg, self.stamp));
        self.insertions += 1;
        while self.bytes > self.max_bytes && self.segments.len() > 1 {
            self.evict_lru();
        }
    }

    /// True when some stored segment already matches `tokens[..b]`.
    fn covered(&self, hashes: &[u64], tokens: &[u32], b: usize) -> bool {
        self.index.get(&(hashes[b], b)).is_some_and(|cands| {
            cands.iter().any(|s| {
                s.tokens.len() >= b && s.tokens[..b] == tokens[..b]
            })
        })
    }

    fn touch(&mut self, seg: &Arc<PrefixSegment>) {
        self.stamp += 1;
        for (s, at) in self.segments.iter_mut() {
            if Arc::ptr_eq(s, seg) {
                *at = self.stamp;
                break;
            }
        }
    }

    fn evict_lru(&mut self) {
        let Some(oldest) = self
            .segments
            .iter()
            .enumerate()
            .min_by_key(|(_, (_, at))| *at)
            .map(|(i, _)| i)
        else {
            return;
        };
        let (seg, _) = self.segments.swap_remove(oldest);
        self.bytes -= seg.bytes();
        for cands in self.index.values_mut() {
            cands.retain(|s| !Arc::ptr_eq(s, &seg));
        }
        self.index.retain(|_, cands| !cands.is_empty());
        self.evictions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fake per-layer KV holding `len` rows of `d` floats whose
    /// values encode (layer, row) so copies are checkable.
    fn fake_kvs(layers: usize, len: usize, d: usize) -> Vec<Kv> {
        (0..layers)
            .map(|li| {
                let row = |t: usize| {
                    (0..d).map(move |c| (li * 1000 + t * 10 + c) as f32)
                };
                Kv {
                    k: (0..len).flat_map(row).collect(),
                    v: (0..len).flat_map(|t| row(t).map(|x| -x)).collect(),
                    len,
                }
            })
            .collect()
    }

    fn empty_kvs(layers: usize) -> Vec<Kv> {
        (0..layers)
            .map(|_| Kv { k: Vec::new(), v: Vec::new(), len: 0 })
            .collect()
    }

    #[test]
    fn extension_attaches_the_cached_prefix() {
        let d = 4;
        let mut cache = PrefixCache::new(1 << 20);
        let prefix: Vec<u32> = (0..10).collect();
        cache.insert(&prefix, &fake_kvs(2, 10, d), d);
        assert_eq!(cache.len(), 1);

        // a prompt extending the cached prefix attaches all 10 rows
        let mut prompt = prefix.clone();
        prompt.extend([40, 41, 42]);
        let (seg, attach) = cache.lookup(&prompt).expect("hit");
        assert_eq!(attach, 10);
        let mut kvs = empty_kvs(2);
        seg.attach(&mut kvs, attach, d);
        let want = fake_kvs(2, 10, d);
        for (got, exp) in kvs.iter().zip(want.iter()) {
            assert_eq!(got.k, exp.k);
            assert_eq!(got.v, exp.v);
            assert_eq!(got.len, 10);
        }
    }

    #[test]
    fn full_prompt_match_attaches_all_but_the_last_position() {
        let d = 2;
        let mut cache = PrefixCache::new(1 << 20);
        let prefix: Vec<u32> = (0..6).collect();
        cache.insert(&prefix, &fake_kvs(1, 6, d), d);
        // the whole prompt IS the cached prefix: the last position
        // still needs its head-projecting step, so attach stops at 5
        let (_, attach) = cache.lookup(&prefix).expect("hit");
        assert_eq!(attach, 5);
    }

    #[test]
    fn divergent_suffixes_share_the_block_aligned_head() {
        let d = 2;
        let mut cache = PrefixCache::new(1 << 20);
        // family head: PREFIX_BLOCK tokens, then suffix "a"
        let mut a: Vec<u32> = (100..100 + PREFIX_BLOCK as u32).collect();
        a.extend([1, 2, 3]);
        cache.insert(&a, &fake_kvs(1, a.len(), d), d);
        // sibling with a different suffix still attaches the head
        let mut b: Vec<u32> = (100..100 + PREFIX_BLOCK as u32).collect();
        b.extend([7, 8]);
        let (_, attach) = cache.lookup(&b).expect("family hit");
        assert_eq!(attach, PREFIX_BLOCK);
        // an unrelated prompt misses
        assert!(cache.lookup(&[9u32, 9, 9, 9]).is_none());
    }

    #[test]
    fn reinserting_a_covered_prefix_is_deduped() {
        let d = 2;
        let mut cache = PrefixCache::new(1 << 20);
        let prefix: Vec<u32> = (0..5).collect();
        cache.insert(&prefix, &fake_kvs(1, 5, d), d);
        let bytes = cache.bytes();
        cache.insert(&prefix, &fake_kvs(1, 5, d), d);
        assert_eq!(cache.insertions, 1, "exact re-insert must dedupe");
        assert_eq!(cache.bytes(), bytes);
    }

    #[test]
    fn lru_eviction_keeps_recently_touched_segments() {
        let d = 2;
        // budget fits roughly two 6-token single-layer segments
        let per_seg = 6 * 4 + 2 * 6 * d * 4;
        let mut cache = PrefixCache::new(2 * per_seg);
        let seg = |base: u32| -> Vec<u32> {
            (base..base + 6).collect()
        };
        cache.insert(&seg(0), &fake_kvs(1, 6, d), d);
        cache.insert(&seg(100), &fake_kvs(1, 6, d), d);
        // touch the first so the second is the LRU victim
        let mut probe = seg(0);
        probe.push(99);
        assert!(cache.lookup(&probe).is_some());
        cache.insert(&seg(200), &fake_kvs(1, 6, d), d);
        assert_eq!(cache.evictions, 1);
        assert!(cache.lookup(&probe).is_some(), "touched segment kept");
        let mut evicted = seg(100);
        evicted.push(99);
        assert!(cache.lookup(&evicted).is_none(), "LRU segment evicted");
        assert!(cache.bytes() <= 2 * per_seg);
    }

    #[test]
    fn one_token_prompts_never_probe() {
        let mut cache = PrefixCache::new(1 << 20);
        cache.insert(&[5], &fake_kvs(1, 1, 2), 2);
        // nothing attachable: attach would be min(1, 1-1) = 0
        assert!(cache.lookup(&[5]).is_none());
    }
}

//! Sparse inference engine: KV-cached autoregressive generation over
//! dense / CSR / MACKO weight backends (the Table-1 deployment benchmark).
//!
//! The decode phase is one matvec per linear per token — exactly the
//! memory-bound SpMV regime the paper's §5.3 targets. The engine shares
//! numerics with model::forward (tested), so a pruned checkpoint can be
//! loaded, converted, and served without touching the HLO path.
//!
//! There is exactly ONE forward implementation: the chunked prefill
//! pass (`Engine::prefill_pass`) plus the batched decode step
//! (`Engine::decode_step_batch`, both private). Every serving mode
//! drives it:
//!  - [`Engine::generate`] / [`Engine::generate_pooled`]: one
//!    sequence, driven as a batch of 1 — so single-sequence decode
//!    inherits the tiled kernels, the batched head projection, and
//!    (via `generate_pooled`) the persistent row-band pool,
//!  - [`Engine::generate_batch`]: many sequences with per-slot KV
//!    caches and slot retirement; each step runs the linears as one
//!    multi-vector SpMM over the live slots (amortizing index/bitmap
//!    decode across the batch, and — with [`Engine::tiled`], the
//!    default — walking each cache-sized weight tile once per step),
//!    finishes with a single batched head projection regardless of
//!    slot count, and shards slots across worker threads
//!    (`--threads N`). Each worker can additionally fan every layer's
//!    linears out across the row-band lanes of a persistent
//!    [`pool::WorkerPool`] (`--shard-workers M` — slot × band
//!    parallelism). Batched results are bit-identical to the
//!    single-sequence path per slot, for any thread count, any
//!    shard-worker count, and either kernel traversal.
//!  - [`scheduler`]: the continuous-batching layer (`elsa serve`) — a
//!    request queue with mid-decode slot admission and pooled KV
//!    caches. `generate_batch` is a thin fixed-admission wrapper over
//!    it.
//!
//! ## Chunked prefill
//!
//! Prompt positions are fed through the layers in windows of
//! [`Engine::prefill_chunk`] positions (time-as-batch through the same
//! batched kernels the decode step uses), with per-position causal
//! attention over the growing cache — and the head projection (the
//! single largest dense GEMV in the model, d_model × vocab) is skipped
//! for every prompt position except the last: prefill costs exactly
//! ONE head projection per request regardless of prompt length, where
//! it used to cost one per prompt token. Chunking is a pure traversal
//! change: each window row is bit-exact with the per-token path, and
//! attending position `t` over the first `t + 1` cache entries replays
//! the per-token accumulation order exactly, so token streams are
//! bit-identical for every `prefill_chunk` value
//! (`rust/tests/determinism.rs` sweeps the axis).
//!
//! ## Quantized decode (`--quant {none,int8,int4}`)
//!
//! The sparse backends can serve int8/int4 payloads
//! ([`crate::sparse::quantized`]): [`Engine::build_quant`] converts
//! every prunable linear to [`CsrQ`] / [`MackoQ`], and dequantization
//! is fused into the same tiled/pooled kernel set, so quantized decode
//! inherits tiling, the batched head, the worker pool, chunked
//! prefill, and the prefix cache unchanged. Parity with f32 is
//! tolerance-based (`rust/tests/quant_parity.rs`), but *within* a
//! quant mode every determinism guarantee above still holds bit-exact
//! — threads, shard-workers, tiling, batching, and the prefix cache
//! remain pure traversal knobs.
//!
//! ## N:M structured decode (`--nm {off,2:4,4:8}`)
//!
//! Semi-structured checkpoints get a dedicated format
//! ([`crate::sparse::nm`]): [`Engine::build_nm`] converts every
//! prunable linear to [`NmWeights`] after verifying the pattern
//! (violations fail loudly at build), and the fixed per-group nonzero
//! count makes the decode inner loops branch-free. N:M implements the
//! same `RowTiled` contract as every other format, so it inherits
//! tiling, the worker pool, chunked prefill, and the prefix cache
//! unchanged, and it is bit-exact *within* itself across every
//! traversal knob — including [`Engine::kernel_path`], the runtime
//! scalar/unrolled toggle that applies to all formats.

pub mod pool;
pub mod prefix;
pub mod scheduler;

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;

use crate::cli::Args;
use crate::model::forward::gelu_tanh;
use crate::model::Params;
use crate::runtime::ConfigEntry;
use crate::sparse::{tile, Csr, CsrQ, KernelPath, Macko, MackoQ, NmMode,
                    NmWeights, QuantMode, SpmmScratch, TilePlan};
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

use pool::WorkerPool;

/// Weight storage backend for one linear layer. Every variant carries
/// a row-tiled execution plan built once at conversion time (the
/// sparse formats embed theirs; dense pairs the matrix with a
/// column-tile plan).
pub enum WeightFmt {
    Dense(Matrix, TilePlan),
    Csr(Csr),
    Macko(Macko),
    CsrQ(CsrQ),
    MackoQ(MackoQ),
    Nm(NmWeights),
}

impl WeightFmt {
    /// Convert one weight to f32 `kind` storage. For quantized
    /// payloads use [`WeightFmt::build_quant`].
    ///
    /// ```
    /// use elsa::infer::{Backend, WeightFmt};
    /// use elsa::sparse::random_sparse_weight;
    ///
    /// let w = random_sparse_weight(64, 48, 0.9, 0);
    /// let fmt = WeightFmt::build(w.clone(), Backend::Macko);
    /// let mut y = vec![0.0f32; 48];
    /// fmt.matvec(&vec![1.0f32; 64], &mut y); // y = W^T x
    /// assert!(fmt.mem_bytes() < w.data.len() * 4);
    /// ```
    pub fn build(w: Matrix, kind: Backend) -> WeightFmt {
        match kind {
            Backend::Dense => {
                let plan = tile::dense_plan(&w);
                WeightFmt::Dense(w, plan)
            }
            Backend::Csr => WeightFmt::Csr(Csr::from_weight(&w)),
            Backend::Macko => WeightFmt::Macko(Macko::from_weight(&w)),
        }
    }

    /// [`WeightFmt::build`] with a quantized payload: `quant == None`
    /// is exactly `build`, otherwise the sparse formats store int8 or
    /// int4 codes with per-row-block scales ([`CsrQ`] / [`MackoQ`]).
    /// Dense weights have no quantized variant — serving them
    /// quantized would change the f32 baseline the parity suites
    /// compare against, so that combination fails loudly here.
    pub fn build_quant(w: Matrix, kind: Backend, quant: QuantMode)
                       -> Result<WeightFmt> {
        Self::build_full(w, kind, quant, NmMode::Off)
    }

    /// The full conversion entry: f32 (`build`), quantized
    /// (`build_quant`), or N:M structured. `nm != Off` verifies the
    /// weight against the pattern ([`NmWeights::from_weight`]) and
    /// rejects violations loudly; it requires a sparse backend and is
    /// mutually exclusive with quantization (the N:M payload is f32 —
    /// combining them would need a quantized N:M format that does not
    /// exist yet, and guessing a silent fallback would misreport what
    /// is being served).
    pub fn build_full(w: Matrix, kind: Backend, quant: QuantMode,
                      nm: NmMode) -> Result<WeightFmt> {
        if nm.is_on() {
            if kind == Backend::Dense {
                anyhow::bail!("--nm requires a sparse backend \
                               (csr or macko), got dense");
            }
            if quant != QuantMode::None {
                anyhow::bail!("--nm and --quant are mutually exclusive \
                               (no quantized N:M payload)");
            }
            return Ok(WeightFmt::Nm(NmWeights::from_weight(&w, nm)?));
        }
        Ok(match (kind, quant) {
            (_, QuantMode::None) => WeightFmt::build(w, kind),
            (Backend::Dense, _) => anyhow::bail!(
                "--quant requires a sparse backend (csr or macko), \
                 got dense"),
            (Backend::Csr, q) => {
                WeightFmt::CsrQ(CsrQ::from_weight(&w, q)?)
            }
            (Backend::Macko, q) => {
                WeightFmt::MackoQ(MackoQ::from_weight(&w, q)?)
            }
        })
    }

    /// y = W^T x (x: din, y: dout).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        match self {
            WeightFmt::Dense(w, _) => {
                let t = w.t_matvec(x);
                y.copy_from_slice(&t);
            }
            WeightFmt::Csr(c) => c.matvec(x, y),
            WeightFmt::Macko(m) => m.matvec(x, y),
            WeightFmt::CsrQ(c) => c.matvec(x, y),
            WeightFmt::MackoQ(m) => m.matvec(x, y),
            WeightFmt::Nm(n) => n.matvec(x, y, KernelPath::Scalar),
        }
    }

    /// Y = X W for a row-major batch X (b, din), writing Y (b, dout).
    /// The sparse formats decode their indices/bitmaps once per output
    /// row and amortize across the batch; every row is bit-exact with
    /// [`WeightFmt::matvec`] on that row alone. `scratch` is reused
    /// across calls so the decode loop stays allocation-free.
    pub fn matvec_batch(&self, x: &[f32], y: &mut [f32], b: usize,
                        scratch: &mut SpmmScratch) {
        match self {
            WeightFmt::Dense(w, _) => {
                crate::sparse::dense_matvec_batch(w, x, y, b)
            }
            WeightFmt::Csr(c) => c.matvec_batch_into(x, y, b, scratch),
            WeightFmt::Macko(m) => m.matvec_batch_into(x, y, b, scratch),
            WeightFmt::CsrQ(c) => c.matvec_batch_into(x, y, b, scratch),
            WeightFmt::MackoQ(m) => {
                m.matvec_batch_into(x, y, b, scratch)
            }
            WeightFmt::Nm(n) => n.matvec_batch_into(x, y, b, scratch),
        }
    }

    /// Tiled variant of [`WeightFmt::matvec_batch`]: the kernel walks
    /// the format's construction-time row-tile plan, so each
    /// cache-sized weight tile is streamed once per step and applied
    /// across every live slot. Bit-identical to the untiled path for
    /// every format, batch size, and [`KernelPath`] (see
    /// [`crate::sparse::tile`]).
    pub fn matvec_batch_tiled(&self, x: &[f32], y: &mut [f32], b: usize,
                              scratch: &mut SpmmScratch,
                              path: KernelPath) {
        match self {
            WeightFmt::Dense(w, plan) => {
                if b == 1 {
                    // same batch-1 delegation as the sparse formats:
                    // both traversals are the identical matvec
                    let t = w.t_matvec(x);
                    y.copy_from_slice(&t);
                    return;
                }
                tile::matvec_batch_tiled(w, plan, x, y, b, scratch, path)
            }
            WeightFmt::Csr(c) => {
                c.matvec_batch_tiled_into(x, y, b, scratch, path)
            }
            WeightFmt::Macko(m) => {
                m.matvec_batch_tiled_into(x, y, b, scratch, path)
            }
            WeightFmt::CsrQ(c) => {
                c.matvec_batch_tiled_into(x, y, b, scratch, path)
            }
            WeightFmt::MackoQ(m) => {
                m.matvec_batch_tiled_into(x, y, b, scratch, path)
            }
            WeightFmt::Nm(n) => {
                n.matvec_batch_tiled_into(x, y, b, scratch, path)
            }
        }
    }

    /// Dispatch for the engine's decode loop. With a multi-lane `pool`
    /// (`--shard-workers > 1`) the layer's tile plan is split into
    /// byte-balanced row-band shards and executed on the pool's
    /// persistent workers ([`tile::pool_matvec_batch_tiled`]); the
    /// [`Engine::tiled`] toggle then only selects the serial traversal
    /// used when the pool is single-lane. Every path — either
    /// [`KernelPath`] included — produces bit-identical output, so no
    /// knob here can change a token. The untiled fallback
    /// (`tiled == false`) always runs the scalar reference kernels; it
    /// predates the path toggle and exists exactly to stay the
    /// untouched baseline.
    pub fn matvec_batch_exec(&self, x: &[f32], y: &mut [f32], b: usize,
                             scratch: &mut SpmmScratch, tiled: bool,
                             pool: &WorkerPool, path: KernelPath) {
        if pool.width() > 1 {
            match self {
                WeightFmt::Dense(w, plan) => tile::pool_matvec_batch_tiled(
                    w, plan, x, y, b, pool, scratch, path),
                WeightFmt::Csr(c) => tile::pool_matvec_batch_tiled(
                    c, &c.plan, x, y, b, pool, scratch, path),
                WeightFmt::Macko(m) => tile::pool_matvec_batch_tiled(
                    m, &m.plan, x, y, b, pool, scratch, path),
                WeightFmt::CsrQ(c) => tile::pool_matvec_batch_tiled(
                    c, &c.plan, x, y, b, pool, scratch, path),
                WeightFmt::MackoQ(m) => tile::pool_matvec_batch_tiled(
                    m, &m.plan, x, y, b, pool, scratch, path),
                WeightFmt::Nm(n) => match n {
                    NmWeights::N2M4(s) => tile::pool_matvec_batch_tiled(
                        s, &s.plan, x, y, b, pool, scratch, path),
                    NmWeights::N4M8(s) => tile::pool_matvec_batch_tiled(
                        s, &s.plan, x, y, b, pool, scratch, path),
                },
            }
        } else if tiled {
            self.matvec_batch_tiled(x, y, b, scratch, path);
        } else {
            self.matvec_batch(x, y, b, scratch);
        }
    }

    /// Rebuild this weight's tile plan with an explicit byte budget
    /// and row cap — see [`Engine::retile`].
    pub fn retile(&mut self, target_bytes: usize, max_rows: usize) {
        match self {
            WeightFmt::Dense(w, plan) => {
                *plan = TilePlan::with_budget(w.cols, |_| w.rows * 4,
                                              target_bytes, max_rows);
            }
            WeightFmt::Csr(c) => c.retile(target_bytes, max_rows),
            WeightFmt::Macko(m) => m.retile(target_bytes, max_rows),
            WeightFmt::CsrQ(c) => c.retile(target_bytes, max_rows),
            WeightFmt::MackoQ(m) => m.retile(target_bytes, max_rows),
            WeightFmt::Nm(n) => n.retile(target_bytes, max_rows),
        }
    }

    /// Actual compact-buffer bytes of this weight's storage — for the
    /// quantized variants this reflects the packed code/scale buffers,
    /// which is the whole point of the format.
    pub fn mem_bytes(&self) -> usize {
        match self {
            WeightFmt::Dense(w, _) => w.data.len() * 4,
            WeightFmt::Csr(c) => c.mem_bytes(),
            WeightFmt::Macko(m) => m.mem_bytes(),
            WeightFmt::CsrQ(c) => c.mem_bytes(),
            WeightFmt::MackoQ(m) => m.mem_bytes(),
            WeightFmt::Nm(n) => n.mem_bytes(),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Dense,
    Csr,
    Macko,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        Some(match s {
            "dense" => Backend::Dense,
            "csr" => Backend::Csr,
            "macko" => Backend::Macko,
            _ => return None,
        })
    }
}

struct Layer {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    wq: WeightFmt,
    wk: WeightFmt,
    wv: WeightFmt,
    wo: WeightFmt,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    w1: WeightFmt,
    b1: Vec<f32>,
    w2: WeightFmt,
    b2: Vec<f32>,
}

/// KV cache for one layer (grows up to seq_len).
struct Kv {
    k: Vec<f32>, // t * d
    v: Vec<f32>,
    len: usize,
}

/// Causal multi-head attention for one sequence over the first `upto`
/// entries of its KV cache: reads the query vector `q` (len d),
/// accumulates the weighted values into `o` (len d, caller-zeroed),
/// using `probs` as softmax scratch. The single numerics
/// implementation shared by the prefill and decode paths — keeping
/// them bit-identical by construction: a chunked-prefill position `t`
/// passes `upto = t + 1` and replays exactly the accumulation the
/// per-token path would have run when the cache held `t + 1` entries.
fn attend_cached(kv: &Kv, upto: usize, q: &[f32], o: &mut [f32],
                 probs: &mut [f32], h: usize, dh: usize, scale: f32,
                 d: usize) {
    debug_assert!(upto <= kv.len);
    for hh in 0..h {
        let c0 = hh * dh;
        let qh = &q[c0..c0 + dh];
        let pr = &mut probs[..upto];
        let mut max = f32::NEG_INFINITY;
        for (j, p) in pr.iter_mut().enumerate() {
            let krow = &kv.k[j * d + c0..j * d + c0 + dh];
            let mut acc = 0.0f32;
            for i in 0..dh {
                acc += qh[i] * krow[i];
            }
            *p = acc * scale;
            max = max.max(*p);
        }
        let mut sum = 0.0f32;
        for p in pr.iter_mut() {
            *p = (*p - max).exp();
            sum += *p;
        }
        let inv = 1.0 / sum;
        for (j, p) in pr.iter().enumerate() {
            let w = p * inv;
            let vrow = &kv.v[j * d + c0..j * d + c0 + dh];
            let orow = &mut o[c0..c0 + dh];
            for i in 0..dh {
                orow[i] += w * vrow[i];
            }
        }
    }
}

pub struct Engine {
    pub cfg: ConfigEntry,
    embed: Matrix,
    pos: Matrix,
    layers: Vec<Layer>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
    head: Matrix, // non-prunable, always dense
    pub backend: Backend,
    /// Batched decode runs the row-tiled kernels (default). The tiled
    /// and untiled paths are bit-identical, so flipping this only
    /// changes the traversal — `rust/tests/kernels.rs` asserts token
    /// streams match either way.
    pub tiled: bool,
    /// Prompt positions fed per prefill window (`--prefill-chunk`,
    /// default [`DEFAULT_PREFILL_CHUNK`]; clamped to >= 1 at use).
    /// A pure traversal knob: every value produces bit-identical
    /// token streams — chunking only changes how many positions share
    /// one pass through the weights.
    pub prefill_chunk: usize,
    /// Which payload the prunable linears carry (`--quant`): f32
    /// (`None`, the default) or fused-dequant int8/int4. A build-time
    /// property of the converted weights — never a runtime toggle —
    /// so one engine serves exactly one quant mode.
    pub quant: QuantMode,
    /// N:M structure of the converted weights (`--nm`): `Off` (the
    /// default) or a verified 2:4 / 4:8 pattern. Like `quant`, a
    /// build-time property of the weights, not a runtime toggle.
    pub nm: NmMode,
    /// Which inner-loop traversal the tiled/pooled kernels run
    /// (`--kernel-path`, default [`KernelPath::Unrolled`], overridable
    /// engine-wide via `ELSA_KERNEL_PATH`). A pure traversal knob:
    /// both paths are bit-identical, so flipping this cannot change a
    /// token — `rust/tests/determinism.rs` sweeps the axis.
    pub kernel_path: KernelPath,
    /// Rows projected through the dense head since construction (one
    /// per (slot, step) of [`Engine::decode_step_batch`]; the chunked
    /// prefill pass never projects). The prefill-efficiency probe:
    /// serving a request must cost exactly one head row per generated
    /// token — and in particular one per request for its whole prompt
    /// — regardless of prompt length or chunk size.
    head_rows: AtomicU64,
}

/// Default prompt window for the chunked prefill pass.
pub const DEFAULT_PREFILL_CHUNK: usize = 16;

impl Engine {
    /// Convert params: prunable matrices go to `backend` storage
    /// (f32 payloads; [`Engine::build_quant`] adds int8/int4).
    ///
    /// ```
    /// use elsa::infer::{Backend, Engine};
    /// use elsa::model::{fake_config, Params};
    ///
    /// let params = Params::init(&fake_config(), 4);
    /// let engine = Engine::build(&params, Backend::Macko).unwrap();
    /// // greedy generation: 3 new tokens after a 2-token prompt
    /// let (tokens, stats) = engine.generate(&[1, 2], 3, 0.0, 0);
    /// assert_eq!(tokens.len(), 5);
    /// assert_eq!(stats.tokens_generated, 3);
    /// assert_eq!(stats.quant_mode, "none");
    /// ```
    pub fn build(params: &Params, backend: Backend) -> Result<Engine> {
        Self::build_quant(params, backend, QuantMode::None)
    }

    /// [`Engine::build`] with a quantized payload: every prunable
    /// linear is converted through [`WeightFmt::build_quant`], so with
    /// `Int8`/`Int4` the sparse formats carry packed codes +
    /// per-row-block scales and dequantize inside the kernel inner
    /// loops. Requires a sparse `backend` when `quant != None` (dense
    /// weights have no quantized variant). Embeddings, positional
    /// table, and the head stay dense f32 — only the prunable linears
    /// quantize, mirroring what the pruners touch.
    pub fn build_quant(params: &Params, backend: Backend,
                       quant: QuantMode) -> Result<Engine> {
        Self::build_full(params, backend, quant, NmMode::Off)
    }

    /// [`Engine::build`] with an N:M structured payload: every
    /// prunable linear is verified against the pattern and converted
    /// to [`NmWeights`] ([`WeightFmt::build_full`]) — a checkpoint
    /// that violates the pattern fails loudly here, at build, not
    /// silently at serve time. Requires a sparse `backend`; the
    /// scalar/unrolled and tiling/pool/prefill machinery is inherited
    /// unchanged through the shared `RowTiled` contract.
    pub fn build_nm(params: &Params, backend: Backend, nm: NmMode)
                    -> Result<Engine> {
        Self::build_full(params, backend, QuantMode::None, nm)
    }

    /// The full build entry behind [`Engine::build`] /
    /// [`Engine::build_quant`] / [`Engine::build_nm`]. Invalid
    /// combinations (quant or N:M on dense, quant + N:M together) are
    /// rejected loudly — see [`WeightFmt::build_full`].
    pub fn build_full(params: &Params, backend: Backend,
                      quant: QuantMode, nm: NmMode) -> Result<Engine> {
        if quant != QuantMode::None && backend == Backend::Dense {
            anyhow::bail!("--quant requires a sparse backend \
                           (csr or macko), got dense");
        }
        if nm.is_on() && backend == Backend::Dense {
            anyhow::bail!("--nm requires a sparse backend \
                           (csr or macko), got dense");
        }
        if nm.is_on() && quant != QuantMode::None {
            anyhow::bail!("--nm and --quant are mutually exclusive \
                           (no quantized N:M payload)");
        }
        let cfg = params.cfg.clone();
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let p = format!("l{l}.");
            let get = |n: &str| params.matrix(&(p.clone() + n));
            let vec = |n: &str| -> Result<Vec<f32>> {
                Ok(params.vector(&(p.clone() + n))?.to_vec())
            };
            let conv = |w: Matrix| WeightFmt::build_full(w, backend,
                                                         quant, nm);
            layers.push(Layer {
                ln1_g: vec("ln1.g")?,
                ln1_b: vec("ln1.b")?,
                wq: conv(get("attn.wq")?)?,
                wk: conv(get("attn.wk")?)?,
                wv: conv(get("attn.wv")?)?,
                wo: conv(get("attn.wo")?)?,
                ln2_g: vec("ln2.g")?,
                ln2_b: vec("ln2.b")?,
                w1: conv(get("mlp.w1")?)?,
                b1: vec("mlp.b1")?,
                w2: conv(get("mlp.w2")?)?,
                b2: vec("mlp.b2")?,
            });
        }
        let pos = params.matrix("pos")?;
        // a positional table shorter than seq_len would silently
        // recycle its last row mid-sequence; fail loudly at load time
        // instead (the decode paths debug_assert the same invariant)
        anyhow::ensure!(
            pos.rows >= cfg.seq_len,
            "checkpoint/config mismatch: positional table has {} rows \
             but config '{}' declares seq_len {}",
            pos.rows, cfg.name, cfg.seq_len);
        Ok(Engine {
            embed: params.matrix("embed")?,
            pos,
            layers,
            lnf_g: params.vector("lnf.g")?.to_vec(),
            lnf_b: params.vector("lnf.b")?.to_vec(),
            head: params.matrix("head")?,
            cfg,
            backend,
            tiled: true,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            quant,
            nm,
            kernel_path: KernelPath::default_path(),
            head_rows: AtomicU64::new(0),
        })
    }

    /// Total rows projected through the dense head since this engine
    /// was built (monotonic; shared across threads). Tests use deltas
    /// of this counter to pin the chunked-prefill contract: exactly
    /// one head projection per request for its whole prompt.
    pub fn head_rows_projected(&self) -> u64 {
        self.head_rows.load(Ordering::Relaxed)
    }

    /// Rebuild every layer's tile plan with an explicit byte budget
    /// and row cap ([`TilePlan::with_budget`]). The default plans
    /// target half an L1d; deployments with different cache geometry —
    /// and toy-sized test models whose whole layer fits one default
    /// tile — use this to pick the shard granularity the
    /// `--shard-workers` pool splits over. Plans are traversal
    /// metadata only: any geometry produces bit-identical tokens.
    pub fn retile(&mut self, target_bytes: usize, max_rows: usize) {
        for l in &mut self.layers {
            l.wq.retile(target_bytes, max_rows);
            l.wk.retile(target_bytes, max_rows);
            l.wv.retile(target_bytes, max_rows);
            l.wo.retile(target_bytes, max_rows);
            l.w1.retile(target_bytes, max_rows);
            l.w2.retile(target_bytes, max_rows);
        }
    }

    /// Total weight storage (the Table-1 "Memory" column).
    pub fn mem_bytes(&self) -> usize {
        let mut total = (self.embed.data.len() + self.pos.data.len()
                         + self.head.data.len()) * 4;
        for l in &self.layers {
            total += l.wq.mem_bytes() + l.wk.mem_bytes() + l.wv.mem_bytes()
                + l.wo.mem_bytes() + l.w1.mem_bytes() + l.w2.mem_bytes();
            total += (l.ln1_g.len() + l.ln1_b.len() + l.ln2_g.len()
                      + l.ln2_b.len() + l.b1.len() + l.b2.len()) * 4;
        }
        total + (self.lnf_g.len() + self.lnf_b.len()) * 4
    }

    fn layernorm_vec(x: &[f32], g: &[f32], b: &[f32], out: &mut [f32]) {
        let n = x.len() as f32;
        let mean = x.iter().sum::<f32>() / n;
        let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for i in 0..x.len() {
            out[i] = (x[i] - mean) * inv * g[i] + b[i];
        }
    }

    /// First half of one layer for `b` packed rows of `scratch.x`:
    /// ln1 into `scratch.xa`, then the Q/K/V projections. Shared
    /// verbatim by the prefill pass and the decode step — the two
    /// drivers differ only in how rows map onto KV caches, so the
    /// projection halves live here exactly once.
    fn layer_qkv(&self, l: &Layer, b: usize, scratch: &mut BatchScratch,
                 pool: &WorkerPool) {
        let d = self.cfg.d_model;
        for r in 0..b {
            Self::layernorm_vec(&scratch.x[r * d..(r + 1) * d],
                                &l.ln1_g, &l.ln1_b,
                                &mut scratch.xa[r * d..(r + 1) * d]);
        }
        l.wq.matvec_batch_exec(&scratch.xa[..b * d],
                               &mut scratch.q[..b * d], b,
                               &mut scratch.spmm, self.tiled, pool,
                               self.kernel_path);
        l.wk.matvec_batch_exec(&scratch.xa[..b * d],
                               &mut scratch.k[..b * d], b,
                               &mut scratch.spmm, self.tiled, pool,
                               self.kernel_path);
        l.wv.matvec_batch_exec(&scratch.xa[..b * d],
                               &mut scratch.v[..b * d], b,
                               &mut scratch.spmm, self.tiled, pool,
                               self.kernel_path);
    }

    /// Second half of one layer for `b` packed rows: O-projection of
    /// `scratch.o` + residual into `scratch.x`, then ln2 / W1 / gelu /
    /// W2 + residual. Shared verbatim by the prefill pass and the
    /// decode step (see [`Engine::layer_qkv`]).
    fn layer_ffn(&self, l: &Layer, b: usize, scratch: &mut BatchScratch,
                 pool: &WorkerPool) {
        let d = self.cfg.d_model;
        let dff = self.cfg.d_ff;
        l.wo.matvec_batch_exec(&scratch.o[..b * d],
                               &mut scratch.tmp_d[..b * d], b,
                               &mut scratch.spmm, self.tiled, pool,
                               self.kernel_path);
        for i in 0..b * d {
            scratch.x[i] += scratch.tmp_d[i];
        }

        for r in 0..b {
            Self::layernorm_vec(&scratch.x[r * d..(r + 1) * d],
                                &l.ln2_g, &l.ln2_b,
                                &mut scratch.xa[r * d..(r + 1) * d]);
        }
        l.w1.matvec_batch_exec(&scratch.xa[..b * d],
                               &mut scratch.ff[..b * dff], b,
                               &mut scratch.spmm, self.tiled, pool,
                               self.kernel_path);
        for r in 0..b {
            let frow = &mut scratch.ff[r * dff..(r + 1) * dff];
            for (f, bias) in frow.iter_mut().zip(l.b1.iter()) {
                *f = gelu_tanh(*f + bias);
            }
        }
        l.w2.matvec_batch_exec(&scratch.ff[..b * dff],
                               &mut scratch.tmp_d[..b * d], b,
                               &mut scratch.spmm, self.tiled, pool,
                               self.kernel_path);
        for r in 0..b {
            for c in 0..d {
                scratch.x[r * d + c] +=
                    scratch.tmp_d[r * d + c] + l.b2[c];
            }
        }
    }

    /// Headless chunked prefill: feed the next `n` prompt positions of
    /// `slot` through every layer as ONE pass — the window is the
    /// batch dimension of the same [`WeightFmt::matvec_batch_exec`]
    /// kernels the decode step uses, so prompt projections get the
    /// tiled/pooled traversals for free — with per-position causal
    /// attention over the cache prefix. No final layernorm and no head
    /// projection: the caller feeds the *last* prompt position through
    /// [`Engine::decode_step_batch`], which projects the head exactly
    /// once for the whole prompt. The layer math itself is the shared
    /// [`Engine::layer_qkv`]/[`Engine::layer_ffn`] halves — only the
    /// row→KV mapping (one slot, window rows, prefix attention) lives
    /// here.
    ///
    /// Bit-exactness: row `r` of every batched linear is bit-exact
    /// with the single-vector matvec on that position alone, and
    /// position `t` attends over the first `t + 1` cache entries in
    /// the per-token accumulation order — so the residual stream (and
    /// therefore every downstream token) is bit-identical for any
    /// window size.
    ///
    /// Requires `slot.fed + n < slot.tokens.len()` (the final prompt
    /// position is the unified step's job) and `n <= prefill_chunk`
    /// capacity of `scratch`.
    fn prefill_pass(&self, slot: &mut Slot, n: usize,
                    scratch: &mut BatchScratch, pool: &WorkerPool) {
        self.prefill_pass_multi(std::slice::from_mut(slot), &[(0, n)],
                                scratch, pool);
    }

    /// Cross-slot batched prefill: one pass over the packed pending
    /// windows of several slots. `jobs` lists `(slot index, window
    /// rows)` pairs — distinct slots — and the windows are packed
    /// job-major into `scratch`, so the whole set of prefilling slots
    /// shares ONE trip through every layer's weights per scheduler
    /// iteration (time × slots as the batch dimension) instead of one
    /// [`WeightFmt::matvec_batch_exec`] dispatch per slot.
    ///
    /// Bit-exactness: row `r` of a batched linear is bit-exact with
    /// the single-vector matvec on that row alone — the invariant the
    /// whole engine is built on — so how many windows share the pass
    /// cannot change any slot's values; and attention stays per-slot
    /// per-position (position `t` attends its own cache's first
    /// `t + 1` entries in per-token order), exactly as in the
    /// single-slot pass. `scratch` must hold `sum(n)` rows.
    fn prefill_pass_multi(&self, slots: &mut [Slot],
                          jobs: &[(usize, usize)],
                          scratch: &mut BatchScratch, pool: &WorkerPool) {
        let b: usize = jobs.iter().map(|&(_, n)| n).sum();
        debug_assert!(b >= 1);
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = d / h;
        let scale = 1.0 / (dh as f32).sqrt();

        // embed + positional for each window position, packed job-major
        let mut off = 0usize;
        for &(si, n) in jobs {
            let slot = &slots[si];
            debug_assert!(n >= 1);
            debug_assert!(slot.fed + n < slot.tokens.len(),
                          "prefill window must leave the final prompt \
                           position for the head-projecting step");
            let t0 = slot.fed;
            for r in 0..n {
                let t = t0 + r;
                // unreachable once the seq_len prompt guards hold; the
                // loud mismatch error lives in Engine::build
                debug_assert!(t < self.pos.rows);
                let e = self.embed.row(slot.tokens[t] as usize);
                let pr = self.pos.row(t);
                let xrow = &mut scratch.x[(off + r) * d..(off + r + 1) * d];
                for c in 0..d {
                    xrow[c] = e[c] + pr[c];
                }
            }
            off += n;
        }

        for (li, l) in self.layers.iter().enumerate() {
            self.layer_qkv(l, b, scratch, pool);

            // per slot: append its window's K/V, then attend each of
            // its positions causally over its own prefix of the cache
            let mut off = 0usize;
            for &(si, n) in jobs {
                let slot = &mut slots[si];
                let t0 = slot.fed;
                let kv = &mut slot.kvs[li];
                kv.k.extend_from_slice(&scratch.k[off * d..(off + n) * d]);
                kv.v.extend_from_slice(&scratch.v[off * d..(off + n) * d]);
                kv.len += n;
                for r in 0..n {
                    let orow =
                        &mut scratch.o[(off + r) * d..(off + r + 1) * d];
                    orow.iter_mut().for_each(|v| *v = 0.0);
                    attend_cached(kv, t0 + r + 1,
                                  &scratch.q[(off + r) * d
                                             ..(off + r + 1) * d],
                                  orow, &mut scratch.probs, h, dh, scale,
                                  d);
                }
                off += n;
            }

            self.layer_ffn(l, b, scratch, pool);
        }
        // no lnf, no head: prompt logits before the last position are
        // never read, so computing them would be pure waste
        for &(si, n) in jobs {
            slots[si].fed += n;
        }
    }

    /// Drive `slot`'s whole prompt: chunked headless passes over
    /// positions `0..len-1`, then the final position through the
    /// unified decode step (ONE head projection). Leaves the slot with
    /// logits for its last prompt token. Returns the number of chunked
    /// passes run. `slot.tokens` must be non-empty.
    fn prefill_slot(&self, slot: &mut Slot, scratch: &mut BatchScratch,
                    pool: &WorkerPool) -> usize {
        let last = slot.tokens.len() - 1;
        let chunk = self.prefill_chunk.max(1);
        let mut chunks = 0usize;
        while slot.fed < last {
            let n = chunk.min(last - slot.fed);
            self.prefill_pass(slot, n, scratch, pool);
            chunks += 1;
        }
        self.decode_step_batch(std::slice::from_mut(slot), &[0],
                               scratch, pool);
        chunks
    }

    /// Greedy/temperature generation. Returns (tokens, decode stats).
    /// A thin batch-of-1 driver over the unified forward
    /// implementation (chunked prefill + batched decode step) — see
    /// [`Engine::generate_pooled`], which this calls with a
    /// single-lane (inline, spawn-free) pool.
    ///
    /// An empty prompt returns zero tokens — the same rule as
    /// [`Engine::generate_batch`] (there is nothing to condition on).
    ///
    /// ```
    /// use elsa::infer::{Backend, Engine};
    /// use elsa::model::{fake_config, Params};
    ///
    /// let params = Params::init(&fake_config(), 4);
    /// let engine = Engine::build(&params, Backend::Csr).unwrap();
    /// // temperature 0 is greedy: the same call reproduces itself
    /// let (a, _) = engine.generate(&[1, 2, 3], 4, 0.0, 0);
    /// let (b, _) = engine.generate(&[1, 2, 3], 4, 0.0, 0);
    /// assert_eq!(a, b);
    /// ```
    pub fn generate(&self, prompt: &[u32], n_new: usize, temperature: f32,
                    seed: u64) -> (Vec<u32>, GenStats) {
        self.generate_pooled(prompt, n_new, temperature, seed,
                             &WorkerPool::new(1))
    }

    /// [`Engine::generate`] with an explicit row-band shard pool:
    /// single-sequence decode fans every linear — and the head
    /// projection — across the pool's persistent lanes when it has
    /// more than one (`elsa infer --shard-workers M`). Tokens are
    /// bit-identical for any pool width; the pool is only a traversal.
    pub fn generate_pooled(&self, prompt: &[u32], n_new: usize,
                           temperature: f32, seed: u64,
                           pool: &WorkerPool) -> (Vec<u32>, GenStats) {
        assert!(prompt.len() <= self.cfg.seq_len,
                "prompt of {} tokens exceeds seq_len {}", prompt.len(),
                self.cfg.seq_len);
        let mut stats = GenStats {
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            tokens_generated: 0,
            tokens_per_second: 0.0,
            mem_bytes: self.mem_bytes(),
            prefill_tokens: 0,
            prefill_chunks: 0,
            prefix_hits: 0,
            prefix_tokens_saved: 0,
            shard_busy_seconds: 0.0,
            shard_idle_seconds: 0.0,
            quant_mode: self.quant.label(),
            nm_mode: self.nm.label(),
            kernel_path: self.kernel_path.label(),
        };
        if prompt.is_empty() {
            return (Vec::new(), stats);
        }
        let d = self.cfg.d_model;
        let cap = self.cfg.seq_len * d;
        let mut slot = Slot {
            tokens: prompt.to_vec(),
            prompt_len: prompt.len(),
            fed: 0,
            kvs: (0..self.cfg.n_layers)
                .map(|_| Kv { k: Vec::with_capacity(cap),
                              v: Vec::with_capacity(cap), len: 0 })
                .collect(),
            rng: Rng::new(seed),
            logits: vec![],
            generated: 0,
            n_new,
        };
        let mut scratch =
            BatchScratch::new(&self.cfg, 1, self.prefill_chunk.max(1));
        let p0 = pool.stats();

        let tp = Timer::start();
        stats.prefill_chunks = self.prefill_slot(&mut slot, &mut scratch,
                                                 pool);
        // same semantics as SchedStats: positions fed headless (the
        // final prompt position rides the head-projecting step)
        stats.prefill_tokens = prompt.len() - 1;
        stats.prefill_seconds = tp.seconds();

        let td = Timer::start();
        while slot.generated < slot.n_new
            && slot.tokens.len() < self.cfg.seq_len
        {
            let next = sample(&slot.logits, temperature, &mut slot.rng);
            slot.tokens.push(next);
            slot.generated += 1;
            if slot.generated >= slot.n_new
                || slot.tokens.len() >= self.cfg.seq_len
            {
                // budget hit: its logits would never be read, so skip
                // the forward pass (same rule as the scheduler)
                break;
            }
            self.decode_step_batch(std::slice::from_mut(&mut slot), &[0],
                                   &mut scratch, pool);
        }
        stats.decode_seconds = td.seconds();
        stats.tokens_generated = slot.generated;
        stats.tokens_per_second =
            slot.generated as f64 / stats.decode_seconds.max(1e-9);
        if pool.width() > 1 {
            let p1 = pool.stats();
            stats.shard_busy_seconds = p1.busy_total() - p0.busy_total();
            stats.shard_idle_seconds = p1.idle_total() - p0.idle_total();
        }
        (slot.tokens, stats)
    }

    /// Feed `tokens` through a fresh KV cache and return the logits
    /// after the last token (test/debug helper for the parity suite).
    /// Rides the same chunked prefill + unified step as every other
    /// path: one head projection total, regardless of `tokens.len()`.
    pub fn logits_for(&self, tokens: &[u32]) -> Vec<f32> {
        assert!(tokens.len() <= self.cfg.seq_len,
                "prompt of {} tokens exceeds seq_len {}", tokens.len(),
                self.cfg.seq_len);
        if tokens.is_empty() {
            return Vec::new();
        }
        let d = self.cfg.d_model;
        let cap = tokens.len() * d;
        let mut slot = Slot {
            tokens: tokens.to_vec(),
            prompt_len: tokens.len(),
            fed: 0,
            kvs: (0..self.cfg.n_layers)
                .map(|_| Kv { k: Vec::with_capacity(cap),
                              v: Vec::with_capacity(cap), len: 0 })
                .collect(),
            rng: Rng::new(0),
            logits: vec![],
            generated: 0,
            n_new: 0,
        };
        let mut scratch =
            BatchScratch::new(&self.cfg, 1, self.prefill_chunk.max(1));
        self.prefill_slot(&mut slot, &mut scratch, &WorkerPool::new(1));
        slot.logits
    }

    /// Batched generation over many prompts with per-slot KV caches and
    /// slot retirement: a thin wrapper over the continuous-batching
    /// [`scheduler`] with *fixed admission* — every prompt becomes a
    /// request arriving at step 0 with `max_slots == prompts.len()`, so
    /// the whole batch is admitted up front (the pre-scheduler
    /// behavior). A slot retires as soon as it has produced `n_new`
    /// tokens or its sequence hits `seq_len`.
    ///
    /// Determinism: a slot `s` with a non-empty prompt reproduces
    /// `generate(&prompts[s], n_new, temperature, seed + s)`
    /// bit-for-bit, for any batch size and any `threads` /
    /// `shard_workers` value — the batched kernels keep each sequence's
    /// accumulation order identical to the single-vector path (pooled
    /// row-band shards are disjoint, so lane count cannot reorder an
    /// accumulation), and each slot samples from its own seeded RNG.
    ///
    /// Prompts may be ragged. A slot with an empty prompt retires
    /// immediately with zero tokens (there is nothing to condition
    /// on) — the same rule `generate(&[], ..)` follows, so the two
    /// paths agree on every input.
    pub fn generate_batch(&self, prompts: &[Vec<u32>], opts: &BatchOptions)
                          -> (Vec<Vec<u32>>, GenStats) {
        for p in prompts {
            assert!(p.len() <= self.cfg.seq_len,
                    "prompt of {} tokens exceeds seq_len {}", p.len(),
                    self.cfg.seq_len);
        }
        let mut queue = scheduler::RequestQueue::new();
        for (s, p) in prompts.iter().enumerate() {
            queue.push(scheduler::Request {
                id: s as u64,
                prompt: p.clone(),
                n_new: opts.n_new,
                seed: opts.seed.wrapping_add(s as u64),
                deadline: None,
            });
        }
        let sched = scheduler::Scheduler::new(self, scheduler::SchedOptions {
            max_slots: prompts.len().max(1),
            temperature: opts.temperature,
            threads: opts.threads,
            shard_workers: opts.shard_workers,
            prefix_cache: opts.prefix_cache,
            pin_workers: opts.pin_workers,
        });
        // run() returns finished requests sorted by id == slot index
        let (finished, st) = sched.run(queue);
        let outs: Vec<Vec<u32>> =
            finished.into_iter().map(|f| f.tokens).collect();
        (outs, GenStats {
            prefill_seconds: st.prefill_seconds,
            decode_seconds: st.decode_seconds,
            tokens_generated: st.tokens_generated,
            // aggregate rate over the run's wall time: prefill/decode
            // seconds are CPU-seconds summed across workers, so they
            // are not a throughput denominator under `threads > 1`
            tokens_per_second: st.tokens_per_second,
            mem_bytes: self.mem_bytes(),
            prefill_tokens: st.prefill_tokens,
            prefill_chunks: st.prefill_chunks,
            prefix_hits: st.prefix_hits,
            prefix_tokens_saved: st.prefix_tokens_saved,
            shard_busy_seconds: st.shard_busy_seconds.iter().sum(),
            shard_idle_seconds: st.shard_idle_seconds.iter().sum(),
            quant_mode: st.quant_mode,
            nm_mode: st.nm_mode,
            kernel_path: st.kernel_path,
        })
    }

    /// One batched decode step: for every slot index in `active`, feed
    /// that slot's next unfed token through all layers, appending to its
    /// KV cache and refreshing its logits. The linears run as one
    /// multi-vector SpMM over the active set — dispatched to `pool`'s
    /// persistent row-band workers when it has more than one lane
    /// (`--shard-workers`), so a step is parallel *within* each layer
    /// on top of the scheduler's slot sharding; attention and layernorm
    /// stay per-slot (each slot has its own cache length/position).
    fn decode_step_batch(&self, slots: &mut [Slot], active: &[usize],
                         scratch: &mut BatchScratch, pool: &WorkerPool) {
        let b = active.len();
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = d / h;
        let scale = 1.0 / (dh as f32).sqrt();

        // embed + positional for each slot's next token
        for (bi, &si) in active.iter().enumerate() {
            let s = &slots[si];
            let t = s.fed;
            // unreachable once the seq_len prompt guards hold; the
            // loud mismatch error lives in Engine::build
            debug_assert!(t < self.pos.rows);
            let e = self.embed.row(s.tokens[t] as usize);
            let pr = self.pos.row(t);
            let xrow = &mut scratch.x[bi * d..(bi + 1) * d];
            for c in 0..d {
                xrow[c] = e[c] + pr[c];
            }
        }

        for (li, l) in self.layers.iter().enumerate() {
            self.layer_qkv(l, b, scratch, pool);

            // per-slot attention over each slot's own cache
            for (bi, &si) in active.iter().enumerate() {
                let kv = &mut slots[si].kvs[li];
                kv.k.extend_from_slice(&scratch.k[bi * d..(bi + 1) * d]);
                kv.v.extend_from_slice(&scratch.v[bi * d..(bi + 1) * d]);
                kv.len += 1;

                let orow = &mut scratch.o[bi * d..(bi + 1) * d];
                orow.iter_mut().for_each(|v| *v = 0.0);
                attend_cached(kv, kv.len,
                              &scratch.q[bi * d..(bi + 1) * d],
                              orow, &mut scratch.probs, h, dh, scale, d);
            }

            self.layer_ffn(l, b, scratch, pool);
        }

        // final layernorm per slot, then ONE batched head projection
        // over the packed activations: the head matrix is streamed
        // once per step via `t_matmat` regardless of how many slots
        // are live (it used to be one `t_matvec` per slot per step).
        // With a multi-lane pool the projection's output columns are
        // fanned across the persistent lanes instead
        // (`tile::pool_t_matmat`). Row bi of either GEMM is
        // bit-identical to `t_matvec(xa_bi)`, so every slot's logits
        // are unchanged.
        for bi in 0..b {
            Self::layernorm_vec(&scratch.x[bi * d..(bi + 1) * d],
                                &self.lnf_g, &self.lnf_b,
                                &mut scratch.xa[bi * d..(bi + 1) * d]);
        }
        let vocab = self.head.cols;
        self.head_rows.fetch_add(b as u64, Ordering::Relaxed);
        if pool.width() > 1 {
            tile::pool_t_matmat(&self.head, &scratch.xa[..b * d],
                                &mut scratch.logits[..b * vocab], b,
                                pool);
        } else {
            self.head.t_matmat(&scratch.xa[..b * d],
                               &mut scratch.logits[..b * vocab], b);
        }
        for (bi, &si) in active.iter().enumerate() {
            let s = &mut slots[si];
            s.logits.resize(vocab, 0.0);
            s.logits.copy_from_slice(
                &scratch.logits[bi * vocab..(bi + 1) * vocab]);
            s.fed += 1;
        }
    }
}

/// Options for [`Engine::generate_batch`].
#[derive(Debug, Clone)]
pub struct BatchOptions {
    /// New tokens to generate per slot (capped by `seq_len`).
    pub n_new: usize,
    /// Sampling temperature (0 = greedy).
    pub temperature: f32,
    /// Slot `s` samples from `Rng::new(seed + s)`, matching a
    /// single-sequence `generate` call with seed `seed + s`.
    pub seed: u64,
    /// Scheduler worker threads (batch capacity is split across them;
    /// 0/1 = inline).
    pub threads: usize,
    /// Row-band shard workers *per scheduler worker*: each worker owns
    /// a persistent [`pool::WorkerPool`] of this many lanes and fans
    /// every layer's linears out across byte-balanced tile shards
    /// (0/1 = serial decode, no pool threads spawned). Composes with
    /// `threads` — slots × bands — and never changes a token.
    pub shard_workers: usize,
    /// Shared-prefix KV cache (`--prefix-cache {on,off}`, default on):
    /// requests whose prompts extend an already-prefilled prefix
    /// attach its cached K/V rows and prefill only their suffix.
    /// Bit-identical streams either way — a hit copies exactly the
    /// rows a cold prefill would have produced.
    pub prefix_cache: bool,
    /// Best-effort core affinity for the row-band shard lanes
    /// (`--pin-workers {on,off}`, default off): Linux pins each
    /// spawned lane to a core via `sched_setaffinity`, elsewhere a
    /// no-op. Pure placement — never changes a token.
    pub pin_workers: bool,
}

impl Default for BatchOptions {
    fn default() -> BatchOptions {
        BatchOptions {
            n_new: 16,
            temperature: 0.0,
            seed: 0,
            threads: 1,
            shard_workers: 1,
            prefix_cache: true,
            pin_workers: false,
        }
    }
}

/// One in-flight sequence of the batched engine. Created by the
/// [`scheduler`] at admission time, with KV buffers drawn from its
/// [`scheduler::KvPool`]; retirement hands the buffers back.
struct Slot {
    tokens: Vec<u32>,
    prompt_len: usize,
    /// Tokens already decoded into the KV cache.
    fed: usize,
    kvs: Vec<Kv>,
    rng: Rng,
    logits: Vec<f32>,
    generated: usize,
    /// This request's token budget (the slot retires once reached).
    n_new: usize,
}

/// Scratch for the unified forward implementation: row-major (rows, ·)
/// activation buffers sized for `max(slot count, prefill window)` —
/// the decode step batches over slots, the prefill pass batches over
/// prompt positions, and both use prefixes of the same buffers. The
/// logits staging is sized for the slot count only: prefill never
/// projects the head.
struct BatchScratch {
    x: Vec<f32>,
    xa: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    o: Vec<f32>,
    ff: Vec<f32>,
    tmp_d: Vec<f32>,
    probs: Vec<f32>,
    /// Staging for the step's single batched head projection
    /// ((b, vocab), written by `t_matmat`, copied out per slot).
    logits: Vec<f32>,
    /// Kernel-side scratch shared by every matvec_batch of the step.
    spmm: SpmmScratch,
}

impl BatchScratch {
    /// `slots` bounds the decode step's batch; `chunk` bounds each
    /// slot's prefill window (a window never exceeds `seq_len - 1`
    /// positions, so an oversized `--prefill-chunk` costs nothing
    /// extra here). The activation rows are sized `slots × window`
    /// because the scheduler packs every prefilling slot's pending
    /// window into ONE cross-slot pass
    /// ([`Engine::prefill_pass_multi`]); the decode step only ever
    /// needs `slots` of them.
    fn new(cfg: &ConfigEntry, slots: usize, chunk: usize) -> BatchScratch {
        let d = cfg.d_model;
        let window = chunk.min(cfg.seq_len.saturating_sub(1)).max(1);
        let rows = slots.max(1) * window;
        BatchScratch {
            x: vec![0.0; rows * d],
            xa: vec![0.0; rows * d],
            q: vec![0.0; rows * d],
            k: vec![0.0; rows * d],
            v: vec![0.0; rows * d],
            o: vec![0.0; rows * d],
            ff: vec![0.0; rows * cfg.d_ff],
            tmp_d: vec![0.0; rows * d],
            probs: vec![0.0; cfg.seq_len],
            logits: vec![0.0; slots.max(1) * cfg.vocab],
            spmm: SpmmScratch::default(),
        }
    }
}

fn sample(logits: &[f32], temperature: f32, rng: &mut Rng) -> u32 {
    if logits.is_empty() {
        return 0;
    }
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as u32)
            .unwrap_or(0);
    }
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f32> =
        logits.iter().map(|&l| ((l - max) / temperature).exp()).collect();
    rng.categorical(&weights) as u32
}

#[derive(Debug, Clone)]
pub struct GenStats {
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    pub tokens_generated: usize,
    pub tokens_per_second: f64,
    pub mem_bytes: usize,
    /// Prompt positions fed through the headless chunked prefill pass
    /// (the final prompt position of each request rides the unified
    /// decode step instead — that is its one head projection).
    pub prefill_tokens: usize,
    /// Chunked prefill passes run (`ceil((len - 1) / prefill_chunk)`
    /// per non-empty prompt).
    pub prefill_chunks: usize,
    /// Requests that attached a shared KV prefix at admission
    /// (0 outside the scheduler path or with `--prefix-cache off`).
    pub prefix_hits: usize,
    /// Prompt positions served from the shared-prefix cache instead
    /// of being recomputed — the sum of attached prefix lengths.
    pub prefix_tokens_saved: usize,
    /// Seconds the decode pool's shard lanes spent executing row-band
    /// jobs, summed over lanes and scheduler workers (0 when
    /// `shard_workers <= 1` — the pool is never dispatched).
    pub shard_busy_seconds: f64,
    /// Seconds shard lanes sat idle while a dispatch was in flight —
    /// the plan-imbalance signal (0 without a multi-lane pool).
    pub shard_idle_seconds: f64,
    /// Payload the engine decoded ("none", "int8", or "int4") — lets
    /// bench/CLI output attribute a tok/s or `mem_bytes` number to its
    /// quant mode without carrying the engine around.
    pub quant_mode: &'static str,
    /// N:M structure the engine served ("off", "2:4", or "4:8") —
    /// same self-description contract as `quant_mode`.
    pub nm_mode: &'static str,
    /// Inner-loop traversal the kernels ran ("scalar" or "unrolled").
    pub kernel_path: &'static str,
}

/// `elsa generate` / `elsa infer` subcommand. `--batch N` serves N
/// prompts through the batched engine; `--threads N` shards the batch
/// across worker threads; `--shard-workers M` additionally shards each
/// layer's linears across M persistent row-band workers per thread
/// (single-sequence decode uses the same pool via
/// [`Engine::generate_pooled`]); `--prefill-chunk C` sets the prompt
/// window of the chunked prefill pass; `--prefix-cache {on,off}`
/// toggles the scheduler's shared-prefix KV cache on the batch path;
/// `--quant {none,int8,int4}` serves quantized sparse payloads with
/// fused dequant (tolerance parity vs f32, bit-exact within a mode);
/// `--nm {off,2:4,4:8}` serves a verified N:M structured checkpoint
/// through the branch-free N:M kernels; `--kernel-path
/// {scalar,unrolled}` picks the inner-loop traversal (bit-identical);
/// `--pin-workers {on,off}` pins shard-pool lanes to cores;
/// `--untiled` falls back to the untiled SpMM kernels (every traversal
/// knob is bit-identical output, for perf comparisons).
pub fn cmd_generate(args: &Args) -> Result<()> {
    let rt = crate::commands::open_runtime(args)?;
    let ck = crate::model::checkpoint::Checkpoint::load(
        &std::path::PathBuf::from(args.require("ckpt")?))?;
    let cfg = rt.manifest.config(&ck.config)?.clone();
    let params = Params::new(&cfg, ck.get("params")?.clone());
    let backend = Backend::parse(&args.str_or("backend", "macko"))
        .ok_or_else(|| anyhow::anyhow!("bad --backend"))?;
    let quant = QuantMode::parse(&args.str_or("quant", "none"))?;
    let nm = NmMode::parse(&args.str_or("nm", "off"))?;
    let mut engine = Engine::build_full(&params, backend, quant, nm)?;
    engine.tiled = !args.bool("untiled");
    if let Some(p) = args.get("kernel-path") {
        engine.kernel_path = KernelPath::parse(p)?;
    }
    engine.prefill_chunk =
        args.usize_or("prefill-chunk", DEFAULT_PREFILL_CHUNK)?.max(1);

    let g = crate::data::Grammar::named(
        &args.str_or("dataset", "synth-c4"), cfg.vocab);
    let prompt_len = args.usize_or("prompt-len", 8)?;
    let n_new = args.usize_or("tokens", cfg.seq_len - prompt_len)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let temperature = args.f32_or("temp", 0.8)?;
    let batch = args.usize_or("batch", 1)?;
    let threads = args.usize_or("threads", 1)?;
    let shard_workers = args.usize_or("shard-workers", 1)?;
    let prefix_cache = scheduler::prefix_cache_flag(args)?;
    let pin_workers = scheduler::pin_workers_flag(args)?;

    if batch <= 1 {
        let prompt = g.generate(prompt_len, seed);
        // sample with `seed` so --batch 1 and slot 0 of --batch N are
        // the same request; single-sequence decode owns its own
        // row-band pool (bands are the only sharding axis here)
        let pool = WorkerPool::new_pinned(shard_workers.max(1),
                                          pin_workers);
        let (tokens, stats) =
            engine.generate_pooled(&prompt, n_new, temperature, seed,
                                   &pool);
        println!("prompt  {:?}", &tokens[..prompt_len]);
        println!("output  {:?}", &tokens[prompt_len..]);
        println!("sparsity {:.4}", params.sparsity());
        println!("backend {:?}", backend);
        println!("quant {}", stats.quant_mode);
        println!("nm {} kernel_path {}", stats.nm_mode,
                 stats.kernel_path);
        println!("tokens_per_s {:.2}", stats.tokens_per_second);
        println!("decode_s {:.4}", stats.decode_seconds);
        println!("prefill_s {:.4} ({} tokens, {} chunk passes, \
                  chunk {})",
                 stats.prefill_seconds, stats.prefill_tokens,
                 stats.prefill_chunks, engine.prefill_chunk);
        if shard_workers > 1 {
            println!("shard_busy_s {:.4} shard_idle_s {:.4}",
                     stats.shard_busy_seconds, stats.shard_idle_seconds);
        }
        println!("mem {}", crate::util::human_bytes(stats.mem_bytes));
    } else {
        let prompts: Vec<Vec<u32>> = (0..batch)
            .map(|r| g.generate(prompt_len, seed.wrapping_add(r as u64)))
            .collect();
        let opts = BatchOptions {
            n_new, temperature, seed, threads, shard_workers,
            prefix_cache, pin_workers,
        };
        let (outs, stats) = engine.generate_batch(&prompts, &opts);
        for (s, out) in outs.iter().enumerate() {
            println!("slot {s:3}: prompt {:?} -> {} new tokens",
                     &out[..prompt_len.min(out.len())],
                     out.len() - prompt_len.min(out.len()));
        }
        println!("sparsity {:.4}", params.sparsity());
        println!("backend {:?}", backend);
        println!("quant {}", stats.quant_mode);
        println!("nm {} kernel_path {}", stats.nm_mode,
                 stats.kernel_path);
        println!("batch {batch} threads {threads} \
                  shard_workers {shard_workers} pin_workers {}",
                 if pin_workers { "on" } else { "off" });
        if shard_workers > 1 {
            println!("shard_busy_s {:.4} shard_idle_s {:.4}",
                     stats.shard_busy_seconds, stats.shard_idle_seconds);
        }
        println!("tokens_generated {}", stats.tokens_generated);
        println!("agg_tokens_per_s {:.2}", stats.tokens_per_second);
        println!("decode_s {:.4}", stats.decode_seconds);
        println!("prefill_s {:.4} ({} tokens, {} chunk passes, \
                  chunk {})",
                 stats.prefill_seconds, stats.prefill_tokens,
                 stats.prefill_chunks, engine.prefill_chunk);
        println!("prefix_cache {} hits {} tokens_saved {}",
                 if prefix_cache { "on" } else { "off" },
                 stats.prefix_hits, stats.prefix_tokens_saved);
        println!("mem {}", crate::util::human_bytes(stats.mem_bytes));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::forward_seq;
    use crate::model::{fake_config, Params};

    fn toy() -> Params {
        Params::init(&fake_config(), 4)
    }

    #[test]
    fn engine_matches_reference_forward() {
        let p = toy();
        let tokens = [1u32, 5, 9, 2, 7];
        let expect = forward_seq(&p, &tokens, None).unwrap();
        for backend in [Backend::Dense, Backend::Csr, Backend::Macko] {
            // sweep the chunk axis through the one forward
            // implementation: logits must match the HLO-path reference
            // for every window size
            for chunk in [1usize, 2, 16] {
                let mut engine = Engine::build(&p, backend).unwrap();
                engine.prefill_chunk = chunk;
                let logits = engine.logits_for(&tokens);
                let last = expect.row(tokens.len() - 1);
                for (a, b) in logits.iter().zip(last.iter()) {
                    assert!((a - b).abs() < 1e-4,
                            "{backend:?} chunk={chunk}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn prefill_chunk_cannot_change_tokens_or_logits() {
        let mut p = toy();
        let alloc = crate::pruners::uniform_alloc(&p.cfg, 0.7);
        p.flat = crate::pruners::magnitude::prune(&p.cfg, &p.flat, &alloc)
            .unwrap();
        let prompt = [1u32, 5, 9, 2, 7, 3];
        for backend in [Backend::Dense, Backend::Csr, Backend::Macko] {
            let mut engine = Engine::build(&p, backend).unwrap();
            engine.prefill_chunk = 1;
            let (want, _) = engine.generate(&prompt, 4, 0.9, 11);
            let want_logits = engine.logits_for(&prompt);
            for chunk in [2usize, 3, 16] {
                engine.prefill_chunk = chunk;
                let (got, _) = engine.generate(&prompt, 4, 0.9, 11);
                assert_eq!(got, want, "{backend:?} chunk={chunk}");
                assert_eq!(engine.logits_for(&prompt), want_logits,
                           "{backend:?} chunk={chunk} logits");
            }
        }
    }

    #[test]
    fn empty_prompt_generate_matches_batch_rule() {
        let p = toy();
        let engine = Engine::build(&p, Backend::Macko).unwrap();
        let (out, stats) = engine.generate(&[], 5, 0.8, 3);
        assert!(out.is_empty(),
                "empty prompt must produce zero tokens, like the batch \
                 path");
        assert_eq!(stats.tokens_generated, 0);
        assert!(engine.logits_for(&[]).is_empty());
    }

    #[test]
    #[should_panic(expected = "exceeds seq_len")]
    fn generate_rejects_oversized_prompt() {
        let p = toy();
        let engine = Engine::build(&p, Backend::Dense).unwrap();
        let long: Vec<u32> = (0..p.cfg.seq_len + 1)
            .map(|i| (i % p.cfg.vocab) as u32)
            .collect();
        engine.generate(&long, 1, 0.0, 0);
    }

    #[test]
    #[should_panic(expected = "exceeds seq_len")]
    fn logits_for_rejects_oversized_prompt() {
        let p = toy();
        let engine = Engine::build(&p, Backend::Dense).unwrap();
        let long: Vec<u32> = (0..p.cfg.seq_len + 1)
            .map(|i| (i % p.cfg.vocab) as u32)
            .collect();
        engine.logits_for(&long);
    }

    #[test]
    fn prefill_projects_the_head_exactly_once_per_request() {
        let p = toy();
        let seq_len = p.cfg.seq_len;
        let engine = Engine::build(&p, Backend::Macko).unwrap();
        let n_new = 3usize;
        // head rows per request = 1 (final prompt position) +
        // (n_new - 1) generation forwards = n_new — independent of
        // prompt length
        for plen in [1usize, 2, 7, seq_len - n_new] {
            let prompt: Vec<u32> =
                (0..plen).map(|i| (i % p.cfg.vocab) as u32).collect();
            let before = engine.head_rows_projected();
            let (_, stats) = engine.generate(&prompt, n_new, 0.7, 5);
            assert_eq!(stats.tokens_generated, n_new);
            assert_eq!(engine.head_rows_projected() - before,
                       n_new as u64,
                       "prompt of {plen} tokens must cost exactly one \
                        head projection beyond the generated tokens");
            assert_eq!(stats.prefill_tokens, plen - 1,
                       "all but the final prompt position are fed \
                        headless");
        }
    }

    #[test]
    fn sparse_backends_agree_on_pruned_model() {
        let mut p = toy();
        // prune 70% by magnitude
        let alloc = crate::pruners::uniform_alloc(&p.cfg, 0.7);
        p.flat = crate::pruners::magnitude::prune(&p.cfg, &p.flat, &alloc)
            .unwrap();
        let prompt = [1u32, 2, 3];
        let (dense_out, _) = Engine::build(&p, Backend::Dense).unwrap()
            .generate(&prompt, 4, 0.0, 0);
        let (csr_out, _) = Engine::build(&p, Backend::Csr).unwrap()
            .generate(&prompt, 4, 0.0, 0);
        let (macko_out, _) = Engine::build(&p, Backend::Macko).unwrap()
            .generate(&prompt, 4, 0.0, 0);
        assert_eq!(dense_out, csr_out);
        assert_eq!(dense_out, macko_out);
    }

    #[test]
    fn quant_requires_sparse_backend_and_reports_mode() {
        let p = toy();
        assert!(Engine::build_quant(&p, Backend::Dense, QuantMode::Int8)
                    .is_err());
        let e =
            Engine::build_quant(&p, Backend::Csr, QuantMode::Int8)
                .unwrap();
        assert_eq!(e.quant, QuantMode::Int8);
        let (out, stats) = e.generate(&[1, 2, 3], 3, 0.0, 0);
        assert_eq!(out.len(), 6);
        assert_eq!(stats.quant_mode, "int8");
        // quantized weights must be strictly smaller than their f32
        // counterpart on the same backend
        let f = Engine::build(&p, Backend::Csr).unwrap();
        assert!(e.mem_bytes() < f.mem_bytes());
        let e4 =
            Engine::build_quant(&p, Backend::Macko, QuantMode::Int4)
                .unwrap();
        let fm = Engine::build(&p, Backend::Macko).unwrap();
        assert!(e4.mem_bytes() < fm.mem_bytes());
        assert_eq!(e4.quant.label(), "int4");
    }

    /// Project every prunable linear of `p` onto a 2:4 pattern
    /// in-place, so the checkpoint passes `NmWeights` verification.
    fn nm24_projected(p: &Params) -> Params {
        let mut q = p.clone();
        for seg in q.cfg.segments.clone() {
            if seg.prunable && seg.is_matrix() {
                let w = Matrix::from_vec(
                    seg.shape[0], seg.shape[1],
                    q.flat[seg.offset..seg.end()].to_vec());
                let proj = crate::sparse::nm_project(&w, 2, 4);
                q.flat[seg.offset..seg.end()]
                    .copy_from_slice(&proj.data);
            }
        }
        q
    }

    #[test]
    fn nm_requires_sparse_backend_and_rejects_bad_combos() {
        let p = toy();
        // dense has no N:M payload
        assert!(Engine::build_nm(&p, Backend::Dense, NmMode::N2M4)
                    .is_err());
        // no quantized N:M payload either
        assert!(Engine::build_full(&p, Backend::Csr, QuantMode::Int8,
                                   NmMode::N2M4)
                    .is_err());
        // an unprojected (dense-ish) checkpoint violates the pattern
        // and must be rejected loudly at build, not at serve time
        let err = Engine::build_nm(&p, Backend::Csr, NmMode::N2M4)
            .unwrap_err();
        assert!(format!("{err:#}").contains("pattern violation"),
                "unexpected error: {err:#}");
        // Off is the identity: behaves exactly like Engine::build
        let off = Engine::build_nm(&p, Backend::Csr, NmMode::Off)
            .unwrap();
        assert_eq!(off.nm, NmMode::Off);
    }

    #[test]
    fn nm_engine_reports_mode_and_matches_projected_reference() {
        let p = nm24_projected(&toy());
        let e = Engine::build_nm(&p, Backend::Macko, NmMode::N2M4)
            .unwrap();
        assert_eq!(e.nm, NmMode::N2M4);
        let (out, stats) = e.generate(&[1, 2, 3], 3, 0.0, 0);
        assert_eq!(out.len(), 6);
        // stats self-describe the structure and the kernel path
        assert_eq!(stats.nm_mode, "2:4");
        assert!(stats.kernel_path == "scalar"
                    || stats.kernel_path == "unrolled");
        // the N:M engine must match an f32 CSR engine built from the
        // same projected checkpoint bit-for-bit (same weights, same
        // accumulation order)
        let f = Engine::build(&p, Backend::Csr).unwrap();
        let (want, _) = f.generate(&[1, 2, 3], 3, 0.0, 0);
        assert_eq!(out, want);
        // fixed 2-of-4 slots beat CSR's 8 B/nnz bookkeeping
        assert!(e.mem_bytes() < f.mem_bytes());
    }

    #[test]
    fn sparse_memory_smaller_after_pruning() {
        let mut p = toy();
        let dense_mem =
            Engine::build(&p, Backend::Macko).unwrap().mem_bytes();
        let alloc = crate::pruners::uniform_alloc(&p.cfg, 0.9);
        p.flat = crate::pruners::magnitude::prune(&p.cfg, &p.flat, &alloc)
            .unwrap();
        let sparse_mem =
            Engine::build(&p, Backend::Macko).unwrap().mem_bytes();
        assert!(sparse_mem < dense_mem);
    }

    #[test]
    fn generate_respects_max_len() {
        let p = toy();
        let engine = Engine::build(&p, Backend::Dense).unwrap();
        let (out, stats) = engine.generate(&[1, 2], 100, 0.5, 1);
        assert!(out.len() <= p.cfg.seq_len);
        assert_eq!(stats.tokens_generated, out.len() - 2);
    }

    #[test]
    fn generate_batch_matches_single_sequence() {
        let p = toy();
        let prompts: Vec<Vec<u32>> =
            vec![vec![1, 2, 3], vec![4, 5], vec![6, 7, 8, 9]];
        for backend in [Backend::Dense, Backend::Csr, Backend::Macko] {
            let engine = Engine::build(&p, backend).unwrap();
            for temp in [0.0f32, 0.9] {
                let opts = BatchOptions {
                    n_new: 4, temperature: temp, seed: 7,
                    ..BatchOptions::default()
                };
                let (outs, stats) =
                    engine.generate_batch(&prompts, &opts);
                let mut total = 0;
                for (s, prompt) in prompts.iter().enumerate() {
                    let (want, _) = engine.generate(
                        prompt, 4, temp, 7 + s as u64);
                    assert_eq!(outs[s], want,
                               "{backend:?} temp={temp} slot {s}");
                    total += want.len() - prompt.len();
                }
                assert_eq!(stats.tokens_generated, total);
            }
        }
    }

    #[test]
    fn generate_batch_single_slot_is_generate() {
        let p = toy();
        let engine = Engine::build(&p, Backend::Macko).unwrap();
        let prompt = vec![2u32, 3, 4];
        let opts = BatchOptions {
            n_new: 5, temperature: 0.7, seed: 11,
            ..BatchOptions::default()
        };
        let (outs, stats) =
            engine.generate_batch(std::slice::from_ref(&prompt), &opts);
        let (want, wstats) = engine.generate(&prompt, 5, 0.7, 11);
        assert_eq!(outs[0], want);
        assert_eq!(stats.tokens_generated, wstats.tokens_generated);
    }
}

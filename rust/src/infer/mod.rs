//! Sparse inference engine: KV-cached autoregressive generation over
//! dense / CSR / MACKO weight backends (the Table-1 deployment benchmark).
//!
//! The decode phase is one matvec per linear per token — exactly the
//! memory-bound SpMV regime the paper's §5.3 targets. The engine shares
//! numerics with model::forward (tested), so a pruned checkpoint can be
//! loaded, converted, and served without touching the HLO path.
//!
//! Three serving modes:
//!  - [`Engine::generate`]: one sequence, one matvec per linear per
//!    token (the original microbenchmark path),
//!  - [`Engine::generate_batch`]: many sequences with per-slot KV
//!    caches and slot retirement; each step runs the linears as one
//!    multi-vector SpMM over the live slots (amortizing index/bitmap
//!    decode across the batch, and — with [`Engine::tiled`], the
//!    default — walking each cache-sized weight tile once per step),
//!    finishes with a single batched head projection regardless of
//!    slot count, and shards slots across worker threads
//!    (`--threads N`). Each worker can additionally fan every layer's
//!    linears out across the row-band lanes of a persistent
//!    [`pool::WorkerPool`] (`--shard-workers M` — slot × band
//!    parallelism). Batched results are bit-identical to the
//!    single-sequence path per slot, for any thread count, any
//!    shard-worker count, and either kernel traversal.
//!  - [`scheduler`]: the continuous-batching layer (`elsa serve`) — a
//!    request queue with mid-decode slot admission and pooled KV
//!    caches. `generate_batch` is a thin fixed-admission wrapper over
//!    it.

pub mod pool;
pub mod scheduler;

use anyhow::Result;

use crate::cli::Args;
use crate::model::forward::gelu_tanh;
use crate::model::Params;
use crate::runtime::ConfigEntry;
use crate::sparse::{tile, Csr, Macko, SpmmScratch, TilePlan};
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

use pool::WorkerPool;

/// Weight storage backend for one linear layer. Every variant carries
/// a row-tiled execution plan built once at conversion time (the
/// sparse formats embed theirs; dense pairs the matrix with a
/// column-tile plan).
pub enum WeightFmt {
    Dense(Matrix, TilePlan),
    Csr(Csr),
    Macko(Macko),
}

impl WeightFmt {
    pub fn build(w: Matrix, kind: Backend) -> WeightFmt {
        match kind {
            Backend::Dense => {
                let plan = tile::dense_plan(&w);
                WeightFmt::Dense(w, plan)
            }
            Backend::Csr => WeightFmt::Csr(Csr::from_weight(&w)),
            Backend::Macko => WeightFmt::Macko(Macko::from_weight(&w)),
        }
    }

    /// y = W^T x (x: din, y: dout).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        match self {
            WeightFmt::Dense(w, _) => {
                let t = w.t_matvec(x);
                y.copy_from_slice(&t);
            }
            WeightFmt::Csr(c) => c.matvec(x, y),
            WeightFmt::Macko(m) => m.matvec(x, y),
        }
    }

    /// Y = X W for a row-major batch X (b, din), writing Y (b, dout).
    /// The sparse formats decode their indices/bitmaps once per output
    /// row and amortize across the batch; every row is bit-exact with
    /// [`WeightFmt::matvec`] on that row alone. `scratch` is reused
    /// across calls so the decode loop stays allocation-free.
    pub fn matvec_batch(&self, x: &[f32], y: &mut [f32], b: usize,
                        scratch: &mut SpmmScratch) {
        match self {
            WeightFmt::Dense(w, _) => {
                crate::sparse::dense_matvec_batch(w, x, y, b)
            }
            WeightFmt::Csr(c) => c.matvec_batch_into(x, y, b, scratch),
            WeightFmt::Macko(m) => m.matvec_batch_into(x, y, b, scratch),
        }
    }

    /// Tiled variant of [`WeightFmt::matvec_batch`]: the kernel walks
    /// the format's construction-time row-tile plan, so each
    /// cache-sized weight tile is streamed once per step and applied
    /// across every live slot. Bit-identical to the untiled path for
    /// every format and batch size (see [`crate::sparse::tile`]).
    pub fn matvec_batch_tiled(&self, x: &[f32], y: &mut [f32], b: usize,
                              scratch: &mut SpmmScratch) {
        match self {
            WeightFmt::Dense(w, plan) => {
                if b == 1 {
                    // same batch-1 delegation as the sparse formats:
                    // both traversals are the identical matvec
                    let t = w.t_matvec(x);
                    y.copy_from_slice(&t);
                    return;
                }
                tile::matvec_batch_tiled(w, plan, x, y, b, scratch)
            }
            WeightFmt::Csr(c) => {
                c.matvec_batch_tiled_into(x, y, b, scratch)
            }
            WeightFmt::Macko(m) => {
                m.matvec_batch_tiled_into(x, y, b, scratch)
            }
        }
    }

    /// Dispatch for the engine's decode loop. With a multi-lane `pool`
    /// (`--shard-workers > 1`) the layer's tile plan is split into
    /// byte-balanced row-band shards and executed on the pool's
    /// persistent workers ([`tile::pool_matvec_batch_tiled`]); the
    /// [`Engine::tiled`] toggle then only selects the serial traversal
    /// used when the pool is single-lane. Every path produces
    /// bit-identical output, so neither knob can change a token.
    pub fn matvec_batch_exec(&self, x: &[f32], y: &mut [f32], b: usize,
                             scratch: &mut SpmmScratch, tiled: bool,
                             pool: &WorkerPool) {
        if pool.width() > 1 {
            match self {
                WeightFmt::Dense(w, plan) => tile::pool_matvec_batch_tiled(
                    w, plan, x, y, b, pool, scratch),
                WeightFmt::Csr(c) => tile::pool_matvec_batch_tiled(
                    c, &c.plan, x, y, b, pool, scratch),
                WeightFmt::Macko(m) => tile::pool_matvec_batch_tiled(
                    m, &m.plan, x, y, b, pool, scratch),
            }
        } else if tiled {
            self.matvec_batch_tiled(x, y, b, scratch);
        } else {
            self.matvec_batch(x, y, b, scratch);
        }
    }

    /// Rebuild this weight's tile plan with an explicit byte budget
    /// and row cap — see [`Engine::retile`].
    pub fn retile(&mut self, target_bytes: usize, max_rows: usize) {
        match self {
            WeightFmt::Dense(w, plan) => {
                *plan = TilePlan::with_budget(w.cols, |_| w.rows * 4,
                                              target_bytes, max_rows);
            }
            WeightFmt::Csr(c) => c.retile(target_bytes, max_rows),
            WeightFmt::Macko(m) => m.retile(target_bytes, max_rows),
        }
    }

    pub fn mem_bytes(&self) -> usize {
        match self {
            WeightFmt::Dense(w, _) => w.data.len() * 4,
            WeightFmt::Csr(c) => c.mem_bytes(),
            WeightFmt::Macko(m) => m.mem_bytes(),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Dense,
    Csr,
    Macko,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        Some(match s {
            "dense" => Backend::Dense,
            "csr" => Backend::Csr,
            "macko" => Backend::Macko,
            _ => return None,
        })
    }
}

struct Layer {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    wq: WeightFmt,
    wk: WeightFmt,
    wv: WeightFmt,
    wo: WeightFmt,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    w1: WeightFmt,
    b1: Vec<f32>,
    w2: WeightFmt,
    b2: Vec<f32>,
}

/// KV cache for one layer (grows up to seq_len).
struct Kv {
    k: Vec<f32>, // t * d
    v: Vec<f32>,
    len: usize,
}

/// Causal multi-head attention for one sequence over its KV cache:
/// reads the query vector `q` (len d), accumulates the weighted values
/// into `o` (len d, caller-zeroed), using `probs` as softmax scratch.
/// The single numerics implementation shared by the single-sequence
/// and batched decode paths — keeping them bit-identical by
/// construction.
fn attend_cached(kv: &Kv, q: &[f32], o: &mut [f32], probs: &mut [f32],
                 h: usize, dh: usize, scale: f32, d: usize) {
    for hh in 0..h {
        let c0 = hh * dh;
        let qh = &q[c0..c0 + dh];
        let pr = &mut probs[..kv.len];
        let mut max = f32::NEG_INFINITY;
        for (j, p) in pr.iter_mut().enumerate() {
            let krow = &kv.k[j * d + c0..j * d + c0 + dh];
            let mut acc = 0.0f32;
            for i in 0..dh {
                acc += qh[i] * krow[i];
            }
            *p = acc * scale;
            max = max.max(*p);
        }
        let mut sum = 0.0f32;
        for p in pr.iter_mut() {
            *p = (*p - max).exp();
            sum += *p;
        }
        let inv = 1.0 / sum;
        for (j, p) in pr.iter().enumerate() {
            let w = p * inv;
            let vrow = &kv.v[j * d + c0..j * d + c0 + dh];
            let orow = &mut o[c0..c0 + dh];
            for i in 0..dh {
                orow[i] += w * vrow[i];
            }
        }
    }
}

pub struct Engine {
    pub cfg: ConfigEntry,
    embed: Matrix,
    pos: Matrix,
    layers: Vec<Layer>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
    head: Matrix, // non-prunable, always dense
    pub backend: Backend,
    /// Batched decode runs the row-tiled kernels (default). The tiled
    /// and untiled paths are bit-identical, so flipping this only
    /// changes the traversal — `rust/tests/kernels.rs` asserts token
    /// streams match either way.
    pub tiled: bool,
}

impl Engine {
    /// Convert params: prunable matrices go to `backend` storage.
    pub fn build(params: &Params, backend: Backend) -> Result<Engine> {
        let cfg = params.cfg.clone();
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let p = format!("l{l}.");
            let get = |n: &str| params.matrix(&(p.clone() + n));
            let vec = |n: &str| -> Result<Vec<f32>> {
                Ok(params.vector(&(p.clone() + n))?.to_vec())
            };
            layers.push(Layer {
                ln1_g: vec("ln1.g")?,
                ln1_b: vec("ln1.b")?,
                wq: WeightFmt::build(get("attn.wq")?, backend),
                wk: WeightFmt::build(get("attn.wk")?, backend),
                wv: WeightFmt::build(get("attn.wv")?, backend),
                wo: WeightFmt::build(get("attn.wo")?, backend),
                ln2_g: vec("ln2.g")?,
                ln2_b: vec("ln2.b")?,
                w1: WeightFmt::build(get("mlp.w1")?, backend),
                b1: vec("mlp.b1")?,
                w2: WeightFmt::build(get("mlp.w2")?, backend),
                b2: vec("mlp.b2")?,
            });
        }
        Ok(Engine {
            embed: params.matrix("embed")?,
            pos: params.matrix("pos")?,
            layers,
            lnf_g: params.vector("lnf.g")?.to_vec(),
            lnf_b: params.vector("lnf.b")?.to_vec(),
            head: params.matrix("head")?,
            cfg,
            backend,
            tiled: true,
        })
    }

    /// Rebuild every layer's tile plan with an explicit byte budget
    /// and row cap ([`TilePlan::with_budget`]). The default plans
    /// target half an L1d; deployments with different cache geometry —
    /// and toy-sized test models whose whole layer fits one default
    /// tile — use this to pick the shard granularity the
    /// `--shard-workers` pool splits over. Plans are traversal
    /// metadata only: any geometry produces bit-identical tokens.
    pub fn retile(&mut self, target_bytes: usize, max_rows: usize) {
        for l in &mut self.layers {
            l.wq.retile(target_bytes, max_rows);
            l.wk.retile(target_bytes, max_rows);
            l.wv.retile(target_bytes, max_rows);
            l.wo.retile(target_bytes, max_rows);
            l.w1.retile(target_bytes, max_rows);
            l.w2.retile(target_bytes, max_rows);
        }
    }

    /// Total weight storage (the Table-1 "Memory" column).
    pub fn mem_bytes(&self) -> usize {
        let mut total = (self.embed.data.len() + self.pos.data.len()
                         + self.head.data.len()) * 4;
        for l in &self.layers {
            total += l.wq.mem_bytes() + l.wk.mem_bytes() + l.wv.mem_bytes()
                + l.wo.mem_bytes() + l.w1.mem_bytes() + l.w2.mem_bytes();
            total += (l.ln1_g.len() + l.ln1_b.len() + l.ln2_g.len()
                      + l.ln2_b.len() + l.b1.len() + l.b2.len()) * 4;
        }
        total + (self.lnf_g.len() + self.lnf_b.len()) * 4
    }

    fn layernorm_vec(x: &[f32], g: &[f32], b: &[f32], out: &mut [f32]) {
        let n = x.len() as f32;
        let mean = x.iter().sum::<f32>() / n;
        let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for i in 0..x.len() {
            out[i] = (x[i] - mean) * inv * g[i] + b[i];
        }
    }

    /// One decode step: append `token` at position `t`, return logits.
    fn decode_step(&self, kvs: &mut [Kv], token: u32, t: usize,
                   scratch: &mut Scratch) -> Vec<f32> {
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = d / h;
        let scale = 1.0 / (dh as f32).sqrt();

        let e = self.embed.row(token as usize);
        let pr = self.pos.row(t.min(self.pos.rows - 1));
        let x = &mut scratch.x;
        for c in 0..d {
            x[c] = e[c] + pr[c];
        }

        for (l, kv) in self.layers.iter().zip(kvs.iter_mut()) {
            Self::layernorm_vec(x, &l.ln1_g, &l.ln1_b, &mut scratch.xa);
            l.wq.matvec(&scratch.xa, &mut scratch.q);
            l.wk.matvec(&scratch.xa, &mut scratch.k);
            l.wv.matvec(&scratch.xa, &mut scratch.v);
            kv.k.extend_from_slice(&scratch.k);
            kv.v.extend_from_slice(&scratch.v);
            kv.len += 1;

            // attention over the cache, per head
            let o = &mut scratch.o;
            o.iter_mut().for_each(|v| *v = 0.0);
            attend_cached(kv, &scratch.q, o, &mut scratch.probs,
                          h, dh, scale, d);
            l.wo.matvec(o, &mut scratch.tmp_d);
            for c in 0..d {
                x[c] += scratch.tmp_d[c];
            }

            Self::layernorm_vec(x, &l.ln2_g, &l.ln2_b, &mut scratch.xa);
            l.w1.matvec(&scratch.xa, &mut scratch.ff);
            for (f, b) in scratch.ff.iter_mut().zip(l.b1.iter()) {
                *f = gelu_tanh(*f + b);
            }
            l.w2.matvec(&scratch.ff, &mut scratch.tmp_d);
            for c in 0..d {
                x[c] += scratch.tmp_d[c] + l.b2[c];
            }
        }

        Self::layernorm_vec(x, &self.lnf_g, &self.lnf_b, &mut scratch.xa);
        self.head.t_matvec(&scratch.xa)
    }

    /// Greedy/temperature generation. Returns (tokens, decode stats).
    pub fn generate(&self, prompt: &[u32], n_new: usize, temperature: f32,
                    seed: u64) -> (Vec<u32>, GenStats) {
        let d = self.cfg.d_model;
        let max_t = self.cfg.seq_len;
        let mut kvs: Vec<Kv> = (0..self.cfg.n_layers)
            .map(|_| Kv { k: Vec::with_capacity(max_t * d),
                          v: Vec::with_capacity(max_t * d), len: 0 })
            .collect();
        let mut scratch = Scratch::new(&self.cfg);
        let mut rng = Rng::new(seed);
        let mut out = prompt.to_vec();

        // prefill (timed separately)
        let tp = Timer::start();
        let mut logits = vec![];
        for (t, &tok) in prompt.iter().enumerate() {
            logits = self.decode_step(&mut kvs, tok, t, &mut scratch);
        }
        let prefill_s = tp.seconds();

        let td = Timer::start();
        for i in 0..n_new {
            let t = prompt.len() + i;
            if t >= max_t {
                break;
            }
            let next = sample(&logits, temperature, &mut rng);
            out.push(next);
            logits = self.decode_step(&mut kvs, next, t, &mut scratch);
        }
        let decode_s = td.seconds();
        let generated = out.len() - prompt.len();
        (out, GenStats {
            prefill_seconds: prefill_s,
            decode_seconds: decode_s,
            tokens_generated: generated,
            tokens_per_second: generated as f64 / decode_s.max(1e-9),
            mem_bytes: self.mem_bytes(),
            shard_busy_seconds: 0.0,
            shard_idle_seconds: 0.0,
        })
    }

    /// Feed `tokens` through a fresh KV cache and return the logits
    /// after the last token (test/debug helper for the parity suite).
    pub fn logits_for(&self, tokens: &[u32]) -> Vec<f32> {
        let d = self.cfg.d_model;
        let mut kvs: Vec<Kv> = (0..self.cfg.n_layers)
            .map(|_| Kv { k: Vec::with_capacity(tokens.len() * d),
                          v: Vec::with_capacity(tokens.len() * d), len: 0 })
            .collect();
        let mut scratch = Scratch::new(&self.cfg);
        let mut logits = vec![];
        for (t, &tok) in tokens.iter().enumerate() {
            logits = self.decode_step(&mut kvs, tok, t, &mut scratch);
        }
        logits
    }

    /// Batched generation over many prompts with per-slot KV caches and
    /// slot retirement: a thin wrapper over the continuous-batching
    /// [`scheduler`] with *fixed admission* — every prompt becomes a
    /// request arriving at step 0 with `max_slots == prompts.len()`, so
    /// the whole batch is admitted up front (the pre-scheduler
    /// behavior). A slot retires as soon as it has produced `n_new`
    /// tokens or its sequence hits `seq_len`.
    ///
    /// Determinism: a slot `s` with a non-empty prompt reproduces
    /// `generate(&prompts[s], n_new, temperature, seed + s)`
    /// bit-for-bit, for any batch size and any `threads` /
    /// `shard_workers` value — the batched kernels keep each sequence's
    /// accumulation order identical to the single-vector path (pooled
    /// row-band shards are disjoint, so lane count cannot reorder an
    /// accumulation), and each slot samples from its own seeded RNG.
    ///
    /// Prompts may be ragged. The one deliberate divergence from the
    /// single-sequence path is the degenerate empty prompt: a slot with
    /// no prompt retires immediately with zero tokens (there is nothing
    /// to condition on), whereas `generate(&[], ..)` falls back to
    /// emitting token 0 and continuing from it.
    pub fn generate_batch(&self, prompts: &[Vec<u32>], opts: &BatchOptions)
                          -> (Vec<Vec<u32>>, GenStats) {
        for p in prompts {
            assert!(p.len() <= self.cfg.seq_len,
                    "prompt of {} tokens exceeds seq_len {}", p.len(),
                    self.cfg.seq_len);
        }
        let mut queue = scheduler::RequestQueue::new();
        for (s, p) in prompts.iter().enumerate() {
            queue.push(scheduler::Request {
                id: s as u64,
                prompt: p.clone(),
                n_new: opts.n_new,
                seed: opts.seed.wrapping_add(s as u64),
                deadline: None,
            });
        }
        let sched = scheduler::Scheduler::new(self, scheduler::SchedOptions {
            max_slots: prompts.len().max(1),
            temperature: opts.temperature,
            threads: opts.threads,
            shard_workers: opts.shard_workers,
        });
        // run() returns finished requests sorted by id == slot index
        let (finished, st) = sched.run(queue);
        let outs: Vec<Vec<u32>> =
            finished.into_iter().map(|f| f.tokens).collect();
        (outs, GenStats {
            prefill_seconds: st.prefill_seconds,
            decode_seconds: st.decode_seconds,
            tokens_generated: st.tokens_generated,
            tokens_per_second: st.tokens_generated as f64
                / st.decode_seconds.max(1e-9),
            mem_bytes: self.mem_bytes(),
            shard_busy_seconds: st.shard_busy_seconds.iter().sum(),
            shard_idle_seconds: st.shard_idle_seconds.iter().sum(),
        })
    }

    /// One batched decode step: for every slot index in `active`, feed
    /// that slot's next unfed token through all layers, appending to its
    /// KV cache and refreshing its logits. The linears run as one
    /// multi-vector SpMM over the active set — dispatched to `pool`'s
    /// persistent row-band workers when it has more than one lane
    /// (`--shard-workers`), so a step is parallel *within* each layer
    /// on top of the scheduler's slot sharding; attention and layernorm
    /// stay per-slot (each slot has its own cache length/position).
    fn decode_step_batch(&self, slots: &mut [Slot], active: &[usize],
                         scratch: &mut BatchScratch, pool: &WorkerPool) {
        let b = active.len();
        let d = self.cfg.d_model;
        let dff = self.cfg.d_ff;
        let h = self.cfg.n_heads;
        let dh = d / h;
        let scale = 1.0 / (dh as f32).sqrt();

        // embed + positional for each slot's next token
        for (bi, &si) in active.iter().enumerate() {
            let s = &slots[si];
            let t = s.fed;
            let e = self.embed.row(s.tokens[t] as usize);
            let pr = self.pos.row(t.min(self.pos.rows - 1));
            let xrow = &mut scratch.x[bi * d..(bi + 1) * d];
            for c in 0..d {
                xrow[c] = e[c] + pr[c];
            }
        }

        for (li, l) in self.layers.iter().enumerate() {
            for bi in 0..b {
                Self::layernorm_vec(&scratch.x[bi * d..(bi + 1) * d],
                                    &l.ln1_g, &l.ln1_b,
                                    &mut scratch.xa[bi * d..(bi + 1) * d]);
            }
            l.wq.matvec_batch_exec(&scratch.xa[..b * d],
                                   &mut scratch.q[..b * d], b,
                                   &mut scratch.spmm, self.tiled, pool);
            l.wk.matvec_batch_exec(&scratch.xa[..b * d],
                                   &mut scratch.k[..b * d], b,
                                   &mut scratch.spmm, self.tiled, pool);
            l.wv.matvec_batch_exec(&scratch.xa[..b * d],
                                   &mut scratch.v[..b * d], b,
                                   &mut scratch.spmm, self.tiled, pool);

            // per-slot attention over each slot's own cache
            for (bi, &si) in active.iter().enumerate() {
                let kv = &mut slots[si].kvs[li];
                kv.k.extend_from_slice(&scratch.k[bi * d..(bi + 1) * d]);
                kv.v.extend_from_slice(&scratch.v[bi * d..(bi + 1) * d]);
                kv.len += 1;

                let orow = &mut scratch.o[bi * d..(bi + 1) * d];
                orow.iter_mut().for_each(|v| *v = 0.0);
                attend_cached(kv, &scratch.q[bi * d..(bi + 1) * d],
                              orow, &mut scratch.probs, h, dh, scale, d);
            }
            l.wo.matvec_batch_exec(&scratch.o[..b * d],
                                   &mut scratch.tmp_d[..b * d], b,
                                   &mut scratch.spmm, self.tiled, pool);
            for i in 0..b * d {
                scratch.x[i] += scratch.tmp_d[i];
            }

            for bi in 0..b {
                Self::layernorm_vec(&scratch.x[bi * d..(bi + 1) * d],
                                    &l.ln2_g, &l.ln2_b,
                                    &mut scratch.xa[bi * d..(bi + 1) * d]);
            }
            l.w1.matvec_batch_exec(&scratch.xa[..b * d],
                                   &mut scratch.ff[..b * dff], b,
                                   &mut scratch.spmm, self.tiled, pool);
            for bi in 0..b {
                let frow = &mut scratch.ff[bi * dff..(bi + 1) * dff];
                for (f, bias) in frow.iter_mut().zip(l.b1.iter()) {
                    *f = gelu_tanh(*f + bias);
                }
            }
            l.w2.matvec_batch_exec(&scratch.ff[..b * dff],
                                   &mut scratch.tmp_d[..b * d], b,
                                   &mut scratch.spmm, self.tiled, pool);
            for bi in 0..b {
                for c in 0..d {
                    scratch.x[bi * d + c] +=
                        scratch.tmp_d[bi * d + c] + l.b2[c];
                }
            }
        }

        // final layernorm per slot, then ONE batched head projection
        // over the packed activations: the head matrix is streamed
        // once per step via `t_matmat` regardless of how many slots
        // are live (it used to be one `t_matvec` per slot per step).
        // Row bi of the batched GEMM is bit-identical to
        // `t_matvec(xa_bi)`, so every slot's logits are unchanged.
        for bi in 0..b {
            Self::layernorm_vec(&scratch.x[bi * d..(bi + 1) * d],
                                &self.lnf_g, &self.lnf_b,
                                &mut scratch.xa[bi * d..(bi + 1) * d]);
        }
        let vocab = self.head.cols;
        self.head.t_matmat(&scratch.xa[..b * d],
                           &mut scratch.logits[..b * vocab], b);
        for (bi, &si) in active.iter().enumerate() {
            let s = &mut slots[si];
            s.logits.resize(vocab, 0.0);
            s.logits.copy_from_slice(
                &scratch.logits[bi * vocab..(bi + 1) * vocab]);
            s.fed += 1;
        }
    }
}

/// Options for [`Engine::generate_batch`].
#[derive(Debug, Clone)]
pub struct BatchOptions {
    /// New tokens to generate per slot (capped by `seq_len`).
    pub n_new: usize,
    /// Sampling temperature (0 = greedy).
    pub temperature: f32,
    /// Slot `s` samples from `Rng::new(seed + s)`, matching a
    /// single-sequence `generate` call with seed `seed + s`.
    pub seed: u64,
    /// Scheduler worker threads (batch capacity is split across them;
    /// 0/1 = inline).
    pub threads: usize,
    /// Row-band shard workers *per scheduler worker*: each worker owns
    /// a persistent [`pool::WorkerPool`] of this many lanes and fans
    /// every layer's linears out across byte-balanced tile shards
    /// (0/1 = serial decode, no pool threads spawned). Composes with
    /// `threads` — slots × bands — and never changes a token.
    pub shard_workers: usize,
}

impl Default for BatchOptions {
    fn default() -> BatchOptions {
        BatchOptions {
            n_new: 16,
            temperature: 0.0,
            seed: 0,
            threads: 1,
            shard_workers: 1,
        }
    }
}

/// One in-flight sequence of the batched engine. Created by the
/// [`scheduler`] at admission time, with KV buffers drawn from its
/// [`scheduler::KvPool`]; retirement hands the buffers back.
struct Slot {
    tokens: Vec<u32>,
    prompt_len: usize,
    /// Tokens already decoded into the KV cache.
    fed: usize,
    kvs: Vec<Kv>,
    rng: Rng,
    logits: Vec<f32>,
    generated: usize,
    /// This request's token budget (the slot retires once reached).
    n_new: usize,
}

struct Scratch {
    x: Vec<f32>,
    xa: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    o: Vec<f32>,
    ff: Vec<f32>,
    tmp_d: Vec<f32>,
    probs: Vec<f32>,
}

impl Scratch {
    fn new(cfg: &ConfigEntry) -> Scratch {
        let d = cfg.d_model;
        Scratch {
            x: vec![0.0; d],
            xa: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            o: vec![0.0; d],
            ff: vec![0.0; cfg.d_ff],
            tmp_d: vec![0.0; d],
            probs: vec![0.0; cfg.seq_len],
        }
    }
}

/// Scratch for the batched decode path: row-major (b, ·) activation
/// buffers sized for the shard's slot count; steps with fewer active
/// slots use prefixes of each buffer.
struct BatchScratch {
    x: Vec<f32>,
    xa: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    o: Vec<f32>,
    ff: Vec<f32>,
    tmp_d: Vec<f32>,
    probs: Vec<f32>,
    /// Staging for the step's single batched head projection
    /// ((b, vocab), written by `t_matmat`, copied out per slot).
    logits: Vec<f32>,
    /// Kernel-side scratch shared by every matvec_batch of the step.
    spmm: SpmmScratch,
}

impl BatchScratch {
    fn new(cfg: &ConfigEntry, b: usize) -> BatchScratch {
        let d = cfg.d_model;
        BatchScratch {
            x: vec![0.0; b * d],
            xa: vec![0.0; b * d],
            q: vec![0.0; b * d],
            k: vec![0.0; b * d],
            v: vec![0.0; b * d],
            o: vec![0.0; b * d],
            ff: vec![0.0; b * cfg.d_ff],
            tmp_d: vec![0.0; b * d],
            probs: vec![0.0; cfg.seq_len],
            logits: vec![0.0; b * cfg.vocab],
            spmm: SpmmScratch::default(),
        }
    }
}

fn sample(logits: &[f32], temperature: f32, rng: &mut Rng) -> u32 {
    if logits.is_empty() {
        return 0;
    }
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as u32)
            .unwrap_or(0);
    }
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f32> =
        logits.iter().map(|&l| ((l - max) / temperature).exp()).collect();
    rng.categorical(&weights) as u32
}

#[derive(Debug, Clone)]
pub struct GenStats {
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
    pub tokens_generated: usize,
    pub tokens_per_second: f64,
    pub mem_bytes: usize,
    /// Seconds the decode pool's shard lanes spent executing row-band
    /// jobs, summed over lanes and scheduler workers (0 when
    /// `shard_workers <= 1` — the pool is never dispatched).
    pub shard_busy_seconds: f64,
    /// Seconds shard lanes sat idle while a dispatch was in flight —
    /// the plan-imbalance signal (0 without a multi-lane pool).
    pub shard_idle_seconds: f64,
}

/// `elsa generate` / `elsa infer` subcommand. `--batch N` serves N
/// prompts through the batched engine; `--threads N` shards the batch
/// across worker threads; `--shard-workers M` additionally shards each
/// layer's linears across M persistent row-band workers per thread;
/// `--untiled` falls back to the untiled SpMM kernels (bit-identical
/// output, for perf comparisons).
pub fn cmd_generate(args: &Args) -> Result<()> {
    let rt = crate::commands::open_runtime(args)?;
    let ck = crate::model::checkpoint::Checkpoint::load(
        &std::path::PathBuf::from(args.require("ckpt")?))?;
    let cfg = rt.manifest.config(&ck.config)?.clone();
    let params = Params::new(&cfg, ck.get("params")?.clone());
    let backend = Backend::parse(&args.str_or("backend", "macko"))
        .ok_or_else(|| anyhow::anyhow!("bad --backend"))?;
    let mut engine = Engine::build(&params, backend)?;
    engine.tiled = !args.bool("untiled");

    let g = crate::data::Grammar::named(
        &args.str_or("dataset", "synth-c4"), cfg.vocab);
    let prompt_len = args.usize_or("prompt-len", 8)?;
    let n_new = args.usize_or("tokens", cfg.seq_len - prompt_len)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let temperature = args.f32_or("temp", 0.8)?;
    let batch = args.usize_or("batch", 1)?;
    let threads = args.usize_or("threads", 1)?;
    let shard_workers = args.usize_or("shard-workers", 1)?;

    if batch <= 1 {
        let prompt = g.generate(prompt_len, seed);
        // sample with `seed` so --batch 1 and slot 0 of --batch N are
        // the same request
        let (tokens, stats) =
            engine.generate(&prompt, n_new, temperature, seed);
        println!("prompt  {:?}", &tokens[..prompt_len]);
        println!("output  {:?}", &tokens[prompt_len..]);
        println!("sparsity {:.4}", params.sparsity());
        println!("backend {:?}", backend);
        println!("tokens_per_s {:.2}", stats.tokens_per_second);
        println!("decode_s {:.4}", stats.decode_seconds);
        println!("mem {}", crate::util::human_bytes(stats.mem_bytes));
    } else {
        let prompts: Vec<Vec<u32>> = (0..batch)
            .map(|r| g.generate(prompt_len, seed.wrapping_add(r as u64)))
            .collect();
        let opts = BatchOptions {
            n_new, temperature, seed, threads, shard_workers,
        };
        let (outs, stats) = engine.generate_batch(&prompts, &opts);
        for (s, out) in outs.iter().enumerate() {
            println!("slot {s:3}: prompt {:?} -> {} new tokens",
                     &out[..prompt_len.min(out.len())],
                     out.len() - prompt_len.min(out.len()));
        }
        println!("sparsity {:.4}", params.sparsity());
        println!("backend {:?}", backend);
        println!("batch {batch} threads {threads} \
                  shard_workers {shard_workers}");
        if shard_workers > 1 {
            println!("shard_busy_s {:.4} shard_idle_s {:.4}",
                     stats.shard_busy_seconds, stats.shard_idle_seconds);
        }
        println!("tokens_generated {}", stats.tokens_generated);
        println!("agg_tokens_per_s {:.2}", stats.tokens_per_second);
        println!("decode_s {:.4}", stats.decode_seconds);
        println!("mem {}", crate::util::human_bytes(stats.mem_bytes));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::forward_seq;
    use crate::model::{fake_config, Params};

    fn toy() -> Params {
        Params::init(&fake_config(), 4)
    }

    #[test]
    fn engine_matches_reference_forward() {
        let p = toy();
        let tokens = [1u32, 5, 9, 2, 7];
        let expect = forward_seq(&p, &tokens, None).unwrap();
        for backend in [Backend::Dense, Backend::Csr, Backend::Macko] {
            let engine = Engine::build(&p, backend).unwrap();
            let mut kvs: Vec<Kv> = (0..p.cfg.n_layers)
                .map(|_| Kv { k: vec![], v: vec![], len: 0 })
                .collect();
            let mut scratch = Scratch::new(&p.cfg);
            let mut logits = vec![];
            for (t, &tok) in tokens.iter().enumerate() {
                logits = engine.decode_step(&mut kvs, tok, t, &mut scratch);
            }
            let last = expect.row(tokens.len() - 1);
            for (a, b) in logits.iter().zip(last.iter()) {
                assert!((a - b).abs() < 1e-4,
                        "{backend:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn sparse_backends_agree_on_pruned_model() {
        let mut p = toy();
        // prune 70% by magnitude
        let alloc = crate::pruners::uniform_alloc(&p.cfg, 0.7);
        p.flat = crate::pruners::magnitude::prune(&p.cfg, &p.flat, &alloc)
            .unwrap();
        let prompt = [1u32, 2, 3];
        let (dense_out, _) = Engine::build(&p, Backend::Dense).unwrap()
            .generate(&prompt, 4, 0.0, 0);
        let (csr_out, _) = Engine::build(&p, Backend::Csr).unwrap()
            .generate(&prompt, 4, 0.0, 0);
        let (macko_out, _) = Engine::build(&p, Backend::Macko).unwrap()
            .generate(&prompt, 4, 0.0, 0);
        assert_eq!(dense_out, csr_out);
        assert_eq!(dense_out, macko_out);
    }

    #[test]
    fn sparse_memory_smaller_after_pruning() {
        let mut p = toy();
        let dense_mem =
            Engine::build(&p, Backend::Macko).unwrap().mem_bytes();
        let alloc = crate::pruners::uniform_alloc(&p.cfg, 0.9);
        p.flat = crate::pruners::magnitude::prune(&p.cfg, &p.flat, &alloc)
            .unwrap();
        let sparse_mem =
            Engine::build(&p, Backend::Macko).unwrap().mem_bytes();
        assert!(sparse_mem < dense_mem);
    }

    #[test]
    fn generate_respects_max_len() {
        let p = toy();
        let engine = Engine::build(&p, Backend::Dense).unwrap();
        let (out, stats) = engine.generate(&[1, 2], 100, 0.5, 1);
        assert!(out.len() <= p.cfg.seq_len);
        assert_eq!(stats.tokens_generated, out.len() - 2);
    }

    #[test]
    fn generate_batch_matches_single_sequence() {
        let p = toy();
        let prompts: Vec<Vec<u32>> =
            vec![vec![1, 2, 3], vec![4, 5], vec![6, 7, 8, 9]];
        for backend in [Backend::Dense, Backend::Csr, Backend::Macko] {
            let engine = Engine::build(&p, backend).unwrap();
            for temp in [0.0f32, 0.9] {
                let opts = BatchOptions {
                    n_new: 4, temperature: temp, seed: 7,
                    ..BatchOptions::default()
                };
                let (outs, stats) =
                    engine.generate_batch(&prompts, &opts);
                let mut total = 0;
                for (s, prompt) in prompts.iter().enumerate() {
                    let (want, _) = engine.generate(
                        prompt, 4, temp, 7 + s as u64);
                    assert_eq!(outs[s], want,
                               "{backend:?} temp={temp} slot {s}");
                    total += want.len() - prompt.len();
                }
                assert_eq!(stats.tokens_generated, total);
            }
        }
    }

    #[test]
    fn generate_batch_single_slot_is_generate() {
        let p = toy();
        let engine = Engine::build(&p, Backend::Macko).unwrap();
        let prompt = vec![2u32, 3, 4];
        let opts = BatchOptions {
            n_new: 5, temperature: 0.7, seed: 11,
            ..BatchOptions::default()
        };
        let (outs, stats) =
            engine.generate_batch(std::slice::from_ref(&prompt), &opts);
        let (want, wstats) = engine.generate(&prompt, 5, 0.7, 11);
        assert_eq!(outs[0], want);
        assert_eq!(stats.tokens_generated, wstats.tokens_generated);
    }
}

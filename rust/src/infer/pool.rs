//! Persistent decode worker pool (ISSUE 4 tentpole).
//!
//! [`par_matvec_batch_tiled`](crate::sparse::par_matvec_batch_tiled)
//! proved that one layer's tile plan can be sharded across threads with
//! bit-identical output — but it pays a `thread::scope` spawn/join per
//! call, which is ruinous at decode granularity (a decode step runs six
//! linears per layer, each a few microseconds of kernel work). This
//! module provides the serving-grade version: a [`WorkerPool`] of
//! long-lived workers that park between dispatches, so
//! `decode_step_batch` can fan every linear's row-band shards out to
//! the same threads step after step with **zero spawns in steady
//! state**. The same lanes also run the dense head projection as
//! per-lane output-column bands (`tile::pool_t_matmat`) and the
//! chunked prefill pass's window-batched linears — every dispatch in
//! the unified forward implementation shares one pool per scheduler
//! worker (and single-sequence decode gets its own via
//! `Engine::generate_pooled`).
//!
//! ## Dispatch protocol
//!
//! [`WorkerPool::run`] publishes one job (a `Fn(usize)` over shard
//! indices) and a task count, then participates as lane 0 while the
//! workers claim indices from a shared atomic counter. Workers
//! spin briefly on the epoch counter before parking on a condvar, so
//! back-to-back decode steps are dispatched in nanoseconds while an
//! idle scheduler costs no CPU. `run` returns only once every task has
//! executed — the per-step barrier that makes it safe to hand workers
//! borrowed slices (the borrow outlives every use by construction).
//!
//! ## Determinism
//!
//! The pool executes each shard exactly once, and the tiled kernels
//! give every shard a disjoint output row band whose per-row
//! accumulation order replays the single-vector `matvec` (see
//! [`crate::sparse::tile`]). Which lane runs which shard, and in what
//! order, therefore cannot affect a single output bit — all PR 1–3
//! bit-exactness guarantees survive pooled decode unchanged. The
//! quantized formats (`CsrQ`/`MackoQ`) ride the same shards with the
//! dequant fused per nonzero, so the within-mode guarantee extends to
//! int8/int4 payloads with no pool-side changes.
//!
//! The kernel-path knob ([`crate::sparse::KernelPath`]) is equally
//! invisible here: scalar and unrolled traversals of a shard produce
//! bit-identical bands, so the pool dispatches the same jobs either
//! way and only the per-lane busy time moves.
//!
//! ## Core pinning (`--pin-workers`)
//!
//! Decode shards are a few microseconds of memory-bound work, so a
//! worker that migrates between cores pays its warmed L1/L2 tile
//! bytes again on the next dispatch. [`WorkerPool::new_pinned`] asks
//! the kernel to keep each spawned lane on one core
//! (`sched_setaffinity`, raw syscall — std-only, no new crates):
//! lane `i` requests core `i % available_parallelism`. Pinning is
//! **best effort and off by default**: it changes scheduling only,
//! never results (determinism is claim-order-independent, see above),
//! it is a no-op on non-Linux builds or when the syscall is refused
//! (containers with restricted affinity masks), and lane 0 — the
//! caller, usually a scheduler worker that exists independently of
//! the pool — is never pinned. Which lanes actually landed on a core
//! is reported in [`PoolStats::pinned_lanes`].
//!
//! ## Accounting
//!
//! Per-lane busy nanoseconds (time inside shard jobs) and the wall time
//! spent under `run` are accumulated into [`PoolStats`]; the scheduler
//! surfaces them as `shard_busy_seconds` / `shard_idle_seconds` in
//! `SchedStats`/`GenStats` so a misbalanced plan shows up in the
//! serving metrics, not just in a profiler.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize,
                        Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Best-effort thread→core affinity, std-only (no `libc` dependency).
/// Linux pins via the raw `sched_setaffinity` syscall; every other
/// target compiles to a no-op that reports failure.
mod affinity {
    /// Ask the kernel to restrict the *calling thread* to `core`.
    /// Returns whether the kernel accepted. Never panics: an
    /// out-of-range core or a refused syscall (e.g. a container with
    /// a restricted affinity mask) just reports `false` and the
    /// thread stays migratable.
    #[cfg(all(target_os = "linux",
              any(target_arch = "x86_64", target_arch = "aarch64")))]
    pub fn pin_current_thread(core: usize) -> bool {
        // 16 × 64 = 1024 bits, the kernel's default cpu_set_t width
        let mut mask = [0u64; 16];
        if core >= mask.len() * 64 {
            return false;
        }
        mask[core / 64] |= 1u64 << (core % 64);
        #[cfg(target_arch = "x86_64")]
        const SYS_SCHED_SETAFFINITY: i64 = 203;
        #[cfg(target_arch = "aarch64")]
        const SYS_SCHED_SETAFFINITY: i64 = 122;
        extern "C" {
            fn syscall(num: i64, ...) -> i64;
        }
        // SAFETY: sched_setaffinity(pid=0 → calling thread, len,
        // mask) reads `mask` (valid for `size_of_val` bytes) and
        // only changes where the scheduler may place this thread.
        let r = unsafe {
            syscall(SYS_SCHED_SETAFFINITY, 0i64,
                    std::mem::size_of_val(&mask), mask.as_ptr())
        };
        r == 0
    }

    #[cfg(not(all(target_os = "linux",
                  any(target_arch = "x86_64",
                      target_arch = "aarch64"))))]
    pub fn pin_current_thread(_core: usize) -> bool {
        false
    }
}

/// Lifetime-erased shard job. Only dereferenced by tasks claimed while
/// the owning [`WorkerPool::run`] call is still blocked on the barrier,
/// which is what makes the erasure sound.
#[derive(Clone, Copy)]
struct Job(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (it is in the type), and `run` does not
// return until every claimed task has finished executing, so the
// borrow behind the raw pointer outlives every dereference.
unsafe impl Send for Job {}

/// State a worker must take the mutex for: the published job and the
/// park/wake protocol. The hot-path counters live outside as atomics.
struct Slot {
    job: Option<Job>,
}

struct Shared {
    /// Bumped once per `run` dispatch; spinning workers watch it.
    epoch: AtomicU64,
    /// Claim word of the current dispatch: `n_tasks` in the high 32
    /// bits, the next unclaimed index in the low 32. A claim is one
    /// `fetch_add(1)`, and the returned value self-describes its
    /// bound — so a straggler claiming against a *stale* word (its
    /// counter already exhausted) or a *fresh* word (it simply helps
    /// with the new dispatch) can never double-claim or run past the
    /// end. `run` installs a fresh word per dispatch.
    claims: AtomicU64,
    /// Tasks not yet finished; `run` returns when this hits zero.
    remaining: AtomicUsize,
    panicked: AtomicBool,
    shutdown: AtomicBool,
    /// Park/wake for workers that exhausted their spin budget.
    slot: Mutex<Slot>,
    work: Condvar,
    /// Wakes the `run` caller when the last task of a dispatch lands.
    done: Condvar,
    /// Busy nanoseconds per lane (lane 0 = the dispatching caller).
    busy_ns: Vec<AtomicU64>,
    /// Wall nanoseconds spent inside `run` (dispatch + barrier).
    wall_ns: AtomicU64,
    runs: AtomicU64,
    /// Core each lane was pinned to, or -1 if unpinned (pinning off,
    /// refused by the kernel, or lane 0 — never pinned). Written once
    /// by each spawned lane before its first dispatch.
    pinned: Vec<AtomicI64>,
}

/// Iterations to spin on the epoch/remaining atomics before parking.
/// Decode steps dispatch every few tens of microseconds, so a short
/// spin catches the next step without a futex round trip; an idle
/// scheduler parks and costs nothing.
const SPIN_LIMIT: u32 = 4096;

/// A pool of `width - 1` persistent worker threads plus the calling
/// thread (lane 0). `width <= 1` spawns nothing and [`WorkerPool::run`]
/// executes inline — the zero-cost configuration the engine uses when
/// `--shard-workers` is 1.
///
/// One pool belongs to one dispatching thread: concurrent `run` calls
/// on the same pool are not supported (each scheduler worker owns its
/// own pool).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    width: usize,
}

impl WorkerPool {
    /// Build a pool with `width.max(1)` lanes (the caller plus
    /// `width - 1` spawned workers, parked until the first dispatch).
    pub fn new(width: usize) -> WorkerPool {
        Self::new_pinned(width, false)
    }

    /// [`WorkerPool::new`] with optional core affinity
    /// (`--pin-workers`): each spawned lane `i` asks to stay on core
    /// `i % available_parallelism` before entering its worker loop.
    /// Best effort — see the module docs; a refused pin leaves the
    /// lane migratable and the pool fully functional. Lane 0 (the
    /// caller) is never pinned.
    pub fn new_pinned(width: usize, pin: bool) -> WorkerPool {
        let width = width.max(1);
        let shared = Arc::new(Shared {
            epoch: AtomicU64::new(0),
            claims: AtomicU64::new(0),
            remaining: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            slot: Mutex::new(Slot { job: None }),
            work: Condvar::new(),
            done: Condvar::new(),
            busy_ns: (0..width).map(|_| AtomicU64::new(0)).collect(),
            wall_ns: AtomicU64::new(0),
            runs: AtomicU64::new(0),
            pinned: (0..width).map(|_| AtomicI64::new(-1)).collect(),
        });
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let handles = (1..width)
            .map(|lane| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || {
                    if pin {
                        let core = lane % cores;
                        if affinity::pin_current_thread(core) {
                            sh.pinned[lane]
                                .store(core as i64, Ordering::Release);
                        }
                    }
                    worker_loop(&sh, lane)
                })
            })
            .collect();
        WorkerPool { shared, handles, width }
    }

    /// Shard lanes available to a dispatch (caller included). The
    /// engine splits each layer's tile plan into up to this many
    /// shards.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Execute `f(0..n_tasks)` across the pool's lanes and block until
    /// every task has run (the per-step barrier). Tasks are claimed
    /// dynamically, each runs exactly once, and the caller participates
    /// as lane 0. With one lane (or one task) everything runs inline on
    /// the caller — no synchronization at all.
    ///
    /// Panics (after draining the dispatch) if a task panicked on a
    /// worker lane.
    pub fn run(&self, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        // TIMING-OK: busy/idle lane accounting for PoolStats — purely
        // observational; task claiming and results are clock-free.
        let t0 = Instant::now();
        if self.width <= 1 || n_tasks == 1 {
            let tb = Instant::now();
            for i in 0..n_tasks {
                f(i);
            }
            self.shared.busy_ns[0].fetch_add(
                tb.elapsed().as_nanos() as u64, Ordering::Relaxed);
            self.finish_run(t0);
            return;
        }

        let sh = &*self.shared;
        debug_assert_eq!(sh.remaining.load(Ordering::Acquire), 0,
                         "concurrent run() on one pool");
        assert!((n_tasks as u64) < (1u64 << 32), "dispatch too large");
        {
            // publish the job, then open the claim window: a worker's
            // claim RMW on `claims` synchronizes with the release
            // store below, so a valid claim always sees the current
            // job and `remaining`.
            let mut slot = sh.slot.lock().unwrap();
            slot.job = Some(Job(f as *const (dyn Fn(usize) + Sync)));
            sh.remaining.store(n_tasks, Ordering::Release);
            sh.claims.store((n_tasks as u64) << 32, Ordering::Release);
            sh.epoch.fetch_add(1, Ordering::Release);
        }
        sh.work.notify_all();

        // lane 0: claim and execute alongside the workers
        drain(sh, 0);

        // barrier: spin briefly (shards are microseconds), then park
        let mut spins = 0u32;
        while sh.remaining.load(Ordering::Acquire) > 0 {
            spins += 1;
            if spins > SPIN_LIMIT {
                let slot = sh.slot.lock().unwrap();
                let _guard = sh
                    .done
                    .wait_timeout_while(
                        slot,
                        std::time::Duration::from_millis(10),
                        |_| sh.remaining.load(Ordering::Acquire) > 0,
                    )
                    .unwrap();
                spins = 0;
            } else {
                std::hint::spin_loop();
            }
        }
        self.finish_run(t0);
        if sh.panicked.swap(false, Ordering::AcqRel) {
            panic!("decode pool worker panicked");
        }
    }

    fn finish_run(&self, t0: Instant) {
        self.shared
            .wall_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.shared.runs.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot the pool's accounting counters.
    pub fn stats(&self) -> PoolStats {
        let busy_seconds: Vec<f64> = self
            .shared
            .busy_ns
            .iter()
            .map(|ns| ns.load(Ordering::Relaxed) as f64 * 1e-9)
            .collect();
        PoolStats {
            lanes: self.width,
            busy_seconds,
            wall_seconds: self.shared.wall_ns.load(Ordering::Relaxed)
                as f64
                * 1e-9,
            runs: self.shared.runs.load(Ordering::Relaxed),
            pinned_lanes: self
                .shared
                .pinned
                .iter()
                .map(|c| {
                    let v = c.load(Ordering::Acquire);
                    usize::try_from(v).ok()
                })
                .collect(),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // take the park mutex so no worker is between its shutdown
        // check and the wait when we notify
        drop(self.shared.slot.lock().unwrap());
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim and execute tasks of the current dispatch until none are
/// left. Called by workers after waking and by the `run` caller as
/// lane 0.
///
/// Every claim is one `fetch_add` on the packed claim word, and the
/// value read back carries both the index and that dispatch's task
/// count — so the bound check can never mix one dispatch's index with
/// another's count, and a valid claim implies the dispatching `run`
/// call is still blocked on the barrier (its `remaining` cannot reach
/// zero until this claim executes and decrements it).
fn drain(sh: &Shared, lane: usize) {
    loop {
        let v = sh.claims.fetch_add(1, Ordering::AcqRel);
        let i = (v & 0xFFFF_FFFF) as usize;
        let n_tasks = (v >> 32) as usize;
        if i >= n_tasks {
            return;
        }
        // the claim is valid, so `run` is still parked on the barrier
        // and the job read here is the one it published
        let job = sh.slot.lock().unwrap().job.expect("claimed with no job");
        // TIMING-OK: per-lane busy accounting for PoolStats only.
        let tb = Instant::now();
        // SAFETY: see `Job` — the dispatching `run` call is blocked on
        // `remaining` until this task (and every other claimed task)
        // has finished, so the erased borrow is live for the whole
        // call.
        let r = catch_unwind(AssertUnwindSafe(|| unsafe { (&*job.0)(i) }));
        sh.busy_ns[lane]
            .fetch_add(tb.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if r.is_err() {
            sh.panicked.store(true, Ordering::Release);
        }
        if sh.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // last task of the dispatch: wake a parked `run` caller
            drop(sh.slot.lock().unwrap());
            sh.done.notify_all();
        }
    }
}

/// Worker thread body: spin on the epoch for fresh dispatches, park on
/// the condvar once the spin budget is spent, drain tasks when work
/// appears, exit on shutdown.
fn worker_loop(sh: &Shared, lane: usize) {
    let mut seen = 0u64;
    loop {
        let mut spins = 0u32;
        loop {
            if sh.shutdown.load(Ordering::Acquire) {
                return;
            }
            let e = sh.epoch.load(Ordering::Acquire);
            if e != seen {
                seen = e;
                break;
            }
            spins += 1;
            if spins > SPIN_LIMIT {
                let slot = sh.slot.lock().unwrap();
                let _guard = sh
                    .work
                    .wait_timeout_while(
                        slot,
                        std::time::Duration::from_millis(50),
                        |_| {
                            !sh.shutdown.load(Ordering::Acquire)
                                && sh.epoch.load(Ordering::Acquire) == seen
                        },
                    )
                    .unwrap();
                spins = 0;
            } else {
                std::hint::spin_loop();
            }
        }
        drain(sh, lane);
    }
}

/// Accounting snapshot of one [`WorkerPool`].
#[derive(Debug, Clone)]
pub struct PoolStats {
    /// Shard lanes (caller + spawned workers).
    pub lanes: usize,
    /// Seconds each lane spent executing shard jobs.
    pub busy_seconds: Vec<f64>,
    /// Wall seconds spent inside `run` (dispatch + barrier), i.e. the
    /// window in which a lane *could* have been busy.
    pub wall_seconds: f64,
    /// Number of `run` dispatches.
    pub runs: u64,
    /// Per-lane core placement: `Some(core)` if the lane was pinned
    /// there ([`WorkerPool::new_pinned`]), `None` if unpinned —
    /// pinning off, refused by the kernel, or lane 0 (the caller,
    /// never pinned).
    pub pinned_lanes: Vec<Option<usize>>,
}

impl PoolStats {
    /// Lanes that actually landed on a core.
    pub fn pinned_count(&self) -> usize {
        self.pinned_lanes.iter().filter(|p| p.is_some()).count()
    }
    /// Seconds a lane sat idle while a dispatch was in flight
    /// (clamped at zero — lane 0 overlaps dispatch bookkeeping).
    pub fn idle_seconds(&self) -> Vec<f64> {
        self.busy_seconds
            .iter()
            .map(|&b| (self.wall_seconds - b).max(0.0))
            .collect()
    }

    pub fn busy_total(&self) -> f64 {
        self.busy_seconds.iter().sum()
    }

    pub fn idle_total(&self) -> f64 {
        self.idle_seconds().iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn every_task_runs_exactly_once() {
        let pool = WorkerPool::new(4);
        for n in [1usize, 2, 3, 7, 64] {
            let hits: Vec<AtomicUsize> =
                (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1,
                           "n={n} task {i} ran a wrong number of times");
            }
        }
        let st = pool.stats();
        assert_eq!(st.lanes, 4);
        assert_eq!(st.runs, 5);
    }

    #[test]
    fn pool_is_reusable_across_many_dispatches() {
        // the steady-state shape: one pool, thousands of tiny runs
        // (dozens under Miri — enough to cross the spin-then-park
        // boundary repeatedly without blowing the interpreter budget)
        let dispatches: usize = if cfg!(miri) { 50 } else { 2000 };
        let pool = WorkerPool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..dispatches {
            pool.run(5, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 5 * dispatches);
        assert_eq!(pool.stats().runs, dispatches as u64);
    }

    #[test]
    fn width_one_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.width(), 1);
        let sum = AtomicUsize::new(0);
        pool.run(8, &|i| {
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 28);
        let st = pool.stats();
        assert_eq!(st.lanes, 1);
        assert!(st.busy_seconds[0] >= 0.0);
    }

    #[test]
    fn zero_width_clamps_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.width(), 1);
        pool.run(2, &|_| {});
    }

    #[test]
    fn tasks_see_borrowed_state_and_write_disjointly() {
        // the exact usage shape of the pooled kernels: tasks write
        // disjoint bands of one buffer borrowed from the caller
        let pool = WorkerPool::new(4);
        let n = 16usize;
        let band = 32usize;
        let mut buf = vec![0.0f32; n * band];
        struct SendPtr(*mut f32);
        // SAFETY: tasks dereference the pointer only through disjoint
        // per-task bands, and `pool.run`'s barrier ends every task
        // before `buf` is read back — no concurrent aliasing.
        unsafe impl Send for SendPtr {}
        unsafe impl Sync for SendPtr {}
        let p = SendPtr(buf.as_mut_ptr());
        pool.run(n, &|i| {
            // SAFETY: band `i` is written by exactly one task
            let s = unsafe {
                std::slice::from_raw_parts_mut(p.0.add(i * band), band)
            };
            for (j, v) in s.iter_mut().enumerate() {
                *v = (i * band + j) as f32;
            }
        });
        for (k, &v) in buf.iter().enumerate() {
            assert_eq!(v, k as f32);
        }
    }

    #[test]
    fn busy_and_idle_accounting_are_consistent() {
        let pool = WorkerPool::new(2);
        pool.run(4, &|_| {
            // enough work to register on the clock
            let mut acc = 0.0f64;
            for i in 0..20_000 {
                acc += (i as f64).sqrt();
            }
            std::hint::black_box(acc);
        });
        let st = pool.stats();
        assert!(st.busy_total() > 0.0);
        assert!(st.wall_seconds > 0.0);
        assert_eq!(st.idle_seconds().len(), 2);
        for idle in st.idle_seconds() {
            assert!(idle >= 0.0);
        }
    }

    #[test]
    fn zero_tasks_is_a_noop() {
        let pool = WorkerPool::new(3);
        pool.run(0, &|_| panic!("must not be called"));
        assert_eq!(pool.stats().runs, 0);
    }

    #[test]
    fn unpinned_pool_reports_no_placements() {
        let pool = WorkerPool::new(4);
        let st = pool.stats();
        assert_eq!(st.pinned_lanes.len(), 4);
        assert!(st.pinned_lanes.iter().all(|p| p.is_none()));
        assert_eq!(st.pinned_count(), 0);
    }

    #[test]
    fn pinned_pool_places_lanes_and_stays_correct() {
        // pinning is best effort, so the hard assertions are about
        // what it must NOT do: break dispatch, pin lane 0, or report
        // a core outside the machine
        let pool = WorkerPool::new_pinned(4, true);
        let hits: Vec<AtomicUsize> =
            (0..32).map(|_| AtomicUsize::new(0)).collect();
        pool.run(32, &|i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
        let st = pool.stats();
        assert_eq!(st.pinned_lanes.len(), 4);
        assert!(st.pinned_lanes[0].is_none(),
                "lane 0 (the caller) must never be pinned");
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        for p in st.pinned_lanes.iter().flatten() {
            assert!(*p < cores, "pinned to nonexistent core {p}");
        }
        assert!(st.pinned_count() <= 3);
    }

    #[test]
    fn pin_flag_off_matches_plain_constructor() {
        let a = WorkerPool::new(3);
        let b = WorkerPool::new_pinned(3, false);
        assert_eq!(a.stats().pinned_count(), 0);
        assert_eq!(b.stats().pinned_count(), 0);
    }
}

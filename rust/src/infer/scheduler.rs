//! Continuous-batching request scheduler with pooled KV caches.
//!
//! The serving layer above [`Engine`]: a [`RequestQueue`] of ragged
//! generation requests (prompt, `n_new`, seed, admission deadline), a
//! [`Scheduler`] that admits queued requests into freed slots
//! *mid-decode* — instead of waiting for the whole batch to retire the
//! way static batching (`Engine::generate_batch`) does — and a
//! [`KvPool`] that recycles per-slot KV-cache buffers across requests
//! so steady-state decode does not touch the allocator.
//!
//! Newly admitted slots consume their prompts through the engine's
//! chunked prefill pass — up to [`Engine::prefill_chunk`] positions per
//! scheduler iteration, headless (zero head projections until the
//! final prompt position rides the shared decode step) — so a long
//! prompt costs `ceil((len-1)/chunk)` passes instead of `len` one-token
//! steps while its batch-mates keep generating every iteration.
//!
//! ## Time model
//!
//! The scheduler runs on a deterministic *step clock*: one tick per
//! batched decode step (summed across workers when `threads > 1`).
//! Request arrivals and admission deadlines are expressed in steps, so
//! a queue built with [`RequestQueue::with_poisson_arrivals`] replays
//! the exact same arrival pattern on every run — load generation is
//! seeded through `util::rng`, never wall-clock. When every worker is
//! idle and the next arrival is in the future, the clock fast-forwards
//! to it instead of spinning through empty steps.
//!
//! ## Determinism guarantee
//!
//! Request `r` with seed `s` reproduces `Engine::generate(&prompt, n_new,
//! temperature, s)` bit-for-bit **regardless of admission order, batch
//! composition, `max_slots`, or `threads`**: the batched kernels keep
//! each sequence's accumulation order identical to the single-vector
//! path, attention/layernorm stay per-slot, and each request samples
//! from its own seeded RNG. Scheduling policy only decides *when* a
//! request runs, never *what* it produces. (`Engine::generate_batch` is
//! a thin wrapper over this module with fixed admission.)

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use super::pool::WorkerPool;
use super::{sample, BatchScratch, Engine, Kv, Slot};
use crate::cli::Args;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::timer::Timer;

/// One generation request. Prompts may be ragged across a queue; every
/// request carries its own token budget and sampling seed.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    /// New tokens to generate (capped by the model's `seq_len`).
    pub n_new: usize,
    /// Sampling seed: the request reproduces
    /// `generate(&prompt, n_new, temperature, seed)` bit-for-bit.
    pub seed: u64,
    /// Admission deadline in scheduler steps *after arrival*: if the
    /// request has not been admitted within this many steps of
    /// arriving, it is dropped as expired (zero tokens). `None` waits
    /// forever.
    pub deadline: Option<u64>,
}

/// A deterministic arrival schedule: requests plus the step at which
/// each one becomes visible to the scheduler.
#[derive(Debug, Clone, Default)]
pub struct RequestQueue {
    entries: Vec<(u64, Request)>,
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue::default()
    }

    /// Enqueue a request that is available from step 0.
    pub fn push(&mut self, req: Request) {
        self.push_at(0, req);
    }

    /// Enqueue a request that arrives at `arrival_step`.
    pub fn push_at(&mut self, arrival_step: u64, req: Request) {
        self.entries.push((arrival_step, req));
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Poisson-ish arrivals: exponential inter-arrival gaps with the
    /// given mean (in steps), drawn from the seeded deterministic RNG.
    /// `mean_gap_steps <= 0` makes every request arrive at step 0.
    pub fn with_poisson_arrivals(reqs: Vec<Request>, mean_gap_steps: f64,
                                 seed: u64) -> RequestQueue {
        let mut rng = Rng::new(seed);
        let mut q = RequestQueue::new();
        let mut t = 0.0f64;
        for r in reqs {
            if mean_gap_steps > 0.0 {
                t += -mean_gap_steps * (1.0 - rng.f64()).ln();
            }
            q.push_at(t as u64, r);
        }
        q
    }

    /// Sorted (arrival, id) pop order for the scheduler.
    fn into_deque(mut self) -> VecDeque<(u64, Request)> {
        self.entries.sort_by_key(|(a, r)| (*a, r.id));
        self.entries.into()
    }
}

/// Recycles per-slot KV-cache buffer sets across requests. A retiring
/// slot's buffers (one K + one V per layer, each holding capacity for
/// `seq_len * d_model` floats) go back to the pool; the next admission
/// reuses them after a `clear()` that keeps the heap allocation, so
/// steady-state decode admits and retires requests allocation-free.
pub struct KvPool {
    layers: usize,
    cap: usize,
    free: Vec<Vec<Kv>>,
    /// Buffer sets that required a fresh heap allocation.
    pub allocated: usize,
    /// Buffer sets served by recycling a retired slot's buffers.
    pub reused: usize,
}

impl KvPool {
    pub(crate) fn new(layers: usize, cap: usize) -> KvPool {
        KvPool { layers, cap, free: Vec::new(), allocated: 0, reused: 0 }
    }

    fn acquire(&mut self) -> Vec<Kv> {
        match self.free.pop() {
            Some(mut kvs) => {
                for kv in kvs.iter_mut() {
                    kv.k.clear();
                    kv.v.clear();
                    kv.len = 0;
                }
                self.reused += 1;
                kvs
            }
            None => {
                self.allocated += 1;
                (0..self.layers)
                    .map(|_| Kv {
                        k: Vec::with_capacity(self.cap),
                        v: Vec::with_capacity(self.cap),
                        len: 0,
                    })
                    .collect()
            }
        }
    }

    fn release(&mut self, kvs: Vec<Kv>) {
        debug_assert_eq!(kvs.len(), self.layers);
        self.free.push(kvs);
    }

    /// Buffer sets currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

/// Scheduler policy knobs.
#[derive(Debug, Clone)]
pub struct SchedOptions {
    /// Maximum concurrently decoding requests (summed across workers).
    pub max_slots: usize,
    /// Sampling temperature shared by every request (0 = greedy).
    pub temperature: f32,
    /// Worker threads; `max_slots` capacity is split across them and
    /// each worker admits from the shared queue into its own slots.
    pub threads: usize,
    /// Row-band shard workers per scheduler worker: each worker owns a
    /// persistent [`WorkerPool`] of this many lanes and dispatches
    /// every layer's linears to it as byte-balanced tile shards
    /// (`--shard-workers`; 0/1 = serial decode, no pool threads).
    /// Orthogonal to `threads` — slots × bands — and, like every other
    /// knob here, incapable of changing a token.
    pub shard_workers: usize,
}

impl Default for SchedOptions {
    fn default() -> SchedOptions {
        SchedOptions {
            max_slots: 8,
            temperature: 0.0,
            threads: 1,
            shard_workers: 1,
        }
    }
}

/// Terminal record for one request (completed or expired).
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    pub id: u64,
    /// Prompt + generated tokens (empty for expired requests).
    pub tokens: Vec<u32>,
    pub prompt_len: usize,
    pub generated: usize,
    /// True if the admission deadline passed before a slot freed up.
    pub expired: bool,
    pub arrival_step: u64,
    /// Step the request entered a slot (`== arrival_step` for requests
    /// that expired without ever being admitted).
    pub admitted_step: u64,
    pub finished_step: u64,
    /// Wall milliseconds from admission to retirement (0 if expired).
    pub latency_ms: f64,
}

/// Aggregate serving metrics for one scheduler run.
#[derive(Debug, Clone)]
pub struct SchedStats {
    pub requests: usize,
    pub expired: usize,
    pub tokens_generated: usize,
    /// Final step-clock value (decode steps summed across workers,
    /// plus idle fast-forward jumps).
    pub steps: u64,
    pub wall_seconds: f64,
    /// Wall seconds of chunked prefill passes plus steps where no slot
    /// was generating yet (max across workers).
    pub prefill_seconds: f64,
    /// Wall seconds of pure generation steps (max across workers).
    pub decode_seconds: f64,
    /// Prompt positions fed via the headless chunked prefill passes
    /// (summed across workers; each admitted request additionally
    /// feeds its final prompt position through the head-projecting
    /// decode step).
    pub prefill_tokens: usize,
    /// Chunked prefill passes run (summed across workers) —
    /// `ceil((prompt_len - 1) / prefill_chunk)` per admitted request.
    pub prefill_chunks: usize,
    /// Aggregate serving throughput: generated tokens / wall seconds.
    pub tokens_per_second: f64,
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    /// Mean steps a served request waited between arrival and admission.
    pub mean_wait_steps: f64,
    pub kv_allocated: usize,
    pub kv_reused: usize,
    /// Row-band shard lanes per scheduler worker (1 = serial decode).
    pub shard_workers: usize,
    /// Per-lane seconds spent executing row-band shard jobs, summed
    /// lane-wise across scheduler workers (all zeros when
    /// `shard_workers <= 1` — the pool is never dispatched).
    pub shard_busy_seconds: Vec<f64>,
    /// Per-lane seconds spent idle while a dispatch was in flight —
    /// the shard-imbalance signal (same layout as
    /// `shard_busy_seconds`).
    pub shard_idle_seconds: Vec<f64>,
}

/// Continuous-batching scheduler over one [`Engine`].
pub struct Scheduler<'e> {
    engine: &'e Engine,
    opts: SchedOptions,
}

/// State shared by the scheduler workers.
struct Shared {
    /// Pending requests in (arrival, id) order.
    queue: Mutex<VecDeque<(u64, Request)>>,
    /// The step clock (see module docs).
    clock: AtomicU64,
    /// Requests currently admitted across all workers (idle workers
    /// fast-forward the clock only when this hits zero).
    active: AtomicUsize,
}

/// Per-request bookkeeping the engine-level `Slot` doesn't carry.
struct Meta {
    id: u64,
    arrival_step: u64,
    admitted_step: u64,
    admitted_at: Instant,
}

struct WorkerOut {
    finished: Vec<FinishedRequest>,
    prefill_seconds: f64,
    decode_seconds: f64,
    /// Prompt positions fed via headless chunked prefill passes.
    prefill_tokens: usize,
    /// Chunked prefill passes run.
    prefill_chunks: usize,
    kv_allocated: usize,
    kv_reused: usize,
    /// Per-lane busy/idle seconds of this worker's decode pool.
    shard_busy: Vec<f64>,
    shard_idle: Vec<f64>,
}

impl<'e> Scheduler<'e> {
    pub fn new(engine: &'e Engine, opts: SchedOptions) -> Scheduler<'e> {
        Scheduler { engine, opts }
    }

    /// Drain `queue` to completion and return every request's terminal
    /// record (sorted by request id) plus aggregate stats.
    pub fn run(&self, queue: RequestQueue)
               -> (Vec<FinishedRequest>, SchedStats) {
        let n_requests = queue.len();
        let max_slots = self.opts.max_slots.max(1);
        let threads = self.opts.threads.max(1).min(max_slots);
        let shared = Shared {
            queue: Mutex::new(queue.into_deque()),
            clock: AtomicU64::new(0),
            active: AtomicUsize::new(0),
        };
        let t0 = Instant::now();
        let outs: Vec<WorkerOut> = if threads <= 1 {
            vec![self.worker(&shared, max_slots)]
        } else {
            let shared = &shared;
            std::thread::scope(|sc| {
                let mut handles = Vec::new();
                for w in 0..threads {
                    let cap = max_slots / threads
                        + usize::from(w < max_slots % threads);
                    handles.push(sc.spawn(move || self.worker(shared, cap)));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("scheduler worker panicked"))
                    .collect()
            })
        };
        let wall = t0.elapsed().as_secs_f64();

        let prefill = outs.iter().fold(0.0, |a, o| a.max(o.prefill_seconds));
        let decode = outs.iter().fold(0.0, |a, o| a.max(o.decode_seconds));
        let prefill_tokens = outs.iter().map(|o| o.prefill_tokens).sum();
        let prefill_chunks = outs.iter().map(|o| o.prefill_chunks).sum();
        let kv_allocated = outs.iter().map(|o| o.kv_allocated).sum();
        let kv_reused = outs.iter().map(|o| o.kv_reused).sum();
        // lane-wise sums across workers (every worker's pool has the
        // same lane count)
        let lanes = self.opts.shard_workers.max(1);
        let mut shard_busy = vec![0.0f64; lanes];
        let mut shard_idle = vec![0.0f64; lanes];
        for o in &outs {
            for (acc, v) in shard_busy.iter_mut().zip(&o.shard_busy) {
                *acc += v;
            }
            for (acc, v) in shard_idle.iter_mut().zip(&o.shard_idle) {
                *acc += v;
            }
        }
        let mut finished: Vec<FinishedRequest> =
            outs.into_iter().flat_map(|o| o.finished).collect();
        finished.sort_by_key(|f| f.id);
        debug_assert_eq!(finished.len(), n_requests,
                         "every request must finish or expire");
        let stats = summarize(&finished, wall,
                              shared.clock.load(Ordering::SeqCst), prefill,
                              decode,
                              PrefillCounts { tokens: prefill_tokens,
                                              chunks: prefill_chunks },
                              kv_allocated, kv_reused,
                              ShardTimes { lanes, busy: shard_busy,
                                           idle: shard_idle });
        (finished, stats)
    }

    /// One worker: a batched decode loop over up to `cap` slots that
    /// samples/retires, admits from the shared queue into freed slots,
    /// chunk-prefills every slot still consuming its prompt, then runs
    /// one batched decode step over the slots with one unfed token
    /// left — every iteration, so a request admitted mid-decode starts
    /// prefilling on the very next iteration while its batch-mates
    /// keep generating.
    ///
    /// The live set is packed in slot order (`indices = 0..slots.len()`
    /// after swap-remove retirement), and the engine's kernels —
    /// row-tiled or not, batched head projection included — are
    /// bit-exact per lane regardless of how the set is packed, so the
    /// determinism guarantee in the module docs is independent of
    /// retirement/admission interleaving.
    fn worker(&self, shared: &Shared, cap: usize) -> WorkerOut {
        let engine = self.engine;
        let cfg = &engine.cfg;
        let chunk = engine.prefill_chunk.max(1);
        let mut pool = KvPool::new(cfg.n_layers, cfg.seq_len * cfg.d_model);
        // this worker's persistent row-band shard pool: created once,
        // workers park between decode steps — no spawns in steady
        // state (a 1-lane pool spawns nothing and decode runs serial)
        let shard_pool = WorkerPool::new(self.opts.shard_workers.max(1));
        let mut slots: Vec<Slot> = Vec::with_capacity(cap);
        let mut meta: Vec<Meta> = Vec::with_capacity(cap);
        let mut scratch = BatchScratch::new(cfg, cap, chunk);
        let mut indices: Vec<usize> = Vec::with_capacity(cap);
        let mut out = WorkerOut {
            finished: Vec::new(),
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            prefill_tokens: 0,
            prefill_chunks: 0,
            kv_allocated: 0,
            kv_reused: 0,
            shard_busy: Vec::new(),
            shard_idle: Vec::new(),
        };

        loop {
            let now = shared.clock.load(Ordering::SeqCst);

            // 1. Sample freshly decoded slots; retire exhausted ones.
            //    (Slots mid-prefill have fed < tokens.len() and skip.)
            let mut i = 0;
            while i < slots.len() {
                let done = {
                    let s = &mut slots[i];
                    if s.fed < s.tokens.len() {
                        false
                    } else if s.logits.is_empty()
                        || s.generated >= s.n_new
                        || s.tokens.len() >= cfg.seq_len
                    {
                        true
                    } else {
                        let next = sample(&s.logits, self.opts.temperature,
                                          &mut s.rng);
                        s.tokens.push(next);
                        s.generated += 1;
                        // if that token hit the budget, its logits would
                        // never be read — retire without the forward pass
                        s.generated >= s.n_new
                            || s.tokens.len() >= cfg.seq_len
                    }
                };
                if done {
                    retire(&mut slots, &mut meta, i, &mut pool, shared,
                           &mut out.finished, now);
                } else {
                    i += 1;
                }
            }

            // 2. Admit arrived requests into freed capacity — this is
            //    the continuous part: admission happens between decode
            //    steps, not at batch boundaries.
            if slots.len() < cap {
                let mut q = shared.queue.lock().unwrap();
                while slots.len() < cap {
                    if !q.front().is_some_and(|(a, _)| *a <= now) {
                        break;
                    }
                    let (arrival, req) = q.pop_front().unwrap();
                    if req.deadline
                        .is_some_and(|d| now > arrival.saturating_add(d))
                    {
                        out.finished.push(FinishedRequest {
                            id: req.id,
                            tokens: Vec::new(),
                            prompt_len: req.prompt.len(),
                            generated: 0,
                            expired: true,
                            arrival_step: arrival,
                            // never admitted: keep wait = 0 rather than
                            // fabricating an admission step
                            admitted_step: arrival,
                            finished_step: now,
                            latency_ms: 0.0,
                        });
                        continue;
                    }
                    if req.prompt.is_empty() {
                        // nothing to condition on: retires immediately
                        // with zero tokens (same rule as generate_batch)
                        out.finished.push(FinishedRequest {
                            id: req.id,
                            tokens: Vec::new(),
                            prompt_len: 0,
                            generated: 0,
                            expired: false,
                            arrival_step: arrival,
                            admitted_step: now,
                            finished_step: now,
                            latency_ms: 0.0,
                        });
                        continue;
                    }
                    assert!(req.prompt.len() <= cfg.seq_len,
                            "request {}: prompt of {} tokens exceeds \
                             seq_len {}", req.id, req.prompt.len(),
                            cfg.seq_len);
                    shared.active.fetch_add(1, Ordering::SeqCst);
                    meta.push(Meta {
                        id: req.id,
                        arrival_step: arrival,
                        admitted_step: now,
                        admitted_at: Instant::now(),
                    });
                    slots.push(Slot {
                        prompt_len: req.prompt.len(),
                        tokens: req.prompt,
                        fed: 0,
                        kvs: pool.acquire(),
                        rng: Rng::new(req.seed),
                        logits: vec![],
                        generated: 0,
                        n_new: req.n_new,
                    });
                }
            }

            // 3. Idle / termination.
            if slots.is_empty() {
                let q = shared.queue.lock().unwrap();
                if q.is_empty() {
                    break;
                }
                if shared.active.load(Ordering::SeqCst) == 0 {
                    // the whole scheduler is idle: fast-forward the
                    // clock to the next arrival instead of spinning
                    // through empty steps, and retry admission
                    // immediately
                    let next = q.front().unwrap().0;
                    shared.clock.fetch_max(next, Ordering::SeqCst);
                    drop(q);
                } else {
                    // other workers are still decoding: park briefly
                    // instead of hot-spinning on their queue mutex
                    drop(q);
                    std::thread::sleep(
                        std::time::Duration::from_micros(50));
                }
                continue;
            }

            // 4. Chunked prefill: every slot still holding more than
            //    one unfed prompt token advances by one headless
            //    window of up to `prefill_chunk` positions — so a
            //    long prompt costs ceil((len-1)/chunk) passes instead
            //    of len-1 steps, with zero head projections, while
            //    generating batch-mates keep stepping every iteration.
            for s in slots.iter_mut() {
                let last = s.tokens.len() - 1;
                if s.fed < last {
                    let n = chunk.min(last - s.fed);
                    let t = Timer::start();
                    engine.prefill_pass(s, n, &mut scratch, &shard_pool);
                    out.prefill_seconds += t.seconds();
                    out.prefill_tokens += n;
                    out.prefill_chunks += 1;
                }
            }

            // 5. One batched decode step over every slot with exactly
            //    one unfed token left (its final prompt position —
            //    the request's single head projection — or its freshly
            //    sampled token). Slots still mid-prefill after their
            //    window sit this step out. A step counts as prefill
            //    only when NO slot is generating yet: mixed steps
            //    produce tokens, so their time must land in
            //    decode_seconds or tokens/decode_s would overstate
            //    throughput for ragged prompts.
            indices.clear();
            indices.extend(slots.iter().enumerate()
                .filter(|(_, s)| s.fed + 1 == s.tokens.len())
                .map(|(i, _)| i));
            if !indices.is_empty() {
                let prefilling =
                    slots.iter().all(|s| s.fed < s.prompt_len);
                let t = Timer::start();
                engine.decode_step_batch(&mut slots, &indices,
                                         &mut scratch, &shard_pool);
                let dt = t.seconds();
                if prefilling {
                    out.prefill_seconds += dt;
                } else {
                    out.decode_seconds += dt;
                }
            }
            shared.clock.fetch_add(1, Ordering::SeqCst);
        }
        out.kv_allocated = pool.allocated;
        out.kv_reused = pool.reused;
        let ps = shard_pool.stats();
        out.shard_idle = ps.idle_seconds();
        out.shard_busy = ps.busy_seconds;
        out
    }
}

/// Lane-wise shard-pool times aggregated across scheduler workers —
/// carried into [`SchedStats`] by [`summarize`].
struct ShardTimes {
    lanes: usize,
    busy: Vec<f64>,
    idle: Vec<f64>,
}

/// Chunked-prefill counters aggregated across scheduler workers.
struct PrefillCounts {
    tokens: usize,
    chunks: usize,
}

fn retire(slots: &mut Vec<Slot>, meta: &mut Vec<Meta>, i: usize,
          pool: &mut KvPool, shared: &Shared,
          finished: &mut Vec<FinishedRequest>, now: u64) {
    let slot = slots.swap_remove(i);
    let m = meta.swap_remove(i);
    pool.release(slot.kvs);
    shared.active.fetch_sub(1, Ordering::SeqCst);
    finished.push(FinishedRequest {
        id: m.id,
        prompt_len: slot.prompt_len,
        generated: slot.generated,
        tokens: slot.tokens,
        expired: false,
        arrival_step: m.arrival_step,
        admitted_step: m.admitted_step,
        finished_step: now,
        latency_ms: m.admitted_at.elapsed().as_secs_f64() * 1e3,
    });
}

fn summarize(finished: &[FinishedRequest], wall: f64, steps: u64,
             prefill: f64, decode: f64, pre: PrefillCounts,
             kv_allocated: usize, kv_reused: usize,
             shard: ShardTimes) -> SchedStats {
    let tokens: usize = finished.iter().map(|f| f.generated).sum();
    let expired = finished.iter().filter(|f| f.expired).count();
    let mut lat = Summary::new();
    let mut wait = 0u64;
    let mut served = 0usize;
    for f in finished.iter().filter(|f| !f.expired && f.prompt_len > 0) {
        lat.push(f.latency_ms);
        wait += f.admitted_step - f.arrival_step;
        served += 1;
    }
    SchedStats {
        requests: finished.len(),
        expired,
        tokens_generated: tokens,
        steps,
        wall_seconds: wall,
        prefill_seconds: prefill,
        decode_seconds: decode,
        prefill_tokens: pre.tokens,
        prefill_chunks: pre.chunks,
        tokens_per_second: tokens as f64 / wall.max(1e-9),
        p50_latency_ms: if lat.n() == 0 { 0.0 } else { lat.median() },
        p95_latency_ms: if lat.n() == 0 { 0.0 } else { lat.percentile(95.0) },
        mean_wait_steps: if served == 0 {
            0.0
        } else {
            wait as f64 / served as f64
        },
        kv_allocated,
        kv_reused,
        shard_workers: shard.lanes,
        shard_busy_seconds: shard.busy,
        shard_idle_seconds: shard.idle,
    }
}

/// Seeded ragged token budgets in `[base/3, base)`: the staggered
/// completion times are what continuous admission exploits, so the
/// bench, the tab1 table and the serving example all draw their
/// request budgets from this one distribution (deterministic per
/// seed).
pub fn ragged_budgets(base: usize, n: usize, seed: u64) -> Vec<usize> {
    let mut rng = Rng::new(seed);
    let lo = (base / 3).max(1);
    (0..n).map(|_| lo + rng.below((base - lo).max(1))).collect()
}

/// Static-batching reference policy on the same machinery: admit
/// requests strictly in id order in groups of `opts.max_slots` and
/// drain each group completely before the next is admitted (ignoring
/// arrival steps — the group launches as one fixed batch). Per-request
/// token streams are bit-identical to the continuous scheduler; only
/// the admission policy differs, which is exactly what
/// `bench_scheduler` measures. The `threads` / `shard_workers` knobs
/// in `opts` apply to each group.
pub fn serve_static_chunks(engine: &Engine, requests: &[Request],
                           opts: &SchedOptions)
                           -> (Vec<FinishedRequest>, SchedStats) {
    let max_slots = opts.max_slots.max(1);
    let lanes = opts.shard_workers.max(1);
    let t0 = Instant::now();
    let mut finished = Vec::with_capacity(requests.len());
    let (mut prefill, mut decode) = (0.0f64, 0.0f64);
    let mut pre = PrefillCounts { tokens: 0, chunks: 0 };
    let mut steps = 0u64;
    let (mut kv_allocated, mut kv_reused) = (0usize, 0usize);
    let mut shard = ShardTimes {
        lanes,
        busy: vec![0.0; lanes],
        idle: vec![0.0; lanes],
    };
    for chunk in requests.chunks(max_slots) {
        let mut q = RequestQueue::new();
        for r in chunk {
            q.push(r.clone());
        }
        let sched = Scheduler::new(engine, SchedOptions {
            max_slots: chunk.len(),
            ..opts.clone()
        });
        let (f, st) = sched.run(q);
        finished.extend(f);
        prefill += st.prefill_seconds;
        decode += st.decode_seconds;
        pre.tokens += st.prefill_tokens;
        pre.chunks += st.prefill_chunks;
        steps += st.steps;
        kv_allocated += st.kv_allocated;
        kv_reused += st.kv_reused;
        for (acc, v) in shard.busy.iter_mut()
            .zip(&st.shard_busy_seconds) {
            *acc += v;
        }
        for (acc, v) in shard.idle.iter_mut()
            .zip(&st.shard_idle_seconds) {
            *acc += v;
        }
    }
    finished.sort_by_key(|f| f.id);
    let wall = t0.elapsed().as_secs_f64();
    let stats = summarize(&finished, wall, steps, prefill, decode, pre,
                          kv_allocated, kv_reused, shard);
    (finished, stats)
}

/// `elsa serve` subcommand: load a checkpoint, synthesize a seeded
/// request stream with Poisson-ish arrivals, and drain it through the
/// continuous-batching scheduler.
pub fn cmd_serve(args: &Args) -> Result<()> {
    let rt = crate::commands::open_runtime(args)?;
    let ck = crate::model::checkpoint::Checkpoint::load(
        &std::path::PathBuf::from(args.require("ckpt")?))?;
    let cfg = rt.manifest.config(&ck.config)?.clone();
    let params = crate::model::Params::new(&cfg, ck.get("params")?.clone());
    let backend = super::Backend::parse(&args.str_or("backend", "macko"))
        .ok_or_else(|| anyhow::anyhow!("bad --backend"))?;
    let mut engine = Engine::build(&params, backend)?;
    engine.tiled = !args.bool("untiled");
    engine.prefill_chunk = args
        .usize_or("prefill-chunk", super::DEFAULT_PREFILL_CHUNK)?
        .max(1);

    let g = crate::data::Grammar::named(
        &args.str_or("dataset", "synth-c4"), cfg.vocab);
    let n_requests = args.usize_or("requests", 32)?;
    let max_slots = args.usize_or("max-slots", 8)?;
    let threads = args.usize_or("threads", 1)?;
    let shard_workers = args.usize_or("shard-workers", 1)?;
    let prompt_len = args.usize_or("prompt-len", 8)?;
    anyhow::ensure!(prompt_len <= cfg.seq_len,
                    "--prompt-len {prompt_len} exceeds the model's \
                     seq_len {}", cfg.seq_len);
    let n_new =
        args.usize_or("tokens", cfg.seq_len.saturating_sub(prompt_len))?;
    let seed = args.usize_or("seed", 0)? as u64;
    let temperature = args.f32_or("temp", 0.8)?;
    let gap = args.f64_or("arrival-gap", 2.0)?;
    let deadline = match args.get("deadline") {
        Some(v) => {
            Some(v.parse::<u64>().with_context(|| format!("--deadline {v}"))?)
        }
        None => None,
    };

    let reqs: Vec<Request> = (0..n_requests)
        .map(|r| Request {
            id: r as u64,
            prompt: g.generate(prompt_len, seed.wrapping_add(r as u64)),
            n_new,
            seed: seed.wrapping_add(r as u64),
            deadline,
        })
        .collect();
    let queue = RequestQueue::with_poisson_arrivals(
        reqs, gap, seed.wrapping_add(0x5eed));
    let sched = Scheduler::new(&engine, SchedOptions {
        max_slots,
        temperature,
        threads,
        shard_workers,
    });
    let (finished, stats) = sched.run(queue);

    if args.bool("verbose") {
        for f in &finished {
            if f.expired {
                println!("req {:4}: arrived {:5} EXPIRED at {:5} \
                          (never admitted)",
                         f.id, f.arrival_step, f.finished_step);
            } else {
                println!("req {:4}: arrived {:5} admitted {:5} finished \
                          {:5} | {:3} new tokens | {:8.2} ms",
                         f.id, f.arrival_step, f.admitted_step,
                         f.finished_step, f.generated, f.latency_ms);
            }
        }
    }
    println!("backend {:?}", backend);
    println!("sparsity {:.4}", params.sparsity());
    println!("requests {} expired {}", stats.requests, stats.expired);
    println!("max_slots {max_slots} threads {threads} \
              shard_workers {shard_workers} arrival_gap {gap}");
    println!("tokens_generated {}", stats.tokens_generated);
    println!("agg_tokens_per_s {:.2}", stats.tokens_per_second);
    println!("p50_ms {:.2}", stats.p50_latency_ms);
    println!("p95_ms {:.2}", stats.p95_latency_ms);
    println!("mean_wait_steps {:.2}", stats.mean_wait_steps);
    println!("steps {}", stats.steps);
    println!("prefill_tokens {} in {} chunk passes (chunk {})",
             stats.prefill_tokens, stats.prefill_chunks,
             engine.prefill_chunk);
    println!("kv_allocated {} kv_reused {}", stats.kv_allocated,
             stats.kv_reused);
    if shard_workers > 1 {
        let busy: f64 = stats.shard_busy_seconds.iter().sum();
        let idle: f64 = stats.shard_idle_seconds.iter().sum();
        println!("shard_busy_s {busy:.4} shard_idle_s {idle:.4} \
                  (per lane: {:?})",
                 stats.shard_busy_seconds.iter()
                     .map(|s| (s * 1e3).round() / 1e3)
                     .collect::<Vec<_>>());
    }
    println!("mem {}", crate::util::human_bytes(engine.mem_bytes()));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::Backend;
    use crate::model::{fake_config, Params};

    #[test]
    fn kvpool_recycles_buffers_without_reallocating() {
        let mut pool = KvPool::new(2, 64);
        let mut a = pool.acquire();
        assert_eq!(pool.allocated, 1);
        assert_eq!(a.len(), 2);
        a[0].k.extend_from_slice(&[1.0; 40]);
        a[0].len = 10;
        pool.release(a);
        assert_eq!(pool.pooled(), 1);
        let b = pool.acquire();
        assert_eq!(pool.allocated, 1, "release->acquire must not allocate");
        assert_eq!(pool.reused, 1);
        assert_eq!(b[0].len, 0, "recycled buffers must come back empty");
        assert!(b[0].k.is_empty());
        assert!(b[0].k.capacity() >= 40, "capacity must be retained");
    }

    #[test]
    fn poisson_arrivals_are_deterministic_and_sorted() {
        let reqs = |n: u64| -> Vec<Request> {
            (0..n)
                .map(|id| Request {
                    id,
                    prompt: vec![1],
                    n_new: 1,
                    seed: id,
                    deadline: None,
                })
                .collect()
        };
        let a = RequestQueue::with_poisson_arrivals(reqs(16), 3.0, 9)
            .into_deque();
        let b = RequestQueue::with_poisson_arrivals(reqs(16), 3.0, 9)
            .into_deque();
        let steps_a: Vec<u64> = a.iter().map(|(s, _)| *s).collect();
        let steps_b: Vec<u64> = b.iter().map(|(s, _)| *s).collect();
        assert_eq!(steps_a, steps_b, "same seed must replay arrivals");
        assert!(steps_a.windows(2).all(|w| w[0] <= w[1]));
        assert!(*steps_a.last().unwrap() > 0, "arrivals should stagger");
    }

    #[test]
    fn unsorted_pushes_are_served_in_arrival_order() {
        let mut q = RequestQueue::new();
        let req = |id| Request {
            id,
            prompt: vec![1],
            n_new: 1,
            seed: id,
            deadline: None,
        };
        q.push_at(9, req(0));
        q.push_at(2, req(1));
        q.push_at(2, req(2));
        let d = q.into_deque();
        let order: Vec<u64> = d.iter().map(|(_, r)| r.id).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn scheduler_smoke_matches_generate() {
        let p = Params::init(&fake_config(), 4);
        let engine = Engine::build(&p, Backend::Macko).unwrap();
        let mut q = RequestQueue::new();
        for id in 0..3u64 {
            q.push_at(id, Request {
                id,
                prompt: vec![1 + id as u32, 2, 3],
                n_new: 3,
                seed: 10 + id,
                deadline: None,
            });
        }
        let sched = Scheduler::new(&engine, SchedOptions {
            max_slots: 2,
            temperature: 0.7,
            ..SchedOptions::default()
        });
        let (finished, stats) = sched.run(q);
        assert_eq!(finished.len(), 3);
        assert_eq!(stats.expired, 0);
        for f in &finished {
            let (want, _) = engine.generate(
                &[1 + f.id as u32, 2, 3], 3, 0.7, 10 + f.id);
            assert_eq!(f.tokens, want, "req {}", f.id);
        }
        assert_eq!(stats.tokens_generated,
                   finished.iter().map(|f| f.generated).sum::<usize>());
    }
}

//! Continuous-batching request scheduler with pooled KV caches.
//!
//! The serving layer above [`Engine`]: a [`RequestQueue`] of ragged
//! generation requests (prompt, `n_new`, seed, admission deadline), a
//! [`Scheduler`] that admits queued requests into freed slots
//! *mid-decode* — instead of waiting for the whole batch to retire the
//! way static batching (`Engine::generate_batch`) does — and a
//! [`KvPool`] that recycles per-slot KV-cache buffers across requests
//! so steady-state decode does not touch the allocator.
//!
//! Newly admitted slots consume their prompts through the engine's
//! chunked prefill pass — up to [`Engine::prefill_chunk`] positions per
//! scheduler iteration, headless (zero head projections until the
//! final prompt position rides the shared decode step) — so a long
//! prompt costs `ceil((len-1)/chunk)` passes instead of `len` one-token
//! steps while its batch-mates keep generating every iteration. Two
//! more prefill levers sit on top:
//!
//!  - **Shared-prefix KV cache** ([`prefix::PrefixCache`], on by
//!    default, `--prefix-cache off` to disable): an admitted request
//!    whose prompt extends an already-prefilled prefix copies the
//!    cached K/V rows into its slot buffers and prefills only its
//!    suffix; a slot finishing its headless prefill publishes the
//!    prefix for later admissions. Copy-on-attach, so decode never
//!    touches shared state — hits are bit-identical to cold starts.
//!  - **Cross-slot batched prefill**: each iteration packs the pending
//!    windows of every prefilling slot into ONE
//!    [`Engine::prefill_pass_multi`] call (time × slots as the batch
//!    dimension) instead of one pass per slot.
//!
//! ## Time model
//!
//! The scheduler runs on a deterministic *step clock*: one tick per
//! batched decode step (summed across workers when `threads > 1`).
//! Request arrivals and admission deadlines are expressed in steps, so
//! a queue built with [`RequestQueue::with_poisson_arrivals`] replays
//! the exact same arrival pattern on every run — load generation is
//! seeded through `util::rng`, never wall-clock. When every worker is
//! idle and the next arrival is in the future, the clock fast-forwards
//! to it instead of spinning through empty steps.
//!
//! ## Determinism guarantee
//!
//! Request `r` with seed `s` reproduces `Engine::generate(&prompt, n_new,
//! temperature, s)` bit-for-bit **regardless of admission order, batch
//! composition, `max_slots`, or `threads`**: the batched kernels keep
//! each sequence's accumulation order identical to the single-vector
//! path, attention/layernorm stay per-slot, and each request samples
//! from its own seeded RNG. Scheduling policy only decides *when* a
//! request runs, never *what* it produces. (`Engine::generate_batch` is
//! a thin wrapper over this module with fixed admission.)
//!
//! The guarantee is *per engine*, and a quantized engine
//! (`--quant int8|int4`, [`crate::sparse::QuantMode`]) is just another
//! engine: an int8 run reproduces an int8 `generate` stream bit-for-bit
//! across threads/shard-workers/tiling/prefix-cache exactly like f32
//! does, because the fused dequantize-multiply-accumulate keeps the
//! same per-row accumulation order. Only the *cross-mode* comparison
//! (int8 vs f32) is tolerance-based — see `rust/tests/quant_parity.rs`
//! and `docs/ARCHITECTURE.md` for where bit-exactness ends.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use super::pool::WorkerPool;
use super::prefix::{PrefixCache, DEFAULT_PREFIX_CACHE_BYTES};
use super::{sample, BatchScratch, Engine, Kv, Slot};
use crate::cli::Args;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::timer::Timer;

/// One generation request. Prompts may be ragged across a queue; every
/// request carries its own token budget and sampling seed.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    /// New tokens to generate (capped by the model's `seq_len`).
    pub n_new: usize,
    /// Sampling seed: the request reproduces
    /// `generate(&prompt, n_new, temperature, seed)` bit-for-bit.
    pub seed: u64,
    /// Admission deadline in scheduler steps *after arrival*: if the
    /// request has not been admitted within this many steps of
    /// arriving, it is dropped as expired (zero tokens). `None` waits
    /// forever.
    pub deadline: Option<u64>,
}

/// A deterministic arrival schedule: requests plus the step at which
/// each one becomes visible to the scheduler.
#[derive(Debug, Clone, Default)]
pub struct RequestQueue {
    entries: Vec<(u64, Request)>,
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue::default()
    }

    /// Enqueue a request that is available from step 0.
    pub fn push(&mut self, req: Request) {
        self.push_at(0, req);
    }

    /// Enqueue a request that arrives at `arrival_step`.
    pub fn push_at(&mut self, arrival_step: u64, req: Request) {
        self.entries.push((arrival_step, req));
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Poisson-ish arrivals: exponential inter-arrival gaps with the
    /// given mean (in steps), drawn from the seeded deterministic RNG.
    /// `mean_gap_steps <= 0` makes every request arrive at step 0.
    pub fn with_poisson_arrivals(reqs: Vec<Request>, mean_gap_steps: f64,
                                 seed: u64) -> RequestQueue {
        let mut rng = Rng::new(seed);
        let mut q = RequestQueue::new();
        let mut t = 0.0f64;
        for r in reqs {
            if mean_gap_steps > 0.0 {
                t += -mean_gap_steps * (1.0 - rng.f64()).ln();
            }
            q.push_at(t as u64, r);
        }
        q
    }

    /// Sorted (arrival, id) pop order for the scheduler.
    fn into_deque(mut self) -> VecDeque<(u64, Request)> {
        self.entries.sort_by_key(|(a, r)| (*a, r.id));
        self.entries.into()
    }
}

/// Releases sampled for the pool's rolling high-water estimate: a
/// buffer keeps its allocation as long as any of the last this-many
/// retiring requests actually needed it.
const KV_RECENT_WINDOW: usize = 8;

/// Shrink slack: a parked buffer may hold up to this multiple of the
/// rolling high-water mark before [`KvPool::release`] trims it.
const KV_SHRINK_MULT: usize = 2;

/// Recycles per-slot KV-cache buffer sets across requests. A retiring
/// slot's buffers (one K + one V per layer) go back to the pool; the
/// next admission reuses them after a `clear()` that keeps the heap
/// allocation, so steady-state decode admits and retires requests
/// allocation-free.
///
/// Buffers grow on demand (up to `seq_len * d_model` floats) and are
/// trimmed on release when their capacity exceeds
/// [`KV_SHRINK_MULT`] × the high-water mark of the last
/// [`KV_RECENT_WINDOW`] releases — so one long-prompt request no
/// longer pins peak-sized buffers for the engine's lifetime once the
/// workload turns short again, while a steadily-long workload never
/// thrashes (the window keeps its watermark high).
pub struct KvPool {
    layers: usize,
    /// Hard per-buffer capacity bound (`seq_len * d_model` floats).
    cap: usize,
    free: Vec<Vec<Kv>>,
    /// Used sizes (floats per buffer) of the most recent releases —
    /// the rolling window behind [`KvPool::watermark`].
    recent: VecDeque<usize>,
    /// Buffer sets that required a fresh heap allocation.
    pub allocated: usize,
    /// Buffer sets served by recycling a retired slot's buffers.
    pub reused: usize,
    /// Buffer sets trimmed by the shrink policy on release.
    pub shrunk: usize,
}

impl KvPool {
    pub(crate) fn new(layers: usize, cap: usize) -> KvPool {
        KvPool {
            layers,
            cap,
            free: Vec::new(),
            recent: VecDeque::new(),
            allocated: 0,
            reused: 0,
            shrunk: 0,
        }
    }

    /// High-water mark (floats per buffer) over the recent releases.
    fn watermark(&self) -> usize {
        self.recent.iter().copied().max().unwrap_or(0)
    }

    fn acquire(&mut self) -> Vec<Kv> {
        match self.free.pop() {
            Some(mut kvs) => {
                for kv in kvs.iter_mut() {
                    kv.k.clear();
                    kv.v.clear();
                    kv.len = 0;
                }
                self.reused += 1;
                kvs
            }
            None => {
                // size fresh buffers to the recent high-water mark
                // instead of the seq_len peak: short-request traffic
                // should not allocate worst-case buffers up front
                let cap = self.watermark().min(self.cap);
                self.allocated += 1;
                (0..self.layers)
                    .map(|_| Kv {
                        k: Vec::with_capacity(cap),
                        v: Vec::with_capacity(cap),
                        len: 0,
                    })
                    .collect()
            }
        }
    }

    fn release(&mut self, mut kvs: Vec<Kv>) {
        debug_assert_eq!(kvs.len(), self.layers);
        let used = kvs.iter().map(|kv| kv.k.len()).max().unwrap_or(0);
        self.recent.push_back(used);
        if self.recent.len() > KV_RECENT_WINDOW {
            self.recent.pop_front();
        }
        // trim buffers the recent workload no longer justifies; the
        // watermark includes `used` just pushed, so the limit never
        // undercuts the data still in the buffers
        let limit = (self.watermark() * KV_SHRINK_MULT).min(self.cap.max(1));
        let mut trimmed = false;
        for kv in kvs.iter_mut() {
            if kv.k.capacity() > limit {
                kv.k.shrink_to(limit);
                trimmed = true;
            }
            if kv.v.capacity() > limit {
                kv.v.shrink_to(limit);
                trimmed = true;
            }
        }
        if trimmed {
            self.shrunk += 1;
        }
        self.free.push(kvs);
    }

    /// Buffer sets currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.free.len()
    }

    /// Heap bytes currently parked in the pool's free buffers (the
    /// `kv_pool_bytes` surfaced in [`SchedStats`]).
    pub fn bytes(&self) -> usize {
        self.free
            .iter()
            .flat_map(|kvs| kvs.iter())
            .map(|kv| (kv.k.capacity() + kv.v.capacity()) * 4)
            .sum()
    }
}

/// Scheduler policy knobs.
#[derive(Debug, Clone)]
pub struct SchedOptions {
    /// Maximum concurrently decoding requests (summed across workers).
    pub max_slots: usize,
    /// Sampling temperature shared by every request (0 = greedy).
    pub temperature: f32,
    /// Worker threads; `max_slots` capacity is split across them and
    /// each worker admits from the shared queue into its own slots.
    pub threads: usize,
    /// Row-band shard workers per scheduler worker: each worker owns a
    /// persistent [`WorkerPool`] of this many lanes and dispatches
    /// every layer's linears to it as byte-balanced tile shards
    /// (`--shard-workers`; 0/1 = serial decode, no pool threads).
    /// Orthogonal to `threads` — slots × bands — and, like every other
    /// knob here, incapable of changing a token.
    pub shard_workers: usize,
    /// Shared-prefix KV cache (`--prefix-cache {on,off}`, default on):
    /// admissions whose prompt extends an already-prefilled prefix
    /// copy the cached K/V rows and prefill only their suffix.
    /// Bit-identical token streams either way — this knob only moves
    /// prefill work, never a token.
    pub prefix_cache: bool,
    /// Best-effort core affinity for each worker's shard-pool lanes
    /// (`--pin-workers {on,off}`, default off): see
    /// [`WorkerPool::new_pinned`]. A placement knob only — refused
    /// pins degrade to the unpinned pool, and tokens are identical
    /// either way.
    pub pin_workers: bool,
}

impl Default for SchedOptions {
    fn default() -> SchedOptions {
        SchedOptions {
            max_slots: 8,
            temperature: 0.0,
            threads: 1,
            shard_workers: 1,
            prefix_cache: true,
            pin_workers: false,
        }
    }
}

/// Terminal record for one request (completed or expired).
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    pub id: u64,
    /// Prompt + generated tokens (empty for expired requests).
    pub tokens: Vec<u32>,
    pub prompt_len: usize,
    pub generated: usize,
    /// True if the admission deadline passed before a slot freed up.
    pub expired: bool,
    pub arrival_step: u64,
    /// Step the request entered a slot (`== arrival_step` for requests
    /// that expired without ever being admitted).
    pub admitted_step: u64,
    pub finished_step: u64,
    /// Step-clock ticks from admission to retirement (0 if expired).
    /// Latency is recorded on the deterministic step clock — the same
    /// clock scheduling runs on — so per-request latency and its
    /// percentiles are identical across runs and thread counts;
    /// [`summarize`] converts to milliseconds with the run's measured
    /// wall-seconds-per-step when reporting.
    pub latency_steps: u64,
}

/// Aggregate serving metrics for one scheduler run.
#[derive(Debug, Clone)]
pub struct SchedStats {
    pub requests: usize,
    pub expired: usize,
    pub tokens_generated: usize,
    /// Final step-clock value (decode steps summed across workers,
    /// plus idle fast-forward jumps).
    pub steps: u64,
    pub wall_seconds: f64,
    /// CPU-seconds of chunked prefill passes plus steps where no slot
    /// was generating yet, summed across workers. This is *work*, not
    /// elapsed time: with `threads > 1` it exceeds the wall time the
    /// prefill overlapped (`wall_seconds` carries the elapsed view),
    /// so dividing token counts by it yields per-core rates.
    pub prefill_seconds: f64,
    /// CPU-seconds of pure generation steps, summed across workers
    /// (same convention as `prefill_seconds`).
    pub decode_seconds: f64,
    /// Prompt positions fed via the headless chunked prefill passes
    /// (summed across workers; each admitted request additionally
    /// feeds its final prompt position through the head-projecting
    /// decode step).
    pub prefill_tokens: usize,
    /// Chunked prefill passes run (summed across workers) —
    /// `ceil((prompt_len - 1) / prefill_chunk)` per admitted request.
    pub prefill_chunks: usize,
    /// Aggregate serving throughput: generated tokens / wall seconds.
    pub tokens_per_second: f64,
    /// Median request latency in milliseconds: the deterministic
    /// step-count percentile scaled by the run's measured
    /// wall-seconds-per-step. The *structure* (which request is the
    /// median, how many steps it took) is bit-stable across runs; only
    /// the ms scale factor carries wall noise.
    pub p50_latency_ms: f64,
    /// 95th-percentile request latency in milliseconds (same
    /// construction as `p50_latency_ms`).
    pub p95_latency_ms: f64,
    /// Mean steps a served request waited between arrival and admission.
    pub mean_wait_steps: f64,
    pub kv_allocated: usize,
    pub kv_reused: usize,
    /// Admissions that attached cached shared-prefix K/V rows instead
    /// of prefilling their full prompt (0 with `--prefix-cache off`).
    pub prefix_hits: usize,
    /// Prompt positions served from the shared-prefix cache — the
    /// exact sum of attached prefix lengths, and exactly the prefill
    /// tokens the cache saved.
    pub prefix_tokens_saved: usize,
    /// `prefix_hits / served` over non-expired, non-empty requests.
    pub prefix_hit_rate: f64,
    /// Heap bytes held by cached prefix segments at the end of the run
    /// (summed across workers' caches when sharded per group).
    pub prefix_cache_bytes: usize,
    /// Heap bytes parked in the KV pools' free buffers at the end of
    /// the run — the high-water pinning signal (summed across
    /// workers).
    pub kv_pool_bytes: usize,
    /// Row-band shard lanes per scheduler worker (1 = serial decode).
    pub shard_workers: usize,
    /// Per-lane seconds spent executing row-band shard jobs, summed
    /// lane-wise across scheduler workers (all zeros when
    /// `shard_workers <= 1` — the pool is never dispatched).
    pub shard_busy_seconds: Vec<f64>,
    /// Per-lane seconds spent idle while a dispatch was in flight —
    /// the shard-imbalance signal (same layout as
    /// `shard_busy_seconds`).
    pub shard_idle_seconds: Vec<f64>,
    /// Weight payload quantization mode of the engine that served the
    /// run (`"none"`, `"int8"`, or `"int4"`) — a build-time property
    /// of the engine, echoed here so bench/serve reports are
    /// self-describing.
    pub quant_mode: &'static str,
    /// N:M structure of the engine's weights (`"off"`, `"2:4"`, or
    /// `"4:8"`) — like `quant_mode`, a build-time property echoed so
    /// bench/serve reports are self-describing.
    pub nm_mode: &'static str,
    /// Kernel traversal the run decoded with (`"scalar"` or
    /// `"unrolled"`). A pure speed knob — within a run the two paths
    /// are bit-identical — but benches compare them, so reports say
    /// which one they measured.
    pub kernel_path: &'static str,
    /// Shard-pool lanes that landed on a requested core, summed
    /// across scheduler workers (0 unless `--pin-workers on` and the
    /// kernel accepted the affinity masks).
    pub pinned_lanes: usize,
    /// Engine weight bytes actually resident (`Engine::mem_bytes`):
    /// the compact quantized buffers when `quant_mode != "none"`.
    pub weight_mem_bytes: usize,
}

/// Continuous-batching scheduler over one [`Engine`].
pub struct Scheduler<'e> {
    engine: &'e Engine,
    opts: SchedOptions,
    /// Shared-prefix KV cache, shared by every worker (`None` with
    /// `--prefix-cache off`). Locked briefly at admission (lookup) and
    /// at prefill completion (insert) — never during a forward pass.
    prefix: Option<Mutex<PrefixCache>>,
}

/// State shared by the scheduler workers.
struct Shared {
    /// Pending requests in (arrival, id) order.
    queue: Mutex<VecDeque<(u64, Request)>>,
    /// The step clock (see module docs).
    clock: AtomicU64,
    /// Requests currently admitted across all workers (idle workers
    /// fast-forward the clock only when this hits zero).
    active: AtomicUsize,
}

/// Per-request bookkeeping the engine-level `Slot` doesn't carry.
struct Meta {
    id: u64,
    arrival_step: u64,
    admitted_step: u64,
    /// Prompt positions attached from the shared-prefix cache at
    /// admission (0 on a cache miss). A finished headless prefill is
    /// published back to the cache only when it fed positions beyond
    /// this point — re-inserting exactly what was attached is noise.
    attached: usize,
}

struct WorkerOut {
    finished: Vec<FinishedRequest>,
    prefill_seconds: f64,
    decode_seconds: f64,
    /// Prompt positions fed via headless chunked prefill passes.
    prefill_tokens: usize,
    /// Chunked prefill passes run.
    prefill_chunks: usize,
    /// Admissions that attached cached shared-prefix K/V rows.
    prefix_hits: usize,
    /// Prompt positions attached from the cache instead of prefilled.
    prefix_tokens_saved: usize,
    kv_allocated: usize,
    kv_reused: usize,
    /// Final heap bytes parked in this worker's KV pool free list.
    kv_pool_bytes: usize,
    /// Per-lane busy/idle seconds of this worker's decode pool.
    shard_busy: Vec<f64>,
    shard_idle: Vec<f64>,
    /// Shard-pool lanes that landed on a requested core.
    pinned_lanes: usize,
}

/// What an idle worker (no local slots) decided at the queue lock.
enum Idle {
    /// Queue drained — the worker's run is over.
    Done,
    /// Whole scheduler idle: the clock jumped to the next arrival;
    /// retry admission immediately.
    FastForwarded,
    /// Other workers still decoding: park briefly off the mutex.
    Park,
}

impl<'e> Scheduler<'e> {
    pub fn new(engine: &'e Engine, opts: SchedOptions) -> Scheduler<'e> {
        let prefix = opts
            .prefix_cache
            .then(|| Mutex::new(PrefixCache::new(DEFAULT_PREFIX_CACHE_BYTES)));
        Scheduler { engine, opts, prefix }
    }

    /// Drain `queue` to completion and return every request's terminal
    /// record (sorted by request id) plus aggregate stats.
    pub fn run(&self, queue: RequestQueue)
               -> (Vec<FinishedRequest>, SchedStats) {
        let n_requests = queue.len();
        let max_slots = self.opts.max_slots.max(1);
        let threads = self.opts.threads.max(1).min(max_slots);
        let shared = Shared {
            queue: Mutex::new(queue.into_deque()),
            clock: AtomicU64::new(0),
            active: AtomicUsize::new(0),
        };
        // TIMING-OK: wall_seconds / throughput reporting only — no
        // scheduling decision reads this clock (those run on the
        // deterministic step clock above).
        let t0 = Instant::now();
        let outs: Vec<WorkerOut> = if threads <= 1 {
            vec![self.worker(&shared, max_slots)]
        } else {
            let shared = &shared;
            std::thread::scope(|sc| {
                let mut handles = Vec::new();
                for w in 0..threads {
                    let cap = max_slots / threads
                        + usize::from(w < max_slots % threads);
                    handles.push(sc.spawn(move || self.worker(shared, cap)));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("scheduler worker panicked"))
                    .collect()
            })
        };
        let wall = t0.elapsed().as_secs_f64();

        let (prefill, decode) = sum_worker_seconds(&outs);
        let prefill_tokens = outs.iter().map(|o| o.prefill_tokens).sum();
        let prefill_chunks = outs.iter().map(|o| o.prefill_chunks).sum();
        let kv_allocated = outs.iter().map(|o| o.kv_allocated).sum();
        let kv_reused = outs.iter().map(|o| o.kv_reused).sum();
        let cache = CacheCounts {
            hits: outs.iter().map(|o| o.prefix_hits).sum(),
            tokens_saved: outs.iter().map(|o| o.prefix_tokens_saved).sum(),
            cache_bytes: self
                .prefix
                .as_ref()
                .map_or(0, |p| p.lock().unwrap().bytes()),
            kv_pool_bytes: outs.iter().map(|o| o.kv_pool_bytes).sum(),
        };
        // lane-wise sums across workers (every worker's pool has the
        // same lane count)
        let lanes = self.opts.shard_workers.max(1);
        let mut shard_busy = vec![0.0f64; lanes];
        let mut shard_idle = vec![0.0f64; lanes];
        let mut pinned_lanes = 0usize;
        for o in &outs {
            for (acc, v) in shard_busy.iter_mut().zip(&o.shard_busy) {
                *acc += v;
            }
            for (acc, v) in shard_idle.iter_mut().zip(&o.shard_idle) {
                *acc += v;
            }
            pinned_lanes += o.pinned_lanes;
        }
        let mut finished: Vec<FinishedRequest> =
            outs.into_iter().flat_map(|o| o.finished).collect();
        finished.sort_by_key(|f| f.id);
        debug_assert_eq!(finished.len(), n_requests,
                         "every request must finish or expire");
        let mut stats = summarize(&finished, wall,
                                  shared.clock.load(Ordering::SeqCst),
                                  prefill, decode,
                                  PrefillCounts { tokens: prefill_tokens,
                                                  chunks: prefill_chunks },
                                  kv_allocated, kv_reused, cache,
                                  ShardTimes { lanes, busy: shard_busy,
                                               idle: shard_idle });
        stats.quant_mode = self.engine.quant.label();
        stats.nm_mode = self.engine.nm.label();
        stats.kernel_path = self.engine.kernel_path.label();
        stats.pinned_lanes = pinned_lanes;
        stats.weight_mem_bytes = self.engine.mem_bytes();
        (finished, stats)
    }

    /// One worker: a batched decode loop over up to `cap` slots that
    /// samples/retires, admits from the shared queue into freed slots,
    /// chunk-prefills every slot still consuming its prompt, then runs
    /// one batched decode step over the slots with one unfed token
    /// left — every iteration, so a request admitted mid-decode starts
    /// prefilling on the very next iteration while its batch-mates
    /// keep generating.
    ///
    /// The live set is packed in slot order (`indices = 0..slots.len()`
    /// after swap-remove retirement), and the engine's kernels —
    /// row-tiled or not, batched head projection included — are
    /// bit-exact per lane regardless of how the set is packed, so the
    /// determinism guarantee in the module docs is independent of
    /// retirement/admission interleaving.
    fn worker(&self, shared: &Shared, cap: usize) -> WorkerOut {
        let engine = self.engine;
        let cfg = &engine.cfg;
        let chunk = engine.prefill_chunk.max(1);
        let mut pool = KvPool::new(cfg.n_layers, cfg.seq_len * cfg.d_model);
        // this worker's persistent row-band shard pool: created once,
        // workers park between decode steps — no spawns in steady
        // state (a 1-lane pool spawns nothing and decode runs serial)
        let shard_pool = WorkerPool::new_pinned(
            self.opts.shard_workers.max(1), self.opts.pin_workers);
        let mut slots: Vec<Slot> = Vec::with_capacity(cap);
        let mut meta: Vec<Meta> = Vec::with_capacity(cap);
        let mut scratch = BatchScratch::new(cfg, cap, chunk);
        let mut indices: Vec<usize> = Vec::with_capacity(cap);
        let mut out = WorkerOut {
            finished: Vec::new(),
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            prefill_tokens: 0,
            prefill_chunks: 0,
            prefix_hits: 0,
            prefix_tokens_saved: 0,
            kv_allocated: 0,
            kv_reused: 0,
            kv_pool_bytes: 0,
            shard_busy: Vec::new(),
            shard_idle: Vec::new(),
            pinned_lanes: 0,
        };
        let mut prefill_jobs: Vec<(usize, usize)> = Vec::with_capacity(cap);

        loop {
            let now = shared.clock.load(Ordering::SeqCst);

            // 1. Sample freshly decoded slots; retire exhausted ones.
            //    (Slots mid-prefill have fed < tokens.len() and skip.)
            let mut i = 0;
            while i < slots.len() {
                let done = {
                    let s = &mut slots[i];
                    if s.fed < s.tokens.len() {
                        false
                    } else if s.logits.is_empty()
                        || s.generated >= s.n_new
                        || s.tokens.len() >= cfg.seq_len
                    {
                        true
                    } else {
                        let next = sample(&s.logits, self.opts.temperature,
                                          &mut s.rng);
                        s.tokens.push(next);
                        s.generated += 1;
                        // if that token hit the budget, its logits would
                        // never be read — retire without the forward pass
                        s.generated >= s.n_new
                            || s.tokens.len() >= cfg.seq_len
                    }
                };
                if done {
                    retire(&mut slots, &mut meta, i, &mut pool, shared,
                           &mut out.finished, now);
                } else {
                    i += 1;
                }
            }

            // 2. Admit arrived requests into freed capacity — this is
            //    the continuous part: admission happens between decode
            //    steps, not at batch boundaries.
            if slots.len() < cap {
                self.admit(shared, cap, &mut slots, &mut meta, &mut pool,
                           &mut out);
            }

            // 3. Idle / termination.
            if slots.is_empty() {
                match idle_step(shared) {
                    Idle::Done => break,
                    Idle::FastForwarded => continue,
                    Idle::Park => {
                        // TIMING-OK: backoff while other workers hold
                        // active slots — affects only when this worker
                        // re-polls, never which step a request is
                        // admitted or retired on (both read the step
                        // clock under the queue lock).
                        std::thread::sleep(
                            std::time::Duration::from_micros(50));
                        continue;
                    }
                }
            }

            // 4. Cross-slot batched chunked prefill: every slot still
            //    holding more than one unfed prompt token contributes
            //    one headless window of up to `prefill_chunk`
            //    positions, and ALL windows run as ONE batched pass —
            //    one trip through each layer's linears for the packed
            //    rows instead of one pass per slot. A long prompt
            //    costs ceil((suffix-1)/chunk) windows, with zero head
            //    projections, while generating batch-mates keep
            //    stepping every iteration.
            prefill_jobs.clear();
            for (i, s) in slots.iter().enumerate() {
                let last = s.tokens.len() - 1;
                if s.fed < last {
                    prefill_jobs.push((i, chunk.min(last - s.fed)));
                }
            }
            if !prefill_jobs.is_empty() {
                let t = Timer::start();
                engine.prefill_pass_multi(&mut slots, &prefill_jobs,
                                          &mut scratch, &shard_pool);
                out.prefill_seconds += t.seconds();
                out.prefill_tokens +=
                    prefill_jobs.iter().map(|(_, n)| n).sum::<usize>();
                out.prefill_chunks += prefill_jobs.len();
                // publish freshly completed headless prefills: a slot
                // that just consumed its last headless window caches
                // prompt[..len-1] for later admissions (skip slots
                // that only replayed an attached prefix)
                if let Some(cache) = self.prefix.as_ref() {
                    let mut cache = cache.lock().unwrap();
                    for &(i, _) in &prefill_jobs {
                        let s = &slots[i];
                        let last = s.tokens.len() - 1;
                        if s.fed == last && last > meta[i].attached {
                            cache.insert(&s.tokens[..last], &s.kvs,
                                         cfg.d_model);
                        }
                    }
                }
            }

            // 5. One batched decode step over every slot with exactly
            //    one unfed token left (its final prompt position —
            //    the request's single head projection — or its freshly
            //    sampled token). Slots still mid-prefill after their
            //    window sit this step out. A step counts as prefill
            //    only when NO slot is generating yet: mixed steps
            //    produce tokens, so their time must land in
            //    decode_seconds or tokens/decode_s would overstate
            //    throughput for ragged prompts.
            indices.clear();
            indices.extend(slots.iter().enumerate()
                .filter(|(_, s)| s.fed + 1 == s.tokens.len())
                .map(|(i, _)| i));
            if !indices.is_empty() {
                let prefilling =
                    slots.iter().all(|s| s.fed < s.prompt_len);
                let t = Timer::start();
                engine.decode_step_batch(&mut slots, &indices,
                                         &mut scratch, &shard_pool);
                let dt = t.seconds();
                if prefilling {
                    out.prefill_seconds += dt;
                } else {
                    out.decode_seconds += dt;
                }
            }
            shared.clock.fetch_add(1, Ordering::SeqCst);
        }
        out.kv_allocated = pool.allocated;
        out.kv_reused = pool.reused;
        out.kv_pool_bytes = pool.bytes();
        let ps = shard_pool.stats();
        out.shard_idle = ps.idle_seconds();
        out.pinned_lanes = ps.pinned_count();
        out.shard_busy = ps.busy_seconds;
        out
    }

    /// Admit arrived requests into this worker's free capacity.
    ///
    /// The clock is read *inside* the queue lock: admission visibility,
    /// deadline expiry, and `admitted_step` all use one coherent `now`
    /// that an idle worker's fast-forward (which also holds this lock,
    /// see [`idle_step`]) cannot move mid-admission. A loop-top clock
    /// read would go stale against a concurrent fast-forward and
    /// expire or mis-stamp requests (`--threads > 1`).
    ///
    /// On a shared-prefix cache hit the new slot starts with the
    /// cached K/V rows copied in and `fed` already past them, so the
    /// prefill loop only feeds the suffix. Lock order is queue →
    /// cache, same as everywhere else.
    fn admit(&self, shared: &Shared, cap: usize, slots: &mut Vec<Slot>,
             meta: &mut Vec<Meta>, pool: &mut KvPool,
             out: &mut WorkerOut) {
        let cfg = &self.engine.cfg;
        let mut q = shared.queue.lock().unwrap();
        let now = shared.clock.load(Ordering::SeqCst);
        while slots.len() < cap {
            if !q.front().is_some_and(|(a, _)| *a <= now) {
                break;
            }
            let (arrival, req) = q.pop_front().unwrap();
            if req.deadline
                .is_some_and(|d| now > arrival.saturating_add(d))
            {
                out.finished.push(FinishedRequest {
                    id: req.id,
                    tokens: Vec::new(),
                    prompt_len: req.prompt.len(),
                    generated: 0,
                    expired: true,
                    arrival_step: arrival,
                    // never admitted: keep wait = 0 rather than
                    // fabricating an admission step
                    admitted_step: arrival,
                    finished_step: now,
                    latency_steps: 0,
                });
                continue;
            }
            if req.prompt.is_empty() {
                // nothing to condition on: retires immediately
                // with zero tokens (same rule as generate_batch)
                out.finished.push(FinishedRequest {
                    id: req.id,
                    tokens: Vec::new(),
                    prompt_len: 0,
                    generated: 0,
                    expired: false,
                    arrival_step: arrival,
                    admitted_step: now,
                    finished_step: now,
                    latency_steps: 0,
                });
                continue;
            }
            assert!(req.prompt.len() <= cfg.seq_len,
                    "request {}: prompt of {} tokens exceeds \
                     seq_len {}", req.id, req.prompt.len(),
                    cfg.seq_len);
            shared.active.fetch_add(1, Ordering::SeqCst);
            let mut kvs = pool.acquire();
            let mut fed = 0usize;
            if let Some(cache) = self.prefix.as_ref() {
                if let Some((seg, n)) =
                    cache.lock().unwrap().lookup(&req.prompt)
                {
                    // copy-on-attach: the cached rows land in this
                    // slot's own buffers, so decode never reads
                    // shared state and the stream stays bit-exact
                    seg.attach(&mut kvs, n, cfg.d_model);
                    fed = n;
                    out.prefix_hits += 1;
                    out.prefix_tokens_saved += n;
                }
            }
            meta.push(Meta {
                id: req.id,
                arrival_step: arrival,
                admitted_step: now,
                attached: fed,
            });
            slots.push(Slot {
                prompt_len: req.prompt.len(),
                tokens: req.prompt,
                fed,
                kvs,
                rng: Rng::new(req.seed),
                logits: vec![],
                generated: 0,
                n_new: req.n_new,
            });
        }
    }
}

/// Decide what an idle worker (no local slots) does, entirely under
/// the queue lock: when the whole scheduler is idle the clock
/// fast-forwards to the *front* (minimum) pending arrival — never
/// past any request another worker could be about to admit, because
/// admission also holds this lock and a concurrent admit either
/// already popped the front entry or will see the forwarded clock.
fn idle_step(shared: &Shared) -> Idle {
    let q = shared.queue.lock().unwrap();
    if q.is_empty() {
        return Idle::Done;
    }
    if shared.active.load(Ordering::SeqCst) == 0 {
        // the whole scheduler is idle: fast-forward the clock to the
        // next arrival instead of spinning through empty steps, and
        // retry admission immediately
        let next = q.front().unwrap().0;
        shared.clock.fetch_max(next, Ordering::SeqCst);
        return Idle::FastForwarded;
    }
    Idle::Park
}

/// Sum each worker's prefill/decode CPU-seconds into run totals.
///
/// Summing (not lane-`max`) is the only reduction consistent with the
/// token counters: `prefill_tokens`/`prefill_chunks` are summed across
/// workers, so a derived tokens-per-second must divide by summed
/// seconds or it overstates multi-worker throughput by up to
/// `threads`×. Elapsed time is reported separately as `wall_seconds`.
fn sum_worker_seconds(outs: &[WorkerOut]) -> (f64, f64) {
    outs.iter().fold((0.0, 0.0), |(p, d), o| {
        (p + o.prefill_seconds, d + o.decode_seconds)
    })
}

/// Lane-wise shard-pool times aggregated across scheduler workers —
/// carried into [`SchedStats`] by [`summarize`].
struct ShardTimes {
    lanes: usize,
    busy: Vec<f64>,
    idle: Vec<f64>,
}

/// Chunked-prefill counters aggregated across scheduler workers.
struct PrefillCounts {
    tokens: usize,
    chunks: usize,
}

/// Shared-prefix-cache and KV-pool memory counters aggregated across
/// scheduler workers — carried into [`SchedStats`] by [`summarize`].
struct CacheCounts {
    hits: usize,
    tokens_saved: usize,
    cache_bytes: usize,
    kv_pool_bytes: usize,
}

fn retire(slots: &mut Vec<Slot>, meta: &mut Vec<Meta>, i: usize,
          pool: &mut KvPool, shared: &Shared,
          finished: &mut Vec<FinishedRequest>, now: u64) {
    let slot = slots.swap_remove(i);
    let m = meta.swap_remove(i);
    pool.release(slot.kvs);
    shared.active.fetch_sub(1, Ordering::SeqCst);
    finished.push(FinishedRequest {
        id: m.id,
        prompt_len: slot.prompt_len,
        generated: slot.generated,
        tokens: slot.tokens,
        expired: false,
        arrival_step: m.arrival_step,
        admitted_step: m.admitted_step,
        finished_step: now,
        latency_steps: now - m.admitted_step,
    });
}

fn summarize(finished: &[FinishedRequest], wall: f64, steps: u64,
             prefill: f64, decode: f64, pre: PrefillCounts,
             kv_allocated: usize, kv_reused: usize, cache: CacheCounts,
             shard: ShardTimes) -> SchedStats {
    let tokens: usize = finished.iter().map(|f| f.generated).sum();
    let expired = finished.iter().filter(|f| f.expired).count();
    // Per-request latency is recorded in deterministic step-clock
    // ticks; only the ms scale factor below touches the wall clock, so
    // which request lands on p50/p95 (and how many steps it took) is
    // identical across runs and thread counts.
    let ms_per_step = wall * 1e3 / steps.max(1) as f64;
    let mut lat = Summary::new();
    let mut wait = 0u64;
    let mut served = 0usize;
    for f in finished.iter().filter(|f| !f.expired && f.prompt_len > 0) {
        lat.push(f.latency_steps as f64 * ms_per_step);
        wait += f.admitted_step - f.arrival_step;
        served += 1;
    }
    SchedStats {
        requests: finished.len(),
        expired,
        tokens_generated: tokens,
        steps,
        wall_seconds: wall,
        prefill_seconds: prefill,
        decode_seconds: decode,
        prefill_tokens: pre.tokens,
        prefill_chunks: pre.chunks,
        tokens_per_second: tokens as f64 / wall.max(1e-9),
        p50_latency_ms: if lat.n() == 0 { 0.0 } else { lat.median() },
        p95_latency_ms: if lat.n() == 0 { 0.0 } else { lat.percentile(95.0) },
        mean_wait_steps: if served == 0 {
            0.0
        } else {
            wait as f64 / served as f64
        },
        kv_allocated,
        kv_reused,
        prefix_hits: cache.hits,
        prefix_tokens_saved: cache.tokens_saved,
        prefix_hit_rate: cache.hits as f64 / served.max(1) as f64,
        prefix_cache_bytes: cache.cache_bytes,
        kv_pool_bytes: cache.kv_pool_bytes,
        shard_workers: shard.lanes,
        shard_busy_seconds: shard.busy,
        shard_idle_seconds: shard.idle,
        // overwritten by callers that hold the engine
        quant_mode: "none",
        nm_mode: "off",
        kernel_path: "scalar",
        pinned_lanes: 0,
        weight_mem_bytes: 0,
    }
}

/// Seeded ragged token budgets in `[base/3, base)`: the staggered
/// completion times are what continuous admission exploits, so the
/// bench, the tab1 table and the serving example all draw their
/// request budgets from this one distribution (deterministic per
/// seed).
pub fn ragged_budgets(base: usize, n: usize, seed: u64) -> Vec<usize> {
    let mut rng = Rng::new(seed);
    let lo = (base / 3).max(1);
    (0..n).map(|_| lo + rng.below((base - lo).max(1))).collect()
}

/// Static-batching reference policy on the same machinery: admit
/// requests strictly in id order in groups of `opts.max_slots` and
/// drain each group completely before the next is admitted (ignoring
/// arrival steps — the group launches as one fixed batch). Per-request
/// token streams are bit-identical to the continuous scheduler; only
/// the admission policy differs, which is exactly what
/// `bench_scheduler` measures. The `threads` / `shard_workers` knobs
/// in `opts` apply to each group.
pub fn serve_static_chunks(engine: &Engine, requests: &[Request],
                           opts: &SchedOptions)
                           -> (Vec<FinishedRequest>, SchedStats) {
    let max_slots = opts.max_slots.max(1);
    let lanes = opts.shard_workers.max(1);
    // TIMING-OK: wall_seconds / throughput reporting only.
    let t0 = Instant::now();
    let mut finished = Vec::with_capacity(requests.len());
    let (mut prefill, mut decode) = (0.0f64, 0.0f64);
    let mut pre = PrefillCounts { tokens: 0, chunks: 0 };
    let mut steps = 0u64;
    let (mut kv_allocated, mut kv_reused) = (0usize, 0usize);
    let mut pinned_lanes = 0usize;
    // each group runs its own Scheduler, hence its own prefix cache:
    // sharing stays within a group, and the totals below sum groups
    let mut cache = CacheCounts {
        hits: 0,
        tokens_saved: 0,
        cache_bytes: 0,
        kv_pool_bytes: 0,
    };
    let mut shard = ShardTimes {
        lanes,
        busy: vec![0.0; lanes],
        idle: vec![0.0; lanes],
    };
    for chunk in requests.chunks(max_slots) {
        let mut q = RequestQueue::new();
        for r in chunk {
            q.push(r.clone());
        }
        let sched = Scheduler::new(engine, SchedOptions {
            max_slots: chunk.len(),
            ..opts.clone()
        });
        let (f, st) = sched.run(q);
        finished.extend(f);
        prefill += st.prefill_seconds;
        decode += st.decode_seconds;
        pre.tokens += st.prefill_tokens;
        pre.chunks += st.prefill_chunks;
        steps += st.steps;
        kv_allocated += st.kv_allocated;
        kv_reused += st.kv_reused;
        cache.hits += st.prefix_hits;
        cache.tokens_saved += st.prefix_tokens_saved;
        cache.cache_bytes += st.prefix_cache_bytes;
        cache.kv_pool_bytes += st.kv_pool_bytes;
        pinned_lanes += st.pinned_lanes;
        for (acc, v) in shard.busy.iter_mut()
            .zip(&st.shard_busy_seconds) {
            *acc += v;
        }
        for (acc, v) in shard.idle.iter_mut()
            .zip(&st.shard_idle_seconds) {
            *acc += v;
        }
    }
    finished.sort_by_key(|f| f.id);
    let wall = t0.elapsed().as_secs_f64();
    let mut stats = summarize(&finished, wall, steps, prefill, decode, pre,
                              kv_allocated, kv_reused, cache, shard);
    stats.quant_mode = engine.quant.label();
    stats.nm_mode = engine.nm.label();
    stats.kernel_path = engine.kernel_path.label();
    stats.pinned_lanes = pinned_lanes;
    stats.weight_mem_bytes = engine.mem_bytes();
    (finished, stats)
}

/// Parse `--prefix-cache {on,off}` (also accepts true/false, 1/0,
/// yes/no; a bare `--prefix-cache` means on). Defaults to on.
pub fn prefix_cache_flag(args: &Args) -> Result<bool> {
    match args.get("prefix-cache") {
        None => Ok(true),
        Some(v) => match v.to_ascii_lowercase().as_str() {
            "on" | "true" | "1" | "yes" => Ok(true),
            "off" | "false" | "0" | "no" => Ok(false),
            other => anyhow::bail!(
                "--prefix-cache expects on|off, got {other:?}"),
        },
    }
}

/// Parse `--pin-workers {on,off}` (also accepts true/false, 1/0,
/// yes/no; a bare `--pin-workers` means on). Defaults to off —
/// pinning is an opt-in placement hint, see
/// [`WorkerPool::new_pinned`].
pub fn pin_workers_flag(args: &Args) -> Result<bool> {
    match args.get("pin-workers") {
        None => Ok(false),
        Some(v) => match v.to_ascii_lowercase().as_str() {
            "on" | "true" | "1" | "yes" => Ok(true),
            "off" | "false" | "0" | "no" => Ok(false),
            other => anyhow::bail!(
                "--pin-workers expects on|off, got {other:?}"),
        },
    }
}

/// `elsa serve` subcommand: load a checkpoint, synthesize a seeded
/// request stream with Poisson-ish arrivals, and drain it through the
/// continuous-batching scheduler.
pub fn cmd_serve(args: &Args) -> Result<()> {
    let rt = crate::commands::open_runtime(args)?;
    let ck = crate::model::checkpoint::Checkpoint::load(
        &std::path::PathBuf::from(args.require("ckpt")?))?;
    let cfg = rt.manifest.config(&ck.config)?.clone();
    let params = crate::model::Params::new(&cfg, ck.get("params")?.clone());
    let backend = super::Backend::parse(&args.str_or("backend", "macko"))
        .ok_or_else(|| anyhow::anyhow!("bad --backend"))?;
    let quant =
        crate::sparse::QuantMode::parse(&args.str_or("quant", "none"))?;
    let nm = crate::sparse::NmMode::parse(&args.str_or("nm", "off"))?;
    let mut engine = Engine::build_full(&params, backend, quant, nm)?;
    engine.tiled = !args.bool("untiled");
    if let Some(p) = args.get("kernel-path") {
        engine.kernel_path = crate::sparse::KernelPath::parse(p)?;
    }
    engine.prefill_chunk = args
        .usize_or("prefill-chunk", super::DEFAULT_PREFILL_CHUNK)?
        .max(1);

    let g = crate::data::Grammar::named(
        &args.str_or("dataset", "synth-c4"), cfg.vocab);
    let n_requests = args.usize_or("requests", 32)?;
    let max_slots = args.usize_or("max-slots", 8)?;
    let threads = args.usize_or("threads", 1)?;
    let shard_workers = args.usize_or("shard-workers", 1)?;
    let prefix_cache = prefix_cache_flag(args)?;
    let pin_workers = pin_workers_flag(args)?;
    let prompt_len = args.usize_or("prompt-len", 8)?;
    anyhow::ensure!(prompt_len <= cfg.seq_len,
                    "--prompt-len {prompt_len} exceeds the model's \
                     seq_len {}", cfg.seq_len);
    let n_new =
        args.usize_or("tokens", cfg.seq_len.saturating_sub(prompt_len))?;
    let seed = args.usize_or("seed", 0)? as u64;
    let temperature = args.f32_or("temp", 0.8)?;
    let gap = args.f64_or("arrival-gap", 2.0)?;
    let deadline = match args.get("deadline") {
        Some(v) => {
            Some(v.parse::<u64>().with_context(|| format!("--deadline {v}"))?)
        }
        None => None,
    };

    let reqs: Vec<Request> = (0..n_requests)
        .map(|r| Request {
            id: r as u64,
            prompt: g.generate(prompt_len, seed.wrapping_add(r as u64)),
            n_new,
            seed: seed.wrapping_add(r as u64),
            deadline,
        })
        .collect();
    let queue = RequestQueue::with_poisson_arrivals(
        reqs, gap, seed.wrapping_add(0x5eed));
    let sched = Scheduler::new(&engine, SchedOptions {
        max_slots,
        temperature,
        threads,
        shard_workers,
        prefix_cache,
        pin_workers,
    });
    let (finished, stats) = sched.run(queue);

    if args.bool("verbose") {
        for f in &finished {
            if f.expired {
                println!("req {:4}: arrived {:5} EXPIRED at {:5} \
                          (never admitted)",
                         f.id, f.arrival_step, f.finished_step);
            } else {
                println!("req {:4}: arrived {:5} admitted {:5} finished \
                          {:5} | {:3} new tokens | {:5} steps",
                         f.id, f.arrival_step, f.admitted_step,
                         f.finished_step, f.generated, f.latency_steps);
            }
        }
    }
    println!("backend {:?}", backend);
    println!("quant {}", stats.quant_mode);
    println!("nm {} kernel_path {}", stats.nm_mode, stats.kernel_path);
    println!("sparsity {:.4}", params.sparsity());
    println!("requests {} expired {}", stats.requests, stats.expired);
    println!("max_slots {max_slots} threads {threads} \
              shard_workers {shard_workers} arrival_gap {gap}");
    println!("pin_workers {} pinned_lanes {}",
             if pin_workers { "on" } else { "off" },
             stats.pinned_lanes);
    println!("tokens_generated {}", stats.tokens_generated);
    println!("agg_tokens_per_s {:.2}", stats.tokens_per_second);
    println!("p50_ms {:.2}", stats.p50_latency_ms);
    println!("p95_ms {:.2}", stats.p95_latency_ms);
    println!("mean_wait_steps {:.2}", stats.mean_wait_steps);
    println!("steps {}", stats.steps);
    println!("prefill_tokens {} in {} chunk passes (chunk {})",
             stats.prefill_tokens, stats.prefill_chunks,
             engine.prefill_chunk);
    println!("prefix_cache {} hits {} tokens_saved {} hit_rate {:.3} \
              cache_bytes {}",
             if prefix_cache { "on" } else { "off" }, stats.prefix_hits,
             stats.prefix_tokens_saved, stats.prefix_hit_rate,
             stats.prefix_cache_bytes);
    println!("kv_allocated {} kv_reused {} kv_pool_bytes {}",
             stats.kv_allocated, stats.kv_reused, stats.kv_pool_bytes);
    if shard_workers > 1 {
        let busy: f64 = stats.shard_busy_seconds.iter().sum();
        let idle: f64 = stats.shard_idle_seconds.iter().sum();
        println!("shard_busy_s {busy:.4} shard_idle_s {idle:.4} \
                  (per lane: {:?})",
                 stats.shard_busy_seconds.iter()
                     .map(|s| (s * 1e3).round() / 1e3)
                     .collect::<Vec<_>>());
    }
    println!("mem {}", crate::util::human_bytes(engine.mem_bytes()));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::Backend;
    use crate::model::{fake_config, Params};

    #[test]
    fn kvpool_recycles_buffers_without_reallocating() {
        let mut pool = KvPool::new(2, 64);
        let mut a = pool.acquire();
        assert_eq!(pool.allocated, 1);
        assert_eq!(a.len(), 2);
        a[0].k.extend_from_slice(&[1.0; 40]);
        a[0].len = 10;
        pool.release(a);
        assert_eq!(pool.pooled(), 1);
        let b = pool.acquire();
        assert_eq!(pool.allocated, 1, "release->acquire must not allocate");
        assert_eq!(pool.reused, 1);
        assert_eq!(b[0].len, 0, "recycled buffers must come back empty");
        assert!(b[0].k.is_empty());
        assert!(b[0].k.capacity() >= 40, "capacity must be retained");
    }

    #[test]
    fn kvpool_shrinks_after_long_then_short_workload() {
        let mut pool = KvPool::new(1, 10_000);
        // one long-prompt request grows its buffers to ~8000 floats
        let mut long = pool.acquire();
        long[0].k.resize(8000, 0.0);
        long[0].v.resize(8000, 0.0);
        long[0].len = 200;
        pool.release(long);
        // then the workload turns short: once the long release ages
        // out of the rolling window, the shrink policy must trim the
        // pinned buffers instead of holding peak bytes forever
        for _ in 0..KV_RECENT_WINDOW {
            let mut kvs = pool.acquire();
            kvs[0].k.resize(100, 0.0);
            kvs[0].v.resize(100, 0.0);
            kvs[0].len = 2;
            pool.release(kvs);
        }
        assert!(pool.shrunk > 0, "shrink policy never fired");
        assert!(pool.bytes() < 8000 * 4,
                "pool still pins peak bytes: {}", pool.bytes());
        assert!(pool.bytes()
                    <= 2 * 100 * KV_SHRINK_MULT * 4 * pool.pooled(),
                "trim must land at watermark * KV_SHRINK_MULT");
    }

    #[test]
    fn steady_long_workloads_keep_their_capacity() {
        let mut pool = KvPool::new(1, 10_000);
        for _ in 0..2 * KV_RECENT_WINDOW {
            let mut kvs = pool.acquire();
            kvs[0].k.resize(4000, 0.0);
            kvs[0].v.resize(4000, 0.0);
            kvs[0].len = 100;
            pool.release(kvs);
        }
        assert_eq!(pool.shrunk, 0,
                   "uniform long workload must never thrash");
        assert_eq!(pool.allocated, 1);
    }

    #[test]
    fn worker_seconds_sum_across_lanes() {
        // 2-worker invariant: prefill/decode seconds must reduce by
        // SUM to match the summed token counters — the old lane-max
        // reduction reported 3.0/5.0 here and overstated derived
        // multi-worker tok/s by ~2x
        let lane = |p: f64, d: f64| WorkerOut {
            finished: Vec::new(),
            prefill_seconds: p,
            decode_seconds: d,
            prefill_tokens: 0,
            prefill_chunks: 0,
            prefix_hits: 0,
            prefix_tokens_saved: 0,
            kv_allocated: 0,
            kv_reused: 0,
            kv_pool_bytes: 0,
            shard_busy: Vec::new(),
            shard_idle: Vec::new(),
            pinned_lanes: 0,
        };
        let outs = vec![lane(1.0, 2.0), lane(3.0, 5.0)];
        let (prefill, decode) = sum_worker_seconds(&outs);
        assert_eq!(prefill, 4.0);
        assert_eq!(decode, 7.0);
    }

    fn shared_with(queue: Vec<(u64, Request)>, clock: u64,
                   active: usize) -> Shared {
        Shared {
            queue: Mutex::new(queue.into_iter().collect()),
            clock: AtomicU64::new(clock),
            active: AtomicUsize::new(active),
        }
    }

    fn simple_req(id: u64, deadline: Option<u64>) -> Request {
        Request {
            id,
            prompt: vec![1, 2, 3],
            n_new: 1,
            seed: id,
            deadline,
        }
    }

    #[test]
    fn admission_checks_deadlines_against_the_live_clock() {
        let p = Params::init(&fake_config(), 4);
        let engine = Engine::build(&p, Backend::Macko).unwrap();
        let sched = Scheduler::new(&engine, SchedOptions::default());
        let shared =
            shared_with(vec![(0, simple_req(0, Some(3)))], 0, 0);
        // the TOCTOU: a worker reads the clock at its loop top (0),
        // then an idle peer fast-forwards it past this request's
        // deadline before admission runs
        let stale_now = shared.clock.load(Ordering::SeqCst);
        assert_eq!(stale_now, 0);
        shared.clock.store(10, Ordering::SeqCst);
        let mut slots = Vec::new();
        let mut meta = Vec::new();
        let mut pool = KvPool::new(engine.cfg.n_layers,
                                   engine.cfg.seq_len * engine.cfg.d_model);
        let mut out = WorkerOut {
            finished: Vec::new(),
            prefill_seconds: 0.0,
            decode_seconds: 0.0,
            prefill_tokens: 0,
            prefill_chunks: 0,
            prefix_hits: 0,
            prefix_tokens_saved: 0,
            kv_allocated: 0,
            kv_reused: 0,
            kv_pool_bytes: 0,
            shard_busy: Vec::new(),
            shard_idle: Vec::new(),
            pinned_lanes: 0,
        };
        sched.admit(&shared, 4, &mut slots, &mut meta, &mut pool,
                    &mut out);
        // admission must judge the deadline by the LIVE clock (10 >
        // 0 + 3), not the stale loop-top read (0) that would have
        // admitted an expired request and skewed wait stats
        assert!(slots.is_empty());
        assert_eq!(out.finished.len(), 1);
        assert!(out.finished[0].expired);
        assert_eq!(out.finished[0].finished_step, 10);
    }

    #[test]
    fn idle_fast_forward_jumps_to_the_minimum_pending_arrival() {
        let shared = shared_with(
            vec![(5, simple_req(0, None)), (9, simple_req(1, None))],
            0, 0);
        assert!(matches!(idle_step(&shared), Idle::FastForwarded));
        // only to the FRONT arrival — never past a request another
        // worker could be about to admit
        assert_eq!(shared.clock.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn idle_worker_parks_while_peers_are_decoding() {
        let shared =
            shared_with(vec![(5, simple_req(0, None))], 0, 1);
        assert!(matches!(idle_step(&shared), Idle::Park));
        assert_eq!(shared.clock.load(Ordering::SeqCst), 0,
                   "clock must not move while any slot is active");
    }

    #[test]
    fn idle_worker_terminates_on_a_drained_queue() {
        let shared = shared_with(Vec::new(), 7, 0);
        assert!(matches!(idle_step(&shared), Idle::Done));
    }

    #[test]
    fn threaded_deadline_expiry_is_seeded_and_stable() {
        // regression stress for the fast-forward race: staggered
        // arrivals with tight deadlines under threads=2 previously
        // interleaved badly (a worker could fast-forward past an
        // arrival a peer was admitting); post-fix every non-expired
        // stream must still match single-sequence generate exactly
        let p = Params::init(&fake_config(), 4);
        let engine = Engine::build(&p, Backend::Macko).unwrap();
        for trial in 0..4u64 {
            let reqs: Vec<Request> = (0..12u64)
                .map(|id| Request {
                    id,
                    prompt: vec![1 + (id % 5) as u32, 2, 3],
                    n_new: 2,
                    seed: 50 + id,
                    deadline: Some(1),
                })
                .collect();
            let queue = RequestQueue::with_poisson_arrivals(
                reqs, 2.0, 0xBAD + trial);
            let sched = Scheduler::new(&engine, SchedOptions {
                max_slots: 2,
                temperature: 0.6,
                threads: 2,
                ..SchedOptions::default()
            });
            let (finished, stats) = sched.run(queue);
            assert_eq!(finished.len(), 12);
            for f in finished.iter().filter(|f| !f.expired) {
                let (want, _) = engine.generate(
                    &[1 + (f.id % 5) as u32, 2, 3], 2, 0.6, 50 + f.id);
                assert_eq!(f.tokens, want,
                           "trial {trial} req {} diverged", f.id);
            }
            assert_eq!(
                stats.expired,
                finished.iter().filter(|f| f.expired).count());
        }
    }

    #[test]
    fn shared_prefix_hits_skip_suffix_prefill_work() {
        let p = Params::init(&fake_config(), 4);
        let engine = Engine::build(&p, Backend::Macko).unwrap();
        let prompt: Vec<u32> = vec![4, 5, 6, 7, 1];
        let reqs = |n: u64| -> RequestQueue {
            let mut q = RequestQueue::new();
            for id in 0..n {
                // spaced arrivals: each request completes (and
                // publishes its prefix) before the next admits
                q.push_at(id * 64, Request {
                    id,
                    prompt: prompt.clone(),
                    n_new: 2,
                    seed: 9 + id,
                    deadline: None,
                });
            }
            q
        };
        let on = Scheduler::new(&engine, SchedOptions::default());
        let (fin_on, st_on) = on.run(reqs(4));
        let off = Scheduler::new(&engine, SchedOptions {
            prefix_cache: false,
            ..SchedOptions::default()
        });
        let (fin_off, st_off) = off.run(reqs(4));
        for (a, b) in fin_on.iter().zip(fin_off.iter()) {
            assert_eq!(a.tokens, b.tokens,
                       "prefix cache changed req {}", a.id);
        }
        assert_eq!(st_off.prefix_hits, 0);
        assert_eq!(st_off.prefix_tokens_saved, 0);
        // req 0 cold-prefills and publishes prompt[..4]; reqs 1..3
        // each attach those 4 positions (cap len-1 = 4)
        assert_eq!(st_on.prefix_hits, 3);
        assert_eq!(st_on.prefix_tokens_saved, 3 * (prompt.len() - 1));
        assert!(st_on.prefix_cache_bytes > 0);
        assert_eq!(st_on.prefill_tokens + st_on.prefix_tokens_saved,
                   st_off.prefill_tokens,
                   "saved tokens must equal skipped prefill work");
    }

    #[test]
    fn poisson_arrivals_are_deterministic_and_sorted() {
        let reqs = |n: u64| -> Vec<Request> {
            (0..n)
                .map(|id| Request {
                    id,
                    prompt: vec![1],
                    n_new: 1,
                    seed: id,
                    deadline: None,
                })
                .collect()
        };
        let a = RequestQueue::with_poisson_arrivals(reqs(16), 3.0, 9)
            .into_deque();
        let b = RequestQueue::with_poisson_arrivals(reqs(16), 3.0, 9)
            .into_deque();
        let steps_a: Vec<u64> = a.iter().map(|(s, _)| *s).collect();
        let steps_b: Vec<u64> = b.iter().map(|(s, _)| *s).collect();
        assert_eq!(steps_a, steps_b, "same seed must replay arrivals");
        assert!(steps_a.windows(2).all(|w| w[0] <= w[1]));
        assert!(*steps_a.last().unwrap() > 0, "arrivals should stagger");
    }

    #[test]
    fn unsorted_pushes_are_served_in_arrival_order() {
        let mut q = RequestQueue::new();
        let req = |id| Request {
            id,
            prompt: vec![1],
            n_new: 1,
            seed: id,
            deadline: None,
        };
        q.push_at(9, req(0));
        q.push_at(2, req(1));
        q.push_at(2, req(2));
        let d = q.into_deque();
        let order: Vec<u64> = d.iter().map(|(_, r)| r.id).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn scheduler_smoke_matches_generate() {
        let p = Params::init(&fake_config(), 4);
        let engine = Engine::build(&p, Backend::Macko).unwrap();
        let mut q = RequestQueue::new();
        for id in 0..3u64 {
            q.push_at(id, Request {
                id,
                prompt: vec![1 + id as u32, 2, 3],
                n_new: 3,
                seed: 10 + id,
                deadline: None,
            });
        }
        let sched = Scheduler::new(&engine, SchedOptions {
            max_slots: 2,
            temperature: 0.7,
            ..SchedOptions::default()
        });
        let (finished, stats) = sched.run(q);
        assert_eq!(finished.len(), 3);
        assert_eq!(stats.expired, 0);
        for f in &finished {
            let (want, _) = engine.generate(
                &[1 + f.id as u32, 2, 3], 3, 0.7, 10 + f.id);
            assert_eq!(f.tokens, want, "req {}", f.id);
        }
        assert_eq!(stats.tokens_generated,
                   finished.iter().map(|f| f.generated).sum::<usize>());
    }
}

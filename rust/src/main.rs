//! `elsa` CLI — leader entrypoint. See cli.rs for subcommands.

fn main() {
    if let Err(e) = elsa::run_cli() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

//! N:M semi-structured sparse format (ISSUE 8 tentpole).
//!
//! [`NmSparse<N, M>`] stores a weight whose transpose rows obey an
//! `N:M` pattern: every group of `M` consecutive input columns holds
//! at most `N` nonzeros. The format stores **exactly** `N` slots per
//! group — a 4-byte value plus a 1-byte in-group column offset, groups
//! row-major and contiguous — padding short groups with explicit
//! zeros. That fixed slot count is the whole point: the matvec/SpMM
//! inner loop is a compile-time-constant `N`-trip walk with no
//! per-row branching (CSR's `row_ptr[o]..row_ptr[o+1]` bounds and
//! MACKO's bitmap scans both branch per row), which is what lets the
//! optimizer keep the accumulators in vector registers. `N` and `M`
//! are const generics, so a malformed pattern (`N > M`, `M > 256`)
//! fails at compile time, and the only two instantiations the engine
//! builds — 2:4 and 4:8, the patterns one-shot pruners like ALPS
//! target — are selected through [`NmMode`]/[`NmWeights`].
//!
//! Construction verifies the pattern against the pruned f32
//! checkpoint and rejects violations loudly (`ensure!`): a group with
//! more than `N` nonzeros, or an input dimension not divisible by
//! `M`, is a checkpoint bug, never something to paper over.
//!
//! ## Bit-exactness
//!
//! Every traversal — single-vector, batched, row-tiled, pooled
//! row-band shards, and both [`KernelPath`]s — accumulates each
//! output row in the identical order: groups ascending, slots
//! ascending within the group, padded slots included (`acc += 0.0 *
//! x` evaluated like any other slot, so the order never depends on
//! which slots happen to be padding). The unrolled paths only change
//! *which independent accumulator* advances next (4 output rows at
//! batch 1, 4 batch lanes otherwise), never the order within one
//! accumulator — so `NmSparse` joins Regime A of the determinism
//! contract exactly like every other format (see
//! `docs/ARCHITECTURE.md` §3).

use anyhow::{bail, ensure, Result};

use super::tile::{self, RowTiled, Tile, TilePlan};
use super::{axpy_lanes, transpose_batch_into, KernelPath, SpmmScratch};
use crate::tensor::Matrix;

/// The engine-facing N:M selector: `--nm {off,2:4,4:8}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NmMode {
    /// No N:M structure — the backend's general format serves.
    #[default]
    Off,
    /// 2 nonzeros per 4 input columns (50% density).
    N2M4,
    /// 4 nonzeros per 8 input columns (50% density, wider groups).
    N4M8,
}

impl NmMode {
    pub fn parse(s: &str) -> Result<NmMode> {
        Ok(match s {
            "off" => NmMode::Off,
            "2:4" => NmMode::N2M4,
            "4:8" => NmMode::N4M8,
            other => bail!("unknown N:M mode '{other}' \
                            (expected off, 2:4 or 4:8)"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            NmMode::Off => "off",
            NmMode::N2M4 => "2:4",
            NmMode::N4M8 => "4:8",
        }
    }

    /// Nonzeros per group (0 when off).
    pub fn n(self) -> usize {
        match self {
            NmMode::Off => 0,
            NmMode::N2M4 => 2,
            NmMode::N4M8 => 4,
        }
    }

    /// Group width in input columns (0 when off).
    pub fn m(self) -> usize {
        match self {
            NmMode::Off => 0,
            NmMode::N2M4 => 4,
            NmMode::N4M8 => 8,
        }
    }

    pub fn is_on(self) -> bool {
        self != NmMode::Off
    }
}

/// N:M weight over W^T rows: row `o` holds `n_in / M` groups of
/// exactly `N` (value, in-group offset) slots, groups ascending,
/// short groups padded with explicit zero slots.
#[derive(Debug, Clone)]
pub struct NmSparse<const N: usize, const M: usize> {
    pub n_out: usize,
    pub n_in: usize,
    /// Real (pre-padding) nonzero count, for honest density reporting.
    nnz: usize,
    /// `n_out * (n_in / M) * N` values, padded slots hold `0.0`.
    pub values: Vec<f32>,
    /// Per-slot column offset within its `M`-group (`0..M`); padded
    /// slots hold `0` (their value is zero, so the column is inert).
    pub offsets: Vec<u8>,
    /// Row-tiled execution plan (traversal metadata only, excluded
    /// from [`NmSparse::mem_bytes`]).
    pub plan: TilePlan,
}

impl<const N: usize, const M: usize> NmSparse<N, M> {
    /// Compile-time pattern check: referencing this constant rejects
    /// a malformed instantiation (`N > M`, zero-width groups, offsets
    /// that would not fit the u8 table) during monomorphization.
    const PATTERN_OK: usize = {
        assert!(N >= 1 && N <= M && M <= 256, "malformed N:M pattern");
        0
    };

    /// Slots stored per output row (uniform — the fixed trip count).
    #[inline(always)]
    fn slots_per_row(&self) -> usize {
        (self.n_in / M) * N
    }

    /// Bytes of payload per row: 4 B value + 1 B offset per slot.
    #[inline(always)]
    fn row_bytes(&self) -> usize {
        self.slots_per_row() * 5
    }

    /// Build from a (din, dout) weight matrix (x @ W orientation),
    /// verifying the N:M pattern group by group. A group with more
    /// than `N` nonzeros or a `din` not divisible by `M` is rejected
    /// loudly — run the checkpoint through [`nm_project`] (or an
    /// N:M-aware pruner) first if it is not already structured.
    pub fn from_weight(w: &Matrix) -> Result<NmSparse<N, M>> {
        let _ = Self::PATTERN_OK;
        let (din, dout) = (w.rows, w.cols);
        ensure!(din % M == 0,
                "N:M ({N}:{M}) needs the input dimension divisible by \
                 {M}, got {din}");
        let gpr = din / M;
        let spr = gpr * N;
        let mut values = Vec::with_capacity(dout * spr);
        let mut offsets: Vec<u8> = Vec::with_capacity(dout * spr);
        let mut nnz = 0usize;
        for c in 0..dout {
            for g in 0..gpr {
                let mut cnt = 0usize;
                for j in 0..M {
                    let v = w.at(g * M + j, c);
                    if v != 0.0 {
                        let lo = g * M;
                        let hi = g * M + M;
                        ensure!(cnt < N,
                                "N:M ({N}:{M}) pattern violation: \
                                 output row {c}, input group {g} \
                                 (rows {lo}..{hi}) has more than {N} \
                                 nonzeros");
                        values.push(v);
                        offsets.push(j as u8);
                        cnt += 1;
                        nnz += 1;
                    }
                }
                // pad to the fixed N slots — the branch-free kernels
                // walk exactly N entries per group, always
                while cnt < N {
                    values.push(0.0);
                    offsets.push(0);
                    cnt += 1;
                }
            }
        }
        let plan = TilePlan::from_row_bytes(dout, |_| spr * 5);
        Ok(NmSparse { n_out: dout, n_in: din, nnz, values, offsets, plan })
    }

    /// One output row's accumulation — THE reference order every
    /// other traversal replays: groups ascending, the fixed `N` slots
    /// ascending within each group, one sequential accumulator.
    #[inline(always)]
    fn row_acc(&self, o: usize, x: &[f32]) -> f32 {
        let gpr = self.n_in / M;
        let spr = gpr * N;
        let mut acc = 0.0f32;
        for g in 0..gpr {
            let x0 = g * M;
            let sb = o * spr + g * N;
            for j in 0..N {
                let k = sb + j;
                // SAFETY: `from_weight` lays out exactly `spr` slots
                // per output row, so `k < n_out * spr == values.len()
                // == offsets.len()`; every stored offset is `< M`, so
                // `x0 + offset < gpr * M == n_in == x.len()`
                // (debug-asserted by the callers).
                acc += unsafe {
                    *self.values.get_unchecked(k)
                        * *x.get_unchecked(
                            x0 + *self.offsets.get_unchecked(k) as usize)
                };
            }
        }
        acc
    }

    /// y = W^T x. The inner loop has a compile-time-constant `N` trip
    /// count per group — no per-row length branch. `Unrolled`
    /// processes four output rows per pass with four independent
    /// accumulators (per-row order unchanged, so both paths are
    /// bit-identical); `Scalar` is the one-row-at-a-time reference.
    pub fn matvec(&self, x: &[f32], y: &mut [f32], path: KernelPath) {
        debug_assert_eq!(x.len(), self.n_in);
        debug_assert_eq!(y.len(), self.n_out);
        match path {
            KernelPath::Scalar => {
                for (o, yo) in y.iter_mut().enumerate() {
                    *yo = self.row_acc(o, x);
                }
            }
            KernelPath::Unrolled => {
                const RO: usize = 4;
                let gpr = self.n_in / M;
                let spr = gpr * N;
                let blocks = self.n_out / RO;
                for blk in 0..blocks {
                    let o0 = blk * RO;
                    let mut acc = [0.0f32; RO];
                    for g in 0..gpr {
                        let x0 = g * M;
                        for (r, a) in acc.iter_mut().enumerate() {
                            let sb = (o0 + r) * spr + g * N;
                            for j in 0..N {
                                let k = sb + j;
                                // SAFETY: same layout argument as
                                // `row_acc` — `o0 + r < n_out` keeps
                                // `k` under `n_out * spr ==
                                // values.len() == offsets.len()`, and
                                // offsets `< M` keep the `x` lookup
                                // under `n_in`.
                                *a += unsafe {
                                    *self.values.get_unchecked(k)
                                        * *x.get_unchecked(
                                            x0 + *self.offsets
                                                .get_unchecked(k)
                                                as usize)
                                };
                            }
                        }
                    }
                    y[o0..o0 + RO].copy_from_slice(&acc);
                }
                for o in blocks * RO..self.n_out {
                    y[o] = self.row_acc(o, x);
                }
            }
        }
    }

    /// Multi-vector SpMM, untiled scalar reference (the analogue of
    /// [`super::Csr::matvec_batch_into`]): decodes each row's fixed
    /// slot list once and amortizes it across the batch. Per sequence
    /// the accumulation order is identical to the scalar
    /// [`NmSparse::matvec`], so results are bit-exact with the
    /// single-vector path.
    pub fn matvec_batch_into(&self, x: &[f32], y: &mut [f32], b: usize,
                             scratch: &mut SpmmScratch) {
        debug_assert_eq!(x.len(), b * self.n_in);
        debug_assert_eq!(y.len(), b * self.n_out);
        if b == 1 {
            return self.matvec(x, y, KernelPath::Scalar);
        }
        transpose_batch_into(x, b, self.n_in, &mut scratch.xt);
        scratch.acc.resize(b, 0.0);
        let xt = &scratch.xt[..];
        let acc = &mut scratch.acc;
        let gpr = self.n_in / M;
        let spr = gpr * N;
        for o in 0..self.n_out {
            acc.fill(0.0);
            for g in 0..gpr {
                let x0 = g * M;
                let sb = o * spr + g * N;
                for j in 0..N {
                    let k = sb + j;
                    let v = self.values[k];
                    let c = x0 + self.offsets[k] as usize;
                    let xrow = &xt[c * b..c * b + b];
                    for (a, xv) in acc.iter_mut().zip(xrow.iter()) {
                        *a += v * xv;
                    }
                }
            }
            for (bi, &a) in acc.iter().enumerate() {
                y[bi * self.n_out + o] = a;
            }
        }
    }

    /// Allocating convenience wrapper over
    /// [`NmSparse::matvec_batch_into`].
    pub fn matvec_batch(&self, x: &[f32], y: &mut [f32], b: usize) {
        self.matvec_batch_into(x, y, b, &mut SpmmScratch::default());
    }

    /// Tiled variant: walks the construction-time [`TilePlan`] like
    /// every other format ([`super::tile`]), bit-identical to the
    /// untiled path for every batch size, geometry and kernel path.
    pub fn matvec_batch_tiled_into(&self, x: &[f32], y: &mut [f32],
                                   b: usize, scratch: &mut SpmmScratch,
                                   path: KernelPath) {
        if b == 1 {
            return self.matvec(x, y, path);
        }
        tile::matvec_batch_tiled(self, &self.plan, x, y, b, scratch, path);
    }

    /// Rebuild the row-tile plan with an explicit byte budget and row
    /// cap — the [`super::Csr::retile`] counterpart. Rows are uniform
    /// here (fixed slot count), so tiles are too.
    pub fn retile(&mut self, target_bytes: usize, max_rows: usize) {
        let rb = self.row_bytes();
        self.plan = TilePlan::with_budget(self.n_out, |_| rb,
                                          target_bytes, max_rows);
    }

    /// Real nonzeros (padding slots excluded).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Resident payload: 4 B per value slot + 1 B per offset slot
    /// (padding included — it is genuinely stored).
    pub fn mem_bytes(&self) -> usize {
        self.values.len() * 4 + self.offsets.len()
    }

    /// Reconstruct the (din, dout) weight, for tests and parity
    /// checks.
    pub fn to_dense(&self) -> Matrix {
        let mut w = Matrix::zeros(self.n_in, self.n_out);
        let gpr = self.n_in / M;
        let spr = gpr * N;
        for o in 0..self.n_out {
            for g in 0..gpr {
                for j in 0..N {
                    let k = o * spr + g * N + j;
                    let v = self.values[k];
                    if v != 0.0 {
                        let r = g * M + self.offsets[k] as usize;
                        *w.at_mut(r, o) += v;
                    }
                }
            }
        }
        w
    }
}

impl<const N: usize, const M: usize> RowTiled for NmSparse<N, M> {
    fn n_in(&self) -> usize {
        self.n_in
    }

    fn n_out(&self) -> usize {
        self.n_out
    }

    fn exec_tiles(&self, tiles: &[Tile], xt: &[f32], yt: &mut [f32],
                  b: usize, path: KernelPath) {
        let Some(first) = tiles.first() else { return };
        let base = first.row0;
        let gpr = self.n_in / M;
        let spr = gpr * N;
        for t in tiles {
            for o in t.row0..t.row1 {
                let yrow = &mut yt[(o - base) * b..(o - base) * b + b];
                yrow.fill(0.0);
                for g in 0..gpr {
                    let x0 = g * M;
                    let sb = o * spr + g * N;
                    for j in 0..N {
                        let k = sb + j;
                        let v = self.values[k];
                        let c = x0 + self.offsets[k] as usize;
                        let xrow = &xt[c * b..c * b + b];
                        axpy_lanes(yrow, xrow, v, path);
                    }
                }
            }
        }
    }
}

/// The two monomorphizations the engine serves, behind one enum so
/// `WeightFmt` stays closed and non-generic. All methods delegate.
#[derive(Debug, Clone)]
pub enum NmWeights {
    N2M4(NmSparse<2, 4>),
    N4M8(NmSparse<4, 8>),
}

macro_rules! nm_delegate {
    ($self:ident, $s:ident => $body:expr) => {
        match $self {
            NmWeights::N2M4($s) => $body,
            NmWeights::N4M8($s) => $body,
        }
    };
}

impl NmWeights {
    /// Build the mode's format from a pruned f32 checkpoint weight,
    /// verifying the pattern ([`NmSparse::from_weight`]).
    pub fn from_weight(w: &Matrix, mode: NmMode) -> Result<NmWeights> {
        match mode {
            NmMode::Off => bail!("NmWeights::from_weight with mode off"),
            NmMode::N2M4 => Ok(NmWeights::N2M4(NmSparse::from_weight(w)?)),
            NmMode::N4M8 => Ok(NmWeights::N4M8(NmSparse::from_weight(w)?)),
        }
    }

    pub fn mode(&self) -> NmMode {
        match self {
            NmWeights::N2M4(_) => NmMode::N2M4,
            NmWeights::N4M8(_) => NmMode::N4M8,
        }
    }

    pub fn n_in(&self) -> usize {
        nm_delegate!(self, s => s.n_in)
    }

    pub fn n_out(&self) -> usize {
        nm_delegate!(self, s => s.n_out)
    }

    pub fn matvec(&self, x: &[f32], y: &mut [f32], path: KernelPath) {
        nm_delegate!(self, s => s.matvec(x, y, path))
    }

    pub fn matvec_batch_into(&self, x: &[f32], y: &mut [f32], b: usize,
                             scratch: &mut SpmmScratch) {
        nm_delegate!(self, s => s.matvec_batch_into(x, y, b, scratch))
    }

    pub fn matvec_batch_tiled_into(&self, x: &[f32], y: &mut [f32],
                                   b: usize, scratch: &mut SpmmScratch,
                                   path: KernelPath) {
        nm_delegate!(self, s =>
            s.matvec_batch_tiled_into(x, y, b, scratch, path))
    }

    pub fn retile(&mut self, target_bytes: usize, max_rows: usize) {
        nm_delegate!(self, s => s.retile(target_bytes, max_rows))
    }

    pub fn nnz(&self) -> usize {
        nm_delegate!(self, s => s.nnz())
    }

    pub fn mem_bytes(&self) -> usize {
        nm_delegate!(self, s => s.mem_bytes())
    }
}

/// Project a (din, dout) weight onto the `n:m` pattern by magnitude:
/// per output column and per group of `m` consecutive input rows,
/// keep the `n` largest-|w| entries and zero the rest (ties broken by
/// lower row index, so the projection is deterministic). The
/// test/bench-side producer of valid N:M checkpoints — i.i.d.
/// magnitude pruning almost never lands on the pattern by accident.
pub fn nm_project(w: &Matrix, n: usize, m: usize) -> Matrix {
    assert!(n >= 1 && n <= m, "malformed {n}:{m} projection");
    assert_eq!(w.rows % m, 0,
               "nm_project: {} rows not divisible by group width {m}",
               w.rows);
    let mut out = w.clone();
    let mut idx: Vec<usize> = Vec::with_capacity(m);
    for c in 0..w.cols {
        for g in 0..w.rows / m {
            idx.clear();
            idx.extend(0..m);
            idx.sort_by(|&a, &b| {
                let va = w.at(g * m + a, c).abs();
                let vb = w.at(g * m + b, c).abs();
                vb.partial_cmp(&va).unwrap().then(a.cmp(&b))
            });
            for &j in &idx[n..] {
                *out.at_mut(g * m + j, c) = 0.0;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{dense_matvec, random_sparse_weight, Csr};
    use crate::util::rng::Rng;

    fn nm24_weight(din: usize, dout: usize, seed: u64) -> Matrix {
        nm_project(&random_sparse_weight(din, dout, 0.3, seed), 2, 4)
    }

    fn input(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn nm24_matches_dense_reference() {
        let w = nm24_weight(64, 48, 1);
        let nm = NmSparse::<2, 4>::from_weight(&w).unwrap();
        let x = input(64, 2);
        let mut yd = vec![0.0f32; 48];
        let mut yn = vec![0.0f32; 48];
        dense_matvec(&w, &x, &mut yd);
        nm.matvec(&x, &mut yn, KernelPath::Scalar);
        for (a, b) in yd.iter().zip(yn.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn nm48_matches_dense_reference() {
        let w = nm_project(&random_sparse_weight(64, 40, 0.3, 3), 4, 8);
        let nm = NmSparse::<4, 8>::from_weight(&w).unwrap();
        let x = input(64, 4);
        let mut yd = vec![0.0f32; 40];
        let mut yn = vec![0.0f32; 40];
        dense_matvec(&w, &x, &mut yd);
        nm.matvec(&x, &mut yn, KernelPath::Scalar);
        for (a, b) in yd.iter().zip(yn.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn rejects_pattern_violation_loudly() {
        // a dense 4-group has 4 nonzeros: 2:4 must refuse it
        let mut w = Matrix::zeros(8, 3);
        for r in 0..4 {
            *w.at_mut(r, 1) = 1.0 + r as f32;
        }
        let err = NmSparse::<2, 4>::from_weight(&w).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pattern violation"), "{msg}");
        assert!(msg.contains("output row 1"), "{msg}");
    }

    #[test]
    fn rejects_input_dim_not_divisible_by_m() {
        let w = Matrix::zeros(10, 4); // 10 % 4 != 0
        let err = NmSparse::<2, 4>::from_weight(&w).unwrap_err();
        assert!(format!("{err:#}").contains("divisible"),
                "{err:#}");
    }

    #[test]
    fn all_zero_groups_pad_and_decode_to_zero() {
        let w = Matrix::zeros(16, 6);
        let nm = NmSparse::<2, 4>::from_weight(&w).unwrap();
        assert_eq!(nm.nnz(), 0);
        // 4 groups x 2 slots per row, all padding — storage is honest
        assert_eq!(nm.values.len(), 6 * 4 * 2);
        let x = vec![1.0f32; 16];
        for path in [KernelPath::Scalar, KernelPath::Unrolled] {
            let mut y = vec![7.0f32; 6];
            nm.matvec(&x, &mut y, path);
            assert!(y.iter().all(|&v| v == 0.0), "{path:?}");
        }
    }

    #[test]
    fn unrolled_matvec_is_bitwise_scalar() {
        // n_out = 45 exercises the 4-row block remainder
        let w = nm24_weight(96, 45, 7);
        let nm = NmSparse::<2, 4>::from_weight(&w).unwrap();
        let x = input(96, 8);
        let mut ys = vec![0.0f32; 45];
        let mut yu = vec![0.0f32; 45];
        nm.matvec(&x, &mut ys, KernelPath::Scalar);
        nm.matvec(&x, &mut yu, KernelPath::Unrolled);
        assert_eq!(ys, yu, "unrolled matvec diverged from scalar");
    }

    #[test]
    fn batch_b1_is_bitwise_matvec() {
        let w = nm24_weight(64, 40, 11);
        let nm = NmSparse::<2, 4>::from_weight(&w).unwrap();
        let x = input(64, 12);
        let mut y1 = vec![0.0f32; 40];
        let mut yb = vec![0.0f32; 40];
        nm.matvec(&x, &mut y1, KernelPath::Scalar);
        nm.matvec_batch(&x, &mut yb, 1);
        assert_eq!(y1, yb);
    }

    #[test]
    fn batch_matches_per_sequence_bitwise() {
        let (din, dout) = (96, 50);
        let w = nm24_weight(din, dout, 21);
        let nm = NmSparse::<2, 4>::from_weight(&w).unwrap();
        for b in [2usize, 4, 7] {
            let x = input(b * din, 100 + b as u64);
            let mut y = vec![0.0f32; b * dout];
            nm.matvec_batch(&x, &mut y, b);
            for bi in 0..b {
                let mut want = vec![0.0f32; dout];
                nm.matvec(&x[bi * din..(bi + 1) * din], &mut want,
                          KernelPath::Scalar);
                assert_eq!(&y[bi * dout..(bi + 1) * dout], &want[..],
                           "b={b} row {bi}");
            }
        }
    }

    #[test]
    fn tiled_and_unrolled_match_untiled_bitwise() {
        let (din, dout) = (64, 45);
        let w = nm24_weight(din, dout, 31);
        let mut nm = NmSparse::<2, 4>::from_weight(&w).unwrap();
        let mut scratch = SpmmScratch::default();
        for b in [1usize, 3, 8] {
            let x = input(b * din, 200 + b as u64);
            let mut want = vec![0.0f32; b * dout];
            nm.matvec_batch_into(&x, &mut want, b, &mut scratch);
            for plan in [TilePlan::from_row_bytes(dout, |_| 90),
                         TilePlan::fixed(dout, 7),
                         TilePlan::fixed(dout, 1)] {
                nm.plan = plan;
                for path in [KernelPath::Scalar, KernelPath::Unrolled] {
                    let mut got = vec![0.0f32; b * dout];
                    nm.matvec_batch_tiled_into(&x, &mut got, b,
                                               &mut scratch, path);
                    assert_eq!(got, want, "b={b} {path:?}");
                }
            }
        }
    }

    #[test]
    fn retile_covers_all_rows_and_stays_bit_exact() {
        let (din, dout, b) = (64, 40, 5);
        let w = nm24_weight(din, dout, 41);
        let mut nm = NmSparse::<2, 4>::from_weight(&w).unwrap();
        let x = input(b * din, 42);
        let mut scratch = SpmmScratch::default();
        let mut want = vec![0.0f32; b * dout];
        nm.matvec_batch_into(&x, &mut want, b, &mut scratch);
        for (tb, mr) in [(64usize, 8usize), (1, 1), (1 << 20, 512)] {
            nm.retile(tb, mr);
            assert_eq!(nm.plan.tiles.first().unwrap().row0, 0);
            assert_eq!(nm.plan.tiles.last().unwrap().row1, dout);
            let mut got = vec![0.0f32; b * dout];
            nm.matvec_batch_tiled_into(&x, &mut got, b, &mut scratch,
                                       KernelPath::Unrolled);
            assert_eq!(got, want, "retile({tb}, {mr})");
        }
    }

    #[test]
    fn to_dense_round_trips_the_projection() {
        let w = nm24_weight(64, 32, 51);
        let nm = NmSparse::<2, 4>::from_weight(&w).unwrap();
        let back = nm.to_dense();
        assert_eq!(back.data, w.data, "to_dense lost the weight");
    }

    #[test]
    fn mem_bytes_counts_values_and_offsets() {
        let w = nm24_weight(64, 32, 61);
        let nm = NmSparse::<2, 4>::from_weight(&w).unwrap();
        let slots = 32 * (64 / 4) * 2;
        assert_eq!(nm.values.len(), slots);
        assert_eq!(nm.offsets.len(), slots);
        assert_eq!(nm.mem_bytes(), slots * 5);
        // at exactly 50% density the 5 B/slot payload undercuts CSR's
        // 8 B/nnz — the format's memory claim at its natural shape
        let dense24 = nm_project(&Matrix::from_vec(
            64, 32, input(64 * 32, 62)), 2, 4);
        let full = NmSparse::<2, 4>::from_weight(&dense24).unwrap();
        assert!(full.mem_bytes() < Csr::from_weight(&dense24).mem_bytes());
    }

    #[test]
    fn nm_project_produces_a_valid_pattern() {
        let w = random_sparse_weight(96, 40, 0.2, 71);
        let p = nm_project(&w, 2, 4);
        // every group obeys the pattern and keeps the largest entries
        for c in 0..p.cols {
            for g in 0..p.rows / 4 {
                let kept: Vec<f32> = (0..4)
                    .map(|j| p.at(g * 4 + j, c))
                    .filter(|v| *v != 0.0)
                    .collect();
                assert!(kept.len() <= 2, "col {c} group {g}");
            }
        }
        assert!(NmSparse::<2, 4>::from_weight(&p).is_ok());
    }

    #[test]
    fn nmweights_delegates_and_reports_mode() {
        let w = nm24_weight(64, 32, 81);
        let nm = NmWeights::from_weight(&w, NmMode::N2M4).unwrap();
        assert_eq!(nm.mode(), NmMode::N2M4);
        assert_eq!(nm.n_in(), 64);
        assert_eq!(nm.n_out(), 32);
        assert!(nm.nnz() > 0);
        assert!(NmWeights::from_weight(&w, NmMode::Off).is_err());
    }

    #[test]
    fn mode_parse_and_labels() {
        assert_eq!(NmMode::parse("off").unwrap(), NmMode::Off);
        assert_eq!(NmMode::parse("2:4").unwrap(), NmMode::N2M4);
        assert_eq!(NmMode::parse("4:8").unwrap(), NmMode::N4M8);
        assert!(NmMode::parse("1:2").is_err());
        assert_eq!(NmMode::N2M4.label(), "2:4");
        assert_eq!(NmMode::N2M4.n(), 2);
        assert_eq!(NmMode::N4M8.m(), 8);
        assert!(!NmMode::Off.is_on());
    }
}

//! Row-tiled execution plans for the SpMM kernels (ISSUE 3 tentpole).
//!
//! The batched kernels in [`crate::sparse`] already amortize
//! index/bitmap decode across the batch; this module adds the
//! *weight-traffic* half of the cross-request reuse story. A [`TilePlan`] groups a format's
//! output rows into cache-sized tiles at `from_weight` time (both
//! `Csr` and `Macko` pack their per-row nonzero payloads row-major, so
//! every tile's value/index/bitmap slices are already contiguous in
//! storage — the plan records boundaries and byte costs, it never
//! copies). The tiled kernels then walk each weight tile **once** per
//! decode step and apply it across all live slots while the tile's
//! payload is L1/L2-resident, instead of streaming the whole matrix
//! once per output row's working set.
//!
//! Tiles are also the sharding unit: [`TilePlan::shard_ranges`] splits
//! the plan into contiguous, byte-balanced row ranges, and
//! [`par_matvec_batch_tiled`] fans those shards across scoped threads
//! so one big layer can use every core even at batch 1 slot-count
//! (intra-layer parallelism, vs. the scheduler's slot sharding).
//!
//! ## Bit-exactness contract
//!
//! Tiling is a pure traversal re-grouping: for every output row and
//! every sequence in the batch, the accumulation order over that row's
//! nonzeros is identical to the format's single-vector `matvec` (and
//! therefore to the untiled `matvec_batch_into`). Tiled output is
//! bit-identical to the untiled path for every format, batch size,
//! tile geometry, and shard count — all PR 1/2 determinism guarantees
//! carry over unchanged. The tests in `rust/tests/kernels.rs` assert
//! exactly this.
//!
//! Quantized formats ([`super::CsrQ`] / [`super::MackoQ`]) join the
//! same contract *within their mode*: the fused
//! dequantize-multiply-accumulate in their `exec_tiles` evaluates one
//! shared dequant expression per nonzero in the identical per-row
//! order as their own untiled `matvec`, so int8/int4 tiled, pooled and
//! sharded outputs are bit-identical to each other. Only the
//! comparison *across* modes (int8 vs f32) is tolerance-based — the
//! quantization error itself, not the traversal, is the sole source of
//! deviation (see `sparse/quantized.rs` for the analytic bound).

//! The tiled kernels also take a [`KernelPath`]: `Unrolled` runs the
//! batch-lane inner loop through 4-wide explicit lane accumulators
//! ([`super::axpy_lanes`]), `Scalar` is the one-lane-at-a-time
//! reference. Each lane's accumulation order is identical either way,
//! so the path choice joins the bit-exactness contract above as
//! another pure traversal knob.

use super::{axpy_lanes, transpose_batch_into, Csr, KernelPath, Macko,
            SpmmScratch};
use crate::infer::pool::WorkerPool;
use crate::tensor::Matrix;

/// One contiguous row range of a [`TilePlan`] plus the estimated bytes
/// of weight payload the kernel streams when walking it.
#[derive(Debug, Clone)]
pub struct Tile {
    pub row0: usize,
    pub row1: usize,
    /// Estimated weight payload (values + indices / bitmap words) in
    /// bytes — the tile-sizing and shard-balancing cost measure.
    pub bytes: usize,
}

/// A row-tiled execution plan: output rows grouped into cache-sized
/// tiles, built once per weight matrix at `from_weight`/load time.
/// The plan is traversal metadata only — it is excluded from the
/// formats' `mem_bytes` weight-storage accounting.
#[derive(Debug, Clone, Default)]
pub struct TilePlan {
    pub n_rows: usize,
    pub tiles: Vec<Tile>,
}

impl TilePlan {
    /// Default per-tile payload budget: half a typical 32 KiB L1d, so
    /// a tile's weight slices and the (b-wide) accumulator rows fit
    /// together.
    pub const TARGET_TILE_BYTES: usize = 16 * 1024;

    /// Row cap per tile, so extremely sparse (or all-zero) matrices
    /// still split into enough tiles to shard across threads.
    pub const MAX_TILE_ROWS: usize = 512;

    /// Build a plan from a per-row payload-size function with the
    /// default cache budget.
    pub fn from_row_bytes(n_rows: usize,
                          row_bytes: impl Fn(usize) -> usize) -> TilePlan {
        Self::with_budget(n_rows, row_bytes, Self::TARGET_TILE_BYTES,
                          Self::MAX_TILE_ROWS)
    }

    /// Build a plan with an explicit byte budget and row cap: rows are
    /// appended to the current tile until adding the next row would
    /// exceed `target_bytes` (or the tile holds `max_rows`), then the
    /// tile is closed. Every tile is non-empty and the tiles cover
    /// `0..n_rows` contiguously; a single row larger than the budget
    /// gets a tile of its own.
    pub fn with_budget(n_rows: usize, row_bytes: impl Fn(usize) -> usize,
                       target_bytes: usize, max_rows: usize) -> TilePlan {
        let max_rows = max_rows.max(1);
        let mut tiles = Vec::new();
        let mut row0 = 0usize;
        let mut bytes = 0usize;
        for r in 0..n_rows {
            let rb = row_bytes(r);
            let rows = r - row0;
            if rows > 0 && (bytes + rb > target_bytes || rows >= max_rows) {
                tiles.push(Tile { row0, row1: r, bytes });
                row0 = r;
                bytes = 0;
            }
            bytes += rb;
        }
        if row0 < n_rows {
            tiles.push(Tile { row0, row1: n_rows, bytes });
        }
        TilePlan { n_rows, tiles }
    }

    /// Fixed geometry: exactly `tile_rows` rows per tile with a ragged
    /// last tile. Test/bench helper for exercising tile boundaries
    /// independently of payload sizes.
    pub fn fixed(n_rows: usize, tile_rows: usize) -> TilePlan {
        let tile_rows = tile_rows.max(1);
        let mut tiles = Vec::new();
        let mut row0 = 0usize;
        while row0 < n_rows {
            let row1 = (row0 + tile_rows).min(n_rows);
            tiles.push(Tile { row0, row1, bytes: 0 });
            row0 = row1;
        }
        TilePlan { n_rows, tiles }
    }

    /// Split the plan into at most `n` contiguous shards of tiles with
    /// roughly equal byte cost (each shard gets at least one tile).
    /// Returns tile-index ranges `[lo, hi)` covering every tile in
    /// order — the unit [`par_matvec_batch_tiled`] hands to each
    /// worker thread.
    pub fn shard_ranges(&self, n: usize) -> Vec<(usize, usize)> {
        let n_tiles = self.tiles.len();
        if n_tiles == 0 {
            return Vec::new();
        }
        let n = n.clamp(1, n_tiles);
        let total: usize = self.tiles.iter().map(|t| t.bytes.max(1)).sum();
        let mut out: Vec<(usize, usize)> = Vec::with_capacity(n);
        let mut lo = 0usize;
        let mut acc = 0usize;
        let mut closed = 0usize;
        for i in 0..n_tiles {
            acc += self.tiles[i].bytes.max(1);
            let shards_left = n - out.len();
            let tiles_after = n_tiles - (i + 1);
            if shards_left > 1 {
                let fair = (total - closed).div_ceil(shards_left);
                // close when the shard reached its fair share, or when
                // every remaining shard needs one of the leftover tiles
                if acc >= fair || tiles_after == shards_left - 1 {
                    out.push((lo, i + 1));
                    lo = i + 1;
                    closed += acc;
                    acc = 0;
                }
            }
        }
        out.push((lo, n_tiles));
        out
    }
}

/// A weight format whose output rows can be computed tile-by-tile into
/// a `(rows, b)` staging layout. The one contract that matters: for
/// every output row and batch lane, `exec_tiles` must replay the exact
/// accumulation order of the format's single-vector `matvec`.
pub trait RowTiled {
    fn n_in(&self) -> usize;
    fn n_out(&self) -> usize;

    /// Compute output rows `tiles[0].row0 .. tiles.last().row1` into
    /// `yt`, laid out `yt[(row - tiles[0].row0) * b + bi]`, reading the
    /// `(n_in, b)` batch re-layout `xt`. Rows in the range are fully
    /// overwritten (zeroed first), so callers never pre-clear. `path`
    /// selects the lane-unrolled or scalar inner loop — bit-identical
    /// per the module contract.
    fn exec_tiles(&self, tiles: &[Tile], xt: &[f32], yt: &mut [f32],
                  b: usize, path: KernelPath);
}

impl RowTiled for Csr {
    fn n_in(&self) -> usize {
        self.n_in
    }

    fn n_out(&self) -> usize {
        self.n_out
    }

    fn exec_tiles(&self, tiles: &[Tile], xt: &[f32], yt: &mut [f32],
                  b: usize, path: KernelPath) {
        let Some(first) = tiles.first() else { return };
        let base = first.row0;
        for t in tiles {
            // this tile's col_idx/values live in the contiguous slice
            // row_ptr[t.row0]..row_ptr[t.row1]; walking it row by row
            // keeps the payload cache-resident across all b lanes
            for o in t.row0..t.row1 {
                let yrow = &mut yt[(o - base) * b..(o - base) * b + b];
                yrow.fill(0.0);
                let lo = self.row_ptr[o] as usize;
                let hi = self.row_ptr[o + 1] as usize;
                for k in lo..hi {
                    let v = self.values[k];
                    let c = self.col_idx[k] as usize;
                    let xrow = &xt[c * b..c * b + b];
                    axpy_lanes(yrow, xrow, v, path);
                }
            }
        }
    }
}

impl RowTiled for Macko {
    fn n_in(&self) -> usize {
        self.n_in
    }

    fn n_out(&self) -> usize {
        self.n_out
    }

    fn exec_tiles(&self, tiles: &[Tile], xt: &[f32], yt: &mut [f32],
                  b: usize, path: KernelPath) {
        let Some(first) = tiles.first() else { return };
        let base = first.row0;
        let wpr = self.words_per_row;
        for t in tiles {
            for o in t.row0..t.row1 {
                let yrow = &mut yt[(o - base) * b..(o - base) * b + b];
                yrow.fill(0.0);
                let mut k = self.row_ptr[o] as usize;
                let word_base = o * wpr;
                for wi in 0..wpr {
                    let mut word = self.bitmap[word_base + wi];
                    let col0 = wi * 64;
                    while word != 0 {
                        let bit = word.trailing_zeros() as usize;
                        let v = self.values[k];
                        let c = col0 + bit;
                        let xrow = &xt[c * b..c * b + b];
                        axpy_lanes(yrow, xrow, v, path);
                        k += 1;
                        word &= word - 1;
                    }
                }
            }
        }
    }
}

/// Dense weights tile over output *columns* of the (din, dout) matrix:
/// a tile's payload is the `w[·, row0..row1]` column band. The r-outer
/// loop streams each weight row segment once per step across every
/// batch lane — and per (column, lane) the accumulation runs r
/// ascending with the same skip-zero rule as [`Matrix::t_matvec`], so
/// rows are bit-exact with the untiled dense path.
impl RowTiled for Matrix {
    fn n_in(&self) -> usize {
        self.rows
    }

    fn n_out(&self) -> usize {
        self.cols
    }

    fn exec_tiles(&self, tiles: &[Tile], xt: &[f32], yt: &mut [f32],
                  b: usize, path: KernelPath) {
        let Some(first) = tiles.first() else { return };
        let base = first.row0;
        for t in tiles {
            let span = t.row1 - t.row0;
            let off = t.row0 - base;
            yt[off * b..(off + span) * b].fill(0.0);
            for r in 0..self.rows {
                let wseg = &self.data[r * self.cols + t.row0
                                      ..r * self.cols + t.row1];
                let xrow = &xt[r * b..r * b + b];
                for (bi, &xv) in xrow.iter().enumerate() {
                    if xv == 0.0 {
                        continue; // same skip rule as t_matvec
                    }
                    match path {
                        KernelPath::Scalar => {
                            for (j, &wv) in wseg.iter().enumerate() {
                                yt[(off + j) * b + bi] += xv * wv;
                            }
                        }
                        KernelPath::Unrolled => {
                            // four independent output columns per pass
                            // — each (column, lane) accumulator still
                            // sees rows r in ascending order
                            let m = wseg.len();
                            let mut j = 0usize;
                            while j + 4 <= m {
                                yt[(off + j) * b + bi] += xv * wseg[j];
                                yt[(off + j + 1) * b + bi] +=
                                    xv * wseg[j + 1];
                                yt[(off + j + 2) * b + bi] +=
                                    xv * wseg[j + 2];
                                yt[(off + j + 3) * b + bi] +=
                                    xv * wseg[j + 3];
                                j += 4;
                            }
                            while j < m {
                                yt[(off + j) * b + bi] += xv * wseg[j];
                                j += 1;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Build the column-tile plan for a dense (din, dout) weight matrix.
pub fn dense_plan(w: &Matrix) -> TilePlan {
    TilePlan::from_row_bytes(w.cols, |_| w.rows * 4)
}

/// Tiled batched SpMM: Y = X W for row-major X (b, n_in), writing Y
/// (b, n_out) — the generic driver behind
/// `Csr::matvec_batch_tiled_into` / `Macko::matvec_batch_tiled_into`
/// and the dense tiled path. Bit-identical to the untiled
/// `matvec_batch_into` for every batch size and plan geometry.
pub fn matvec_batch_tiled<T: RowTiled>(t: &T, plan: &TilePlan, x: &[f32],
                                       y: &mut [f32], b: usize,
                                       scratch: &mut SpmmScratch,
                                       path: KernelPath) {
    debug_assert_eq!(x.len(), b * t.n_in());
    debug_assert_eq!(y.len(), b * t.n_out());
    debug_assert_eq!(plan.n_rows, t.n_out(), "plan built for another shape");
    transpose_batch_into(x, b, t.n_in(), &mut scratch.xt);
    scratch.yt.resize(t.n_out() * b, 0.0);
    t.exec_tiles(&plan.tiles, &scratch.xt, &mut scratch.yt, b, path);
    scatter_rows(&scratch.yt, y, b, t.n_out());
}

/// Intra-layer sharded variant of [`matvec_batch_tiled`]: the plan's
/// tiles are split into byte-balanced contiguous shards and executed
/// on `threads` scoped workers, each writing its own disjoint row band
/// of the `(n_out, b)` staging buffer. One big layer can therefore
/// use every core even when the live slot count is 1 — the
/// complementary axis to the scheduler's slot sharding. Output is
/// bit-identical to the serial tiled (and untiled) paths for any
/// thread count; `threads <= 1` runs inline.
pub fn par_matvec_batch_tiled<T: RowTiled + Sync>(
    t: &T, plan: &TilePlan, x: &[f32], y: &mut [f32], b: usize,
    threads: usize, scratch: &mut SpmmScratch, path: KernelPath) {
    let shards = plan.shard_ranges(threads);
    if shards.len() <= 1 {
        return matvec_batch_tiled(t, plan, x, y, b, scratch, path);
    }
    debug_assert_eq!(x.len(), b * t.n_in());
    debug_assert_eq!(y.len(), b * t.n_out());
    transpose_batch_into(x, b, t.n_in(), &mut scratch.xt);
    scratch.yt.resize(t.n_out() * b, 0.0);
    let xt = &scratch.xt[..];

    // carve the staging buffer into one disjoint row band per shard
    let mut bands: Vec<&mut [f32]> = Vec::with_capacity(shards.len());
    let mut rest = scratch.yt.as_mut_slice();
    for &(t0, t1) in &shards {
        let rows = plan.tiles[t1 - 1].row1 - plan.tiles[t0].row0;
        let (band, tail) = rest.split_at_mut(rows * b);
        bands.push(band);
        rest = tail;
    }
    std::thread::scope(|sc| {
        for (&(t0, t1), band) in shards.iter().zip(bands) {
            let tiles = &plan.tiles[t0..t1];
            sc.spawn(move || t.exec_tiles(tiles, xt, band, b, path));
        }
    });
    scatter_rows(&scratch.yt, y, b, t.n_out());
}

/// [`par_matvec_batch_tiled`] on a persistent [`WorkerPool`] instead
/// of a per-call `thread::scope`: the plan's tiles are split into
/// byte-balanced contiguous shards (one per pool lane) and dispatched
/// to the pool's parked workers — the engine's decode loop calls this
/// for every linear of every layer of every step, so the spawn-free
/// steady state is what makes intra-layer sharding pay off at decode
/// granularity. Each shard writes its own disjoint row band of the
/// `(n_out, b)` staging buffer with the same per-row accumulation
/// order as the serial kernels, so output is bit-identical to the
/// serial tiled (and untiled) paths for any pool width. A single-lane
/// pool (or single-shard plan) runs the serial tiled kernel inline.
pub fn pool_matvec_batch_tiled<T: RowTiled + Sync>(
    t: &T, plan: &TilePlan, x: &[f32], y: &mut [f32], b: usize,
    pool: &WorkerPool, scratch: &mut SpmmScratch, path: KernelPath) {
    let shards = plan.shard_ranges(pool.width());
    if shards.len() <= 1 {
        return matvec_batch_tiled(t, plan, x, y, b, scratch, path);
    }
    debug_assert_eq!(x.len(), b * t.n_in());
    debug_assert_eq!(y.len(), b * t.n_out());
    transpose_batch_into(x, b, t.n_in(), &mut scratch.xt);
    scratch.yt.resize(t.n_out() * b, 0.0);
    let xt = &scratch.xt[..];
    let tiles = &plan.tiles[..];

    /// Raw staging-buffer base shared by the shard tasks; sound
    /// because every shard writes a disjoint row band.
    struct StagingPtr(*mut f32);
    // SAFETY: the wrapped pointer is only dereferenced through the
    // disjoint per-shard row bands carved out below, and the `pool.run`
    // barrier ends every task before `scratch.yt` is touched again —
    // no two threads ever alias a band.
    unsafe impl Send for StagingPtr {}
    unsafe impl Sync for StagingPtr {}
    let yt_base = StagingPtr(scratch.yt.as_mut_ptr());

    pool.run(shards.len(), &|s| {
        let (t0, t1) = shards[s];
        let row0 = tiles[t0].row0;
        let rows = tiles[t1 - 1].row1 - row0;
        // SAFETY: shard `s` owns rows `row0..row0 + rows` exclusively —
        // shard ranges are contiguous and non-overlapping — and the
        // buffer was sized to n_out * b above, so this band is in
        // bounds and written by exactly one task.
        let band = unsafe {
            std::slice::from_raw_parts_mut(yt_base.0.add(row0 * b),
                                           rows * b)
        };
        t.exec_tiles(&tiles[t0..t1], xt, band, b, path);
    });
    scatter_rows(&scratch.yt, y, b, t.n_out());
}

/// [`Matrix::t_matmat`] on a persistent [`WorkerPool`]: the head
/// projection's output columns are split into one contiguous band per
/// pool lane and the bands run on the pool's parked workers — the
/// engine's decode step calls this for the dense head GEMM (d_model ×
/// vocab, the single largest dense matrix in the model) when decoding
/// with `--shard-workers > 1`, so the head shares the same lanes as
/// the layer linears.
///
/// Bit-exactness: every output element `y[bi, j]` is computed wholly
/// within one band, accumulating over weight rows `r` in ascending
/// order with the same skip-zero rule as `t_matvec`/`t_matmat` — so
/// each row of `y` is bit-identical to the serial projection for any
/// pool width. A single-lane pool (or single-column head) runs the
/// serial GEMM inline.
pub fn pool_t_matmat(a: &Matrix, x: &[f32], y: &mut [f32], b: usize,
                     pool: &WorkerPool) {
    let (n, m) = (a.rows, a.cols);
    debug_assert_eq!(x.len(), b * n);
    debug_assert_eq!(y.len(), b * m);
    let lanes = pool.width().min(m);
    if lanes <= 1 {
        return a.t_matmat(x, y, b);
    }

    /// Raw output base shared by the band tasks; sound because every
    /// task writes a disjoint set of column indices.
    struct OutPtr(*mut f32);
    // SAFETY: tasks only write through `out` at column indices inside
    // their own `c0..c1` band — the bands partition `0..m` — and the
    // `pool.run` barrier ends every task before the `y` borrow is
    // released, so no two threads ever alias an element.
    unsafe impl Send for OutPtr {}
    unsafe impl Sync for OutPtr {}
    let y_base = OutPtr(y.as_mut_ptr());

    pool.run(lanes, &|band| {
        let c0 = band * m / lanes;
        let c1 = (band + 1) * m / lanes;
        // SAFETY: band tasks write only columns c0..c1 of each output
        // row — the bands partition 0..m, so every element is written
        // by exactly one task, and the buffer was checked to b * m.
        let out = |bi: usize, j: usize| unsafe {
            &mut *y_base.0.add(bi * m + j)
        };
        for bi in 0..b {
            for j in c0..c1 {
                *out(bi, j) = 0.0;
            }
        }
        for r in 0..n {
            let wseg = &a.data[r * m + c0..r * m + c1];
            for bi in 0..b {
                let xv = x[bi * n + r];
                if xv == 0.0 {
                    continue; // same skip rule as t_matvec/t_matmat
                }
                for (k, &wv) in wseg.iter().enumerate() {
                    *out(bi, c0 + k) += xv * wv;
                }
            }
        }
    });
}

/// Re-layout the (n_out, b) staging buffer back to the engine's
/// row-major (b, n_out) output.
fn scatter_rows(yt: &[f32], y: &mut [f32], b: usize, n_out: usize) {
    for o in 0..n_out {
        let yrow = &yt[o * b..o * b + b];
        for (bi, &v) in yrow.iter().enumerate() {
            y[bi * n_out + o] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_budget_covers_all_rows_contiguously() {
        let plan = TilePlan::with_budget(100, |_| 100, 256, 512);
        assert_eq!(plan.n_rows, 100);
        assert!(!plan.tiles.is_empty());
        assert_eq!(plan.tiles[0].row0, 0);
        assert_eq!(plan.tiles.last().unwrap().row1, 100);
        for w in plan.tiles.windows(2) {
            assert_eq!(w[0].row1, w[1].row0, "tiles must be contiguous");
        }
        for t in &plan.tiles {
            assert!(t.row1 > t.row0, "tiles must be non-empty");
            // 100-byte rows under a 256-byte budget: 2 rows per tile
            assert!(t.row1 - t.row0 <= 2);
        }
    }

    #[test]
    fn with_budget_handles_oversized_and_zero_rows() {
        // a row bigger than the budget still gets (its own) tile
        let plan = TilePlan::with_budget(3, |_| 1 << 20, 1024, 512);
        assert_eq!(plan.tiles.len(), 3);
        // all-zero rows: the row cap bounds tile length
        let plan = TilePlan::with_budget(1000, |_| 0, 1024, 512);
        assert_eq!(plan.tiles.last().unwrap().row1, 1000);
        assert!(plan.tiles.len() >= 2, "row cap must split zero-byte rows");
        assert!(plan.tiles.iter().all(|t| t.row1 - t.row0 <= 512));
    }

    #[test]
    fn fixed_is_ragged_at_the_end() {
        let plan = TilePlan::fixed(45, 7);
        assert_eq!(plan.tiles.len(), 7);
        assert_eq!(plan.tiles.last().unwrap().row1 -
                   plan.tiles.last().unwrap().row0, 3);
        assert_eq!(plan.tiles.last().unwrap().row1, 45);
    }

    #[test]
    fn shard_ranges_cover_and_balance() {
        let plan = TilePlan::with_budget(64, |_| 512, 1024, 512);
        for n in [1usize, 2, 3, 5, 100] {
            let shards = plan.shard_ranges(n);
            assert!(!shards.is_empty());
            assert!(shards.len() <= n.min(plan.tiles.len()));
            assert_eq!(shards[0].0, 0);
            assert_eq!(shards.last().unwrap().1, plan.tiles.len());
            for w in shards.windows(2) {
                assert_eq!(w[0].1, w[1].0, "shards must be contiguous");
            }
            for &(lo, hi) in &shards {
                assert!(hi > lo, "shards must be non-empty");
            }
        }
    }

    #[test]
    fn shard_ranges_empty_plan() {
        let plan = TilePlan::default();
        assert!(plan.shard_ranges(4).is_empty());
    }

    #[test]
    fn shard_ranges_more_shards_than_tiles_degrades_to_one_per_tile() {
        // 3 tiles, 64 requested shards: every tile becomes its own
        // shard and nothing is empty or dropped
        let plan = TilePlan::fixed(30, 10);
        assert_eq!(plan.tiles.len(), 3);
        let shards = plan.shard_ranges(64);
        assert_eq!(shards, vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn shard_ranges_single_row_plan() {
        // a 1-row weight has one tile; any shard request yields the
        // one full-coverage shard
        let plan = TilePlan::from_row_bytes(1, |_| 12);
        assert_eq!(plan.tiles.len(), 1);
        for n in [1usize, 2, 8] {
            assert_eq!(plan.shard_ranges(n), vec![(0, 1)]);
        }
    }

    #[test]
    fn shard_ranges_all_zero_weight_splits_by_row_cap() {
        // an all-zero weight has zero-byte rows: the row cap still
        // produces enough tiles to shard, and the byte-balancer
        // (which clamps each tile to >= 1 byte) covers all of them
        let w = Matrix::zeros(16, 1200);
        let csr = Csr::from_weight(&w);
        assert!(csr.plan.tiles.len() >= 2,
                "row cap must split an all-zero plan");
        for n in [1usize, 2, 5] {
            let shards = csr.plan.shard_ranges(n);
            assert_eq!(shards.len(), n.min(csr.plan.tiles.len()));
            assert_eq!(shards[0].0, 0);
            assert_eq!(shards.last().unwrap().1, csr.plan.tiles.len());
            for w2 in shards.windows(2) {
                assert_eq!(w2[0].1, w2[1].0);
            }
        }
    }

    #[test]
    fn shard_ranges_zero_request_clamps_to_one() {
        let plan = TilePlan::fixed(20, 5);
        assert_eq!(plan.shard_ranges(0), vec![(0, plan.tiles.len())]);
    }

    #[test]
    fn pooled_t_matmat_matches_serial_for_any_pool_width() {
        let mut rng = crate::util::rng::Rng::new(31);
        let mut a = Matrix::randn(40, 57, 1.0, &mut rng);
        a.data[11] = 0.0; // exercise the skip-zero rule
        for b in [1usize, 3, 8] {
            let mut x: Vec<f32> =
                (0..b * 40).map(|_| rng.normal()).collect();
            x[7] = 0.0;
            let mut want = vec![0.0f32; b * 57];
            a.t_matmat(&x, &mut want, b);
            for width in [1usize, 2, 3, 64] {
                let pool = WorkerPool::new(width);
                let mut got = vec![9.0f32; b * 57];
                // twice per pool: the second dispatch exercises the
                // parked steady state, not the cold start
                for _ in 0..2 {
                    pool_t_matmat(&a, &x, &mut got, b, &pool);
                    assert_eq!(got, want, "b={b} width={width}");
                }
            }
        }
    }

    #[test]
    fn pooled_tiled_matches_serial_for_any_pool_width() {
        use crate::sparse::random_sparse_weight;
        let (din, dout, b) = (72, 60, 4);
        let w = random_sparse_weight(din, dout, 0.8, 23);
        let csr = Csr::from_weight(&w);
        let plan = TilePlan::fixed(dout, 4);
        let mut rng = crate::util::rng::Rng::new(9);
        let x: Vec<f32> = (0..b * din).map(|_| rng.normal()).collect();
        let mut want = vec![0.0f32; b * dout];
        let mut s0 = SpmmScratch::default();
        matvec_batch_tiled(&csr, &plan, &x, &mut want, b, &mut s0,
                           KernelPath::Scalar);
        for width in [1usize, 2, 3, 16] {
            let pool = WorkerPool::new(width);
            let mut got = vec![0.0f32; b * dout];
            let mut sp = SpmmScratch::default();
            // twice per pool: the second dispatch exercises the parked
            // steady state, not the cold start; alternate the kernel
            // path — both must match the serial scalar reference
            for path in [KernelPath::Scalar, KernelPath::Unrolled] {
                pool_matvec_batch_tiled(&csr, &plan, &x, &mut got, b,
                                        &pool, &mut sp, path);
                assert_eq!(got, want, "pool width {width} {path:?}");
            }
        }
    }

    #[test]
    fn unrolled_paths_match_scalar_for_all_rowtiled_impls() {
        use crate::sparse::{random_sparse_weight, Macko};
        let (din, dout) = (72, 53);
        let w = random_sparse_weight(din, dout, 0.7, 77);
        let csr = Csr::from_weight(&w);
        let mck = Macko::from_weight(&w);
        let plan = TilePlan::fixed(dout, 5);
        let dplan = TilePlan::fixed(dout, 5);
        let mut rng = crate::util::rng::Rng::new(78);
        for b in [2usize, 3, 4, 5, 8, 9] {
            let mut x: Vec<f32> =
                (0..b * din).map(|_| rng.normal()).collect();
            x[din / 2] = 0.0; // exercise the dense skip-zero rule
            let mut want = vec![0.0f32; b * dout];
            let mut got = vec![0.0f32; b * dout];
            let mut s = SpmmScratch::default();
            matvec_batch_tiled(&csr, &plan, &x, &mut want, b, &mut s,
                               KernelPath::Scalar);
            matvec_batch_tiled(&csr, &plan, &x, &mut got, b, &mut s,
                               KernelPath::Unrolled);
            assert_eq!(got, want, "csr b={b}");
            matvec_batch_tiled(&mck, &plan, &x, &mut want, b, &mut s,
                               KernelPath::Scalar);
            matvec_batch_tiled(&mck, &plan, &x, &mut got, b, &mut s,
                               KernelPath::Unrolled);
            assert_eq!(got, want, "macko b={b}");
            matvec_batch_tiled(&w, &dplan, &x, &mut want, b, &mut s,
                               KernelPath::Scalar);
            matvec_batch_tiled(&w, &dplan, &x, &mut got, b, &mut s,
                               KernelPath::Unrolled);
            assert_eq!(got, want, "dense b={b}");
        }
    }
}

//! Quantized payload variants of the sparse serving formats — the
//! Elsa-L serving path (paper §3.3).
//!
//! [`CsrQ`] and [`MackoQ`] mirror [`Csr`] / [`Macko`] exactly — same
//! row order, same index/bitmap structure, same tile plans — but store
//! the nonzero values as int8 or int4 codes with per-row-block absmax
//! scales instead of f32. Decode is memory-bandwidth-bound, so
//! shrinking bytes-per-nonzero from 4 to 1 (int8) or 0.5 (int4) is a
//! direct tok/s multiplier on top of sparsity; the paper reports up to
//! 7.80× serve-time memory compression at 27B with this scheme.
//!
//! ## Format layout
//!
//! Per output row, the nonzero values are chunked into blocks of
//! [`QUANT_BLOCK`] (blocks never span rows). Each block stores one f32
//! scale `absmax / qmax` (qmax = 127 for int8, 7 for int4; scale 1.0
//! for an all-zero block) plus one code per nonzero:
//! `code = round(v / scale)` clamped to `[-qmax, qmax]`. Int8 codes
//! are one byte each; int4 codes are packed two per byte, low nibble
//! first, with every row starting byte-aligned (an odd-length row pads
//! its final high nibble with 0). Dequantization is
//! `code as f32 * scale`, fused into every kernel inner loop — the
//! codes are never materialized back to an f32 buffer.
//!
//! ## Error bounds
//!
//! Rounding to the nearest code bounds the per-weight error by half a
//! quantization step: `|v - dq(v)| <= block_absmax / 254` for int8 and
//! `block_absmax / 14` for int4 (no clamp error: the block absmax maps
//! to exactly qmax). A matvec row error is therefore bounded by the
//! weighted sum of those per-weight bounds, which the tolerance tests
//! here and in `rust/tests/quant_parity.rs` assert.
//!
//! ## Bit-exactness contract
//!
//! f32 parity is tolerance-based, but *within* a quant mode the PR 1–6
//! determinism guarantees carry over unchanged: every kernel
//! (single-vector, batched, tiled, pooled shards) dequantizes through
//! the one shared `dq` expression and replays the single-vector
//! accumulation order per row, so int8 run N == int8 run M bit-exactly
//! across batch sizes, tile geometries, shard counts, and threads.
//! The sweep in `rust/tests/determinism.rs` pins this with a quant
//! axis.

use anyhow::{bail, ensure, Result};

use super::tile::{self, RowTiled, Tile, TilePlan};
use super::{axpy_lanes, transpose_batch_into, KernelPath, SpmmScratch};
use crate::tensor::Matrix;

/// Which payload a serving weight carries: f32 (`None`) or a
/// quantized code stream. Parsed from `--quant {none,int8,int4}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    None,
    Int8,
    Int4,
}

impl QuantMode {
    /// Parse a `--quant` flag value.
    pub fn parse(s: &str) -> Result<QuantMode> {
        match s {
            "none" | "off" | "f32" => Ok(QuantMode::None),
            "int8" | "i8" => Ok(QuantMode::Int8),
            "int4" | "i4" => Ok(QuantMode::Int4),
            other => bail!("unknown quant mode '{other}' \
                            (expected none, int8 or int4)"),
        }
    }

    /// Stable display/stats label.
    pub fn label(self) -> &'static str {
        match self {
            QuantMode::None => "none",
            QuantMode::Int8 => "int8",
            QuantMode::Int4 => "int4",
        }
    }

    fn qmax(self) -> f32 {
        match self {
            QuantMode::Int8 => 127.0,
            QuantMode::Int4 => 7.0,
            QuantMode::None => unreachable!("f32 payloads are not quantized"),
        }
    }
}

/// Default scale-block length: one f32 scale per 64 nonzeros keeps the
/// scale overhead at 6.25% of an int8 payload while staying fine
/// enough that a single outlier only coarsens 63 neighbours.
pub const QUANT_BLOCK: usize = 64;

/// The quantized code stream. Int8 indexes codes directly with the
/// format's `row_ptr`; int4 packs two codes per byte and carries its
/// own per-row byte offsets so every row starts byte-aligned.
#[derive(Debug, Clone)]
enum QuantPayload {
    Int8 { codes: Vec<i8> },
    Int4 { packed: Vec<u8>, byte_ptr: Vec<u32> },
}

impl QuantPayload {
    /// Payload start offset of output row `o`.
    #[inline(always)]
    fn base(&self, o: usize, row_ptr: &[u32]) -> usize {
        match self {
            QuantPayload::Int8 { .. } => row_ptr[o] as usize,
            QuantPayload::Int4 { byte_ptr, .. } => byte_ptr[o] as usize,
        }
    }

    /// Code `j` of the row starting at `base`, as f32. Int4 nibbles
    /// are two's complement: sign-extend via the i8 shift pair.
    #[inline(always)]
    fn code(&self, base: usize, j: usize) -> f32 {
        match self {
            QuantPayload::Int8 { codes } => codes[base + j] as f32,
            QuantPayload::Int4 { packed, .. } => {
                let byte = packed[base + (j >> 1)];
                let nib = if j & 1 == 0 { byte & 0x0f } else { byte >> 4 };
                (((nib << 4) as i8) >> 4) as f32
            }
        }
    }

    fn mem_bytes(&self) -> usize {
        match self {
            QuantPayload::Int8 { codes } => codes.len(),
            QuantPayload::Int4 { packed, byte_ptr } => {
                packed.len() + byte_ptr.len() * 4
            }
        }
    }

    /// Payload bytes a row of `nnz` nonzeros streams (tile costing).
    fn row_bytes(&self, nnz: usize) -> usize {
        match self {
            QuantPayload::Int8 { .. } => nnz,
            QuantPayload::Int4 { .. } => nnz.div_ceil(2),
        }
    }

    fn mode(&self) -> QuantMode {
        match self {
            QuantPayload::Int8 { .. } => QuantMode::Int8,
            QuantPayload::Int4 { .. } => QuantMode::Int4,
        }
    }
}

/// THE dequantization expression. Every kernel in this module funnels
/// through this one function, which is what makes within-mode
/// bit-exactness structural rather than something each kernel has to
/// re-earn: there is no second dequant formula to drift.
#[inline(always)]
fn dq(payload: &QuantPayload, scales: &[f32], block: usize, base: usize,
      sp: usize, j: usize) -> f32 {
    payload.code(base, j) * scales[sp + j / block]
}

/// Quantize row-major packed nonzero values (as produced by the
/// `from_weight` loops) into a payload + scales. Shared by both
/// formats so the code/scale layout — and therefore the dequantized
/// value stream — is identical for a given weight matrix.
fn quantize_rows(values: &[f32], row_ptr: &[u32], mode: QuantMode,
                 block: usize)
                 -> Result<(QuantPayload, Vec<f32>, Vec<u32>)> {
    ensure!(mode != QuantMode::None,
            "quantize_rows needs int8 or int4, got none");
    ensure!(block >= 1, "scale block must be >= 1");
    for (k, &v) in values.iter().enumerate() {
        ensure!(v.is_finite(),
                "refusing to quantize non-finite weight {v} at nonzero {k}");
    }
    let qmax = mode.qmax();
    let n_rows = row_ptr.len() - 1;
    let mut scales = Vec::new();
    let mut scale_ptr = Vec::with_capacity(n_rows + 1);
    scale_ptr.push(0u32);
    let mut codes = Vec::new();
    let mut packed = Vec::new();
    let mut byte_ptr = Vec::with_capacity(n_rows + 1);
    byte_ptr.push(0u32);
    for o in 0..n_rows {
        let lo = row_ptr[o] as usize;
        let hi = row_ptr[o + 1] as usize;
        let mut pending = 0u8;
        let mut have_low = false;
        for chunk in values[lo..hi].chunks(block) {
            let absmax = chunk.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let scale = if absmax > 0.0 { absmax / qmax } else { 1.0 };
            scales.push(scale);
            for &v in chunk {
                let q = (v / scale).round().clamp(-qmax, qmax) as i8;
                match mode {
                    QuantMode::Int8 => codes.push(q),
                    QuantMode::Int4 => {
                        if have_low {
                            packed.push(pending | ((q as u8 & 0x0f) << 4));
                            have_low = false;
                        } else {
                            pending = q as u8 & 0x0f;
                            have_low = true;
                        }
                    }
                    QuantMode::None => unreachable!(),
                }
            }
        }
        if have_low {
            packed.push(pending); // odd row: pad high nibble stays 0
        }
        scale_ptr.push(scales.len() as u32);
        byte_ptr.push(packed.len() as u32);
    }
    let payload = match mode {
        QuantMode::Int8 => QuantPayload::Int8 { codes },
        QuantMode::Int4 => QuantPayload::Int4 { packed, byte_ptr },
        QuantMode::None => unreachable!(),
    };
    Ok((payload, scales, scale_ptr))
}

/// [`Csr`] with a quantized payload: same `row_ptr`/`col_idx`
/// structure, int8/int4 codes + per-row-block scales instead of f32
/// values. Dequant is fused into every kernel inner loop.
#[derive(Debug, Clone)]
pub struct CsrQ {
    pub n_out: usize,
    pub n_in: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    payload: QuantPayload,
    scales: Vec<f32>,
    scale_ptr: Vec<u32>,
    scale_block: usize,
    /// Row-tiled execution plan (see [`tile`]); traversal metadata
    /// only, excluded from [`CsrQ::mem_bytes`].
    pub plan: TilePlan,
}

impl CsrQ {
    /// Build from a (din, dout) weight matrix with the default
    /// [`QUANT_BLOCK`] scale block. Fails loudly on non-finite weights
    /// or `mode == None` (f32 serving stays on [`Csr`]).
    pub fn from_weight(w: &Matrix, mode: QuantMode) -> Result<CsrQ> {
        Self::from_weight_blocked(w, mode, QUANT_BLOCK)
    }

    /// [`CsrQ::from_weight`] with an explicit scale-block length — the
    /// accuracy/overhead knob the tolerance tests sweep.
    pub fn from_weight_blocked(w: &Matrix, mode: QuantMode, block: usize)
                               -> Result<CsrQ> {
        let (din, dout) = (w.rows, w.cols);
        let mut row_ptr = Vec::with_capacity(dout + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for c in 0..dout {
            for r in 0..din {
                let v = w.at(r, c);
                if v != 0.0 {
                    col_idx.push(r as u32);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        let (payload, scales, scale_ptr) =
            quantize_rows(&values, &row_ptr, mode, block)?;
        // per row: 4-byte column indices + packed codes + block scales
        let plan = TilePlan::from_row_bytes(dout, |o| {
            let nnz = (row_ptr[o + 1] - row_ptr[o]) as usize;
            let sb = (scale_ptr[o + 1] - scale_ptr[o]) as usize;
            nnz * 4 + payload.row_bytes(nnz) + sb * 4
        });
        Ok(CsrQ { n_out: dout, n_in: din, row_ptr, col_idx, payload,
                  scales, scale_ptr, scale_block: block, plan })
    }

    #[inline(always)]
    fn dq(&self, base: usize, sp: usize, j: usize) -> f32 {
        dq(&self.payload, &self.scales, self.scale_block, base, sp, j)
    }

    /// y = W^T x with dequant fused into the accumulation loop; same
    /// traversal and accumulation order as [`Csr::matvec`].
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n_in);
        debug_assert_eq!(y.len(), self.n_out);
        for o in 0..self.n_out {
            let lo = self.row_ptr[o] as usize;
            let hi = self.row_ptr[o + 1] as usize;
            let base = self.payload.base(o, &self.row_ptr);
            let sp = self.scale_ptr[o] as usize;
            let mut acc = 0.0f32;
            for k in lo..hi {
                // SAFETY: `from_weight` stores only column indices
                // `< n_in`, and `x.len() == n_in` is debug-asserted
                // above — same invariant as [`Csr::matvec`].
                let xv = unsafe { *x.get_unchecked(self.col_idx[k] as usize) };
                acc += self.dq(base, sp, k - lo) * xv;
            }
            y[o] = acc;
        }
    }

    /// Batched SpMM; see [`Csr::matvec_batch`]. Allocates scratch per
    /// call; hot loops should use [`CsrQ::matvec_batch_into`].
    pub fn matvec_batch(&self, x: &[f32], y: &mut [f32], b: usize) {
        self.matvec_batch_into(x, y, b, &mut SpmmScratch::default());
    }

    /// [`CsrQ::matvec_batch`] with caller-owned scratch. Per sequence
    /// the accumulation order replays [`CsrQ::matvec`], so results are
    /// bit-exact with the single-vector path.
    pub fn matvec_batch_into(&self, x: &[f32], y: &mut [f32], b: usize,
                             scratch: &mut SpmmScratch) {
        debug_assert_eq!(x.len(), b * self.n_in);
        debug_assert_eq!(y.len(), b * self.n_out);
        if b == 1 {
            return self.matvec(x, y);
        }
        transpose_batch_into(x, b, self.n_in, &mut scratch.xt);
        scratch.acc.resize(b, 0.0);
        let xt = &scratch.xt[..];
        let acc = &mut scratch.acc;
        for o in 0..self.n_out {
            acc.fill(0.0);
            let lo = self.row_ptr[o] as usize;
            let hi = self.row_ptr[o + 1] as usize;
            let base = self.payload.base(o, &self.row_ptr);
            let sp = self.scale_ptr[o] as usize;
            for k in lo..hi {
                let v = self.dq(base, sp, k - lo);
                let c = self.col_idx[k] as usize;
                let xrow = &xt[c * b..c * b + b];
                for (a, xv) in acc.iter_mut().zip(xrow.iter()) {
                    *a += v * xv;
                }
            }
            for (bi, &a) in acc.iter().enumerate() {
                y[bi * self.n_out + o] = a;
            }
        }
    }

    /// Tiled variant; see [`Csr::matvec_batch_tiled_into`].
    /// Bit-identical to the untiled path for every batch size and
    /// either [`KernelPath`].
    pub fn matvec_batch_tiled_into(&self, x: &[f32], y: &mut [f32],
                                   b: usize, scratch: &mut SpmmScratch,
                                   path: KernelPath) {
        if b == 1 {
            return self.matvec(x, y);
        }
        tile::matvec_batch_tiled(self, &self.plan, x, y, b, scratch, path);
    }

    /// Rebuild the row-tile plan; see [`Csr::retile`]. Traversal
    /// metadata only — output is bit-identical for any geometry.
    pub fn retile(&mut self, target_bytes: usize, max_rows: usize) {
        let plan = TilePlan::with_budget(self.n_out, |o| {
            let nnz = (self.row_ptr[o + 1] - self.row_ptr[o]) as usize;
            let sb = (self.scale_ptr[o + 1] - self.scale_ptr[o]) as usize;
            nnz * 4 + self.payload.row_bytes(nnz) + sb * 4
        }, target_bytes, max_rows);
        self.plan = plan;
    }

    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Which quantized payload this weight carries.
    pub fn mode(&self) -> QuantMode {
        self.payload.mode()
    }

    /// Actual compact-buffer bytes: indices + codes + scales. The
    /// whole point of the format — compare with [`Csr::mem_bytes`].
    pub fn mem_bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_idx.len() * 4
            + self.payload.mem_bytes() + self.scales.len() * 4
            + self.scale_ptr.len() * 4
    }

    /// Materialize the dequantized weight as a dense (din, dout)
    /// matrix — test/debug helper, never on the serving path.
    pub fn to_dense(&self) -> Matrix {
        let mut w = Matrix::zeros(self.n_in, self.n_out);
        for o in 0..self.n_out {
            let lo = self.row_ptr[o] as usize;
            let hi = self.row_ptr[o + 1] as usize;
            let base = self.payload.base(o, &self.row_ptr);
            let sp = self.scale_ptr[o] as usize;
            for k in lo..hi {
                let r = self.col_idx[k] as usize;
                w.data[r * self.n_out + o] = self.dq(base, sp, k - lo);
            }
        }
        w
    }
}

impl RowTiled for CsrQ {
    fn n_in(&self) -> usize {
        self.n_in
    }

    fn n_out(&self) -> usize {
        self.n_out
    }

    fn exec_tiles(&self, tiles: &[Tile], xt: &[f32], yt: &mut [f32],
                  b: usize, path: KernelPath) {
        let Some(first) = tiles.first() else { return };
        let base_row = first.row0;
        for t in tiles {
            for o in t.row0..t.row1 {
                let yrow =
                    &mut yt[(o - base_row) * b..(o - base_row) * b + b];
                yrow.fill(0.0);
                let lo = self.row_ptr[o] as usize;
                let hi = self.row_ptr[o + 1] as usize;
                let base = self.payload.base(o, &self.row_ptr);
                let sp = self.scale_ptr[o] as usize;
                for k in lo..hi {
                    let v = self.dq(base, sp, k - lo);
                    let c = self.col_idx[k] as usize;
                    let xrow = &xt[c * b..c * b + b];
                    axpy_lanes(yrow, xrow, v, path);
                }
            }
        }
    }
}

/// [`Macko`] with a quantized payload: same bitmap/`row_ptr`
/// structure, int8/int4 codes + per-row-block scales instead of f32
/// values. The 1-bit indices plus sub-byte codes make this the
/// smallest format at moderate sparsity.
#[derive(Debug, Clone)]
pub struct MackoQ {
    pub n_out: usize,
    pub n_in: usize,
    words_per_row: usize,
    pub bitmap: Vec<u64>,
    pub row_ptr: Vec<u32>,
    payload: QuantPayload,
    scales: Vec<f32>,
    scale_ptr: Vec<u32>,
    scale_block: usize,
    /// Row-tiled execution plan (see [`tile`]); traversal metadata
    /// only, excluded from [`MackoQ::mem_bytes`].
    pub plan: TilePlan,
}

impl MackoQ {
    /// Build from a (din, dout) weight matrix with the default
    /// [`QUANT_BLOCK`] scale block. Fails loudly on non-finite weights
    /// or `mode == None` (f32 serving stays on [`Macko`]).
    pub fn from_weight(w: &Matrix, mode: QuantMode) -> Result<MackoQ> {
        Self::from_weight_blocked(w, mode, QUANT_BLOCK)
    }

    /// [`MackoQ::from_weight`] with an explicit scale-block length.
    pub fn from_weight_blocked(w: &Matrix, mode: QuantMode, block: usize)
                               -> Result<MackoQ> {
        let (din, dout) = (w.rows, w.cols);
        let wpr = din.div_ceil(64);
        let mut bitmap = vec![0u64; dout * wpr];
        let mut row_ptr = Vec::with_capacity(dout + 1);
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for c in 0..dout {
            for r in 0..din {
                let v = w.at(r, c);
                if v != 0.0 {
                    bitmap[c * wpr + r / 64] |= 1u64 << (r % 64);
                    values.push(v);
                }
            }
            row_ptr.push(values.len() as u32);
        }
        let (payload, scales, scale_ptr) =
            quantize_rows(&values, &row_ptr, mode, block)?;
        // per row: bitmap words + packed codes + block scales
        let plan = TilePlan::from_row_bytes(dout, |o| {
            let nnz = (row_ptr[o + 1] - row_ptr[o]) as usize;
            let sb = (scale_ptr[o + 1] - scale_ptr[o]) as usize;
            wpr * 8 + payload.row_bytes(nnz) + sb * 4
        });
        Ok(MackoQ { n_out: dout, n_in: din, words_per_row: wpr, bitmap,
                    row_ptr, payload, scales, scale_ptr,
                    scale_block: block, plan })
    }

    #[inline(always)]
    fn dq(&self, base: usize, sp: usize, j: usize) -> f32 {
        dq(&self.payload, &self.scales, self.scale_block, base, sp, j)
    }

    /// y = W^T x via bitmap scan with fused dequant; same traversal
    /// and accumulation order as [`Macko::matvec`].
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n_in);
        debug_assert_eq!(y.len(), self.n_out);
        for o in 0..self.n_out {
            let base = self.payload.base(o, &self.row_ptr);
            let sp = self.scale_ptr[o] as usize;
            let mut j = 0usize;
            let mut acc = 0.0f32;
            let word_base = o * self.words_per_row;
            for wi in 0..self.words_per_row {
                let mut word = self.bitmap[word_base + wi];
                let col0 = wi * 64;
                while word != 0 {
                    let bit = word.trailing_zeros() as usize;
                    // SAFETY: bitmap bits are set only for columns
                    // `< n_in` (tail-word bits beyond `n_in` are never
                    // set at construction), and `x.len() == n_in` is
                    // debug-asserted above — same invariant as
                    // [`Macko::matvec`].
                    let xv = unsafe { *x.get_unchecked(col0 + bit) };
                    acc += self.dq(base, sp, j) * xv;
                    j += 1;
                    word &= word - 1;
                }
            }
            y[o] = acc;
        }
    }

    /// Batched SpMM; see [`Macko::matvec_batch`]. Allocates scratch
    /// per call; hot loops should use [`MackoQ::matvec_batch_into`].
    pub fn matvec_batch(&self, x: &[f32], y: &mut [f32], b: usize) {
        self.matvec_batch_into(x, y, b, &mut SpmmScratch::default());
    }

    /// [`MackoQ::matvec_batch`] with caller-owned scratch. Bit-exact
    /// with [`MackoQ::matvec`] per sequence.
    pub fn matvec_batch_into(&self, x: &[f32], y: &mut [f32], b: usize,
                             scratch: &mut SpmmScratch) {
        debug_assert_eq!(x.len(), b * self.n_in);
        debug_assert_eq!(y.len(), b * self.n_out);
        if b == 1 {
            return self.matvec(x, y);
        }
        transpose_batch_into(x, b, self.n_in, &mut scratch.xt);
        scratch.acc.resize(b, 0.0);
        let xt = &scratch.xt[..];
        let acc = &mut scratch.acc;
        for o in 0..self.n_out {
            acc.fill(0.0);
            let base = self.payload.base(o, &self.row_ptr);
            let sp = self.scale_ptr[o] as usize;
            let mut j = 0usize;
            let word_base = o * self.words_per_row;
            for wi in 0..self.words_per_row {
                let mut word = self.bitmap[word_base + wi];
                let col0 = wi * 64;
                while word != 0 {
                    let bit = word.trailing_zeros() as usize;
                    let v = self.dq(base, sp, j);
                    let c = col0 + bit;
                    let xrow = &xt[c * b..c * b + b];
                    for (a, xv) in acc.iter_mut().zip(xrow.iter()) {
                        *a += v * xv;
                    }
                    j += 1;
                    word &= word - 1;
                }
            }
            for (bi, &a) in acc.iter().enumerate() {
                y[bi * self.n_out + o] = a;
            }
        }
    }

    /// Tiled variant; see [`Macko::matvec_batch_tiled_into`].
    /// Bit-identical to the untiled path for every batch size and
    /// either [`KernelPath`].
    pub fn matvec_batch_tiled_into(&self, x: &[f32], y: &mut [f32],
                                   b: usize, scratch: &mut SpmmScratch,
                                   path: KernelPath) {
        if b == 1 {
            return self.matvec(x, y);
        }
        tile::matvec_batch_tiled(self, &self.plan, x, y, b, scratch, path);
    }

    /// Rebuild the row-tile plan; see [`Macko::retile`].
    pub fn retile(&mut self, target_bytes: usize, max_rows: usize) {
        let wpr = self.words_per_row;
        let plan = TilePlan::with_budget(self.n_out, |o| {
            let nnz = (self.row_ptr[o + 1] - self.row_ptr[o]) as usize;
            let sb = (self.scale_ptr[o + 1] - self.scale_ptr[o]) as usize;
            wpr * 8 + self.payload.row_bytes(nnz) + sb * 4
        }, target_bytes, max_rows);
        self.plan = plan;
    }

    pub fn nnz(&self) -> usize {
        match &self.payload {
            QuantPayload::Int8 { codes } => codes.len(),
            QuantPayload::Int4 { .. } => {
                *self.row_ptr.last().unwrap_or(&0) as usize
            }
        }
    }

    /// Which quantized payload this weight carries.
    pub fn mode(&self) -> QuantMode {
        self.payload.mode()
    }

    /// Actual compact-buffer bytes: bitmap + codes + scales. Compare
    /// with [`Macko::mem_bytes`].
    pub fn mem_bytes(&self) -> usize {
        self.bitmap.len() * 8 + self.row_ptr.len() * 4
            + self.payload.mem_bytes() + self.scales.len() * 4
            + self.scale_ptr.len() * 4
    }

    /// Materialize the dequantized weight as a dense (din, dout)
    /// matrix — test/debug helper, never on the serving path.
    pub fn to_dense(&self) -> Matrix {
        let mut w = Matrix::zeros(self.n_in, self.n_out);
        for o in 0..self.n_out {
            let base = self.payload.base(o, &self.row_ptr);
            let sp = self.scale_ptr[o] as usize;
            let mut j = 0usize;
            let word_base = o * self.words_per_row;
            for wi in 0..self.words_per_row {
                let mut word = self.bitmap[word_base + wi];
                let col0 = wi * 64;
                while word != 0 {
                    let bit = word.trailing_zeros() as usize;
                    w.data[(col0 + bit) * self.n_out + o] =
                        self.dq(base, sp, j);
                    j += 1;
                    word &= word - 1;
                }
            }
        }
        w
    }
}

impl RowTiled for MackoQ {
    fn n_in(&self) -> usize {
        self.n_in
    }

    fn n_out(&self) -> usize {
        self.n_out
    }

    fn exec_tiles(&self, tiles: &[Tile], xt: &[f32], yt: &mut [f32],
                  b: usize, path: KernelPath) {
        let Some(first) = tiles.first() else { return };
        let base_row = first.row0;
        let wpr = self.words_per_row;
        for t in tiles {
            for o in t.row0..t.row1 {
                let yrow =
                    &mut yt[(o - base_row) * b..(o - base_row) * b + b];
                yrow.fill(0.0);
                let base = self.payload.base(o, &self.row_ptr);
                let sp = self.scale_ptr[o] as usize;
                let mut j = 0usize;
                let word_base = o * wpr;
                for wi in 0..wpr {
                    let mut word = self.bitmap[word_base + wi];
                    let col0 = wi * 64;
                    while word != 0 {
                        let bit = word.trailing_zeros() as usize;
                        let v = self.dq(base, sp, j);
                        let c = col0 + bit;
                        let xrow = &xt[c * b..c * b + b];
                        axpy_lanes(yrow, xrow, v, path);
                        j += 1;
                        word &= word - 1;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::pool::WorkerPool;
    use crate::sparse::{random_sparse_weight, Csr, Macko};
    use crate::util::rng::Rng;

    #[test]
    fn parse_and_label_round_trip() {
        assert_eq!(QuantMode::parse("none").unwrap(), QuantMode::None);
        assert_eq!(QuantMode::parse("off").unwrap(), QuantMode::None);
        assert_eq!(QuantMode::parse("int8").unwrap(), QuantMode::Int8);
        assert_eq!(QuantMode::parse("int4").unwrap(), QuantMode::Int4);
        assert!(QuantMode::parse("fp8").is_err());
        assert_eq!(QuantMode::None.label(), "none");
        assert_eq!(QuantMode::Int8.label(), "int8");
        assert_eq!(QuantMode::Int4.label(), "int4");
    }

    #[test]
    fn none_mode_is_rejected_at_construction() {
        let w = random_sparse_weight(8, 8, 0.5, 1);
        assert!(CsrQ::from_weight(&w, QuantMode::None).is_err());
        assert!(MackoQ::from_weight(&w, QuantMode::None).is_err());
    }

    #[test]
    fn non_finite_weights_are_rejected_loudly() {
        let mut w = Matrix::zeros(4, 4);
        w.data[5] = f32::NAN;
        assert!(CsrQ::from_weight(&w, QuantMode::Int8).is_err());
        assert!(MackoQ::from_weight(&w, QuantMode::Int4).is_err());
        w.data[5] = f32::INFINITY;
        let err = CsrQ::from_weight(&w, QuantMode::Int8).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
    }

    #[test]
    fn all_zero_rows_quantize_to_exact_zero_with_unit_scale() {
        // unreachable via from_weight (exact zeros are dropped), but
        // the helper must still be total: scale 1.0, codes 0
        let vals = [0.0f32; 5];
        let (payload, scales, scale_ptr) =
            quantize_rows(&vals, &[0, 5], QuantMode::Int8, 2).unwrap();
        assert_eq!(scales, vec![1.0, 1.0, 1.0]);
        assert_eq!(&scale_ptr[..], &[0u32, 3]);
        for j in 0..5 {
            assert_eq!(dq(&payload, &scales, 2, 0, 0, j), 0.0);
        }
    }

    /// Build the f32 [`Csr`] whose values are exactly the dequantized
    /// codes, at the original nonzero positions — the bitwise
    /// reference for the fused kernels.
    fn dequant_csr(q: &CsrQ) -> Csr {
        let mut values = Vec::with_capacity(q.nnz());
        for o in 0..q.n_out {
            let lo = q.row_ptr[o] as usize;
            let hi = q.row_ptr[o + 1] as usize;
            let base = q.payload.base(o, &q.row_ptr);
            let sp = q.scale_ptr[o] as usize;
            for k in lo..hi {
                values.push(q.dq(base, sp, k - lo));
            }
        }
        let row_ptr = q.row_ptr.clone();
        let plan = TilePlan::from_row_bytes(q.n_out, |o| {
            (row_ptr[o + 1] - row_ptr[o]) as usize * 8
        });
        Csr { n_out: q.n_out, n_in: q.n_in, row_ptr,
              col_idx: q.col_idx.clone(), values, plan }
    }

    /// The [`Macko`] counterpart of [`dequant_csr`]: same bitmap,
    /// dequantized values (stored in the same ascending-column order).
    fn dequant_macko(q: &MackoQ) -> Macko {
        let mut values = Vec::with_capacity(q.nnz());
        for o in 0..q.n_out {
            let lo = q.row_ptr[o] as usize;
            let hi = q.row_ptr[o + 1] as usize;
            let base = q.payload.base(o, &q.row_ptr);
            let sp = q.scale_ptr[o] as usize;
            for k in lo..hi {
                values.push(q.dq(base, sp, k - lo));
            }
        }
        let wpr = q.n_in.div_ceil(64);
        let row_ptr = q.row_ptr.clone();
        let plan = TilePlan::from_row_bytes(q.n_out, |o| {
            wpr * 8 + (row_ptr[o + 1] - row_ptr[o]) as usize * 4
        });
        Macko { n_out: q.n_out, n_in: q.n_in, words_per_row: wpr,
                bitmap: q.bitmap.clone(), row_ptr, values, plan }
    }

    #[test]
    fn quantized_paths_bitwise_match_dequantized_reference() {
        // untiled == tiled == pooled == the f32 reference holding the
        // dequantized values, for both modes, both formats, coarse and
        // fine scale blocks, multiple batch sizes
        let (din, dout) = (96, 72);
        let w = random_sparse_weight(din, dout, 0.8, 7);
        let mut rng = Rng::new(5);
        let pool = WorkerPool::new(4);
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            for block in [3usize, 64] {
                let mut q =
                    CsrQ::from_weight_blocked(&w, mode, block).unwrap();
                q.retile(64, 8); // force a multi-tile plan
                let r = dequant_csr(&q);
                let mut qm =
                    MackoQ::from_weight_blocked(&w, mode, block).unwrap();
                qm.retile(64, 8);
                let rm = dequant_macko(&qm);
                for b in [1usize, 4, 7] {
                    let tag = format!("{mode:?} block={block} b={b}");
                    let x: Vec<f32> =
                        (0..b * din).map(|_| rng.normal()).collect();
                    let mut scratch = SpmmScratch::default();
                    let mut want = vec![0.0f32; b * dout];
                    r.matvec_batch_into(&x, &mut want, b, &mut scratch);
                    let mut got = vec![1.0f32; b * dout];
                    q.matvec_batch_into(&x, &mut got, b, &mut scratch);
                    assert_eq!(got, want, "csrq untiled {tag}");
                    for path in [KernelPath::Scalar,
                                 KernelPath::Unrolled] {
                        got.fill(1.0);
                        q.matvec_batch_tiled_into(&x, &mut got, b,
                                                  &mut scratch, path);
                        assert_eq!(got, want, "csrq tiled {tag} {path:?}");
                        got.fill(1.0);
                        tile::pool_matvec_batch_tiled(&q, &q.plan, &x,
                                                      &mut got, b, &pool,
                                                      &mut scratch, path);
                        assert_eq!(got, want,
                                   "csrq pooled {tag} {path:?}");
                    }

                    rm.matvec_batch_into(&x, &mut want, b, &mut scratch);
                    got.fill(1.0);
                    qm.matvec_batch_into(&x, &mut got, b, &mut scratch);
                    assert_eq!(got, want, "mackoq untiled {tag}");
                    for path in [KernelPath::Scalar,
                                 KernelPath::Unrolled] {
                        got.fill(1.0);
                        qm.matvec_batch_tiled_into(&x, &mut got, b,
                                                   &mut scratch, path);
                        assert_eq!(got, want,
                                   "mackoq tiled {tag} {path:?}");
                        got.fill(1.0);
                        tile::pool_matvec_batch_tiled(&qm, &qm.plan, &x,
                                                      &mut got, b, &pool,
                                                      &mut scratch, path);
                        assert_eq!(got, want,
                                   "mackoq pooled {tag} {path:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn both_formats_dequantize_identically() {
        // one quantize_rows implementation → one dequantized weight
        let w = random_sparse_weight(70, 50, 0.75, 9);
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let c = CsrQ::from_weight(&w, mode).unwrap();
            let m = MackoQ::from_weight(&w, mode).unwrap();
            assert_eq!(c.to_dense().data, m.to_dense().data, "{mode:?}");
        }
    }

    #[test]
    fn dequant_error_within_analytic_bound() {
        // |v - dq(v)| <= block_absmax / (2 * qmax): half a step, no
        // clamp error (the absmax maps to exactly qmax)
        let w = random_sparse_weight(64, 40, 0.7, 13);
        for (mode, qmax) in
            [(QuantMode::Int8, 127.0f32), (QuantMode::Int4, 7.0)] {
            for block in [3usize, 64] {
                let q =
                    CsrQ::from_weight_blocked(&w, mode, block).unwrap();
                let d = q.to_dense();
                for c in 0..w.cols {
                    let rv: Vec<(usize, f32)> = (0..w.rows)
                        .filter_map(|r| {
                            let v = w.at(r, c);
                            (v != 0.0).then_some((r, v))
                        })
                        .collect();
                    for chunk in rv.chunks(block) {
                        let absmax = chunk.iter()
                            .fold(0.0f32, |a, &(_, v)| a.max(v.abs()));
                        let bound =
                            absmax / (2.0 * qmax) * 1.0001 + 1e-7;
                        for &(r, v) in chunk {
                            let e = (d.at(r, c) - v).abs();
                            assert!(e <= bound,
                                    "{mode:?} block={block} r={r} c={c}: \
                                     err {e} > bound {bound}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn quantized_matvec_error_bounded_by_measured_per_weight_error() {
        // row error <= sum_k |dv_k| * |x_k| (+ f32 rounding slack)
        let (din, dout) = (80, 48);
        let w = random_sparse_weight(din, dout, 0.75, 17);
        let csr = Csr::from_weight(&w);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..din).map(|_| rng.normal()).collect();
        let mut yf = vec![0.0f32; dout];
        csr.matvec(&x, &mut yf);
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let q = CsrQ::from_weight(&w, mode).unwrap();
            let d = q.to_dense();
            let mut yq = vec![0.0f32; dout];
            q.matvec(&x, &mut yq);
            for o in 0..dout {
                let bound: f32 = (0..din)
                    .map(|r| (d.at(r, o) - w.at(r, o)).abs() * x[r].abs())
                    .sum();
                let slack = 1e-4 + 1e-5 * yf[o].abs();
                let e = (yq[o] - yf[o]).abs();
                assert!(e <= bound + slack,
                        "{mode:?} row {o}: err {e} > {bound} + {slack}");
            }
        }
    }

    #[test]
    fn int4_odd_row_packing_round_trips() {
        // output rows with nnz 1, 3, 5 exercise byte alignment and the
        // pad nibble
        let mut w = Matrix::zeros(8, 3);
        let cols: [&[(usize, f32)]; 3] = [
            &[(2, 1.0)],
            &[(0, 0.5), (3, -0.25), (7, 1.0)],
            &[(1, -1.0), (2, 0.75), (4, 0.5), (5, -0.5), (6, 0.25)],
        ];
        for (c, entries) in cols.iter().enumerate() {
            for &(r, v) in entries.iter() {
                w.data[r * 3 + c] = v;
            }
        }
        let q = CsrQ::from_weight(&w, QuantMode::Int4).unwrap();
        let QuantPayload::Int4 { packed, byte_ptr } = &q.payload else {
            panic!("expected int4 payload");
        };
        assert_eq!(&byte_ptr[..], &[0u32, 1, 3, 6]);
        assert_eq!(packed.len(), 6);
        // pad nibbles of odd-length rows stay zero
        assert_eq!(packed[0] >> 4, 0, "row 0 pad nibble");
        assert_eq!(packed[2] >> 4, 0, "row 1 pad nibble");
        assert_eq!(packed[5] >> 4, 0, "row 2 pad nibble");
        let d = q.to_dense();
        for (c, entries) in cols.iter().enumerate() {
            let absmax = entries.iter()
                .fold(0.0f32, |a, &(_, v)| a.max(v.abs()));
            for &(r, v) in entries.iter() {
                let e = (d.at(r, c) - v).abs();
                assert!(e <= absmax / 14.0 + 1e-6, "r={r} c={c}: {e}");
            }
            for r in 0..8 {
                if !entries.iter().any(|&(rr, _)| rr == r) {
                    assert_eq!(d.at(r, c), 0.0, "r={r} c={c} not zero");
                }
            }
        }
    }

    #[test]
    fn outlier_in_next_block_does_not_poison_scales() {
        // 4 small weights then a 100x outlier: with block=4 the
        // outlier lands in block 1 and block 0 keeps its fine scale
        let mut w = Matrix::zeros(5, 1);
        for r in 0..4 {
            w.data[r] = 0.01;
        }
        w.data[4] = 100.0;
        let q =
            CsrQ::from_weight_blocked(&w, QuantMode::Int8, 4).unwrap();
        assert_eq!(q.scales.len(), 2);
        let d = q.to_dense();
        for r in 0..4 {
            assert!((d.at(r, 0) - 0.01).abs() <= 0.01 * 1e-4,
                    "block 0 element {r} coarsened: {}", d.at(r, 0));
        }
        // the absmax element of a block dequantizes near-exactly
        assert!((d.at(4, 0) - 100.0).abs() <= 100.0 * 1e-5);
    }

    #[test]
    fn quantized_mem_meets_compression_targets() {
        // the acceptance numbers: >= 3x (int8) / >= 5x (int4) vs the
        // dense f32 matrix on a bench-shaped 90%-sparse weight
        let w = random_sparse_weight(512, 512, 0.9, 1);
        let dense_f32 = (512 * 512 * 4) as f64;
        let c8 = CsrQ::from_weight(&w, QuantMode::Int8).unwrap();
        let c4 = CsrQ::from_weight(&w, QuantMode::Int4).unwrap();
        let m8 = MackoQ::from_weight(&w, QuantMode::Int8).unwrap();
        let m4 = MackoQ::from_weight(&w, QuantMode::Int4).unwrap();
        assert!(dense_f32 / c8.mem_bytes() as f64 >= 3.0,
                "csr int8 {}", c8.mem_bytes());
        assert!(dense_f32 / c4.mem_bytes() as f64 >= 5.0,
                "csr int4 {}", c4.mem_bytes());
        assert!(dense_f32 / m8.mem_bytes() as f64 >= 3.0,
                "macko int8 {}", m8.mem_bytes());
        assert!(dense_f32 / m4.mem_bytes() as f64 >= 5.0,
                "macko int4 {}", m4.mem_bytes());
        assert!(c4.mem_bytes() < c8.mem_bytes());
        assert!(m4.mem_bytes() < m8.mem_bytes());
        // and strictly smaller than their own f32 counterparts
        assert!(c8.mem_bytes() < Csr::from_weight(&w).mem_bytes());
        assert!(m8.mem_bytes() < Macko::from_weight(&w).mem_bytes());
        assert_eq!(c8.mode(), QuantMode::Int8);
        assert_eq!(m4.mode(), QuantMode::Int4);
        assert_eq!(c8.nnz(), Csr::from_weight(&w).nnz());
        assert_eq!(m4.nnz(), Macko::from_weight(&w).nnz());
    }

    #[test]
    fn empty_matrix_ok() {
        let w = Matrix::zeros(32, 16);
        let x = vec![1.0f32; 32];
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let q = CsrQ::from_weight(&w, mode).unwrap();
            let mut y = vec![7.0f32; 16];
            q.matvec(&x, &mut y);
            assert!(y.iter().all(|&v| v == 0.0));
            let qm = MackoQ::from_weight(&w, mode).unwrap();
            let mut y2 = vec![7.0f32; 16];
            qm.matvec(&x, &mut y2);
            assert!(y2.iter().all(|&v| v == 0.0));
        }
    }
}

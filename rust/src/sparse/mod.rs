//! Sparse matrix formats + SpMV — the deployment substrate for Table 1.
//!
//! Two formats, mirroring the paper's §5.3 benchmark:
//!  - `Csr`: textbook compressed sparse row (here: compressed sparse
//!    *column* groups fit our (din, dout) x@W orientation — we store the
//!    transpose W^T row-wise so SpMV streams output rows),
//!  - `Macko`: a MACKO-like bitmap format (Macko & Boža 2025): per
//!    output row, a din-bit occupancy bitmap plus densely packed values.
//!    At moderate sparsity this beats CSR's 4-byte-per-nnz index
//!    overhead — exactly MACKO's claim — and decodes with popcount-free
//!    sequential scans.
//!
//! Memory accounting is real (`mem_bytes` sums the actual buffers), so
//! the Table-1 memory column reflects genuine storage.
//!
//! Both formats also come in quantized variants ([`CsrQ`] / [`MackoQ`]
//! in [`quantized`]): identical index/bitmap structure, int8 or int4
//! codes with per-row-block absmax scales instead of f32 values, and
//! dequant fused into the same kernel set — the Elsa-L serving path.
//!
//! Semi-structured N:M checkpoints get their own format ([`NmSparse`]
//! in [`nm`]): a fixed nonzero count per M-column group makes the
//! inner loop branch-free with compile-time trip counts. Every
//! format's hot loops additionally come in two [`KernelPath`]s —
//! `Scalar` (the bit-exact reference) and `Unrolled` (explicit
//! fixed-width lane accumulators) — that produce bit-identical output
//! because unrolling only ever spreads *independent* accumulators
//! (batch lanes, output rows), never reassociates within one.

pub mod nm;
pub mod quantized;
pub mod tile;

pub use nm::{nm_project, NmMode, NmSparse, NmWeights};
pub use quantized::{CsrQ, MackoQ, QuantMode, QUANT_BLOCK};
pub use tile::{dense_plan, matvec_batch_tiled, par_matvec_batch_tiled,
               pool_matvec_batch_tiled, pool_t_matmat, RowTiled, Tile,
               TilePlan};

use anyhow::{bail, Result};

use crate::tensor::Matrix;

/// Runtime traversal-path toggle for the hot SpMM loops. Both paths
/// are bit-identical (see the module docs); `Scalar` exists as the
/// always-trusted reference and as the CI forcing target, `Unrolled`
/// is the default serving path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelPath {
    /// One accumulator at a time, the exact pre-PR-8 loops.
    Scalar,
    /// Manual 4-wide unrolling over independent accumulators (batch
    /// lanes in the tiled kernels, output rows in the N:M matvec).
    #[default]
    Unrolled,
}

/// Environment variable that forces a kernel path engine-wide — the
/// CI `kernel-paths` steps set it to run the whole kernel test suite
/// once per path. An invalid value panics: a typo silently falling
/// back to the default would defeat the forcing.
pub const KERNEL_PATH_ENV: &str = "ELSA_KERNEL_PATH";

impl KernelPath {
    pub fn parse(s: &str) -> Result<KernelPath> {
        Ok(match s {
            "scalar" => KernelPath::Scalar,
            "unrolled" => KernelPath::Unrolled,
            other => bail!("unknown kernel path '{other}' \
                            (expected scalar or unrolled)"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Unrolled => "unrolled",
        }
    }

    /// The engine-build default: `ELSA_KERNEL_PATH` if set (panicking
    /// on garbage), else `Unrolled`. Explicit `--kernel-path` flags
    /// and explicit-path tests override/ignore this freely.
    pub fn default_path() -> KernelPath {
        // DETERMINISM-OK: engine-build configuration read, resolved
        // once before any serving starts — the chosen path is constant
        // for the engine's lifetime and both paths are bit-identical.
        match std::env::var(KERNEL_PATH_ENV) {
            Ok(v) => KernelPath::parse(&v).unwrap_or_else(|e| {
                panic!("{KERNEL_PATH_ENV}: {e}")
            }),
            Err(_) => KernelPath::Unrolled,
        }
    }
}

/// `acc[:] += v * xrow[:]` across the batch lanes of one nonzero —
/// the shared inner step of every format's tiled/batched kernel. The
/// `Unrolled` arm walks four independent lanes per iteration; lane
/// accumulation order per lane is identical to `Scalar`, so the two
/// paths are bit-exact. `#[inline(always)]` so the per-path `match`
/// is hoisted out of callers' nonzero loops (loop unswitching).
#[inline(always)]
pub(crate) fn axpy_lanes(acc: &mut [f32], xrow: &[f32], v: f32,
                         path: KernelPath) {
    debug_assert_eq!(acc.len(), xrow.len());
    match path {
        KernelPath::Scalar => {
            for (a, xv) in acc.iter_mut().zip(xrow.iter()) {
                *a += v * xv;
            }
        }
        KernelPath::Unrolled => {
            let b = acc.len();
            let mut i = 0usize;
            while i + 4 <= b {
                // four independent lanes — no reassociation within any
                // SAFETY: the loop guard holds i + 4 <= b and
                // `acc.len() == xrow.len() == b` (debug-asserted
                // above), so lanes i..i+4 are in bounds of both
                // slices.
                unsafe {
                    *acc.get_unchecked_mut(i) +=
                        v * *xrow.get_unchecked(i);
                    *acc.get_unchecked_mut(i + 1) +=
                        v * *xrow.get_unchecked(i + 1);
                    *acc.get_unchecked_mut(i + 2) +=
                        v * *xrow.get_unchecked(i + 2);
                    *acc.get_unchecked_mut(i + 3) +=
                        v * *xrow.get_unchecked(i + 3);
                }
                i += 4;
            }
            while i < b {
                acc[i] += v * xrow[i];
                i += 1;
            }
        }
    }
}

/// CSR over W^T: row r holds the non-zeros of output neuron r.
#[derive(Debug, Clone)]
pub struct Csr {
    pub n_out: usize,
    pub n_in: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
    /// Row-tiled execution plan, built once here at construction time
    /// (see [`tile`]); traversal metadata only, excluded from
    /// [`Csr::mem_bytes`].
    pub plan: TilePlan,
}

impl Csr {
    /// Build from a (din, dout) weight matrix (x @ W orientation).
    pub fn from_weight(w: &Matrix) -> Csr {
        let (din, dout) = (w.rows, w.cols);
        let mut row_ptr = Vec::with_capacity(dout + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0);
        for c in 0..dout {
            for r in 0..din {
                let v = w.at(r, c);
                if v != 0.0 {
                    col_idx.push(r as u32);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        // 8 bytes per nonzero: a 4-byte value + a 4-byte column index
        let plan = TilePlan::from_row_bytes(dout, |o| {
            (row_ptr[o + 1] - row_ptr[o]) as usize * 8
        });
        Csr { n_out: dout, n_in: din, row_ptr, col_idx, values, plan }
    }

    /// y = W^T x  i.e. y[c] = sum_r W[r, c] * x[r].
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n_in);
        debug_assert_eq!(y.len(), self.n_out);
        for o in 0..self.n_out {
            let lo = self.row_ptr[o] as usize;
            let hi = self.row_ptr[o + 1] as usize;
            let mut acc = 0.0f32;
            for k in lo..hi {
                // SAFETY: `from_weight` stores only column indices
                // `< n_in`, and `x.len() == n_in` is debug-asserted
                // above, so the lookup is in bounds.
                let xv = unsafe { *x.get_unchecked(self.col_idx[k] as usize) };
                acc += self.values[k] * xv;
            }
            y[o] = acc;
        }
    }

    /// Multi-vector SpMM: Y = X W for a row-major batch X of shape
    /// (b, din), writing Y (b, dout). Decodes each output row's index
    /// list once and amortizes it across the whole batch — the classic
    /// SpMM win in the memory-bound decode regime. Per sequence the
    /// accumulation order is identical to [`Csr::matvec`], so results
    /// are bit-exact with the single-vector path. Allocates scratch per
    /// call; hot loops should hold a [`SpmmScratch`] and use
    /// [`Csr::matvec_batch_into`].
    pub fn matvec_batch(&self, x: &[f32], y: &mut [f32], b: usize) {
        self.matvec_batch_into(x, y, b, &mut SpmmScratch::default());
    }

    /// [`Csr::matvec_batch`] with caller-owned scratch (no per-call
    /// heap allocation once the scratch has warmed up).
    pub fn matvec_batch_into(&self, x: &[f32], y: &mut [f32], b: usize,
                             scratch: &mut SpmmScratch) {
        debug_assert_eq!(x.len(), b * self.n_in);
        debug_assert_eq!(y.len(), b * self.n_out);
        if b == 1 {
            return self.matvec(x, y);
        }
        // stage the batch as (din, b) so the inner loop is contiguous
        transpose_batch_into(x, b, self.n_in, &mut scratch.xt);
        scratch.acc.resize(b, 0.0);
        let xt = &scratch.xt[..];
        let acc = &mut scratch.acc;
        for o in 0..self.n_out {
            acc.fill(0.0);
            let lo = self.row_ptr[o] as usize;
            let hi = self.row_ptr[o + 1] as usize;
            for k in lo..hi {
                let v = self.values[k];
                let c = self.col_idx[k] as usize;
                let xrow = &xt[c * b..c * b + b];
                for (a, xv) in acc.iter_mut().zip(xrow.iter()) {
                    *a += v * xv;
                }
            }
            for (bi, &a) in acc.iter().enumerate() {
                y[bi * self.n_out + o] = a;
            }
        }
    }

    /// Tiled variant of [`Csr::matvec_batch_into`]: walks each
    /// cache-sized row tile of the construction-time [`TilePlan`] once
    /// per step and applies it across all `b` sequences while the
    /// tile's index/value slices are cache-resident. Bit-identical to
    /// the untiled path for every batch size and either [`KernelPath`]
    /// (see [`tile`]); `b == 1` falls through to the single-vector
    /// scan, which has no batch lanes to unroll.
    pub fn matvec_batch_tiled_into(&self, x: &[f32], y: &mut [f32],
                                   b: usize, scratch: &mut SpmmScratch,
                                   path: KernelPath) {
        if b == 1 {
            return self.matvec(x, y);
        }
        tile::matvec_batch_tiled(self, &self.plan, x, y, b, scratch, path);
    }

    /// Matrix convenience wrapper over [`Csr::matvec_batch`]:
    /// returns X @ W for X of shape (b, din). Allocates the output and
    /// a fresh scratch; hot loops should hold both and call
    /// [`Csr::matmat_into`].
    pub fn matmat(&self, x: &Matrix) -> Matrix {
        let mut y = Matrix::zeros(x.rows, self.n_out);
        self.matmat_into(x, &mut y, &mut SpmmScratch::default());
        y
    }

    /// [`Csr::matmat`] with caller-owned output and scratch — the
    /// allocation-free form for repeated calls.
    pub fn matmat_into(&self, x: &Matrix, y: &mut Matrix,
                       scratch: &mut SpmmScratch) {
        assert_eq!(x.cols, self.n_in, "matmat shape mismatch");
        assert_eq!((y.rows, y.cols), (x.rows, self.n_out),
                   "matmat output shape mismatch");
        self.matvec_batch_into(&x.data, &mut y.data, x.rows, scratch);
    }

    /// Rebuild the row-tile plan with an explicit byte budget and row
    /// cap ([`TilePlan::with_budget`]): the deployment tuning knob for
    /// cache sizes other than the default, and the stress knob the
    /// integration suites use to force multi-tile plans on toy-sized
    /// layers. Traversal metadata only — output is bit-identical for
    /// any geometry.
    pub fn retile(&mut self, target_bytes: usize, max_rows: usize) {
        let plan = TilePlan::with_budget(self.n_out, |o| {
            (self.row_ptr[o + 1] - self.row_ptr[o]) as usize * 8
        }, target_bytes, max_rows);
        self.plan = plan;
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn mem_bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_idx.len() * 4
            + self.values.len() * 4
    }
}

/// Reusable scratch for the batched kernels: the (n, b) re-layout of
/// the input batch, the per-row accumulator, and the tiled kernels'
/// (n_out, b) staging buffer. Hold one per decode loop so repeated
/// `matvec_batch_into` / `matvec_batch_tiled_into` calls stop hitting
/// the allocator.
#[derive(Debug, Default)]
pub struct SpmmScratch {
    xt: Vec<f32>,
    acc: Vec<f32>,
    yt: Vec<f32>,
}

/// Re-layout a row-major (b, n) batch as (n, b) into `xt` so batched
/// kernels get unit-stride access across the batch in their inner
/// loops. Every element of `xt[..b * n]` is overwritten.
fn transpose_batch_into(x: &[f32], b: usize, n: usize, xt: &mut Vec<f32>) {
    xt.resize(b * n, 0.0);
    for bi in 0..b {
        let row = &x[bi * n..(bi + 1) * n];
        for (c, &v) in row.iter().enumerate() {
            xt[c * b + bi] = v;
        }
    }
}

/// MACKO-like bitmap format: per output row, a din-bit bitmap + packed
/// non-zero values in input order.
#[derive(Debug, Clone)]
pub struct Macko {
    pub n_out: usize,
    pub n_in: usize,
    words_per_row: usize,
    pub bitmap: Vec<u64>,
    pub row_ptr: Vec<u32>,
    pub values: Vec<f32>,
    /// Row-tiled execution plan, built once here at construction time
    /// (see [`tile`]); traversal metadata only, excluded from
    /// [`Macko::mem_bytes`].
    pub plan: TilePlan,
}

impl Macko {
    pub fn from_weight(w: &Matrix) -> Macko {
        let (din, dout) = (w.rows, w.cols);
        let wpr = din.div_ceil(64);
        let mut bitmap = vec![0u64; dout * wpr];
        let mut row_ptr = Vec::with_capacity(dout + 1);
        let mut values = Vec::new();
        row_ptr.push(0);
        for c in 0..dout {
            for r in 0..din {
                let v = w.at(r, c);
                if v != 0.0 {
                    bitmap[c * wpr + r / 64] |= 1u64 << (r % 64);
                    values.push(v);
                }
            }
            row_ptr.push(values.len() as u32);
        }
        // per row: the din-bit bitmap words plus the packed values
        let plan = TilePlan::from_row_bytes(dout, |o| {
            wpr * 8 + (row_ptr[o + 1] - row_ptr[o]) as usize * 4
        });
        Macko { n_out: dout, n_in: din, words_per_row: wpr, bitmap,
                row_ptr, values, plan }
    }

    /// y = W^T x via bitmap scan: iterate set bits word by word.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n_in);
        debug_assert_eq!(y.len(), self.n_out);
        for o in 0..self.n_out {
            let mut k = self.row_ptr[o] as usize;
            let mut acc = 0.0f32;
            let base = o * self.words_per_row;
            for wi in 0..self.words_per_row {
                let mut word = self.bitmap[base + wi];
                let col0 = wi * 64;
                while word != 0 {
                    let bit = word.trailing_zeros() as usize;
                    // SAFETY: `values` holds one entry per set bitmap
                    // bit in scan order, so `k < values.len()`; and
                    // `col0 + bit < words_per_row * 64` rounds up to
                    // `n_in` with the tail-word bits never set, so the
                    // `x` lookup (len `n_in`, debug-asserted) is in
                    // bounds.
                    acc += unsafe {
                        *self.values.get_unchecked(k)
                            * *x.get_unchecked(col0 + bit)
                    };
                    k += 1;
                    word &= word - 1;
                }
            }
            y[o] = acc;
        }
    }

    /// Multi-vector SpMM over the bitmap format: Y = X W for row-major
    /// X (b, din), writing Y (b, dout). Each output row's bitmap is
    /// scanned once per step instead of once per sequence — the decode
    /// cost MACKO pays for its 1-bit indices is amortized across the
    /// batch. Bit-exact with [`Macko::matvec`] per sequence. Allocates
    /// scratch per call; hot loops should hold a [`SpmmScratch`] and
    /// use [`Macko::matvec_batch_into`].
    pub fn matvec_batch(&self, x: &[f32], y: &mut [f32], b: usize) {
        self.matvec_batch_into(x, y, b, &mut SpmmScratch::default());
    }

    /// [`Macko::matvec_batch`] with caller-owned scratch (no per-call
    /// heap allocation once the scratch has warmed up).
    pub fn matvec_batch_into(&self, x: &[f32], y: &mut [f32], b: usize,
                             scratch: &mut SpmmScratch) {
        debug_assert_eq!(x.len(), b * self.n_in);
        debug_assert_eq!(y.len(), b * self.n_out);
        if b == 1 {
            return self.matvec(x, y);
        }
        transpose_batch_into(x, b, self.n_in, &mut scratch.xt);
        scratch.acc.resize(b, 0.0);
        let xt = &scratch.xt[..];
        let acc = &mut scratch.acc;
        for o in 0..self.n_out {
            acc.fill(0.0);
            let mut k = self.row_ptr[o] as usize;
            let base = o * self.words_per_row;
            for wi in 0..self.words_per_row {
                let mut word = self.bitmap[base + wi];
                let col0 = wi * 64;
                while word != 0 {
                    let bit = word.trailing_zeros() as usize;
                    let v = self.values[k];
                    let c = col0 + bit;
                    let xrow = &xt[c * b..c * b + b];
                    for (a, xv) in acc.iter_mut().zip(xrow.iter()) {
                        *a += v * xv;
                    }
                    k += 1;
                    word &= word - 1;
                }
            }
            for (bi, &a) in acc.iter().enumerate() {
                y[bi * self.n_out + o] = a;
            }
        }
    }

    /// Tiled variant of [`Macko::matvec_batch_into`]: walks each
    /// cache-sized row tile of the construction-time [`TilePlan`] once
    /// per step and applies it across all `b` sequences while the
    /// tile's bitmap/value slices are cache-resident. Bit-identical to
    /// the untiled path for every batch size and either [`KernelPath`]
    /// (see [`tile`]); `b == 1` falls through to the single-vector
    /// scan, which has no batch lanes to unroll.
    pub fn matvec_batch_tiled_into(&self, x: &[f32], y: &mut [f32],
                                   b: usize, scratch: &mut SpmmScratch,
                                   path: KernelPath) {
        if b == 1 {
            return self.matvec(x, y);
        }
        tile::matvec_batch_tiled(self, &self.plan, x, y, b, scratch, path);
    }

    /// Matrix convenience wrapper over [`Macko::matvec_batch`]:
    /// returns X @ W for X of shape (b, din). Allocates the output and
    /// a fresh scratch; hot loops should hold both and call
    /// [`Macko::matmat_into`].
    pub fn matmat(&self, x: &Matrix) -> Matrix {
        let mut y = Matrix::zeros(x.rows, self.n_out);
        self.matmat_into(x, &mut y, &mut SpmmScratch::default());
        y
    }

    /// [`Macko::matmat`] with caller-owned output and scratch — the
    /// allocation-free form for repeated calls.
    pub fn matmat_into(&self, x: &Matrix, y: &mut Matrix,
                       scratch: &mut SpmmScratch) {
        assert_eq!(x.cols, self.n_in, "matmat shape mismatch");
        assert_eq!((y.rows, y.cols), (x.rows, self.n_out),
                   "matmat output shape mismatch");
        self.matvec_batch_into(&x.data, &mut y.data, x.rows, scratch);
    }

    /// Rebuild the row-tile plan with an explicit byte budget and row
    /// cap — the [`Csr::retile`] counterpart for the bitmap format.
    pub fn retile(&mut self, target_bytes: usize, max_rows: usize) {
        let wpr = self.words_per_row;
        let plan = TilePlan::with_budget(self.n_out, |o| {
            wpr * 8 + (self.row_ptr[o + 1] - self.row_ptr[o]) as usize * 4
        }, target_bytes, max_rows);
        self.plan = plan;
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn mem_bytes(&self) -> usize {
        self.bitmap.len() * 8 + self.row_ptr.len() * 4
            + self.values.len() * 4
    }
}

/// Dense GEMV baseline on W (din, dout): y = W^T x.
pub fn dense_matvec(w: &Matrix, x: &[f32], y: &mut [f32]) {
    let t = w.t_matvec(x);
    y.copy_from_slice(&t);
}

/// Dense batched baseline: Y = X W for row-major X (b, din). Loops the
/// skip-zero GEMV per row, so each row is bit-exact with
/// [`dense_matvec`].
pub fn dense_matvec_batch(w: &Matrix, x: &[f32], y: &mut [f32], b: usize) {
    debug_assert_eq!(x.len(), b * w.rows);
    debug_assert_eq!(y.len(), b * w.cols);
    for bi in 0..b {
        let t = w.t_matvec(&x[bi * w.rows..(bi + 1) * w.rows]);
        y[bi * w.cols..(bi + 1) * w.cols].copy_from_slice(&t);
    }
}

/// Dense matrix wrapper: returns X @ W (same accumulation order as
/// [`dense_matvec`] per row, via the skip-zero ikj GEMM).
pub fn dense_matmat(w: &Matrix, x: &Matrix) -> Matrix {
    x.matmul(w)
}

/// Seeded random (din, dout) weight with i.i.d. zeroing at `sparsity`
/// — the one weight ensemble shared by the kernel benches and the
/// bit-identity test suites, so they all measure the same matrices.
pub fn random_sparse_weight(din: usize, dout: usize, sparsity: f64,
                            seed: u64) -> Matrix {
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut w = Matrix::randn(din, dout, 1.0, &mut rng);
    for x in w.data.iter_mut() {
        if rng.f64() < sparsity {
            *x = 0.0;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sparse_weight(din: usize, dout: usize, sparsity: f64, seed: u64)
                     -> Matrix {
        random_sparse_weight(din, dout, sparsity, seed)
    }

    #[test]
    fn csr_matches_dense() {
        let w = sparse_weight(64, 48, 0.8, 0);
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let mut yd = vec![0.0; 48];
        let mut yc = vec![0.0; 48];
        dense_matvec(&w, &x, &mut yd);
        Csr::from_weight(&w).matvec(&x, &mut yc);
        for (a, b) in yd.iter().zip(yc.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn macko_matches_dense() {
        for din in [64usize, 100, 130] {
            let w = sparse_weight(din, 32, 0.7, din as u64);
            let mut rng = Rng::new(2);
            let x: Vec<f32> = (0..din).map(|_| rng.normal()).collect();
            let mut yd = vec![0.0; 32];
            let mut ym = vec![0.0; 32];
            dense_matvec(&w, &x, &mut yd);
            Macko::from_weight(&w).matvec(&x, &mut ym);
            for (a, b) in yd.iter().zip(ym.iter()) {
                assert!((a - b).abs() < 1e-4, "din={din}");
            }
        }
    }

    #[test]
    fn macko_smaller_than_csr_at_moderate_sparsity() {
        // MACKO's raison d'etre: at 50-90% sparsity the 1-bit bitmap
        // beats CSR's 32-bit indices
        let w = sparse_weight(256, 256, 0.7, 3);
        let csr = Csr::from_weight(&w).mem_bytes();
        let mck = Macko::from_weight(&w).mem_bytes();
        assert!(mck < csr, "macko {mck} >= csr {csr}");
    }

    #[test]
    fn csr_wins_at_extreme_sparsity() {
        let w = sparse_weight(256, 256, 0.995, 4);
        let csr = Csr::from_weight(&w).mem_bytes();
        let mck = Macko::from_weight(&w).mem_bytes();
        assert!(csr < mck, "csr {csr} >= macko {mck}");
    }

    #[test]
    fn empty_matrix_ok() {
        let w = Matrix::zeros(32, 16);
        let x = vec![1.0f32; 32];
        let mut y = vec![7.0f32; 16];
        Csr::from_weight(&w).matvec(&x, &mut y);
        assert!(y.iter().all(|&v| v == 0.0));
        let mut y2 = vec![7.0f32; 16];
        Macko::from_weight(&w).matvec(&x, &mut y2);
        assert!(y2.iter().all(|&v| v == 0.0));
    }

    fn batch_input(b: usize, din: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..b * din).map(|_| rng.normal()).collect()
    }

    #[test]
    fn matvec_batch_b1_is_bitwise_matvec() {
        let (din, dout) = (96, 80);
        let w = sparse_weight(din, dout, 0.8, 11);
        let x = batch_input(1, din, 12);
        let csr = Csr::from_weight(&w);
        let mck = Macko::from_weight(&w);

        let mut y1 = vec![0.0f32; dout];
        let mut yb = vec![0.0f32; dout];
        csr.matvec(&x, &mut y1);
        csr.matvec_batch(&x, &mut yb, 1);
        assert_eq!(y1, yb, "csr batch=1 must be bit-exact");

        mck.matvec(&x, &mut y1);
        mck.matvec_batch(&x, &mut yb, 1);
        assert_eq!(y1, yb, "macko batch=1 must be bit-exact");

        dense_matvec(&w, &x, &mut y1);
        dense_matvec_batch(&w, &x, &mut yb, 1);
        assert_eq!(y1, yb, "dense batch=1 must be bit-exact");
    }

    #[test]
    fn matvec_batch_matches_per_sequence() {
        // ragged-ish dims across formats; batched rows must equal the
        // per-sequence kernels bit-for-bit (batch 2, 4, 7)
        let (din, dout) = (100, 72);
        let w = sparse_weight(din, dout, 0.75, 21);
        let csr = Csr::from_weight(&w);
        let mck = Macko::from_weight(&w);
        for b in [2usize, 4, 7] {
            let x = batch_input(b, din, 100 + b as u64);
            let mut yc = vec![0.0f32; b * dout];
            let mut ym = vec![0.0f32; b * dout];
            let mut yd = vec![0.0f32; b * dout];
            csr.matvec_batch(&x, &mut yc, b);
            mck.matvec_batch(&x, &mut ym, b);
            dense_matvec_batch(&w, &x, &mut yd, b);
            for bi in 0..b {
                let xi = &x[bi * din..(bi + 1) * din];
                let mut want = vec![0.0f32; dout];
                csr.matvec(xi, &mut want);
                assert_eq!(&yc[bi * dout..(bi + 1) * dout], &want[..],
                           "csr b={b} row {bi}");
                mck.matvec(xi, &mut want);
                assert_eq!(&ym[bi * dout..(bi + 1) * dout], &want[..],
                           "macko b={b} row {bi}");
                dense_matvec(&w, xi, &mut want);
                assert_eq!(&yd[bi * dout..(bi + 1) * dout], &want[..],
                           "dense b={b} row {bi}");
            }
        }
    }

    #[test]
    fn matmat_agrees_with_dense() {
        let (din, dout, b) = (64, 48, 5);
        let w = sparse_weight(din, dout, 0.7, 31);
        let x = Matrix::from_vec(b, din, batch_input(b, din, 32));
        let expect = dense_matmat(&w, &x);
        let yc = Csr::from_weight(&w).matmat(&x);
        let ym = Macko::from_weight(&w).matmat(&x);
        assert_eq!((yc.rows, yc.cols), (b, dout));
        assert_eq!((ym.rows, ym.cols), (b, dout));
        for (a, b) in expect.data.iter().zip(yc.data.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in expect.data.iter().zip(ym.data.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn matvec_batch_into_reuses_scratch_across_batch_sizes() {
        // the engine shrinks b as slots retire; one scratch must serve
        // every size (and the results must stay bit-exact)
        let (din, dout) = (80, 40);
        let w = sparse_weight(din, dout, 0.8, 41);
        let csr = Csr::from_weight(&w);
        let mck = Macko::from_weight(&w);
        let mut scratch = SpmmScratch::default();
        for &b in &[5usize, 3, 7, 1] {
            let x = batch_input(b, din, 200 + b as u64);
            let mut got = vec![0.0f32; b * dout];
            let mut want = vec![0.0f32; b * dout];
            csr.matvec_batch_into(&x, &mut got, b, &mut scratch);
            csr.matvec_batch(&x, &mut want, b);
            assert_eq!(got, want, "csr b={b}");
            mck.matvec_batch_into(&x, &mut got, b, &mut scratch);
            mck.matvec_batch(&x, &mut want, b);
            assert_eq!(got, want, "macko b={b}");
        }
    }

    #[test]
    fn matmat_into_reuses_scratch_and_matches_matmat() {
        let (din, dout) = (64, 40);
        let w = sparse_weight(din, dout, 0.8, 51);
        let csr = Csr::from_weight(&w);
        let mck = Macko::from_weight(&w);
        let mut scratch = SpmmScratch::default();
        for &b in &[3usize, 6, 2] {
            let x = Matrix::from_vec(b, din, batch_input(b, din, b as u64));
            let mut y = Matrix::zeros(b, dout);
            csr.matmat_into(&x, &mut y, &mut scratch);
            assert_eq!(y.data, csr.matmat(&x).data, "csr b={b}");
            mck.matmat_into(&x, &mut y, &mut scratch);
            assert_eq!(y.data, mck.matmat(&x).data, "macko b={b}");
        }
    }

    #[test]
    fn axpy_lanes_paths_are_bitwise_identical() {
        // every remainder class of the 4-wide unroll
        for b in [1usize, 2, 3, 4, 5, 7, 8, 16, 19] {
            let mut rng = Rng::new(b as u64);
            let xrow: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
            let base: Vec<f32> = (0..b).map(|_| rng.normal()).collect();
            let v = rng.normal();
            let mut s = base.clone();
            let mut u = base.clone();
            axpy_lanes(&mut s, &xrow, v, KernelPath::Scalar);
            axpy_lanes(&mut u, &xrow, v, KernelPath::Unrolled);
            assert_eq!(s, u, "b={b}");
        }
    }

    #[test]
    fn kernel_path_parse_and_labels() {
        assert_eq!(KernelPath::parse("scalar").unwrap(),
                   KernelPath::Scalar);
        assert_eq!(KernelPath::parse("unrolled").unwrap(),
                   KernelPath::Unrolled);
        assert!(KernelPath::parse("simd").is_err());
        assert_eq!(KernelPath::Scalar.label(), "scalar");
        assert_eq!(KernelPath::default(), KernelPath::Unrolled);
    }

    #[test]
    fn matvec_batch_empty_matrix_ok() {
        let w = Matrix::zeros(24, 10);
        let b = 3;
        let x = vec![1.0f32; b * 24];
        let mut y = vec![5.0f32; b * 10];
        Csr::from_weight(&w).matvec_batch(&x, &mut y, b);
        assert!(y.iter().all(|&v| v == 0.0));
        let mut y2 = vec![5.0f32; b * 10];
        Macko::from_weight(&w).matvec_batch(&x, &mut y2, b);
        assert!(y2.iter().all(|&v| v == 0.0));
    }
}

//! Sparse matrix formats + SpMV — the deployment substrate for Table 1.
//!
//! Two formats, mirroring the paper's §5.3 benchmark:
//!  - `Csr`: textbook compressed sparse row (here: compressed sparse
//!    *column* groups fit our (din, dout) x@W orientation — we store the
//!    transpose W^T row-wise so SpMV streams output rows),
//!  - `Macko`: a MACKO-like bitmap format (Macko & Boža 2025): per
//!    output row, a din-bit occupancy bitmap plus densely packed values.
//!    At moderate sparsity this beats CSR's 4-byte-per-nnz index
//!    overhead — exactly MACKO's claim — and decodes with popcount-free
//!    sequential scans.
//!
//! Memory accounting is real (`mem_bytes` sums the actual buffers), so
//! the Table-1 memory column reflects genuine storage.

use crate::tensor::Matrix;

/// CSR over W^T: row r holds the non-zeros of output neuron r.
#[derive(Debug, Clone)]
pub struct Csr {
    pub n_out: usize,
    pub n_in: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from a (din, dout) weight matrix (x @ W orientation).
    pub fn from_weight(w: &Matrix) -> Csr {
        let (din, dout) = (w.rows, w.cols);
        let mut row_ptr = Vec::with_capacity(dout + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0);
        for c in 0..dout {
            for r in 0..din {
                let v = w.at(r, c);
                if v != 0.0 {
                    col_idx.push(r as u32);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Csr { n_out: dout, n_in: din, row_ptr, col_idx, values }
    }

    /// y = W^T x  i.e. y[c] = sum_r W[r, c] * x[r].
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n_in);
        debug_assert_eq!(y.len(), self.n_out);
        for o in 0..self.n_out {
            let lo = self.row_ptr[o] as usize;
            let hi = self.row_ptr[o + 1] as usize;
            let mut acc = 0.0f32;
            for k in lo..hi {
                acc += self.values[k]
                    * unsafe { *x.get_unchecked(self.col_idx[k] as usize) };
            }
            y[o] = acc;
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn mem_bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_idx.len() * 4
            + self.values.len() * 4
    }
}

/// MACKO-like bitmap format: per output row, a din-bit bitmap + packed
/// non-zero values in input order.
#[derive(Debug, Clone)]
pub struct Macko {
    pub n_out: usize,
    pub n_in: usize,
    words_per_row: usize,
    pub bitmap: Vec<u64>,
    pub row_ptr: Vec<u32>,
    pub values: Vec<f32>,
}

impl Macko {
    pub fn from_weight(w: &Matrix) -> Macko {
        let (din, dout) = (w.rows, w.cols);
        let wpr = din.div_ceil(64);
        let mut bitmap = vec![0u64; dout * wpr];
        let mut row_ptr = Vec::with_capacity(dout + 1);
        let mut values = Vec::new();
        row_ptr.push(0);
        for c in 0..dout {
            for r in 0..din {
                let v = w.at(r, c);
                if v != 0.0 {
                    bitmap[c * wpr + r / 64] |= 1u64 << (r % 64);
                    values.push(v);
                }
            }
            row_ptr.push(values.len() as u32);
        }
        Macko { n_out: dout, n_in: din, words_per_row: wpr, bitmap,
                row_ptr, values }
    }

    /// y = W^T x via bitmap scan: iterate set bits word by word.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n_in);
        debug_assert_eq!(y.len(), self.n_out);
        for o in 0..self.n_out {
            let mut k = self.row_ptr[o] as usize;
            let mut acc = 0.0f32;
            let base = o * self.words_per_row;
            for wi in 0..self.words_per_row {
                let mut word = self.bitmap[base + wi];
                let col0 = wi * 64;
                while word != 0 {
                    let bit = word.trailing_zeros() as usize;
                    acc += unsafe {
                        *self.values.get_unchecked(k)
                            * *x.get_unchecked(col0 + bit)
                    };
                    k += 1;
                    word &= word - 1;
                }
            }
            y[o] = acc;
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn mem_bytes(&self) -> usize {
        self.bitmap.len() * 8 + self.row_ptr.len() * 4
            + self.values.len() * 4
    }
}

/// Dense GEMV baseline on W (din, dout): y = W^T x.
pub fn dense_matvec(w: &Matrix, x: &[f32], y: &mut [f32]) {
    let t = w.t_matvec(x);
    y.copy_from_slice(&t);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sparse_weight(din: usize, dout: usize, sparsity: f64, seed: u64)
                     -> Matrix {
        let mut rng = Rng::new(seed);
        let mut w = Matrix::randn(din, dout, 1.0, &mut rng);
        for x in w.data.iter_mut() {
            if (rng.f64()) < sparsity {
                *x = 0.0;
            }
        }
        w
    }

    #[test]
    fn csr_matches_dense() {
        let w = sparse_weight(64, 48, 0.8, 0);
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let mut yd = vec![0.0; 48];
        let mut yc = vec![0.0; 48];
        dense_matvec(&w, &x, &mut yd);
        Csr::from_weight(&w).matvec(&x, &mut yc);
        for (a, b) in yd.iter().zip(yc.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn macko_matches_dense() {
        for din in [64usize, 100, 130] {
            let w = sparse_weight(din, 32, 0.7, din as u64);
            let mut rng = Rng::new(2);
            let x: Vec<f32> = (0..din).map(|_| rng.normal()).collect();
            let mut yd = vec![0.0; 32];
            let mut ym = vec![0.0; 32];
            dense_matvec(&w, &x, &mut yd);
            Macko::from_weight(&w).matvec(&x, &mut ym);
            for (a, b) in yd.iter().zip(ym.iter()) {
                assert!((a - b).abs() < 1e-4, "din={din}");
            }
        }
    }

    #[test]
    fn macko_smaller_than_csr_at_moderate_sparsity() {
        // MACKO's raison d'etre: at 50-90% sparsity the 1-bit bitmap
        // beats CSR's 32-bit indices
        let w = sparse_weight(256, 256, 0.7, 3);
        let csr = Csr::from_weight(&w).mem_bytes();
        let mck = Macko::from_weight(&w).mem_bytes();
        assert!(mck < csr, "macko {mck} >= csr {csr}");
    }

    #[test]
    fn csr_wins_at_extreme_sparsity() {
        let w = sparse_weight(256, 256, 0.995, 4);
        let csr = Csr::from_weight(&w).mem_bytes();
        let mck = Macko::from_weight(&w).mem_bytes();
        assert!(csr < mck, "csr {csr} >= macko {mck}");
    }

    #[test]
    fn empty_matrix_ok() {
        let w = Matrix::zeros(32, 16);
        let x = vec![1.0f32; 32];
        let mut y = vec![7.0f32; 16];
        Csr::from_weight(&w).matvec(&x, &mut y);
        assert!(y.iter().all(|&v| v == 0.0));
        let mut y2 = vec![7.0f32; 16];
        Macko::from_weight(&w).matvec(&x, &mut y2);
        assert!(y2.iter().all(|&v| v == 0.0));
    }
}

//! Experiment harness: one module per paper table/figure (DESIGN.md §5).
//!
//! `elsa exp --id <fig2|fig3|fig4|tab1|tab2|tab3|fig5|tab7|tab8|tab9|
//! fig6|all>` regenerates the corresponding artifact into `results/`.
//! `--scale quick|full` trades sweep breadth for wall-clock (quick =
//! tiny-model sweeps sized for a single CPU core; full adds the `small`
//! model and longer ELSA budgets).

pub mod fig2_ppl_sweep;
pub mod fig3_pareto;
pub mod fig4_zeroshot;
pub mod fig5_elsal;
pub mod fig6_objective;
pub mod tab1_inference;
pub mod tab2_extreme;
pub mod tab3_cost;
pub mod tab7_nonuniform;
pub mod tab8_nm;
pub mod tab9_projection;

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::coordinator::elsa::{prune_elsa, ElsaOptions};
use crate::coordinator::pretrain::{pretrain_cached, PretrainOptions};
use crate::data::Dataset;
use crate::model::checkpoint::Checkpoint;
use crate::runtime::{ConfigEntry, Runtime};

/// Sweep scale knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

pub struct Ctx {
    pub rt: Runtime,
    pub results: PathBuf,
    pub ckpts: PathBuf,
    pub scale: Scale,
}

impl Ctx {
    pub fn from_args(args: &Args) -> Result<Ctx> {
        Ok(Ctx {
            rt: crate::commands::open_runtime(args)?,
            results: PathBuf::from(args.str_or("results", "results")),
            ckpts: PathBuf::from(args.str_or("ckpt-dir", "checkpoints")),
            scale: match args.str_or("scale", "quick").as_str() {
                "full" => Scale::Full,
                _ => Scale::Quick,
            },
        })
    }

    /// Models swept by the cross-scale experiments.
    pub fn sweep_models(&self) -> Vec<&'static str> {
        match self.scale {
            Scale::Quick => vec!["tiny"],
            Scale::Full => vec!["tiny", "small"],
        }
    }

    /// Pretraining budget per config (steps).
    pub fn pretrain_steps(&self, cfg: &str) -> usize {
        match (cfg, self.scale) {
            ("tiny", _) => 800,
            ("small", Scale::Quick) => 400,
            ("small", Scale::Full) => 700,
            ("med", _) => 350,
            _ => 400,
        }
    }

    /// ELSA pruning budget per config (x-update steps).
    pub fn elsa_steps(&self, cfg: &str) -> usize {
        match (cfg, self.scale) {
            ("tiny", Scale::Quick) => 600,
            ("tiny", Scale::Full) => 1000,
            ("small", _) => 300,
            ("med", _) => 200,
            _ => 300,
        }
    }

    /// Dense model + the two evaluation corpora for a config.
    pub fn dense_setup(&self, cfg_name: &str)
                       -> Result<(ConfigEntry, Vec<f32>, Dataset, Dataset)> {
        let cfg = self.rt.manifest.config(cfg_name)?.clone();
        let c4 = Dataset::standard("synth-c4", cfg.vocab);
        let wiki = Dataset::standard("synth-wiki", cfg.vocab);
        let opts = PretrainOptions::new(self.pretrain_steps(cfg_name));
        let dense = pretrain_cached(&self.rt, &cfg, &c4.train, &opts,
                                    &self.ckpts)?;
        Ok((cfg, dense, c4, wiki))
    }

    /// Prune-with-cache: experiments share pruned checkpoints. `tag`
    /// disambiguates variants (pattern, precision, ...).
    pub fn pruned_cached(&self, cfg: &ConfigEntry, method: &str,
                         sparsity: f64, tag: &str,
                         build: impl FnOnce() -> Result<Vec<f32>>)
                         -> Result<Vec<f32>> {
        let path = self.ckpts.join(format!(
            "pruned_{}_{}_{:.0}{}{}.bin", cfg.name, method,
            sparsity * 1000.0, if tag.is_empty() { "" } else { "_" }, tag));
        if path.exists() {
            let ck = Checkpoint::load(&path)?;
            return Ok(ck.get("params")?.clone());
        }
        let p = build()?;
        let mut ck = Checkpoint::new(&cfg.name);
        ck.insert("params", p.clone());
        ck.save(&path)?;
        Ok(p)
    }

    /// Standard ELSA run for the sweeps (per-config budget, paper-style
    /// hyperparameters — Table 5 analogue).
    pub fn run_elsa(&self, cfg: &ConfigEntry, dense: &[f32], train: &[u32],
                    sparsity: f64, mutate: impl FnOnce(&mut ElsaOptions))
                    -> Result<Vec<f32>> {
        let mut opts = ElsaOptions::new(sparsity, self.elsa_steps(&cfg.name));
        opts.lr = 1e-3;
        // Table-5 analogue, tuned on this testbed: constant small penalty
        // at moderate sparsity, strong cosine-ramped penalty + denser z/u
        // updates in the high-sparsity regime.
        if sparsity <= 0.6 {
            opts.lam = 5e-3;
        } else {
            opts.lam = 0.5;
            opts.interval_k = 16;
        }
        mutate(&mut opts);
        let (p, m) = prune_elsa(&self.rt, cfg, train, dense, &opts)?;
        crate::info!("elsa", "{} @ {:.2}: achieved {:.4}, {:.1}s",
                     cfg.name, sparsity, m.achieved_sparsity,
                     m.wall_seconds);
        Ok(p)
    }
}

/// Append a line to the run log in results/ (indexed by EXPERIMENTS.md).
pub fn log_run(ctx: &Ctx, line: &str) -> Result<()> {
    std::fs::create_dir_all(&ctx.results)?;
    let path = ctx.results.join("RUNLOG.md");
    let mut text = if path.exists() {
        std::fs::read_to_string(&path)?
    } else {
        "# Experiment run log\n\n".to_string()
    };
    text.push_str(line);
    text.push('\n');
    std::fs::write(&path, text)?;
    Ok(())
}

pub fn cmd_exp(args: &Args) -> Result<()> {
    let ctx = Ctx::from_args(args)?;
    let id = args.str_or("id", "all");
    let run = |id: &str, ctx: &Ctx| -> Result<()> {
        crate::info!("exp", "=== running {id} ===");
        let t = crate::util::timer::Timer::start();
        match id {
            "fig2" => fig2_ppl_sweep::run(ctx, args)?,
            "fig3" => fig3_pareto::run(ctx, args)?,
            "fig4" => fig4_zeroshot::run(ctx, args)?,
            "tab1" => tab1_inference::run(ctx, args)?,
            "tab2" => tab2_extreme::run(ctx, args)?,
            "tab3" => tab3_cost::run(ctx, args)?,
            "fig5" => fig5_elsal::run(ctx, args)?,
            "tab7" => tab7_nonuniform::run(ctx, args)?,
            "tab8" => tab8_nm::run(ctx, args)?,
            "tab9" => tab9_projection::run(ctx, args)?,
            "fig6" => fig6_objective::run(ctx, args)?,
            other => bail!("unknown experiment id '{other}'"),
        }
        log_run(ctx, &format!("- `{id}` finished in {:.1}s", t.seconds()))?;
        Ok(())
    };
    if id == "all" {
        for id in ["fig2", "fig3", "fig4", "tab1", "tab2", "tab3", "fig5",
                   "tab7", "tab8", "tab9", "fig6"] {
            run(id, &ctx)?;
        }
    } else {
        run(&id, &ctx)?;
    }
    Ok(())
}

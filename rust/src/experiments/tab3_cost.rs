//! Table 3: pruning compute vs quality at 90% sparsity — wall-clock
//! seconds (the GPU-hours analogue on this single-core testbed) against
//! achieved perplexity, for every method.

use anyhow::Result;

use super::Ctx;
use crate::cli::Args;
use crate::coordinator::eval_ppl;
use crate::report::{f2, Table};
use crate::util::timer::Timer;

const METHODS: [&str; 6] =
    ["wanda", "sparsegpt", "alps", "wanda-lora", "wanda-full", "elsa"];

pub fn run(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.sweep_models()[0];
    let (cfg, dense, c4, wiki) = ctx.dense_setup(model)?;
    let sp = 0.9;

    let mut table = Table::new(
        &format!("Table 3 — pruning cost vs quality at 90% ({model})"),
        &["method", "wall_clock_s", "ppl_wiki", "ppl_c4"]);

    for method in METHODS {
        let t = Timer::start();
        let pruned = if method == "elsa" {
            ctx.run_elsa(&cfg, &dense, &c4.train, sp, |_| {})?
        } else {
            crate::pruners::prune_oneshot(&ctx.rt, &cfg, method, &dense,
                                          &c4.train, sp, args)?
        };
        let wall = t.seconds();
        let pw = eval_ppl(&ctx.rt, &cfg, &pruned, &wiki.valid)?;
        let pc = eval_ppl(&ctx.rt, &cfg, &pruned, &c4.valid)?;
        crate::info!("tab3", "{method}: {wall:.1}s wiki={pw:.2} c4={pc:.2}");
        table.row(vec![method.into(), f2(wall), f2(pw), f2(pc)]);
    }
    let path = table.save(&ctx.results, "tab3")?;
    crate::info!("tab3", "wrote {}", path.display());
    Ok(())
}

//! Table 9 (ablation): objective-aware (Fisher-weighted) projection vs
//! plain Euclidean projection at 70/80/90% — the benefit grows with
//! sparsity.

use anyhow::Result;

use super::Ctx;
use crate::cli::Args;
use crate::coordinator::eval_ppl;
use crate::report::{f2, Table};

const SPARSITIES: [f64; 3] = [0.7, 0.8, 0.9];

pub fn run(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.sweep_models()[0];
    let (cfg, dense, c4, _) = ctx.dense_setup(model)?;

    let mut table = Table::new(
        &format!("Table 9 — objective-aware projection ablation ({model}, \
                  ppl on synth-c4)"),
        &["sparsity", "euclidean", "objective_aware"]);

    for &sp in &SPARSITIES {
        let plain = ctx.pruned_cached(&cfg, "elsa-noproj", sp, "", || {
            ctx.run_elsa(&cfg, &dense, &c4.train, sp,
                         |o| o.objective_aware = false)
        })?;
        let aware = ctx.pruned_cached(&cfg, "elsa", sp, "", || {
            ctx.run_elsa(&cfg, &dense, &c4.train, sp, |_| {})
        })?;
        let pe = eval_ppl(&ctx.rt, &cfg, &plain, &c4.valid)?;
        let pa = eval_ppl(&ctx.rt, &cfg, &aware, &c4.valid)?;
        crate::info!("tab9", "{sp}: euclid={pe:.2} fisher={pa:.2}");
        table.row(vec![format!("{sp:.1}"), f2(pe), f2(pa)]);
    }
    let _ = args;
    let path = table.save(&ctx.results, "tab9")?;
    crate::info!("tab9", "wrote {}", path.display());
    Ok(())
}

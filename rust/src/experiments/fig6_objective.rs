//! Fig 6 (ablation): NTP vs REM objective data efficiency — the
//! surrogate-free next-token objective keeps improving with more data,
//! while layer-wise reconstruction saturates (the paper's §C.2).
//!
//! Emulation of the paper's protocol (fixed optimization steps, varying
//! data budget): ELSA sees `budget` distinct training tokens (the batcher
//! cycles a truncated corpus); REM = SparseGPT with a calibration set of
//! the same token budget.

use anyhow::Result;

use super::Ctx;
use crate::cli::Args;
use crate::coordinator::eval_ppl;
use crate::pruners;
use crate::report::{f2, Table};

pub fn run(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.sweep_models()[0];
    let (cfg, dense, c4, _) = ctx.dense_setup(model)?;
    let sp = 0.9;

    let budgets: &[usize] = match ctx.scale {
        super::Scale::Quick => &[4_096, 16_384, 65_536, 262_144],
        super::Scale::Full => &[4_096, 16_384, 65_536, 262_144, 524_288],
    };

    let mut table = Table::new(
        &format!("Fig 6 — data efficiency of NTP (ELSA) vs REM \
                  (SparseGPT) at 90% ({model}, ppl on synth-c4)"),
        &["data_tokens", "ntp_elsa", "rem_sparsegpt"]);

    for &budget in budgets {
        let train = &c4.train[..budget.min(c4.train.len())];

        let elsa = ctx.pruned_cached(
            &cfg, "elsa", sp, &format!("d{budget}"), || {
                ctx.run_elsa(&cfg, &dense, train, sp, |_| {})
            })?;
        let ntp = eval_ppl(&ctx.rt, &cfg, &elsa, &c4.valid)?;

        // REM: calibration sequences drawn from the same token budget
        let n_seqs =
            (budget / cfg.seq_len).clamp(2, pruners::CALIB_SEQS * 4);
        let sg = ctx.pruned_cached(
            &cfg, "sparsegpt", sp, &format!("d{budget}"), || {
                let params =
                    crate::model::Params::new(&cfg, dense.clone());
                let seqs = crate::data::calibration(train, n_seqs,
                                                    cfg.seq_len, 7);
                let calib = crate::model::forward::collect_calibration(
                    &params, &seqs)?;
                pruners::sparsegpt::prune(
                    &cfg, &dense, &calib, &pruners::uniform_alloc(&cfg, sp))
            })?;
        let rem = eval_ppl(&ctx.rt, &cfg, &sg, &c4.valid)?;

        crate::info!("fig6", "{budget} tokens: ntp={ntp:.2} rem={rem:.2}");
        table.row(vec![budget.to_string(), f2(ntp), f2(rem)]);
    }
    let _ = args;
    let path = table.save(&ctx.results, "fig6")?;
    crate::info!("fig6", "wrote {}", path.display());
    Ok(())
}

//! Table 8: N:M semi-structured sparsity (2:4 and 4:8) — ELSA adapts to
//! hardware-friendly patterns by swapping the projection set.

use anyhow::Result;

use super::Ctx;
use crate::cli::Args;
use crate::coordinator::eval_ppl;
use crate::coordinator::patterns::{project_mask, Pattern};
use crate::model::Params;
use crate::pruners;
use crate::report::{f2, f4, Table};

pub fn run(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.sweep_models()[0];
    let (cfg, dense, c4, wiki) = ctx.dense_setup(model)?;

    let mut table = Table::new(
        &format!("Table 8 — N:M semi-structured sparsity ({model})"),
        &["pattern", "method", "ppl_wiki", "ppl_c4", "achieved"]);

    for (n, m) in [(2usize, 4usize), (4, 8)] {
        let pat = Pattern::NM { n, m };
        let tag = format!("{n}x{m}");
        // magnitude / wanda under the N:M mask (their standard variants)
        for method in ["magnitude", "wanda"] {
            let pruned = ctx.pruned_cached(&cfg, method, 0.5, &tag, || {
                let scores: Vec<f32> = match method {
                    "magnitude" => dense.iter().map(|x| x.abs()).collect(),
                    _ => {
                        let calib = pruners::calibrate(&cfg, &dense,
                                                       &c4.train, 7)?;
                        let mut s = vec![0.0f32; cfg.flat_len];
                        for seg in cfg.segments.iter()
                            .filter(|s| s.prunable) {
                            let xn = calib[&seg.name].col_norms();
                            let cols = seg.shape[1];
                            for i in 0..seg.len() {
                                let r = i / cols;
                                s[seg.offset + i] =
                                    dense[seg.offset + i].abs() * xn[r];
                            }
                        }
                        s
                    }
                };
                let mask = project_mask(&cfg, &scores, &pat, 0.5);
                let mut p = dense.clone();
                for (x, mk) in p.iter_mut().zip(mask.iter()) {
                    *x *= mk;
                }
                Ok(p)
            })?;
            let p = Params::new(&cfg, pruned.clone());
            let pw = eval_ppl(&ctx.rt, &cfg, &pruned, &wiki.valid)?;
            let pc = eval_ppl(&ctx.rt, &cfg, &pruned, &c4.valid)?;
            table.row(vec![format!("{n}:{m}"), method.into(), f2(pw),
                           f2(pc), f4(p.sparsity())]);
        }
        // ELSA with the N:M projection
        let pruned = ctx.pruned_cached(&cfg, "elsa", 0.5, &tag, || {
            ctx.run_elsa(&cfg, &dense, &c4.train, 0.5, |o| {
                o.pattern = Pattern::NM { n, m };
                o.lam = 5e-3; // 50% effective sparsity -> moderate penalty
            })
        })?;
        let p = Params::new(&cfg, pruned.clone());
        let pw = eval_ppl(&ctx.rt, &cfg, &pruned, &wiki.valid)?;
        let pc = eval_ppl(&ctx.rt, &cfg, &pruned, &c4.valid)?;
        crate::info!("tab8", "elsa {n}:{m}: wiki={pw:.2} c4={pc:.2}");
        table.row(vec![format!("{n}:{m}"), "elsa".into(), f2(pw), f2(pc),
                       f4(p.sparsity())]);
    }
    let _ = args;
    let path = table.save(&ctx.results, "tab8")?;
    crate::info!("tab8", "wrote {}", path.display());
    Ok(())
}

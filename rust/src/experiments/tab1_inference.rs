//! Table 1: deployment gains — end-to-end generation latency, throughput
//! and weight memory vs sparsity, on the rust sparse engine (the MACKO
//! substitute, DESIGN.md §3).

use anyhow::Result;

use super::Ctx;
use crate::cli::Args;
use crate::infer::scheduler::{ragged_budgets, serve_static_chunks,
                              Request, RequestQueue, SchedOptions,
                              Scheduler};
use crate::infer::{Backend, BatchOptions, Engine};
use crate::model::Params;
use crate::report::{f2, Table};
use crate::util::human_bytes;

const SPARSITIES: [f64; 4] = [0.5, 0.7, 0.9, 0.95];

/// Sparsity used for the batched serving sweep (the paper's headline
/// extreme-sparsity regime that is also in SPARSITIES, so the pruned
/// checkpoint is shared with the single-sequence table).
const BATCH_SWEEP_SPARSITY: f64 = 0.9;

pub fn run(ctx: &Ctx, args: &Args) -> Result<()> {
    // The decode-phase SpMV story needs matrices big enough that weight
    // streaming dominates (tiny's d=64 layers are overhead-bound), so
    // this table always uses the `small` config (d=128).
    let model = match ctx.scale {
        super::Scale::Quick => "small",
        super::Scale::Full => "med",
    };
    let (cfg, dense, c4, _) = ctx.dense_setup(model)?;

    let mut table = Table::new(
        &format!("Table 1 — latency / throughput / memory ({model}, \
                  MACKO backend)"),
        &["sparsity", "latency_ms_per_tok", "speedup", "tokens_per_s",
          "prefill_tok_s", "throughput_x", "memory", "compression_x"]);

    let n_new = cfg.seq_len - 8;
    let reps = match ctx.scale {
        super::Scale::Quick => 6,
        super::Scale::Full => 10,
    };
    let prompt: Vec<u32> = c4.valid[..8].to_vec();

    let bench = |params: &Params, backend: Backend|
                 -> Result<(f64, f64, f64, usize)> {
        let engine = Engine::build(params, backend)?;
        // warmup
        engine.generate(&prompt, n_new, 0.8, 0);
        let mut lat = crate::util::stats::Summary::new();
        let mut tps = crate::util::stats::Summary::new();
        let mut pre = crate::util::stats::Summary::new();
        for r in 0..reps {
            let (_, stats) = engine.generate(&prompt, n_new, 0.8, r as u64);
            lat.push(stats.decode_seconds * 1e3
                     / stats.tokens_generated as f64);
            tps.push(stats.tokens_per_second);
            // whole-prompt rate: chunked headless passes + the one
            // head-projecting step, all timed as prefill
            pre.push(prompt.len() as f64
                     / stats.prefill_seconds.max(1e-9));
        }
        Ok((lat.median(), tps.median(), pre.median(),
            engine.mem_bytes()))
    };

    // dense reference uses the dense backend (what you'd actually deploy)
    let dense_params = Params::new(&cfg, dense.clone());
    let (lat0, tps0, pre0, mem0) = bench(&dense_params, Backend::Dense)?;
    table.row(vec!["dense".into(), f2(lat0), "x1.00".into(), f2(tps0),
                   f2(pre0), "x1.00".into(), human_bytes(mem0),
                   "x1.00".into()]);

    for &sp in &SPARSITIES {
        let pruned = ctx.pruned_cached(&cfg, "elsa", sp, "", || {
            ctx.run_elsa(&cfg, &dense, &c4.train, sp, |_| {})
        })?;
        let p = Params::new(&cfg, pruned);
        let (lat, tps, pre, mem) = bench(&p, Backend::Macko)?;
        crate::info!("tab1", "{sp:.2}: {lat:.2} ms/tok ({:.2}x), \
                      {tps:.1} tok/s, {}", lat0 / lat, human_bytes(mem));
        table.row(vec![
            format!("{sp:.2}"), f2(lat),
            format!("x{:.2}", lat0 / lat), f2(tps), f2(pre),
            format!("x{:.2}", tps / tps0), human_bytes(mem),
            format!("x{:.2}", mem0 as f64 / mem as f64),
        ]);
    }
    let path = table.save(&ctx.results, "tab1")?;
    crate::info!("tab1", "wrote {}", path.display());

    // ----------------------------------------------------------------
    // Batched serving sweep: aggregate decode throughput per batch size
    // on the 90%-sparse checkpoint, all three backends. `--threads N`
    // shards slots across workers; `--batch-sizes 1,2,4,8` overrides
    // the sweep.
    // ----------------------------------------------------------------
    let threads = args.usize_or("threads", 1)?;
    let batch_sizes = args.usize_list_or("batch-sizes", &[1, 2, 4, 8])?;
    let pruned = ctx.pruned_cached(&cfg, "elsa", BATCH_SWEEP_SPARSITY,
                                   "", || {
        ctx.run_elsa(&cfg, &dense, &c4.train, BATCH_SWEEP_SPARSITY,
                     |_| {})
    })?;
    let p = Params::new(&cfg, pruned);

    let mut bt = Table::new(
        &format!("Table 1b — batched decode throughput ({model}, \
                  sparsity {BATCH_SWEEP_SPARSITY}, {threads} threads)"),
        &["batch", "dense_tok_s", "csr_tok_s", "macko_tok_s",
          "macko_untiled_tok_s", "macko_scaling_x"]);

    let mut macko_base = 0.0f64;
    // wrap prompt windows so any --batch-sizes value stays in bounds
    let n_windows = c4.valid.len() / 8;
    for &bsz in &batch_sizes {
        let prompts: Vec<Vec<u32>> = (0..bsz)
            .map(|r| {
                let s = (r % n_windows) * 8;
                c4.valid[s..s + 8].to_vec()
            })
            .collect();
        let opts = BatchOptions {
            n_new, temperature: 0.8, seed: 0, threads,
            ..BatchOptions::default()
        };
        let mut row = vec![bsz.to_string()];
        let mut macko_tps = 0.0f64;
        let mut macko_untiled_tps = 0.0f64;
        for backend in [Backend::Dense, Backend::Csr, Backend::Macko] {
            let mut engine = Engine::build(&p, backend)?;
            engine.generate_batch(&prompts, &opts); // warmup
            let mut best = 0.0f64;
            for _ in 0..reps.min(3) {
                let (_, stats) = engine.generate_batch(&prompts, &opts);
                best = best.max(stats.tokens_per_second);
            }
            if backend == Backend::Macko {
                macko_tps = best;
                // per-kernel comparison: same engine with the untiled
                // SpMM traversal (token streams are bit-identical, so
                // only the walk differs)
                engine.tiled = false;
                engine.generate_batch(&prompts, &opts); // warmup
                for _ in 0..reps.min(3) {
                    let (_, stats) =
                        engine.generate_batch(&prompts, &opts);
                    macko_untiled_tps =
                        macko_untiled_tps.max(stats.tokens_per_second);
                }
            }
            row.push(f2(best));
        }
        if macko_base == 0.0 {
            macko_base = macko_tps;
        }
        row.push(f2(macko_untiled_tps));
        row.push(format!("x{:.2}", macko_tps / macko_base.max(1e-9)));
        crate::info!("tab1", "batch {bsz}: macko {macko_tps:.1} tok/s \
                      aggregate ({threads} threads)");
        bt.row(row);
    }
    let path = bt.save(&ctx.results, "tab1_batch")?;
    crate::info!("tab1", "wrote {}", path.display());

    // ----------------------------------------------------------------
    // Table 1c — continuous-batching scheduler vs static batching on
    // the same 90%-sparse checkpoint: a seeded request stream with
    // ragged token budgets and Poisson-ish arrivals, drained through
    // `Scheduler` (mid-decode admission, pooled KV buffers) and through
    // the static chunked policy. Columns report aggregate throughput
    // and per-request service-latency percentiles.
    // ----------------------------------------------------------------
    let n_req = match ctx.scale {
        super::Scale::Quick => 10,
        super::Scale::Full => 24,
    };
    let max_slots = args.usize_or("max-slots", 4)?;
    let budgets = ragged_budgets(n_new, n_req, 17);
    let reqs: Vec<Request> = (0..n_req)
        .map(|r| {
            let s = (r % n_windows) * 8;
            Request {
                id: r as u64,
                prompt: c4.valid[s..s + 8].to_vec(),
                n_new: budgets[r],
                seed: r as u64,
                deadline: None,
            }
        })
        .collect();

    let mut st = Table::new(
        &format!("Table 1c — continuous-batching scheduler ({model}, \
                  sparsity {BATCH_SWEEP_SPARSITY}, {n_req} requests, \
                  {max_slots} slots, {threads} threads)"),
        &["backend", "sched_tok_s", "p50_ms", "p95_ms", "wait_steps",
          "kv_reused", "static_tok_s", "speedup_x"]);
    let sopts = SchedOptions {
        max_slots,
        temperature: 0.8,
        threads,
        ..SchedOptions::default()
    };
    for backend in [Backend::Dense, Backend::Csr, Backend::Macko] {
        let engine = Engine::build(&p, backend)?;
        // warm caches with the static policy, then measure both
        serve_static_chunks(&engine, &reqs, &sopts);
        let (_, stat) = serve_static_chunks(&engine, &reqs, &sopts);
        let queue =
            RequestQueue::with_poisson_arrivals(reqs.clone(), 2.0, 7);
        let sched = Scheduler::new(&engine, sopts.clone());
        let (_, sc) = sched.run(queue);
        crate::info!("tab1", "{backend:?}: scheduler {:.1} tok/s vs \
                      static {:.1} tok/s (x{:.2})",
                     sc.tokens_per_second, stat.tokens_per_second,
                     sc.tokens_per_second
                         / stat.tokens_per_second.max(1e-9));
        st.row(vec![
            format!("{backend:?}"),
            f2(sc.tokens_per_second),
            f2(sc.p50_latency_ms),
            f2(sc.p95_latency_ms),
            f2(sc.mean_wait_steps),
            sc.kv_reused.to_string(),
            f2(stat.tokens_per_second),
            format!("x{:.2}", sc.tokens_per_second
                    / stat.tokens_per_second.max(1e-9)),
        ]);
    }
    let path = st.save(&ctx.results, "tab1_sched")?;
    crate::info!("tab1", "wrote {}", path.display());
    Ok(())
}

//! Table 1: deployment gains — end-to-end generation latency, throughput
//! and weight memory vs sparsity, on the rust sparse engine (the MACKO
//! substitute, DESIGN.md §3).

use anyhow::Result;

use super::Ctx;
use crate::cli::Args;
use crate::infer::{Backend, Engine};
use crate::model::Params;
use crate::report::{f2, Table};
use crate::util::human_bytes;

const SPARSITIES: [f64; 4] = [0.5, 0.7, 0.9, 0.95];

pub fn run(ctx: &Ctx, _args: &Args) -> Result<()> {
    // The decode-phase SpMV story needs matrices big enough that weight
    // streaming dominates (tiny's d=64 layers are overhead-bound), so
    // this table always uses the `small` config (d=128).
    let model = match ctx.scale {
        super::Scale::Quick => "small",
        super::Scale::Full => "med",
    };
    let (cfg, dense, c4, _) = ctx.dense_setup(model)?;

    let mut table = Table::new(
        &format!("Table 1 — latency / throughput / memory ({model}, \
                  MACKO backend)"),
        &["sparsity", "latency_ms_per_tok", "speedup", "tokens_per_s",
          "throughput_x", "memory", "compression_x"]);

    let n_new = cfg.seq_len - 8;
    let reps = match ctx.scale {
        super::Scale::Quick => 6,
        super::Scale::Full => 10,
    };
    let prompt: Vec<u32> = c4.valid[..8].to_vec();

    let bench = |params: &Params, backend: Backend| -> Result<(f64, f64,
                                                               usize)> {
        let engine = Engine::build(params, backend)?;
        // warmup
        engine.generate(&prompt, n_new, 0.8, 0);
        let mut lat = crate::util::stats::Summary::new();
        let mut tps = crate::util::stats::Summary::new();
        for r in 0..reps {
            let (_, stats) = engine.generate(&prompt, n_new, 0.8, r as u64);
            lat.push(stats.decode_seconds * 1e3
                     / stats.tokens_generated as f64);
            tps.push(stats.tokens_per_second);
        }
        Ok((lat.median(), tps.median(), engine.mem_bytes()))
    };

    // dense reference uses the dense backend (what you'd actually deploy)
    let dense_params = Params::new(&cfg, dense.clone());
    let (lat0, tps0, mem0) = bench(&dense_params, Backend::Dense)?;
    table.row(vec!["dense".into(), f2(lat0), "x1.00".into(), f2(tps0),
                   "x1.00".into(), human_bytes(mem0), "x1.00".into()]);

    for &sp in &SPARSITIES {
        let pruned = ctx.pruned_cached(&cfg, "elsa", sp, "", || {
            ctx.run_elsa(&cfg, &dense, &c4.train, sp, |_| {})
        })?;
        let p = Params::new(&cfg, pruned);
        let (lat, tps, mem) = bench(&p, Backend::Macko)?;
        crate::info!("tab1", "{sp:.2}: {lat:.2} ms/tok ({:.2}x), \
                      {tps:.1} tok/s, {}", lat0 / lat, human_bytes(mem));
        table.row(vec![
            format!("{sp:.2}"), f2(lat),
            format!("x{:.2}", lat0 / lat), f2(tps),
            format!("x{:.2}", tps / tps0), human_bytes(mem),
            format!("x{:.2}", mem0 as f64 / mem as f64),
        ]);
    }
    let path = table.save(&ctx.results, "tab1")?;
    crate::info!("tab1", "wrote {}", path.display());
    Ok(())
}

//! Fig 3: Pareto frontier — perplexity vs number of non-zero parameters.
//! Derived from the fig2 sweep data (runs it first if missing).

use anyhow::{Context, Result};

use super::Ctx;
use crate::cli::Args;
use crate::report::Table;

pub fn run(ctx: &Ctx, args: &Args) -> Result<()> {
    let fig2_csv = ctx.results.join("fig2.csv");
    if !fig2_csv.exists() {
        crate::info!("fig3", "fig2.csv missing; running fig2 first");
        super::fig2_ppl_sweep::run(ctx, args)?;
    }
    let text = std::fs::read_to_string(&fig2_csv)?;
    let mut lines = text.lines();
    let header: Vec<&str> =
        lines.next().context("empty fig2.csv")?.split(',').collect();
    let col = |name: &str| -> Result<usize> {
        header.iter().position(|c| *c == name)
            .with_context(|| format!("fig2.csv missing column {name}"))
    };
    let (c_model, c_method, c_ppl, c_nnz) =
        (col("model")?, col("method")?, col("ppl_c4")?, col("nnz_total")?);

    // points: (nnz, ppl, model, method)
    let mut pts: Vec<(f64, f64, String, String)> = vec![];
    for line in lines {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() <= c_nnz {
            continue;
        }
        pts.push((f[c_nnz].parse()?, f[c_ppl].parse()?,
                  f[c_model].to_string(), f[c_method].to_string()));
    }
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    // mark Pareto-optimal points (no other point has <= nnz and <= ppl)
    let mut table = Table::new(
        "Fig 3 — ppl vs non-zero params (Pareto frontier marked)",
        &["nnz", "ppl_c4", "model", "method", "pareto"]);
    let mut best_so_far = f64::INFINITY;
    for (nnz, ppl, model, method) in &pts {
        let pareto = *ppl < best_so_far;
        if pareto {
            best_so_far = *ppl;
        }
        table.row(vec![format!("{nnz:.0}"), format!("{ppl:.2}"),
                       model.clone(), method.clone(),
                       if pareto { "yes" } else { "no" }.into()]);
    }
    let path = table.save(&ctx.results, "fig3")?;
    crate::info!("fig3", "wrote {}", path.display());
    Ok(())
}

//! Table 2: extreme sparsity (90/95/99%) — ELSA vs Wanda + retraining
//! (LoRA / full fine-tune) at matched data budgets.

use anyhow::Result;

use super::Ctx;
use crate::cli::Args;
use crate::coordinator::eval_ppl;
use crate::report::{f2, Table};

const SPARSITIES: [f64; 3] = [0.90, 0.95, 0.99];
const METHODS: [&str; 3] = ["wanda-lora", "wanda-full", "elsa"];

pub fn run(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.sweep_models()[0];
    let (cfg, dense, c4, wiki) = ctx.dense_setup(model)?;

    let mut table = Table::new(
        &format!("Table 2 — extreme sparsity ({model})"),
        &["sparsity", "method", "ppl_wiki", "ppl_c4"]);

    for &sp in &SPARSITIES {
        for method in METHODS {
            let pruned = ctx.pruned_cached(&cfg, method, sp, "", || {
                if method == "elsa" {
                    ctx.run_elsa(&cfg, &dense, &c4.train, sp, |o| {
                        // extreme sparsity: double budget (paper §B.3)
                        if sp > 0.95 {
                            o.steps *= 2;
                        }
                    })
                } else {
                    // matched budget: retraining steps = ELSA steps
                    crate::pruners::prune_oneshot(
                        &ctx.rt, &cfg, method, &dense, &c4.train, sp, args)
                }
            })?;
            let pw = eval_ppl(&ctx.rt, &cfg, &pruned, &wiki.valid)?;
            let pc = eval_ppl(&ctx.rt, &cfg, &pruned, &c4.valid)?;
            crate::info!("tab2", "{method} @{sp}: wiki={pw:.2} c4={pc:.2}");
            table.row(vec![format!("{sp:.2}"), method.into(), f2(pw),
                           f2(pc)]);
        }
    }
    let path = table.save(&ctx.results, "tab2")?;
    crate::info!("tab2", "wrote {}", path.display());
    Ok(())
}

//! Fig 1 + Fig 2 + Table 10: perplexity vs sparsity across methods and
//! model scales, on both evaluation corpora. The headline experiment —
//! existing methods deteriorate past ~70% sparsity while ELSA stays
//! stable.

use anyhow::Result;

use super::Ctx;
use crate::cli::Args;
use crate::coordinator::eval_ppl;
use crate::model::Params;
use crate::pruners;
use crate::report::{f2, f4, Table};

pub const SPARSITIES: [f64; 5] = [0.5, 0.6, 0.7, 0.8, 0.9];
pub const METHODS: [&str; 6] =
    ["magnitude", "wanda", "sparsegpt", "l-admm", "alps", "elsa"];

pub fn run(ctx: &Ctx, args: &Args) -> Result<()> {
    let mut table = Table::new(
        "Fig 2 / Table 10 — perplexity vs sparsity (synth-c4 / synth-wiki)",
        &["model", "method", "sparsity", "ppl_c4", "ppl_wiki",
          "achieved", "nnz_total"]);

    for model in ctx.sweep_models() {
        let (cfg, dense, c4, wiki) = ctx.dense_setup(model)?;
        let dense_c4 = eval_ppl(&ctx.rt, &cfg, &dense, &c4.valid)?;
        let dense_wiki = eval_ppl(&ctx.rt, &cfg, &dense, &wiki.valid)?;
        let dense_nnz = Params::new(&cfg, dense.clone()).nnz_total();
        table.row(vec![model.into(), "dense".into(), "0.00".into(),
                       f2(dense_c4), f2(dense_wiki), "0.0000".into(),
                       dense_nnz.to_string()]);

        for &sp in &SPARSITIES {
            for method in METHODS {
                let pruned = ctx.pruned_cached(&cfg, method, sp, "", || {
                    if method == "elsa" {
                        ctx.run_elsa(&cfg, &dense, &c4.train, sp, |_| {})
                    } else {
                        pruners::prune_oneshot(&ctx.rt, &cfg, method,
                                               &dense, &c4.train, sp, args)
                    }
                })?;
                let p = Params::new(&cfg, pruned.clone());
                let ppl_c4 = eval_ppl(&ctx.rt, &cfg, &pruned, &c4.valid)?;
                let ppl_wiki =
                    eval_ppl(&ctx.rt, &cfg, &pruned, &wiki.valid)?;
                crate::info!("fig2", "{model} {method} @{sp:.1}: \
                              c4={ppl_c4:.2} wiki={ppl_wiki:.2}");
                table.row(vec![
                    model.into(), method.into(), format!("{sp:.2}"),
                    f2(ppl_c4), f2(ppl_wiki), f4(p.sparsity()),
                    p.nnz_total().to_string(),
                ]);
            }
        }
    }
    let path = table.save(&ctx.results, "fig2")?;
    crate::info!("fig2", "wrote {}", path.display());
    Ok(())
}

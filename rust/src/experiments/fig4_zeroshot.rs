//! Fig 4 + Tables 11/12: zero-shot probe accuracy of pruned models across
//! sparsity levels. The paper's claim: the accuracy gap between ELSA and
//! the baselines widens as sparsity grows.

use anyhow::Result;

use super::Ctx;
use crate::cli::Args;
use crate::data::Grammar;
use crate::eval::{build_suite, score_task, TASK_NAMES};
use crate::model::Params;
use crate::pruners;
use crate::report::{pct, Table};

const SPARSITIES: [f64; 4] = [0.5, 0.7, 0.8, 0.9];
const METHODS: [&str; 5] =
    ["magnitude", "wanda", "sparsegpt", "alps", "elsa"];

pub fn run(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.sweep_models()[0];
    let (cfg, dense, c4, _) = ctx.dense_setup(model)?;
    let g = Grammar::named("synth-c4", cfg.vocab);
    let n_ex = match ctx.scale {
        super::Scale::Quick => 25,
        super::Scale::Full => 60,
    };
    let suite = build_suite(&g, n_ex, 0x4E05);

    let mut cols: Vec<&str> = vec!["sparsity", "method"];
    cols.extend(TASK_NAMES.iter());
    cols.push("avg");
    let mut table = Table::new(
        &format!("Fig 4 / Table 11 — zero-shot accuracy (%), {model}"),
        &cols);

    let mut eval_row = |label: &str, sp_label: &str, params: &Params|
                       -> Result<()> {
        let mut row = vec![sp_label.to_string(), label.to_string()];
        let mut sum = 0.0;
        for (_, exs) in &suite {
            let acc = score_task(params, exs)?;
            sum += acc;
            row.push(pct(acc));
        }
        row.push(pct(sum / suite.len() as f64));
        crate::info!("fig4", "{sp_label} {label}: avg={:.1}%",
                     100.0 * sum / suite.len() as f64);
        table.row(row);
        Ok(())
    };

    eval_row("dense", "0.0", &Params::new(&cfg, dense.clone()))?;
    for &sp in &SPARSITIES {
        for method in METHODS {
            let pruned = ctx.pruned_cached(&cfg, method, sp, "", || {
                if method == "elsa" {
                    ctx.run_elsa(&cfg, &dense, &c4.train, sp, |_| {})
                } else {
                    pruners::prune_oneshot(&ctx.rt, &cfg, method, &dense,
                                           &c4.train, sp, args)
                }
            })?;
            eval_row(method, &format!("{sp:.1}"),
                     &Params::new(&cfg, pruned))?;
        }
    }
    let path = table.save(&ctx.results, "fig4")?;
    crate::info!("fig4", "wrote {}", path.display());
    Ok(())
}

//! Table 7: non-uniform sparsity allocation at 70% — SparseGPT uniform,
//! OWL, EvoPress-lite, SparseLLM-style global saliency ranking (with
//! and without UniPruning-style NLL feedback), ELSA (global budget)
//! and ELSA seeded with the EvoPress allocation.

use anyhow::Result;

use super::Ctx;
use crate::cli::Args;
use crate::coordinator::eval_ppl;
use crate::coordinator::patterns::Pattern;
use crate::pruners::{self, alloc};
use crate::report::{f2, Table};

pub fn run(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = ctx.sweep_models()[0];
    let (cfg, dense, c4, wiki) = ctx.dense_setup(model)?;
    let sp = 0.7;

    let mut table = Table::new(
        &format!("Table 7 — non-uniform allocation at 70% ({model})"),
        &["method", "ppl_wiki", "ppl_c4"]);
    let mut add = |name: &str, pruned: &[f32]| -> Result<()> {
        let pw = eval_ppl(&ctx.rt, &cfg, pruned, &wiki.valid)?;
        let pc = eval_ppl(&ctx.rt, &cfg, pruned, &c4.valid)?;
        crate::info!("tab7", "{name}: wiki={pw:.2} c4={pc:.2}");
        table.row(vec![name.into(), f2(pw), f2(pc)]);
        Ok(())
    };

    // uniform layer-wise baseline
    let sg = ctx.pruned_cached(&cfg, "sparsegpt", sp, "", || {
        pruners::prune_oneshot(&ctx.rt, &cfg, "sparsegpt", &dense,
                               &c4.train, sp, args)
    })?;
    add("sparsegpt (uniform)", &sg)?;

    // OWL allocation on wanda
    let owl = ctx.pruned_cached(&cfg, "wanda-owl", sp, "", || {
        pruners::prune_oneshot(&ctx.rt, &cfg, "wanda-owl", &dense,
                               &c4.train, sp, args)
    })?;
    add("owl (wanda)", &owl)?;

    // EvoPress-lite allocation on wanda
    let calib = pruners::calibrate(&cfg, &dense, &c4.train, 7)?;
    let evo_alloc = alloc::evopress_allocation(
        &cfg, &dense, &calib, &c4.train, sp,
        &alloc::EvoOptions::default())?;
    let evo = ctx.pruned_cached(&cfg, "wanda-evo", sp, "", || {
        pruners::wanda::prune(&cfg, &dense, &calib, &evo_alloc)
    })?;
    add("evopress (wanda)", &evo)?;

    // SparseLLM-style global saliency ranking across all segments
    let glob_alloc =
        alloc::global_allocation(&cfg, &dense, &calib, sp)?;
    let glob = ctx.pruned_cached(&cfg, "wanda-global", sp, "", || {
        pruners::wanda::prune(&cfg, &dense, &calib, &glob_alloc)
    })?;
    add("global (wanda)", &glob)?;

    // ... refined by UniPruning-style held-out-NLL feedback
    let fb_alloc = alloc::feedback_allocation(
        &cfg, &dense, &calib, &c4.train, &glob_alloc, sp, 2)?;
    let fb = ctx.pruned_cached(&cfg, "wanda-global-fb", sp, "", || {
        pruners::wanda::prune(&cfg, &dense, &calib, &fb_alloc)
    })?;
    add("global+feedback (wanda)", &fb)?;

    // ELSA with the EvoPress non-uniform budget
    let evo_pat = Pattern::NonUniform {
        per_segment: evo_alloc.clone(),
        default: sp,
    };
    let elsa_evo = ctx.pruned_cached(&cfg, "elsa-evo", sp, "", || {
        ctx.run_elsa(&cfg, &dense, &c4.train, sp,
                     |o| o.pattern = evo_pat.clone())
    })?;
    add("elsa (evopress alloc)", &elsa_evo)?;

    // ELSA's native global budget (the paper's uniform ELSA)
    let elsa = ctx.pruned_cached(&cfg, "elsa", sp, "", || {
        ctx.run_elsa(&cfg, &dense, &c4.train, sp, |_| {})
    })?;
    add("elsa (global)", &elsa)?;

    let path = table.save(&ctx.results, "tab7")?;
    crate::info!("tab7", "wrote {}", path.display());
    Ok(())
}

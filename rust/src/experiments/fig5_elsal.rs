//! Fig 5 + §5.4: scaling with low-precision states — ELSA-L ((bf16, fp8)
//! for (u, z) + block-wise INT8 Adam) on the largest local config,
//! reporting perplexity at 90% against the strongest baselines plus the
//! measured state-memory saving (the paper reports 55%).

use anyhow::Result;

use super::Ctx;
use crate::cli::Args;
use crate::coordinator::elsa::{prune_elsa, ElsaOptions};
use crate::coordinator::eval_ppl;
use crate::report::{f2, Table};
use crate::util::human_bytes;

pub fn run(ctx: &Ctx, args: &Args) -> Result<()> {
    let model = match ctx.scale {
        super::Scale::Quick => "small",
        super::Scale::Full => "med",
    };
    let (cfg, dense, c4, wiki) = ctx.dense_setup(model)?;
    let sp = 0.9;

    let mut table = Table::new(
        &format!("Fig 5 — ELSA-L at 90% sparsity ({model})"),
        &["method", "ppl_wiki", "ppl_c4", "aux_state_bytes",
          "opt_state_bytes", "state_saving_vs_fp32"]);

    // baselines for the bar chart
    for method in ["wanda", "sparsegpt", "alps"] {
        let pruned = ctx.pruned_cached(&cfg, method, sp, "", || {
            crate::pruners::prune_oneshot(&ctx.rt, &cfg, method, &dense,
                                          &c4.train, sp, args)
        })?;
        let pw = eval_ppl(&ctx.rt, &cfg, &pruned, &wiki.valid)?;
        let pc = eval_ppl(&ctx.rt, &cfg, &pruned, &c4.valid)?;
        table.row(vec![method.into(), f2(pw), f2(pc), "-".into(),
                       "-".into(), "-".into()]);
    }

    // ELSA (fp32 states) vs ELSA-L (quantized states)
    let steps = ctx.elsa_steps(model);
    let mut run_variant = |name: &str, low_mem: bool| -> Result<()> {
        let mut opts = ElsaOptions::new(sp, steps);
        opts.lam = 2e-2;
        if low_mem {
            opts = opts.low_memory();
        }
        let (pruned, metrics) =
            prune_elsa(&ctx.rt, &cfg, &c4.train, &dense, &opts)?;
        let pw = eval_ppl(&ctx.rt, &cfg, &pruned, &wiki.valid)?;
        let pc = eval_ppl(&ctx.rt, &cfg, &pruned, &c4.valid)?;
        let fp32_state = 4 * cfg.flat_len * 4; // z + u + m + v in f32
        let used = metrics.aux_state_bytes + metrics.opt_state_bytes;
        let saving = 1.0 - used as f64 / fp32_state as f64;
        crate::info!("fig5", "{name}: wiki={pw:.2} c4={pc:.2} states={} \
                      saving={:.0}%", human_bytes(used), saving * 100.0);
        table.row(vec![
            name.into(), f2(pw), f2(pc),
            human_bytes(metrics.aux_state_bytes),
            human_bytes(metrics.opt_state_bytes),
            format!("{:.0}%", saving * 100.0),
        ]);
        Ok(())
    };
    run_variant("elsa", false)?;
    run_variant("elsa-l", true)?;

    let path = table.save(&ctx.results, "fig5")?;
    crate::info!("fig5", "wrote {}", path.display());
    Ok(())
}

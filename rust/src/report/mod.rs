//! Result emitters: CSV + markdown tables into results/, indexed by
//! EXPERIMENTS.md.

use std::path::{Path, PathBuf};

use anyhow::Result;

/// A rectangular result table with named columns.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(),
                   "row width mismatch in '{}'", self.title);
        self.rows.push(cells);
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.columns.join(",") + "\n";
        for r in &self.rows {
            s += &r.join(",");
            s.push('\n');
        }
        s
    }

    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n", self.title);
        s += &format!("| {} |\n", self.columns.join(" | "));
        s += &format!("|{}\n", "---|".repeat(self.columns.len()));
        for r in &self.rows {
            s += &format!("| {} |\n", r.join(" | "));
        }
        s
    }

    /// Write both `<id>.csv` and append to `<id>.md` under `dir`.
    pub fn save(&self, dir: &Path, id: &str) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let csv = dir.join(format!("{id}.csv"));
        std::fs::write(&csv, self.to_csv())?;
        let md = dir.join(format!("{id}.md"));
        let mut text = if md.exists() {
            std::fs::read_to_string(&md)?
        } else {
            String::new()
        };
        text += &self.to_markdown();
        text.push('\n');
        std::fs::write(&md, text)?;
        Ok(csv)
    }
}

/// Format helpers.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}

pub fn pct(x: f64) -> String {
    format!("{:.1}", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_and_markdown() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
        let md = t.to_markdown();
        assert!(md.contains("### demo"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}

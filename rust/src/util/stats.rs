//! Summary statistics for benches and experiment reporting.

/// Running summary over a sample of f64 observations.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub xs: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn n(&self) -> usize {
        self.xs.len()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q / 100.0 * (sorted.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = pos - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Geometric mean of positive values (perplexity aggregation across seeds).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.std() - 1.5811).abs() < 1e-3);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert!((s.percentile(25.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!(geomean(&[]).is_nan());
    }
}

//! Leveled stderr logger with elapsed-time prefixes.
//!
//! `ELSA_LOG=debug|info|warn|quiet` selects verbosity (default info).
//!
//! TIMING-OK: the elapsed-time prefix decorates stderr lines only.
//! DETERMINISM-OK: the `ELSA_LOG` env read selects log *verbosity* —
//! it cannot change any computed value or token.

use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Quiet = 3,
}

static START: OnceLock<Instant> = OnceLock::new();
static LEVEL: OnceLock<Level> = OnceLock::new();

fn level() -> Level {
    *LEVEL.get_or_init(|| match std::env::var("ELSA_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("quiet") => Level::Quiet,
        _ => Level::Info,
    })
}

pub fn log(lvl: Level, tag: &str, msg: &str) {
    if lvl < level() {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{t:8.2}s {tag}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::logger::log(
            $crate::util::logger::Level::Info, $tag, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::logger::log(
            $crate::util::logger::Level::Debug, $tag, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::logger::log(
            $crate::util::logger::Level::Warn, $tag, &format!($($arg)*))
    };
}

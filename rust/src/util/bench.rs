//! Minimal benchmark harness (criterion is not in the offline vendor
//! set). Warmup + timed iterations with median/MAD reporting, and a
//! throughput helper. Used by every target in rust/benches (all declared
//! `harness = false`).
//!
//! TIMING-OK: measurement harness — wall time is the *output* here,
//! and nothing downstream of a bench result feeds back into kernels or
//! scheduling.

use std::time::Instant;

use super::stats::Summary;

pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn per_iter_pretty(&self) -> String {
        fmt_ns(self.median_ns)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Run `f` repeatedly: a few warmup calls, then timed batches until
/// `budget_ms` of measurement or `max_iters`, whichever first.
pub fn bench(name: &str, budget_ms: u64, mut f: impl FnMut()) -> BenchResult {
    // warmup
    for _ in 0..3 {
        f();
    }
    let budget = std::time::Duration::from_millis(budget_ms);
    let mut samples = Summary::new();
    let start = Instant::now();
    let mut iters = 0usize;
    while start.elapsed() < budget && iters < 1_000_000 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        iters += 1;
    }
    let r = BenchResult {
        name: name.to_string(),
        median_ns: samples.median(),
        mean_ns: samples.mean(),
        std_ns: samples.std(),
        iters,
    };
    println!("{:<44} {:>12}/iter   ({} iters, sd {})", r.name,
             r.per_iter_pretty(), r.iters, fmt_ns(r.std_ns));
    r
}

/// Report a throughput line derived from a bench result.
pub fn throughput(r: &BenchResult, units: f64, unit_name: &str) {
    let per_sec = units / (r.median_ns / 1e9);
    println!("{:<44} {:>12.2} {unit_name}/s", format!("  -> {}", r.name),
             per_sec);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench("noop-ish", 10, || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(r.iters > 10);
        assert!(r.median_ns >= 0.0);
    }
}

//! Wall-clock timing helpers for the cost analysis (Table 3) and benches.
//!
//! TIMING-OK: this module *is* the wall clock — everything here feeds
//! reporting (bench medians, wall_seconds, cost tables), never token
//! selection or scheduling decisions, which run on the deterministic
//! step clock (see `infer/scheduler.rs` module docs).

use std::time::Instant;

/// Scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.seconds())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.seconds() >= 0.004);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}

//! Minimal JSON parser + writer (serde is not in the offline vendor set).
//!
//! Covers the full JSON grammar we produce/consume: the AOT manifest,
//! experiment result files and checkpoints metadata. Numbers are kept as
//! f64 (the manifest only stores shapes/offsets well below 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use BTreeMap so emission is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a usize: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Convenience: `[1,2,3]` -> Vec<usize> (shape lists in the manifest).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

// ---------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?);
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------

pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(&mut s, v);
    s
}

fn write_value(out: &mut String, v: &Value) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{}", n);
            }
        }
        Value::Str(s) => write_string(out, s),
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, x);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(out, k);
                out.push(':');
                write_value(out, x);
            }
            out.push('}');
        }
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Build helpers for report emission.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere",
                       "d": true, "e": null}, "f": "A"}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("f").unwrap().as_str().unwrap(), "A");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        let emitted = to_string(&v);
        let v2 = parse(&emitted).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn usize_vec() {
        let v = parse("[8, 65, 256]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![8, 65, 256]);
        assert!(parse("[1.5]").unwrap().as_usize_vec().is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo → ∞\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → ∞");
    }
}

//! Deterministic PRNG (PCG64-DXSM-lite) + sampling helpers.
//!
//! Everything stochastic in the coordinator (corpus generation, batch
//! order, EvoPress mutations, bench inputs) flows through this so every
//! experiment is bit-reproducible from its seed.

/// PCG-XSH-RR 64/32 with 64-bit output composition.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut r = Rng { state: 0, inc: (seed << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        r.next_u32();
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's rejection-free-enough method.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut x = self.f32() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>()
            / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(11);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}

//! Small self-contained substrates the rest of the crate builds on.
//!
//! The offline vendor set ships only the `xla` dependency closure (no
//! serde/clap/rayon/criterion), so JSON, RNG, statistics, timing and the
//! bench harness are implemented here and unit-tested like any other
//! module.

pub mod bench;
pub mod json;
pub mod logger;
pub mod rng;
pub mod stats;
pub mod timer;

/// Format a byte count as a human-readable size.
pub fn human_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KB");
        assert_eq!(human_bytes(5 * 1024 * 1024), "5.00 MB");
    }
}

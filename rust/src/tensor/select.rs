//! Selection: k-th largest threshold + top-k masks via quickselect.
//!
//! The ELSA z-update is a *global* projection onto `||z||_0 <= k` over a
//! multi-million-entry score vector every `interval_k` steps — an O(d)
//! quickselect instead of an O(d log d) sort is the difference between
//! the projection being free and being the coordinator bottleneck
//! (see EXPERIMENTS.md §Perf).

use crate::util::rng::Rng;

/// Value of the k-th largest element (1-based k) of `xs`, O(n) expected.
/// NaNs are treated as -inf (never selected).
pub fn kth_largest(xs: &[f32], k: usize) -> f32 {
    assert!(k >= 1 && k <= xs.len(), "k={k} out of range n={}", xs.len());
    let mut buf: Vec<f32> =
        xs.iter().map(|&x| if x.is_nan() { f32::NEG_INFINITY } else { x })
            .collect();
    let idx = k - 1; // select index `idx` in descending order
    let mut rng = Rng::new(0x9e3779b97f4a7c15);
    let (mut lo, mut hi) = (0usize, buf.len());
    loop {
        if hi - lo <= 16 {
            let slice = &mut buf[lo..hi];
            slice.sort_by(|a, b| b.partial_cmp(a).unwrap());
            return buf[idx];
        }
        let pivot = buf[lo + rng.below(hi - lo)];
        // three-way partition (descending): [> pivot | == pivot | < pivot]
        let (mut i, mut j, mut p) = (lo, lo, hi);
        while j < p {
            if buf[j] > pivot {
                buf.swap(i, j);
                i += 1;
                j += 1;
            } else if buf[j] < pivot {
                p -= 1;
                buf.swap(j, p);
            } else {
                j += 1;
            }
        }
        if idx < i {
            hi = i;
        } else if idx < p {
            return pivot;
        } else {
            lo = p;
        }
    }
}

/// 0/1 mask keeping exactly `k` entries with the largest scores.
/// Ties at the threshold are broken by index order (first come first kept)
/// so the mask cardinality is exact — required for exact-sparsity claims.
pub fn topk_mask(scores: &[f32], k: usize) -> Vec<f32> {
    let n = scores.len();
    let mut mask = vec![0.0f32; n];
    if k == 0 {
        return mask;
    }
    if k >= n {
        mask.fill(1.0);
        return mask;
    }
    let thr = kth_largest(scores, k);
    let mut kept = 0usize;
    // strictly-above first
    for (m, &s) in mask.iter_mut().zip(scores.iter()) {
        if s > thr {
            *m = 1.0;
            kept += 1;
        }
    }
    // fill remaining budget from entries equal to the threshold
    if kept < k {
        for (m, &s) in mask.iter_mut().zip(scores.iter()) {
            if *m == 0.0 && s == thr {
                *m = 1.0;
                kept += 1;
                if kept == k {
                    break;
                }
            }
        }
    }
    debug_assert_eq!(kept, k);
    mask
}

/// Indices of the top-k scores (order unspecified).
pub fn topk_indices(scores: &[f32], k: usize) -> Vec<usize> {
    topk_mask(scores, k)
        .iter()
        .enumerate()
        .filter(|(_, m)| **m > 0.0)
        .map(|(i, _)| i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn kth_matches_sort() {
        let mut rng = Rng::new(7);
        for n in [1usize, 2, 17, 100, 1000] {
            let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            for k in [1, n / 2 + 1, n] {
                assert_eq!(kth_largest(&xs, k), sorted[k - 1], "n={n} k={k}");
            }
        }
    }

    #[test]
    fn topk_mask_exact_cardinality() {
        let mut rng = Rng::new(8);
        let xs: Vec<f32> = (0..5000).map(|_| rng.normal()).collect();
        for k in [0usize, 1, 100, 2500, 4999, 5000] {
            let m = topk_mask(&xs, k);
            let kept = m.iter().filter(|x| **x > 0.0).count();
            assert_eq!(kept, k);
        }
    }

    #[test]
    fn topk_mask_with_ties() {
        let xs = vec![1.0f32; 100];
        let m = topk_mask(&xs, 37);
        assert_eq!(m.iter().filter(|x| **x > 0.0).count(), 37);
    }

    #[test]
    fn topk_keeps_largest() {
        let xs = vec![5.0, -1.0, 3.0, 0.5, 4.0];
        let m = topk_mask(&xs, 2);
        assert_eq!(m, vec![1.0, 0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn nan_never_selected() {
        let xs = vec![f32::NAN, 1.0, 2.0];
        let m = topk_mask(&xs, 2);
        assert_eq!(m, vec![0.0, 1.0, 1.0]);
    }
}

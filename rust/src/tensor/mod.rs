//! Dense f32 tensor substrate: matrices, GEMM/GEMV, Cholesky, selection.
//!
//! This is the linear-algebra floor under the baseline pruners
//! (SparseGPT's Hessian solves, L-ADMM/ALPS reconstruction), the rust
//! reference forward, and the sparse-engine comparisons. Deliberately
//! f32-only and row-major.

pub mod linalg;
pub mod select;

use crate::util::rng::Rng;

/// Row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal() * std).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// C = A @ B, ikj loop order (streaming, cache-friendly).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (p, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue; // free sparsity win for pruned matrices
                }
                let brow = &other.data[p * n..(p + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// y = A @ x (GEMV).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            let row = self.row(r);
            let mut acc = 0.0f32;
            for (a, b) in row.iter().zip(x.iter()) {
                acc += a * b;
            }
            y[r] = acc;
        }
        y
    }

    /// y = A^T @ x without materializing the transpose.
    pub fn t_matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.rows, x.len());
        let mut y = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            let xv = x[r];
            if xv == 0.0 {
                continue;
            }
            let row = self.row(r);
            for (yj, &a) in y.iter_mut().zip(row.iter()) {
                *yj += xv * a;
            }
        }
        y
    }

    /// Gram matrix A^T A (the layer-wise Hessian proxy X^T X).
    pub fn gram(&self) -> Matrix {
        let n = self.cols;
        let mut g = Matrix::zeros(n, n);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..n {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                let grow = &mut g.data[i * n..(i + 1) * n];
                for j in 0..n {
                    grow[j] += ri * row[j];
                }
            }
        }
        g
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|x| **x != 0.0).count()
    }
}

/// Elementwise vector helpers used across the coordinator.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b.iter()).map(|(x, y)| x * y).sum()
}

pub fn l2(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(5, 7, 1.0, &mut rng);
        let i = Matrix::eye(7);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(6, 4, 1.0, &mut rng);
        let x: Vec<f32> = (0..4).map(|_| rng.normal()).collect();
        let xm = Matrix::from_vec(4, 1, x.clone());
        let via_mm = a.matmul(&xm);
        let via_mv = a.matvec(&x);
        for (u, v) in via_mm.data.iter().zip(via_mv.iter()) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn t_matvec_matches_transpose() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(6, 4, 1.0, &mut rng);
        let x: Vec<f32> = (0..6).map(|_| rng.normal()).collect();
        let direct = a.t_matvec(&x);
        let via_t = a.transpose().matvec(&x);
        for (u, v) in direct.iter().zip(via_t.iter()) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn gram_is_xtx() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(8, 3, 1.0, &mut rng);
        let g = a.gram();
        let expect = a.transpose().matmul(&a);
        for (u, v) in g.data.iter().zip(expect.data.iter()) {
            assert!((u - v).abs() < 1e-4);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(3, 5, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }
}

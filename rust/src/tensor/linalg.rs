//! Cholesky factorization + solves (f64 accumulation for stability),
//! plus the dense batched transpose-GEMM (`t_matmat`) used by the
//! engine's head projection.
//!
//! SparseGPT and ALPS both need `H^{-1}` of the damped layer Hessian
//! `H = X^T X + eps I`; we factor once and reuse triangular solves.

use anyhow::{bail, Result};

use super::Matrix;

impl Matrix {
    /// Batched transpose-GEMM: Y = X A for A = self (n, m) and a
    /// row-major batch X (b, n), writing Y (b, m). The r-outer loop
    /// streams every weight row of A exactly **once** per call and
    /// applies it across all b lanes — so the engine's per-step head
    /// projection costs one pass over the head matrix regardless of
    /// how many slots are live.
    ///
    /// Bit-exactness: for each lane `bi`, the accumulation over rows r
    /// runs in the same ascending order with the same skip-zero rule
    /// as [`Matrix::t_matvec`], so row `bi` of Y is bit-identical to
    /// `self.t_matvec(&x[bi * n..(bi + 1) * n])`.
    pub fn t_matmat(&self, x: &[f32], y: &mut [f32], b: usize) {
        let (n, m) = (self.rows, self.cols);
        debug_assert_eq!(x.len(), b * n);
        debug_assert_eq!(y.len(), b * m);
        y.fill(0.0);
        for r in 0..n {
            let wrow = &self.data[r * m..(r + 1) * m];
            for bi in 0..b {
                let xv = x[bi * n + r];
                if xv == 0.0 {
                    continue;
                }
                let yrow = &mut y[bi * m..(bi + 1) * m];
                for (yj, &a) in yrow.iter_mut().zip(wrow.iter()) {
                    *yj += xv * a;
                }
            }
        }
    }
}

/// Lower-triangular Cholesky factor L with H = L L^T.
#[derive(Debug, Clone)]
pub struct Cholesky {
    pub n: usize,
    /// row-major lower triangle (full n x n storage, upper = 0)
    pub l: Vec<f64>,
}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix.
    pub fn factor(h: &Matrix) -> Result<Cholesky> {
        if h.rows != h.cols {
            bail!("cholesky: matrix not square");
        }
        let n = h.rows;
        let mut l = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut sum = h.at(i, j) as f64;
                for k in 0..j {
                    sum -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    if sum <= 0.0 {
                        bail!("cholesky: not positive definite at {i} \
                               (pivot {sum:.3e}); increase damping");
                    }
                    l[i * n + i] = sum.sqrt();
                } else {
                    l[i * n + j] = sum / l[j * n + j];
                }
            }
        }
        Ok(Cholesky { n, l })
    }

    /// Solve H x = b.
    pub fn solve(&self, b: &[f32]) -> Vec<f32> {
        let n = self.n;
        debug_assert_eq!(b.len(), n);
        // forward: L y = b
        let mut y = vec![0.0f64; n];
        for i in 0..n {
            let mut sum = b[i] as f64;
            for k in 0..i {
                sum -= self.l[i * n + k] * y[k];
            }
            y[i] = sum / self.l[i * n + i];
        }
        // backward: L^T x = y
        let mut x = vec![0.0f64; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in i + 1..n {
                sum -= self.l[k * n + i] * x[k];
            }
            x[i] = sum / self.l[i * n + i];
        }
        x.into_iter().map(|v| v as f32).collect()
    }

    /// Full inverse H^{-1} (needed column-wise by SparseGPT's OBS update).
    pub fn inverse(&self) -> Matrix {
        let n = self.n;
        let mut inv = Matrix::zeros(n, n);
        let mut e = vec![0.0f32; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = self.solve(&e);
            e[j] = 0.0;
            for i in 0..n {
                *inv.at_mut(i, j) = col[i];
            }
        }
        inv
    }

    /// diag(H^{-1}) without materializing the full inverse.
    pub fn inverse_diag(&self) -> Vec<f32> {
        // Columns of L^{-1}: solve L v = e_j; then (H^{-1})_jj = ||v_j||^2
        // restricted to rows >= j. We do it column by column.
        let n = self.n;
        let mut diag = vec![0.0f32; n];
        let mut v = vec![0.0f64; n];
        for j in 0..n {
            for x in v.iter_mut() {
                *x = 0.0;
            }
            v[j] = 1.0;
            for i in j..n {
                let mut sum = v[i];
                for k in j..i {
                    sum -= self.l[i * n + k] * v[k];
                }
                v[i] = sum / self.l[i * n + i];
            }
            diag[j] = v[j..n].iter().map(|x| x * x).sum::<f64>() as f32;
        }
        diag
    }
}

/// Add `eps * mean(diag)` damping in place (SparseGPT convention).
pub fn damp(h: &mut Matrix, eps: f32) {
    let n = h.rows;
    let mean_diag: f32 =
        (0..n).map(|i| h.at(i, i)).sum::<f32>() / n as f32;
    let add = eps * mean_diag.max(1e-8);
    for i in 0..n {
        *h.at_mut(i, i) += add;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let a = Matrix::randn(n + 4, n, 1.0, &mut rng);
        let mut h = a.gram();
        damp(&mut h, 0.01);
        h
    }

    #[test]
    fn factor_reconstructs() {
        let h = spd(8, 0);
        let ch = Cholesky::factor(&h).unwrap();
        let n = h.rows;
        for i in 0..n {
            for j in 0..n {
                let mut v = 0.0;
                for k in 0..n {
                    v += ch.l[i * n + k] * ch.l[j * n + k];
                }
                assert!((v as f32 - h.at(i, j)).abs() < 1e-3,
                        "({i},{j}): {v} vs {}", h.at(i, j));
            }
        }
    }

    #[test]
    fn solve_inverts() {
        let h = spd(10, 1);
        let ch = Cholesky::factor(&h).unwrap();
        let mut rng = Rng::new(2);
        let b: Vec<f32> = (0..10).map(|_| rng.normal()).collect();
        let x = ch.solve(&b);
        let back = h.matvec(&x);
        for (u, v) in back.iter().zip(b.iter()) {
            assert!((u - v).abs() < 1e-3);
        }
    }

    #[test]
    fn inverse_matches_solve() {
        let h = spd(6, 3);
        let ch = Cholesky::factor(&h).unwrap();
        let inv = ch.inverse();
        let prod = h.matmul(&inv);
        for i in 0..6 {
            for j in 0..6 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - expect).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn inverse_diag_matches_full() {
        let h = spd(7, 4);
        let ch = Cholesky::factor(&h).unwrap();
        let inv = ch.inverse();
        let diag = ch.inverse_diag();
        for i in 0..7 {
            assert!((diag[i] - inv.at(i, i)).abs() < 1e-4);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let h = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        assert!(Cholesky::factor(&h).is_err());
    }

    #[test]
    fn t_matmat_rows_are_bitwise_t_matvec() {
        let mut rng = Rng::new(5);
        let mut a = Matrix::randn(9, 6, 1.0, &mut rng);
        // zero a few entries so the skip-zero rule is exercised on
        // both the weight and the activation side
        a.data[3] = 0.0;
        a.data[20] = 0.0;
        for b in [1usize, 3, 8] {
            let mut x: Vec<f32> =
                (0..b * 9).map(|_| rng.normal()).collect();
            x[0] = 0.0;
            if b > 1 {
                x[9 + 4] = 0.0;
            }
            let mut y = vec![7.0f32; b * 6];
            a.t_matmat(&x, &mut y, b);
            for bi in 0..b {
                let want = a.t_matvec(&x[bi * 9..(bi + 1) * 9]);
                assert_eq!(&y[bi * 6..(bi + 1) * 6], &want[..],
                           "b={b} row {bi}");
            }
        }
    }
}

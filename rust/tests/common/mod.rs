//! Shared fixtures for the integration suites (ISSUE 4 satellite):
//! the toy serving model, pruned-parameter builders, and request
//! factories that used to be copy-pasted across `scheduler.rs`,
//! `engine_batch.rs`, `engine_parity.rs` and `kernels.rs`. Every suite
//! builds the *same* toy engine from here, so a numerics change shows
//! up consistently instead of in whichever suite happened to share the
//! seed.

// each test crate compiles its own copy and uses a subset
#![allow(dead_code)]

use elsa::infer::scheduler::Request;
use elsa::infer::{Backend, Engine};
use elsa::model::{synthetic_config, Params};
use elsa::pruners::{magnitude, uniform_alloc};
use elsa::runtime::ConfigEntry;
use elsa::sparse::{nm_project, NmMode, QuantMode};
use elsa::tensor::Matrix;

/// Vocab of the toy serving model — prompt token streams index modulo
/// this.
pub const TOY_VOCAB: usize = 48;

/// The toy serving model every integration suite decodes on: d=40
/// (attention heads of 10), 2 layers, vocab 48, seq_len 20 — big
/// enough for multi-word MACKO bitmaps per head, small enough that a
/// full determinism sweep stays fast.
pub fn toy_cfg() -> ConfigEntry {
    synthetic_config("toy_t", 40, 2, 4, 64, TOY_VOCAB, 20)
}

/// Init `cfg` at `seed` and magnitude-prune it to `sparsity`.
pub fn pruned_params(cfg: &ConfigEntry, sparsity: f64, seed: u64)
                     -> Params {
    let dense = Params::init(cfg, seed);
    let pruned = magnitude::prune(cfg, &dense.flat,
                                  &uniform_alloc(cfg, sparsity))
        .expect("magnitude prune");
    Params::new(cfg, pruned)
}

/// The standard 75%-sparse toy engine plus its `seq_len`.
pub fn engine(backend: Backend) -> (Engine, usize) {
    let cfg = toy_cfg();
    let seq_len = cfg.seq_len;
    let p = pruned_params(&cfg, 0.75, 1);
    (Engine::build(&p, backend).expect("engine"), seq_len)
}

/// The standard toy engine with quantized weight payloads
/// (`CsrQ`/`MackoQ` via [`QuantMode`]) — same params/seed as
/// [`engine`], so its streams are the tolerance-parity counterpart of
/// the f32 engine's and bit-exactly reproducible within the mode.
/// Requires a sparse backend (`build_quant` rejects Dense).
pub fn quant_engine(backend: Backend, quant: QuantMode)
                    -> (Engine, usize) {
    let cfg = toy_cfg();
    let seq_len = cfg.seq_len;
    let p = pruned_params(&cfg, 0.75, 1);
    (Engine::build_quant(&p, backend, quant).expect("quant engine"),
     seq_len)
}

/// [`pruned_params`] re-projected so every prunable linear satisfies
/// the requested N:M pattern (magnitude top-N per group via
/// [`nm_project`]); the `NmWeights` build verifies it. The toy dims
/// (d_model 40, d_ff 64) divide by both 4 and 8, so 2:4 and 4:8 both
/// apply.
pub fn nm_params(cfg: &ConfigEntry, nm: NmMode, seed: u64) -> Params {
    let mut p = pruned_params(cfg, 0.5, seed);
    for seg in p.cfg.segments.clone() {
        if seg.prunable && seg.is_matrix() {
            let w = Matrix::from_vec(
                seg.shape[0], seg.shape[1],
                p.flat[seg.offset..seg.end()].to_vec());
            let proj = nm_project(&w, nm.n(), nm.m());
            p.flat[seg.offset..seg.end()].copy_from_slice(&proj.data);
        }
    }
    p
}

/// The toy engine serving an N:M structured checkpoint through the
/// branch-free `NmSparse` kernels — same seed convention as
/// [`engine`], but the weights are projected (see [`nm_params`]), so
/// its streams are self-consistent rather than comparable to the
/// unstructured engine's. Requires a sparse backend.
pub fn nm_engine(backend: Backend, nm: NmMode) -> (Engine, usize) {
    let cfg = toy_cfg();
    let seq_len = cfg.seq_len;
    let p = nm_params(&cfg, nm, 1);
    (Engine::build_nm(&p, backend, nm).expect("nm engine"), seq_len)
}

/// The toy engine with deliberately tiny tile plans (64-byte budget,
/// 8-row cap): at toy scale the default 16 KiB budget puts a whole
/// layer in one tile, so pooled `--shard-workers` decode would never
/// actually shard. Retiling forces multi-tile plans so the pool, the
/// ragged tile boundaries, and the shard balancer are all genuinely
/// exercised — tokens are bit-identical to [`engine`] regardless
/// (plans are traversal metadata only).
pub fn banded_engine(backend: Backend) -> (Engine, usize) {
    let (mut e, seq_len) = engine(backend);
    e.retile(64, 8);
    (e, seq_len)
}

/// A request with the suites' conventional seed (`100 + id`) and no
/// deadline.
pub fn req(id: u64, prompt: Vec<u32>, n_new: usize) -> Request {
    Request { id, prompt, n_new, seed: 100 + id, deadline: None }
}

/// Ragged prompts (1–5 tokens) + ragged budgets (2–7 tokens) for
/// determinism sweeps — deterministic in `id`, so every suite replays
/// the identical stream.
pub fn ragged_requests(n: u64) -> Vec<Request> {
    (0..n)
        .map(|id| {
            let plen = 1 + (id as usize % 5);
            let prompt = (0..plen)
                .map(|i| ((id as usize * 7 + i * 3) % TOY_VOCAB) as u32)
                .collect();
            req(id, prompt, 2 + (id as usize % 6))
        })
        .collect()
}

/// Prompt lengths that straddle the chunked-prefill windows swept in
/// `determinism.rs` (chunks {1, 3, 16} on the seq_len-20 toy model).
/// The chunked pass feeds `len - 1` positions headless, so for each
/// chunk the headless count hits one-below / exactly-at / one-above a
/// window boundary: chunk 3 → counts {2,3,4} (lens 3,4,5) and {5,6,7}
/// (lens 6,7,8), chunk 16 → counts {15,16,17} (lens 16,17,18). Long
/// prompts get a 2-token budget so `prompt_len + n_new <= seq_len`
/// always holds (no request retires early on seq_len — the probe
/// tests count on it).
pub const STRADDLING_PROMPT_LENS: [usize; 11] =
    [1, 2, 3, 4, 5, 6, 7, 8, 16, 17, 18];

/// Deterministic requests whose prompts cycle through
/// [`STRADDLING_PROMPT_LENS`] — the chunk-boundary companion to
/// [`ragged_requests`].
pub fn chunk_straddling_requests(n: u64) -> Vec<Request> {
    (0..n)
        .map(|id| {
            let plen = STRADDLING_PROMPT_LENS
                [id as usize % STRADDLING_PROMPT_LENS.len()];
            let prompt = (0..plen)
                .map(|i| ((id as usize * 11 + i * 5) % TOY_VOCAB) as u32)
                .collect();
            req(id, prompt, if plen >= 15 { 2 } else { 3 })
        })
        .collect()
}

/// Length of the identical "system prompt" head shared by every
/// request in [`shared_prefix_requests`] — equal to the prefix
/// cache's `PREFIX_BLOCK`, so the divergent-suffix family can attach
/// at the block-aligned boundary.
pub const SHARED_SYSTEM_PROMPT_LEN: usize = 8;

/// The shared-prefix serving family: every prompt starts with the
/// same [`SHARED_SYSTEM_PROMPT_LEN`]-token system prompt, then
/// diverges into a per-request suffix whose length cycles
/// {1, 2, 3, 4, 8, 9} — straddling the swept prefill-chunk windows
/// ({1, 3, 16}) on the suffix side of an attach. Every 5th request
/// has NO suffix: its full prompt IS the cached system prompt, the
/// identity case where attach must stop one position short of the
/// prompt end. Suffix first-tokens are distinct across ids (17 is
/// coprime to [`TOY_VOCAB`]), so the system prompt head is the only
/// shareable prefix and expected cache savings are exactly
/// `min(SHARED_SYSTEM_PROMPT_LEN, prompt_len - 1)` per hit.
pub fn shared_prefix_requests(n: u64) -> Vec<Request> {
    const SUFFIX_LENS: [usize; 6] = [1, 2, 3, 4, 8, 9];
    let system: Vec<u32> = (0..SHARED_SYSTEM_PROMPT_LEN)
        .map(|i| ((i * 13 + 5) % TOY_VOCAB) as u32)
        .collect();
    (0..n)
        .map(|id| {
            let mut prompt = system.clone();
            if id % 5 != 4 {
                let slen = SUFFIX_LENS[id as usize % SUFFIX_LENS.len()];
                prompt.extend((0..slen).map(|i| {
                    ((id as usize * 17 + i * 7) % TOY_VOCAB) as u32
                }));
            }
            let n_new = if prompt.len() >= 15 { 2 } else { 3 };
            req(id, prompt, n_new)
        })
        .collect()
}

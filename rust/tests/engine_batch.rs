//! Batched-engine behaviour: determinism under threading, slot
//! retirement edge cases, and GenStats token accounting (ISSUE 1
//! satellite tests).

mod common;

use common::engine;
use elsa::infer::{Backend, BatchOptions};

fn opts(n_new: usize, threads: usize) -> BatchOptions {
    BatchOptions {
        n_new,
        temperature: 0.8,
        seed: 3,
        threads,
        ..BatchOptions::default()
    }
}

#[test]
fn batched_matches_per_sequence_for_batch_2_4_7() {
    for backend in [Backend::Csr, Backend::Macko] {
        let (engine, _) = engine(backend);
        for b in [2usize, 4, 7] {
            let prompts: Vec<Vec<u32>> = (0..b)
                .map(|s| (0..4).map(|i| ((s * 7 + i * 3) % 48) as u32)
                     .collect())
                .collect();
            let o = opts(8, 1);
            let (outs, stats) = engine.generate_batch(&prompts, &o);
            let mut total = 0usize;
            for (s, prompt) in prompts.iter().enumerate() {
                let (want, _) =
                    engine.generate(prompt, 8, 0.8, 3 + s as u64);
                assert_eq!(outs[s], want, "{backend:?} b={b} slot {s}");
                total += want.len() - prompt.len();
            }
            assert_eq!(stats.tokens_generated, total, "{backend:?} b={b}");
        }
    }
}

#[test]
fn threads_1_vs_4_identical() {
    for backend in [Backend::Csr, Backend::Macko, Backend::Dense] {
        let (engine, _) = engine(backend);
        let prompts: Vec<Vec<u32>> = (0..6)
            .map(|s| (0..3 + s % 3).map(|i| ((s + i * 5) % 48) as u32)
                 .collect())
            .collect();
        let (out1, st1) = engine.generate_batch(&prompts, &opts(9, 1));
        let (out4, st4) = engine.generate_batch(&prompts, &opts(9, 4));
        assert_eq!(out1, out4, "{backend:?}: thread count changed output");
        assert_eq!(st1.tokens_generated, st4.tokens_generated);
        // oversubscribed: more threads than slots must also be safe
        let (out9, _) = engine.generate_batch(&prompts, &opts(9, 9));
        assert_eq!(out1, out9, "{backend:?}: oversubscription changed output");
    }
}

#[test]
fn shard_workers_do_not_change_output_and_report_busy_time() {
    // slot sharding x band sharding: every combination must reproduce
    // the single-threaded streams, and a multi-lane pool must account
    // busy time once it actually decoded something (banded_engine
    // forces multi-tile plans, so the pool really dispatches)
    for backend in [Backend::Csr, Backend::Macko, Backend::Dense] {
        let (engine, _) = common::banded_engine(backend);
        let prompts: Vec<Vec<u32>> = (0..5)
            .map(|s| (0..2 + s % 3).map(|i| ((s * 3 + i) % 48) as u32)
                 .collect())
            .collect();
        let (want, st0) = engine.generate_batch(&prompts, &opts(7, 1));
        assert_eq!(st0.shard_busy_seconds, 0.0,
                   "serial decode must not dispatch the pool");
        for (threads, shard_workers) in
            [(1usize, 2usize), (1, 8), (2, 2), (4, 3)] {
            let o = BatchOptions {
                shard_workers,
                ..opts(7, threads)
            };
            let (got, st) = engine.generate_batch(&prompts, &o);
            assert_eq!(got, want,
                       "{backend:?} threads={threads} \
                        shard_workers={shard_workers} changed output");
            assert!(st.shard_busy_seconds > 0.0,
                    "{backend:?}: pooled decode must account busy time");
        }
    }
}

#[test]
fn ragged_prompts_account_consistently() {
    let (engine, seq_len) = engine(Backend::Macko);
    let prompts: Vec<Vec<u32>> = vec![
        vec![1],
        vec![2, 3, 4],
        vec![5, 6, 7, 8, 9],
        (0..8).map(|i| (i * 2 % 48) as u32).collect(),
    ];
    let n_new = 6;
    let (outs, stats) = engine.generate_batch(&prompts, &opts(n_new, 2));
    let mut total = 0usize;
    for (s, prompt) in prompts.iter().enumerate() {
        assert_eq!(&outs[s][..prompt.len()], &prompt[..],
                   "slot {s} lost its prompt");
        let gen = outs[s].len() - prompt.len();
        let expect = n_new.min(seq_len - prompt.len());
        assert_eq!(gen, expect, "slot {s}");
        total += gen;
    }
    assert_eq!(stats.tokens_generated, total);
}

#[test]
fn slot_hitting_seq_len_retires_mid_batch() {
    let (engine, seq_len) = engine(Backend::Csr);
    // slot 0 can only fit 2 new tokens; slot 1 has room for all 5
    let long: Vec<u32> = (0..seq_len - 2).map(|i| (i % 48) as u32).collect();
    let prompts = vec![long.clone(), vec![1, 2, 3]];
    let n_new = 5;
    let (outs, stats) = engine.generate_batch(&prompts, &opts(n_new, 1));
    assert_eq!(outs[0].len(), seq_len, "slot 0 must stop at seq_len");
    assert_eq!(outs[0].len() - long.len(), 2);
    assert_eq!(outs[1].len() - 3, n_new);
    assert_eq!(stats.tokens_generated, 2 + n_new);
    // and the capped slot still matches its single-sequence twin
    let (want, _) = engine.generate(&long, n_new, 0.8, 3);
    assert_eq!(outs[0], want);
}

#[test]
fn empty_prompt_retires_with_zero_tokens() {
    let (engine, _) = engine(Backend::Macko);
    let prompts: Vec<Vec<u32>> = vec![vec![], vec![4, 5, 6], vec![]];
    let n_new = 4;
    let (outs, stats) = engine.generate_batch(&prompts, &opts(n_new, 2));
    assert_eq!(outs[0], Vec::<u32>::new());
    assert_eq!(outs[2], Vec::<u32>::new());
    assert_eq!(outs[1].len(), 3 + n_new);
    assert_eq!(stats.tokens_generated, n_new,
               "accounting must count only real tokens");
    // the single-sequence path follows the same rule now (the old
    // token-0 fallback divergence is gone)
    let (single, sstats) = engine.generate(&[], n_new, 0.8, 3);
    assert_eq!(single, outs[0], "generate(&[]) must match the batch");
    assert_eq!(sstats.tokens_generated, 0);
}

#[test]
fn zero_new_tokens_and_empty_batch_are_noops() {
    let (engine, _) = engine(Backend::Csr);
    let prompts = vec![vec![1u32, 2], vec![3, 4, 5]];
    let (outs, stats) = engine.generate_batch(&prompts, &opts(0, 2));
    assert_eq!(outs[0], vec![1, 2]);
    assert_eq!(outs[1], vec![3, 4, 5]);
    assert_eq!(stats.tokens_generated, 0);

    let (outs, stats) = engine.generate_batch(&[], &opts(4, 4));
    assert!(outs.is_empty());
    assert_eq!(stats.tokens_generated, 0);
}

#[test]
fn prompt_filling_seq_len_generates_nothing() {
    let (engine, seq_len) = engine(Backend::Macko);
    let full: Vec<u32> = (0..seq_len).map(|i| (i % 48) as u32).collect();
    let prompts = vec![full.clone(), vec![1, 2]];
    let (outs, stats) = engine.generate_batch(&prompts, &opts(3, 1));
    assert_eq!(outs[0], full);
    assert_eq!(outs[1].len(), 2 + 3);
    assert_eq!(stats.tokens_generated, 3);
}

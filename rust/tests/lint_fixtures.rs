//! Fixture suite for `elsa-lint` (rust/src/lint): each rule class has
//! a bad snippet it must fire on and a good snippet it must stay quiet
//! on. The same files are checked against the Python mirror by
//! `ci/test_lint_mirror.py`, so the two implementations cannot drift
//! apart without a fixture failing on one side.
//!
//! The snippets live in `rust/tests/lint_fixtures/*.rs` as data
//! (`include_str!`) — they are linted, never compiled.

use elsa::lint::{lint_source, Config, Rule};

fn rules(v: &[elsa::lint::Violation]) -> Vec<Rule> {
    v.iter().map(|x| x.rule).collect()
}

/// Narrow config for the alloc fixtures: the fixture masquerades as a
/// kernel file whose only hot fn is `hot`.
fn fixture_cfg() -> Config {
    Config {
        hot_fns: &[("sparse/fixture.rs", &["hot"])],
        ..Config::repo()
    }
}

#[test]
fn bad_unsafe_fires_on_both_sites() {
    let src = include_str!("lint_fixtures/bad_unsafe.rs");
    let v = lint_source(&Config::repo(), "infer/fixture.rs", src);
    assert_eq!(rules(&v), vec![Rule::Safety, Rule::Safety], "{v:?}");
    assert_eq!(v[0].line, 3);
    assert_eq!(v[1].line, 7);
}

#[test]
fn good_unsafe_is_quiet() {
    let src = include_str!("lint_fixtures/good_unsafe.rs");
    let v = lint_source(&Config::repo(), "infer/fixture.rs", src);
    assert!(v.is_empty(), "{v:?}");
}

#[test]
fn bad_nondet_fires_in_watched_module_only() {
    let src = include_str!("lint_fixtures/bad_nondet.rs");
    let v = lint_source(&Config::repo(), "sparse/fixture.rs", src);
    assert_eq!(rules(&v), vec![Rule::Nondet, Rule::Nondet], "{v:?}");
    assert_eq!(v[0].line, 5);
    assert_eq!(v[1].line, 10);
    // the same source outside the watched modules is legal
    let outside = lint_source(&Config::repo(), "util/fixture.rs", src);
    assert!(outside.is_empty(), "{outside:?}");
}

#[test]
fn good_nondet_is_quiet() {
    let src = include_str!("lint_fixtures/good_nondet.rs");
    let v = lint_source(&Config::repo(), "sparse/fixture.rs", src);
    assert!(v.is_empty(), "{v:?}");
}

#[test]
fn bad_alloc_fires_only_inside_the_listed_hot_fn() {
    let src = include_str!("lint_fixtures/bad_alloc.rs");
    let v = lint_source(&fixture_cfg(), "sparse/fixture.rs", src);
    assert_eq!(rules(&v), vec![Rule::Alloc], "{v:?}");
    assert_eq!(v[0].line, 5);
}

#[test]
fn good_alloc_is_quiet() {
    let src = include_str!("lint_fixtures/good_alloc.rs");
    let v = lint_source(&fixture_cfg(), "sparse/fixture.rs", src);
    assert!(v.is_empty(), "{v:?}");
}

#[test]
fn renamed_hot_fn_is_a_config_error() {
    // the alloc fixture has no fn named `decode`: a stale table entry
    // must surface as a violation, not silently stop scanning
    let cfg = Config {
        hot_fns: &[("sparse/fixture.rs", &["decode"])],
        ..Config::repo()
    };
    let src = include_str!("lint_fixtures/bad_alloc.rs");
    let v = lint_source(&cfg, "sparse/fixture.rs", src);
    assert_eq!(rules(&v), vec![Rule::Config], "{v:?}");
}

#[test]
fn bad_wildcard_fires_once() {
    let src = include_str!("lint_fixtures/bad_wildcard.rs");
    let v = lint_source(&Config::repo(), "infer/fixture.rs", src);
    assert_eq!(rules(&v), vec![Rule::Wildcard], "{v:?}");
    assert_eq!(v[0].line, 12);
}

#[test]
fn good_wildcard_is_quiet() {
    let src = include_str!("lint_fixtures/good_wildcard.rs");
    let v = lint_source(&Config::repo(), "infer/fixture.rs", src);
    assert!(v.is_empty(), "{v:?}");
}

#[test]
fn repo_policy_accepts_the_committed_tree() {
    // same check the blocking `elsa-lint` CI step runs; kept here too
    // so `cargo test` alone catches violations before CI does
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("src");
    let v = elsa::lint::lint_tree(&Config::repo(), &root).unwrap();
    assert!(
        v.is_empty(),
        "lint violations in rust/src:\n{}",
        v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("\n")
    );
}

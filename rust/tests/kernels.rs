//! Row-tiled kernel bit-identity suite (ISSUE 3): the tiled SpMM
//! paths must be bit-identical to the untiled `matvec_batch_into`
//! kernels for every format, batch size, tile geometry (including
//! ragged boundaries and all-zero rows), and shard count — whether the
//! shards run on per-call scoped threads (`par_matvec_batch_tiled`) or
//! on the persistent decode pool (`pool_matvec_batch_tiled`) — and the
//! engine/scheduler token streams must be unchanged with tiling on vs
//! off, so the PR 1/2 determinism guarantees carry over.
//!
//! The tiled entry points take a [`KernelPath`]; most assertions here
//! run the unrolled (default) traversal against the scalar untiled
//! reference — the strongest single statement of the PR 8 contract —
//! and `kernel_paths_bit_identical_across_formats` pins
//! scalar == unrolled directly for every format including N:M. CI
//! runs this whole suite twice, once per forced path
//! (`ELSA_KERNEL_PATH`), which covers the engine-level streams both
//! ways.

mod common;

use common::{banded_engine, engine, TOY_VOCAB};
use elsa::infer::pool::WorkerPool;
use elsa::infer::scheduler::{Request, RequestQueue, SchedOptions,
                             Scheduler};
use elsa::infer::{Backend, BatchOptions, Engine};
use elsa::sparse::{dense_matvec_batch, dense_plan, nm_project,
                   par_matvec_batch_tiled, pool_matvec_batch_tiled,
                   random_sparse_weight, tile, Csr, KernelPath, Macko,
                   NmSparse, SpmmScratch, TilePlan};
use elsa::tensor::Matrix;
use elsa::util::rng::Rng;

fn batch_input(b: usize, din: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..b * din).map(|_| rng.normal()).collect()
}

#[test]
fn tiled_matches_untiled_bit_exact_all_formats() {
    // ragged-ish dims so the default (byte-budget) plans end ragged too
    let (din, dout) = (100, 72);
    for &sp in &[0.5f64, 0.9] {
        let w = random_sparse_weight(din, dout, sp, 7);
        let nw = nm_project(&w, 2, 4);
        let csr = Csr::from_weight(&w);
        let mck = Macko::from_weight(&w);
        let nm = NmSparse::<2, 4>::from_weight(&nw).unwrap();
        let dplan = dense_plan(&w);
        let mut su = SpmmScratch::default();
        let mut st = SpmmScratch::default();
        for &b in &[1usize, 3, 8] {
            let x = batch_input(b, din, 40 + b as u64);
            let mut want = vec![0.0f32; b * dout];
            let mut got = vec![0.0f32; b * dout];
            for path in [KernelPath::Scalar, KernelPath::Unrolled] {
                csr.matvec_batch_into(&x, &mut want, b, &mut su);
                csr.matvec_batch_tiled_into(&x, &mut got, b, &mut st,
                                            path);
                assert_eq!(got, want, "csr sp={sp} b={b} {path:?}");

                mck.matvec_batch_into(&x, &mut want, b, &mut su);
                mck.matvec_batch_tiled_into(&x, &mut got, b, &mut st,
                                            path);
                assert_eq!(got, want, "macko sp={sp} b={b} {path:?}");

                nm.matvec_batch_into(&x, &mut want, b, &mut su);
                nm.matvec_batch_tiled_into(&x, &mut got, b, &mut st,
                                           path);
                assert_eq!(got, want, "nm24 sp={sp} b={b} {path:?}");

                dense_matvec_batch(&w, &x, &mut want, b);
                tile::matvec_batch_tiled(&w, &dplan, &x, &mut got, b,
                                         &mut st, path);
                assert_eq!(got, want, "dense sp={sp} b={b} {path:?}");
            }
        }
    }
}

#[test]
fn ragged_tile_boundaries_bit_exact() {
    // 45 output rows: tile_rows 7 leaves a ragged 3-row tail, 1 is the
    // degenerate row-per-tile plan, 64 collapses to a single tile
    let (din, dout, b) = (64, 45, 5);
    let w = random_sparse_weight(din, dout, 0.8, 13);
    let csr = Csr::from_weight(&w);
    let mck = Macko::from_weight(&w);
    let x = batch_input(b, din, 99);
    let mut su = SpmmScratch::default();
    let mut st = SpmmScratch::default();
    let mut want = vec![0.0f32; b * dout];
    let mut got = vec![0.0f32; b * dout];
    for &tile_rows in &[7usize, 1, 64] {
        let plan = TilePlan::fixed(dout, tile_rows);
        assert_eq!(plan.tiles.last().unwrap().row1, dout);

        csr.matvec_batch_into(&x, &mut want, b, &mut su);
        tile::matvec_batch_tiled(&csr, &plan, &x, &mut got, b, &mut st,
                                 KernelPath::Unrolled);
        assert_eq!(got, want, "csr tile_rows={tile_rows}");

        mck.matvec_batch_into(&x, &mut want, b, &mut su);
        tile::matvec_batch_tiled(&mck, &plan, &x, &mut got, b, &mut st,
                                 KernelPath::Unrolled);
        assert_eq!(got, want, "macko tile_rows={tile_rows}");
    }
}

#[test]
fn all_zero_rows_bit_exact_and_zero() {
    // zero out a band of output columns (rows of W^T) spanning tile
    // boundaries, plus the fully-zero matrix
    let (din, dout, b) = (48, 40, 4);
    let mut w = random_sparse_weight(din, dout, 0.6, 21);
    for r in 0..din {
        for c in 10..25 {
            *w.at_mut(r, c) = 0.0;
        }
    }
    let x = batch_input(b, din, 5);
    let mut su = SpmmScratch::default();
    let mut st = SpmmScratch::default();
    let mut want = vec![0.0f32; b * dout];
    let mut got = vec![7.0f32; b * dout];
    let csr = Csr::from_weight(&w);
    csr.matvec_batch_into(&x, &mut want, b, &mut su);
    tile::matvec_batch_tiled(&csr, &TilePlan::fixed(dout, 6), &x,
                             &mut got, b, &mut st,
                             KernelPath::Unrolled);
    assert_eq!(got, want);
    for bi in 0..b {
        for c in 10..25 {
            assert_eq!(got[bi * dout + c], 0.0, "zero row must stay 0");
        }
    }

    let z = Matrix::zeros(din, dout);
    let mck = Macko::from_weight(&z);
    let mut got = vec![7.0f32; b * dout];
    mck.matvec_batch_tiled_into(&x, &mut got, b, &mut st,
                                KernelPath::Unrolled);
    assert!(got.iter().all(|&v| v == 0.0));
}

#[test]
fn construction_plans_cover_all_rows() {
    let w = random_sparse_weight(130, 97, 0.9, 3);
    for plan in [&Csr::from_weight(&w).plan, &Macko::from_weight(&w).plan,
                 &dense_plan(&w)] {
        assert_eq!(plan.n_rows, 97);
        assert_eq!(plan.tiles[0].row0, 0);
        assert_eq!(plan.tiles.last().unwrap().row1, 97);
        for pair in plan.tiles.windows(2) {
            assert_eq!(pair[0].row1, pair[1].row0);
        }
    }
}

#[test]
fn retile_covers_all_rows_and_stays_bit_exact() {
    // the shard-granularity knob: any explicit budget/row-cap must
    // still cover every row contiguously and cannot change a bit
    let (din, dout, b) = (80, 56, 4);
    let w = random_sparse_weight(din, dout, 0.8, 37);
    let x = batch_input(b, din, 3);
    let mut su = SpmmScratch::default();
    let mut st = SpmmScratch::default();
    let mut want = vec![0.0f32; b * dout];
    let mut got = vec![0.0f32; b * dout];
    let mut csr = Csr::from_weight(&w);
    let mut mck = Macko::from_weight(&w);
    csr.matvec_batch_into(&x, &mut want, b, &mut su);
    for &(budget, cap) in &[(64usize, 8usize), (1, 1), (1 << 20, 512)] {
        csr.retile(budget, cap);
        assert_eq!(csr.plan.tiles[0].row0, 0);
        assert_eq!(csr.plan.tiles.last().unwrap().row1, dout);
        csr.matvec_batch_tiled_into(&x, &mut got, b, &mut st,
                                    KernelPath::Unrolled);
        assert_eq!(got, want, "csr retile({budget}, {cap})");

        mck.retile(budget, cap);
        mck.matvec_batch_into(&x, &mut want, b, &mut su);
        mck.matvec_batch_tiled_into(&x, &mut got, b, &mut st,
                                    KernelPath::Unrolled);
        assert_eq!(got, want, "macko retile({budget}, {cap})");
        csr.matvec_batch_into(&x, &mut want, b, &mut su);
    }
}

#[test]
fn sharded_tiled_matches_serial_any_thread_count() {
    let (din, dout, b) = (96, 88, 6);
    let w = random_sparse_weight(din, dout, 0.85, 31);
    let csr = Csr::from_weight(&w);
    let mck = Macko::from_weight(&w);
    // a fine-grained plan so every thread count gets real shards
    let plan = TilePlan::fixed(dout, 5);
    let x = batch_input(b, din, 17);
    let mut su = SpmmScratch::default();
    let mut st = SpmmScratch::default();
    let mut want = vec![0.0f32; b * dout];
    let mut got = vec![0.0f32; b * dout];
    for &threads in &[1usize, 2, 5, 64] {
        csr.matvec_batch_into(&x, &mut want, b, &mut su);
        par_matvec_batch_tiled(&csr, &plan, &x, &mut got, b, threads,
                               &mut st, KernelPath::Unrolled);
        assert_eq!(got, want, "csr threads={threads}");

        mck.matvec_batch_into(&x, &mut want, b, &mut su);
        par_matvec_batch_tiled(&mck, &plan, &x, &mut got, b, threads,
                               &mut st, KernelPath::Unrolled);
        assert_eq!(got, want, "macko threads={threads}");
    }
}

#[test]
fn persistent_pool_matches_serial_across_formats_and_batches() {
    // the engine's exact usage shape: ONE pool dispatched for many
    // different plans, formats and batch sizes, steady-state, with
    // bit-identical results every time
    let (din, dout) = (96, 88);
    let w = random_sparse_weight(din, dout, 0.85, 31);
    let csr = Csr::from_weight(&w);
    let mck = Macko::from_weight(&w);
    let plan = TilePlan::fixed(dout, 5);
    let dplan = dense_plan(&w);
    let mut su = SpmmScratch::default();
    let mut st = SpmmScratch::default();
    for &width in &[2usize, 5] {
        let pool = WorkerPool::new(width);
        for round in 0..3u64 {
            for &b in &[1usize, 4, 6] {
                let x = batch_input(b, din, 17 + round + b as u64);
                let mut want = vec![0.0f32; b * dout];
                let mut got = vec![0.0f32; b * dout];

                csr.matvec_batch_into(&x, &mut want, b, &mut su);
                pool_matvec_batch_tiled(&csr, &plan, &x, &mut got, b,
                                        &pool, &mut st,
                                        KernelPath::Unrolled);
                assert_eq!(got, want,
                           "csr width={width} b={b} round={round}");

                mck.matvec_batch_into(&x, &mut want, b, &mut su);
                pool_matvec_batch_tiled(&mck, &plan, &x, &mut got, b,
                                        &pool, &mut st,
                                        KernelPath::Unrolled);
                assert_eq!(got, want,
                           "macko width={width} b={b} round={round}");

                dense_matvec_batch(&w, &x, &mut want, b);
                pool_matvec_batch_tiled(&w, &dplan, &x, &mut got, b,
                                        &pool, &mut st,
                                        KernelPath::Unrolled);
                assert_eq!(got, want,
                           "dense width={width} b={b} round={round}");
            }
        }
        let ps = pool.stats();
        assert!(ps.runs > 0, "multi-tile plans must dispatch the pool");
    }
}

#[test]
fn pooled_head_gemm_matches_serial_across_widths_and_batches() {
    // the dense head projection rides the same persistent pool as the
    // layer linears when --shard-workers > 1: one pool, many dispatch
    // shapes, bit-identical to the serial t_matmat every time
    let (d, vocab) = (48, 130);
    let mut rng = Rng::new(77);
    let head = Matrix::randn(d, vocab, 1.0, &mut rng);
    for &width in &[2usize, 5] {
        let pool = WorkerPool::new(width);
        for round in 0..3u64 {
            for &b in &[1usize, 3, 8] {
                let x = batch_input(b, d, 100 + round + b as u64);
                let mut want = vec![0.0f32; b * vocab];
                let mut got = vec![5.0f32; b * vocab];
                head.t_matmat(&x, &mut want, b);
                elsa::sparse::pool_t_matmat(&head, &x, &mut got, b,
                                            &pool);
                assert_eq!(got, want,
                           "width={width} b={b} round={round}");
            }
        }
    }
}

#[test]
fn kernel_paths_bit_identical_across_formats() {
    // the PR 8 contract stated directly: for every format, batch size
    // and traversal (tiled / scoped threads / persistent pool), the
    // unrolled kernels produce the same bits as the scalar reference
    let (din, dout) = (96, 61);
    let w = random_sparse_weight(din, dout, 0.7, 51);
    let nw = nm_project(&w, 2, 4);
    let csr = Csr::from_weight(&w);
    let mck = Macko::from_weight(&w);
    let nm = NmSparse::<2, 4>::from_weight(&nw).unwrap();
    let plan = TilePlan::fixed(dout, 5);
    let dplan = dense_plan(&w);
    let pool = WorkerPool::new(3);
    let mut st = SpmmScratch::default();
    for &b in &[1usize, 2, 4, 7, 8] {
        let x = batch_input(b, din, 400 + b as u64);
        let mut scalar = vec![0.0f32; b * dout];
        let mut unrolled = vec![0.0f32; b * dout];
        let run = |y: &mut [f32], path: KernelPath,
                   st: &mut SpmmScratch| {
            tile::matvec_batch_tiled(&csr, &plan, &x, y, b, st, path);
            let mut t = vec![0.0f32; b * dout];
            tile::matvec_batch_tiled(&mck, &plan, &x, &mut t, b, st,
                                     path);
            y.iter_mut().zip(&t).for_each(|(a, v)| *a += v);
            tile::matvec_batch_tiled(&nm, &nm.plan, &x, &mut t, b, st,
                                     path);
            y.iter_mut().zip(&t).for_each(|(a, v)| *a += v);
            tile::matvec_batch_tiled(&w, &dplan, &x, &mut t, b, st,
                                     path);
            y.iter_mut().zip(&t).for_each(|(a, v)| *a += v);
            par_matvec_batch_tiled(&csr, &plan, &x, &mut t, b, 3, st,
                                   path);
            y.iter_mut().zip(&t).for_each(|(a, v)| *a += v);
            pool_matvec_batch_tiled(&nm, &nm.plan, &x, &mut t, b,
                                    &pool, st, path);
            y.iter_mut().zip(&t).for_each(|(a, v)| *a += v);
        };
        run(&mut scalar, KernelPath::Scalar, &mut st);
        run(&mut unrolled, KernelPath::Unrolled, &mut st);
        assert_eq!(scalar, unrolled, "b={b}");
    }
}

#[test]
fn nm_rides_pool_and_scoped_threads_bit_exact() {
    // N:M through the same shard machinery as every other format:
    // scoped threads and the persistent pool must replay the untiled
    // scalar reference bit-for-bit, both kernel paths
    let (din, dout) = (104, 66);
    let nw = nm_project(&random_sparse_weight(din, dout, 0.4, 61), 2, 4);
    let nm = NmSparse::<2, 4>::from_weight(&nw).unwrap();
    let plan = TilePlan::fixed(dout, 7);
    let pool = WorkerPool::new(4);
    let mut su = SpmmScratch::default();
    let mut st = SpmmScratch::default();
    for &b in &[1usize, 3, 8] {
        let x = batch_input(b, din, 700 + b as u64);
        let mut want = vec![0.0f32; b * dout];
        let mut got = vec![0.0f32; b * dout];
        nm.matvec_batch_into(&x, &mut want, b, &mut su);
        for path in [KernelPath::Scalar, KernelPath::Unrolled] {
            for &threads in &[1usize, 2, 5] {
                par_matvec_batch_tiled(&nm, &plan, &x, &mut got, b,
                                       threads, &mut st, path);
                assert_eq!(got, want,
                           "par b={b} threads={threads} {path:?}");
            }
            pool_matvec_batch_tiled(&nm, &plan, &x, &mut got, b, &pool,
                                    &mut st, path);
            assert_eq!(got, want, "pool b={b} {path:?}");
        }
    }
}

#[test]
fn engine_streams_identical_tiled_vs_untiled() {
    let prompts: Vec<Vec<u32>> =
        vec![vec![1, 2, 3], vec![4, 5], vec![6, 7, 8, 9], vec![10]];
    for backend in [Backend::Dense, Backend::Csr, Backend::Macko] {
        let (mut engine, _) = engine(backend);
        assert!(engine.tiled, "tiling must be the default");
        for temp in [0.0f32, 0.9] {
            let opts = BatchOptions {
                n_new: 5, temperature: temp, seed: 3,
                ..BatchOptions::default()
            };
            engine.tiled = true;
            let (tiled, _) = engine.generate_batch(&prompts, &opts);
            engine.tiled = false;
            let (untiled, _) = engine.generate_batch(&prompts, &opts);
            assert_eq!(tiled, untiled,
                       "{backend:?} temp={temp}: tiling changed tokens");
            // and both still reproduce the single-sequence engine
            for (s, prompt) in prompts.iter().enumerate() {
                let (want, _) =
                    engine.generate(prompt, 5, temp, 3 + s as u64);
                assert_eq!(tiled[s], want,
                           "{backend:?} temp={temp} slot {s}");
            }
        }
    }
}

#[test]
fn scheduler_streams_unchanged_with_tiling_on_vs_off() {
    // end-to-end continuous batching: staggered arrivals, ragged
    // budgets, mid-decode admission — the token streams must not
    // depend on the kernel traversal, for any worker or shard-worker
    // count (banded_engine forces multi-tile plans so shard_workers=2
    // really pools)
    let reqs: Vec<Request> = (0..9u64)
        .map(|id| Request {
            id,
            prompt: (0..1 + (id as usize % 4))
                .map(|i| ((id as usize * 5 + i) % TOY_VOCAB) as u32)
                .collect(),
            n_new: 2 + (id as usize % 5),
            seed: 50 + id,
            deadline: None,
        })
        .collect();
    for backend in [Backend::Csr, Backend::Macko] {
        let (mut engine, _) = banded_engine(backend);
        for (threads, shard_workers) in [(1usize, 1usize), (3, 1), (1, 2)] {
            let run = |engine: &Engine| {
                let queue = RequestQueue::with_poisson_arrivals(
                    reqs.clone(), 1.5, 11);
                let sched = Scheduler::new(engine, SchedOptions {
                    max_slots: 3,
                    temperature: 0.8,
                    threads,
                    shard_workers,
                    ..SchedOptions::default()
                });
                let (finished, _) = sched.run(queue);
                finished.into_iter().map(|f| (f.id, f.tokens))
                    .collect::<Vec<_>>()
            };
            engine.tiled = true;
            let tiled = run(&engine);
            engine.tiled = false;
            let untiled = run(&engine);
            assert_eq!(tiled, untiled,
                       "{backend:?} threads={threads} \
                        shard_workers={shard_workers}: tiling changed \
                        scheduler streams");
            for (id, tokens) in &tiled {
                let r = &reqs[*id as usize];
                let (want, _) = engine.generate(&r.prompt, r.n_new, 0.8,
                                                r.seed);
                assert_eq!(tokens, &want,
                           "{backend:?} threads={threads} \
                            shard_workers={shard_workers} req {id}");
            }
        }
    }
}

//! Continuous-batching scheduler edge cases (ISSUE 2 satellite tests):
//! mid-decode admission into just-retired slots, queue drain, empty
//! prompts, deadline expiry, KvPool reuse bit-identity, determinism
//! across thread counts and admission orders, and KvPool counter
//! invariance under pooled row-band decode (`shard_workers`).

mod common;

use common::{engine, ragged_requests, req};
use elsa::infer::scheduler::{serve_static_chunks, RequestQueue,
                             SchedOptions, Scheduler};
use elsa::infer::Backend;

#[test]
fn continuous_admission_matches_per_sequence_generate() {
    for backend in [Backend::Csr, Backend::Macko] {
        let (engine, _) = engine(backend);
        let reqs = ragged_requests(7);
        let queue =
            RequestQueue::with_poisson_arrivals(reqs.clone(), 1.5, 3);
        let sched = Scheduler::new(&engine, SchedOptions {
            max_slots: 2,
            temperature: 0.8,
            ..SchedOptions::default()
        });
        let (finished, stats) = sched.run(queue);
        assert_eq!(finished.len(), reqs.len());
        assert_eq!(stats.expired, 0);
        let mut total = 0usize;
        for f in &finished {
            let r = &reqs[f.id as usize];
            let (want, _) =
                engine.generate(&r.prompt, r.n_new, 0.8, r.seed);
            assert_eq!(f.tokens, want,
                       "{backend:?} req {} diverged under continuous \
                        admission", f.id);
            total += f.generated;
        }
        assert_eq!(stats.tokens_generated, total);
        assert!(stats.p50_latency_ms <= stats.p95_latency_ms);
    }
}

#[test]
fn admission_reuses_just_retired_slot() {
    let (engine, _) = engine(Backend::Macko);
    // one slot, three requests: every retirement must hand its KV
    // buffers to the next admission (two reuses, one fresh allocation)
    let reqs: Vec<_> = (0..3)
        .map(|id| req(id, vec![1 + id as u32, 2, 3], 4))
        .collect();
    let mut queue = RequestQueue::new();
    for r in &reqs {
        queue.push(r.clone());
    }
    let sched = Scheduler::new(&engine, SchedOptions {
        max_slots: 1,
        temperature: 0.8,
        ..SchedOptions::default()
    });
    let (finished, stats) = sched.run(queue);
    assert_eq!(finished.len(), 3);
    assert_eq!(stats.kv_allocated, 1, "one slot allocates one buffer set");
    assert_eq!(stats.kv_reused, 2, "retired buffers must be recycled");
    for f in &finished {
        let r = &reqs[f.id as usize];
        let (want, _) = engine.generate(&r.prompt, r.n_new, 0.8, r.seed);
        assert_eq!(f.tokens, want, "req {}", f.id);
    }
    // requests are serialized through the single slot, so later ones
    // waited in the queue
    assert!(stats.mean_wait_steps > 0.0);
}

#[test]
fn kv_pool_reuse_is_bit_identical_to_fresh_buffers() {
    let (engine, _) = engine(Backend::Csr);
    let reqs = ragged_requests(5);
    let run = |max_slots: usize| {
        let mut queue = RequestQueue::new();
        for r in &reqs {
            queue.push(r.clone());
        }
        let sched = Scheduler::new(&engine, SchedOptions {
            max_slots,
            temperature: 0.8,
            ..SchedOptions::default()
        });
        sched.run(queue)
    };
    // max_slots=1 funnels every request through one recycled buffer
    // set; max_slots=5 gives each request a fresh allocation
    let (reused, st_reused) = run(1);
    let (fresh, st_fresh) = run(5);
    assert!(st_reused.kv_reused >= 4);
    assert_eq!(st_fresh.kv_reused, 0);
    for (a, b) in reused.iter().zip(fresh.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens,
                   "req {}: recycled KV buffers changed the stream",
                   a.id);
    }
}

#[test]
fn kv_pool_counters_unchanged_by_shard_workers() {
    // pooled row-band decode parallelizes *within* a step; it must not
    // perturb slot admission/retirement, so the KvPool counters are
    // invariant in `shard_workers` (and the streams identical)
    let (engine, _) = engine(Backend::Macko);
    let reqs = ragged_requests(6);
    let run = |shard_workers: usize| {
        let queue =
            RequestQueue::with_poisson_arrivals(reqs.clone(), 1.0, 4);
        let sched = Scheduler::new(&engine, SchedOptions {
            max_slots: 2,
            temperature: 0.8,
            shard_workers,
            ..SchedOptions::default()
        });
        sched.run(queue)
    };
    let (f1, s1) = run(1);
    for sw in [2usize, 8] {
        let (fsw, ssw) = run(sw);
        assert_eq!(ssw.kv_allocated, s1.kv_allocated,
                   "shard_workers={sw} changed kv_allocated");
        assert_eq!(ssw.kv_reused, s1.kv_reused,
                   "shard_workers={sw} changed kv_reused");
        assert_eq!(ssw.shard_workers, sw);
        for (a, b) in f1.iter().zip(fsw.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens,
                       "shard_workers={sw} changed req {}'s stream",
                       a.id);
        }
    }
    // the serial run never dispatches the pool
    assert_eq!(s1.shard_workers, 1);
    assert!(s1.shard_busy_seconds.iter().all(|&b| b == 0.0));
}

#[test]
fn empty_queue_drains_immediately() {
    let (engine, _) = engine(Backend::Macko);
    for threads in [1usize, 4] {
        let sched = Scheduler::new(&engine, SchedOptions {
            max_slots: 4,
            temperature: 0.8,
            threads,
            ..SchedOptions::default()
        });
        let (finished, stats) = sched.run(RequestQueue::new());
        assert!(finished.is_empty());
        assert_eq!(stats.tokens_generated, 0);
        assert_eq!(stats.steps, 0);
    }
}

#[test]
fn empty_prompt_request_finishes_with_zero_tokens() {
    let (engine, _) = engine(Backend::Macko);
    let mut queue = RequestQueue::new();
    queue.push(req(0, vec![], 4));
    queue.push(req(1, vec![4, 5, 6], 4));
    let sched = Scheduler::new(&engine, SchedOptions {
        max_slots: 2,
        temperature: 0.8,
        ..SchedOptions::default()
    });
    let (finished, stats) = sched.run(queue);
    assert_eq!(finished.len(), 2);
    assert_eq!(finished[0].tokens, Vec::<u32>::new());
    assert_eq!(finished[0].generated, 0);
    assert!(!finished[0].expired, "empty prompt is served, not expired");
    assert_eq!(finished[1].tokens.len(), 3 + 4);
    assert_eq!(stats.tokens_generated, 4);
}

#[test]
fn deadline_expires_unadmitted_request() {
    let (engine, _) = engine(Backend::Csr);
    let mut queue = RequestQueue::new();
    // req 0 occupies the only slot for ~14 steps; req 1 allows at most
    // 2 steps of queue wait, so it must expire untouched
    queue.push(req(0, vec![1, 2, 3], 10));
    let mut impatient = req(1, vec![7, 8], 10);
    impatient.deadline = Some(2);
    queue.push(impatient);
    let sched = Scheduler::new(&engine, SchedOptions {
        max_slots: 1,
        temperature: 0.8,
        ..SchedOptions::default()
    });
    let (finished, stats) = sched.run(queue);
    assert_eq!(finished.len(), 2);
    assert_eq!(stats.expired, 1);
    assert!(!finished[0].expired);
    assert_eq!(finished[0].generated, 10);
    assert!(finished[1].expired, "deadline 2 must expire behind req 0");
    assert_eq!(finished[1].generated, 0);
    assert!(finished[1].tokens.is_empty());
    // the served request still matches its single-sequence twin
    let (want, _) = engine.generate(&[1, 2, 3], 10, 0.8, 100);
    assert_eq!(finished[0].tokens, want);
}

#[test]
fn thread_count_does_not_change_streams() {
    for backend in [Backend::Csr, Backend::Macko] {
        let (engine, _) = engine(backend);
        let reqs = ragged_requests(8);
        let run = |threads: usize| {
            let queue = RequestQueue::with_poisson_arrivals(
                reqs.clone(), 1.0, 9);
            let sched = Scheduler::new(&engine, SchedOptions {
                max_slots: 4,
                temperature: 0.8,
                threads,
                ..SchedOptions::default()
            });
            sched.run(queue)
        };
        let (f1, s1) = run(1);
        let (f4, s4) = run(4);
        // admission interleavings may differ across thread counts, but
        // every request's token stream is pinned by its own seed
        assert_eq!(s1.tokens_generated, s4.tokens_generated,
                   "{backend:?}");
        for (a, b) in f1.iter().zip(f4.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens,
                       "{backend:?} req {}: thread count changed output",
                       a.id);
        }
        // oversubscription (more threads than slots) must also be safe
        let (f9, _) = run(9);
        for (a, b) in f1.iter().zip(f9.iter()) {
            assert_eq!(a.tokens, b.tokens, "{backend:?} oversubscribed");
        }
    }
}

#[test]
fn prefill_chunk_does_not_change_streams_or_token_accounting() {
    // the scheduler consumes admitted prompts in prefill_chunk-sized
    // headless windows; the window size is pure traversal — streams
    // and generated-token accounting are invariant, only the pass
    // counts change
    let reqs = ragged_requests(7);
    for backend in [Backend::Csr, Backend::Macko] {
        let run = |chunk: usize| {
            let (mut engine, _) = engine(backend);
            engine.prefill_chunk = chunk;
            let queue = RequestQueue::with_poisson_arrivals(
                reqs.clone(), 1.5, 21);
            let sched = Scheduler::new(&engine, SchedOptions {
                max_slots: 3,
                temperature: 0.8,
                ..SchedOptions::default()
            });
            sched.run(queue)
        };
        let (f1, s1) = run(1);
        let expect_prefill: usize =
            reqs.iter().map(|r| r.prompt.len() - 1).sum();
        assert_eq!(s1.prefill_tokens, expect_prefill, "{backend:?}");
        for chunk in [3usize, 16] {
            let (fc, sc) = run(chunk);
            assert_eq!(sc.tokens_generated, s1.tokens_generated,
                       "{backend:?} chunk={chunk}");
            assert_eq!(sc.prefill_tokens, s1.prefill_tokens,
                       "{backend:?} chunk={chunk}: same positions fed \
                        headless, whatever the window");
            assert!(sc.prefill_chunks <= s1.prefill_chunks,
                    "{backend:?} chunk={chunk}: wider windows cannot \
                     need more passes");
            for (a, b) in f1.iter().zip(fc.iter()) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.tokens, b.tokens,
                           "{backend:?} chunk={chunk} changed req {}'s \
                            stream", a.id);
            }
        }
    }
}

#[test]
fn long_then_short_workload_releases_peak_kv_bytes() {
    // ISSUE 6 satellite: the KvPool used to preserve peak capacity
    // forever, so one long-prompt request pinned peak-sized K/V
    // buffers for the engine's lifetime. With the shrink policy, a
    // workload that turns short must trim the parked buffers once the
    // long release ages out of the pool's rolling high-water window.
    let (engine, seq_len) = engine(Backend::Macko);
    let mut queue = RequestQueue::new();
    let long_prompt: Vec<u32> =
        (0..seq_len - 3).map(|i| (i % 7) as u32).collect();
    queue.push(req(0, long_prompt, 2));
    // more short requests than the pool's release window, so the
    // long high-water mark ages out
    for id in 1..=12u64 {
        queue.push(req(id, vec![1 + (id % 5) as u32, 2], 1));
    }
    let sched = Scheduler::new(&engine, SchedOptions {
        max_slots: 1,
        temperature: 0.8,
        ..SchedOptions::default()
    });
    let (finished, stats) = sched.run(queue);
    assert_eq!(finished.len(), 13);
    assert_eq!(stats.expired, 0);
    assert!(stats.kv_pool_bytes > 0,
            "retired buffers should be parked in the pool");
    // peak: the long request's ~(seq_len-1) cached rows per layer;
    // post-shrink the pool may hold at most 2x the short-request
    // watermark (3 rows), far below the pinned-peak bytes that the
    // pre-fix capacity-preserving clear() held forever
    let d = 40; // toy model d_model
    let peak = 2 * (seq_len - 1) * d * 4 * 2; // layers x (k+v) x f32
    assert!(stats.kv_pool_bytes < peak / 2,
            "pool still pins peak bytes: {} (peak ~{peak})",
            stats.kv_pool_bytes);
}

#[test]
fn static_chunks_match_continuous_streams() {
    let (engine, _) = engine(Backend::Macko);
    let reqs = ragged_requests(6);
    let sopts = SchedOptions {
        max_slots: 2,
        temperature: 0.8,
        ..SchedOptions::default()
    };
    let (stat, st) = serve_static_chunks(&engine, &reqs, &sopts);
    assert_eq!(stat.len(), reqs.len());
    assert_eq!(st.expired, 0);
    let queue = RequestQueue::with_poisson_arrivals(reqs.clone(), 1.0, 2);
    let sched = Scheduler::new(&engine, sopts);
    let (cont, _) = sched.run(queue);
    for (a, b) in stat.iter().zip(cont.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens,
                   "admission policy changed req {}'s stream", a.id);
    }
}

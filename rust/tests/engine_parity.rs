//! Cross-backend parity: the same pruned checkpoint served via the
//! Dense, CSR and MACKO backends must produce identical greedy token
//! streams and logits within 1e-3 (ISSUE 1 acceptance test).
//!
//! The checkpoint takes a save/load round trip through the binary
//! checkpoint format first, so the test covers the full deployment
//! path: prune -> checkpoint -> load -> convert -> serve.

mod common;

use std::path::PathBuf;

use elsa::infer::{Backend, Engine};
use elsa::model::checkpoint::Checkpoint;
use elsa::model::{fake_config, synthetic_config, Params};

/// Prune `cfg` at `sparsity` (via the shared fixture builder) and
/// round-trip through a checkpoint file.
fn pruned_via_checkpoint(cfg: &elsa::runtime::ConfigEntry, sparsity: f64,
                         seed: u64, tag: &str) -> Params {
    let pruned = common::pruned_params(cfg, sparsity, seed);

    let path: PathBuf = std::env::temp_dir().join(format!(
        "elsa_parity_{}_{}.bin", std::process::id(), tag));
    let mut ck = Checkpoint::new(&cfg.name);
    ck.insert("params", pruned.flat);
    ck.save(&path).expect("checkpoint save");
    let loaded = Checkpoint::load(&path).expect("checkpoint load");
    let p = Params::new(cfg, loaded.get("params").unwrap().clone());
    let _ = std::fs::remove_file(&path);
    p
}

const BACKENDS: [Backend; 3] =
    [Backend::Dense, Backend::Csr, Backend::Macko];

#[test]
fn greedy_streams_identical_across_backends() {
    let cfg = fake_config();
    let p = pruned_via_checkpoint(&cfg, 0.7, 4, "greedy");
    assert!(p.sparsity() > 0.5, "prune did not take");

    let prompt = [1u32, 5, 3];
    let mut outs = vec![];
    for backend in BACKENDS {
        let engine = Engine::build(&p, backend).unwrap();
        let (out, stats) = engine.generate(&prompt, 4, 0.0, 0);
        assert_eq!(stats.tokens_generated, out.len() - prompt.len());
        outs.push((backend, out));
    }
    for (backend, out) in &outs[1..] {
        assert_eq!(out, &outs[0].1,
                   "{backend:?} diverged from {:?}", outs[0].0);
    }
}

#[test]
fn logits_agree_within_tolerance() {
    // a larger config exercises multi-word MACKO bitmaps (din > 64)
    let cfg = synthetic_config("parity", 72, 2, 4, 96, 64, 16);
    for sparsity in [0.5, 0.9] {
        let p = pruned_via_checkpoint(&cfg, sparsity,
                                      (sparsity * 100.0) as u64,
                                      "logits");
        let tokens = [1u32, 9, 33, 2, 60, 17];
        let reference = Engine::build(&p, Backend::Dense).unwrap()
            .logits_for(&tokens);
        assert_eq!(reference.len(), cfg.vocab);
        for backend in [Backend::Csr, Backend::Macko] {
            let mut engine = Engine::build(&p, backend).unwrap();
            let logits = engine.logits_for(&tokens);
            let mut max_err = 0.0f32;
            for (a, b) in reference.iter().zip(logits.iter()) {
                max_err = max_err.max((a - b).abs());
            }
            assert!(max_err < 1e-3,
                    "{backend:?} sp={sparsity}: max_err={max_err}");
            // the prefill window is a traversal knob: logits must be
            // BIT-identical across chunk sizes, not just within 1e-3
            for chunk in [1usize, 4, 32] {
                engine.prefill_chunk = chunk;
                assert_eq!(engine.logits_for(&tokens), logits,
                           "{backend:?} sp={sparsity} chunk={chunk}");
            }
        }
    }
}

#[test]
fn batched_streams_identical_across_backends() {
    let cfg = synthetic_config("parity_b", 48, 1, 4, 64, 32, 24);
    let p = pruned_via_checkpoint(&cfg, 0.8, 9, "batched");
    let prompts: Vec<Vec<u32>> =
        vec![vec![1, 2, 3], vec![7, 8], vec![4, 5, 6, 9, 10]];
    let opts = elsa::infer::BatchOptions {
        n_new: 6,
        temperature: 0.0,
        seed: 0,
        ..elsa::infer::BatchOptions::default()
    };
    let reference = Engine::build(&p, Backend::Dense).unwrap()
        .generate_batch(&prompts, &opts).0;
    for backend in [Backend::Csr, Backend::Macko] {
        let outs = Engine::build(&p, backend).unwrap()
            .generate_batch(&prompts, &opts).0;
        assert_eq!(outs, reference, "{backend:?} batched diverged");
    }
}

// Fixture: rule 1 (safety) must fire on both sites below.
pub fn first(x: &[f32]) -> f32 {
    unsafe { *x.get_unchecked(0) }
}

pub struct Wrapper(pub *mut f32);
unsafe impl Send for Wrapper {}

// Fixture: rule 3 (alloc) must stay quiet — the only allocation in a
// hot fn is annotated, and allocations in unlisted fns are free.

pub fn hot(n: usize) -> f32 {
    // ALLOC-OK: fixture — warmup buffer allocated once per call for
    // the test, amortized across the whole dispatch.
    let mut acc = vec![0.0f32; n];
    for (i, a) in acc.iter_mut().enumerate() {
        *a = i as f32;
    }
    acc.iter().sum()
}

pub fn cold(n: usize) -> Vec<f32> {
    (0..n).map(|i| i as f32).collect()
}

// Fixture: rule 3 (alloc) must fire once — `hot` is on the hot-fn
// table, `cold` is not.

pub fn hot(n: usize) -> f32 {
    let mut acc = Vec::new();
    for i in 0..n {
        acc.push(i as f32);
    }
    acc.iter().sum()
}

pub fn cold(n: usize) -> Vec<f32> {
    (0..n).map(|i| i as f32).collect()
}

// Fixture: rule 2 (nondet) must stay quiet — every nondeterminism
// source carries an annotation with a reason.

pub fn stamp() -> f64 {
    // TIMING-OK: measurement only; never feeds token selection.
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn verbose() -> bool {
    // DETERMINISM-OK: selects log verbosity only — it cannot change
    // any computed value or token.
    std::env::var("FIXTURE_LOG").is_ok()
}

// Fixture: rule 4 (wildcard) must stay quiet — the enum match is
// exhaustive, and `_` over a *string* scrutinee is legal even though
// the arm bodies name enum variants (the Backend::parse shape).

pub enum KernelPath {
    Scalar,
    Unrolled,
}

pub fn cost(p: KernelPath) -> u32 {
    match p {
        KernelPath::Scalar => 1,
        KernelPath::Unrolled => 2,
    }
}

pub fn parse(s: &str) -> Option<KernelPath> {
    match s {
        "scalar" => Some(KernelPath::Scalar),
        "unrolled" => Some(KernelPath::Unrolled),
        _ => None,
    }
}

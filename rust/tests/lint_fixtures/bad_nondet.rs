// Fixture: rule 2 (nondet) must fire twice when this file is linted
// under a watched-module path such as `sparse/fixture.rs`.

pub fn stamp() -> f64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn pause() {
    std::thread::sleep(std::time::Duration::from_millis(1));
}

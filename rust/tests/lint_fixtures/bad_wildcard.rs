// Fixture: rule 4 (wildcard) must fire once — the match patterns name
// KernelPath variants, so `_ =>` hides future variants.

pub enum KernelPath {
    Scalar,
    Unrolled,
}

pub fn cost(p: KernelPath) -> u32 {
    match p {
        KernelPath::Scalar => 1,
        _ => 2,
    }
}

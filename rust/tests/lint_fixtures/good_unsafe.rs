// Fixture: rule 1 (safety) must stay quiet — every unsafe site is
// justified, and `unsafe` inside comments/strings is not code.
pub fn first(x: &[f32]) -> f32 {
    // SAFETY: callers guarantee x is non-empty.
    unsafe { *x.get_unchecked(0) }
}

pub struct Wrapper(pub *mut f32);
// SAFETY: the pointer is only dereferenced through disjoint per-task
// bands, and the dispatch barrier outlives every borrow.
unsafe impl Send for Wrapper {}
unsafe impl Sync for Wrapper {}

pub fn not_code() -> &'static str {
    // a comment mentioning unsafe { } is not code either
    "unsafe { boom() }"
}

//! Property tests for the convergence theory (paper §4 / Appendix A).
//!
//! Corollary 4.5 / Theorem 4.6 are exercised on a convex quadratic
//! f(x) = 1/2 (x-a)^T D (x-a) where every ADMM subproblem has a closed
//! form, so the tests isolate the *algorithm* (x/z/u updates, the
//! projection, the quantized state cycle) from stochastic-gradient noise:
//!
//!  - monotone decrease of the augmented Lagrangian when λ satisfies the
//!    Cor-4.5 condition λ^{-1}β² - (λ-μ)/2 < 0 (here μ=0 ⇒ λ > √2 β),
//!  - primal residual ‖x-z‖ → 0,
//!  - λ-stationarity of the limit (Def 4.4): the support of x survives
//!    one projected-gradient step with stepsize 1/λ,
//!  - ELSA-L (Thm 4.6): the INT8-quantized state cycle still converges
//!    to feasibility when λ absorbs the quantization noise γ, and the
//!    quantized trajectory tracks the exact one.

use elsa::tensor::select::topk_mask;
use elsa::quant::{Precision, StoredVec};
use elsa::util::rng::Rng;

struct Quad {
    d: Vec<f64>, // diagonal Hessian
    a: Vec<f64>, // minimizer
}

impl Quad {
    fn new(n: usize, seed: u64) -> Quad {
        let mut rng = Rng::new(seed);
        Quad {
            d: (0..n).map(|_| 0.5 + 4.0 * rng.f64()).collect(),
            a: (0..n).map(|_| rng.normal() as f64 * 2.0).collect(),
        }
    }

    fn beta(&self) -> f64 {
        self.d.iter().cloned().fold(0.0, f64::max)
    }

    fn f(&self, x: &[f64]) -> f64 {
        x.iter()
            .zip(self.d.iter().zip(self.a.iter()))
            .map(|(x, (d, a))| 0.5 * d * (x - a) * (x - a))
            .sum()
    }

    fn grad(&self, x: &[f64]) -> Vec<f64> {
        x.iter()
            .zip(self.d.iter().zip(self.a.iter()))
            .map(|(x, (d, a))| d * (x - a))
            .collect()
    }

    /// exact x-update: argmin f(x) + lam/2 ||x - z + u||^2
    fn x_update(&self, z: &[f64], u: &[f64], lam: f64) -> Vec<f64> {
        (0..z.len())
            .map(|i| {
                (self.d[i] * self.a[i] + lam * (z[i] - u[i]))
                    / (self.d[i] + lam)
            })
            .collect()
    }
}

fn project_topk(v: &[f64], k: usize) -> Vec<f64> {
    let scores: Vec<f32> = v.iter().map(|x| (x * x) as f32).collect();
    let mask = topk_mask(&scores, k);
    v.iter()
        .zip(mask.iter())
        .map(|(x, m)| if *m > 0.0 { *x } else { 0.0 })
        .collect()
}

fn aug_lagrangian(q: &Quad, x: &[f64], z: &[f64], u: &[f64], lam: f64)
                  -> f64 {
    // L = f(x) + lam/2 ||x-z+u||^2 - lam/2 ||u||^2 (scaled form, eq. 6)
    let pen: f64 = x.iter().zip(z.iter().zip(u.iter()))
        .map(|(x, (z, u))| (x - z + u) * (x - z + u))
        .sum();
    let uu: f64 = u.iter().map(|u| u * u).sum();
    q.f(x) + 0.5 * lam * (pen - uu)
}

struct AdmmRun {
    x: Vec<f64>,
    z: Vec<f64>,
    residuals: Vec<f64>,
    lagrangian: Vec<f64>,
}

fn run_admm(q: &Quad, k: usize, lam: f64, iters: usize,
            quant: Option<Precision>) -> AdmmRun {
    let n = q.d.len();
    let mut z = project_topk(&q.a, k);
    let mut u = vec![0.0f64; n];
    let mut x = vec![0.0f64; n];
    let mut residuals = vec![];
    let mut lagrangian = vec![];
    for _ in 0..iters {
        x = q.x_update(&z, &u, lam);
        let xu: Vec<f64> =
            x.iter().zip(u.iter()).map(|(a, b)| a + b).collect();
        z = project_topk(&xu, k);
        for i in 0..n {
            u[i] += x[i] - z[i];
        }
        if let Some(p) = quant {
            // ELSA-L: states live in low precision between iterations
            let zf: Vec<f32> = z.iter().map(|v| *v as f32).collect();
            let uf: Vec<f32> = u.iter().map(|v| *v as f32).collect();
            z = StoredVec::quantize(&zf, p).dequantize()
                .iter().map(|v| *v as f64).collect();
            u = StoredVec::quantize(&uf, p).dequantize()
                .iter().map(|v| *v as f64).collect();
        }
        let res: f64 = x.iter().zip(z.iter())
            .map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        residuals.push(res);
        lagrangian.push(aug_lagrangian(q, &x, &z, &u, lam));
    }
    AdmmRun { x, z, residuals, lagrangian }
}

const N: usize = 64;
const K: usize = 12;

#[test]
fn lagrangian_decreases_under_cor45_condition() {
    let q = Quad::new(N, 0);
    // Cor 4.5 (mu = 0): lambda^{-1} beta^2 - lambda/2 < 0 <=> lam > √2 β
    let lam = 1.5 * q.beta() * std::f64::consts::SQRT_2;
    let run = run_admm(&q, K, lam, 200, None);
    // monotone non-increase after the first few iterations
    let mut violations = 0;
    for w in run.lagrangian.windows(2).skip(5) {
        if w[1] > w[0] + 1e-9 {
            violations += 1;
        }
    }
    assert_eq!(violations, 0,
               "augmented Lagrangian increased {violations} times");
}

#[test]
fn primal_residual_vanishes() {
    let q = Quad::new(N, 1);
    let lam = 2.0 * q.beta();
    let run = run_admm(&q, K, lam, 400, None);
    let last = *run.residuals.last().unwrap();
    assert!(last < 1e-6, "residual did not vanish: {last}");
    // and the residual sequence trends down by orders of magnitude
    assert!(last < run.residuals[0] * 1e-4);
}

#[test]
fn limit_point_is_lambda_stationary() {
    let q = Quad::new(N, 2);
    let lam = 2.0 * q.beta();
    let run = run_admm(&q, K, lam, 500, None);
    // Def 4.4: x̄ ∈ argmin_{S} ‖x - (x̄ - ∇f(x̄)/λ)‖, i.e. projecting the
    // gradient step onto S must recover x̄'s support and values.
    let g = q.grad(&run.x);
    let step: Vec<f64> = run.x.iter().zip(g.iter())
        .map(|(x, g)| x - g / lam).collect();
    let proj = project_topk(&step, K);
    let supp = |v: &[f64]| -> Vec<usize> {
        v.iter().enumerate().filter(|(_, x)| **x != 0.0)
            .map(|(i, _)| i).collect()
    };
    assert_eq!(supp(&proj), supp(&run.z), "support not stationary");
    // and x is the constrained optimum on that support: gradient is zero
    // there (for a separable quadratic, x_i = a_i on the support)
    for i in supp(&run.z) {
        assert!((run.x[i] - q.a[i]).abs() < 1e-6,
                "non-optimal on support at {i}");
    }
}

#[test]
fn elsa_l_converges_with_quantized_states() {
    // Thm 4.6: with λ large enough relative to the quantization
    // contraction γ, the low-precision cycle still reaches feasibility.
    let q = Quad::new(N, 3);
    let lam = 4.0 * q.beta();
    let exact = run_admm(&q, K, lam, 300, None);
    let quant = run_admm(&q, K, lam, 300, Some(Precision::Int8Block(64)));
    let res_q = *quant.residuals.last().unwrap();
    // residual shrinks to the quantization noise floor
    assert!(res_q < quant.residuals[0] * 1e-2,
            "quantized run did not contract: {res_q}");
    // the quantized solution tracks the exact one on most coordinates
    let agree = exact.z.iter().zip(quant.z.iter())
        .filter(|(a, b)| (a.abs() > 1e-12) == (b.abs() > 1e-12))
        .count();
    assert!(agree as f64 >= 0.9 * N as f64,
            "supports diverged: {agree}/{N}");
}

#[test]
fn sparsity_constraint_always_feasible() {
    let q = Quad::new(N, 4);
    let run = run_admm(&q, K, 2.0 * q.beta(), 100, None);
    let nnz = run.z.iter().filter(|x| **x != 0.0).count();
    assert!(nnz <= K);
}

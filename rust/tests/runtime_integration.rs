//! Integration tests across the runtime boundary: the AOT HLO artifacts
//! and the rust-side model must agree numerically.
//!
//! These tests need `make artifacts` to have run; they are skipped (with
//! a loud message) when artifacts/ is absent so `cargo test` stays green
//! in a fresh checkout.

use std::path::{Path, PathBuf};

use elsa::data::Dataset;
use elsa::model::{forward, Params};
use elsa::runtime::{self, Runtime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn manifest_loads_and_is_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    for (name, cfg) in &rt.manifest.configs {
        assert_eq!(&cfg.name, name);
        assert!(cfg.prunable_len() > 0);
        assert!(cfg.prunable_len() < cfg.flat_len);
        let ts = cfg.artifact("train_step").unwrap();
        assert_eq!(ts.args.len(), 11);
        assert_eq!(ts.outputs.len(), 4);
        // prunable mask cardinality matches prunable_len
        let pm = cfg.prunable_mask();
        let ones = pm.iter().filter(|x| **x > 0.0).count();
        assert_eq!(ones, cfg.prunable_len());
    }
}

#[test]
fn rust_forward_matches_hlo_logits() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let cfg = rt.manifest.config("tiny").unwrap().clone();
    let params = Params::init(&cfg, 42);

    let ds = Dataset::generate("synth-c4", cfg.vocab, 10_000, 0, 9);
    let be = cfg.eval_batch;
    let s = cfg.seq_len;
    let tokens: Vec<u32> = ds.train[..be * s].to_vec();
    let tokens_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();

    let exe = rt.executable("tiny", "logits").unwrap();
    let outs = rt
        .execute(&exe, &[
            runtime::lit_f32(&params.flat),
            runtime::lit_i32_2d(&tokens_i32, be, s).unwrap(),
        ])
        .unwrap();
    let hlo_logits = runtime::to_f32(&outs[0]).unwrap(); // (be, s, v)

    // compare a couple of sequences against the rust forward
    for b in [0usize, be - 1] {
        let seq = &tokens[b * s..(b + 1) * s];
        let rust_logits = forward::forward_seq(&params, seq, None).unwrap();
        let mut max_err = 0.0f32;
        for t in 0..s {
            for c in 0..cfg.vocab {
                let h = hlo_logits[(b * s + t) * cfg.vocab + c];
                let r = rust_logits.at(t, c);
                max_err = max_err.max((h - r).abs());
            }
        }
        assert!(max_err < 2e-3,
                "rust forward diverges from HLO: max_err={max_err}");
    }
}

#[test]
fn train_step_decreases_loss_from_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let cfg = rt.manifest.config("tiny").unwrap().clone();
    let d = cfg.flat_len;
    let params = Params::init(&cfg, 0);
    let ds = Dataset::generate("synth-c4", cfg.vocab, 50_000, 0, 1);
    let mut batcher =
        elsa::data::Batcher::new(&ds.train, cfg.batch, cfg.seq_len, 0);

    let exe = rt.executable("tiny", "train_step").unwrap();
    let zeros = vec![0.0f32; d];
    let ones = vec![1.0f32; d];
    let pmask = cfg.prunable_mask();

    let mut p = params.flat;
    let mut m = zeros.clone();
    let mut v = zeros.clone();
    let batch = batcher.next_batch(); // repeated batch: loss must drop fast
    let mut losses = vec![];
    for t in 0..8 {
        let outs = rt
            .execute(&exe, &[
                runtime::lit_f32(&p),
                runtime::lit_f32(&m),
                runtime::lit_f32(&v),
                runtime::lit_f32(&zeros),
                runtime::lit_f32(&zeros),
                runtime::lit_f32(&ones),
                runtime::lit_f32(&pmask),
                runtime::lit_i32_2d(&batch, cfg.batch, cfg.seq_len + 1)
                    .unwrap(),
                runtime::lit_scalar((t + 1) as f32),
                runtime::lit_scalar(3e-3),
                runtime::lit_scalar(0.0),
            ])
            .unwrap();
        p = runtime::to_f32(&outs[0]).unwrap();
        m = runtime::to_f32(&outs[1]).unwrap();
        v = runtime::to_f32(&outs[2]).unwrap();
        losses.push(runtime::to_scalar(&outs[3]).unwrap());
    }
    assert!(losses[7] < losses[0] - 0.3, "{losses:?}");
}

#[test]
fn quant_roundtrip_artifact_matches_rust_codec() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let Some(q) = rt.manifest.quant_demo.clone() else { return };
    let exe = rt.compile_file(&q.file).unwrap();
    let mut rng = elsa::util::rng::Rng::new(5);
    let x: Vec<f32> = (0..q.n).map(|_| rng.normal() * 4.0).collect();
    let result = exe
        .execute::<xla::Literal>(&[runtime::lit_f32(&x)])
        .unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let outs = result.to_tuple().unwrap();
    let remat = runtime::to_f32(&outs[0]).unwrap();
    // rust-side absmax int8 reference
    let absmax = x.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
    let scale = absmax / q.vmax;
    for (i, (&r, &orig)) in remat.iter().zip(x.iter()).enumerate() {
        let expect = (orig / scale).round().clamp(-q.vmax, q.vmax) * scale;
        assert!((r - expect).abs() < 1e-5, "idx {i}: {r} vs {expect}");
    }
}

//! Prune → quantize → serve pipeline (ISSUE 9): worker-count
//! bit-identity for every one-shot method, exact sparsity budgets on
//! non-1/32-aligned targets, and end-to-end stream identity through
//! `Engine::build_quant` + the continuous-batching scheduler
//! regardless of how many workers pruned the checkpoint.
//!
//! Everything runs through [`elsa::pruners::prune_oneshot_core`] — the
//! Runtime-free half of `elsa prune` — on the shared toy serving model
//! from `common`.

mod common;

use std::collections::BTreeMap;

use common::{ragged_requests, toy_cfg, TOY_VOCAB};
use elsa::infer::scheduler::{RequestQueue, SchedOptions, Scheduler};
use elsa::infer::{Backend, Engine};
use elsa::model::Params;
use elsa::pruners::{prune_oneshot_core, AllocMode, PruneOptions};
use elsa::runtime::ConfigEntry;
use elsa::sparse::QuantMode;
use elsa::util::rng::Rng;

/// Every pool-parallelized one-shot method.
const METHODS: [&str; 5] =
    ["magnitude", "wanda", "sparsegpt", "l-admm", "alps"];

fn toy_train(n: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(TOY_VOCAB) as u32).collect()
}

fn opts(workers: usize) -> PruneOptions {
    PruneOptions { workers, ..PruneOptions::default() }
}

fn per_column_kept(cfg: &ConfigEntry, flat: &[f32])
                   -> BTreeMap<String, Vec<usize>> {
    let p = Params::new(cfg, flat.to_vec());
    cfg.segments
        .iter()
        .filter(|s| s.prunable)
        .map(|seg| {
            let w = p.matrix(&seg.name).unwrap();
            let kept = (0..w.cols)
                .map(|c| {
                    (0..w.rows).filter(|&r| w.at(r, c) != 0.0).count()
                })
                .collect();
            (seg.name.clone(), kept)
        })
        .collect()
}

#[test]
fn every_method_is_bit_identical_across_worker_counts() {
    let cfg = toy_cfg();
    let dense = Params::init(&cfg, 3).flat;
    let train = toy_train(4096, 11);
    for method in METHODS {
        let serial = prune_oneshot_core(&cfg, method, &dense, &train,
                                        0.6, &opts(1))
            .unwrap();
        for workers in [2, 8] {
            let pooled = prune_oneshot_core(&cfg, method, &dense,
                                            &train, 0.6,
                                            &opts(workers))
                .unwrap();
            assert_eq!(serial, pooled,
                       "{method} diverged at --workers {workers}");
        }
    }
}

#[test]
fn allocation_modes_are_bit_identical_across_worker_counts() {
    let cfg = toy_cfg();
    let dense = Params::init(&cfg, 3).flat;
    let train = toy_train(4096, 11);
    for alloc in [AllocMode::Owl, AllocMode::Global] {
        let base = PruneOptions { workers: 1, alloc,
                                  ..PruneOptions::default() };
        let serial = prune_oneshot_core(&cfg, "wanda", &dense, &train,
                                        0.6, &base)
            .unwrap();
        let pooled = prune_oneshot_core(
            &cfg, "wanda", &dense, &train, 0.6,
            &PruneOptions { workers: 4, alloc,
                            ..PruneOptions::default() })
            .unwrap();
        assert_eq!(serial, pooled, "alloc {alloc:?} diverged");
    }
}

#[test]
fn sparsegpt_budget_is_exact_per_column_on_unaligned_targets() {
    let cfg = toy_cfg();
    let dense = Params::init(&cfg, 3).flat;
    let train = toy_train(4096, 11);
    // 0.55 and 0.9 are NOT multiples of 1/32: the pre-ISSUE-9
    // per-block rounding achieved 0.5625 / 0.90625 instead
    for sp in [0.55f64, 0.9] {
        let pruned = prune_oneshot_core(&cfg, "sparsegpt", &dense,
                                        &train, sp, &opts(2))
            .unwrap();
        for (name, kept) in per_column_kept(&cfg, &pruned) {
            let seg = cfg.segment(&name).unwrap();
            let din = seg.shape[0];
            let expect = ((1.0 - sp) * din as f64).round() as usize;
            for (c, k) in kept.iter().enumerate() {
                assert_eq!(*k, expect, "{name} col {c} sp={sp}");
            }
        }
    }
}

#[test]
fn wanda_and_magnitude_budgets_are_exact_on_unaligned_targets() {
    let cfg = toy_cfg();
    let dense = Params::init(&cfg, 3).flat;
    let train = toy_train(4096, 11);
    let sp = 0.55f64;
    // wanda: per-column keep quota
    let wanda = prune_oneshot_core(&cfg, "wanda", &dense, &train, sp,
                                   &opts(2))
        .unwrap();
    for (name, kept) in per_column_kept(&cfg, &wanda) {
        let seg = cfg.segment(&name).unwrap();
        let expect =
            ((1.0 - sp) * seg.shape[0] as f64).round() as usize;
        for (c, k) in kept.iter().enumerate() {
            assert_eq!(*k, expect, "{name} col {c}");
        }
    }
    // magnitude: whole-layer keep quota
    let mag = prune_oneshot_core(&cfg, "magnitude", &dense, &train, sp,
                                 &opts(2))
        .unwrap();
    let p = Params::new(&cfg, mag);
    for seg in cfg.segments.iter().filter(|s| s.prunable) {
        let w = p.matrix(&seg.name).unwrap();
        let expect = ((1.0 - sp) * seg.len() as f64).round() as usize;
        assert_eq!(w.nnz(), expect, "{}", seg.name);
    }
}

/// The full producer→consumer path: prune with N workers, quantize at
/// engine build, serve through the continuous-batching scheduler —
/// token streams must be bit-identical to the serially-pruned run.
#[test]
fn prune_quantize_serve_streams_are_worker_count_invariant() {
    let cfg = toy_cfg();
    let dense = Params::init(&cfg, 3).flat;
    let train = toy_train(4096, 11);

    let serve = |flat: &[f32]| -> BTreeMap<u64, Vec<u32>> {
        let p = Params::new(&cfg, flat.to_vec());
        let engine = Engine::build_quant(&p, Backend::Macko,
                                         QuantMode::Int8)
            .expect("quant engine");
        let mut queue = RequestQueue::new();
        for r in ragged_requests(6) {
            queue.push(r);
        }
        let sched = Scheduler::new(&engine, SchedOptions {
            max_slots: 3,
            threads: 2,
            temperature: 0.8,
            ..SchedOptions::default()
        });
        let (finished, _) = sched.run(queue);
        finished.into_iter().map(|f| (f.id, f.tokens)).collect()
    };

    let base = prune_oneshot_core(&cfg, "sparsegpt", &dense, &train,
                                  0.75, &opts(1))
        .unwrap();
    let base_streams = serve(&base);
    assert_eq!(base_streams.len(), 6);
    for workers in [2, 8] {
        let pruned = prune_oneshot_core(&cfg, "sparsegpt", &dense,
                                        &train, 0.75, &opts(workers))
            .unwrap();
        assert_eq!(base, pruned, "checkpoint diverged at {workers}");
        let streams = serve(&pruned);
        assert_eq!(base_streams, streams,
                   "served streams diverged at --workers {workers}");
    }
}

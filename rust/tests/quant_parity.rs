//! Tolerance-based parity for quantized decode (ISSUE 7 tentpole):
//! the repo's first test regime where the comparison against the f32
//! reference is *bounded*, not bit-exact. int8/int4 payloads cannot
//! reproduce f32 logits bitwise — the quantization error is real and
//! analytically bounded (per weight: block absmax / 254 for int8,
//! / 14 for int4; see `sparse/quantized.rs`) — so this suite pins:
//!
//! 1. **Logits tolerance**: quantized `logits_for` stays within a
//!    scale-relative envelope of the f32 engine's logits on the toy
//!    serving model, int8 strictly tighter than int4.
//! 2. **Margin-guarded greedy agreement**: wherever the f32 top-2
//!    logit margin exceeds twice the measured max-abs logit error,
//!    the quantized argmax MUST equal the f32 argmax (that much is
//!    mathematics); the test additionally requires that enough
//!    teacher-forced steps actually clear the margin bar — the
//!    end-to-end statement that int8 error is small relative to the
//!    model's decision margins.
//! 3. **Within-mode bit-exactness**: a quantized engine is just
//!    another engine — scheduler streams reproduce its own
//!    single-sequence `generate` bit-for-bit across threads ×
//!    shard-workers × tiling, and `CsrQ`/`MackoQ` (identical codes
//!    and scales by construction) produce bitwise-identical streams.
//! 4. **Memory accounting**: `mem_bytes` of a quantized engine is
//!    strictly below its f32 counterpart, int4 below int8, and the
//!    serving stats (`GenStats`/`SchedStats`) self-describe the mode.

mod common;

use common::{engine, nm_engine, nm_params, quant_engine,
             ragged_requests, toy_cfg, TOY_VOCAB};
use elsa::infer::scheduler::{RequestQueue, SchedOptions, Scheduler};
use elsa::infer::{Backend, Engine};
use elsa::sparse::{NmMode, QuantMode};

const SPARSE_BACKENDS: [Backend; 2] = [Backend::Csr, Backend::Macko];

fn toy_prompt(len: usize, salt: usize) -> Vec<u32> {
    (0..len)
        .map(|i| ((salt * 13 + i * 7) % TOY_VOCAB) as u32)
        .collect()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

/// Largest and second-largest values of `xs` (the argmax margin).
fn top2(xs: &[f32]) -> (f32, f32) {
    let (mut a, mut b) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
    for &x in xs {
        if x > a {
            b = a;
            a = x;
        } else if x > b {
            b = x;
        }
    }
    (a, b)
}

#[test]
fn quantized_logits_stay_within_scale_relative_envelope() {
    // the tolerance regime: error is measured against the dynamic
    // range of the f32 logits, not an absolute cap, so the bound
    // survives re-seeding the toy model. int8 must sit well inside
    // the int4 envelope — if it doesn't, the scale machinery is
    // broken even though both "pass" their own caps.
    for backend in SPARSE_BACKENDS {
        let (f32_engine, _) = engine(backend);
        let (int8, _) = quant_engine(backend, QuantMode::Int8);
        let (int4, _) = quant_engine(backend, QuantMode::Int4);
        let mut worst8 = 0.0f32;
        let mut worst4 = 0.0f32;
        let mut scale = 0.0f32;
        for salt in 0..6 {
            let prompt = toy_prompt(1 + salt % 9, salt);
            let lf = f32_engine.logits_for(&prompt);
            scale = scale.max(
                lf.iter().fold(0.0f32, |m, &v| m.max(v.abs())));
            worst8 = worst8
                .max(max_abs_diff(&lf, &int8.logits_for(&prompt)));
            worst4 = worst4
                .max(max_abs_diff(&lf, &int4.logits_for(&prompt)));
        }
        assert!(scale > 0.0, "{backend:?}: degenerate f32 logits");
        // int8 quantizes per 64-value block at ~0.4% per weight; two
        // transformer layers + head leave ample room inside 25% of
        // the logit range. int4 is ~14x coarser per weight.
        assert!(worst8 <= 0.25 * scale,
                "{backend:?}: int8 logit error {worst8} vs scale \
                 {scale}");
        assert!(worst4 <= 1.5 * scale,
                "{backend:?}: int4 logit error {worst4} vs scale \
                 {scale}");
        assert!(worst8 < worst4,
                "{backend:?}: int8 ({worst8}) must beat int4 \
                 ({worst4})");
        assert!(worst8 > 0.0,
                "{backend:?}: int8 logits bitwise-equal f32 — the \
                 quantized path is not actually being exercised");
    }
}

#[test]
fn greedy_agreement_where_the_margin_clears_the_error() {
    // teacher-force along the f32 greedy path and compare argmaxes
    // step by step. When the f32 top-2 margin exceeds 2x the measured
    // max-abs logit error the argmaxes cannot differ; the test's
    // content is the qualifying counts — int8's error must be small
    // relative to real decision margins on most steps.
    let n_new = 8usize;
    for backend in SPARSE_BACKENDS {
        let (f32_engine, _) = engine(backend);
        for (quant, min_qualifying) in
            [(QuantMode::Int8, 0usize), (QuantMode::Int4, 0)]
        {
            let (q, _) = quant_engine(backend, quant);
            let mut steps = 0usize;
            let mut qualifying = 0usize;
            for salt in 0..5 {
                let prompt = toy_prompt(2 + salt % 5, 31 + salt);
                let (stream, _) =
                    f32_engine.generate(&prompt, n_new, 0.0, 7);
                let mut prefix = prompt.clone();
                for &tok in &stream {
                    let lf = f32_engine.logits_for(&prefix);
                    let lq = q.logits_for(&prefix);
                    let diff = max_abs_diff(&lf, &lq);
                    let (best, second) = top2(&lf);
                    steps += 1;
                    if best - second > 2.0 * diff {
                        qualifying += 1;
                        assert_eq!(
                            argmax(&lq), argmax(&lf),
                            "{backend:?} {quant:?}: argmax flipped \
                             under a {:.4} margin with error {diff:.4}",
                            best - second);
                    }
                    prefix.push(tok);
                }
            }
            // int8: at ~0.4%-per-weight error most toy-model steps
            // must clear the margin bar; int4 gets no floor (its
            // qualifying steps are still hard-asserted above).
            let floor = if quant == QuantMode::Int8 {
                steps / 2
            } else {
                min_qualifying
            };
            assert!(qualifying >= floor,
                    "{backend:?} {quant:?}: only {qualifying}/{steps} \
                     teacher-forced steps cleared the margin bar");
        }
    }
}

#[test]
fn quantized_scheduler_streams_match_quantized_generate() {
    // within-mode bit-exactness at the serving layer (the full sweep
    // lives in determinism.rs; this is the direct named check): the
    // scheduler on a quantized engine reproduces that same engine's
    // single-sequence streams bit-for-bit across threads x
    // shard-workers x tiling.
    for backend in SPARSE_BACKENDS {
        for quant in [QuantMode::Int8, QuantMode::Int4] {
            let (mut e, _) = quant_engine(backend, quant);
            e.retile(64, 8); // force real multi-tile plans at toy scale
            for (threads, shard_workers, tiled) in
                [(1usize, 1usize, true), (2, 2, true), (2, 8, false)]
            {
                e.tiled = tiled;
                let reqs = ragged_requests(5);
                let queue = RequestQueue::with_poisson_arrivals(
                    reqs.clone(), 1.0, 21);
                let sched = Scheduler::new(&e, SchedOptions {
                    max_slots: 2,
                    temperature: 0.8,
                    threads,
                    shard_workers,
                    prefix_cache: true,
                    pin_workers: false,
                });
                let (finished, stats) = sched.run(queue);
                assert_eq!(stats.quant_mode, quant.label());
                assert_eq!(stats.weight_mem_bytes, e.mem_bytes());
                for f in &finished {
                    let r = &reqs[f.id as usize];
                    let (want, _) =
                        e.generate(&r.prompt, r.n_new, 0.8, r.seed);
                    assert_eq!(
                        f.tokens, want,
                        "{backend:?} {quant:?} threads={threads} \
                         shard_workers={shard_workers} tiled={tiled}: \
                         req {} diverged within its own mode", f.id);
                }
            }
        }
    }
}

#[test]
fn csrq_and_mackoq_streams_are_bitwise_identical() {
    // both quantized formats collect a row's nonzeros in the same
    // column order and quantize them with the same block machinery,
    // so their codes, scales and accumulation orders coincide — the
    // two engines must agree to the bit, mirroring the f32 Csr/Macko
    // parity the engine suite already pins.
    for quant in [QuantMode::Int8, QuantMode::Int4] {
        let (c, _) = quant_engine(Backend::Csr, quant);
        let (m, _) = quant_engine(Backend::Macko, quant);
        for salt in 0..4 {
            let prompt = toy_prompt(3 + salt, 5 + salt);
            let (a, _) = c.generate(&prompt, 6, 0.8, 42);
            let (b, _) = m.generate(&prompt, 6, 0.8, 42);
            assert_eq!(a, b, "{quant:?} salt={salt}");
            assert_eq!(c.logits_for(&prompt), m.logits_for(&prompt),
                       "{quant:?} salt={salt} logits");
        }
    }
}

#[test]
fn quantized_runs_reproduce_themselves_bitwise() {
    // int8 run N == int8 run M: the within-mode determinism headline,
    // stated directly (the randomized sweep covers the axes).
    for quant in [QuantMode::Int8, QuantMode::Int4] {
        let (e, _) = quant_engine(Backend::Macko, quant);
        let prompt = toy_prompt(4, 9);
        let (a, _) = e.generate(&prompt, 8, 0.9, 3);
        let (b, _) = e.generate(&prompt, 8, 0.9, 3);
        assert_eq!(a, b, "{quant:?}");
    }
}

#[test]
fn engine_memory_shrinks_monotonically_with_precision() {
    // engine-level accounting: the quantized payloads must actually
    // shrink the resident weight bytes (the >= 3x / >= 5x vs dense
    // f32 targets are pinned against the bench-shaped matrices in
    // sparse::quantized's own tests; the toy engine here is tiny and
    // its fixed overheads proportionally larger).
    for backend in SPARSE_BACKENDS {
        let (f, _) = engine(backend);
        let (i8e, _) = quant_engine(backend, QuantMode::Int8);
        let (i4e, _) = quant_engine(backend, QuantMode::Int4);
        assert!(i8e.mem_bytes() < f.mem_bytes(),
                "{backend:?}: int8 {} !< f32 {}", i8e.mem_bytes(),
                f.mem_bytes());
        assert!(i4e.mem_bytes() < i8e.mem_bytes(),
                "{backend:?}: int4 {} !< int8 {}", i4e.mem_bytes(),
                i8e.mem_bytes());
        let (_, stats) = i8e.generate(&toy_prompt(3, 1), 4, 0.0, 0);
        assert_eq!(stats.quant_mode, "int8");
        let (_, f_stats) = f.generate(&toy_prompt(3, 1), 4, 0.0, 0);
        assert_eq!(f_stats.quant_mode, "none");
    }
}

#[test]
fn nm_engine_stats_self_describe_and_shrink_memory() {
    // the N:M counterpart of the quant accounting test: an N:M engine
    // must name its pattern (and its kernel path) in both GenStats and
    // SchedStats, stay quant_mode "none", reproduce its own streams
    // through the scheduler, and spend fewer weight bytes than the f32
    // CSR engine on the *same projected checkpoint* — NmSparse stores
    // 5 B per slot (f32 value + u8 offset) at exactly-N-of-M density
    // where CSR spends 8 B per nonzero.
    for backend in SPARSE_BACKENDS {
        for nm in [NmMode::N2M4, NmMode::N4M8] {
            let (e, _) = nm_engine(backend, nm);
            let (tokens, stats) =
                e.generate(&toy_prompt(3, 1), 4, 0.0, 0);
            assert_eq!(stats.nm_mode, nm.label(),
                       "{backend:?} {nm:?}: GenStats nm_mode");
            assert_eq!(stats.quant_mode, "none",
                       "{backend:?} {nm:?}: N:M is an f32 format");
            assert_eq!(stats.kernel_path, e.kernel_path.label());
            assert!(!tokens.is_empty());

            let csr_f32 =
                Engine::build(&nm_params(&toy_cfg(), nm, 1),
                              Backend::Csr)
                    .expect("f32 engine on projected params");
            assert!(e.mem_bytes() < csr_f32.mem_bytes(),
                    "{backend:?} {nm:?}: nm {} !< f32 csr {}",
                    e.mem_bytes(), csr_f32.mem_bytes());

            let reqs = ragged_requests(4);
            let queue = RequestQueue::with_poisson_arrivals(
                reqs.clone(), 1.0, 13);
            let sched = Scheduler::new(&e, SchedOptions {
                max_slots: 2,
                temperature: 0.8,
                threads: 2,
                shard_workers: 2,
                prefix_cache: true,
                pin_workers: false,
            });
            let (finished, sstats) = sched.run(queue);
            assert_eq!(sstats.nm_mode, nm.label(),
                       "{backend:?} {nm:?}: SchedStats nm_mode");
            assert_eq!(sstats.kernel_path, e.kernel_path.label());
            assert_eq!(sstats.weight_mem_bytes, e.mem_bytes());
            for f in &finished {
                let r = &reqs[f.id as usize];
                let (want, _) =
                    e.generate(&r.prompt, r.n_new, 0.8, r.seed);
                assert_eq!(f.tokens, want,
                           "{backend:?} {nm:?}: req {} diverged \
                            within its own mode", f.id);
            }
        }
    }
}

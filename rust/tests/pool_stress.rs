//! Seeded interleaving stress test for the WorkerPool claim protocol.
//!
//! The pool hands out tasks through one packed `AtomicU64` — the
//! dispatch's task count in the high 32 bits, the claim counter in the
//! low 32 — so a claim's bound check can never mix one dispatch's
//! index with another's count. This suite hammers exactly that word:
//! thousands of back-to-back dispatch epochs on a long-lived pool,
//! with ragged seeded task counts and per-(epoch, task) spin jitter so
//! claims land in shifting interleavings, asserting every task runs
//! exactly once (no double-claim, no lost task).
//!
//! The sanitizer CI jobs run this same suite: under TSan
//! (`RUSTFLAGS=-Zsanitizer=thread`) it probes the claim word's
//! ordering, and under Miri the shrunk constants below keep the
//! interpreter within budget while still crossing the spin-then-park
//! boundary.

use std::sync::atomic::{AtomicU32, Ordering};

use elsa::infer::pool::WorkerPool;
use elsa::util::rng::Rng;

// Miri executes every interleaving under an interpreter ~1000x slower
// than native; fewer, smaller epochs still cover claim/park/reuse.
const EPOCHS: usize = if cfg!(miri) { 8 } else { 1000 };
const MAX_TASKS: usize = if cfg!(miri) { 12 } else { 96 };

/// Deterministic per-(epoch, task) spin so the interleaving shifts
/// from epoch to epoch without any wall-clock or OS-scheduler input.
fn jitter_spins(epoch: usize, task: usize) -> u32 {
    let x = (epoch as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((task as u64).wrapping_mul(0x85EB_CA6B));
    (x % 64) as u32
}

#[test]
fn claim_protocol_never_double_claims_or_drops() {
    let widths: &[usize] = if cfg!(miri) { &[2, 4] } else { &[2, 4, 8] };
    for &lanes in widths {
        let pool = WorkerPool::new(lanes);
        let mut rng = Rng::new(0xC1A1_4000 + lanes as u64);
        for epoch in 0..EPOCHS {
            let n_tasks = 1 + rng.below(MAX_TASKS);
            let hits: Vec<AtomicU32> =
                (0..n_tasks).map(|_| AtomicU32::new(0)).collect();
            pool.run(n_tasks, &|i| {
                for _ in 0..jitter_spins(epoch, i) {
                    std::hint::spin_loop();
                }
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                let n = h.load(Ordering::Relaxed);
                assert_eq!(
                    n, 1,
                    "lanes={lanes} epoch={epoch}: task {i} of \
                     {n_tasks} ran {n} times"
                );
            }
        }
    }
}

#[test]
fn degenerate_dispatches_interleave_with_wide_ones() {
    // empty and single-task dispatches run inline on the caller; make
    // sure alternating them with real dispatches never corrupts the
    // claim word the next wide dispatch reads
    let pool = WorkerPool::new(4);
    let total = AtomicU32::new(0);
    let mut expected = 0u32;
    for epoch in 0..EPOCHS {
        let n_tasks = match epoch % 4 {
            0 => 0,
            1 => 1,
            2 => 7,
            _ => 33,
        };
        pool.run(n_tasks, &|_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        expected += n_tasks as u32;
        assert_eq!(total.load(Ordering::Relaxed), expected,
                   "epoch {epoch}");
    }
}

//! Seeded randomized determinism sweep (ISSUE 4 satellite, extended
//! by ISSUE 5, ISSUE 6 and ISSUE 8): one harness that subsumes the
//! ad-hoc pairwise checks scattered across the older suites. ~70
//! seeded scheduler configurations are drawn over backend ×
//! tiled/untiled × threads {1,2,4} × shard-workers {1,2,8} ×
//! prefill-chunk {1,3,16} × max_slots × temperature × arrival pattern
//! × prefix-cache {on,off} × quant {none,int8,int4} (ISSUE 7: sparse
//! backends only) × nm {off,2:4,4:8} (ISSUE 8: sparse f32 backends
//! only, projected checkpoints) × kernel-path {scalar,unrolled} ×
//! pin-workers {on,off} × request fixture (ragged / chunk-straddling
//! / shared-prefix families), and every single one must reproduce the
//! single-sequence `generate()` streams of a chunk-size-1
//! scalar-kernel reference engine **built at the same quant/nm mode**
//! bit-for-bit — the engine's headline guarantee: scheduling policy,
//! kernel traversal (including the unrolled path), slot sharding,
//! row-band pooling, lane pinning, prefill chunking and shared-prefix
//! KV caching decide *when* and *where* a request computes, never
//! *what* it produces. Quantization and N:M projection change *what*
//! (tolerance-bounded vs f32 / different weights — see
//! `quant_parity.rs`) but are build-time properties of the engine, so
//! within a mode every axis above must still be bit-exact.
//!
//! The engines use deliberately tiny tile plans
//! (`common::banded_engine`) so `--shard-workers > 1` genuinely
//! dispatches the persistent pool at toy scale instead of degrading to
//! one shard, and the request streams mix ragged prompts whose
//! headless position counts sit one-below / exactly-at / one-above
//! every chunk-window boundary (prompt lengths 1–18 against chunks
//! {1,3,16} on a seq_len-20 model).

mod common;

use std::collections::HashMap;

use common::{banded_engine, chunk_straddling_requests, nm_engine,
             quant_engine, ragged_requests, shared_prefix_requests,
             SHARED_SYSTEM_PROMPT_LEN, TOY_VOCAB};
use elsa::infer::scheduler::{RequestQueue, SchedOptions, Scheduler};
use elsa::infer::{Backend, Engine};
use elsa::sparse::{KernelPath, NmMode, QuantMode};
use elsa::util::rng::Rng;

const BACKENDS: [Backend; 3] =
    [Backend::Dense, Backend::Csr, Backend::Macko];
const QUANTS: [QuantMode; 3] =
    [QuantMode::None, QuantMode::Int8, QuantMode::Int4];
const NMS: [NmMode; 3] = [NmMode::Off, NmMode::N2M4, NmMode::N4M8];
const THREADS: [usize; 3] = [1, 2, 4];
const SHARD_WORKERS: [usize; 3] = [1, 2, 8];
const PREFILL_CHUNKS: [usize; 3] = [1, 3, 16];
const MAX_SLOTS: [usize; 4] = [1, 2, 3, 5];
const TEMPERATURES: [f32; 3] = [0.0, 0.6, 0.9];
const ARRIVAL_GAPS: [f64; 3] = [0.0, 1.0, 2.5];
const CASES: usize = 70;

/// One drawn configuration of the sweep.
#[derive(Debug)]
struct Case {
    backend_idx: usize,
    /// Index into [`QUANTS`] — forced to 0 (f32) for the dense
    /// backend, which has no quantized serving format.
    quant_idx: usize,
    /// Index into [`NMS`] — forced to 0 (off) for the dense backend
    /// and for quantized cells (no quantized N:M payload).
    nm_idx: usize,
    tiled: bool,
    /// Kernel traversal for the sweep engine; the reference engine
    /// always runs scalar, so every unrolled case is also a
    /// cross-path identity check.
    scalar_path: bool,
    threads: usize,
    shard_workers: usize,
    /// Best-effort lane affinity — a placement hint, never a token.
    pin_workers: bool,
    prefill_chunk: usize,
    max_slots: usize,
    temperature: f32,
    arrival_gap: f64,
    n_requests: u64,
    /// 0 = ragged prompts, 1 = chunk-straddling prompts, 2 = the
    /// shared-prefix family (identical system prompt, divergent
    /// suffixes, one full-prompt-is-a-cached-prefix request).
    fixture: usize,
    /// Shared-prefix KV cache on/off — must never change a token.
    prefix_cache: bool,
    queue_seed: u64,
}

fn draw(rng: &mut Rng) -> Case {
    let backend_idx = rng.below(BACKENDS.len());
    let quant_idx = if BACKENDS[backend_idx] == Backend::Dense {
        0
    } else {
        rng.below(QUANTS.len())
    };
    Case {
        backend_idx,
        quant_idx,
        nm_idx: if BACKENDS[backend_idx] == Backend::Dense
                    || quant_idx != 0 {
            0
        } else {
            rng.below(NMS.len())
        },
        tiled: rng.below(2) == 1,
        scalar_path: rng.below(2) == 1,
        threads: THREADS[rng.below(THREADS.len())],
        shard_workers: SHARD_WORKERS[rng.below(SHARD_WORKERS.len())],
        pin_workers: rng.below(4) == 0,
        prefill_chunk: PREFILL_CHUNKS[rng.below(PREFILL_CHUNKS.len())],
        max_slots: MAX_SLOTS[rng.below(MAX_SLOTS.len())],
        temperature: TEMPERATURES[rng.below(TEMPERATURES.len())],
        arrival_gap: ARRIVAL_GAPS[rng.below(ARRIVAL_GAPS.len())],
        n_requests: 3 + rng.below(5) as u64,
        fixture: rng.below(3),
        // biased toward on — the default, and the riskier path
        prefix_cache: rng.below(4) != 0,
        queue_seed: rng.next_u64(),
    }
}

#[test]
fn randomized_sweep_reproduces_single_sequence_streams() {
    // one engine per (backend, quant) cell, built lazily and shared
    // across cases (`tiled` and `prefill_chunk` are flipped per case;
    // neither can change tokens, which the sweep verifies), plus a
    // chunk-size-1 reference engine per cell: every case must
    // reproduce the per-token-prefill single-sequence streams OF THE
    // SAME QUANT MODE, whatever its own chunk is — int8 vs f32 is a
    // tolerance question (quant_parity.rs), never a sweep question
    let banded = |bi: usize, qi: usize, ni: usize| -> Engine {
        let (mut e, _) = if ni == 0 {
            quant_engine(BACKENDS[bi], QUANTS[qi])
        } else {
            nm_engine(BACKENDS[bi], NMS[ni])
        };
        e.retile(64, 8); // same tiny plans as common::banded_engine
        e
    };
    type Cell = (usize, usize, usize);
    let mut engines: HashMap<Cell, Engine> = HashMap::new();
    let mut ref_engines: HashMap<Cell, Engine> = HashMap::new();
    // reference streams are pure functions of (backend, quant, nm,
    // prompt, n_new, temperature, seed) — cache them across cases
    let mut reference: HashMap<(Cell, Vec<u32>, usize, u32, u64),
                               Vec<u32>> = HashMap::new();

    let mut rng = Rng::new(0xD5_EED);
    let mut pooled_cases = 0usize;
    let mut chunked_cases = 0usize;
    let mut shared_on_cases = 0usize;
    let mut quantized_cases = 0usize;
    let mut nm_cases = 0usize;
    let mut scalar_cases = 0usize;
    let mut unrolled_cases = 0usize;
    for case_no in 0..CASES {
        let mut case = draw(&mut rng);
        if case_no % 4 == 0 {
            // pin a quarter of the sweep to the shared-prefix family
            // with the cache on, so cache-hit coverage never depends
            // on how the axes happen to be drawn
            case.fixture = 2;
            case.prefix_cache = true;
        }
        // pin disjoint fifths of the sweep to the quantized and the
        // N:M cells, so both build modes hit their coverage floors
        // regardless of the draw (both need a sparse backend)
        if case_no % 5 == 1 {
            if BACKENDS[case.backend_idx] == Backend::Dense {
                case.backend_idx = 1 + case_no % 2;
            }
            case.nm_idx = 0;
            if case.quant_idx == 0 {
                case.quant_idx = 1 + case_no % 2;
            }
        } else if case_no % 5 == 3 {
            if BACKENDS[case.backend_idx] == Backend::Dense {
                case.backend_idx = 1 + case_no % 2;
            }
            case.quant_idx = 0;
            if case.nm_idx == 0 {
                case.nm_idx = 1 + (case_no / 5) % 2;
            }
        }
        let cell = (case.backend_idx, case.quant_idx, case.nm_idx);
        let engine = engines
            .entry(cell)
            .or_insert_with(|| banded(cell.0, cell.1, cell.2));
        engine.tiled = case.tiled;
        engine.prefill_chunk = case.prefill_chunk;
        engine.kernel_path = if case.scalar_path {
            KernelPath::Scalar
        } else {
            KernelPath::Unrolled
        };
        if case.shard_workers > 1 {
            pooled_cases += 1;
        }
        if case.prefill_chunk > 1 {
            chunked_cases += 1;
        }
        if case.fixture == 2 && case.prefix_cache {
            shared_on_cases += 1;
        }
        if case.quant_idx != 0 {
            quantized_cases += 1;
        }
        if case.nm_idx != 0 {
            nm_cases += 1;
        }
        if case.scalar_path {
            scalar_cases += 1;
        } else {
            unrolled_cases += 1;
        }

        let reqs = match case.fixture {
            0 => ragged_requests(case.n_requests),
            1 => chunk_straddling_requests(case.n_requests),
            _ => shared_prefix_requests(case.n_requests),
        };
        let queue = RequestQueue::with_poisson_arrivals(
            reqs.clone(), case.arrival_gap, case.queue_seed);
        let sched = Scheduler::new(engine, SchedOptions {
            max_slots: case.max_slots,
            temperature: case.temperature,
            threads: case.threads,
            shard_workers: case.shard_workers,
            prefix_cache: case.prefix_cache,
            pin_workers: case.pin_workers,
        });
        let (finished, stats) = sched.run(queue);
        assert_eq!(finished.len(), reqs.len(), "case {case_no} {case:?}");
        assert_eq!(stats.expired, 0, "case {case_no} {case:?}");
        assert_eq!(stats.nm_mode, NMS[case.nm_idx].label(),
                   "case {case_no}: stats must echo the engine's nm");

        let ref_engine = ref_engines.entry(cell).or_insert_with(|| {
            let mut e = banded(cell.0, cell.1, cell.2);
            e.prefill_chunk = 1;
            // the reference always runs the scalar kernels, so every
            // unrolled case doubles as a cross-path identity check
            e.kernel_path = KernelPath::Scalar;
            e
        });
        for f in &finished {
            let r = &reqs[f.id as usize];
            let key = (cell, r.prompt.clone(), r.n_new,
                       case.temperature.to_bits(), r.seed);
            let want = reference.entry(key).or_insert_with(|| {
                ref_engine
                    .generate(&r.prompt, r.n_new, case.temperature,
                              r.seed)
                    .0
            });
            assert_eq!(&f.tokens, want,
                       "case {case_no} {case:?}: req {} diverged from \
                        chunk-1 single-sequence generate", f.id);
        }
    }
    // the draw is seeded, so this is deterministic: make sure the
    // sweep actually covered the configurations it exists for
    assert!(pooled_cases >= 10,
            "sweep drew only {pooled_cases} pooled cases — reseed it");
    assert!(chunked_cases >= 10,
            "sweep drew only {chunked_cases} chunked cases — reseed it");
    assert!(shared_on_cases >= 10,
            "sweep ran only {shared_on_cases} shared-prefix cache-on \
             cases — repin it");
    assert!(quantized_cases >= 10,
            "sweep drew only {quantized_cases} quantized cases — \
             reseed it");
    assert!(nm_cases >= 10,
            "sweep drew only {nm_cases} N:M cases — repin it");
    assert!(scalar_cases >= 10,
            "sweep drew only {scalar_cases} scalar-path cases — \
             reseed it");
    assert!(unrolled_cases >= 10,
            "sweep drew only {unrolled_cases} unrolled-path cases — \
             reseed it");
}

#[test]
fn chunked_prefill_is_bit_identical_to_per_token_reference() {
    // the direct (scheduler-free) axis: every chunk size must replay
    // the chunk-1 streams and logits exactly, including ragged prompts
    // that straddle chunk boundaries (len % chunk ∈ {0, 1, chunk-1})
    // and a prompt filling all but one position of seq_len
    let prompt_lens: [usize; 12] =
        [1, 2, 3, 4, 5, 6, 7, 8, 16, 17, 18, 19];
    for backend in BACKENDS {
        let (mut engine, seq_len) = banded_engine(backend);
        for &plen in &prompt_lens {
            assert!(plen < seq_len);
            let prompt: Vec<u32> = (0..plen)
                .map(|i| ((plen * 5 + i * 3) % TOY_VOCAB) as u32)
                .collect();
            engine.prefill_chunk = 1;
            let (want, _) = engine.generate(&prompt, 3, 0.8, 9);
            let want_logits = engine.logits_for(&prompt);
            for chunk in [2usize, 3, 5, 16] {
                engine.prefill_chunk = chunk;
                let (got, _) = engine.generate(&prompt, 3, 0.8, 9);
                assert_eq!(got, want,
                           "{backend:?} plen={plen} chunk={chunk}");
                assert_eq!(engine.logits_for(&prompt), want_logits,
                           "{backend:?} plen={plen} chunk={chunk} \
                            logits");
            }
        }
    }
}

#[test]
fn prefill_projects_head_once_per_request_in_the_scheduler() {
    // the projection-count probe at the serving layer: total head rows
    // across a scheduler run must equal the generated token count —
    // i.e. exactly ONE head projection per request covers its whole
    // prompt (the final position), however long, at any chunk size
    for chunk in [1usize, 3, 16] {
        let (mut engine, _) = banded_engine(Backend::Macko);
        engine.prefill_chunk = chunk;
        // 11 requests = one per STRADDLING_PROMPT_LENS entry, so every
        // boundary-adjacent headless count is exercised at every chunk
        let reqs = chunk_straddling_requests(11);
        let expect_tokens: usize = reqs.iter().map(|r| r.n_new).sum();
        let queue = RequestQueue::with_poisson_arrivals(
            reqs.clone(), 1.0, 3);
        let before = engine.head_rows_projected();
        let sched = Scheduler::new(&engine, SchedOptions {
            max_slots: 2,
            temperature: 0.8,
            ..SchedOptions::default()
        });
        let (_, stats) = sched.run(queue);
        assert_eq!(stats.tokens_generated, expect_tokens,
                   "chunk={chunk}: fixture must not hit seq_len");
        assert_eq!(engine.head_rows_projected() - before,
                   stats.tokens_generated as u64,
                   "chunk={chunk}: prefill must project the head \
                    exactly once per request regardless of prompt \
                    length");
        // and the headless prompt-token accounting matches the
        // prompts: every position but the last, in ceil((len-1)/chunk)
        // passes per request
        let expect_prefill: usize =
            reqs.iter().map(|r| r.prompt.len() - 1).sum();
        let expect_chunks: usize = reqs.iter()
            .map(|r| (r.prompt.len() - 1).div_ceil(chunk))
            .sum();
        assert_eq!(stats.prefill_tokens, expect_prefill, "chunk={chunk}");
        assert_eq!(stats.prefill_chunks, expect_chunks, "chunk={chunk}");
    }
}

#[test]
fn empty_prompt_generate_agrees_with_the_batch_path() {
    // ISSUE 5 satellite: the old divergence (generate(&[], ..) emitted
    // token 0; the batch path retired with zero tokens) is gone — the
    // batch rule won on every path
    let (engine, _) = banded_engine(Backend::Csr);
    let (out, stats) = engine.generate(&[], 4, 0.8, 1);
    assert!(out.is_empty());
    assert_eq!(stats.tokens_generated, 0);
    let (batch_out, _) = engine.generate_batch(
        &[vec![], vec![1, 2, 3]],
        &elsa::infer::BatchOptions { n_new: 4, temperature: 0.8,
                                     seed: 1,
                                     ..Default::default() });
    assert_eq!(batch_out[0], out, "empty prompt: paths must agree");
}

#[test]
#[should_panic(expected = "exceeds seq_len")]
fn generate_rejects_oversized_prompt_like_generate_batch() {
    // ISSUE 5 satellite: the seq_len guard generate_batch always had —
    // an oversized prompt used to silently grow the KV cache past
    // seq_len and recycle the last positional row
    let (engine, seq_len) = banded_engine(Backend::Macko);
    let long: Vec<u32> = (0..seq_len + 1)
        .map(|i| (i % TOY_VOCAB) as u32)
        .collect();
    engine.generate(&long, 1, 0.0, 0);
}

#[test]
#[should_panic(expected = "exceeds seq_len")]
fn logits_for_rejects_oversized_prompt_like_generate_batch() {
    let (engine, seq_len) = banded_engine(Backend::Macko);
    let long: Vec<u32> = (0..seq_len + 1)
        .map(|i| (i % TOY_VOCAB) as u32)
        .collect();
    engine.logits_for(&long);
}

#[test]
fn prefix_cache_hits_replay_cold_start_streams_exactly() {
    // the deterministic hit matrix (ISSUE 6 tentpole): arrivals are
    // spaced 40 steps apart — far beyond any request's busy ticks on
    // the toy model, and idle workers fast-forward rather than tick —
    // so each request completes (and publishes its prefix) before the
    // next admits. Every request after the first is then a GUARANTEED
    // cache hit, at every backend × threads × prefill-chunk ×
    // shard-workers cell, which pins three things bit-exactly:
    //   1. hit streams == cold single-sequence generate streams,
    //   2. prefix_hits == n - 1,
    //   3. prefix_tokens_saved == Σ attached prefix lengths, exactly.
    let n: u64 = 5;
    let mut hit_cases = 0usize;
    for &backend in &BACKENDS {
        let (mut engine, _) = banded_engine(backend);
        for threads in [1usize, 2] {
            for chunk in [1usize, 3, 16] {
                for shard_workers in [1usize, 2] {
                    engine.prefill_chunk = chunk;
                    let reqs = shared_prefix_requests(n);
                    let mut queue = RequestQueue::new();
                    for (i, r) in reqs.iter().enumerate() {
                        queue.push_at(i as u64 * 40, r.clone());
                    }
                    let sched = Scheduler::new(&engine, SchedOptions {
                        max_slots: 2,
                        temperature: 0.8,
                        threads,
                        shard_workers,
                        prefix_cache: true,
                        pin_workers: false,
                    });
                    let (finished, stats) = sched.run(queue);
                    let tag = format!(
                        "{backend:?} threads={threads} chunk={chunk} \
                         shard_workers={shard_workers}");
                    assert_eq!(finished.len(), reqs.len(), "{tag}");
                    for f in &finished {
                        let r = &reqs[f.id as usize];
                        let (want, _) = engine.generate(
                            &r.prompt, r.n_new, 0.8, r.seed);
                        assert_eq!(f.tokens, want,
                                   "{tag}: req {} cache-hit stream \
                                    diverged from cold start", f.id);
                    }
                    // requests admit strictly one at a time in id
                    // order, so req 0 cold-prefills the system prompt
                    // and every later request attaches it: exactly
                    // min(SHARED_SYSTEM_PROMPT_LEN, len - 1) positions
                    // each (the full-prompt-is-a-cached-prefix request
                    // stops one short of its prompt end)
                    let want_saved: usize = reqs[1..]
                        .iter()
                        .map(|r| SHARED_SYSTEM_PROMPT_LEN
                                 .min(r.prompt.len() - 1))
                        .sum();
                    assert_eq!(stats.prefix_hits, reqs.len() - 1,
                               "{tag}: hits");
                    assert_eq!(stats.prefix_tokens_saved, want_saved,
                               "{tag}: tokens_saved must equal the sum \
                                of attached prefix lengths");
                    if stats.prefix_hits > 0 {
                        hit_cases += 1;
                    }

                    // the off axis on the identical queue: same
                    // streams, zero hits
                    let mut queue = RequestQueue::new();
                    for (i, r) in reqs.iter().enumerate() {
                        queue.push_at(i as u64 * 40, r.clone());
                    }
                    let off = Scheduler::new(&engine, SchedOptions {
                        max_slots: 2,
                        temperature: 0.8,
                        threads,
                        shard_workers,
                        prefix_cache: false,
                        pin_workers: false,
                    });
                    let (fin_off, st_off) = off.run(queue);
                    assert_eq!(st_off.prefix_hits, 0, "{tag}");
                    assert_eq!(st_off.prefix_tokens_saved, 0, "{tag}");
                    for (a, b) in finished.iter().zip(fin_off.iter()) {
                        assert_eq!(a.tokens, b.tokens,
                                   "{tag}: on/off streams differ at \
                                    req {}", a.id);
                    }
                    // the cache saved exactly the prefill work it
                    // claimed to
                    assert_eq!(stats.prefill_tokens
                                   + stats.prefix_tokens_saved,
                               st_off.prefill_tokens, "{tag}");
                }
            }
        }
    }
    assert!(hit_cases >= 10,
            "matrix produced only {hit_cases} prefix-hit cases");
}

#[test]
fn identical_cases_are_bit_identical_across_runs() {
    // the sweep itself must be replayable: same seed, same streams,
    // run to run, including pooled multi-thread configurations
    let run = || {
        let (engine, _) = banded_engine(Backend::Macko);
        let reqs = ragged_requests(6);
        let queue =
            RequestQueue::with_poisson_arrivals(reqs, 1.5, 77);
        let sched = Scheduler::new(&engine, SchedOptions {
            max_slots: 3,
            temperature: 0.8,
            threads: 2,
            shard_workers: 2,
            ..SchedOptions::default()
        });
        let (finished, _) = sched.run(queue);
        finished.into_iter().map(|f| (f.id, f.tokens))
            .collect::<Vec<_>>()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "re-running an identical pooled config diverged");
}

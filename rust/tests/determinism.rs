//! Seeded randomized determinism sweep (ISSUE 4 satellite): one
//! harness that subsumes the ad-hoc pairwise checks scattered across
//! the older suites. ~50 seeded scheduler configurations are drawn
//! over backend × tiled/untiled × threads {1,2,4} × shard-workers
//! {1,2,8} × max_slots × temperature × arrival pattern, and every
//! single one must reproduce the single-sequence `generate()` streams
//! bit-for-bit — the engine's headline guarantee: scheduling policy,
//! kernel traversal, slot sharding and row-band pooling decide *when*
//! and *where* a request computes, never *what* it produces.
//!
//! The engines use deliberately tiny tile plans
//! (`common::banded_engine`) so `--shard-workers > 1` genuinely
//! dispatches the persistent pool at toy scale instead of degrading to
//! one shard.

mod common;

use std::collections::HashMap;

use common::{banded_engine, ragged_requests};
use elsa::infer::scheduler::{RequestQueue, SchedOptions, Scheduler};
use elsa::infer::{Backend, Engine};
use elsa::util::rng::Rng;

const BACKENDS: [Backend; 3] =
    [Backend::Dense, Backend::Csr, Backend::Macko];
const THREADS: [usize; 3] = [1, 2, 4];
const SHARD_WORKERS: [usize; 3] = [1, 2, 8];
const MAX_SLOTS: [usize; 4] = [1, 2, 3, 5];
const TEMPERATURES: [f32; 3] = [0.0, 0.6, 0.9];
const ARRIVAL_GAPS: [f64; 3] = [0.0, 1.0, 2.5];
const CASES: usize = 50;

/// One drawn configuration of the sweep.
#[derive(Debug)]
struct Case {
    backend_idx: usize,
    tiled: bool,
    threads: usize,
    shard_workers: usize,
    max_slots: usize,
    temperature: f32,
    arrival_gap: f64,
    n_requests: u64,
    queue_seed: u64,
}

fn draw(rng: &mut Rng) -> Case {
    Case {
        backend_idx: rng.below(BACKENDS.len()),
        tiled: rng.below(2) == 1,
        threads: THREADS[rng.below(THREADS.len())],
        shard_workers: SHARD_WORKERS[rng.below(SHARD_WORKERS.len())],
        max_slots: MAX_SLOTS[rng.below(MAX_SLOTS.len())],
        temperature: TEMPERATURES[rng.below(TEMPERATURES.len())],
        arrival_gap: ARRIVAL_GAPS[rng.below(ARRIVAL_GAPS.len())],
        n_requests: 3 + rng.below(5) as u64,
        queue_seed: rng.next_u64(),
    }
}

#[test]
fn randomized_sweep_reproduces_single_sequence_streams() {
    // one engine per backend, shared across cases (`tiled` is flipped
    // per case; it cannot change tokens, which the sweep verifies)
    let mut engines: Vec<Engine> = BACKENDS
        .iter()
        .map(|&b| banded_engine(b).0)
        .collect();
    // reference streams are pure functions of (backend, prompt, n_new,
    // temperature, seed) — cache them across cases
    let mut reference: HashMap<(usize, Vec<u32>, usize, u32, u64),
                               Vec<u32>> = HashMap::new();

    let mut rng = Rng::new(0xD5_EED);
    let mut pooled_cases = 0usize;
    for case_no in 0..CASES {
        let case = draw(&mut rng);
        let engine = &mut engines[case.backend_idx];
        engine.tiled = case.tiled;
        if case.shard_workers > 1 {
            pooled_cases += 1;
        }

        let reqs = ragged_requests(case.n_requests);
        let queue = RequestQueue::with_poisson_arrivals(
            reqs.clone(), case.arrival_gap, case.queue_seed);
        let sched = Scheduler::new(engine, SchedOptions {
            max_slots: case.max_slots,
            temperature: case.temperature,
            threads: case.threads,
            shard_workers: case.shard_workers,
        });
        let (finished, stats) = sched.run(queue);
        assert_eq!(finished.len(), reqs.len(), "case {case_no} {case:?}");
        assert_eq!(stats.expired, 0, "case {case_no} {case:?}");

        for f in &finished {
            let r = &reqs[f.id as usize];
            let key = (case.backend_idx, r.prompt.clone(), r.n_new,
                       case.temperature.to_bits(), r.seed);
            let want = reference.entry(key).or_insert_with(|| {
                engines[case.backend_idx]
                    .generate(&r.prompt, r.n_new, case.temperature,
                              r.seed)
                    .0
            });
            assert_eq!(&f.tokens, want,
                       "case {case_no} {case:?}: req {} diverged from \
                        single-sequence generate", f.id);
        }
    }
    // the draw is seeded, so this is deterministic: make sure the
    // sweep actually covered the pooled configurations it exists for
    assert!(pooled_cases >= 10,
            "sweep drew only {pooled_cases} pooled cases — reseed it");
}

#[test]
fn identical_cases_are_bit_identical_across_runs() {
    // the sweep itself must be replayable: same seed, same streams,
    // run to run, including pooled multi-thread configurations
    let run = || {
        let (engine, _) = banded_engine(Backend::Macko);
        let reqs = ragged_requests(6);
        let queue =
            RequestQueue::with_poisson_arrivals(reqs, 1.5, 77);
        let sched = Scheduler::new(&engine, SchedOptions {
            max_slots: 3,
            temperature: 0.8,
            threads: 2,
            shard_workers: 2,
        });
        let (finished, _) = sched.run(queue);
        finished.into_iter().map(|f| (f.id, f.tokens))
            .collect::<Vec<_>>()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "re-running an identical pooled config diverged");
}

//! Continuous-batching scheduler bench: aggregate decode throughput and
//! per-request latency percentiles under staggered (Poisson-ish,
//! seeded) arrivals with ragged token budgets, for three admission
//! policies over the identical request stream:
//!
//!  - sequential: one request at a time (`Engine::generate`) — also
//!    produces the reference streams every other policy must match
//!    bit-for-bit,
//!  - static: groups of `max_slots` requests, each group drained
//!    completely before the next is admitted (what
//!    `Engine::generate_batch` does),
//!  - continuous: the `Scheduler` — freed slots are refilled from the
//!    queue mid-decode, KV buffers recycled through the `KvPool`,
//!  - continuous_pooled: the same scheduler with each worker fanning
//!    every layer's linears across a persistent row-band pool
//!    (`--shard-workers`) — ISSUE 4's slot × band end-to-end cell.
//!
//! The claim under test (ISSUE 2): continuous admission beats static
//! batching on aggregate tok/s because ragged budgets leave static
//! groups running mostly-empty tails, while the scheduler keeps
//! occupancy (and therefore SpMM amortization) high. ISSUE 4 adds:
//! pooled decode serves the identical streams, with per-lane busy/idle
//! accounting in the log. ISSUE 5 adds: chunked vs per-token prefill
//! rates on the serve path (`prefill_chunked_tok_s` /
//! `prefill_pertoken_tok_s` in the summary; identical streams either
//! way — the >= 1.0 ratio gate lives in the kernels section).
//! ISSUE 6 adds: the shared-prefix cell — a high-duplication stream
//! (identical 48-token system prompt per request) drained with the
//! prefix cache off then on, streams asserted identical before
//! timing, gated in CI via `prefix_cached_uncached_ratio >= 1.0`.
//!
//! Run: cargo bench --bench bench_scheduler [-- <threads> <requests>
//! <max_slots> <shard_workers>]. Writes a machine-readable summary to
//! `$BENCH_OUT` (default `BENCH_scheduler.json`) for the CI regression
//! gate.

use elsa::infer::scheduler::{ragged_budgets, serve_static_chunks,
                             Request, RequestQueue, SchedOptions,
                             Scheduler};
use elsa::infer::{Backend, Engine};
use elsa::model::{synthetic_config, Params};
use elsa::pruners::{magnitude, uniform_alloc};
use elsa::util::json::{num, obj, to_string};
use elsa::util::rng::Rng;
use elsa::util::timer::Timer;

const TEMPERATURE: f32 = 0.8;
const ARRIVAL_GAP_STEPS: f64 = 2.0;

fn main() {
    let argn = |i: usize, default: usize| -> usize {
        std::env::args()
            .nth(i)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    };
    let threads = argn(1, 1);
    let n_requests = argn(2, 24);
    let max_slots = argn(3, 6);
    let shard_workers = argn(4, 2).max(1);

    // serving-sized toy model, 90% sparse (same shape as bench_batch)
    let cfg = synthetic_config("sched_bench", 128, 2, 4, 512, 256, 96);
    let params = Params::init(&cfg, 0);
    let pruned = magnitude::prune(&cfg, &params.flat,
                                  &uniform_alloc(&cfg, 0.9))
        .expect("magnitude prune");
    let p = Params::new(&cfg, pruned);
    let mut engine = Engine::build(&p, Backend::Macko).expect("engine");

    // the request stream: ragged budgets are what continuous admission
    // exploits (static groups idle through their longest member's tail)
    let prompt_len = 8;
    let base = cfg.seq_len - prompt_len;
    let budgets = ragged_budgets(base, n_requests, 1);
    let mut rng = Rng::new(1);
    let reqs: Vec<Request> = (0..n_requests)
        .map(|r| Request {
            id: r as u64,
            prompt: (0..prompt_len)
                .map(|_| rng.below(cfg.vocab) as u32)
                .collect(),
            n_new: budgets[r],
            seed: r as u64,
            deadline: None,
        })
        .collect();
    let budget: usize = reqs.iter().map(|r| r.n_new).sum();
    println!("== scheduler bench: d={} L={} sp=0.90 macko | \
              {n_requests} requests ({budget} token budget), \
              {max_slots} slots, {threads} thread(s) ==",
             cfg.d_model, cfg.n_layers);

    // sequential baseline + reference streams
    engine.generate(&reqs[0].prompt, 8, TEMPERATURE, 0); // warmup
    let t = Timer::start();
    let mut reference: Vec<Vec<u32>> = Vec::with_capacity(n_requests);
    let mut seq_tokens = 0usize;
    for r in &reqs {
        let (out, stats) =
            engine.generate(&r.prompt, r.n_new, TEMPERATURE, r.seed);
        seq_tokens += stats.tokens_generated;
        reference.push(out);
    }
    let seq_s = t.seconds();
    let seq_tps = seq_tokens as f64 / seq_s;
    println!("sequential : {seq_tps:9.1} tok/s  ({seq_tokens} tokens \
              in {seq_s:.3}s)");

    // static batching: admit in fixed groups, drain each fully
    let sopts = SchedOptions {
        max_slots,
        temperature: TEMPERATURE,
        threads,
        ..SchedOptions::default()
    };
    let (fin, st) = serve_static_chunks(&engine, &reqs, &sopts);
    for f in &fin {
        assert_eq!(f.tokens, reference[f.id as usize],
                   "static policy diverged from generate on req {}",
                   f.id);
    }
    println!("static     : {:9.1} tok/s | p50 {:7.2} ms | p95 {:7.2} ms \
              | {} steps",
             st.tokens_per_second, st.p50_latency_ms, st.p95_latency_ms,
             st.steps);

    // continuous batching: mid-decode admission + pooled KV buffers
    let queue =
        RequestQueue::with_poisson_arrivals(reqs.clone(),
                                            ARRIVAL_GAP_STEPS, 7);
    let sched = Scheduler::new(&engine, sopts.clone());
    let (fin, sc) = sched.run(queue);
    for f in &fin {
        assert!(!f.expired, "no deadlines given, nothing may expire");
        assert_eq!(f.tokens, reference[f.id as usize],
                   "scheduler diverged from generate on req {}", f.id);
    }
    let speedup = sc.tokens_per_second / st.tokens_per_second.max(1e-9);
    println!("continuous : {:9.1} tok/s | p50 {:7.2} ms | p95 {:7.2} ms \
              | {} steps | wait {:.1} | kv reuse {}/{}",
             sc.tokens_per_second, sc.p50_latency_ms, sc.p95_latency_ms,
             sc.steps, sc.mean_wait_steps, sc.kv_reused,
             sc.kv_reused + sc.kv_allocated);
    println!("continuous vs static: x{speedup:.2} aggregate tok/s \
              (bit-identical streams)");

    // continuous + pooled row-band decode: each scheduler worker fans
    // every linear across `shard_workers` persistent lanes — same
    // queue, same streams, ISSUE 4's end-to-end serve-path cell
    let queue =
        RequestQueue::with_poisson_arrivals(reqs.clone(),
                                            ARRIVAL_GAP_STEPS, 7);
    let sched = Scheduler::new(&engine, SchedOptions {
        shard_workers,
        ..sopts.clone()
    });
    let (fin, sp) = sched.run(queue);
    for f in &fin {
        assert_eq!(f.tokens, reference[f.id as usize],
                   "pooled scheduler diverged from generate on req {}",
                   f.id);
    }
    let busy: f64 = sp.shard_busy_seconds.iter().sum();
    let idle: f64 = sp.shard_idle_seconds.iter().sum();
    println!("cont+pooled: {:9.1} tok/s | p50 {:7.2} ms | p95 {:7.2} ms \
              | {} steps | {shard_workers} bands | busy {busy:.3}s \
              idle {idle:.3}s",
             sp.tokens_per_second, sp.p50_latency_ms, sp.p95_latency_ms,
             sp.steps);
    println!("pooled vs continuous: x{:.2} aggregate tok/s \
              (bit-identical streams)",
             sp.tokens_per_second / sc.tokens_per_second.max(1e-9));

    // chunked vs per-token prefill on the serve path: the same
    // continuous queue drained with prefill_chunk = 1 (one prompt
    // position per scheduler iteration) — streams must be identical;
    // the headless-token rates go in the summary (the >= 1.0 ratio
    // gate lives in the kernels section, on the isolated sweep)
    let prefill_rate = |st: &elsa::infer::scheduler::SchedStats| {
        st.prefill_tokens as f64 / st.prefill_seconds.max(1e-9)
    };
    let chunked_rate = prefill_rate(&sc);
    let default_chunk = engine.prefill_chunk;
    engine.prefill_chunk = 1;
    let queue =
        RequestQueue::with_poisson_arrivals(reqs.clone(),
                                            ARRIVAL_GAP_STEPS, 7);
    let sched = Scheduler::new(&engine, sopts.clone());
    let (fin, s1) = sched.run(queue);
    for f in &fin {
        assert_eq!(f.tokens, reference[f.id as usize],
                   "per-token prefill diverged from generate on req {}",
                   f.id);
    }
    let pertoken_rate = prefill_rate(&s1);
    println!("prefill    : chunked {chunked_rate:9.1} tok/s \
              ({} tokens, {} passes) vs per-token \
              {pertoken_rate:9.1} tok/s (identical streams)",
             sc.prefill_tokens, sc.prefill_chunks);

    // shared-prefix serving cell (ISSUE 6): a high-duplication stream
    // — every prompt opens with the same 48-token system prompt, then
    // an 8-token unique tail — drained twice over the identical
    // arrival schedule, prefix cache off then on. Streams are
    // asserted identical BEFORE timing; the cached/uncached aggregate
    // tok/s ratio is the CI-gated number (prefill dominates this
    // stream, so cache hits shift real work, not noise)
    engine.prefill_chunk = default_chunk;
    let sys_len = 48usize;
    let tail_len = 8usize;
    let mut rng = Rng::new(5);
    let system: Vec<u32> =
        (0..sys_len).map(|_| rng.below(cfg.vocab) as u32).collect();
    let shared_reqs: Vec<Request> = (0..n_requests)
        .map(|r| {
            let mut prompt = system.clone();
            prompt.extend(
                (0..tail_len).map(|_| rng.below(cfg.vocab) as u32));
            Request {
                id: r as u64,
                prompt,
                n_new: 8,
                seed: 1000 + r as u64,
                deadline: None,
            }
        })
        .collect();
    let shared_ref: Vec<Vec<u32>> = shared_reqs
        .iter()
        .map(|r| engine.generate(&r.prompt, r.n_new, TEMPERATURE,
                                 r.seed).0)
        .collect();
    // spaced arrivals: the first request finishes its cold prefill
    // before the second admits, so the cell measures steady cache
    // hits rather than a cold-start race
    let shared_queue = || {
        let mut q = RequestQueue::new();
        for (i, r) in shared_reqs.iter().enumerate() {
            q.push_at(i as u64 * 10, r.clone());
        }
        q
    };
    let run_shared = |prefix_cache: bool| {
        let sched = Scheduler::new(&engine, SchedOptions {
            prefix_cache,
            ..sopts.clone()
        });
        let (fin, st) = sched.run(shared_queue());
        for f in &fin {
            assert_eq!(f.tokens, shared_ref[f.id as usize],
                       "shared-prefix stream (cache={prefix_cache}) \
                        diverged on req {}", f.id);
        }
        st
    };
    let su = run_shared(false);
    let ss = run_shared(true);
    assert!(ss.prefix_hits > 0,
            "high-duplication stream produced no cache hits");
    let prefix_ratio =
        ss.tokens_per_second / su.tokens_per_second.max(1e-9);
    println!("shared-pfx : cached {:9.1} tok/s vs uncached {:9.1} \
              tok/s | x{prefix_ratio:.2} | {} hits, {} tokens saved \
              (hit rate {:.2}, identical streams)",
             ss.tokens_per_second, su.tokens_per_second,
             ss.prefix_hits, ss.prefix_tokens_saved,
             ss.prefix_hit_rate);

    // machine-readable summary for the CI regression gate
    let policy = |tps: f64, p50: f64, p95: f64, steps: u64| {
        obj(vec![
            ("tok_s", num(tps)),
            ("p50_ms", num(p50)),
            ("p95_ms", num(p95)),
            ("steps", num(steps as f64)),
        ])
    };
    let j = obj(vec![
        ("config", obj(vec![
            ("d_model", num(cfg.d_model as f64)),
            ("n_layers", num(cfg.n_layers as f64)),
            ("sparsity", num(0.9)),
            ("requests", num(n_requests as f64)),
            ("max_slots", num(max_slots as f64)),
            ("threads", num(threads as f64)),
        ])),
        ("sequential", policy(seq_tps, 0.0, 0.0, 0)),
        ("static", policy(st.tokens_per_second, st.p50_latency_ms,
                          st.p95_latency_ms, st.steps)),
        ("continuous", policy(sc.tokens_per_second, sc.p50_latency_ms,
                              sc.p95_latency_ms, sc.steps)),
        ("continuous_pooled",
         policy(sp.tokens_per_second, sp.p50_latency_ms,
                sp.p95_latency_ms, sp.steps)),
        ("shard_workers", num(shard_workers as f64)),
        ("shard_busy_s", num(busy)),
        ("shard_idle_s", num(idle)),
        ("prefill_chunked_tok_s", num(chunked_rate)),
        ("prefill_pertoken_tok_s", num(pertoken_rate)),
        ("prefill_chunks", num(sc.prefill_chunks as f64)),
        ("kv_reused", num(sc.kv_reused as f64)),
        ("kv_allocated", num(sc.kv_allocated as f64)),
        ("kv_pool_bytes", num(sc.kv_pool_bytes as f64)),
        ("prefix_cached",
         policy(ss.tokens_per_second, ss.p50_latency_ms,
                ss.p95_latency_ms, ss.steps)),
        ("prefix_uncached_tok_s", num(su.tokens_per_second)),
        ("prefix_cached_uncached_ratio", num(prefix_ratio)),
        ("prefix_hits", num(ss.prefix_hits as f64)),
        ("prefix_tokens_saved", num(ss.prefix_tokens_saved as f64)),
        ("prefix_hit_rate", num(ss.prefix_hit_rate)),
        ("speedup_x", num(speedup)),
    ]);
    let path = std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_scheduler.json".to_string());
    std::fs::write(&path, to_string(&j) + "\n")
        .expect("write bench summary");
    println!("wrote {path}");
}

//! ELSA z-update micro-bench: the global Fisher-weighted top-k projection
//! at realistic coordinate counts (O(d) quickselect vs O(d log d) sort).
//!
//! Run: cargo bench --bench bench_projection

use elsa::tensor::select::{kth_largest, topk_mask};
use elsa::util::bench::{bench, throughput};
use elsa::util::rng::Rng;

fn main() {
    for &d in &[100_000usize, 1_000_000, 3_000_000] {
        let mut rng = Rng::new(0);
        let scores: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
        let k = d / 10;

        let r = bench(&format!("kth_largest       d={d}"), 400, || {
            std::hint::black_box(kth_largest(&scores, k));
        });
        throughput(&r, d as f64, "elem");

        let r = bench(&format!("topk_mask (10%)   d={d}"), 400, || {
            std::hint::black_box(topk_mask(&scores, k));
        });
        throughput(&r, d as f64, "elem");

        // the sort-based strawman, for the §Perf before/after record
        let r = bench(&format!("full-sort baseline d={d}"), 400, || {
            let mut s = scores.clone();
            s.sort_by(|a, b| b.partial_cmp(a).unwrap());
            std::hint::black_box(s[k - 1]);
        });
        throughput(&r, d as f64, "elem");
        println!();
    }
}

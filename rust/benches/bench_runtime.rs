//! End-to-end HLO dispatch bench: train_step / eval_loss throughput per
//! config through the PJRT runtime — the x-update cost that dominates
//! every ELSA run (Table 3's wall-clock column).
//!
//! Needs artifacts/ (make artifacts). Run: cargo bench --bench bench_runtime

use std::path::Path;

use elsa::data::Dataset;
use elsa::model::Params;
use elsa::runtime::{self, Runtime};
use elsa::util::bench::{bench, throughput};

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return;
    }
    let rt = Runtime::load(dir).unwrap();

    for cfg_name in ["tiny", "small"] {
        let Ok(cfg) = rt.manifest.config(cfg_name) else { continue };
        let cfg = cfg.clone();
        let d = cfg.flat_len;
        let params = Params::init(&cfg, 0);
        let ds = Dataset::generate("synth-c4", cfg.vocab, 60_000, 0, 0);
        let mut batcher =
            elsa::data::Batcher::new(&ds.train, cfg.batch, cfg.seq_len, 0);
        let batch = batcher.next_batch();
        let zeros = vec![0.0f32; d];
        let ones = vec![1.0f32; d];
        let pmask = cfg.prunable_mask();

        let exe = rt.executable(cfg_name, "train_step").unwrap();
        let tokens_per_step = (cfg.batch * cfg.seq_len) as f64;
        let mut p = params.flat.clone();
        let mut m = zeros.clone();
        let mut v = zeros.clone();
        let mut t = 0f32;
        let r = bench(&format!("train_step {cfg_name} (d={d})"), 3000,
                      || {
            t += 1.0;
            let outs = rt.execute(&exe, &[
                runtime::lit_f32(&p),
                runtime::lit_f32(&m),
                runtime::lit_f32(&v),
                runtime::lit_f32(&zeros),
                runtime::lit_f32(&zeros),
                runtime::lit_f32(&ones),
                runtime::lit_f32(&pmask),
                runtime::lit_i32_2d(&batch, cfg.batch, cfg.seq_len + 1)
                    .unwrap(),
                runtime::lit_scalar(t),
                runtime::lit_scalar(1e-3),
                runtime::lit_scalar(0.0),
            ]).unwrap();
            p = runtime::to_f32(&outs[0]).unwrap();
            m = runtime::to_f32(&outs[1]).unwrap();
            v = runtime::to_f32(&outs[2]).unwrap();
        });
        throughput(&r, tokens_per_step, "token");

        let exe = rt.executable(cfg_name, "eval_loss").unwrap();
        let ebatch = elsa::data::Batcher::eval_batches(
            &ds.train, cfg.eval_batch, cfg.seq_len)[0].clone();
        let r = bench(&format!("eval_loss  {cfg_name}"), 2000, || {
            let outs = rt.execute(&exe, &[
                runtime::lit_f32(&params.flat),
                runtime::lit_i32_2d(&ebatch, cfg.eval_batch,
                                    cfg.seq_len + 1).unwrap(),
            ]).unwrap();
            std::hint::black_box(runtime::to_scalar(&outs[0]).unwrap());
        });
        throughput(&r, (cfg.eval_batch * cfg.seq_len) as f64, "token");
        println!();
    }
}

//! ELSA-L codec bench: quant/dequant throughput per precision — the
//! per-outer-iteration overhead of low-precision state storage (§3.3).
//!
//! Run: cargo bench --bench bench_quant

use elsa::quant::{Precision, StoredVec};
use elsa::util::bench::{bench, throughput};
use elsa::util::rng::Rng;

fn main() {
    let d = 1_000_000usize;
    let mut rng = Rng::new(0);
    let xs: Vec<f32> = (0..d).map(|_| rng.normal()).collect();

    for (name, p) in [
        ("bf16", Precision::Bf16),
        ("fp8-e4m3", Precision::Fp8E4M3),
        ("int8", Precision::Int8),
        ("int8-block256", Precision::Int8Block(256)),
    ] {
        let r = bench(&format!("quantize   {name} d={d}"), 500, || {
            std::hint::black_box(StoredVec::quantize(&xs, p));
        });
        throughput(&r, d as f64, "elem");
        let sv = StoredVec::quantize(&xs, p);
        let r = bench(&format!("dequantize {name} d={d}"), 500, || {
            std::hint::black_box(sv.dequantize());
        });
        throughput(&r, d as f64, "elem");
        println!("  stored size: {} B ({:.2}x vs f32)\n", sv.mem_bytes(),
                 (d * 4) as f64 / sv.mem_bytes() as f64);
    }
}

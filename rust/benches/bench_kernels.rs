//! Row-tiled SpMM kernel bench (ISSUE 3/4): tiled vs untiled across
//! {Csr, Macko, dense} x batch {1, 4, 8, 16} x sparsity {0.5, 0.9,
//! 0.95}, an intra-layer sharding scaling check (per-call scoped
//! spawns vs the persistent decode pool), and per-backend end-to-end
//! batched decode tok/s on the serving-sized toy model — including
//! pooled row-band decode (`--shard-workers`).
//!
//! Every tiled/pooled cell is asserted bit-identical to its untiled
//! counterpart before it is timed — a bench that silently measured a
//! diverging kernel would be worse than no bench.
//!
//! Run: cargo bench --bench bench_kernels [-- <threads> [small]].
//! Writes a machine-readable summary to `$BENCH_OUT` (default
//! `BENCH_kernels.json`) for the CI regression gate
//! (`ci/compare_bench.py --section kernels`): per-backend engine
//! tok/s floors (now including `macko_pooled` and the
//! `{backend}_prefill` chunked-prefill cells), the aggregate
//! tiled/untiled throughput ratio (batches >= 4; batch 1 delegates to
//! the identical matvec on both paths, so it would only dilute the
//! signal), `pooled_serial_ratio` — best-of-3 pooled row-band
//! decode (`shard-workers = threads`) over the best-of-3 serial
//! engine, which pins that band-parallel serving never collapses
//! against the serial path (at shard-workers=1 the dispatch takes
//! the serial branch structurally, so no runtime gate is needed
//! there) — and `chunked_pertoken_ratio`, the aggregate chunked-vs-
//! per-token prefill throughput ratio, gated >= 1.0: the chunked pass
//! shares one weight walk per window and skips the head projection
//! for every prompt position but the last, so it must never lose to
//! the one-position-at-a-time cadence.
//!
//! ISSUE 7 adds the quantized serving cells: end-to-end
//! `{csr,macko}_{int8,int4}` tok/s floors (streams asserted bitwise
//! run-to-run within each mode before timing) and `int8_f32_ratio` —
//! fused-dequant int8 CSR matvec over f32 CSR matvec at the
//! bandwidth-bound decode shape (batch 1, cache-exceeding matrix),
//! gated >= 1.0: fewer payload bytes per row must never decode slower.
//!
//! ISSUE 8 adds the N:M / kernel-path matrix: `nm24_{b1,b8}`
//! end-to-end tok/s floors (streams asserted bit-identical to the f32
//! CSR engine on the same 2:4-projected checkpoint before timing),
//! `nm24_csr_ratio` — branch-free `NmSparse` batch-1 matvec over
//! unstructured CSR on the *same* projected cache-exceeding matrix,
//! gated >= 1.0 (5 B/slot with fixed trip counts must never lose to
//! 8 B/nnz with a data-dependent loop bound) — and
//! `unrolled_scalar_ratio`, the aggregate scalar/unrolled timing
//! ratio across the tiled formats at the batch-8 decode shape, gated
//! >= 1.0 after asserting both paths bit-identical: the lane-unrolled
//! traversal must never cost throughput.

use elsa::infer::pool::WorkerPool;
use elsa::infer::{Backend, BatchOptions, Engine};
use elsa::model::{synthetic_config, Params};
use elsa::pruners::{magnitude, uniform_alloc};
use elsa::sparse::{dense_matvec_batch, dense_plan, nm_project,
                   par_matvec_batch_tiled, pool_matvec_batch_tiled,
                   random_sparse_weight, tile, Csr, CsrQ, KernelPath,
                   Macko, NmMode, NmSparse, QuantMode, SpmmScratch};
use elsa::tensor::Matrix;
use elsa::util::bench::{bench, throughput};
use elsa::util::json::{num, obj, s, to_string, Value};
use elsa::util::rng::Rng;
use elsa::util::timer::Timer;

const SPARSITIES: [f64; 3] = [0.5, 0.9, 0.95];
const BATCHES: [usize; 4] = [1, 4, 8, 16];

struct SweepTotals {
    untiled_ns: f64,
    tiled_ns: f64,
}

/// One (format, sparsity, batch) cell: assert tiled == untiled
/// bitwise, time both, return (untiled_ns, tiled_ns, ratio) and push
/// a JSON row.
#[allow(clippy::too_many_arguments)]
fn cell(fmt: &str, sp: f64, b: usize, flops: f64, budget_ms: u64,
        rows: &mut Vec<Value>, totals: &mut SweepTotals,
        mut untiled: impl FnMut(&mut [f32]),
        mut tiled: impl FnMut(&mut [f32]), dout: usize) {
    let mut yu = vec![0.0f32; b * dout];
    let mut yt = vec![0.0f32; b * dout];
    untiled(&mut yu);
    tiled(&mut yt);
    assert_eq!(yu, yt, "{fmt} sp={sp} b={b}: tiled diverged from untiled");

    let ru = bench(&format!("{fmt:<6} untiled sp={sp:.2} b={b:<2}"),
                   budget_ms, || {
        untiled(&mut yu);
        std::hint::black_box(&yu);
    });
    throughput(&ru, flops, "flop");
    let rt = bench(&format!("{fmt:<6} tiled   sp={sp:.2} b={b:<2}"),
                   budget_ms, || {
        tiled(&mut yt);
        std::hint::black_box(&yt);
    });
    throughput(&rt, flops, "flop");
    let ratio = ru.median_ns / rt.median_ns.max(1e-9);
    println!("  -> tiled/untiled throughput ratio x{ratio:.2}\n");
    if b > 1 {
        totals.untiled_ns += ru.median_ns;
        totals.tiled_ns += rt.median_ns;
    }
    rows.push(obj(vec![
        ("fmt", s(fmt)),
        ("sparsity", num(sp)),
        ("batch", num(b as f64)),
        ("untiled_ns", num(ru.median_ns)),
        ("tiled_ns", num(rt.median_ns)),
        ("ratio", num(ratio)),
    ]));
}

/// Tiled vs untiled sweep; returns (json rows, per-format ratios,
/// aggregate sparse-format ratio). Weight matrices are converted once
/// per sparsity and shared by every (format, batch) cell.
fn kernel_sweep(dim: usize, budget_ms: u64)
                -> (Vec<Value>, Vec<(&'static str, f64)>, f64) {
    let mut rows: Vec<Value> = Vec::new();
    let mut totals = [
        ("csr", SweepTotals { untiled_ns: 0.0, tiled_ns: 0.0 }),
        ("macko", SweepTotals { untiled_ns: 0.0, tiled_ns: 0.0 }),
        ("dense", SweepTotals { untiled_ns: 0.0, tiled_ns: 0.0 }),
    ];
    println!("== row-tiled SpMM sweep, {dim}x{dim} ==");
    for &sp in &SPARSITIES {
        let w = random_sparse_weight(dim, dim, sp, 42);
        let flops1 = w.nnz() as f64 * 2.0;
        let csr = Csr::from_weight(&w);
        let macko = Macko::from_weight(&w);
        let dplan = dense_plan(&w);
        let mut su = SpmmScratch::default();
        let mut st = SpmmScratch::default();
        let mut rng = Rng::new(7);
        for &b in &BATCHES {
            let x: Vec<f32> =
                (0..b * dim).map(|_| rng.normal()).collect();
            let flops = flops1 * b as f64;
            cell("csr", sp, b, flops, budget_ms, &mut rows,
                 &mut totals[0].1,
                 |y| csr.matvec_batch_into(&x, y, b, &mut su),
                 |y| csr.matvec_batch_tiled_into(&x, y, b, &mut st,
                                                 KernelPath::Unrolled),
                 dim);
            cell("macko", sp, b, flops, budget_ms, &mut rows,
                 &mut totals[1].1,
                 |y| macko.matvec_batch_into(&x, y, b, &mut su),
                 |y| macko.matvec_batch_tiled_into(&x, y, b, &mut st,
                                                   KernelPath::Unrolled),
                 dim);
            cell("dense", sp, b, flops, budget_ms, &mut rows,
                 &mut totals[2].1,
                 |y| dense_matvec_batch(&w, &x, y, b),
                 |y| tile::matvec_batch_tiled(&w, &dplan, &x, y, b,
                                              &mut st,
                                              KernelPath::Unrolled),
                 dim);
        }
    }
    let mut per_fmt: Vec<(&'static str, f64)> = Vec::new();
    let mut sparse_totals = SweepTotals { untiled_ns: 0.0, tiled_ns: 0.0 };
    for (fmt, t) in &totals {
        let ratio = t.untiled_ns / t.tiled_ns.max(1e-9);
        println!("-- {fmt}: aggregate tiled/untiled x{ratio:.2} \
                  (batches > 1) --");
        let rkey = match *fmt {
            "csr" => "csr_tiled_ratio",
            "macko" => "macko_tiled_ratio",
            _ => "dense_tiled_ratio",
        };
        per_fmt.push((rkey, ratio));
        if *fmt != "dense" {
            sparse_totals.untiled_ns += t.untiled_ns;
            sparse_totals.tiled_ns += t.tiled_ns;
        }
    }
    let agg = sparse_totals.untiled_ns / sparse_totals.tiled_ns.max(1e-9);
    println!("== aggregate sparse tiled/untiled ratio x{agg:.2} ==\n");
    (rows, per_fmt, agg)
}

/// Intra-layer row-range sharding on one big layer: the tile plan is
/// split into byte-balanced shards across scoped threads — the
/// complementary axis to the scheduler's slot sharding (useful when
/// one huge layer dominates and the live slot count is small).
fn shard_sweep(dim: usize, threads: usize, budget_ms: u64) {
    let b = 8usize;
    let sp = 0.9;
    let w = random_sparse_weight(dim, dim, sp, 11);
    let csr = Csr::from_weight(&w);
    let flops = csr.nnz() as f64 * 2.0 * b as f64;
    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..b * dim).map(|_| rng.normal()).collect();
    let mut y1 = vec![0.0f32; b * dim];
    let mut yn = vec![0.0f32; b * dim];
    let mut s1 = SpmmScratch::default();
    let mut sn = SpmmScratch::default();

    println!("== intra-layer sharding, csr {dim}x{dim} sp={sp:.2} \
              b={b} ({} tiles) ==", csr.plan.tiles.len());
    par_matvec_batch_tiled(&csr, &csr.plan, &x, &mut y1, b, 1, &mut s1,
                           KernelPath::Unrolled);
    par_matvec_batch_tiled(&csr, &csr.plan, &x, &mut yn, b, threads,
                           &mut sn, KernelPath::Unrolled);
    assert_eq!(y1, yn, "sharded kernel diverged from serial tiled");

    let r = bench(&format!("csr tiled   1 shard        b={b}"),
                  budget_ms, || {
        par_matvec_batch_tiled(&csr, &csr.plan, &x, &mut y1, b, 1,
                               &mut s1, KernelPath::Unrolled);
        std::hint::black_box(&y1);
    });
    throughput(&r, flops, "flop");
    let serial_ns = r.median_ns;
    let r = bench(&format!("csr tiled   {threads} shards (spawn) b={b}"),
                  budget_ms, || {
        par_matvec_batch_tiled(&csr, &csr.plan, &x, &mut yn, b, threads,
                               &mut sn, KernelPath::Unrolled);
        std::hint::black_box(&yn);
    });
    throughput(&r, flops, "flop");
    let spawn_ns = r.median_ns;
    println!("  -> intra-layer scaling x{:.2} at {threads} threads \
              (bit-identical output)\n", serial_ns / spawn_ns.max(1e-9));

    // the same shards on the persistent pool: no thread::scope per
    // call — this is the dispatch the engine's decode loop pays, so
    // the pool-vs-spawn ratio is the whole point of ISSUE 4
    let pool = WorkerPool::new(threads);
    let mut yp = vec![0.0f32; b * dim];
    let mut sp = SpmmScratch::default();
    pool_matvec_batch_tiled(&csr, &csr.plan, &x, &mut yp, b, &pool,
                            &mut sp, KernelPath::Unrolled);
    assert_eq!(y1, yp, "pooled kernel diverged from serial tiled");
    let r = bench(&format!("csr tiled   {threads} shards (pool)  b={b}"),
                  budget_ms, || {
        pool_matvec_batch_tiled(&csr, &csr.plan, &x, &mut yp, b, &pool,
                                &mut sp, KernelPath::Unrolled);
        std::hint::black_box(&yp);
    });
    throughput(&r, flops, "flop");
    println!("  -> pool vs per-call spawn x{:.2}, pool vs serial \
              x{:.2} (bit-identical output)\n",
             spawn_ns / r.median_ns.max(1e-9),
             serial_ns / r.median_ns.max(1e-9));
}

/// The serving-sized toy model (d=128, L=2, 90% sparse) shared by the
/// end-to-end and prefill sweeps.
fn bench_model() -> (elsa::runtime::ConfigEntry, Params) {
    let cfg = synthetic_config("kern_bench", 128, 2, 4, 512, 256, 96);
    let params = Params::init(&cfg, 0);
    let pruned = magnitude::prune(&cfg, &params.flat,
                                  &uniform_alloc(&cfg, 0.9))
        .expect("magnitude prune");
    (cfg.clone(), Params::new(&cfg, pruned))
}

/// Chunked vs per-token prefill, per backend: a near-seq_len prompt is
/// consumed with `prefill_chunk = 1` (the old one-position-at-a-time
/// cadence, head projection skipped all the same) and with the default
/// window, after asserting the token streams are identical. The
/// chunked rate must never fall below the per-token rate — prompt
/// positions share one pass over each weight and the index/bitmap
/// decode amortizes across the window — which is what the CI
/// `min_chunked_pertoken_ratio` gate pins (aggregate over the sparse
/// and dense backends).
fn prefill_sweep(chunk: usize) -> (Vec<(&'static str, Value)>, f64) {
    let (cfg, p) = bench_model();
    let prompt_len = cfg.seq_len - 1;
    let mut rng = Rng::new(5);
    let prompt: Vec<u32> = (0..prompt_len)
        .map(|_| rng.below(cfg.vocab) as u32)
        .collect();
    println!("== chunked prefill, {prompt_len}-token prompt, \
              chunk {chunk} vs 1 ==");
    // best-of-3 prefill seconds for the engine's current chunk setting
    let best_prefill_s = |engine: &Engine| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let (_, stats) = engine.generate(&prompt, 1, 0.0, 0);
            best = best.min(stats.prefill_seconds);
        }
        best
    };
    let mut cells: Vec<(&'static str, Value)> = Vec::new();
    let (mut pertoken_total_s, mut chunked_total_s) = (0.0f64, 0.0f64);
    for backend in [Backend::Dense, Backend::Csr, Backend::Macko] {
        let mut engine = Engine::build(&p, backend).expect("engine");
        engine.prefill_chunk = 1;
        let (reference, _) = engine.generate(&prompt, 1, 0.0, 0); // warmup
        let pertoken_s = best_prefill_s(&engine);
        engine.prefill_chunk = chunk;
        let (got, _) = engine.generate(&prompt, 1, 0.0, 0); // warmup
        assert_eq!(got, reference,
                   "{backend:?}: prefill chunking changed the stream");
        let chunked_s = best_prefill_s(&engine);
        pertoken_total_s += pertoken_s;
        chunked_total_s += chunked_s;
        let pertoken_tps = prompt_len as f64 / pertoken_s.max(1e-9);
        let chunked_tps = prompt_len as f64 / chunked_s.max(1e-9);
        println!("{:>6}: chunked {chunked_tps:9.1} prefill tok/s vs \
                  per-token {pertoken_tps:9.1} (x{:.2}, identical \
                  stream)",
                 format!("{backend:?}"),
                 chunked_tps / pertoken_tps.max(1e-9));
        let key = match backend {
            Backend::Dense => "dense_prefill",
            Backend::Csr => "csr_prefill",
            Backend::Macko => "macko_prefill",
        };
        cells.push((key, obj(vec![
            ("tok_s", num(chunked_tps)),
            ("pertoken_tok_s", num(pertoken_tps)),
        ])));
    }
    let ratio = pertoken_total_s / chunked_total_s.max(1e-9);
    println!("== aggregate chunked/per-token prefill ratio \
              x{ratio:.2} ==\n");
    (cells, ratio)
}

/// End-to-end batched decode per backend (tiled engine): the tok/s
/// numbers the CI gate floors. Also reports macko with tiling off so
/// regressions in the *dispatch* show up, not just in the kernels,
/// plus a pooled macko cell (`shard_workers = threads`, floored as
/// `macko_pooled`) whose best-of-3 ratio against the best-of-3 serial
/// run is the CI `pooled_serial_ratio` gate — row-band decode must
/// never collapse versus the serial engine. (shard-workers=1 needs no
/// runtime gate: the dispatch takes the serial branch structurally.)
fn engine_sweep(n_new: usize, threads: usize)
                -> (Vec<(&'static str, f64)>, f64) {
    let (cfg, p) = bench_model();
    let batch = 8usize;
    let prompt_len = 8usize;
    let mut rng = Rng::new(1);
    let prompts: Vec<Vec<u32>> = (0..batch)
        .map(|_| (0..prompt_len)
             .map(|_| rng.below(cfg.vocab) as u32).collect())
        .collect();
    let opts = BatchOptions {
        n_new, temperature: 0.8, seed: 0, threads: 1,
        shard_workers: 1, ..BatchOptions::default()
    };

    println!("== end-to-end decode, d={} L={} sp=0.90, batch={batch}, \
              tiled kernels ==", cfg.d_model, cfg.n_layers);
    let mut out = Vec::new();
    let mut pooled_serial_ratio = 0.0f64;
    for backend in [Backend::Dense, Backend::Csr, Backend::Macko] {
        let mut engine = Engine::build(&p, backend).expect("engine");
        engine.generate_batch(&prompts, &opts); // warmup
        let t = Timer::start();
        let (_, stats) = engine.generate_batch(&prompts, &opts);
        let tps = stats.tokens_generated as f64 / t.seconds().max(1e-9);
        println!("{:>6}: {tps:9.1} tok/s aggregate",
                 format!("{backend:?}"));
        let key = match backend {
            Backend::Dense => "dense",
            Backend::Csr => "csr",
            Backend::Macko => "macko",
        };
        out.push((key, tps));
        if backend == Backend::Macko {
            engine.tiled = false;
            engine.generate_batch(&prompts, &opts); // warmup untiled
            let t = Timer::start();
            let (_, stats) = engine.generate_batch(&prompts, &opts);
            let utps =
                stats.tokens_generated as f64 / t.seconds().max(1e-9);
            println!("{:>6}: {utps:9.1} tok/s aggregate (untiled)",
                     "macko");
            out.push(("macko_untiled", utps));
            engine.tiled = true;

            // pooled vs serial: shard-workers=1 neutrality needs no
            // runtime gate — `matvec_batch_exec` takes the serial
            // branch structurally when the pool is single-lane — so
            // the ratio that CAN regress is multi-lane row-band decode
            // against the serial engine. Both sides are best-of-3 so
            // the gate compares throughput plateaus, not single-run
            // jitter on a shared runner.
            let best_of = |engine: &Engine, o: &BatchOptions| -> f64 {
                let mut best = 0.0f64;
                for _ in 0..3 {
                    let t = Timer::start();
                    let (_, stats) = engine.generate_batch(&prompts, o);
                    best = best.max(stats.tokens_generated as f64
                                    / t.seconds().max(1e-9));
                }
                best
            };
            let reference: Vec<Vec<u32>> =
                engine.generate_batch(&prompts, &opts).0; // warmup
            let stps = best_of(&engine, &opts);

            // row-band pooling: one scheduler worker fanning each
            // linear across `threads` persistent lanes
            let popts = BatchOptions {
                shard_workers: threads.max(2),
                ..opts.clone()
            };
            let (outs, stats) =
                engine.generate_batch(&prompts, &popts); // warmup
            assert_eq!(outs, reference,
                       "pooled decode changed the streams");
            let mtps = best_of(&engine, &popts);
            pooled_serial_ratio = mtps / stps.max(1e-9);
            println!("{:>6}: {mtps:9.1} tok/s aggregate \
                      ({} shard-workers, x{pooled_serial_ratio:.2} vs \
                      serial best-of-3 {stps:.1}, busy/idle \
                      {:.3}s/{:.3}s)",
                     "macko", popts.shard_workers,
                     stats.shard_busy_seconds, stats.shard_idle_seconds);
            out.push(("macko_pooled", mtps));
        }
    }
    println!();
    (out, pooled_serial_ratio)
}

/// Quantized decode cells (ISSUE 7): end-to-end tok/s per sparse
/// backend x quant mode on the same serving-sized model as
/// `engine_sweep` — the `{csr,macko}_{int8,int4}` floors the CI gate
/// pins. Before timing, each engine's batched streams are asserted
/// bit-identical across two runs (the within-mode determinism
/// contract; quantized decode has no f32-bitwise reference, so
/// run-to-run stability IS the pre-timing correctness check here,
/// with the tolerance parity pinned in `rust/tests/quant_parity.rs`).
fn quant_engine_sweep(n_new: usize) -> Vec<(&'static str, f64)> {
    let (cfg, p) = bench_model();
    let batch = 8usize;
    let prompt_len = 8usize;
    let mut rng = Rng::new(1);
    let prompts: Vec<Vec<u32>> = (0..batch)
        .map(|_| (0..prompt_len)
             .map(|_| rng.below(cfg.vocab) as u32).collect())
        .collect();
    let opts = BatchOptions {
        n_new, temperature: 0.8, seed: 0, threads: 1,
        shard_workers: 1, ..BatchOptions::default()
    };
    println!("== quantized end-to-end decode, d={} L={} sp=0.90, \
              batch={batch} ==", cfg.d_model, cfg.n_layers);
    let mut out = Vec::new();
    for (backend, quant, key) in [
        (Backend::Csr, QuantMode::Int8, "csr_int8"),
        (Backend::Csr, QuantMode::Int4, "csr_int4"),
        (Backend::Macko, QuantMode::Int8, "macko_int8"),
        (Backend::Macko, QuantMode::Int4, "macko_int4"),
    ] {
        let engine = Engine::build_quant(&p, backend, quant)
            .expect("quant engine");
        let (a, _) = engine.generate_batch(&prompts, &opts); // warmup
        let (b, _) = engine.generate_batch(&prompts, &opts);
        assert_eq!(a, b, "{key}: quantized decode is not bitwise \
                          reproducible within its mode");
        let t = Timer::start();
        let (_, stats) = engine.generate_batch(&prompts, &opts);
        let tps = stats.tokens_generated as f64 / t.seconds().max(1e-9);
        println!("{key:>11}: {tps:9.1} tok/s aggregate (weights {} B \
                  vs f32 backend {} B)",
                 engine.mem_bytes(),
                 Engine::build(&p, backend).expect("engine").mem_bytes());
        out.push((key, tps));
    }
    println!();
    out
}

/// The bandwidth-bound kernel cell behind the CI `min_int8_f32_ratio`
/// gate: batch-1 CSR matvec (the decode shape — one FMA per nonzero,
/// so the payload stream dominates) on a matrix sized past the last
/// cache level, f32 (8 B/nnz) vs fused-dequant int8 (~5 B/nnz).
/// Shrinking bytes-per-row must never lose to f32 here — that claim
/// is the whole point of the Elsa-L serving path.
fn quant_kernel_ratio(budget_ms: u64) -> f64 {
    let dim = 2048usize; // 2.1M nnz at sp=0.5: past L2/L3 on CI runners
    let sp = 0.5f64;
    let w = random_sparse_weight(dim, dim, sp, 23);
    let csr = Csr::from_weight(&w);
    let q = CsrQ::from_weight(&w, QuantMode::Int8).expect("csrq");
    let mut rng = Rng::new(9);
    let x: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
    let mut yf = vec![0.0f32; dim];
    let mut yq = vec![0.0f32; dim];
    csr.matvec(&x, &mut yf);
    q.matvec(&x, &mut yq);
    // sanity before timing: the quantized output tracks f32 (loose —
    // the tight analytic bound lives in sparse::quantized's tests)
    let scale = yf.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let worst = yf.iter().zip(&yq)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(worst <= 0.05 * scale + 1e-3,
            "int8 matvec error {worst} vs output scale {scale}");

    println!("== int8 vs f32 decode-shape matvec, csr {dim}x{dim} \
              sp={sp:.2} b=1 ==");
    let flops = csr.nnz() as f64 * 2.0;
    let rf = bench("csr    f32  b=1", budget_ms, || {
        csr.matvec(&x, &mut yf);
        std::hint::black_box(&yf);
    });
    throughput(&rf, flops, "flop");
    let rq = bench("csr    int8 b=1", budget_ms, || {
        q.matvec(&x, &mut yq);
        std::hint::black_box(&yq);
    });
    throughput(&rq, flops, "flop");
    let ratio = rf.median_ns / rq.median_ns.max(1e-9);
    println!("  -> int8/f32 throughput ratio x{ratio:.2} \
              ({} vs {} payload bytes)\n", q.mem_bytes(),
             csr.mem_bytes());
    ratio
}

/// One scalar-vs-unrolled cell: assert the two `KernelPath`s produce
/// bit-identical output, then time both and accumulate into
/// `(scalar_ns, unrolled_ns)` totals.
fn path_cell(fmt: &str, sp: f64, b: usize, budget_ms: u64,
             totals: &mut (f64, f64), dout: usize,
             mut run: impl FnMut(&mut [f32], KernelPath)) {
    let mut ys = vec![0.0f32; b * dout];
    let mut yu = vec![0.0f32; b * dout];
    run(&mut ys, KernelPath::Scalar);
    run(&mut yu, KernelPath::Unrolled);
    assert_eq!(ys, yu,
               "{fmt} sp={sp} b={b}: unrolled diverged from scalar");
    let rs = bench(&format!("{fmt:<6} scalar   sp={sp:.2} b={b}"),
                   budget_ms, || {
        run(&mut ys, KernelPath::Scalar);
        std::hint::black_box(&ys);
    });
    let ru = bench(&format!("{fmt:<6} unrolled sp={sp:.2} b={b}"),
                   budget_ms, || {
        run(&mut yu, KernelPath::Unrolled);
        std::hint::black_box(&yu);
    });
    totals.0 += rs.median_ns;
    totals.1 += ru.median_ns;
    println!("  -> scalar/unrolled ratio x{:.2}\n",
             rs.median_ns / ru.median_ns.max(1e-9));
}

/// Scalar vs unrolled kernel paths (ISSUE 8) across the tiled formats
/// at the batch-8 decode shape. Unrolling spreads *independent*
/// accumulators (batch lanes / output rows) across the loop body
/// without reassociating any per-accumulator sum — so both paths are
/// bit-identical (asserted per cell) and the unrolled one must never
/// cost throughput, which is what the CI `min_unrolled_scalar_ratio`
/// gate pins on the aggregate scalar/unrolled timing ratio.
fn path_sweep(dim: usize, budget_ms: u64) -> f64 {
    let b = 8usize;
    let mut totals = (0.0f64, 0.0f64);
    println!("== scalar vs unrolled kernel paths, {dim}x{dim} b={b} ==");
    for &sp in &[0.5f64, 0.9] {
        let w = random_sparse_weight(dim, dim, sp, 42);
        let csr = Csr::from_weight(&w);
        let macko = Macko::from_weight(&w);
        let nm = NmSparse::<2, 4>::from_weight(&nm_project(&w, 2, 4))
            .expect("nm24 weight");
        let dplan = dense_plan(&w);
        let mut st = SpmmScratch::default();
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..b * dim).map(|_| rng.normal()).collect();
        path_cell("csr", sp, b, budget_ms, &mut totals, dim, |y, p| {
            csr.matvec_batch_tiled_into(&x, y, b, &mut st, p)
        });
        path_cell("macko", sp, b, budget_ms, &mut totals, dim, |y, p| {
            macko.matvec_batch_tiled_into(&x, y, b, &mut st, p)
        });
        path_cell("nm24", sp, b, budget_ms, &mut totals, dim, |y, p| {
            nm.matvec_batch_tiled_into(&x, y, b, &mut st, p)
        });
        path_cell("dense", sp, b, budget_ms, &mut totals, dim, |y, p| {
            tile::matvec_batch_tiled(&w, &dplan, &x, y, b, &mut st, p)
        });
    }
    let ratio = totals.0 / totals.1.max(1e-9);
    println!("== aggregate scalar/unrolled ratio x{ratio:.2} ==\n");
    ratio
}

/// The decode-shape cell behind the CI `min_nm24_csr_ratio` gate:
/// batch-1 matvec on a cache-exceeding 2:4-projected matrix, f32 CSR
/// (8 B per nonzero, data-dependent row loop) vs branch-free
/// `NmSparse` (5 B per slot, fixed N-per-group trip counts) on the
/// SAME weights. Both walk a row's nonzeros in ascending column order
/// and padded N:M slots contribute exact zeros, so the outputs are
/// asserted bit-identical before timing; fewer payload bytes with
/// static loop bounds must never decode slower.
fn nm_kernel_ratio(budget_ms: u64) -> f64 {
    let dim = 2048usize; // past L2/L3 on CI runners, like the int8 cell
    let w = nm_project(&random_sparse_weight(dim, dim, 0.5, 23), 2, 4);
    let csr = Csr::from_weight(&w);
    let nm = NmSparse::<2, 4>::from_weight(&w).expect("nm24 weight");
    let mut rng = Rng::new(9);
    let x: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
    let mut yc = vec![0.0f32; dim];
    let mut yn = vec![0.0f32; dim];
    csr.matvec(&x, &mut yc);
    nm.matvec(&x, &mut yn, KernelPath::Unrolled);
    assert_eq!(yc, yn,
               "nm24 matvec diverged from csr on the same weights");

    println!("== nm 2:4 vs csr decode-shape matvec, {dim}x{dim} \
              b=1 ==");
    let flops = csr.nnz() as f64 * 2.0;
    let rc = bench("csr    f32  b=1", budget_ms, || {
        csr.matvec(&x, &mut yc);
        std::hint::black_box(&yc);
    });
    throughput(&rc, flops, "flop");
    let rn = bench("nm24   f32  b=1", budget_ms, || {
        nm.matvec(&x, &mut yn, KernelPath::Unrolled);
        std::hint::black_box(&yn);
    });
    throughput(&rn, flops, "flop");
    let ratio = rc.median_ns / rn.median_ns.max(1e-9);
    println!("  -> nm24/csr throughput ratio x{ratio:.2} \
              ({} vs {} payload bytes)\n", nm.mem_bytes(),
             csr.mem_bytes());
    ratio
}

/// Project every prunable linear of the bench model onto N:M so the
/// `NmWeights` build verifies — same shape as the serving example's
/// helper and the integration fixtures' `nm_params`.
fn project_params_nm(p: &Params, n: usize, m: usize) -> Params {
    let mut q = p.clone();
    for seg in q.cfg.segments.clone() {
        if seg.prunable && seg.is_matrix() {
            let w = Matrix::from_vec(
                seg.shape[0], seg.shape[1],
                q.flat[seg.offset..seg.end()].to_vec());
            let proj = nm_project(&w, n, m);
            q.flat[seg.offset..seg.end()].copy_from_slice(&proj.data);
        }
    }
    q
}

/// N:M serving cells (ISSUE 8): end-to-end decode tok/s through the
/// branch-free `NmSparse` engine at the single-stream (b=1) and
/// batched (b=8) decode shapes — the `nm24_b1`/`nm24_b8` floors the
/// CI gate pins. Before timing, each cell's token streams are
/// asserted bit-identical to the f32 CSR engine serving the same
/// 2:4-projected checkpoint (identical accumulation order — the
/// cross-format identity the kernel and engine suites pin).
fn nm_engine_sweep(n_new: usize) -> Vec<(&'static str, f64)> {
    let (cfg, p) = bench_model();
    let p = project_params_nm(&p, 2, 4);
    let prompt_len = 8usize;
    let nm_e = Engine::build_nm(&p, Backend::Csr, NmMode::N2M4)
        .expect("nm engine");
    let f32_e = Engine::build(&p, Backend::Csr).expect("csr engine");
    println!("== nm 2:4 end-to-end decode, d={} L={} (weights {} B \
              vs f32 csr {} B) ==", cfg.d_model, cfg.n_layers,
             nm_e.mem_bytes(), f32_e.mem_bytes());
    let mut rng = Rng::new(1);
    let mut out = Vec::new();
    for (b, key) in [(1usize, "nm24_b1"), (8, "nm24_b8")] {
        let prompts: Vec<Vec<u32>> = (0..b)
            .map(|_| (0..prompt_len)
                 .map(|_| rng.below(cfg.vocab) as u32).collect())
            .collect();
        let opts = BatchOptions {
            n_new, temperature: 0.8, seed: 0, threads: 1,
            shard_workers: 1, ..BatchOptions::default()
        };
        let (want, _) = f32_e.generate_batch(&prompts, &opts);
        let (got, stats) = nm_e.generate_batch(&prompts, &opts); // warmup
        assert_eq!(got, want,
                   "{key}: N:M decode diverged from f32 csr on the \
                    same projected checkpoint");
        assert_eq!(stats.nm_mode, "2:4");
        let t = Timer::start();
        let (_, stats) = nm_e.generate_batch(&prompts, &opts);
        let tps = stats.tokens_generated as f64 / t.seconds().max(1e-9);
        println!("{key:>11}: {tps:9.1} tok/s aggregate (b={b})");
        out.push((key, tps));
    }
    println!();
    out
}

fn main() {
    let threads = std::env::args()
        .nth(1)
        .and_then(|a| a.parse::<usize>().ok())
        .unwrap_or(2);
    let small = std::env::args().nth(2).as_deref() == Some("small");
    let (dim, budget_ms, n_new) =
        if small { (512, 60, 24) } else { (768, 200, 56) };

    let (rows, per_fmt, agg_ratio) = kernel_sweep(dim, budget_ms);
    shard_sweep(if small { dim } else { 1024 }, threads, budget_ms);
    let (prefill_cells, chunked_pertoken_ratio) =
        prefill_sweep(elsa::infer::DEFAULT_PREFILL_CHUNK);
    let (engine, pooled_serial_ratio) = engine_sweep(n_new, threads);
    let quant_cells = quant_engine_sweep(n_new);
    let nm_cells = nm_engine_sweep(n_new);
    let int8_f32_ratio = quant_kernel_ratio(budget_ms);
    let nm24_csr_ratio = nm_kernel_ratio(budget_ms);
    let unrolled_scalar_ratio = path_sweep(dim, budget_ms);

    // machine-readable summary for the CI regression gate
    let mut top: Vec<(&str, Value)> = vec![
        ("config", obj(vec![
            ("dim", num(dim as f64)),
            ("small", num(if small { 1.0 } else { 0.0 })),
            ("threads", num(threads as f64)),
        ])),
        ("kernels", Value::Arr(rows)),
        ("tiled_untiled_ratio", num(agg_ratio)),
        ("pooled_serial_ratio", num(pooled_serial_ratio)),
        ("chunked_pertoken_ratio", num(chunked_pertoken_ratio)),
        ("int8_f32_ratio", num(int8_f32_ratio)),
        ("nm24_csr_ratio", num(nm24_csr_ratio)),
        ("unrolled_scalar_ratio", num(unrolled_scalar_ratio)),
    ];
    for &(key, ratio) in &per_fmt {
        top.push((key, num(ratio)));
    }
    for (key, cell) in prefill_cells {
        top.push((key, cell));
    }
    for &(key, tps) in &engine {
        top.push((key, obj(vec![("tok_s", num(tps))])));
    }
    for &(key, tps) in &quant_cells {
        top.push((key, obj(vec![("tok_s", num(tps))])));
    }
    for &(key, tps) in &nm_cells {
        top.push((key, obj(vec![("tok_s", num(tps))])));
    }
    let j = obj(top);
    let path = std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_kernels.json".to_string());
    std::fs::write(&path, to_string(&j) + "\n")
        .expect("write bench summary");
    println!("wrote {path}");
}

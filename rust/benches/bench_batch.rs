//! Batched serving bench: multi-vector SpMM kernels and end-to-end
//! batched decode vs N× sequential single-sequence decode.
//!
//! The claim under test (ISSUE 1 / Table 1b): at batch=8 the batched
//! CSR/MACKO decode path yields measurably higher aggregate tokens/sec
//! than running the same 8 sequences one at a time, because index /
//! bitmap decode is amortized across the batch in the memory-bound
//! decode regime.
//!
//! Run: cargo bench --bench bench_batch [-- <threads>]

use elsa::infer::{Backend, BatchOptions, Engine};
use elsa::model::{synthetic_config, Params};
use elsa::pruners::{magnitude, uniform_alloc};
use elsa::sparse::{random_sparse_weight, Csr, Macko, SpmmScratch};
use elsa::util::bench::{bench, throughput};
use elsa::util::rng::Rng;
use elsa::util::timer::Timer;

fn kernel_sweep() {
    let (din, dout) = (768, 768);
    let sp = 0.9;
    let w = random_sparse_weight(din, dout, sp, 42);
    let nnz = w.nnz() as f64;
    let csr = Csr::from_weight(&w);
    let macko = Macko::from_weight(&w);
    let mut rng = Rng::new(7);

    println!("== SpMM {din}x{dout} sp={sp:.2}: batched vs b x matvec ==");
    for &b in &[1usize, 2, 4, 8] {
        let x: Vec<f32> = (0..b * din).map(|_| rng.normal()).collect();
        let mut y = vec![0.0f32; b * dout];

        let r = bench(&format!("csr    seq    b={b}"), 300, || {
            for bi in 0..b {
                let (xs, ys) = (&x[bi * din..(bi + 1) * din],
                                &mut y[bi * dout..(bi + 1) * dout]);
                csr.matvec(xs, ys);
            }
            std::hint::black_box(&y);
        });
        throughput(&r, nnz * 2.0 * b as f64, "flop");

        let mut scratch = SpmmScratch::default();
        let r = bench(&format!("csr    batch  b={b}"), 300, || {
            csr.matvec_batch_into(&x, &mut y, b, &mut scratch);
            std::hint::black_box(&y);
        });
        throughput(&r, nnz * 2.0 * b as f64, "flop");

        let r = bench(&format!("macko  seq    b={b}"), 300, || {
            for bi in 0..b {
                let (xs, ys) = (&x[bi * din..(bi + 1) * din],
                                &mut y[bi * dout..(bi + 1) * dout]);
                macko.matvec(xs, ys);
            }
            std::hint::black_box(&y);
        });
        throughput(&r, nnz * 2.0 * b as f64, "flop");

        let r = bench(&format!("macko  batch  b={b}"), 300, || {
            macko.matvec_batch_into(&x, &mut y, b, &mut scratch);
            std::hint::black_box(&y);
        });
        throughput(&r, nnz * 2.0 * b as f64, "flop");
        println!();
    }
}

fn engine_sweep(threads: usize) {
    // a serving-sized toy model: big enough that weight streaming
    // dominates, small enough for a bench target
    let cfg = synthetic_config("bench", 128, 2, 4, 512, 256, 96);
    let params = Params::init(&cfg, 0);
    let pruned = magnitude::prune(&cfg, &params.flat,
                                  &uniform_alloc(&cfg, 0.9))
        .expect("magnitude prune");
    let p = Params::new(&cfg, pruned);

    let prompt_len = 8;
    let n_new = 56;
    let batch = 8;
    let mut rng = Rng::new(1);
    let prompts: Vec<Vec<u32>> = (0..batch)
        .map(|_| (0..prompt_len)
             .map(|_| rng.below(cfg.vocab) as u32).collect())
        .collect();

    println!("== end-to-end decode, d={} L={} sp=0.90, batch={batch}, \
              {threads} thread(s) ==", cfg.d_model, cfg.n_layers);
    for backend in [Backend::Dense, Backend::Csr, Backend::Macko] {
        let engine = Engine::build(&p, backend).expect("engine");

        // sequential baseline: the same prompts one at a time
        let t = Timer::start();
        let mut seq_tokens = 0usize;
        for (s, prompt) in prompts.iter().enumerate() {
            let (_, stats) = engine.generate(prompt, n_new, 0.8,
                                             s as u64);
            seq_tokens += stats.tokens_generated;
        }
        let seq_s = t.seconds();
        let seq_tps = seq_tokens as f64 / seq_s;

        // batched path on identical work
        let opts = BatchOptions {
            n_new, temperature: 0.8, seed: 0, threads,
            ..BatchOptions::default()
        };
        engine.generate_batch(&prompts, &opts); // warmup
        let t = Timer::start();
        let (_, stats) = engine.generate_batch(&prompts, &opts);
        let bat_s = t.seconds();
        let bat_tps = stats.tokens_generated as f64 / bat_s;

        println!("{:>6}: sequential {seq_tps:9.1} tok/s | batched \
                  {bat_tps:9.1} tok/s | speedup x{:.2}",
                 format!("{backend:?}"), bat_tps / seq_tps);
    }
}

fn main() {
    let threads = std::env::args()
        .nth(1)
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(1);
    kernel_sweep();
    engine_sweep(threads);
    if threads == 1 {
        // show the thread-sharded numbers too
        engine_sweep(4);
    }
}

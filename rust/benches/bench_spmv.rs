//! Table-1 micro-bench: SpMV across formats and sparsities (the MACKO
//! comparison — who wins where, and the CSR/MACKO crossover).
//!
//! Run: cargo bench --bench bench_spmv

use elsa::sparse::{dense_matvec, random_sparse_weight, Csr, Macko};
use elsa::util::bench::{bench, throughput};
use elsa::util::rng::Rng;

fn main() {
    let (din, dout) = (768, 768);
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..din).map(|_| rng.normal()).collect();
    let mut y = vec![0.0f32; dout];

    println!("== SpMV {din}x{dout}, y = W^T x ==");
    for &sp in &[0.0, 0.5, 0.7, 0.9, 0.95, 0.99] {
        let w = random_sparse_weight(din, dout, sp, 42);
        let nnz = w.nnz() as f64;

        let r = bench(&format!("dense   sp={sp:.2}"), 300, || {
            dense_matvec(&w, &x, &mut y);
            std::hint::black_box(&y);
        });
        throughput(&r, (din * dout) as f64 * 2.0, "flop");

        let csr = Csr::from_weight(&w);
        let r = bench(&format!("csr     sp={sp:.2} ({} B)",
                               csr.mem_bytes()), 300, || {
            csr.matvec(&x, &mut y);
            std::hint::black_box(&y);
        });
        throughput(&r, nnz * 2.0, "flop");

        let macko = Macko::from_weight(&w);
        let r = bench(&format!("macko   sp={sp:.2} ({} B)",
                               macko.mem_bytes()), 300, || {
            macko.matvec(&x, &mut y);
            std::hint::black_box(&y);
        });
        throughput(&r, nnz * 2.0, "flop");
        println!();
    }
}

//! End-to-end generation bench across backends (the Table-1 protocol as a
//! repeatable micro-bench, with a synthetic 90%-sparse model so it runs
//! without checkpoints).
//!
//! Run: cargo bench --bench bench_generate

use elsa::infer::{Backend, Engine};
use elsa::model::Params;
use elsa::pruners::{magnitude, uniform_alloc};
use elsa::runtime::manifest::{ArtifactSpec, Segment};
use elsa::runtime::ConfigEntry;
use elsa::util::bench::bench;
use std::collections::BTreeMap;

/// A manifest-free model config mirroring `small` for engine benches.
fn bench_config() -> ConfigEntry {
    let (v, d, l, s) = (512usize, 128usize, 4usize, 64usize);
    let f = 4 * d;
    let mut segments = vec![];
    let mut off = 0usize;
    let mut add = |name: String, shape: Vec<usize>, prunable: bool,
                   init: &str, segs: &mut Vec<Segment>| {
        let len: usize = shape.iter().product();
        segs.push(Segment { name, offset: off, shape, prunable,
                            init: init.into() });
        off += len;
    };
    add("embed".into(), vec![v, d], false, "normal", &mut segments);
    add("pos".into(), vec![s, d], false, "normal", &mut segments);
    for i in 0..l {
        let p = format!("l{i}.");
        add(p.clone() + "ln1.g", vec![d], false, "ones", &mut segments);
        add(p.clone() + "ln1.b", vec![d], false, "zeros", &mut segments);
        for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
            add(p.clone() + w, vec![d, d], true, "normal", &mut segments);
        }
        add(p.clone() + "ln2.g", vec![d], false, "ones", &mut segments);
        add(p.clone() + "ln2.b", vec![d], false, "zeros", &mut segments);
        add(p.clone() + "mlp.w1", vec![d, f], true, "normal",
            &mut segments);
        add(p.clone() + "mlp.b1", vec![f], false, "zeros", &mut segments);
        add(p.clone() + "mlp.w2", vec![f, d], true, "normal",
            &mut segments);
        add(p.clone() + "mlp.b2", vec![d], false, "zeros", &mut segments);
    }
    add("lnf.g".into(), vec![d], false, "ones", &mut segments);
    add("lnf.b".into(), vec![d], false, "zeros", &mut segments);
    add("head".into(), vec![d, v], false, "normal", &mut segments);
    ConfigEntry {
        name: "bench".into(), vocab: v, d_model: d, n_layers: l,
        n_heads: 4, seq_len: s, batch: 8, eval_batch: 8, d_ff: f,
        lora_rank: 4, lora_alpha: 8.0, flat_len: off, lora_len: 0,
        segments, lora_segments: vec![],
        artifacts: BTreeMap::<String, ArtifactSpec>::new(),
    }
}

fn main() {
    let cfg = bench_config();
    for &sp in &[0.0, 0.9, 0.95] {
        let mut params = Params::init(&cfg, 7);
        if sp > 0.0 {
            params.flat = magnitude::prune(&cfg, &params.flat,
                                           &uniform_alloc(&cfg, sp))
                .unwrap();
        }
        for backend in [Backend::Dense, Backend::Csr, Backend::Macko] {
            let engine = Engine::build(&params, backend).unwrap();
            let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8];
            let n_new = cfg.seq_len - prompt.len();
            let r = bench(
                &format!("generate {backend:?} sp={sp:.2} ({} new tok)",
                         n_new),
                2500,
                || {
                    std::hint::black_box(
                        engine.generate(&prompt, n_new, 0.8, 0));
                });
            let ms_per_tok = r.median_ns / 1e6 / n_new as f64;
            println!("  -> {:.3} ms/token | weights {}", ms_per_tok,
                     elsa::util::human_bytes(engine.mem_bytes()));
        }
        println!();
    }
}

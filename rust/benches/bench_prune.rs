//! Pool-parallel pruning bench (ISSUE 9): every one-shot method
//! (magnitude, wanda, sparsegpt, l-admm, alps) timed serially and on a
//! persistent `--workers N` pool over the serving-sized toy model.
//!
//! Before a single cell is timed, the pooled output is asserted
//! **bitwise identical** to the serial output for every method — the
//! whole point of the column-sharded solvers is that lane interleaving
//! cannot change a bit, and a bench that silently measured a diverging
//! pruner would be worse than no bench.
//!
//! Calibration statistics are collected once outside the timed region
//! (both paths share them), so each cell measures pruning itself.
//!
//! Run: cargo bench --bench bench_prune [-- <workers> [small]].
//! Writes a machine-readable summary to `$BENCH_OUT` (default
//! `BENCH_prune.json`) for the CI regression gate
//! (`ci/compare_bench.py --section prune`): per-method
//! weight-equivalent throughput cells `{method}_w1` / `{method}_par`
//! (`tok_s` = prunable weights pruned per second — the tok/s slot the
//! shared gate machinery floors) and `prune_parallel_serial_ratio`,
//! the aggregate serial/parallel timing ratio across all five methods,
//! gated >= 1.0: fanning independent columns/segments across persistent
//! lanes must never cost wall-clock against the serial walk.

use elsa::infer::pool::WorkerPool;
use elsa::model::{synthetic_config, Params};
use elsa::pruners::{calibrate, ladmm, magnitude, sparsegpt,
                    uniform_alloc, wanda};
use elsa::util::bench::{bench, throughput};
use elsa::util::json::{num, obj, to_string, Value};
use elsa::util::rng::Rng;

/// (method, serial-cell key, parallel-cell key) — fixed key names so
/// the committed baseline floors match regardless of the worker count
/// the CI invocation picks.
const METHODS: [(&str, &str, &str); 5] = [
    ("magnitude", "magnitude_w1", "magnitude_par"),
    ("wanda", "wanda_w1", "wanda_par"),
    ("sparsegpt", "sparsegpt_w1", "sparsegpt_par"),
    ("l-admm", "ladmm_w1", "ladmm_par"),
    ("alps", "alps_w1", "alps_par"),
];

fn main() {
    let workers = std::env::args()
        .nth(1)
        .and_then(|a| a.parse::<usize>().ok())
        .unwrap_or(2)
        .max(2);
    let small = std::env::args().nth(2).as_deref() == Some("small");
    let (d, mlp, seq, budget_ms) =
        if small { (96, 384, 64, 60) } else { (128, 512, 96, 200) };

    let cfg = synthetic_config("prune_bench", d, 2, 4, mlp, 256, seq);
    let dense = Params::init(&cfg, 3).flat;
    let mut rng = Rng::new(11);
    let train: Vec<u32> =
        (0..8192).map(|_| rng.below(cfg.vocab) as u32).collect();
    let sp = 0.7f64;
    let alloc = uniform_alloc(&cfg, sp);
    let calib = calibrate(&cfg, &dense, &train, 7).expect("calibration");
    let weights: f64 = cfg.segments
        .iter()
        .filter(|s| s.prunable)
        .map(|s| s.len() as f64)
        .sum();
    let pool = WorkerPool::new(workers);

    let run = |method: &str, pool: Option<&WorkerPool>| -> Vec<f32> {
        match method {
            "magnitude" => {
                magnitude::prune_pooled(&cfg, &dense, &alloc, pool)
            }
            "wanda" => {
                wanda::prune_pooled(&cfg, &dense, &calib, &alloc, pool)
            }
            "sparsegpt" => sparsegpt::prune_pooled(
                &cfg, &dense, &calib, &alloc, pool),
            "l-admm" => ladmm::prune_pooled(
                &cfg, &dense, &calib, &alloc,
                &ladmm::LAdmmOptions::default(), pool),
            "alps" => ladmm::prune_pooled(
                &cfg, &dense, &calib, &alloc,
                &ladmm::LAdmmOptions::alps(), pool),
            other => panic!("unknown method {other}"),
        }
        .expect("prune")
    };

    println!("== pool-parallel pruning, d={d} L=2 mlp={mlp} \
              ({weights:.0} prunable weights) @ sp={sp}, \
              workers {{1, {workers}}} ==");
    let mut cells: Vec<(&'static str, f64)> = Vec::new();
    let (mut serial_ns, mut parallel_ns) = (0.0f64, 0.0f64);
    for (method, key_w1, key_par) in METHODS {
        // determinism first: --workers N must be bit-identical to
        // --workers 1 before either cell's timing means anything
        let want = run(method, None);
        let got = run(method, Some(&pool));
        assert_eq!(want, got,
                   "{method}: pooled prune diverged from serial");

        let rs = bench(&format!("{method:<9} workers=1"), budget_ms,
                       || {
            std::hint::black_box(run(method, None));
        });
        throughput(&rs, weights, "w");
        let rp = bench(&format!("{method:<9} workers={workers}"),
                       budget_ms, || {
            std::hint::black_box(run(method, Some(&pool)));
        });
        throughput(&rp, weights, "w");
        serial_ns += rs.median_ns;
        parallel_ns += rp.median_ns;
        println!("  -> serial/parallel ratio x{:.2} (bit-identical \
                  output)\n", rs.median_ns / rp.median_ns.max(1e-9));
        cells.push((key_w1, weights / (rs.median_ns / 1e9)));
        cells.push((key_par, weights / (rp.median_ns / 1e9)));
    }
    let ratio = serial_ns / parallel_ns.max(1e-9);
    println!("== aggregate serial/parallel pruning ratio x{ratio:.2} \
              at {workers} workers ==\n");

    // machine-readable summary for the CI regression gate
    let mut top: Vec<(&str, Value)> = vec![
        ("config", obj(vec![
            ("d_model", num(d as f64)),
            ("small", num(if small { 1.0 } else { 0.0 })),
            ("workers", num(workers as f64)),
            ("sparsity", num(sp)),
            ("prunable_weights", num(weights)),
        ])),
        ("prune_parallel_serial_ratio", num(ratio)),
    ];
    for &(key, tps) in &cells {
        top.push((key, obj(vec![("tok_s", num(tps))])));
    }
    let j = obj(top);
    let path = std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_prune.json".to_string());
    std::fs::write(&path, to_string(&j) + "\n")
        .expect("write bench summary");
    println!("wrote {path}");
}

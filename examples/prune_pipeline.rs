//! End-to-end driver (the EXPERIMENTS.md §E2E run): the full system on a
//! real small workload, proving all layers compose.
//!
//!   1. pretrain the `small` (~0.9M param) transformer on synth-c4,
//!      logging the loss curve (L2 train_step HLO driven from rust),
//!   2. prune to 90% with ELSA (global Fisher-weighted ADMM projection)
//!      and with SparseGPT as the layer-wise comparator (`--workers N`
//!      fans the comparator across pool lanes, `--alloc` picks the
//!      cross-layer budget — both flow through `prune_oneshot` and are
//!      bit-identical to the serial/uniform defaults),
//!   3. evaluate perplexity on both held-out corpora + the 7-task
//!      zero-shot probe suite,
//!   4. write a summary table to results/e2e.{csv,md}.
//!
//! Run: `cargo run --release --example prune_pipeline
//!       [-- --steps 600 --workers 4]`

use std::path::Path;

use anyhow::Result;
use elsa::cli::Args;
use elsa::coordinator::elsa::{prune_elsa, ElsaOptions};
use elsa::coordinator::eval_ppl;
use elsa::coordinator::pretrain::{pretrain, PretrainOptions};
use elsa::data::{Dataset, Grammar};
use elsa::eval::{build_suite, score_task};
use elsa::model::Params;
use elsa::report::{f2, pct, Table};
use elsa::runtime::Runtime;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = if argv.is_empty() {
        Args::parse(&["e2e".to_string()])?
    } else {
        let mut full = vec!["e2e".to_string()];
        full.extend(argv);
        Args::parse(&full)?
    };

    let rt = Runtime::load(Path::new("artifacts"))?;
    let cfg = rt.manifest.config(&args.str_or("config", "small"))?.clone();
    let c4 = Dataset::standard("synth-c4", cfg.vocab);
    let wiki = Dataset::standard("synth-wiki", cfg.vocab);

    // --- 1. pretraining with loss curve --------------------------------
    let steps = args.usize_or("steps", 600)?;
    println!("[1/4] pretraining {} ({} params) for {steps} steps",
             cfg.name, cfg.flat_len);
    let mut popts = PretrainOptions::new(steps);
    popts.log_every = 50;
    let t0 = std::time::Instant::now();
    let (dense, losses) = pretrain(&rt, &cfg, &c4.train, &popts)?;
    println!("  loss curve (every 50): {:?}",
             losses.iter().step_by(50).map(|l| (l * 100.0).round() / 100.0)
                   .collect::<Vec<_>>());
    println!("  pretrain wall: {:.1}s", t0.elapsed().as_secs_f64());
    let dense_c4 = eval_ppl(&rt, &cfg, &dense, &c4.valid)?;
    let dense_wiki = eval_ppl(&rt, &cfg, &dense, &wiki.valid)?;
    println!("  dense ppl: c4={dense_c4:.2} wiki={dense_wiki:.2}");

    // --- 2. prune: ELSA vs SparseGPT at 90% -----------------------------
    let sp = args.f64_or("sparsity", 0.9)?;
    println!("[2/4] ELSA @ {:.0}%", sp * 100.0);
    let mut eopts = ElsaOptions::new(sp, args.usize_or("elsa-steps", 300)?);
    eopts.lam = 2e-2;
    let (elsa_p, metrics) = prune_elsa(&rt, &cfg, &c4.train, &dense,
                                       &eopts)?;
    println!("  achieved {:.4}, final residual {:.2e}, {:.1}s",
             metrics.achieved_sparsity,
             metrics.residuals.last().map(|r| r.1).unwrap_or(f64::NAN),
             metrics.wall_seconds);

    println!("[2/4] SparseGPT @ {:.0}% (layer-wise comparator)",
             sp * 100.0);
    let sg_p = elsa::pruners::prune_oneshot(&rt, &cfg, "sparsegpt", &dense,
                                            &c4.train, sp, &args)?;

    // --- 3. evaluate ----------------------------------------------------
    println!("[3/4] evaluating");
    let g = Grammar::named("synth-c4", cfg.vocab);
    let suite = build_suite(&g, 30, 0xE2E);
    let mut table = Table::new(
        &format!("E2E pipeline — {} @ {:.0}% sparsity", cfg.name,
                 sp * 100.0),
        &["model", "ppl_c4", "ppl_wiki", "zeroshot_avg", "sparsity"]);
    for (name, params) in [("dense", &dense), ("elsa", &elsa_p),
                           ("sparsegpt", &sg_p)] {
        let pc = eval_ppl(&rt, &cfg, params, &c4.valid)?;
        let pw = eval_ppl(&rt, &cfg, params, &wiki.valid)?;
        let pobj = Params::new(&cfg, params.clone());
        let mut acc = 0.0;
        for (_, exs) in &suite {
            acc += score_task(&pobj, exs)?;
        }
        acc /= suite.len() as f64;
        println!("  {name:10} ppl c4={pc:7.2} wiki={pw:7.2} \
                  zs={:.1}% sparsity={:.3}", acc * 100.0, pobj.sparsity());
        table.row(vec![name.into(), f2(pc), f2(pw), pct(acc),
                       format!("{:.4}", pobj.sparsity())]);
    }

    // --- 4. persist -----------------------------------------------------
    let path = table.save(Path::new("results"), "e2e")?;
    println!("[4/4] wrote {}", path.display());
    Ok(())
}
